#!/usr/bin/env bash
# Perf trajectory seeder: times `repro --fig 7` end-to-end and the
# functional executor (single-worker vs shard-parallel, interval pipeline
# on vs off, blocked vs simd vs legacy kernels, plus a 1/2/4/8-worker
# sweep over the persistent pool) and writes the results to
# BENCH_exec.json at the repo root, then drives the serving engine's
# closed-loop load generator into BENCH_serve.json beside it — at the
# default micro-batch cap and again pinned to caps 1 and 8, landing the
# serve_batch1_p50_ms / serve_batch8_p50_ms spread and the executor's
# exec_batch_amortization probe in the same artifact. Re-run
# before and after a perf-relevant change and diff the two files
# (scripts/bench_diff.sh automates the diff and is what CI's bench-diff
# gate runs). CI's bench job uploads both files as artifacts
# (.github/workflows/ci.yml).
#
# The executor numbers come from `bench --metrics` — the process metrics
# registry is the single source (the same numbers the table and the
# `exec_*=` trailers render); this script only re-keys the registry
# snapshot into the historical BENCH_exec.json shape.
#
# Env knobs: SCALE (default 6, the harness default), ITERS (default 3),
# OUT (default BENCH_exec.json), BENCH_MODEL / BENCH_DATASET (GCN / AK),
# SERVE_REQUESTS (default 64) / SERVE_OUT (default BENCH_serve.json).
set -euo pipefail
cd "$(dirname "$0")/.."

SCALE="${SCALE:-6}"
ITERS="${ITERS:-3}"
OUT="${OUT:-BENCH_exec.json}"
MODEL="${BENCH_MODEL:-GCN}"
DATASET="${BENCH_DATASET:-AK}"
BIN=rust/target/release/switchblade

if [[ ! -x "$BIN" ]]; then
  echo "building release binary..." >&2
  (cd rust && cargo build --release)
fi

echo "timing repro --fig 7 (scale $SCALE)..." >&2
t0=$(date +%s.%N)
"$BIN" repro --fig 7 --scale "$SCALE" --out results >/dev/null
t1=$(date +%s.%N)
repro_s=$(awk -v a="$t0" -v b="$t1" 'BEGIN { printf "%.3f", b - a }')

METRICS=$(mktemp "${TMPDIR:-/tmp}/bench_metrics.XXXXXX.json")
trap 'rm -f "$METRICS"' EXIT

echo "timing executor ($MODEL on $DATASET, $ITERS iters, profiled, worker sweep)..." >&2
bench_out=$("$BIN" bench --model "$MODEL" --dataset "$DATASET" --scale "$SCALE" \
  --iters "$ITERS" --profile --sweep --metrics "$METRICS")

# Pull one value out of the flat metrics JSON (one "name": value per line).
m() { sed -n "s/^ *\"$1\": *\(.*\)$/\1/p" "$METRICS" | head -1 | tr -d ','; }
# Default for optional keys so the JSON stays valid if a section is absent.
md() { v=$(m "$1"); printf '%s' "${v:-$2}"; }
# The profile JSON is nested, so it rides on the stdout trailer instead.
get() { printf '%s\n' "$bench_out" | sed -n "s/^$1=//p" | head -1; }

# exec_pipeline_on / exec_bitmatch are 0/1 counters in the registry;
# BENCH_exec.json keeps the historical string/bool spellings.
pipeline=$([ "$(md exec_pipeline_on 0)" = "1" ] && echo on || echo off)
bitmatch=$([ "$(md exec_bitmatch 0)" = "1" ] && echo true || echo false)

cat > "$OUT" <<EOF
{
  "scale": $SCALE,
  "repro_fig7_s": $repro_s,
  "bench_model": "$MODEL",
  "bench_dataset": "$DATASET",
  "exec_ms_single": $(m exec_ms_single),
  "exec_ms_parallel": $(m exec_ms_parallel),
  "exec_ms_simd": $(md exec_ms_simd null),
  "exec_ms_pipeline_off": $(md exec_ms_pipeline_off null),
  "exec_ms_legacy": $(md exec_ms_legacy null),
  "exec_ms_w1": $(md exec_ms_w1 null),
  "exec_ms_w2": $(md exec_ms_w2 null),
  "exec_ms_w4": $(md exec_ms_w4 null),
  "exec_ms_w8": $(md exec_ms_w8 null),
  "exec_workers": $(m exec_workers),
  "exec_speedup": $(m exec_speedup),
  "exec_simd_speedup": $(md exec_simd_speedup null),
  "exec_pool_spawned": $(md exec_pool_spawned 0),
  "exec_pool_batches": $(md exec_pool_batches 0),
  "exec_pool_utilization": $(md exec_pool_utilization 0),
  "exec_pool_queue_depth": $(md exec_pool_queue_depth 0),
  "exec_pipeline": "$pipeline",
  "exec_pipeline_speedup": $(md exec_pipeline_speedup null),
  "exec_prepared": $(md exec_prepared 0),
  "exec_bitmatch": $bitmatch,
  "exec_scratch_hits": $(md exec_scratch_hits 0),
  "exec_scratch_misses": $(md exec_scratch_misses 0),
  "exec_scratch_hit_rate": $(md exec_scratch_hit_rate 0),
  "profile": $(v=$(get exec_profile_json); printf '%s' "${v:-null}")
}
EOF
echo "wrote $OUT:" >&2
cat "$OUT"

# Serving trajectory point: closed-loop load through the persistent
# native engine. `serve --bench` writes the flat JSON itself — same
# one-key-per-line shape as BENCH_exec.json, same bench_diff.sh gate.
SERVE_OUT="${SERVE_OUT:-BENCH_serve.json}"
SERVE_REQUESTS="${SERVE_REQUESTS:-64}"
echo "timing serving engine ($MODEL on $DATASET, $SERVE_REQUESTS closed-loop requests)..." >&2
"$BIN" serve --model "$MODEL" --dataset "$DATASET" --scale "$SCALE" \
  --bench --requests "$SERVE_REQUESTS" --out "$SERVE_OUT" >/dev/null

# Cross-request batching trajectory: the same closed loop pinned to
# micro-batch caps 1 and 8 — the p50 spread is the serving-side
# amortization win — plus the executor-layer probe's solo/batched wall
# ratio from `bench --batch-size`. All three keys are spliced into
# BENCH_serve.json so bench_diff.sh gates the batched latencies the same
# way it gates the rest of the serving trajectory.
echo "timing serving engine at micro-batch caps 1 and 8..." >&2
B1=$(mktemp "${TMPDIR:-/tmp}/bench_serve_b1.XXXXXX.json")
B8=$(mktemp "${TMPDIR:-/tmp}/bench_serve_b8.XXXXXX.json")
trap 'rm -f "$METRICS" "$B1" "$B8"' EXIT
"$BIN" serve --model "$MODEL" --dataset "$DATASET" --scale "$SCALE" \
  --bench --requests "$SERVE_REQUESTS" --batch 1 --out "$B1" >/dev/null
"$BIN" serve --model "$MODEL" --dataset "$DATASET" --scale "$SCALE" \
  --bench --requests "$SERVE_REQUESTS" --batch 8 --out "$B8" >/dev/null
sv() { sed -n "s/^ *\"serve_p50_ms\": *\(.*\)$/\1/p" "$1" | head -1 | tr -d ','; }
batch1_p50=$(sv "$B1")
batch8_p50=$(sv "$B8")

echo "probing executor batch amortization (batch 8)..." >&2
amort=$("$BIN" bench --model "$MODEL" --dataset "$DATASET" --scale "$SCALE" \
  --iters "$ITERS" --batch-size 8 | sed -n 's/^exec_batch_amortization=//p' | head -1)

awk -v b1="${batch1_p50:-null}" -v b8="${batch8_p50:-null}" -v am="${amort:-null}" '
  NR == 1 && /^{/ {
    print
    printf "  \"serve_batch1_p50_ms\": %s,\n", b1
    printf "  \"serve_batch8_p50_ms\": %s,\n", b8
    printf "  \"exec_batch_amortization\": %s,\n", am
    next
  }
  { print }
' "$SERVE_OUT" > "$SERVE_OUT.tmp" && mv "$SERVE_OUT.tmp" "$SERVE_OUT"

echo "wrote $SERVE_OUT:" >&2
cat "$SERVE_OUT"
