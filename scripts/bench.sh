#!/usr/bin/env bash
# Perf trajectory seeder: times `repro --fig 7` end-to-end and the
# functional executor (single-worker vs shard-parallel, interval pipeline
# on vs off, kernel vs legacy) and writes the results to BENCH_exec.json
# at the repo root. Re-run before and after a perf-relevant change and
# diff the two files. CI's scheduled bench job uploads this file as an
# artifact (.github/workflows/ci.yml).
#
# Env knobs: SCALE (default 6, the harness default), ITERS (default 3),
# OUT (default BENCH_exec.json), BENCH_MODEL / BENCH_DATASET (GCN / AK).
set -euo pipefail
cd "$(dirname "$0")/.."

SCALE="${SCALE:-6}"
ITERS="${ITERS:-3}"
OUT="${OUT:-BENCH_exec.json}"
MODEL="${BENCH_MODEL:-GCN}"
DATASET="${BENCH_DATASET:-AK}"
BIN=rust/target/release/switchblade

if [[ ! -x "$BIN" ]]; then
  echo "building release binary..." >&2
  (cd rust && cargo build --release)
fi

echo "timing repro --fig 7 (scale $SCALE)..." >&2
t0=$(date +%s.%N)
"$BIN" repro --fig 7 --scale "$SCALE" --out results >/dev/null
t1=$(date +%s.%N)
repro_s=$(awk -v a="$t0" -v b="$t1" 'BEGIN { printf "%.3f", b - a }')

echo "timing executor ($MODEL on $DATASET, $ITERS iters, profiled)..." >&2
bench_out=$("$BIN" bench --model "$MODEL" --dataset "$DATASET" --scale "$SCALE" --iters "$ITERS" --profile)

get() { printf '%s\n' "$bench_out" | sed -n "s/^$1=//p" | head -1; }
# Default for optional keys so the JSON stays valid if a section is absent.
getd() { v=$(get "$1"); printf '%s' "${v:-$2}"; }

cat > "$OUT" <<EOF
{
  "scale": $SCALE,
  "repro_fig7_s": $repro_s,
  "bench_model": "$MODEL",
  "bench_dataset": "$DATASET",
  "exec_ms_single": $(get exec_ms_single),
  "exec_ms_parallel": $(get exec_ms_parallel),
  "exec_ms_pipeline_off": $(getd exec_ms_pipeline_off null),
  "exec_ms_legacy": $(getd exec_ms_legacy null),
  "exec_workers": $(get exec_workers),
  "exec_speedup": $(get exec_speedup),
  "exec_pipeline": "$(getd exec_pipeline on)",
  "exec_pipeline_speedup": $(getd exec_pipeline_speedup null),
  "exec_prepared": $(getd exec_prepared 0),
  "exec_bitmatch": $(get exec_bitmatch),
  "exec_scratch_hits": $(getd exec_scratch_hits 0),
  "exec_scratch_misses": $(getd exec_scratch_misses 0),
  "profile": $(getd exec_profile_json null)
}
EOF
echo "wrote $OUT:" >&2
cat "$OUT"
