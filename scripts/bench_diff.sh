#!/usr/bin/env bash
# Perf-regression gate: compare two bench artifacts and fail when a
# latency key regressed beyond a threshold.
#
#   scripts/bench_diff.sh BASELINE.json CANDIDATE.json [MAX_PCT]
#
# Both files may be BENCH_exec.json (scripts/bench.sh), BENCH_serve.json
# (`switchblade serve --bench`) or a raw `switchblade bench --metrics`
# snapshot — each is flat JSON with one "name": value pair per line, so
# the same sed extraction works on all of them.
#
# Gated keys (lower is better): exec_ms_parallel (the headline number),
# exec_ms_single, exec_ms_simd, exec_ms_pipeline_off, the worker-sweep
# points exec_ms_w1/w2/w4/w8, repro_fig7_s, the serving-engine tail
# latencies serve_p50_ms/serve_p95_ms/serve_p99_ms, and the batched
# serving points serve_batch1_p50_ms/serve_batch8_p50_ms. A key missing
# or non-numeric on either side is reported and skipped, never fatal —
# an exec artifact has no serve keys and vice versa, a raw metrics file
# has no repro_fig7_s, and an old baseline may predate a key. The gate
# fails (exit 1) only when a key present on both sides regressed by more
# than MAX_PCT percent (default 10).
#
# Fault/recovery counters (serve_errors, serve_timeouts, and the
# exec_worker_panics / serve_entry_restarts / serve_degraded metrics) are
# deliberately NOT gated: they are workload facts, not latencies — a
# chaos run with injected faults must not trip the perf gate. Neither is
# exec_batch_amortization: it is a higher-is-better ratio, so the
# lower-is-better latency gate would read an improvement as a
# regression; it rides in BENCH_serve.json for the trajectory record.
#
# Exit codes: 0 ok / nothing comparable, 1 regression, 2 usage error.
set -euo pipefail

if [[ $# -lt 2 || $# -gt 3 ]]; then
  echo "usage: $0 BASELINE.json CANDIDATE.json [MAX_PCT]" >&2
  exit 2
fi
BASE="$1"
CAND="$2"
MAX_PCT="${3:-${BENCH_DIFF_MAX_PCT:-10}}"

for f in "$BASE" "$CAND"; do
  if [[ ! -f "$f" ]]; then
    echo "bench_diff: '$f' not found — nothing to gate, skipping" >&2
    exit 0
  fi
done

# One value from flat JSON: `"key": 12.5,` -> `12.5` (first match wins).
val() { sed -n "s/^ *\"$2\": *\(.*\)$/\1/p" "$1" | head -1 | tr -d ', '; }

is_num() { [[ "$1" =~ ^-?[0-9]+([.][0-9]+)?([eE][+-]?[0-9]+)?$ ]]; }

fail=0
compared=0
for key in exec_ms_parallel exec_ms_single exec_ms_simd exec_ms_pipeline_off \
           exec_ms_w1 exec_ms_w2 exec_ms_w4 exec_ms_w8 repro_fig7_s \
           serve_p50_ms serve_p95_ms serve_p99_ms \
           serve_batch1_p50_ms serve_batch8_p50_ms; do
  b=$(val "$BASE" "$key")
  c=$(val "$CAND" "$key")
  if ! is_num "${b:-x}" || ! is_num "${c:-x}"; then
    echo "bench_diff: $key — not numeric on both sides (base='${b:-<missing>}', cand='${c:-<missing>}'), skipped"
    continue
  fi
  compared=$((compared + 1))
  # Percent change, guarded against a ~zero baseline (timer noise).
  verdict=$(awk -v b="$b" -v c="$c" -v m="$MAX_PCT" 'BEGIN {
    if (b <= 1e-9) { print "OK 0.0"; exit }
    pct = 100.0 * (c - b) / b
    print (pct > m ? "REGRESSED" : "OK"), sprintf("%+.1f", pct)
  }')
  state=${verdict%% *}
  pct=${verdict#* }
  echo "bench_diff: $key — base $b, candidate $c (${pct}%, limit +${MAX_PCT}%): $state"
  if [[ "$state" == "REGRESSED" ]]; then
    fail=1
  fi
done

if [[ $compared -eq 0 ]]; then
  echo "bench_diff: no comparable keys between $BASE and $CAND — skipping gate" >&2
  exit 0
fi
if [[ $fail -ne 0 ]]; then
  echo "bench_diff: FAIL — latency regressed beyond ${MAX_PCT}% against $BASE" >&2
  exit 1
fi
echo "bench_diff: OK — no key regressed beyond ${MAX_PCT}%"
