#!/usr/bin/env bash
# Repo-wide check: format, lints, release build, and the tier-1 test
# suite. Run from anywhere; requires the rust toolchain on PATH.
set -euo pipefail
SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"
cd "$SCRIPT_DIR/../rust"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (all targets, warnings are errors) =="
cargo clippy --all-targets -- -D warnings

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

# Zoo smoke: every shipped .gnn spec must survive the CLI pipeline —
# compile, simulate (tiny scale), and the executor-vs-oracle diff — so a
# grammar or spec regression fails fast.
echo "== zoo smoke: compile + simulate + validate examples/models/*.gnn =="
for f in "$SCRIPT_DIR"/../examples/models/*.gnn; do
  echo "--- $(basename "$f")"
  cargo run --release --quiet -- compile --model-file "$f" > /dev/null
  cargo run --release --quiet -- simulate --model-file "$f" AK --scale 12 > /dev/null
  cargo run --release --quiet -- validate --model-file "$f" --scale 11 > /dev/null
done

# Profiler smoke: `bench --profile` at tiny scale — the walk-level phase
# profiler and the kernel-vs-legacy differential path must not rot, and
# the profile JSON trailer bench.sh embeds must stay present.
echo "== profiler smoke: bench --profile at tiny scale =="
prof_out=$(cargo run --release --quiet -- bench --model GCN --dataset AK \
  --scale 12 --iters 1 --profile)
echo "$prof_out" | grep -q '^exec_profile_json={' \
  || { echo "bench --profile lost its exec_profile_json trailer" >&2; exit 1; }
echo "$prof_out" | grep -q '^exec_ms_legacy=' \
  || { echo "bench --profile lost its exec_ms_legacy trailer" >&2; exit 1; }
echo "profiler smoke OK"

# Optional perf step: BENCH=1 ./scripts/check.sh also records the wall
# clock of `repro --fig 7` + executor throughput into BENCH_exec.json.
if [[ "${BENCH:-0}" != "0" ]]; then
  echo "== bench (BENCH=1) =="
  "$SCRIPT_DIR/bench.sh"
fi

echo "all checks passed"
