#!/usr/bin/env bash
# Repo-wide check, stage-selectable so CI can run stages as separate jobs:
#
#   scripts/check.sh              # everything (fmt clippy test smoke profiler)
#   scripts/check.sh fmt          # one stage
#   scripts/check.sh clippy test  # any subset, in the given order
#
# Stages:
#   fmt        cargo fmt --check
#   clippy     cargo clippy --all-targets -- -D warnings
#   test       tier-1 gate: cargo build --release && cargo test -q
#   test-simd  SIMD slice: every test with `simd` in its name (kernel
#              tail shapes + the executor-level Simd differential) — the
#              second leg of CI's test-job kernel matrix
#   smoke      zoo smoke: compile + simulate + validate examples/models/*.gnn
#   profiler   `bench --profile` at tiny scale + its machine-readable trailers
#   trace      `bench --trace/--metrics` at tiny scale: Chrome-trace JSON
#              schema sanity + metrics self-diff through bench_diff.sh
#   serve      serving-engine smoke at tiny scale: native engine over a zoo
#              model + the out-of-zoo gin spec with --verify (bit-identity
#              to a direct executor run), trailer pins, and a
#              `serve --bench` artifact that self-diffs clean
#   chaos      reliability gate: the chaos integration suite (injected
#              worker panics, stalls, NaNs against the real stack) + a
#              `serve --inject` smoke pinning the recovery trailers
#   batch      cross-request batching smoke: the batched differential
#              tests + `bench --batch-size` trailer pins (bit-identity
#              and amortization) + a `serve --batch 8 --verify` run
#   bench      scripts/bench.sh -> BENCH_exec.json + BENCH_serve.json
#              (perf trajectory point)
#   bench-diff scripts/bench_diff.sh BENCH_exec.json (and BENCH_serve.json
#              when present) against $BASELINE (skips gracefully when no
#              baseline is present)
#   all        fmt clippy test smoke profiler trace serve chaos batch
#              (+ bench when BENCH=1, the historical knob)
set -euo pipefail
SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"
cd "$SCRIPT_DIR/../rust"

if ! command -v cargo >/dev/null 2>&1; then
  echo "error: check.sh needs the rust toolchain, but 'cargo' is not on PATH." >&2
  echo "       Install it from https://rustup.rs (or run inside an image that" >&2
  echo "       ships it) and re-run. No stage can run without cargo." >&2
  exit 2
fi

stage_fmt() {
  echo "== cargo fmt --check =="
  cargo fmt --check
}

stage_clippy() {
  echo "== cargo clippy (all targets, warnings are errors) =="
  cargo clippy --all-targets -- -D warnings
}

stage_test() {
  echo "== tier-1: cargo build --release && cargo test -q =="
  cargo build --release
  cargo test -q
}

# SIMD differential slice: every test whose name mentions `simd` — the
# chunks-of-8 kernel tail-shape tests and the executor-level
# Simd-vs-Naive bit-identity differential. Runs as its own CI matrix
# leg so a SIMD regression is named in the job, not buried in tier-1.
stage_test_simd() {
  echo "== simd slice: cargo test -q simd =="
  cargo test -q simd
}

# Zoo smoke: every shipped .gnn spec must survive the CLI pipeline —
# compile, simulate (tiny scale), and the executor-vs-oracle diff — so a
# grammar or spec regression fails fast.
stage_smoke() {
  echo "== zoo smoke: compile + simulate + validate examples/models/*.gnn =="
  for f in "$SCRIPT_DIR"/../examples/models/*.gnn; do
    echo "--- $(basename "$f")"
    cargo run --release --quiet -- compile --model-file "$f" > /dev/null
    cargo run --release --quiet -- simulate --model-file "$f" AK --scale 12 > /dev/null
    cargo run --release --quiet -- validate --model-file "$f" --scale 11 > /dev/null
  done
}

# Profiler smoke: `bench --profile` at tiny scale — the walk-level phase
# profiler, the kernel-vs-legacy differential path and the interval
# pipeline's per-mode timing must not rot, and the trailer lines
# bench.sh embeds must stay present.
stage_profiler() {
  echo "== profiler smoke: bench --profile at tiny scale =="
  local prof_out
  prof_out=$(cargo run --release --quiet -- bench --model GCN --dataset AK \
    --scale 12 --iters 1 --profile)
  local key
  for key in 'exec_profile_json={' 'exec_ms_legacy=' 'exec_ms_pipeline_off=' \
             'exec_pipeline=on' 'exec_bitmatch=true'; do
    echo "$prof_out" | grep -q "^$key" \
      || { echo "bench --profile lost its '$key' trailer" >&2; exit 1; }
  done
  echo "profiler smoke OK"
}

# Trace smoke: `bench --trace --metrics` at tiny scale. Checks the
# Chrome-trace artifact is loadable JSON with the expected event shape
# (traceEvents array, ph:"X" complete events, named worker lanes) and
# that the metrics artifact round-trips through bench_diff.sh against
# itself with zero regressions.
stage_trace() {
  echo "== trace smoke: bench --trace/--metrics at tiny scale =="
  local dir trace metrics
  dir=$(mktemp -d "${TMPDIR:-/tmp}/switchblade_trace.XXXXXX")
  trap 'rm -rf "$dir"' RETURN
  trace="$dir/t.json" metrics="$dir/m.json"
  cargo run --release --quiet -- bench --model GCN --dataset AK \
    --scale 12 --iters 1 --pipeline on --trace "$trace" --metrics "$metrics" \
    > /dev/null
  if command -v python3 >/dev/null 2>&1; then
    python3 - "$trace" <<'PY'
import json, sys
with open(sys.argv[1]) as f:
    t = json.load(f)
evs = t["traceEvents"]
assert any(e.get("ph") == "X" for e in evs), "no complete events"
lanes = {e["args"]["name"] for e in evs if e.get("name") == "thread_name"}
assert "main/prepare" in lanes, f"main lane missing: {lanes}"
assert any(l.startswith("worker ") for l in lanes), f"worker lane missing: {lanes}"
print(f"trace OK: {sum(e.get('ph') == 'X' for e in evs)} spans, lanes {sorted(lanes)}")
PY
  else
    local key
    for key in '"traceEvents"' '"ph":"X"' '"main/prepare"' '"worker 0"'; do
      grep -q "$key" "$trace" \
        || { echo "trace artifact lost $key" >&2; exit 1; }
    done
  fi
  grep -q '"exec_ms_parallel"' "$metrics" \
    || { echo "metrics artifact lost exec_ms_parallel" >&2; exit 1; }
  "$SCRIPT_DIR/bench_diff.sh" "$metrics" "$metrics"
  echo "trace smoke OK"
}

# Serving-engine smoke: the persistent native engine must serve a zoo
# model AND an out-of-zoo spec file, verified bit-identical to a direct
# executor run, with the greppable trailers and the BENCH_serve.json
# load-generator artifact intact.
stage_serve() {
  echo "== serve smoke: native engine + --verify + --bench at tiny scale =="
  local dir out bench_json
  dir=$(mktemp -d "${TMPDIR:-/tmp}/switchblade_serve.XXXXXX")
  trap 'rm -rf "$dir"' RETURN
  out=$(cargo run --release --quiet -- serve --model GCN \
    --model-file "$SCRIPT_DIR"/../examples/models/gin.gnn \
    --dataset AK --scale 12 --requests 8 --verify)
  local key
  for key in 'serve_backend=native' 'serve_entries=2' 'serve_requests=8' \
             'serve_verified=ok' 'serve_p50_ms=' 'serve_p99_ms=' \
             'serve_errors=0'; do
    echo "$out" | grep -q "^$key" \
      || { echo "serve lost its '$key' trailer" >&2; exit 1; }
  done
  bench_json="$dir/BENCH_serve.json"
  cargo run --release --quiet -- serve --model GCN --dataset AK --scale 12 \
    --requests 8 --bench --out "$bench_json" > /dev/null
  for key in '"serve_qps"' '"serve_p50_ms"' '"serve_p95_ms"' '"serve_p99_ms"'; do
    grep -q "$key" "$bench_json" \
      || { echo "BENCH_serve.json lost $key" >&2; exit 1; }
  done
  "$SCRIPT_DIR/bench_diff.sh" "$bench_json" "$bench_json"
  echo "serve smoke OK"
}

# Reliability gate: the chaos integration suite runs the fault-injection
# scenarios (worker panics, stragglers, NaNs, stalls, deadlines, and the
# disarmed differential) against the real engine, then a `serve --inject`
# smoke proves the CLI wiring end to end — an injected worker panic must
# leave the serving run alive, with the fault visible in its trailers.
stage_chaos() {
  echo "== chaos: fault-injection suite + serve --inject smoke =="
  cargo test -q --release --test integration_chaos
  local out
  out=$(cargo run --release --quiet -- serve --model GCN --dataset AK \
    --scale 12 --requests 8 --inject 'worker_panic@shard=0@skip=1' 2>/dev/null)
  local key
  # No serve_requests pin: depending on where the injected panic lands
  # (warm-up vs an in-flight request) a request may legitimately fail.
  for key in 'serve_backend=native' 'serve_requests=' 'serve_p50_ms=' \
             'serve_timeouts=' 'serve_faults_injected='; do
    echo "$out" | grep -q "^$key" \
      || { echo "serve --inject lost its '$key' trailer" >&2; exit 1; }
  done
  local fired
  fired=$(echo "$out" | sed -n 's/^serve_faults_injected=//p')
  [[ "$fired" -ge 1 ]] \
    || { echo "serve --inject never fired (serve_faults_injected=$fired)" >&2; exit 1; }
  echo "chaos OK (faults injected: $fired)"
}

# Cross-request batching smoke: the batched-vs-sequential differential
# tests (bit-identity + the one-walk trace pin + the serve micro-batch
# integration), then `bench --batch-size` at tiny scale pinning the
# machine-readable trailers (the probe verifies bit-identity in-process:
# exec_bitmatch covers the batched outputs too), then a batched
# `serve --verify` run proving the serving path end to end.
stage_batch() {
  echo "== batch smoke: batched differentials + bench --batch-size + serve --batch =="
  cargo test -q --release batched
  cargo test -q --release --test integration_serve batch
  local out
  out=$(cargo run --release --quiet -- bench --model GCN --dataset AK \
    --scale 12 --iters 1 --batch-size 4)
  local key
  for key in 'exec_batch=4' 'exec_batch_amortization=' 'exec_bitmatch=true'; do
    echo "$out" | grep -q "^$key" \
      || { echo "bench --batch-size lost its '$key' trailer" >&2; exit 1; }
  done
  out=$(cargo run --release --quiet -- serve --model GCN --dataset AK \
    --scale 12 --requests 8 --batch 8 --verify)
  for key in 'serve_backend=native' 'serve_verified=ok' 'serve_errors=0'; do
    echo "$out" | grep -q "^$key" \
      || { echo "serve --batch lost its '$key' trailer" >&2; exit 1; }
  done
  echo "batch smoke OK"
}

stage_bench() {
  echo "== bench: scripts/bench.sh -> BENCH_exec.json + BENCH_serve.json =="
  "$SCRIPT_DIR/bench.sh"
}

# Perf-regression gate: diff this checkout's BENCH_exec.json (and, when
# both sides carry one, BENCH_serve.json) against a baseline (main's
# uploaded artifact in CI, any older run locally). Skips — success —
# when either side is absent, so the gate never blocks the first run or
# a fork without artifact access.
stage_bench_diff() {
  echo "== bench-diff: BENCH_exec.json vs \${BASELINE:-baseline/BENCH_exec.json} =="
  local baseline="${BASELINE:-$SCRIPT_DIR/../baseline/BENCH_exec.json}"
  if [[ ! -f "$SCRIPT_DIR/../BENCH_exec.json" ]]; then
    echo "no BENCH_exec.json in this checkout — run 'check.sh bench' first; skipping" >&2
    return 0
  fi
  "$SCRIPT_DIR/bench_diff.sh" "$baseline" "$SCRIPT_DIR/../BENCH_exec.json" \
    "${BENCH_DIFF_MAX_PCT:-10}"
  local serve_baseline="${SERVE_BASELINE:-$(dirname "$baseline")/BENCH_serve.json}"
  if [[ -f "$SCRIPT_DIR/../BENCH_serve.json" ]]; then
    echo "== bench-diff: BENCH_serve.json vs $serve_baseline =="
    "$SCRIPT_DIR/bench_diff.sh" "$serve_baseline" "$SCRIPT_DIR/../BENCH_serve.json" \
      "${BENCH_DIFF_MAX_PCT:-10}"
  fi
}

run_stage() {
  case "$1" in
    fmt)        stage_fmt ;;
    clippy)     stage_clippy ;;
    test)       stage_test ;;
    test-simd)  stage_test_simd ;;
    smoke)      stage_smoke ;;
    profiler)   stage_profiler ;;
    trace)      stage_trace ;;
    serve)      stage_serve ;;
    chaos)      stage_chaos ;;
    batch)      stage_batch ;;
    bench)      stage_bench ;;
    bench-diff) stage_bench_diff ;;
    all)
      stage_fmt
      stage_clippy
      stage_test
      stage_smoke
      stage_profiler
      stage_trace
      stage_serve
      stage_chaos
      stage_batch
      if [[ "${BENCH:-0}" != "0" ]]; then
        stage_bench
      fi
      ;;
    *)
      echo "unknown stage '$1' (fmt|clippy|test|test-simd|smoke|profiler|trace|serve|chaos|batch|bench|bench-diff|all)" >&2
      exit 2
      ;;
  esac
}

if [[ $# -eq 0 ]]; then
  run_stage all
else
  for s in "$@"; do
    run_stage "$s"
  done
fi
echo "check.sh: ${*:-all} passed"
