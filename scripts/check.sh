#!/usr/bin/env bash
# Repo-wide check, stage-selectable so CI can run stages as separate jobs:
#
#   scripts/check.sh              # everything (fmt clippy test smoke profiler)
#   scripts/check.sh fmt          # one stage
#   scripts/check.sh clippy test  # any subset, in the given order
#
# Stages:
#   fmt       cargo fmt --check
#   clippy    cargo clippy --all-targets -- -D warnings
#   test      tier-1 gate: cargo build --release && cargo test -q
#   smoke     zoo smoke: compile + simulate + validate examples/models/*.gnn
#   profiler  `bench --profile` at tiny scale + its machine-readable trailers
#   bench     scripts/bench.sh -> BENCH_exec.json (perf trajectory point)
#   all       fmt clippy test smoke profiler (+ bench when BENCH=1, the
#             historical knob)
set -euo pipefail
SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"
cd "$SCRIPT_DIR/../rust"

if ! command -v cargo >/dev/null 2>&1; then
  echo "error: check.sh needs the rust toolchain, but 'cargo' is not on PATH." >&2
  echo "       Install it from https://rustup.rs (or run inside an image that" >&2
  echo "       ships it) and re-run. No stage can run without cargo." >&2
  exit 2
fi

stage_fmt() {
  echo "== cargo fmt --check =="
  cargo fmt --check
}

stage_clippy() {
  echo "== cargo clippy (all targets, warnings are errors) =="
  cargo clippy --all-targets -- -D warnings
}

stage_test() {
  echo "== tier-1: cargo build --release && cargo test -q =="
  cargo build --release
  cargo test -q
}

# Zoo smoke: every shipped .gnn spec must survive the CLI pipeline —
# compile, simulate (tiny scale), and the executor-vs-oracle diff — so a
# grammar or spec regression fails fast.
stage_smoke() {
  echo "== zoo smoke: compile + simulate + validate examples/models/*.gnn =="
  for f in "$SCRIPT_DIR"/../examples/models/*.gnn; do
    echo "--- $(basename "$f")"
    cargo run --release --quiet -- compile --model-file "$f" > /dev/null
    cargo run --release --quiet -- simulate --model-file "$f" AK --scale 12 > /dev/null
    cargo run --release --quiet -- validate --model-file "$f" --scale 11 > /dev/null
  done
}

# Profiler smoke: `bench --profile` at tiny scale — the walk-level phase
# profiler, the kernel-vs-legacy differential path and the interval
# pipeline's per-mode timing must not rot, and the trailer lines
# bench.sh embeds must stay present.
stage_profiler() {
  echo "== profiler smoke: bench --profile at tiny scale =="
  local prof_out
  prof_out=$(cargo run --release --quiet -- bench --model GCN --dataset AK \
    --scale 12 --iters 1 --profile)
  local key
  for key in 'exec_profile_json={' 'exec_ms_legacy=' 'exec_ms_pipeline_off=' \
             'exec_pipeline=on' 'exec_bitmatch=true'; do
    echo "$prof_out" | grep -q "^$key" \
      || { echo "bench --profile lost its '$key' trailer" >&2; exit 1; }
  done
  echo "profiler smoke OK"
}

stage_bench() {
  echo "== bench: scripts/bench.sh -> BENCH_exec.json =="
  "$SCRIPT_DIR/bench.sh"
}

run_stage() {
  case "$1" in
    fmt)      stage_fmt ;;
    clippy)   stage_clippy ;;
    test)     stage_test ;;
    smoke)    stage_smoke ;;
    profiler) stage_profiler ;;
    bench)    stage_bench ;;
    all)
      stage_fmt
      stage_clippy
      stage_test
      stage_smoke
      stage_profiler
      if [[ "${BENCH:-0}" != "0" ]]; then
        stage_bench
      fi
      ;;
    *)
      echo "unknown stage '$1' (fmt|clippy|test|smoke|profiler|bench|all)" >&2
      exit 2
      ;;
  esac
}

if [[ $# -eq 0 ]]; then
  run_stage all
else
  for s in "$@"; do
    run_stage "$s"
  done
fi
echo "check.sh: ${*:-all} passed"
