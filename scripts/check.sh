#!/usr/bin/env bash
# Repo-wide check: format, lints, release build, and the tier-1 test
# suite. Run from anywhere; requires the rust toolchain on PATH.
set -euo pipefail
SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"
cd "$SCRIPT_DIR/../rust"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (all targets, warnings are errors) =="
cargo clippy --all-targets -- -D warnings

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

# Optional perf step: BENCH=1 ./scripts/check.sh also records the wall
# clock of `repro --fig 7` + executor throughput into BENCH_exec.json.
if [[ "${BENCH:-0}" != "0" ]]; then
  echo "== bench (BENCH=1) =="
  "$SCRIPT_DIR/bench.sh"
fi

echo "all checks passed"
