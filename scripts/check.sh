#!/usr/bin/env bash
# Repo-wide check: format, lints, release build, and the tier-1 test
# suite. Run from anywhere; requires the rust toolchain on PATH.
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (all targets, warnings are errors) =="
cargo clippy --all-targets -- -D warnings

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

echo "all checks passed"
