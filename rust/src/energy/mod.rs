//! Energy and area models (paper §VI / Tbl V).
//!
//! The paper synthesises the design with Synopsys DC at TSMC 28 nm and
//! reports Tbl V: 28.25 mm², 6.06 W total with the breakdown
//! MU 15.46%/24.02%, VU 6.37%/14.95%, CTRL 2.11%/2.66%, RAM 76.06%/58.38%
//! (area%/power%). We encode that table directly and compute energy as
//!
//!   E = Σ_unit P_unit × (α·busy + (1-α)·total) / f   +   E_dram(bytes)
//!
//! where α splits dynamic (busy-proportional) from static power, and
//! `E_dram = bytes × 8 × 7 pJ/bit` (§VI). For the GPU comparison the
//! paper converts 28 nm → 12 nm; we apply the same published scaling
//! factor to SWITCHBLADE's on-chip power.

use crate::sim::SimResult;

/// Tbl V: component shares of the 6.06 W / 28.25 mm² totals.
#[derive(Clone, Copy, Debug)]
pub struct AreaPower {
    pub total_area_mm2: f64,
    pub total_power_w: f64,
    pub mu_area_pct: f64,
    pub vu_area_pct: f64,
    pub ctrl_area_pct: f64,
    pub ram_area_pct: f64,
    pub mu_power_pct: f64,
    pub vu_power_pct: f64,
    pub ctrl_power_pct: f64,
    pub ram_power_pct: f64,
}

/// Tbl V as published (TSMC 28 nm @ 1 GHz).
pub const TBL5: AreaPower = AreaPower {
    total_area_mm2: 28.25,
    total_power_w: 6.06,
    mu_area_pct: 15.46,
    vu_area_pct: 6.37,
    ctrl_area_pct: 2.11,
    ram_area_pct: 76.06,
    mu_power_pct: 24.02,
    vu_power_pct: 14.95,
    ctrl_power_pct: 2.66,
    ram_power_pct: 58.38,
};

/// 28 nm → 12 nm power scaling the paper applies for the GPU comparison
/// (§VII-A Energy, citing [26]): capacitance/voltage scaling gives ≈0.45×.
pub const POWER_SCALE_28_TO_12: f64 = 0.45;

/// Fraction of unit power that is dynamic (busy-proportional); the rest
/// is static/leakage charged for the full runtime.
pub const DYNAMIC_FRACTION: f64 = 0.7;

/// Energy estimate for one simulated run.
#[derive(Clone, Copy, Debug)]
pub struct EnergyResult {
    pub onchip_j: f64,
    pub dram_j: f64,
}

impl EnergyResult {
    pub fn total_j(&self) -> f64 {
        self.onchip_j + self.dram_j
    }
}

/// Energy of a SWITCHBLADE simulation at the given clock, using the Tbl V
/// breakdown, scaled to 12 nm for cross-platform comparison.
pub fn switchblade_energy(r: &SimResult, freq_hz: f64, scale_to_12nm: bool) -> EnergyResult {
    let t = TBL5;
    let unit = |power_pct: f64, busy: f64| -> f64 {
        let p = t.total_power_w * power_pct / 100.0;
        let busy_s = busy / freq_hz;
        let total_s = r.cycles / freq_hz;
        p * (DYNAMIC_FRACTION * busy_s + (1.0 - DYNAMIC_FRACTION) * total_s)
    };
    // RAM activity tracks the sum of unit activity (every op touches SPM);
    // approximate RAM busy with the max of the three streams.
    let ram_busy = r.vu_busy.max(r.mu_busy).max(r.dram_busy);
    let mut onchip = unit(t.mu_power_pct, r.mu_busy)
        + unit(t.vu_power_pct, r.vu_busy)
        + unit(t.ctrl_power_pct, r.cycles)
        + unit(t.ram_power_pct, ram_busy);
    if scale_to_12nm {
        onchip *= POWER_SCALE_28_TO_12;
    }
    let dram_j = r.traffic.total() as f64 * 8.0 * 7.0e-12;
    EnergyResult {
        onchip_j: onchip,
        dram_j,
    }
}

/// Tbl V printable rows (area/power percentage table).
pub fn tbl5_rows() -> Vec<(&'static str, f64, f64)> {
    let t = TBL5;
    vec![
        ("MU", t.mu_area_pct, t.mu_power_pct),
        ("VU", t.vu_area_pct, t.vu_power_pct),
        ("CTRL", t.ctrl_area_pct, t.ctrl_power_pct),
        ("RAM", t.ram_area_pct, t.ram_power_pct),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Traffic;

    fn result(cycles: f64, busy: f64) -> SimResult {
        SimResult {
            cycles,
            seconds: cycles / 1e9,
            vu_busy: busy,
            mu_busy: busy,
            dram_busy: busy,
            traffic: Traffic::default(),
            shards_processed: 1,
            intervals_processed: 1,
            instructions: 1,
        }
    }

    #[test]
    fn tbl5_percentages_sum_to_100() {
        let t = TBL5;
        let area = t.mu_area_pct + t.vu_area_pct + t.ctrl_area_pct + t.ram_area_pct;
        let power = t.mu_power_pct + t.vu_power_pct + t.ctrl_power_pct + t.ram_power_pct;
        assert!((area - 100.0).abs() < 0.5, "area {area}");
        assert!((power - 100.0).abs() < 0.5, "power {power}");
    }

    #[test]
    fn busier_is_costlier() {
        let idle = switchblade_energy(&result(1e6, 1e5), 1e9, true);
        let busy = switchblade_energy(&result(1e6, 9e5), 1e9, true);
        assert!(busy.total_j() > idle.total_j());
    }

    #[test]
    fn bounded_by_full_power() {
        // Energy can never exceed total power × time.
        let r = result(1e6, 1e6);
        let e = switchblade_energy(&r, 1e9, false);
        assert!(e.onchip_j <= TBL5.total_power_w * (r.cycles / 1e9) * 1.001);
    }

    #[test]
    fn dram_energy_is_7pj_per_bit() {
        let mut r = result(1e6, 1e5);
        r.traffic.add(crate::sim::stats_tag_for_tests(), 1_000_000);
        let e = switchblade_energy(&r, 1e9, true);
        assert!((e.dram_j - 1_000_000.0 * 8.0 * 7.0e-12).abs() < 1e-15);
    }
}
