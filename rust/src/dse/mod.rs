//! Design-space exploration & auto-tuning (the co-design loop, closed).
//!
//! The paper hand-picks one hardware point (Tbl III) and one partition
//! method per figure; this subsystem *searches* instead. It crosses a
//! declarative [`SearchSpace`] over `AcceleratorConfig` knobs — sThread
//! count, DstBuffer/SrcEdgeBuffer sizes, VU/MU geometry, HBM1 vs HBM2 —
//! with the partition method (FGGP/DSW), evaluates every candidate
//! through the existing `compile → partition → simulate → energy`
//! pipeline in parallel over OS threads, and reports the Pareto frontier
//! over (latency, energy, on-chip SRAM area proxy) plus per-objective
//! champions.
//!
//! Repeated points are near-free: the [`cache`] layer memoises compiled
//! programs by model-spec fingerprint (source + layers/dims), generated
//! graphs by `(dataset, scale)`, and partitionings by `(dataset, scale,
//! method, PartitionConfig)` — design points that differ only in compute
//! geometry or memory generation share one partitioning. The same layer
//! now also backs the `coordinator` figure harness. Workloads carry an
//! open [`ModelSpec`](crate::ir::spec::ModelSpec), so any `.gnn`-defined
//! model can be tuned, not just the four paper networks.
//!
//! Entry points: [`tune`] (drives `switchblade tune <model> <dataset>`),
//! or [`evaluate_all`] + [`frontier`] for custom loops.

pub mod cache;
pub mod evaluate;
pub mod pareto;
pub mod space;

pub use cache::{CacheSnapshot, CacheStats, Caches, GraphCache, PartitionCache, ProgramCache};
pub use evaluate::{evaluate_all, evaluate_one, EvalPoint, Workload};
pub use pareto::{champion, dominates, frontier, pareto_indices, Objective};
pub use space::{DesignPoint, MemoryKind, SearchSpace};

use std::path::Path;
use std::sync::Arc;

use crate::graph::datasets::Dataset;
use crate::ir::spec::ModelSpec;
use crate::util::report::{bytes, f as ff, speedup, Table};

/// Load a tuned [`DesignPoint`] from a `switchblade tune` artifact —
/// `dse_*_frontier.{json,csv}` or the (unsorted) `dse_*_sweep` twins.
/// Picks the row with the lowest `latency ms` (the latency champion); if
/// no row carries a parseable latency, falls back to the first row. This
/// is what `repro --config` / `serve --config` call instead of
/// hard-coding the Tbl III default.
pub fn load_design(path: &Path) -> Result<DesignPoint, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let is_json = path
        .extension()
        .map(|x| x.eq_ignore_ascii_case("json"))
        .unwrap_or(false);
    // (config label, latency) per row; a missing/unparseable latency
    // becomes +inf so such rows lose every comparison, while index order
    // breaks ties (first row wins when no latencies exist at all).
    let rows: Vec<(String, f64)> = if is_json {
        // Table::write_json layout: one `{...}` object per row line, all
        // values JSON strings, labels contain no escapes.
        fn field(line: &str, key: &str) -> Option<String> {
            let pat = format!("\"{key}\": \"");
            let start = line.find(&pat)? + pat.len();
            let rest = &line[start..];
            Some(rest[..rest.find('"')?].to_string())
        }
        text.lines()
            .filter_map(|line| {
                let label = field(line, "config")?;
                let lat = field(line, "latency ms")
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(f64::INFINITY);
                Some((label, lat))
            })
            .collect()
    } else {
        // CSV: locate the `config` / `latency ms` columns in the header.
        // Cells are comma-free (labels use spaces), so a naive split works.
        let mut lines = text.lines();
        let header = lines
            .next()
            .ok_or_else(|| format!("{}: empty file", path.display()))?;
        let col = header
            .split(',')
            .position(|h| h.trim() == "config")
            .unwrap_or(0);
        let lat_col = header.split(',').position(|h| h.trim() == "latency ms");
        lines
            .filter_map(|row| {
                let cells: Vec<&str> = row.split(',').collect();
                let label = cells.get(col)?.trim().to_string();
                let lat = lat_col
                    .and_then(|c| cells.get(c))
                    .and_then(|v| v.trim().parse().ok())
                    .unwrap_or(f64::INFINITY);
                Some((label, lat))
            })
            .collect()
    };
    let best = rows
        .iter()
        .enumerate()
        .min_by(|(ai, a), (bi, b)| a.1.total_cmp(&b.1).then(ai.cmp(bi)))
        .map(|(_, r)| &r.0)
        .ok_or_else(|| format!("{}: no data rows", path.display()))?;
    DesignPoint::parse_label(best)
        .ok_or_else(|| format!("{}: unparseable design label '{best}'", path.display()))
}

/// Tuning run parameters.
#[derive(Clone, Debug)]
pub struct TuneOptions {
    pub space: SearchSpace,
    /// Maximum number of grid points to evaluate (0 = exhaustive).
    pub budget: usize,
    /// Objective the headline "best point" is reported for.
    pub objective: Objective,
}

impl Default for TuneOptions {
    fn default() -> Self {
        TuneOptions {
            space: SearchSpace::default(),
            budget: 64,
            objective: Objective::Latency,
        }
    }
}

/// Everything a tuning sweep produced.
#[derive(Clone, Debug)]
pub struct TuneReport {
    pub workload: Workload,
    pub objective: Objective,
    /// Every evaluated point, in sweep order (baseline included).
    pub evaluated: Vec<EvalPoint>,
    /// Indices into `evaluated`: the non-dominated set, sorted by latency.
    pub frontier: Vec<usize>,
    /// The Tbl III + FGGP design evaluated on the same workload.
    pub baseline: EvalPoint,
    /// Cache counters at the end of the sweep.
    pub caches: CacheSnapshot,
}

/// Run a budgeted design-space sweep for `(model spec, dataset)` and fold
/// the results into a [`TuneReport`]. The paper-default point is always
/// appended (if not already sampled) so "best vs Tbl III" is well-defined.
pub fn tune(
    model: &Arc<ModelSpec>,
    dataset: Dataset,
    caches: &Caches,
    opts: &TuneOptions,
) -> TuneReport {
    let workload = Workload {
        model: Arc::clone(model),
        dataset,
    };
    let mut points = opts.space.sample(opts.budget);
    let default_pt = DesignPoint::paper_default();
    if !points.contains(&default_pt) {
        points.push(default_pt);
    }
    let evaluated = evaluate_all(&workload, &points, caches);
    let mut frontier = pareto::frontier(&evaluated);
    frontier.sort_by(|&a, &b| evaluated[a].latency_s.total_cmp(&evaluated[b].latency_s));
    let baseline = *evaluated
        .iter()
        .find(|e| e.point == default_pt)
        .expect("baseline point is always evaluated");
    TuneReport {
        workload,
        objective: opts.objective,
        evaluated,
        frontier,
        baseline,
        caches: caches.snapshot(),
    }
}

impl TuneReport {
    /// The evaluated point minimising `o`.
    pub fn best(&self, o: Objective) -> &EvalPoint {
        &self.evaluated[champion(&self.evaluated, o).expect("non-empty sweep")]
    }

    /// Borrow the frontier members (latency-sorted).
    pub fn frontier_points(&self) -> Vec<&EvalPoint> {
        self.frontier.iter().map(|&i| &self.evaluated[i]).collect()
    }

    fn push_row(&self, t: &mut Table, e: &EvalPoint, on_frontier: bool) {
        let marks: Vec<&str> = Objective::ALL
            .iter()
            .filter(|&&o| self.best(o).point == e.point)
            .map(|o| o.name())
            .collect();
        t.row(vec![
            e.point.label(),
            e.point.num_sthreads.to_string(),
            ff(e.latency_s * 1e3, 3),
            ff(e.energy_j * 1e3, 3),
            bytes(e.sram_bytes),
            format!("{:.3e}", e.edp()),
            ff(e.utilization, 3),
            speedup(self.baseline.latency_s / e.latency_s),
            if on_frontier { "yes" } else { "no" }.into(),
            marks.join("+"),
        ]);
    }

    fn table_headers() -> [&'static str; 10] {
        [
            "config",
            "T",
            "latency ms",
            "energy mJ",
            "SRAM",
            "EDP J*s",
            "util",
            "vs TblIII",
            "pareto",
            "best",
        ]
    }

    /// The non-dominated points (latency-sorted), one row each.
    pub fn frontier_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "DSE Pareto frontier — {} ({} of {} points non-dominated)",
                self.workload.name(),
                self.frontier.len(),
                self.evaluated.len()
            ),
            &Self::table_headers(),
        );
        for &i in &self.frontier {
            self.push_row(&mut t, &self.evaluated[i], true);
        }
        t
    }

    /// Every evaluated point (sweep order) — the CSV/JSON artifact.
    pub fn sweep_table(&self) -> Table {
        let mut t = Table::new(
            &format!("DSE sweep — {}", self.workload.name()),
            &Self::table_headers(),
        );
        let on_frontier: Vec<bool> = {
            let mut v = vec![false; self.evaluated.len()];
            for &i in &self.frontier {
                v[i] = true;
            }
            v
        };
        for (e, &of) in self.evaluated.iter().zip(&on_frontier) {
            self.push_row(&mut t, e, of);
        }
        t
    }

    /// Multi-line human summary: champions, baseline comparison, caches.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for o in Objective::ALL {
            let b = self.best(o);
            out.push_str(&format!(
                "best {:7} {}  ({:.3} ms, {:.3} mJ, {})\n",
                o.name(),
                b.point.label(),
                b.latency_s * 1e3,
                b.energy_j * 1e3,
                bytes(b.sram_bytes)
            ));
        }
        let b = self.best(self.objective);
        out.push_str(&format!(
            "vs Tbl III default (objective {}): {} latency, {} energy\n",
            self.objective.name(),
            speedup(self.baseline.latency_s / b.latency_s),
            speedup(self.baseline.energy_j / b.energy_j)
        ));
        out.push_str(&self.caches.summary());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::zoo::ModelZoo;
    use crate::partition::Method;

    fn gcn() -> Arc<ModelSpec> {
        ModelZoo::builtin().get("gcn").unwrap()
    }

    fn tiny_options() -> TuneOptions {
        TuneOptions {
            space: SearchSpace {
                sthreads: vec![1, 3],
                dst_buffer_bytes: vec![8 * 1024 * 1024],
                src_edge_buffer_bytes: vec![1024 * 1024],
                vu: vec![(16, 32)],
                mu: vec![(32, 128)],
                memories: vec![MemoryKind::Hbm1, MemoryKind::Hbm2],
                methods: vec![Method::Fggp],
            },
            budget: 0,
            objective: Objective::Latency,
        }
    }

    #[test]
    fn load_design_reads_frontier_artifacts() {
        let caches = Caches::new(10);
        let r = tune(&gcn(), Dataset::Ak, &caches, &tiny_options());
        let dir = std::env::temp_dir();
        let json = dir.join("switchblade_test_frontier.json");
        let csv = dir.join("switchblade_test_frontier.csv");
        r.frontier_table().write_json(&json).unwrap();
        r.frontier_table().write_csv(&csv).unwrap();
        let from_json = load_design(&json).unwrap();
        let from_csv = load_design(&csv).unwrap();
        assert_eq!(from_json, from_csv);
        // Row 1 of a latency-sorted frontier is the latency champion.
        assert_eq!(from_json, r.frontier_points()[0].point);
        let _ = std::fs::remove_file(json);
        let _ = std::fs::remove_file(csv);
        assert!(load_design(Path::new("/nonexistent/x.json")).is_err());
    }

    #[test]
    fn tune_reports_baseline_and_frontier() {
        let caches = Caches::new(10);
        let r = tune(&gcn(), Dataset::Ak, &caches, &tiny_options());
        // 2 sthreads × 2 memories = 4 grid points; baseline is one of them.
        assert_eq!(r.evaluated.len(), 4);
        assert!(!r.frontier.is_empty());
        assert_eq!(r.baseline.point, DesignPoint::paper_default());
        // The best-latency point can never lose to a point in the sweep.
        assert!(r.best(Objective::Latency).latency_s <= r.baseline.latency_s);
        // Frontier is latency-sorted.
        let lats: Vec<f64> = r.frontier_points().iter().map(|e| e.latency_s).collect();
        assert!(lats.windows(2).all(|w| w[0] <= w[1]));
        // The pre-warmed graph makes every per-point lookup a hit. (The
        // partition cache also hits for the HBM1/HBM2 twins, but with only
        // four points racing in parallel that count is not deterministic.)
        assert!(r.caches.graphs.hits >= 4, "{}", r.caches.summary());
        let rendered = r.frontier_table().render();
        assert!(rendered.contains("Pareto frontier"));
        assert!(r.summary().contains("best latency"));
        assert_eq!(r.sweep_table().rows.len(), r.evaluated.len());
    }
}
