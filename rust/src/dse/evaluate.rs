//! Candidate evaluation: run one workload through the existing
//! `compile → partition → simulate → energy` pipeline for every design
//! point, fanned out over OS threads and memoised through [`Caches`].

use std::sync::{Arc, Mutex};

use crate::energy::switchblade_energy;
use crate::graph::datasets::Dataset;
use crate::ir::spec::ModelSpec;
use crate::sim::simulate;

use super::cache::Caches;
use super::space::DesignPoint;

/// The (model spec, dataset) pair a sweep optimises for. The model is any
/// zoo entry or user-loaded `.gnn` spec — sweeps are no longer restricted
/// to the four paper models.
#[derive(Clone, Debug)]
pub struct Workload {
    pub model: Arc<ModelSpec>,
    pub dataset: Dataset,
}

impl Workload {
    pub fn name(&self) -> String {
        format!("{} on {}", self.model.display(), self.dataset.full_name())
    }
}

/// One evaluated design point with every metric the Pareto stage and the
/// report tables consume.
#[derive(Clone, Copy, Debug)]
pub struct EvalPoint {
    pub point: DesignPoint,
    pub cycles: f64,
    pub latency_s: f64,
    pub energy_j: f64,
    /// On-chip SRAM capacity of the point — the area proxy objective.
    pub sram_bytes: u64,
    pub utilization: f64,
    pub traffic_bytes: u64,
    pub shards: u64,
}

impl EvalPoint {
    /// Energy-delay product (J·s) — the classic single-number co-design
    /// objective.
    pub fn edp(&self) -> f64 {
        self.latency_s * self.energy_j
    }

    /// Minimisation objectives in Pareto order: latency, energy, SRAM.
    pub fn objectives(&self) -> [f64; 3] {
        [self.latency_s, self.energy_j, self.sram_bytes as f64]
    }
}

/// Evaluate one design point for `w`, reusing whatever the caches hold.
pub fn evaluate_one(w: &Workload, p: DesignPoint, caches: &Caches) -> EvalPoint {
    let prog = caches.program(&w.model);
    let accel = p.accel();
    let pc = accel.partition_config(&prog);
    let parts = caches.partitions(w.dataset, p.method, pc);
    let sim = simulate(&prog, &parts, &accel);
    let energy = switchblade_energy(&sim, accel.freq_hz, true);
    EvalPoint {
        point: p,
        cycles: sim.cycles,
        latency_s: sim.seconds,
        energy_j: energy.total_j(),
        sram_bytes: accel.sram_bytes(),
        utilization: sim.overall_utilization(),
        traffic_bytes: sim.traffic.total(),
        shards: sim.shards_processed,
    }
}

/// Evaluate all points in parallel over OS threads. Results come back in
/// input order regardless of completion order.
pub fn evaluate_all(w: &Workload, points: &[DesignPoint], caches: &Caches) -> Vec<EvalPoint> {
    // Warm the per-workload singletons up front so the workers do not all
    // rebuild them in a first-lookup stampede.
    let _ = caches.graph(w.dataset);
    let _ = caches.program(&w.model);

    let indexed: Vec<(usize, DesignPoint)> = points.iter().copied().enumerate().collect();
    let results: Mutex<Vec<(usize, EvalPoint)>> = Mutex::new(Vec::with_capacity(points.len()));
    let results_ref = &results;
    let workers = crate::coordinator::num_workers().max(1);
    std::thread::scope(|s| {
        for chunk in indexed.chunks(indexed.len().div_ceil(workers).max(1)) {
            s.spawn(move || {
                for &(i, p) in chunk {
                    let e = evaluate_one(w, p, caches);
                    results_ref.lock().unwrap().push((i, e));
                }
            });
        }
    });
    let mut out = results.into_inner().unwrap();
    out.sort_by_key(|(i, _)| *i);
    out.into_iter().map(|(_, e)| e).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::zoo::ModelZoo;

    #[test]
    fn parallel_matches_serial_and_preserves_order() {
        let caches = Caches::new(10);
        let w = Workload {
            model: ModelZoo::builtin().get("gcn").unwrap(),
            dataset: Dataset::Ak,
        };
        let points = [
            DesignPoint::paper_default(),
            DesignPoint {
                num_sthreads: 1,
                ..DesignPoint::paper_default()
            },
            DesignPoint::paper_default(), // duplicate: pure cache hit
        ];
        let par = evaluate_all(&w, &points, &caches);
        assert_eq!(par.len(), points.len());
        for (e, p) in par.iter().zip(points.iter()) {
            assert_eq!(e.point, *p);
            assert!(e.cycles > 0.0 && e.energy_j > 0.0 && e.shards > 0);
        }
        // The duplicate third point must reproduce the first exactly (same
        // cached partitioning, deterministic simulator).
        assert_eq!(par[0].cycles, par[2].cycles);
        assert_eq!(par[0].energy_j, par[2].energy_j);
        // And serial re-evaluation agrees.
        let serial = evaluate_one(&w, points[1], &caches);
        assert_eq!(serial.cycles, par[1].cycles);
        assert!(caches.snapshot().partitions.hits > 0);
        assert_eq!(w.name(), "GCN on ak2010");
    }
}
