//! Generalized memoization layer for the explore/evaluate pipeline.
//!
//! Design-space exploration revisits the same expensive intermediates
//! thousands of times: the compiled program depends only on the model
//! spec (keyed by its content fingerprint, which covers source *and*
//! layers/dims — the old enum key silently collided distinct shapes), a
//! partitioning only on `(dataset, scale, method, PartitionConfig)`, and a
//! generated graph only on `(dataset, scale)`. Each gets its own
//! thread-safe cache with hit/miss accounting, and [`Caches`] bundles the
//! three behind the derived-key lookups every caller actually wants.
//!
//! This subsumes the coordinator's original one-off `GraphCache`: the
//! type of the same name here is a drop-in replacement (`new(scale)` /
//! `get(dataset)`), and `coordinator` re-exports it for compatibility.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::compiler::compile;
use crate::graph::datasets::Dataset;
use crate::graph::Csr;
use crate::ir::spec::ModelSpec;
use crate::isa::Program;
use crate::partition::{Method, PartitionConfig, Partitions};

/// Hit/miss counters for one cache (a miss is counted per `get` that had
/// to build, so `hits + misses` equals the number of lookups).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

/// A keyed, thread-safe memo table. Lookups that race on the same fresh
/// key may build twice (the map lock is not held across the build, so
/// parallel sweeps never serialise on unrelated keys); the first insert
/// wins and both callers see the same `Arc`.
struct Memo<K, V> {
    map: Mutex<HashMap<K, Arc<V>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<K: Eq + Hash, V> Memo<K, V> {
    fn new() -> Self {
        Memo {
            map: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    fn get_or_build(&self, key: K, build: impl FnOnce() -> V) -> Arc<V> {
        if let Some(v) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return v.clone();
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let v = Arc::new(build());
        self.map.lock().unwrap().entry(key).or_insert(v).clone()
    }

    fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

/// Compiled programs keyed by [`ModelSpec::fingerprint`] — stable over
/// (name, source, layers/dims). Compilation is config-independent, so
/// every design point of a sweep shares one compile; two shapes of the
/// same model no longer collide the way the old `Memo<Model, _>` key did.
pub struct ProgramCache {
    memo: Memo<u64, Program>,
}

impl Default for ProgramCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ProgramCache {
    pub fn new() -> Self {
        ProgramCache { memo: Memo::new() }
    }

    pub fn get(&self, spec: &ModelSpec) -> Arc<Program> {
        self.memo
            .get_or_build(spec.fingerprint(), || compile(&spec.graph()))
    }

    pub fn stats(&self) -> CacheStats {
        self.memo.stats()
    }
}

/// Generated graphs keyed by dataset at a fixed scale (generation
/// dominates harness runtime).
pub struct GraphCache {
    scale: u32,
    memo: Memo<Dataset, Csr>,
}

impl GraphCache {
    pub fn new(scale: u32) -> Self {
        GraphCache {
            scale,
            memo: Memo::new(),
        }
    }

    pub fn scale(&self) -> u32 {
        self.scale
    }

    pub fn get(&self, d: Dataset) -> Arc<Csr> {
        let scale = self.scale;
        self.memo.get_or_build(d, || d.load(scale))
    }

    pub fn stats(&self) -> CacheStats {
        self.memo.stats()
    }
}

/// Full partition-cache key: the graph identity plus everything the
/// partitioners read. Two design points with different VU/MU geometry or
/// DRAM map to the same key — those lookups are the near-free hits that
/// make dense sweeps cheap.
pub type PartitionKey = (Dataset, u32, Method, PartitionConfig);

/// Partitionings keyed by [`PartitionKey`].
pub struct PartitionCache {
    memo: Memo<PartitionKey, Partitions>,
}

impl Default for PartitionCache {
    fn default() -> Self {
        Self::new()
    }
}

impl PartitionCache {
    pub fn new() -> Self {
        PartitionCache { memo: Memo::new() }
    }

    pub fn get(
        &self,
        dataset: Dataset,
        scale: u32,
        method: Method,
        pc: PartitionConfig,
        g: &Csr,
    ) -> Arc<Partitions> {
        self.memo
            .get_or_build((dataset, scale, method, pc), || method.run(g, pc))
    }

    pub fn stats(&self) -> CacheStats {
        self.memo.stats()
    }
}

/// Point-in-time view of all three caches (what `tune` reports).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheSnapshot {
    pub graphs: CacheStats,
    pub programs: CacheStats,
    pub partitions: CacheStats,
}

impl CacheSnapshot {
    /// One-line human summary for CLI/bench output.
    pub fn summary(&self) -> String {
        let one = |name: &str, s: &CacheStats| {
            format!(
                "{name} {}/{} hits ({:.0}%)",
                s.hits,
                s.lookups(),
                100.0 * s.hit_rate()
            )
        };
        format!(
            "cache: {}, {}, {}",
            one("programs", &self.programs),
            one("partitions", &self.partitions),
            one("graphs", &self.graphs)
        )
    }

    /// Publish all three caches into the metrics registry under
    /// `dse_cache_{kind}_{hits,misses,hit_rate}` names.
    pub fn record_metrics(&self) {
        use crate::obs::metrics;
        for (kind, s) in [
            ("graphs", &self.graphs),
            ("programs", &self.programs),
            ("partitions", &self.partitions),
        ] {
            metrics::counter_abs(&format!("dse_cache_{kind}_hits"), s.hits);
            metrics::counter_abs(&format!("dse_cache_{kind}_misses"), s.misses);
            metrics::gauge(&format!("dse_cache_{kind}_hit_rate"), s.hit_rate());
        }
    }
}

/// The cache bundle threaded through the coordinator and the DSE
/// evaluator: graph, program and partition lookups with one shared scale.
pub struct Caches {
    graphs: GraphCache,
    programs: ProgramCache,
    partitions: PartitionCache,
}

impl Caches {
    pub fn new(scale: u32) -> Self {
        Caches {
            graphs: GraphCache::new(scale),
            programs: ProgramCache::new(),
            partitions: PartitionCache::new(),
        }
    }

    pub fn scale(&self) -> u32 {
        self.graphs.scale()
    }

    pub fn graph(&self, d: Dataset) -> Arc<Csr> {
        self.graphs.get(d)
    }

    pub fn program(&self, spec: &ModelSpec) -> Arc<Program> {
        self.programs.get(spec)
    }

    /// Partitioning of `d` (at the bundle's scale) for `method` under `pc`,
    /// generating the graph on demand.
    pub fn partitions(&self, d: Dataset, method: Method, pc: PartitionConfig) -> Arc<Partitions> {
        let g = self.graph(d);
        self.partitions.get(d, self.scale(), method, pc, &g)
    }

    pub fn snapshot(&self) -> CacheSnapshot {
        CacheSnapshot {
            graphs: self.graphs.stats(),
            programs: self.programs.stats(),
            partitions: self.partitions.stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::spec::ModelDims;
    use crate::ir::zoo::ModelZoo;
    use crate::sim::AcceleratorConfig;

    #[test]
    fn program_cache_counts_hits_and_misses() {
        let zoo = ModelZoo::builtin();
        let (gcn, gat) = (zoo.get("gcn").unwrap(), zoo.get("gat").unwrap());
        let c = ProgramCache::new();
        let a = c.get(&gcn);
        let b = c.get(&gcn);
        assert!(Arc::ptr_eq(&a, &b));
        let _ = c.get(&gat);
        let s = c.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 2);
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn program_cache_distinguishes_dims_of_one_model() {
        // The old enum key collided every shape of a model; the spec
        // fingerprint must not.
        let gcn = ModelZoo::builtin().get("gcn").unwrap();
        let small = gcn.with_dims(ModelDims::uniform(1, 8)).unwrap();
        let c = ProgramCache::new();
        let a = c.get(&gcn);
        let b = c.get(&small);
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(c.stats().misses, 2);
        assert_eq!(c.stats().hits, 0);
    }

    #[test]
    fn graph_cache_reuses_generation() {
        let c = GraphCache::new(10);
        let a = c.get(Dataset::Ak);
        let b = c.get(Dataset::Ak);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(c.stats().hits, 1);
        assert_eq!(c.stats().misses, 1);
    }

    #[test]
    fn partition_cache_key_distinguishes_method_and_config() {
        let caches = Caches::new(10);
        let prog = caches.program(&ModelZoo::builtin().get("gcn").unwrap());
        let accel = AcceleratorConfig::switchblade();
        let pc = accel.partition_config(&prog);
        let pc2 = accel.with_sthreads(1).partition_config(&prog);

        let a = caches.partitions(Dataset::Ak, Method::Fggp, pc);
        let b = caches.partitions(Dataset::Ak, Method::Fggp, pc); // hit
        let c = caches.partitions(Dataset::Ak, Method::Dsw, pc); // miss: method
        let d = caches.partitions(Dataset::Ak, Method::Fggp, pc2); // miss: config
        assert!(Arc::ptr_eq(&a, &b));
        assert!(!Arc::ptr_eq(&a, &c));
        assert!(!Arc::ptr_eq(&a, &d));

        let s = caches.snapshot();
        assert_eq!(s.partitions.hits, 1);
        assert_eq!(s.partitions.misses, 3);
        // The four partition lookups shared one generated graph.
        assert_eq!(s.graphs.misses, 1);
        assert_eq!(s.graphs.hits, 3);
        assert!(s.summary().contains("partitions 1/4"));
    }
}
