//! Declarative design space: which architecture/partition knobs the
//! explorer may turn, and how a concrete [`DesignPoint`] maps back onto an
//! [`AcceleratorConfig`] + partition method.
//!
//! Axes follow the co-design thesis: sThread count (SLMT, §IV-C), the two
//! streaming buffers (DB/SEB, Tbl III + Fig 13), VU/MU geometry, off-chip
//! memory generation (HBM1 vs HBM2), and the partition method (FGGP vs
//! DSW). The space is a plain cartesian grid; budgeted sampling draws a
//! fixed-seed random subset so even tiny budgets cover every axis without
//! stride-aliasing artefacts.

use crate::partition::Method;
use crate::sim::{AcceleratorConfig, DramConfig, HBM1, HBM2};

/// Off-chip memory generation — a named, hashable stand-in for the
/// float-valued [`DramConfig`] presets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemoryKind {
    Hbm1,
    Hbm2,
}

impl MemoryKind {
    pub const ALL: [MemoryKind; 2] = [MemoryKind::Hbm1, MemoryKind::Hbm2];

    pub fn name(&self) -> &'static str {
        match self {
            MemoryKind::Hbm1 => "HBM1",
            MemoryKind::Hbm2 => "HBM2",
        }
    }

    pub fn config(&self) -> DramConfig {
        match self {
            MemoryKind::Hbm1 => HBM1,
            MemoryKind::Hbm2 => HBM2,
        }
    }
}

/// One candidate configuration: everything the evaluate stage needs to
/// build the hardware model and the partitioning.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct DesignPoint {
    pub num_sthreads: u32,
    pub dst_buffer: u64,
    pub src_edge_buffer: u64,
    /// VU geometry: (SIMD cores, lanes per core).
    pub vu: (u32, u32),
    /// MU geometry: (systolic rows, cols).
    pub mu: (u32, u32),
    pub memory: MemoryKind,
    pub method: Method,
}

impl DesignPoint {
    /// The Tbl III SWITCHBLADE row with FGGP — the paper's shipped design,
    /// always evaluated as the sweep baseline.
    pub fn paper_default() -> Self {
        DesignPoint {
            num_sthreads: 3,
            dst_buffer: 8 * 1024 * 1024,
            src_edge_buffer: 1024 * 1024,
            vu: (16, 32),
            mu: (32, 128),
            memory: MemoryKind::Hbm1,
            method: Method::Fggp,
        }
    }

    /// Materialise the accelerator model for this point. Clock, weight
    /// and graph buffers stay at their Tbl III values — they are not
    /// search axes. Zero-valued axes are clamped to 1 (same rule as the
    /// `with_*` builders) so a degenerate user-built space cannot divide
    /// by zero deep inside the sweep.
    pub fn accel(&self) -> AcceleratorConfig {
        AcceleratorConfig {
            vu_cores: self.vu.0.max(1),
            vu_lanes: self.vu.1.max(1),
            mu_rows: self.mu.0.max(1),
            mu_cols: self.mu.1.max(1),
            dst_buffer: self.dst_buffer.max(1),
            src_edge_buffer: self.src_edge_buffer.max(1),
            num_sthreads: self.num_sthreads.max(1),
            dram: self.memory.config(),
            ..AcceleratorConfig::switchblade()
        }
    }

    /// Parse a point back out of its [`DesignPoint::label`] form, e.g.
    /// `"FGGP T3 DB8M SEB1024K MU32x128 VU16x32 HBM1"`. This is how
    /// `repro`/`serve --config` consume `dse_*_frontier.{json,csv}`
    /// artifacts without a serde dependency. Token order is free; every
    /// axis must appear exactly as `label` writes it.
    pub fn parse_label(s: &str) -> Option<DesignPoint> {
        fn geometry(tok: &str) -> Option<(u32, u32)> {
            let (a, b) = tok.split_once('x')?;
            Some((a.parse().ok()?, b.parse().ok()?))
        }
        let mut method = None;
        let mut sthreads = None;
        let mut db = None;
        let mut seb = None;
        let mut vu = None;
        let mut mu = None;
        let mut memory = None;
        for tok in s.split_whitespace() {
            if let Some(m) = Method::parse(tok) {
                method = Some(m);
            } else if tok.eq_ignore_ascii_case("HBM1") {
                memory = Some(MemoryKind::Hbm1);
            } else if tok.eq_ignore_ascii_case("HBM2") {
                memory = Some(MemoryKind::Hbm2);
            } else if let Some(r) = tok.strip_prefix("DB").and_then(|r| r.strip_suffix('M')) {
                db = Some(r.parse::<u64>().ok()? * 1024 * 1024);
            } else if let Some(r) = tok.strip_prefix("SEB").and_then(|r| r.strip_suffix('K')) {
                seb = Some(r.parse::<u64>().ok()? * 1024);
            } else if let Some(r) = tok.strip_prefix("MU") {
                mu = Some(geometry(r)?);
            } else if let Some(r) = tok.strip_prefix("VU") {
                vu = Some(geometry(r)?);
            } else if let Some(r) = tok.strip_prefix('T') {
                sthreads = Some(r.parse::<u32>().ok()?);
            } else {
                return None;
            }
        }
        Some(DesignPoint {
            num_sthreads: sthreads?,
            dst_buffer: db?,
            src_edge_buffer: seb?,
            vu: vu?,
            mu: mu?,
            memory: memory?,
            method: method?,
        })
    }

    /// Compact one-cell label for tables/CSV.
    pub fn label(&self) -> String {
        format!(
            "{} T{} DB{}M SEB{}K MU{}x{} VU{}x{} {}",
            self.method.name(),
            self.num_sthreads,
            self.dst_buffer / (1024 * 1024),
            self.src_edge_buffer / 1024,
            self.mu.0,
            self.mu.1,
            self.vu.0,
            self.vu.1,
            self.memory.name(),
        )
    }
}

/// The declarative search space: one `Vec` of options per axis. The grid
/// is the cartesian product of all axes.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    pub sthreads: Vec<u32>,
    pub dst_buffer_bytes: Vec<u64>,
    pub src_edge_buffer_bytes: Vec<u64>,
    pub vu: Vec<(u32, u32)>,
    pub mu: Vec<(u32, u32)>,
    pub memories: Vec<MemoryKind>,
    pub methods: Vec<Method>,
}

impl Default for SearchSpace {
    /// The neighbourhood of the paper's design the evaluation chapters
    /// actually probe: the Fig 11 sThread sweep, the Fig 13 DstBuffer
    /// enlargement, halving/doubling the SEB, a half-height MU, both HBM
    /// generations, and both partition methods (240 points).
    fn default() -> Self {
        SearchSpace {
            sthreads: vec![1, 2, 3, 4, 6],
            dst_buffer_bytes: vec![8 * 1024 * 1024, 13 * 1024 * 1024],
            src_edge_buffer_bytes: vec![512 * 1024, 1024 * 1024, 2 * 1024 * 1024],
            vu: vec![(16, 32)],
            mu: vec![(32, 128), (16, 128)],
            memories: vec![MemoryKind::Hbm1, MemoryKind::Hbm2],
            methods: vec![Method::Fggp, Method::Dsw],
        }
    }
}

impl SearchSpace {
    /// Number of points in the full grid.
    pub fn len(&self) -> usize {
        self.sthreads.len()
            * self.dst_buffer_bytes.len()
            * self.src_edge_buffer_bytes.len()
            * self.vu.len()
            * self.mu.len()
            * self.memories.len()
            * self.methods.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enumerate the full grid in row-major order (`sthreads` innermost).
    pub fn enumerate(&self) -> Vec<DesignPoint> {
        let mut out = Vec::with_capacity(self.len());
        for &method in &self.methods {
            for &memory in &self.memories {
                for &mu in &self.mu {
                    for &vu in &self.vu {
                        for &src_edge_buffer in &self.src_edge_buffer_bytes {
                            for &dst_buffer in &self.dst_buffer_bytes {
                                for &num_sthreads in &self.sthreads {
                                    out.push(DesignPoint {
                                        num_sthreads,
                                        dst_buffer,
                                        src_edge_buffer,
                                        vu,
                                        mu,
                                        memory,
                                        method,
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Deterministic subset of at most `budget` points (`budget == 0`
    /// means exhaustive): a seeded shuffle of the grid, so every axis is
    /// sampled without the aliasing a fixed stride would suffer when the
    /// stride divides an axis length. The picked points are returned in
    /// grid order.
    pub fn sample(&self, budget: usize) -> Vec<DesignPoint> {
        let all = self.enumerate();
        if budget == 0 || all.len() <= budget {
            return all;
        }
        let mut idx: Vec<usize> = (0..all.len()).collect();
        crate::util::rng::Rng::new(0xD5E).shuffle(&mut idx);
        idx.truncate(budget);
        idx.sort_unstable();
        idx.into_iter().map(|i| all[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_size_is_axis_product() {
        let s = SearchSpace::default();
        assert_eq!(s.len(), 5 * 2 * 3 * 1 * 2 * 2 * 2);
        assert_eq!(s.enumerate().len(), s.len());
        assert!(!s.is_empty());
    }

    #[test]
    fn default_space_contains_paper_default() {
        assert!(
            SearchSpace::default()
                .enumerate()
                .contains(&DesignPoint::paper_default()),
            "the Tbl III design must be a grid point of the default space"
        );
    }

    #[test]
    fn sample_respects_budget_and_spans_sthreads() {
        let s = SearchSpace::default();
        let picked = s.sample(16);
        assert_eq!(picked.len(), 16);
        let mut threads: Vec<u32> = picked.iter().map(|p| p.num_sthreads).collect();
        threads.sort_unstable();
        threads.dedup();
        assert!(
            threads.len() >= 2,
            "budgeted sample must span several sThread counts, got {threads:?}"
        );
        // Exhaustive when the budget covers the grid (or is 0).
        assert_eq!(s.sample(0).len(), s.len());
        assert_eq!(s.sample(s.len() + 5).len(), s.len());
    }

    #[test]
    fn paper_default_matches_tbl3() {
        let a = DesignPoint::paper_default().accel();
        let want = AcceleratorConfig::switchblade();
        assert_eq!(a.num_sthreads, want.num_sthreads);
        assert_eq!(a.dst_buffer, want.dst_buffer);
        assert_eq!(a.src_edge_buffer, want.src_edge_buffer);
        assert_eq!(a.sram_bytes(), want.sram_bytes());
        assert_eq!(a.vu_throughput(), want.vu_throughput());
        assert!((a.dram.bandwidth_bytes_per_s - want.dram.bandwidth_bytes_per_s).abs() < 1e-3);
    }

    #[test]
    fn labels_roundtrip_through_parse() {
        for p in SearchSpace::default().enumerate() {
            assert_eq!(
                DesignPoint::parse_label(&p.label()),
                Some(p),
                "label '{}' did not roundtrip",
                p.label()
            );
        }
        assert_eq!(DesignPoint::parse_label("not a label"), None);
        assert_eq!(DesignPoint::parse_label("FGGP T3"), None, "missing axes");
    }

    #[test]
    fn hbm2_point_gets_the_faster_memory() {
        let p = DesignPoint {
            memory: MemoryKind::Hbm2,
            ..DesignPoint::paper_default()
        };
        assert!((p.accel().dram.bandwidth_bytes_per_s - 900.0e9).abs() < 1e-3);
        assert!(p.label().contains("HBM2"));
    }
}
