//! Pareto analysis over evaluated design points: dominated-point
//! elimination on (latency, energy, SRAM area proxy) and per-objective
//! champions.

use super::evaluate::EvalPoint;

/// True iff `a` dominates `b`: no worse on every objective and strictly
/// better on at least one (all objectives minimised). Exact ties dominate
/// in neither direction, so duplicated points are both kept.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// Indices of the non-dominated members of `objs` (each row one point), in
/// input order.
pub fn pareto_indices(objs: &[Vec<f64>]) -> Vec<usize> {
    (0..objs.len())
        .filter(|&i| !objs.iter().any(|other| dominates(other, &objs[i])))
        .collect()
}

/// Non-dominated subset of evaluated points over
/// `(latency, energy, sram)`, as indices into `points`.
pub fn frontier(points: &[EvalPoint]) -> Vec<usize> {
    let objs: Vec<Vec<f64>> = points.iter().map(|p| p.objectives().to_vec()).collect();
    pareto_indices(&objs)
}

/// Scalar objective for champion selection and `tune --objective`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    Latency,
    Energy,
    Edp,
}

impl Objective {
    pub const ALL: [Objective; 3] = [Objective::Latency, Objective::Energy, Objective::Edp];

    pub fn name(&self) -> &'static str {
        match self {
            Objective::Latency => "latency",
            Objective::Energy => "energy",
            Objective::Edp => "edp",
        }
    }

    pub fn parse(s: &str) -> Option<Objective> {
        match s.to_ascii_lowercase().as_str() {
            "latency" | "lat" | "time" => Some(Objective::Latency),
            "energy" => Some(Objective::Energy),
            "edp" => Some(Objective::Edp),
            _ => None,
        }
    }

    pub fn value(&self, p: &EvalPoint) -> f64 {
        match self {
            Objective::Latency => p.latency_s,
            Objective::Energy => p.energy_j,
            Objective::Edp => p.edp(),
        }
    }
}

/// Index of the point minimising `o` (ties broken by input order).
pub fn champion(points: &[EvalPoint], o: Objective) -> Option<usize> {
    points
        .iter()
        .enumerate()
        .min_by(|a, b| o.value(a.1).total_cmp(&o.value(b.1)))
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::space::DesignPoint;

    fn pt(latency_ms: f64, energy_mj: f64, sram_mb: u64) -> EvalPoint {
        EvalPoint {
            point: DesignPoint::paper_default(),
            cycles: latency_ms * 1e6,
            latency_s: latency_ms * 1e-3,
            energy_j: energy_mj * 1e-3,
            sram_bytes: sram_mb * 1024 * 1024,
            utilization: 0.5,
            traffic_bytes: 1,
            shards: 1,
        }
    }

    #[test]
    fn dominance_requires_strict_improvement() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 1.0]));
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]), "ties dominate neither way");
        assert!(!dominates(&[1.0, 3.0], &[2.0, 1.0]), "trade-offs do not dominate");
        assert!(!dominates(&[2.0, 1.0], &[1.0, 1.0]));
    }

    #[test]
    fn frontier_drops_dominated_keeps_ties() {
        let points = vec![
            pt(1.0, 9.0, 8),  // fastest
            pt(9.0, 1.0, 8),  // most efficient
            pt(5.0, 5.0, 4),  // smallest
            pt(6.0, 6.0, 8),  // dominated by (5.0, 5.0, 4)
            pt(5.0, 5.0, 4),  // exact duplicate of the smallest: kept
        ];
        let f = frontier(&points);
        assert_eq!(f, vec![0, 1, 2, 4]);
    }

    #[test]
    fn champions_per_objective() {
        let points = vec![pt(1.0, 9.0, 8), pt(9.0, 1.0, 8), pt(3.0, 2.0, 4)];
        assert_eq!(champion(&points, Objective::Latency), Some(0));
        assert_eq!(champion(&points, Objective::Energy), Some(1));
        // EDP: 9, 9, 6 (in 1e-6 J·s units) → the balanced point wins.
        assert_eq!(champion(&points, Objective::Edp), Some(2));
        assert_eq!(champion(&[], Objective::Latency), None);
    }

    #[test]
    fn objective_parsing() {
        assert_eq!(Objective::parse("latency"), Some(Objective::Latency));
        assert_eq!(Objective::parse("EDP"), Some(Objective::Edp));
        assert_eq!(Objective::parse("power"), None);
        for o in Objective::ALL {
            assert_eq!(Objective::parse(o.name()), Some(o));
        }
    }
}
