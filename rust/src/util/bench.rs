//! Minimal benchmark harness (criterion is not available in the offline
//! image). Provides warm-up, repeated timed runs, and robust summary
//! statistics; bench binaries (`rust/benches/*.rs`, `harness = false`)
//! use it to time harness execution *and* to print the paper-figure series.

use std::time::{Duration, Instant};

/// Summary statistics over timed iterations.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn per_iter_ns(&self) -> f64 {
        self.mean.as_nanos() as f64
    }
}

/// Time `f` with `warmup` unrecorded runs followed by `iters` recorded runs.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let total: Duration = samples.iter().sum();
    Stats {
        iters,
        mean: total / iters as u32,
        median: samples[iters / 2],
        min: samples[0],
        max: samples[iters - 1],
    }
}

/// Print a one-line bench report in a stable grep-able format.
pub fn report(name: &str, stats: &Stats) {
    println!(
        "bench {name:40} mean {:>12?} median {:>12?} min {:>12?} max {:>12?} (n={})",
        stats.mean, stats.median, stats.min, stats.max, stats.iters
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_exact_iters() {
        let mut count = 0usize;
        let s = bench(2, 5, || {
            count += 1;
            count
        });
        assert_eq!(count, 7);
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median <= s.max);
    }
}
