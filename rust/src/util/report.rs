//! Result reporting: aligned-text tables (stdout, matching the paper's
//! figure series), CSV files under `results/`, and a minimal JSON writer.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// A simple column-aligned table with a title; the experiment harness
/// prints one per paper figure.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            for i in 0..ncol {
                let _ = write!(out, "{:<w$}  ", cells[i], w = widths[i]);
            }
            let _ = writeln!(out);
        };
        line(&mut out, &self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&mut out, &sep);
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Write the table as JSON: `{"title": ..., "rows": [...]}` with one
    /// object per row keyed by the headers (all values emitted as JSON
    /// strings — cells are already formatted).
    pub fn write_json(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let esc = |c: &str| {
            let mut s = String::with_capacity(c.len() + 2);
            s.push('"');
            for ch in c.chars() {
                match ch {
                    '"' => s.push_str("\\\""),
                    '\\' => s.push_str("\\\\"),
                    '\n' => s.push_str("\\n"),
                    '\r' => s.push_str("\\r"),
                    '\t' => s.push_str("\\t"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(s, "\\u{:04x}", c as u32);
                    }
                    c => s.push(c),
                }
            }
            s.push('"');
            s
        };
        let mut out = String::new();
        let _ = writeln!(out, "{{");
        let _ = writeln!(out, "  \"title\": {},", esc(&self.title));
        let _ = writeln!(out, "  \"rows\": [");
        for (ri, r) in self.rows.iter().enumerate() {
            let fields: Vec<String> = self
                .headers
                .iter()
                .zip(r)
                .map(|(h, c)| format!("{}: {}", esc(h), esc(c)))
                .collect();
            let comma = if ri + 1 < self.rows.len() { "," } else { "" };
            let _ = writeln!(out, "    {{{}}}{comma}", fields.join(", "));
        }
        let _ = writeln!(out, "  ]");
        let _ = writeln!(out, "}}");
        fs::write(path, out)
    }

    /// Write the table as CSV (for EXPERIMENTS.md provenance).
    pub fn write_csv(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut s = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(
            s,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(s, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        fs::write(path, s)
    }
}

/// Format helper: fixed-precision float cell.
pub fn f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// Format helper: speedup-style cell (`1.85x`).
pub fn speedup(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format helper: human bytes.
pub fn bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new("demo", &["model", "speedup"]);
        t.row(vec!["GCN".into(), speedup(1.2345)]);
        t.row(vec!["GGNN".into(), speedup(12.0)]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("1.23x"));
        assert!(r.contains("12.00x"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2048), "2.00 KB");
        assert_eq!(bytes(8 * 1024 * 1024), "8.00 MB");
    }

    #[test]
    fn json_escapes_and_structures() {
        let mut t = Table::new("j\"son", &["a", "b"]);
        t.row(vec!["x\ny".into(), "1.5".into()]);
        t.row(vec!["plain".into(), "2".into()]);
        let p = std::env::temp_dir().join("switchblade_test_json.json");
        t.write_json(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("\"title\": \"j\\\"son\""));
        assert!(s.contains("{\"a\": \"x\\ny\", \"b\": \"1.5\"},"));
        assert!(s.contains("{\"a\": \"plain\", \"b\": \"2\"}\n"));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new("csv", &["a", "b"]);
        t.row(vec!["x,y".into(), "1".into()]);
        let p = std::env::temp_dir().join("switchblade_test_csv.csv");
        t.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("\"x,y\",1"));
        let _ = std::fs::remove_file(p);
    }
}
