//! Small self-contained utilities (the image is offline, so everything that
//! would normally come from a crates.io dependency lives here).

pub mod bench;
pub mod prop;
pub mod report;
pub mod rng;

/// Ceiling division for unsigned integers.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Geometric mean of a slice (used for the paper's "average speedup").
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 128), 1);
        assert_eq!(ceil_div(0, 128), 0);
    }

    #[test]
    fn geomean_basic() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn mean_basic() {
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-12);
    }
}
