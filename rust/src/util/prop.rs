//! Hand-rolled property-testing scaffolding (proptest is not available in
//! the offline image). A `Cases` runner drives a closure with a seeded RNG
//! for N cases and reports the failing seed so a failure reproduces with
//! `Cases::only(seed)`.

use super::rng::Rng;

/// Property-test runner.
pub struct Cases {
    n: u64,
    base_seed: u64,
    only: Option<u64>,
}

impl Cases {
    pub fn new(n: u64) -> Self {
        Cases {
            n,
            base_seed: 0xC0FFEE,
            only: None,
        }
    }

    /// Re-run a single failing case by its reported seed.
    pub fn only(seed: u64) -> Self {
        Cases {
            n: 1,
            base_seed: seed,
            only: Some(seed),
        }
    }

    /// Run `prop` for every case; panic with the case seed on failure.
    pub fn run(&self, name: &str, mut prop: impl FnMut(&mut Rng)) {
        if let Some(seed) = self.only {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
            return;
        }
        for i in 0..self.n {
            let seed = self.base_seed.wrapping_add(i);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut rng = Rng::new(seed);
                prop(&mut rng);
            }));
            if let Err(e) = result {
                let msg = e
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!("property '{name}' failed at case {i} (seed {seed:#x}): {msg}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        Cases::new(20).run("sum-commutes", |rng| {
            let a = rng.gen_range(1000) as i64;
            let b = rng.gen_range(1000) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_seed_on_failure() {
        Cases::new(3).run("always-fails", |_| panic!("boom"));
    }
}
