//! Deterministic PRNG (xoshiro256**) used by the dataset generators and the
//! hand-rolled property tests. No external `rand` crate is available in the
//! offline image, and determinism across runs is a hard requirement for the
//! experiment harness anyway.

/// xoshiro256** by Blackman & Vigna (public domain reference implementation).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so that small consecutive seeds give independent
    /// streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses the widening-multiply trick (Lemire).
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[-scale, scale)` (feature initialisation).
    #[inline]
    pub fn f32_sym(&mut self, scale: f32) -> f32 {
        (self.f64() as f32 * 2.0 - 1.0) * scale
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.gen_range(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut acc = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            acc += x;
        }
        // Mean of U[0,1) should be close to 0.5.
        assert!((acc / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
