//! The shared partition-walk scheduler: ONE definition of the Alg 2
//! execution order, driven through phase-hook visitors.
//!
//! The paper's PLOF execution order (Alg 2, Fig 3) is: for every phase
//! group, for every destination interval — run the ScatterPhase on the
//! iThread, stream the interval's shards through the sThreads
//! (GatherPhase), then run the ApplyPhase on the iThread. Both functional
//! backends of this crate follow that order: the [`exec::Executor`]
//! (real numbers) and the [`sim::Engine`] (cycle timing). Before this
//! module existed each hand-rolled its own group→interval→shard loop
//! nest, and the two could silently drift apart.
//!
//! [`PartitionWalk`] is now the only place the loop nest exists. A
//! backend implements [`PhaseVisitor`] and receives the traversal as a
//! sequence of hook calls; it cannot reorder, skip, or duplicate steps.
//!
//! # The phase-hook contract
//!
//! For one `(program, partitions)` pair, [`PartitionWalk::drive`] calls
//! the visitor exactly as follows (canonical order):
//!
//! ```text
//! for group g (program order):
//!     begin_group(g)
//!     for interval i (ascending vertex ranges):
//!         begin_interval(g, i)
//!         scatter_phase(g, i)              # iThread: group.scatter instrs
//!         for shard s of interval i (ascending global shard index):
//!             gather_shard(g, i, s)        # sThreads: group.gather instrs
//!         lookahead_interval(g, i, next)   # next = (g, i+1), or (g+1, 0) at
//!                                          # g's last interval; skipped only
//!                                          # at the very end of the walk
//!         end_gather(g, i)                 # barrier: all shards of i done
//!         apply_phase(g, i)                # iThread: group.apply instrs
//!         end_interval(g, i)
//!     end_group(g)
//! ```
//!
//! Hooks the backend does not need have empty default bodies. The
//! `scatter_phase` / `apply_phase` hooks are invoked even when the
//! corresponding instruction list is empty — whether "empty phase" has a
//! cost (e.g. a phase-switch bubble) is the backend's decision, not the
//! walker's.
//!
//! Two contract points matter for parallel backends:
//!
//! * `gather_shard` is a *schedule point*, not a completion point: the
//!   executor queues the shard for its worker pool there and drains the
//!   queue at `end_gather`, so shards run concurrently while the *walk
//!   order* (and therefore the deterministic merge order of gather
//!   accumulators) stays canonical.
//! * `end_gather` is the only place an interval's gather results may be
//!   reduced — it is the software analogue of the hardware phase
//!   scheduler waiting for all sThreads before switching to ApplyPhase.
//! * `lookahead_interval` is the interval-pipelining hook (paper §IV-C:
//!   consecutive intervals overlap on different hardware resources). It
//!   fires between the last `gather_shard` of interval *i* and
//!   `end_gather(i)`, naming the *next* interval in walk order: interval
//!   *i+1* of the same group or — at a group's last interval — interval 0
//!   of the following group, so a backend whose resources outlive a group
//!   can also pipeline across the boundary. Only the walk's very last
//!   interval gets no lookahead. It is advisory — not a traced step,
//!   never reordering the walk — and a pipelined backend may use it to
//!   prepare the next interval's DstBuffer state while the current
//!   interval's shards drain (the executor's `PipelineMode::Interval`
//!   does exactly that, against a second buffer set ping-ponged through
//!   its scratch pools; `PipelineMode::Group` additionally takes the
//!   cross-group notices, gated on its own dependence analysis).
//!
//! # Traces
//!
//! [`Traced`] wraps any visitor and records the `(group, interval,
//! shard, phase)` sequence as [`WalkStep`]s; [`canonical_trace`] records
//! the walk with a no-op visitor. The scheduler tests assert that the
//! executor's and the simulator's recorded traces are identical to the
//! canonical one — the order-equivalence property that previously had to
//! be taken on faith.
//!
//! [`PhaseProfile`] is the timing counterpart. [`PartitionWalk::drive`]
//! brackets every hook in an [`obs::trace`](crate::obs::trace) span
//! (inert unless a trace session is open), and
//! [`PhaseProfile::from_spans`] folds that span stream into wall time
//! per `(group, phase)` plus per-shard gather statistics —
//! `exec::Executor::run_profiled` opens a session around one walk and
//! derives the profile from it, so `switchblade bench --profile` and
//! `--trace` are two views of the *same* measurement, and the profile
//! can point the next perf PR at the actual hot phase instead of a
//! guess.

use crate::isa::{PhaseGroup, Program};
use crate::obs::trace::{self, cat, names, Span, TRACK_MAIN};
use crate::partition::{Interval, Partitions, Shard};
use crate::util::report::Table;

/// Which of the three Alg 2 phases a [`WalkStep`] belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    /// iThread pre-processing per interval.
    Scatter,
    /// sThread work for one shard.
    Gather,
    /// iThread post-processing per interval.
    Apply,
}

/// One step of the canonical traversal, as recorded by [`Traced`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WalkStep {
    pub group: u32,
    pub interval: u32,
    /// Global shard index (into `Partitions::shards`) for
    /// [`Phase::Gather`] steps; `None` for the interval-side phases.
    pub shard: Option<u32>,
    pub phase: Phase,
}

/// Group-scope context handed to `begin_group` / `end_group`.
pub struct GroupCtx<'a> {
    pub index: usize,
    pub group: &'a PhaseGroup,
}

/// Interval-scope context handed to every per-interval hook.
pub struct StepCtx<'a> {
    pub group_idx: usize,
    pub group: &'a PhaseGroup,
    pub interval_idx: usize,
    pub interval: &'a Interval,
}

/// Backend hooks for the canonical walk. All methods default to no-ops;
/// a backend overrides the ones it gives semantics to. See the module
/// docs for the exact call sequence (the phase-hook contract).
pub trait PhaseVisitor {
    fn begin_group(&mut self, _cx: &GroupCtx) {}
    fn end_group(&mut self, _cx: &GroupCtx) {}
    fn begin_interval(&mut self, _cx: &StepCtx) {}
    /// The interval's ScatterPhase (iThread).
    fn scatter_phase(&mut self, _cx: &StepCtx) {}
    /// One shard's GatherPhase (sThreads). `shard_idx` is the global
    /// index into `Partitions::shards`.
    fn gather_shard(&mut self, _cx: &StepCtx, _shard_idx: usize, _shard: &Shard) {}
    /// Pipelining lookahead: `next` is the following interval in walk
    /// order — interval `i+1` of the same group, or interval 0 of the
    /// next group at a group's last interval (skipped only at the very
    /// end of the walk). Fired before `end_gather`, so a pipelined
    /// backend can overlap next-interval preparation with the current
    /// interval's gather drain. Advisory — it is not a walk step and must
    /// not change observable order; backends are expected to apply their
    /// own safety gates (the executor ignores cross-group notices unless
    /// its dependence analysis proves them safe).
    fn lookahead_interval(&mut self, _cx: &StepCtx, _next: &StepCtx) {}
    /// All shards of the interval have been offered; gather results may
    /// now be reduced.
    fn end_gather(&mut self, _cx: &StepCtx) {}
    /// The interval's ApplyPhase (iThread).
    fn apply_phase(&mut self, _cx: &StepCtx) {}
    fn end_interval(&mut self, _cx: &StepCtx) {}
}

/// The canonical Alg 2 traversal over one `(program, partitions)` pair.
pub struct PartitionWalk<'a> {
    program: &'a Program,
    parts: &'a Partitions,
}

impl<'a> PartitionWalk<'a> {
    pub fn new(program: &'a Program, parts: &'a Partitions) -> Self {
        PartitionWalk { program, parts }
    }

    /// Drive a visitor through the canonical order. This loop nest is the
    /// single source of truth for PLOF execution order — backends must
    /// not reimplement it.
    ///
    /// Every hook is bracketed in an [`obs::trace`](crate::obs::trace)
    /// span on the main track (group / interval scopes plus one span per
    /// scatter / gather / drain / apply step), so any traced or profiled
    /// walk — executor or simulator — gets its phase timeline for free.
    /// With no trace session open the span guards are inert.
    pub fn drive<V: PhaseVisitor>(&self, v: &mut V) {
        for (gi, group) in self.program.groups.iter().enumerate() {
            let gcx = GroupCtx { index: gi, group };
            let _group_span =
                trace::span_args(names::GROUP, cat::WALK, TRACK_MAIN, gi as i32, -1, -1);
            v.begin_group(&gcx);
            for (ii, iv) in self.parts.intervals.iter().enumerate() {
                let cx = StepCtx {
                    group_idx: gi,
                    group,
                    interval_idx: ii,
                    interval: iv,
                };
                let _interval_span = trace::span_args(
                    names::INTERVAL,
                    cat::WALK,
                    TRACK_MAIN,
                    gi as i32,
                    ii as i32,
                    -1,
                );
                v.begin_interval(&cx);
                {
                    let _s = trace::span_args(
                        names::SCATTER,
                        cat::WALK,
                        TRACK_MAIN,
                        gi as i32,
                        ii as i32,
                        -1,
                    );
                    v.scatter_phase(&cx);
                }
                for (si, shard) in self.parts.shards_of_indexed(ii) {
                    let _g = trace::span_args(
                        names::GATHER_SHARD,
                        cat::WALK,
                        TRACK_MAIN,
                        gi as i32,
                        ii as i32,
                        si as i32,
                    );
                    v.gather_shard(&cx, si, shard);
                }
                if let Some(next) = self.parts.intervals.get(ii + 1) {
                    let ncx = StepCtx {
                        group_idx: gi,
                        group,
                        interval_idx: ii + 1,
                        interval: next,
                    };
                    v.lookahead_interval(&cx, &ncx);
                } else if let (Some(ngroup), Some(first)) = (
                    self.program.groups.get(gi + 1),
                    self.parts.intervals.first(),
                ) {
                    // A group's last interval looks across the boundary:
                    // the next thing the walk runs is interval 0 of the
                    // following group.
                    let ncx = StepCtx {
                        group_idx: gi + 1,
                        group: ngroup,
                        interval_idx: 0,
                        interval: first,
                    };
                    v.lookahead_interval(&cx, &ncx);
                }
                {
                    let _d = trace::span_args(
                        names::GATHER_DRAIN,
                        cat::WALK,
                        TRACK_MAIN,
                        gi as i32,
                        ii as i32,
                        -1,
                    );
                    v.end_gather(&cx);
                }
                {
                    let _a = trace::span_args(
                        names::APPLY,
                        cat::WALK,
                        TRACK_MAIN,
                        gi as i32,
                        ii as i32,
                        -1,
                    );
                    v.apply_phase(&cx);
                }
                v.end_interval(&cx);
            }
            v.end_group(&gcx);
        }
    }
}

/// Visitor wrapper recording the `(group, interval, shard, phase)` step
/// sequence while delegating every hook to the wrapped visitor.
pub struct Traced<'v, V> {
    pub inner: &'v mut V,
    steps: Vec<WalkStep>,
}

impl<'v, V> Traced<'v, V> {
    pub fn new(inner: &'v mut V) -> Self {
        Traced {
            inner,
            steps: Vec::new(),
        }
    }

    pub fn steps(&self) -> &[WalkStep] {
        &self.steps
    }

    pub fn into_steps(self) -> Vec<WalkStep> {
        self.steps
    }
}

impl<V: PhaseVisitor> PhaseVisitor for Traced<'_, V> {
    fn begin_group(&mut self, cx: &GroupCtx) {
        self.inner.begin_group(cx);
    }

    fn end_group(&mut self, cx: &GroupCtx) {
        self.inner.end_group(cx);
    }

    fn begin_interval(&mut self, cx: &StepCtx) {
        self.inner.begin_interval(cx);
    }

    fn scatter_phase(&mut self, cx: &StepCtx) {
        self.steps.push(WalkStep {
            group: cx.group_idx as u32,
            interval: cx.interval_idx as u32,
            shard: None,
            phase: Phase::Scatter,
        });
        self.inner.scatter_phase(cx);
    }

    fn gather_shard(&mut self, cx: &StepCtx, shard_idx: usize, shard: &Shard) {
        self.steps.push(WalkStep {
            group: cx.group_idx as u32,
            interval: cx.interval_idx as u32,
            shard: Some(shard_idx as u32),
            phase: Phase::Gather,
        });
        self.inner.gather_shard(cx, shard_idx, shard);
    }

    // Not a walk step (the lookahead is advisory), but it must reach the
    // wrapped backend or tracing would silently disable its pipelining.
    fn lookahead_interval(&mut self, cx: &StepCtx, next: &StepCtx) {
        self.inner.lookahead_interval(cx, next);
    }

    fn end_gather(&mut self, cx: &StepCtx) {
        self.inner.end_gather(cx);
    }

    fn apply_phase(&mut self, cx: &StepCtx) {
        self.steps.push(WalkStep {
            group: cx.group_idx as u32,
            interval: cx.interval_idx as u32,
            shard: None,
            phase: Phase::Apply,
        });
        self.inner.apply_phase(cx);
    }

    fn end_interval(&mut self, cx: &StepCtx) {
        self.inner.end_interval(cx);
    }
}

/// Wall time spent in one group's phases, as folded from the walk's
/// span stream by [`PhaseProfile::from_spans`].
///
/// For a pooled backend like the executor, `gather_shard` is only a
/// schedule point — the shard work happens when the pool drains at
/// `end_gather` — so `gather_s` folds both together: it is the time from
/// the walker's perspective that the group spent in GatherPhase work
/// (queueing + pool drain + deterministic merge).
#[derive(Clone, Debug, Default)]
pub struct PhaseTimes {
    /// Seconds in `scatter_phase` hooks (iThread pre-processing).
    pub scatter_s: f64,
    /// Seconds in `gather_shard` + `end_gather` hooks (sThread work).
    pub gather_s: f64,
    /// Seconds in `apply_phase` hooks (iThread post-processing).
    pub apply_s: f64,
    /// Intervals walked for this group.
    pub intervals: u64,
    /// Shards offered to this group's GatherPhase.
    pub shards: u64,
    /// Largest single gather step (one `gather_shard` hook or one
    /// `end_gather` drain) — the load-balance ceiling.
    pub max_gather_s: f64,
    /// Next-interval DstBuffer preparations that ran under this group's
    /// gather drains (interval pipelining) — folded from the `prepare`
    /// spans the pipelined executor emits inside `end_gather`. Zero for
    /// non-pipelined backends or `PipelineMode::Off`.
    pub prepared: u64,
    /// Seconds spent in those preparations. Main-thread work overlapped
    /// with the worker pool, so it is *not* added to [`total_s`]: in a
    /// parallel drain it is already contained in the gather wall time.
    ///
    /// [`total_s`]: PhaseTimes::total_s
    pub prepare_s: f64,
}

impl PhaseTimes {
    pub fn total_s(&self) -> f64 {
        self.scatter_s + self.gather_s + self.apply_s
    }
}

/// A full walk's timing breakdown: one [`PhaseTimes`] per phase group, in
/// program order (prologue group included when the program has one).
#[derive(Clone, Debug, Default)]
pub struct PhaseProfile {
    pub groups: Vec<PhaseTimes>,
}

impl PhaseProfile {
    /// Fold a span stream (what one [`obs::trace`](crate::obs::trace)
    /// session recorded around a walk) into per-(group, phase) wall
    /// times — the profile consumer of the trace producer.
    ///
    /// Only walk-category step spans and the executor's `prepare` spans
    /// are folded; scope spans (`group` / `interval` lanes) contribute
    /// counts, and worker-lane `shard` spans are ignored so pooled
    /// gather work is not double-counted (the drain span already holds
    /// its wall time).
    pub fn from_spans(spans: &[Span]) -> PhaseProfile {
        let mut groups: Vec<PhaseTimes> = Vec::new();
        for s in spans {
            if s.group < 0 {
                continue;
            }
            let gi = s.group as usize;
            if groups.len() <= gi {
                groups.resize_with(gi + 1, PhaseTimes::default);
            }
            let g = &mut groups[gi];
            let secs = s.dur_ns as f64 / 1e9;
            match s.name {
                names::SCATTER if s.cat == cat::WALK => g.scatter_s += secs,
                names::GATHER_SHARD if s.cat == cat::WALK => {
                    g.shards += 1;
                    g.gather_s += secs;
                    g.max_gather_s = g.max_gather_s.max(secs);
                }
                names::GATHER_DRAIN if s.cat == cat::WALK => {
                    g.gather_s += secs;
                    g.max_gather_s = g.max_gather_s.max(secs);
                }
                names::APPLY if s.cat == cat::WALK => g.apply_s += secs,
                names::INTERVAL if s.cat == cat::WALK => g.intervals += 1,
                names::PREPARE => {
                    g.prepared += 1;
                    g.prepare_s += secs;
                }
                _ => {}
            }
        }
        PhaseProfile { groups }
    }

    /// Grow to at least `n` groups (all-zero rows for groups the span
    /// stream never touched), so the profile's group axis always matches
    /// the program's.
    pub fn pad_groups(&mut self, n: usize) {
        if self.groups.len() < n {
            self.groups.resize_with(n, PhaseTimes::default);
        }
    }

    /// Total hook seconds across all groups and phases.
    pub fn total_s(&self) -> f64 {
        self.groups.iter().map(|g| g.total_s()).sum()
    }

    /// The per-`(group, phase)` timing table `switchblade bench --profile`
    /// prints: one row per phase of each group plus a TOTAL row, with each
    /// row's share of the whole walk.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "walk profile — wall time per (group, phase)",
            &["group", "phase", "time ms", "calls", "mean us", "share"],
        );
        let total = self.total_s().max(f64::MIN_POSITIVE);
        for (gi, g) in self.groups.iter().enumerate() {
            // `prepare` is the interval-pipelining row: next-interval
            // DstBuffer preparations overlapped under the gather drain.
            let rows: [(&str, f64, u64); 4] = [
                ("scatter", g.scatter_s, g.intervals),
                ("gather", g.gather_s, g.shards),
                ("apply", g.apply_s, g.intervals),
                ("prepare", g.prepare_s, g.prepared),
            ];
            for (phase, secs, calls) in rows {
                let mean_us = if calls == 0 {
                    0.0
                } else {
                    secs * 1e6 / calls as f64
                };
                t.row(vec![
                    format!("g{gi}"),
                    phase.into(),
                    format!("{:.3}", secs * 1e3),
                    calls.to_string(),
                    format!("{mean_us:.1}"),
                    format!("{:.1}%", secs / total * 100.0),
                ]);
            }
        }
        t.row(vec![
            "ALL".into(),
            "total".into(),
            format!("{:.3}", self.total_s() * 1e3),
            self.groups.iter().map(|g| g.shards).sum::<u64>().to_string(),
            "".into(),
            "100.0%".into(),
        ]);
        t
    }

    /// Compact JSON rendering (one object, no trailing newline) —
    /// embedded verbatim by `scripts/bench.sh` into `BENCH_exec.json`.
    pub fn to_json(&self) -> String {
        let groups: Vec<String> = self
            .groups
            .iter()
            .enumerate()
            .map(|(gi, g)| {
                format!(
                    "{{\"group\":{gi},\"scatter_s\":{:.9},\"gather_s\":{:.9},\
                     \"apply_s\":{:.9},\"intervals\":{},\"shards\":{},\
                     \"max_gather_s\":{:.9},\"prepared\":{},\"prepare_s\":{:.9}}}",
                    g.scatter_s,
                    g.gather_s,
                    g.apply_s,
                    g.intervals,
                    g.shards,
                    g.max_gather_s,
                    g.prepared,
                    g.prepare_s
                )
            })
            .collect();
        format!(
            "{{\"total_s\":{:.9},\"groups\":[{}]}}",
            self.total_s(),
            groups.join(",")
        )
    }
}

/// The canonical `(group, interval, shard, phase)` order for one
/// `(program, partitions)` pair — what any conforming backend must emit.
pub fn canonical_trace(program: &Program, parts: &Partitions) -> Vec<WalkStep> {
    struct Null;
    impl PhaseVisitor for Null {}
    let mut null = Null;
    let mut tr = Traced::new(&mut null);
    PartitionWalk::new(program, parts).drive(&mut tr);
    tr.into_steps()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::{Method, PartitionConfig, Shard};

    fn toy_parts() -> Partitions {
        // Two intervals: the first with two shards, the second with none
        // (an isolated destination range).
        let cfg = PartitionConfig {
            shard_bytes: 1024,
            dst_bytes: 1024,
            dim_src: 1,
            dim_edge: 1,
            dim_dst: 1,
            num_sthreads: 2,
        };
        let shard = |iv: u32| Shard {
            interval: iv,
            ..Shard::default()
        };
        Partitions {
            method: Method::Dsw,
            config: cfg,
            num_vertices: 8,
            num_edges: 0,
            intervals: vec![
                Interval {
                    begin: 0,
                    end: 4,
                    shard_begin: 0,
                    shard_end: 2,
                },
                Interval {
                    begin: 4,
                    end: 8,
                    shard_begin: 2,
                    shard_end: 2,
                },
            ],
            shards: vec![shard(0), shard(0)],
        }
    }

    fn toy_program(groups: usize) -> Program {
        Program {
            model_name: "toy".into(),
            groups: vec![PhaseGroup::default(); groups],
            ..Program::default()
        }
    }

    #[test]
    fn canonical_order_is_scatter_shards_apply() {
        let p = toy_program(1);
        let parts = toy_parts();
        let t = canonical_trace(&p, &parts);
        let s = |interval, shard, phase| WalkStep {
            group: 0,
            interval,
            shard,
            phase,
        };
        assert_eq!(
            t,
            vec![
                s(0, None, Phase::Scatter),
                s(0, Some(0), Phase::Gather),
                s(0, Some(1), Phase::Gather),
                s(0, None, Phase::Apply),
                s(1, None, Phase::Scatter),
                s(1, None, Phase::Apply),
            ]
        );
    }

    #[test]
    fn groups_are_outermost() {
        let p = toy_program(2);
        let parts = toy_parts();
        let t = canonical_trace(&p, &parts);
        assert_eq!(t.len(), 12);
        // Every group-0 step precedes every group-1 step.
        let split = t.iter().position(|s| s.group == 1).unwrap();
        assert!(t[..split].iter().all(|s| s.group == 0));
        assert!(t[split..].iter().all(|s| s.group == 1));
    }

    #[test]
    fn hooks_fire_in_contract_order() {
        #[derive(Default)]
        struct Log {
            hooks: Vec<&'static str>,
            lookaheads: Vec<(usize, usize)>,
        }
        impl PhaseVisitor for Log {
            fn begin_group(&mut self, _: &GroupCtx) {
                self.hooks.push("bg");
            }
            fn end_group(&mut self, _: &GroupCtx) {
                self.hooks.push("eg");
            }
            fn begin_interval(&mut self, _: &StepCtx) {
                self.hooks.push("bi");
            }
            fn scatter_phase(&mut self, _: &StepCtx) {
                self.hooks.push("s");
            }
            fn gather_shard(&mut self, _: &StepCtx, _: usize, _: &Shard) {
                self.hooks.push("g");
            }
            fn lookahead_interval(&mut self, cx: &StepCtx, next: &StepCtx) {
                // The lookahead always names the next interval in walk
                // order: (g, i+1), or (g+1, 0) across the boundary.
                if next.group_idx == cx.group_idx {
                    assert_eq!(next.interval_idx, cx.interval_idx + 1);
                } else {
                    assert_eq!(next.group_idx, cx.group_idx + 1);
                    assert_eq!(next.interval_idx, 0);
                }
                self.lookaheads.push((next.group_idx, next.interval_idx));
                self.hooks.push("la");
            }
            fn end_gather(&mut self, _: &StepCtx) {
                self.hooks.push("G");
            }
            fn apply_phase(&mut self, _: &StepCtx) {
                self.hooks.push("a");
            }
            fn end_interval(&mut self, _: &StepCtx) {
                self.hooks.push("ei");
            }
        }
        let mut log = Log::default();
        PartitionWalk::new(&toy_program(1), &toy_parts()).drive(&mut log);
        // With a single group the lookahead fires only while a next
        // interval exists (between the last gather_shard and end_gather
        // of interval 0, never at the walk's final interval).
        assert_eq!(
            log.hooks,
            vec![
                "bg", "bi", "s", "g", "g", "la", "G", "a", "ei", "bi", "s", "G", "a", "ei",
                "eg"
            ]
        );
        assert_eq!(log.lookaheads, vec![(0, 1)]);

        // With two groups the boundary interval also gets a lookahead,
        // naming interval 0 of the next group; only the very last
        // interval of the walk goes without one.
        let mut log = Log::default();
        PartitionWalk::new(&toy_program(2), &toy_parts()).drive(&mut log);
        assert_eq!(
            log.hooks,
            vec![
                "bg", "bi", "s", "g", "g", "la", "G", "a", "ei", "bi", "s", "la", "G", "a",
                "ei", "eg", "bg", "bi", "s", "g", "g", "la", "G", "a", "ei", "bi", "s", "G",
                "a", "ei", "eg"
            ]
        );
        assert_eq!(log.lookaheads, vec![(0, 1), (1, 0), (1, 1)]);
    }

    #[test]
    fn traced_walk_profiles_from_its_span_stream() {
        struct Null;
        impl PhaseVisitor for Null {}
        let mut null = Null;
        let sess = trace::begin();
        PartitionWalk::new(&toy_program(2), &toy_parts()).drive(&mut null);
        let tr = sess.end();
        let p = PhaseProfile::from_spans(&tr.spans);
        assert_eq!(p.groups.len(), 2);
        for g in &p.groups {
            // Two intervals per group; the first has two shards.
            assert_eq!(g.intervals, 2);
            assert_eq!(g.shards, 2);
            assert!(g.scatter_s >= 0.0 && g.gather_s >= 0.0 && g.apply_s >= 0.0);
            assert!(g.max_gather_s <= g.gather_s + 1e-12);
            assert!(g.total_s() <= p.total_s() + 1e-12);
        }
        // Renderings exist and carry the per-(group, phase) rows.
        let rendered = p.table().render();
        assert!(rendered.contains("g0"));
        assert!(rendered.contains("gather"));
        let json = p.to_json();
        assert!(json.starts_with("{\"total_s\":"));
        assert!(json.contains("\"groups\":[{\"group\":0,"));
        assert!(json.contains("\"shards\":2"));
        // Pipelining columns exist (zero here — only the pipelined
        // executor emits `prepare` spans).
        assert!(json.contains("\"prepared\":0"));
        assert!(p.table().render().contains("prepare"));
    }

    #[test]
    fn from_spans_folds_phases_and_skips_worker_lanes() {
        let mk = |name, cat_, dur_ns: u64, g: i32, i: i32, s: i32| Span {
            name,
            cat: cat_,
            track: TRACK_MAIN,
            start_ns: 0,
            dur_ns,
            group: g,
            interval: i,
            shard: s,
        };
        let spans = [
            mk(names::GROUP, cat::WALK, 1_000, 0, -1, -1), // scope: untimed
            mk(names::INTERVAL, cat::WALK, 500, 0, 0, -1), // scope: counted
            mk(names::SCATTER, cat::WALK, 10, 0, 0, -1),
            mk(names::GATHER_SHARD, cat::WALK, 30, 0, 0, 0),
            mk(names::GATHER_SHARD, cat::WALK, 50, 0, 0, 1),
            mk(names::GATHER_DRAIN, cat::WALK, 40, 0, 0, -1),
            mk(names::PREPARE, cat::EXEC, 20, 0, 1, -1),
            mk(names::APPLY, cat::WALK, 5, 0, 0, -1),
            // Worker-lane view of pooled work: must not double-count.
            mk(names::SHARD, cat::EXEC, 9_999, 0, 0, 0),
            // Span without a group index: skipped.
            mk(names::COMPILE, cat::FRONTEND, 7, -1, -1, -1),
        ];
        let mut p = PhaseProfile::from_spans(&spans);
        assert_eq!(p.groups.len(), 1);
        let g = &p.groups[0];
        assert_eq!(g.intervals, 1);
        assert_eq!(g.shards, 2);
        let ns = 1e-9;
        assert!((g.scatter_s - 10.0 * ns).abs() < 1e-15);
        assert!((g.gather_s - 120.0 * ns).abs() < 1e-15);
        assert!((g.max_gather_s - 50.0 * ns).abs() < 1e-15);
        assert!((g.apply_s - 5.0 * ns).abs() < 1e-15);
        assert_eq!(g.prepared, 1);
        assert!((g.prepare_s - 20.0 * ns).abs() < 1e-15);
        // pad_groups grows the axis with zero rows, never shrinks.
        p.pad_groups(3);
        assert_eq!(p.groups.len(), 3);
        assert_eq!(p.groups[2].shards, 0);
        p.pad_groups(1);
        assert_eq!(p.groups.len(), 3);
    }
}
