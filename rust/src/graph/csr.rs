//! Compressed-sparse-row graph storage.
//!
//! The partitioner (DSW-GP / FGGP) iterates edges grouped by *destination*
//! interval and then by *source* vertex, so the canonical layout here is
//! **CSC-like**: for each destination we store its in-neighbours. We keep
//! the conventional name `Csr` and the direction explicit in method names.

use super::VertexId;

/// An edge list in COO form, the interchange format between generators,
/// the partitioner and the functional executor.
#[derive(Clone, Debug, Default)]
pub struct EdgeList {
    pub num_vertices: usize,
    /// `(src, dst)` pairs. Parallel edges are allowed (multigraphs appear in
    /// the Gunrock dataset dumps); self loops are allowed.
    pub edges: Vec<(VertexId, VertexId)>,
}

impl EdgeList {
    pub fn new(num_vertices: usize) -> Self {
        EdgeList {
            num_vertices,
            edges: Vec::new(),
        }
    }

    pub fn push(&mut self, src: VertexId, dst: VertexId) {
        debug_assert!((src as usize) < self.num_vertices);
        debug_assert!((dst as usize) < self.num_vertices);
        self.edges.push((src, dst));
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Deduplicate parallel edges (keeps the graph a simple digraph).
    pub fn dedup(&mut self) {
        self.edges.sort_unstable();
        self.edges.dedup();
    }
}

/// Dual-indexed sparse graph: both out-adjacency (CSR) and in-adjacency
/// (CSC) are materialised because:
///  * ScatterOp iterates edges by source (CSR),
///  * GatherOp and the DSW-GP partitioner iterate by destination (CSC).
#[derive(Clone, Debug)]
pub struct Csr {
    num_vertices: usize,
    num_edges: usize,
    // CSR: out-edges.
    out_offsets: Vec<u64>,
    out_targets: Vec<VertexId>,
    // CSC: in-edges, plus the originating edge id so edge features follow.
    in_offsets: Vec<u64>,
    in_sources: Vec<VertexId>,
    /// For in-edge k (in CSC order), `in_edge_ids[k]` is the edge's id in
    /// the canonical (CSR) edge numbering. Edge features are stored in
    /// canonical order, so GatherPhase uses this indirection.
    in_edge_ids: Vec<u64>,
}

impl Csr {
    /// Build both indices from an edge list. Canonical edge ids are the
    /// CSR (source-major) positions.
    pub fn from_edge_list(el: &EdgeList) -> Self {
        let n = el.num_vertices;
        let m = el.edges.len();

        // --- CSR (by source) -------------------------------------------------
        let mut out_deg = vec![0u64; n + 1];
        for &(s, _) in &el.edges {
            out_deg[s as usize + 1] += 1;
        }
        for i in 0..n {
            out_deg[i + 1] += out_deg[i];
        }
        let out_offsets = out_deg;
        let mut out_targets = vec![0 as VertexId; m];
        let mut cursor = out_offsets.clone();
        // Canonical edge id for (s, d): position in out_targets.
        let mut canonical_id = vec![0u64; m];
        for (k, &(s, d)) in el.edges.iter().enumerate() {
            let pos = cursor[s as usize];
            out_targets[pos as usize] = d;
            canonical_id[k] = pos;
            cursor[s as usize] += 1;
        }

        // --- CSC (by destination) -------------------------------------------
        let mut in_deg = vec![0u64; n + 1];
        for &(_, d) in &el.edges {
            in_deg[d as usize + 1] += 1;
        }
        for i in 0..n {
            in_deg[i + 1] += in_deg[i];
        }
        let in_offsets = in_deg;
        let mut in_sources = vec![0 as VertexId; m];
        let mut in_edge_ids = vec![0u64; m];
        let mut cursor = in_offsets.clone();
        for (k, &(s, d)) in el.edges.iter().enumerate() {
            let pos = cursor[d as usize] as usize;
            in_sources[pos] = s;
            in_edge_ids[pos] = canonical_id[k];
            cursor[d as usize] += 1;
        }

        // Sort each in-neighbour list by source id: FGGP scans sources in
        // ascending order (Alg 3 `srcPtr` sweep).
        let mut csr = Csr {
            num_vertices: n,
            num_edges: m,
            out_offsets,
            out_targets,
            in_offsets,
            in_sources,
            in_edge_ids,
        };
        csr.sort_in_lists();
        csr
    }

    fn sort_in_lists(&mut self) {
        // Perf: one reused scratch buffer instead of a fresh Vec per vertex
        // (a million-vertex graph would otherwise pay a million allocations
        // — EXPERIMENTS.md §Perf L3 #2).
        let mut scratch: Vec<(VertexId, u64)> = Vec::new();
        for v in 0..self.num_vertices {
            let (lo, hi) = (
                self.in_offsets[v] as usize,
                self.in_offsets[v + 1] as usize,
            );
            if hi - lo < 2 {
                continue;
            }
            scratch.clear();
            scratch.extend(
                self.in_sources[lo..hi]
                    .iter()
                    .copied()
                    .zip(self.in_edge_ids[lo..hi].iter().copied()),
            );
            scratch.sort_unstable_by_key(|&(s, _)| s);
            for (i, &(s, e)) in scratch.iter().enumerate() {
                self.in_sources[lo + i] = s;
                self.in_edge_ids[lo + i] = e;
            }
        }
    }

    pub fn num_vertices(&self) -> usize {
        self.num_vertices
    }

    pub fn num_edges(&self) -> usize {
        self.num_edges
    }

    #[inline]
    pub fn out_neighbors(&self, v: VertexId) -> &[VertexId] {
        let (lo, hi) = (
            self.out_offsets[v as usize] as usize,
            self.out_offsets[v as usize + 1] as usize,
        );
        &self.out_targets[lo..hi]
    }

    #[inline]
    pub fn in_neighbors(&self, v: VertexId) -> &[VertexId] {
        let (lo, hi) = (
            self.in_offsets[v as usize] as usize,
            self.in_offsets[v as usize + 1] as usize,
        );
        &self.in_sources[lo..hi]
    }

    /// In-edges of `v` as `(source, canonical edge id)` pairs.
    #[inline]
    pub fn in_edges(&self, v: VertexId) -> impl Iterator<Item = (VertexId, u64)> + '_ {
        let (lo, hi) = (
            self.in_offsets[v as usize] as usize,
            self.in_offsets[v as usize + 1] as usize,
        );
        self.in_sources[lo..hi]
            .iter()
            .copied()
            .zip(self.in_edge_ids[lo..hi].iter().copied())
    }

    #[inline]
    pub fn out_degree(&self, v: VertexId) -> usize {
        (self.out_offsets[v as usize + 1] - self.out_offsets[v as usize]) as usize
    }

    #[inline]
    pub fn in_degree(&self, v: VertexId) -> usize {
        (self.in_offsets[v as usize + 1] - self.in_offsets[v as usize]) as usize
    }

    /// Canonical-order edges `(src, dst, edge_id)`; edge_id == position.
    pub fn edges_canonical(&self) -> impl Iterator<Item = (VertexId, VertexId, u64)> + '_ {
        (0..self.num_vertices as u32).flat_map(move |s| {
            let (lo, hi) = (
                self.out_offsets[s as usize] as usize,
                self.out_offsets[s as usize + 1] as usize,
            );
            (lo..hi).map(move |k| (s, self.out_targets[k], k as u64))
        })
    }

    /// Mean in-degree (used in dataset summaries and the GPU cost model).
    pub fn avg_degree(&self) -> f64 {
        self.num_edges as f64 / self.num_vertices.max(1) as f64
    }

    /// Max in-degree.
    pub fn max_in_degree(&self) -> usize {
        (0..self.num_vertices as u32)
            .map(|v| self.in_degree(v))
            .max()
            .unwrap_or(0)
    }

    /// Coefficient of variation of the in-degree distribution — a cheap
    /// skew proxy used to sanity-check that the synthetic generators match
    /// the character of the original dataset (power-law vs mesh).
    pub fn in_degree_cv(&self) -> f64 {
        let n = self.num_vertices.max(1) as f64;
        let mean = self.num_edges as f64 / n;
        if mean == 0.0 {
            return 0.0;
        }
        let var = (0..self.num_vertices as u32)
            .map(|v| {
                let d = self.in_degree(v) as f64 - mean;
                d * d
            })
            .sum::<f64>()
            / n;
        var.sqrt() / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Csr {
        // 0 -> 1, 0 -> 2, 1 -> 3, 2 -> 3, 3 -> 0
        let mut el = EdgeList::new(4);
        for (s, d) in [(0, 1), (0, 2), (1, 3), (2, 3), (3, 0)] {
            el.push(s, d);
        }
        Csr::from_edge_list(&el)
    }

    #[test]
    fn counts() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 5);
        assert_eq!(g.avg_degree(), 1.25);
    }

    #[test]
    fn out_neighbors() {
        let g = diamond();
        assert_eq!(g.out_neighbors(0), &[1, 2]);
        assert_eq!(g.out_neighbors(3), &[0]);
        assert_eq!(g.out_degree(0), 2);
    }

    #[test]
    fn in_neighbors_sorted() {
        let g = diamond();
        assert_eq!(g.in_neighbors(3), &[1, 2]);
        assert_eq!(g.in_neighbors(0), &[3]);
        assert_eq!(g.in_degree(3), 2);
    }

    #[test]
    fn edge_ids_consistent() {
        let g = diamond();
        // in_edges of 3 must reference canonical ids whose CSR slot holds dst 3.
        for (_s, eid) in g.in_edges(3) {
            assert_eq!(g.out_targets[eid as usize], 3);
        }
    }

    #[test]
    fn canonical_edges_cover_all() {
        let g = diamond();
        let edges: Vec<_> = g.edges_canonical().collect();
        assert_eq!(edges.len(), 5);
        for (i, &(_, _, id)) in edges.iter().enumerate() {
            assert_eq!(i as u64, id);
        }
    }

    #[test]
    fn degree_cv_zero_for_regular() {
        // Ring: every vertex in-degree 1.
        let mut el = EdgeList::new(8);
        for i in 0..8u32 {
            el.push(i, (i + 1) % 8);
        }
        let g = Csr::from_edge_list(&el);
        assert!(g.in_degree_cv() < 1e-12);
        assert_eq!(g.max_in_degree(), 1);
    }

    #[test]
    fn dedup_removes_parallel_edges() {
        let mut el = EdgeList::new(2);
        el.push(0, 1);
        el.push(0, 1);
        el.push(1, 0);
        el.dedup();
        assert_eq!(el.num_edges(), 2);
    }
}
