//! The paper's evaluation datasets (Tbl IV) as deterministic synthetic
//! stand-ins, plus the scaling machinery used by the experiment harness.
//!
//! | Paper dataset        | |V|       | |E|        | Character        | Generator |
//! |----------------------|-----------|------------|------------------|-----------|
//! | ak2010 (AK)          | 45,293    | 108,549    | planar mesh      | mesh2d    |
//! | coAuthorsDBLP (AD)   | 299,068   | 977,676    | citation/co-auth | BA        |
//! | hollywood (HW)       | 1,139,905 | 57,515,616 | dense power-law  | R-MAT     |
//! | cit-Patents (CP)     | 3,774,768 | 16,518,948 | sparse citation  | BA        |
//! | soc-LiveJournal (SL) | 4,847,571 | 43,369,619 | social power-law | R-MAT     |
//!
//! `scale = k` divides vertex and edge counts by `2^k` (average degree is
//! preserved), so the default harness scale keeps cycle-level simulation
//! tractable while retaining each graph's sparsity character.

use super::generators;
use super::{Csr, EdgeList};

/// The five evaluation graphs, in the paper's order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dataset {
    /// ak2010 — Alaska redistricting adjacency (planar, near-regular).
    Ak,
    /// coAuthorsDBLP — co-authorship network.
    Ad,
    /// hollywood-2009 — actor collaboration (dense, highly skewed).
    Hw,
    /// cit-Patents — patent citations (sparse, mild skew).
    Cp,
    /// soc-LiveJournal1 — social network (large, skewed).
    Sl,
}

impl Dataset {
    pub const ALL: [Dataset; 5] = [Dataset::Ak, Dataset::Ad, Dataset::Hw, Dataset::Cp, Dataset::Sl];

    pub fn code(&self) -> &'static str {
        match self {
            Dataset::Ak => "AK",
            Dataset::Ad => "AD",
            Dataset::Hw => "HW",
            Dataset::Cp => "CP",
            Dataset::Sl => "SL",
        }
    }

    pub fn full_name(&self) -> &'static str {
        match self {
            Dataset::Ak => "ak2010",
            Dataset::Ad => "coAuthorsDBLP",
            Dataset::Hw => "hollywood",
            Dataset::Cp => "cit-Patents",
            Dataset::Sl => "soc-LiveJournal",
        }
    }

    pub fn parse(s: &str) -> Option<Dataset> {
        match s.to_ascii_uppercase().as_str() {
            "AK" | "AK2010" => Some(Dataset::Ak),
            "AD" | "COAUTHORSDBLP" | "DBLP" => Some(Dataset::Ad),
            "HW" | "HOLLYWOOD" => Some(Dataset::Hw),
            "CP" | "CIT-PATENTS" | "PATENTS" => Some(Dataset::Cp),
            "SL" | "SOC-LIVEJOURNAL" | "LIVEJOURNAL" => Some(Dataset::Sl),
            _ => None,
        }
    }

    /// Paper-reported full-scale sizes.
    pub fn paper_size(&self) -> (usize, usize) {
        match self {
            Dataset::Ak => (45_293, 108_549),
            Dataset::Ad => (299_068, 977_676),
            Dataset::Hw => (1_139_905, 57_515_616),
            Dataset::Cp => (3_774_768, 16_518_948),
            Dataset::Sl => (4_847_571, 43_369_619),
        }
    }

    /// Per-dataset scale cap: the small graphs (AK, AD) are not shrunk as
    /// aggressively as the giants, or they degenerate to launch-overhead
    /// microbenchmarks that distort every baseline comparison.
    fn max_scale(&self) -> u32 {
        match self {
            Dataset::Ak => 2,
            Dataset::Ad => 4,
            _ => u32::MAX,
        }
    }

    /// Generate the synthetic stand-in at `1 / 2^scale` of paper size.
    /// `scale = 0` reproduces full size.
    pub fn generate(&self, scale: u32) -> EdgeList {
        let scale = scale.min(self.max_scale());
        let (pv, pe) = self.paper_size();
        let v = (pv >> scale).max(64);
        let e = (pe >> scale).max(256);
        let seed = 0xB1ADE0 + *self as u64;
        match self {
            // Planar redistricting mesh: pick rows×cols ≈ v with the mesh's
            // natural edge count (≈4 per vertex per direction).
            Dataset::Ak => {
                let side = (v as f64).sqrt() as usize;
                generators::mesh2d(side.max(8), side.max(8), false)
            }
            // Co-authorship / citations: preferential attachment with
            // m = avg out-degree.
            Dataset::Ad | Dataset::Cp => {
                let m = (e / v).max(1);
                generators::barabasi_albert(v, m, seed)
            }
            // Social / collaboration power-law: R-MAT at the graph's density.
            Dataset::Hw | Dataset::Sl => {
                let n = v.next_power_of_two();
                generators::rmat(n, e, 0.57, 0.19, 0.19, seed)
            }
        }
    }

    /// Generate + index at the harness default scale.
    pub fn load(&self, scale: u32) -> Csr {
        Csr::from_edge_list(&self.generate(scale))
    }
}

/// Default scale used by the experiment harness: 1/64 of paper size keeps
/// the largest graph (HW) under ~1 M edges so a full 4-model × 5-dataset
/// sweep simulates in minutes. EXPERIMENTS.md reports the scale used per run.
pub const DEFAULT_SCALE: u32 = 6;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_roundtrip() {
        for d in Dataset::ALL {
            assert_eq!(Dataset::parse(d.code()), Some(d));
            assert_eq!(Dataset::parse(d.full_name()), Some(d));
        }
        assert_eq!(Dataset::parse("nope"), None);
    }

    #[test]
    fn scaled_sizes_track_paper_ratio() {
        for d in Dataset::ALL {
            let g = d.load(8);
            let (pv, pe) = d.paper_size();
            let paper_deg = pe as f64 / pv as f64;
            let got_deg = g.avg_degree();
            // Average degree within 2.5x of the paper's (generators are not
            // exact but must preserve density character).
            assert!(
                got_deg > paper_deg / 2.5 && got_deg < paper_deg * 2.5,
                "{}: paper avg deg {paper_deg:.2}, generated {got_deg:.2}",
                d.code()
            );
        }
    }

    #[test]
    fn skew_character_matches() {
        // Power-law datasets must be skewed; the mesh must not be.
        let hw = Dataset::Hw.load(8);
        let ak = Dataset::Ak.load(4);
        assert!(hw.in_degree_cv() > 1.0, "HW cv={}", hw.in_degree_cv());
        assert!(ak.in_degree_cv() < 0.5, "AK cv={}", ak.in_degree_cv());
    }

    #[test]
    fn generation_is_deterministic() {
        let a = Dataset::Sl.generate(9);
        let b = Dataset::Sl.generate(9);
        assert_eq!(a.edges, b.edges);
    }
}
