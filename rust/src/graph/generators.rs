//! Deterministic synthetic graph generators.
//!
//! The paper evaluates on five Gunrock dataset dumps (Tbl IV). Those files
//! are not redistributable inside this offline image, so we generate graphs
//! with matched vertex/edge counts and degree character (see
//! `graph::datasets` for the mapping and DESIGN.md §3 for the substitution
//! argument). All generators are deterministic in `(seed, parameters)`.

use super::{Csr, EdgeList, VertexId};
use crate::util::rng::Rng;

/// R-MAT (recursive matrix) generator — the standard model for skewed
/// power-law graphs such as social networks (soc-LiveJournal) and
/// collaboration networks (hollywood).
///
/// `(a, b, c)` are the upper-left / upper-right / lower-left quadrant
/// probabilities; `d = 1 - a - b - c`.
pub fn rmat(
    num_vertices: usize,
    num_edges: usize,
    a: f64,
    b: f64,
    c: f64,
    seed: u64,
) -> EdgeList {
    assert!(num_vertices.is_power_of_two(), "rmat needs power-of-two n");
    let scale = num_vertices.trailing_zeros();
    let mut rng = Rng::new(seed);
    let mut el = EdgeList::new(num_vertices);
    el.edges.reserve(num_edges);
    // Perf: quadrant selection in 16-bit fixed point, four levels per u64
    // draw — ~4× fewer RNG calls and no f64 conversions than the naive
    // per-level f64 path (EXPERIMENTS.md §Perf L3 #1).
    let t_a = (a * 65536.0) as u32;
    let t_ab = ((a + b) * 65536.0) as u32;
    let t_abc = ((a + b + c) * 65536.0) as u32;
    for _ in 0..num_edges {
        let (mut s, mut d) = (0u64, 0u64);
        let mut bits = 0u64;
        let mut left = 0u32;
        for _ in 0..scale {
            if left == 0 {
                bits = rng.next_u64();
                left = 4;
            }
            let r = (bits & 0xFFFF) as u32;
            bits >>= 16;
            left -= 1;
            s <<= 1;
            d <<= 1;
            if r < t_a {
                // upper-left: neither bit set
            } else if r < t_ab {
                d |= 1;
            } else if r < t_abc {
                s |= 1;
            } else {
                s |= 1;
                d |= 1;
            }
        }
        el.push(s as VertexId, d as VertexId);
    }
    el
}

/// Barabási–Albert preferential attachment — citation-style graphs
/// (coAuthorsDBLP, cit-Patents). Each new vertex attaches `m` edges to
/// existing vertices with probability proportional to degree.
pub fn barabasi_albert(num_vertices: usize, m: usize, seed: u64) -> EdgeList {
    assert!(num_vertices > m && m >= 1);
    let mut rng = Rng::new(seed);
    let mut el = EdgeList::new(num_vertices);
    // Repeated-vertex list: sampling uniformly from it is degree-
    // proportional sampling.
    let mut targets: Vec<VertexId> = Vec::with_capacity(2 * num_vertices * m);
    // Seed clique over the first m+1 vertices.
    for i in 0..=m as u32 {
        for j in 0..=m as u32 {
            if i != j {
                el.push(i, j);
                targets.push(j);
            }
        }
    }
    for v in (m as u32 + 1)..num_vertices as u32 {
        let mut chosen: Vec<VertexId> = Vec::with_capacity(m);
        while chosen.len() < m {
            let t = targets[rng.usize_in(0, targets.len())];
            if t != v && !chosen.contains(&t) {
                chosen.push(t);
            }
        }
        for &t in &chosen {
            // Citation direction: new work cites (points at) older work.
            el.push(v, t);
            targets.push(t);
            targets.push(v);
        }
    }
    el
}

/// Erdős–Rényi G(n, m): uniform random edges, low skew.
pub fn erdos_renyi(num_vertices: usize, num_edges: usize, seed: u64) -> EdgeList {
    let mut rng = Rng::new(seed);
    let mut el = EdgeList::new(num_vertices);
    el.edges.reserve(num_edges);
    for _ in 0..num_edges {
        let s = rng.gen_range(num_vertices as u64) as VertexId;
        let d = rng.gen_range(num_vertices as u64) as VertexId;
        el.push(s, d);
    }
    el
}

/// 2-D grid/mesh — planar, near-regular graphs such as redistricting
/// adjacency (ak2010). Both directions of each adjacency are emitted;
/// `diag` adds the diagonal neighbours (8-neighbourhood).
pub fn mesh2d(rows: usize, cols: usize, diag: bool) -> EdgeList {
    let n = rows * cols;
    let mut el = EdgeList::new(n);
    let idx = |r: usize, c: usize| (r * cols + c) as VertexId;
    let offsets: &[(i64, i64)] = if diag {
        &[(0, 1), (1, 0), (1, 1), (1, -1)]
    } else {
        &[(0, 1), (1, 0)]
    };
    for r in 0..rows {
        for c in 0..cols {
            let v = idx(r, c);
            for &(dr, dc) in offsets {
                let (nr, nc) = (r as i64 + dr, c as i64 + dc);
                if nr >= 0 && nr < rows as i64 && nc >= 0 && nc < cols as i64 {
                    let u = idx(nr as usize, nc as usize);
                    el.push(v, u);
                    el.push(u, v);
                }
            }
        }
    }
    el
}

/// Convenience: generate and index.
pub fn rmat_csr(n: usize, m: usize, seed: u64) -> Csr {
    // Graph500 parameters: heavy skew.
    Csr::from_edge_list(&rmat(n, m, 0.57, 0.19, 0.19, seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmat_counts_and_determinism() {
        let a = rmat(1 << 10, 8_000, 0.57, 0.19, 0.19, 1);
        let b = rmat(1 << 10, 8_000, 0.57, 0.19, 0.19, 1);
        assert_eq!(a.num_edges(), 8_000);
        assert_eq!(a.edges, b.edges);
        let c = rmat(1 << 10, 8_000, 0.57, 0.19, 0.19, 2);
        assert_ne!(a.edges, c.edges);
    }

    #[test]
    fn rmat_is_skewed() {
        let g = Csr::from_edge_list(&rmat(1 << 12, 40_000, 0.57, 0.19, 0.19, 3));
        // Power-law-ish: max degree far above mean, high CV.
        assert!(g.max_in_degree() as f64 > 10.0 * g.avg_degree());
        assert!(g.in_degree_cv() > 1.0);
    }

    #[test]
    fn ba_counts() {
        let el = barabasi_albert(1_000, 4, 5);
        // m*(m+1) seed edges + m per subsequent vertex.
        assert_eq!(el.num_edges(), 4 * 5 + (1_000 - 5) * 4);
        let g = Csr::from_edge_list(&el);
        // Preferential attachment produces hubs.
        assert!(g.max_in_degree() > 20);
    }

    #[test]
    fn er_is_uniform() {
        let g = Csr::from_edge_list(&erdos_renyi(4_096, 32_768, 7));
        assert_eq!(g.num_edges(), 32_768);
        assert!(g.in_degree_cv() < 0.6); // Poisson-like, low skew
    }

    #[test]
    fn mesh_is_regular() {
        let g = Csr::from_edge_list(&mesh2d(32, 32, true));
        assert_eq!(g.num_vertices(), 1_024);
        // Interior vertices have 8 neighbours each direction.
        assert_eq!(g.max_in_degree(), 8);
        assert!(g.in_degree_cv() < 0.3);
    }

    #[test]
    fn vertex_ids_in_range() {
        for el in [
            rmat(1 << 8, 1_000, 0.57, 0.19, 0.19, 11),
            barabasi_albert(300, 3, 11),
            erdos_renyi(256, 1_000, 11),
            mesh2d(10, 10, false),
        ] {
            for &(s, d) in &el.edges {
                assert!((s as usize) < el.num_vertices);
                assert!((d as usize) < el.num_vertices);
            }
        }
    }
}
