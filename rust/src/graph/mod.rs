//! Graph substrate: CSR/COO storage, degree statistics, and the synthetic
//! dataset generators that stand in for the paper's Tbl IV workloads.

mod csr;
pub mod datasets;
pub mod generators;

pub use csr::{Csr, EdgeList};

/// Vertex id type used throughout. u32 covers the paper's largest dataset
/// (soc-LiveJournal, 4.8 M vertices) with room to spare and halves the
/// memory traffic of the partitioner relative to u64.
pub type VertexId = u32;
