//! Evaluation baselines (paper §VI): the NVIDIA V100 running DGL in the
//! operator-by-operator paradigm, and the authors' HyGCN reproduction.

pub mod gpu;
pub mod hygcn;

pub use gpu::{gpu_run, GpuConfig, GpuResult};
pub use hygcn::{hygcn_run, HygcnConfig, HygcnResult};
