//! V100 + DGL baseline cost model (Tbl III row 1).
//!
//! DGL executes GNNs operator-by-operator: every operator reads its
//! inputs from and writes its output to HBM (§I: "all operators read and
//! write to DRAM"). We therefore price each IR node with a roofline
//! `max(compute, memory)` using per-class efficiency factors plus a
//! per-operator kernel-launch overhead, and charge full input+output
//! traffic per operator — the comparator Fig 9 normalises against.
//!
//! Efficiency factors are calibrated once (EXPERIMENTS.md §Calibration)
//! against the published characterisation of GCN on V100 ([36], [42]):
//! GTR ops sustain a small fraction of peak bandwidth due to random
//! access; DMMs reach a large fraction of peak FLOPs at dim 128; ELW ops
//! stream at near-peak bandwidth.

use crate::graph::Csr;
use crate::ir::{IrGraph, IrOp, Loc};

/// V100 parameters.
#[derive(Clone, Copy, Debug)]
pub struct GpuConfig {
    /// Peak fp32 throughput (FLOP/s).
    pub peak_flops: f64,
    /// HBM-2 bandwidth (bytes/s).
    pub bandwidth: f64,
    /// Kernel launch + framework overhead per operator (seconds). DGL
    /// dispatches one or more CUDA kernels per operator; 5 µs is a
    /// conservative per-op figure for DGL 0.7.
    pub launch_overhead_s: f64,
    /// Sustained-bandwidth fraction for *standalone* irregular GTR
    /// kernels (edge softmax, scatter materialisation).
    pub gtr_bw_eff: f64,
    /// Sustained-bandwidth fraction for DGL's fused gSpMM (cuSPARSE-class
    /// kernels; considerably better-tuned than ad-hoc edge kernels).
    pub spmm_bw_eff: f64,
    /// Sustained-bandwidth fraction for streaming ELW kernels.
    pub elw_bw_eff: f64,
    /// Sustained-FLOP fraction for dense matmul at GNN sizes.
    pub dmm_flop_eff: f64,
    /// Board power (W) attributed to GNN execution, *including HBM*
    /// (TDP-derated by the utilisation these memory-bound kernels
    /// achieve — nvidia-smi on DGL GNN workloads reads 80–110 W on V100).
    pub effective_power_w: f64,
}

impl Default for GpuConfig {
    fn default() -> Self {
        GpuConfig {
            peak_flops: 14.0e12,
            bandwidth: 900.0e9,
            launch_overhead_s: 5.0e-6,
            gtr_bw_eff: 0.12,
            spmm_bw_eff: 0.55,
            elw_bw_eff: 0.70,
            dmm_flop_eff: 0.30,
            effective_power_w: 90.0,
        }
    }
}

/// Per-model-on-graph cost estimate.
#[derive(Clone, Copy, Debug)]
pub struct GpuResult {
    pub seconds: f64,
    /// Total HBM traffic in bytes (op-by-op paradigm).
    pub dram_bytes: u64,
    /// Operator (kernel) count executed.
    pub operators: u64,
    pub energy_j: f64,
}

/// Nodes DGL fuses into a single gSpMM kernel: a `Gather` whose input
/// chain is `ScatterSrc` (optionally through one `RowScale` by an edge
/// column — `u_mul_e` + sum). DGL 0.7's `update_all(copy_u/u_mul_e, sum|max)`
/// compiles to exactly this. The scatter (and rowscale) nodes then cost
/// nothing standalone; the gather is priced as an SpMM: cached-gather
/// source reads + output writes, no `[E, d]` materialisation.
fn dgl_fused(ir: &IrGraph) -> std::collections::HashSet<usize> {
    let users = ir.users();
    let mut fused = std::collections::HashSet::new();
    for node in &ir.nodes {
        let IrOp::Gather(_) = node.op else { continue };
        let e = node.inputs[0];
        // Optional u_mul_e row-scale step.
        if matches!(ir.nodes[e].op, IrOp::RowScale) && users[e].len() == 1 {
            let a = ir.nodes[e].inputs[0];
            if matches!(ir.nodes[a].op, IrOp::ScatterSrc) && users[a].len() == 1 {
                fused.insert(e);
                fused.insert(a);
                continue;
            }
        }
        if matches!(ir.nodes[e].op, IrOp::ScatterSrc) && users[e].len() == 1 {
            fused.insert(e);
        }
    }
    fused
}

/// Price one model on one graph.
pub fn gpu_run(ir: &IrGraph, g: &Csr, cfg: &GpuConfig) -> GpuResult {
    let n = g.num_vertices() as f64;
    let m = g.num_edges() as f64;
    let mut seconds = 0.0;
    let mut bytes = 0u64;
    let mut operators = 0u64;
    let fused = dgl_fused(ir);

    for node in &ir.nodes {
        if fused.contains(&node.id) {
            continue; // folded into the consuming gSpMM gather
        }
        let rows = match node.loc {
            Loc::Vertex => n,
            Loc::Edge => m,
            Loc::Param => 0.0,
        };
        let cols = node.cols as f64;
        let out_bytes = rows * cols * 4.0;
        // Input bytes: every non-param operand is re-read from HBM.
        let in_bytes: f64 = node
            .inputs
            .iter()
            .map(|&i| {
                let inode = &ir.nodes[i];
                let irows = match inode.loc {
                    Loc::Vertex => n,
                    Loc::Edge => m,
                    Loc::Param => match inode.op {
                        IrOp::Weight { rows, .. } => rows as f64,
                        _ => 1.0,
                    },
                };
                irows * inode.cols as f64 * 4.0
            })
            .sum();

        let (t, b) = match &node.op {
            // Data nodes: materialised once at model setup; not charged.
            IrOp::Input | IrOp::Degree | IrOp::Weight { .. } | IrOp::Bias { .. } | IrOp::Output => {
                continue;
            }
            IrOp::Dmm => {
                let k = ir.nodes[node.inputs[0]].cols as f64;
                let flops = 2.0 * rows * k * cols;
                let mem = in_bytes + out_bytes;
                let t = (flops / (cfg.peak_flops * cfg.dmm_flop_eff))
                    .max(mem / (cfg.bandwidth * cfg.elw_bw_eff));
                (t, mem)
            }
            IrOp::Gather(_) if fused.contains(&node.inputs[0]) => {
                // gSpMM: per-edge gather of source rows (random access) +
                // output accumulation; edge index traffic.
                let d = node.cols as f64;
                let mem = m * d * 4.0 + n * d * 4.0 + m * 8.0;
                (mem / (cfg.bandwidth * cfg.spmm_bw_eff), mem)
            }
            IrOp::ScatterSrc | IrOp::ScatterDst | IrOp::Gather(_) => {
                // Standalone irregular op: bandwidth-bound at derated
                // efficiency, plus index traffic (one s32 per edge).
                let mem = in_bytes + out_bytes + m * 4.0;
                (mem / (cfg.bandwidth * cfg.gtr_bw_eff), mem)
            }
            IrOp::Unary(_) | IrOp::Binary(_) | IrOp::RowScale | IrOp::Concat => {
                let mem = in_bytes + out_bytes;
                (mem / (cfg.bandwidth * cfg.elw_bw_eff), mem)
            }
        };
        seconds += t + cfg.launch_overhead_s;
        bytes += b as u64;
        operators += 1;
    }

    // Board power includes HBM, so no separate DRAM-energy term.
    let energy_j = seconds * cfg.effective_power_w;
    GpuResult {
        seconds,
        dram_bytes: bytes,
        operators,
        energy_j,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::ir::models::Model;

    fn graph() -> Csr {
        Csr::from_edge_list(&generators::rmat(1 << 12, 40_000, 0.57, 0.19, 0.19, 1))
    }

    #[test]
    fn monotone_in_graph_size() {
        let ir = Model::Gcn.build_paper();
        let small = gpu_run(&ir, &graph(), &GpuConfig::default());
        let big_g =
            Csr::from_edge_list(&generators::rmat(1 << 14, 160_000, 0.57, 0.19, 0.19, 1));
        let big = gpu_run(&ir, &big_g, &GpuConfig::default());
        assert!(big.seconds > small.seconds);
        assert!(big.dram_bytes > small.dram_bytes);
    }

    #[test]
    fn op_by_op_traffic_exceeds_fused() {
        // The GPU paradigm moves far more data than PLOF end-to-end
        // (Fig 9's premise).
        let ir = Model::Gat.build_paper();
        let g = graph();
        let r = gpu_run(&ir, &g, &GpuConfig::default());
        // At minimum each of GAT's ~30 ops re-touches vertex-scale data.
        let vertex_bytes = (g.num_vertices() * 128 * 4) as u64;
        assert!(r.dram_bytes > 10 * vertex_bytes);
    }

    #[test]
    fn more_ops_more_launches() {
        let g = graph();
        let gcn = gpu_run(&Model::Gcn.build_paper(), &g, &GpuConfig::default());
        let ggnn = gpu_run(&Model::Ggnn.build_paper(), &g, &GpuConfig::default());
        assert!(ggnn.operators > gcn.operators);
    }

    #[test]
    fn energy_positive_and_scales() {
        let g = graph();
        let r = gpu_run(&Model::Sage.build_paper(), &g, &GpuConfig::default());
        assert!(r.energy_j > 0.0);
    }
}
