//! HyGCN reproduction (paper §VI "we reproduce HyGCN ... and compare its
//! performance against SWITCHBLADE under the GCN").
//!
//! HyGCN (HPCA'20) is a hardwired two-engine design for GCN-style models:
//!
//! * an **aggregation engine** (16×SIMD32) consuming graph shards produced
//!   by window-sliding partitioning with sparsity elimination (our DSW
//!   partitioner is exactly that, Fig 4-a),
//! * a **combination engine** (8×4×128 systolic MAC) for the dense
//!   `X·W` stage,
//! * inter-stage pipelining: aggregation of interval *i+1* overlaps
//!   combination of interval *i*.
//!
//! Only GCN-shaped models (gather → combine per layer) map onto the
//! hardwired pipeline; that restriction is HyGCN's flexibility cost and
//! the reason the paper only compares on GCN.

use crate::graph::Csr;
use crate::partition::{partition_dsw, PartitionConfig, Partitions};

/// HyGCN configuration (Tbl III row 2).
#[derive(Clone, Copy, Debug)]
pub struct HygcnConfig {
    pub freq_hz: f64,
    /// Aggregation engine lanes: 16 cores × 32 lanes.
    pub simd_lanes: u64,
    /// Combination engine MACs: 8 groups × 4 × 128.
    pub systolic_rows: u64,
    pub systolic_cols: u64,
    /// Input buffer (sources) per Tbl III: 128 KB.
    pub input_buffer: u64,
    /// Edge buffer: 2 MB.
    pub edge_buffer: u64,
    /// Output/aggregation buffers bound the interval: 8 MB.
    pub agg_buffer: u64,
    /// HBM-1.
    pub bandwidth: f64,
    pub dram_latency_ns: f64,
}

impl Default for HygcnConfig {
    fn default() -> Self {
        HygcnConfig {
            freq_hz: 1.0e9,
            simd_lanes: 16 * 32,
            systolic_rows: 8 * 4,
            systolic_cols: 128,
            input_buffer: 128 * 1024,
            edge_buffer: 2 * 1024 * 1024,
            agg_buffer: 8 * 1024 * 1024,
            bandwidth: 256.0e9,
            dram_latency_ns: 100.0,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct HygcnResult {
    pub cycles: f64,
    pub seconds: f64,
    pub dram_bytes: u64,
    /// Mean input-buffer occupancy (Fig 12's HyGCN bar, ≈44%).
    pub buffer_occupancy: f64,
    pub num_shards: u64,
}

/// Run a `layers`-deep GCN of width `dim` over `g`.
///
/// Per layer and destination interval:
///   t_agg  = edge traversal on SIMD + shard streaming from HBM
///   t_comb = interval_rows × dim × dim on the systolic array
/// and intervals pipeline: Σ max(t_agg, t_comb) + fill.
pub fn hygcn_run(g: &Csr, layers: u32, dim: u32, cfg: &HygcnConfig) -> HygcnResult {
    // HyGCN's window-sliding partitioner == DSW with sparsity elimination.
    let pc = PartitionConfig {
        shard_bytes: cfg.input_buffer,
        dst_bytes: cfg.agg_buffer,
        dim_src: dim,
        dim_edge: 0,
        dim_dst: dim,
        num_sthreads: 1,
    };
    let parts: Partitions = partition_dsw(g, pc);

    let bpc = cfg.bandwidth / cfg.freq_hz; // bytes per cycle
    let lat = cfg.dram_latency_ns * 1e-9 * cfg.freq_hz;

    let mut total_cycles = 0.0f64;
    let mut bytes = 0u64;
    let mut occ_sum = 0.0;
    let mut shards = 0u64;

    for layer in 0..layers {
        let _ = layer;
        let mut prev_comb_end = 0.0f64;
        let mut t = total_cycles;
        for (ii, iv) in parts.intervals.iter().enumerate() {
            // ---- aggregation of interval ii --------------------------------
            let mut agg_cycles = 0.0;
            for s in parts.shards_of(ii) {
                shards += 1;
                let loaded = s.loaded_sources as u64;
                let load_bytes = loaded * dim as u64 * 4 + s.num_edges() as u64 * 8;
                bytes += load_bytes;
                let dma = load_bytes as f64 / bpc + lat;
                // Edge-parallel aggregation on the SIMD engine; random
                // access through the crossbar halves sustained throughput
                // (same derating as SWITCHBLADE's VU GTR rate).
                let compute =
                    (s.num_edges() as u64 * dim as u64) as f64 / (cfg.simd_lanes as f64 / 2.0);
                // Within a shard, DMA and compute overlap (HyGCN
                // prefetches), but the 128 KB input buffer forces frequent
                // window switches whose DMA setup latency is exposed at
                // each boundary (no SLMT to hide it — exactly the gap
                // SWITCHBLADE's shard threads close).
                agg_cycles += dma.max(compute) + lat + 24.0;
                occ_sum += s.useful_bytes(&pc) as f64 / cfg.input_buffer as f64;
            }
            let agg_end = t + agg_cycles;

            // ---- combination of interval ii (pipelined after agg) ---------
            let rows = iv.len() as u64;
            let comb = ((rows as f64 / cfg.systolic_rows as f64).ceil()
                * (dim as f64 / cfg.systolic_cols as f64).ceil()
                * dim as f64)
                + (cfg.systolic_rows + cfg.systolic_cols) as f64;
            // Weights + output traffic.
            let comb_bytes = rows * dim as u64 * 4;
            bytes += comb_bytes;
            let comb_start = agg_end.max(prev_comb_end);
            prev_comb_end = comb_start + comb.max(comb_bytes as f64 / bpc);
            t = agg_end;
        }
        total_cycles = prev_comb_end.max(t);
    }

    // Weight residency (once).
    let w_bytes = layers as u64 * dim as u64 * dim as u64 * 4;
    bytes += w_bytes;
    total_cycles += w_bytes as f64 / bpc;

    HygcnResult {
        cycles: total_cycles,
        seconds: total_cycles / cfg.freq_hz,
        dram_bytes: bytes,
        buffer_occupancy: if shards > 0 { occ_sum / shards as f64 } else { 0.0 },
        num_shards: shards,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn graph() -> Csr {
        Csr::from_edge_list(&generators::rmat(1 << 12, 40_000, 0.57, 0.19, 0.19, 2))
    }

    #[test]
    fn runs_and_scales() {
        let g = graph();
        let r1 = hygcn_run(&g, 1, 128, &HygcnConfig::default());
        let r2 = hygcn_run(&g, 2, 128, &HygcnConfig::default());
        assert!(r2.cycles > r1.cycles);
        assert!(r1.dram_bytes > 0);
        assert!(r1.num_shards > 0);
    }

    #[test]
    fn occupancy_is_poor_with_window_sliding() {
        // Fig 12: HyGCN's sparsity-eliminated windows reach ~44% occupancy
        // on skewed graphs.
        let g = graph();
        let r = hygcn_run(&g, 2, 128, &HygcnConfig::default());
        assert!(
            r.buffer_occupancy < 0.7,
            "expected poor occupancy, got {:.2}",
            r.buffer_occupancy
        );
        assert!(r.buffer_occupancy > 0.05);
    }

    #[test]
    fn deterministic() {
        let g = graph();
        let a = hygcn_run(&g, 2, 128, &HygcnConfig::default());
        let b = hygcn_run(&g, 2, 128, &HygcnConfig::default());
        assert_eq!(a.cycles.to_bits(), b.cycles.to_bits());
    }
}
