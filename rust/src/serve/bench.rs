//! Load generator for the serving engine: closed loop (a bounded
//! in-flight window driven as fast as completions allow — the
//! steady-state throughput probe) or open loop (fixed-rate arrivals at a
//! target QPS regardless of completions — the latency-under-load probe,
//! where admission-control rejections appear when the engine can't keep
//! up). Latencies are sojourn times (queue wait + execution) measured
//! engine-side from submission, and percentiles are computed exactly
//! from the collected samples — not from the log-bucketed registry
//! histograms. The report lands in `BENCH_serve.json` beside the exec
//! trajectory and its `serve_p50_ms`/`serve_p99_ms` keys are gated by
//! `scripts/bench_diff.sh`.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::obs::metrics;
use crate::util::report::Table;

use super::engine::{Engine, EntryId, Input, ServeError, SubmitOptions, Ticket};

/// Load-generator knobs.
#[derive(Clone, Copy, Debug)]
pub struct BenchOptions {
    /// Open-loop offered rate; 0 = closed loop.
    pub qps: f64,
    /// Open-loop duration, seconds.
    pub duration_s: f64,
    /// Closed-loop request count.
    pub requests: usize,
    /// Closed-loop in-flight window (keep it <= the engine's queue
    /// depth or the closed loop will trip its own admission control).
    pub window: usize,
    /// Per-request deadline, milliseconds; `None` = unbounded waits.
    /// Set, each request is submitted with a queue deadline and settled
    /// with `Ticket::wait_timeout` — expiries count as `timeouts`, not
    /// errors.
    pub deadline_ms: Option<u64>,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions {
            qps: 0.0,
            duration_s: 2.0,
            requests: 64,
            window: 4,
            deadline_ms: None,
        }
    }
}

/// What a load-generator run measured.
#[derive(Debug, Clone)]
pub struct BenchReport {
    /// `"closed"` or `"open"`.
    pub mode: &'static str,
    /// Open-loop target rate (0 for closed loop).
    pub offered_qps: f64,
    /// Wall time of the whole run including the drain, seconds.
    pub wall_s: f64,
    /// Requests that completed successfully.
    pub completed: u64,
    /// Submissions rejected by admission control.
    pub rejected: u64,
    /// Requests that failed with a typed per-request error.
    pub errors: u64,
    /// Requests that exceeded their deadline (queue-side expiry or
    /// `wait_timeout`); only non-zero when `deadline_ms` is set.
    pub timeouts: u64,
    /// Sojourn times (queue wait + execution), seconds, sorted.
    lat: Vec<f64>,
}

impl BenchReport {
    pub(crate) fn from_parts(
        mode: &'static str,
        offered_qps: f64,
        wall_s: f64,
        mut lat: Vec<f64>,
        rejected: u64,
        errors: u64,
        timeouts: u64,
    ) -> Self {
        lat.sort_by(|a, b| a.total_cmp(b));
        BenchReport {
            mode,
            offered_qps,
            wall_s,
            completed: lat.len() as u64,
            rejected,
            errors,
            timeouts,
            lat,
        }
    }

    /// Achieved throughput, completed requests per second.
    pub fn qps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.completed as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Exact (nearest-rank) latency percentile, `q` in [0, 1]; 0 when
    /// nothing completed.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.lat.is_empty() {
            return 0.0;
        }
        let idx = ((self.lat.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        self.lat[idx.min(self.lat.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }

    pub fn mean(&self) -> f64 {
        if self.lat.is_empty() {
            0.0
        } else {
            self.lat.iter().sum::<f64>() / self.lat.len() as f64
        }
    }

    /// Publish the report into the process metrics registry (exported
    /// by `--metrics`). The `serve_p50_s`/`serve_p99_s`/
    /// `serve_requests_per_sec` names predate the engine and are kept.
    pub fn record_metrics(&self) {
        metrics::gauge("serve_qps", self.qps());
        metrics::gauge("serve_p50_ms", self.p50() * 1e3);
        metrics::gauge("serve_p95_ms", self.p95() * 1e3);
        metrics::gauge("serve_p99_ms", self.p99() * 1e3);
        metrics::gauge("serve_mean_ms", self.mean() * 1e3);
        metrics::gauge("serve_p50_s", self.p50());
        metrics::gauge("serve_p99_s", self.p99());
        metrics::gauge("serve_requests_per_sec", self.qps());
    }

    /// `BENCH_serve.json`: flat JSON, one `"name": value` per line —
    /// the same sed-greppable shape as BENCH_exec.json, so
    /// `scripts/bench_diff.sh` extracts keys from either unchanged.
    pub fn to_json(&self) -> String {
        format!(
            "{{\n  \"serve_mode\": \"{}\",\n  \"serve_offered_qps\": {:.3},\n  \
             \"serve_wall_s\": {:.6},\n  \"serve_requests\": {},\n  \
             \"serve_rejected\": {},\n  \"serve_errors\": {},\n  \
             \"serve_timeouts\": {},\n  \
             \"serve_qps\": {:.3},\n  \"serve_mean_ms\": {:.6},\n  \
             \"serve_p50_ms\": {:.6},\n  \"serve_p95_ms\": {:.6},\n  \
             \"serve_p99_ms\": {:.6}\n}}\n",
            self.mode,
            self.offered_qps,
            self.wall_s,
            self.completed,
            self.rejected,
            self.errors,
            self.timeouts,
            self.qps(),
            self.mean() * 1e3,
            self.p50() * 1e3,
            self.p95() * 1e3,
            self.p99() * 1e3,
        )
    }

    pub fn table(&self, title: &str) -> Table {
        let mut t = Table::new(title, &["metric", "value"]);
        t.row(vec!["loop".into(), self.mode.into()]);
        if self.offered_qps > 0.0 {
            t.row(vec!["offered qps".into(), format!("{:.1}", self.offered_qps)]);
        }
        t.row(vec!["completed".into(), self.completed.to_string()]);
        t.row(vec!["rejected".into(), self.rejected.to_string()]);
        t.row(vec!["errors".into(), self.errors.to_string()]);
        if self.timeouts > 0 {
            t.row(vec!["timeouts".into(), self.timeouts.to_string()]);
        }
        t.row(vec!["throughput".into(), format!("{:.1} req/s", self.qps())]);
        t.row(vec!["p50 latency".into(), format!("{:.3} ms", self.p50() * 1e3)]);
        t.row(vec!["p95 latency".into(), format!("{:.3} ms", self.p95() * 1e3)]);
        t.row(vec!["p99 latency".into(), format!("{:.3} ms", self.p99() * 1e3)]);
        t.row(vec!["mean latency".into(), format!("{:.3} ms", self.mean() * 1e3)]);
        t
    }
}

fn settle(
    t: Ticket,
    deadline: Option<Duration>,
    lat: &mut Vec<f64>,
    errors: &mut u64,
    timeouts: &mut u64,
) {
    let r = match deadline {
        Some(d) => t.wait_timeout(d),
        None => t.wait(),
    };
    match r {
        Ok(r) => lat.push(r.wait_s + r.exec_s),
        Err(ServeError::DeadlineExceeded { .. }) => *timeouts += 1,
        Err(_) => *errors += 1,
    }
}

fn submit(
    engine: &Engine,
    id: EntryId,
    seed: u64,
    deadline: Option<Duration>,
) -> Result<Ticket, ServeError> {
    engine.submit_with(id, Input::Seeded(seed), SubmitOptions { deadline })
}

/// Drive the engine with the configured load, round-robining requests
/// across `ids` (each request's features are seeded by its index, so a
/// run is reproducible end to end).
pub fn run_bench(engine: &Engine, ids: &[EntryId], opts: &BenchOptions) -> BenchReport {
    assert!(!ids.is_empty(), "run_bench needs at least one registered entry");
    if opts.qps > 0.0 {
        open_loop(engine, ids, opts)
    } else {
        closed_loop(engine, ids, opts)
    }
}

fn closed_loop(engine: &Engine, ids: &[EntryId], opts: &BenchOptions) -> BenchReport {
    let requests = opts.requests.max(1);
    let window = opts.window.max(1);
    let dl = opts.deadline_ms.map(Duration::from_millis);
    let mut lat = Vec::with_capacity(requests);
    let (mut rejected, mut errors, mut timeouts) = (0u64, 0u64, 0u64);
    let mut inflight: VecDeque<Ticket> = VecDeque::with_capacity(window);
    let t0 = Instant::now();
    for r in 0..requests {
        match submit(engine, ids[r % ids.len()], r as u64, dl) {
            Ok(t) => {
                inflight.push_back(t);
                if inflight.len() >= window {
                    let t = inflight.pop_front().expect("window bound just checked");
                    settle(t, dl, &mut lat, &mut errors, &mut timeouts);
                }
            }
            Err(ServeError::Rejected { .. }) => rejected += 1,
            Err(_) => errors += 1,
        }
    }
    while let Some(t) = inflight.pop_front() {
        settle(t, dl, &mut lat, &mut errors, &mut timeouts);
    }
    BenchReport::from_parts(
        "closed",
        0.0,
        t0.elapsed().as_secs_f64(),
        lat,
        rejected,
        errors,
        timeouts,
    )
}

fn open_loop(engine: &Engine, ids: &[EntryId], opts: &BenchOptions) -> BenchReport {
    let interval = Duration::from_secs_f64(1.0 / opts.qps);
    let deadline = Duration::from_secs_f64(opts.duration_s.max(1e-3));
    let dl = opts.deadline_ms.map(Duration::from_millis);
    let mut tickets = Vec::new();
    let mut lat = Vec::new();
    let (mut rejected, mut errors, mut timeouts) = (0u64, 0u64, 0u64);
    let t0 = Instant::now();
    let mut r: u32 = 0;
    loop {
        // Arrival schedule is absolute (r * interval from t0), so a slow
        // submission doesn't shift every later arrival.
        let target = interval * r;
        if target >= deadline {
            break;
        }
        let now = t0.elapsed();
        if target > now {
            std::thread::sleep(target - now);
        }
        match submit(engine, ids[r as usize % ids.len()], r as u64, dl) {
            Ok(t) => tickets.push(t),
            Err(ServeError::Rejected { .. }) => rejected += 1,
            Err(_) => errors += 1,
        }
        r += 1;
    }
    for t in tickets {
        settle(t, dl, &mut lat, &mut errors, &mut timeouts);
    }
    BenchReport::from_parts(
        "open",
        opts.qps,
        t0.elapsed().as_secs_f64(),
        lat,
        rejected,
        errors,
        timeouts,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(lat: Vec<f64>) -> BenchReport {
        BenchReport::from_parts("closed", 0.0, 1.0, lat, 2, 1, 0)
    }

    #[test]
    fn percentiles_are_exact_over_the_samples() {
        let r = report((1..=100).map(|i| i as f64).collect());
        assert_eq!(r.p50(), 50.0);
        assert_eq!(r.p95(), 95.0);
        assert_eq!(r.p99(), 99.0);
        assert_eq!(r.percentile(0.0), 1.0);
        assert_eq!(r.percentile(1.0), 100.0);
        assert_eq!(r.completed, 100);
        assert_eq!(r.qps(), 100.0);
    }

    #[test]
    fn from_parts_sorts_unordered_latencies() {
        let r = report(vec![3.0, 1.0, 2.0]);
        assert_eq!(r.percentile(0.0), 1.0);
        assert_eq!(r.percentile(1.0), 3.0);
        assert!((r.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_run_reports_zeroes_not_panics() {
        let r = report(Vec::new());
        assert_eq!(r.p50(), 0.0);
        assert_eq!(r.p99(), 0.0);
        assert_eq!(r.mean(), 0.0);
        assert_eq!(r.qps(), 0.0);
    }

    #[test]
    fn json_is_flat_and_carries_the_gated_keys() {
        let j = report(vec![0.001, 0.002, 0.003]).to_json();
        for key in [
            "\"serve_qps\":",
            "\"serve_p50_ms\":",
            "\"serve_p95_ms\":",
            "\"serve_p99_ms\":",
            "\"serve_requests\":",
            "\"serve_rejected\": 2",
            "\"serve_errors\": 1",
            "\"serve_timeouts\": 0",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        // One "name": value per line — the bench_diff.sh contract.
        for line in j.lines().filter(|l| l.contains(':')) {
            assert_eq!(line.matches(':').count(), 1, "not flat: {line}");
        }
    }
}
