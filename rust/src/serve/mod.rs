//! `serve` — a persistent multi-model inference service over the native
//! executor.
//!
//! The subsystem turns the repo from a benchmark harness into an
//! inference engine: [`engine::Engine`] owns, per (model, graph) entry,
//! the compiled `Program`, `Partitions`, and a warm `exec::Executor`
//! (persistent worker pool + scratch arenas reused across requests) on
//! a dedicated thread, so *any* zoo or `--model-file` spec is servable
//! — not just the four paper models with baked PJRT artifacts.
//!
//! - [`queue`] — the bounded submission queue: admission control as a
//!   channel-capacity fact (full → typed `Rejected`, never unbounded
//!   latency).
//! - [`batch`] — micro-batch assembly: block for one request, drain the
//!   burst behind it up to a cap, no batching timer.
//! - [`engine`] — the engine itself: registration, submission tickets,
//!   typed per-request errors, live stats probes.
//! - [`bench`] — the closed/open-loop load generator behind
//!   `switchblade serve --bench`, reporting throughput + exact
//!   p50/p95/p99 into `BENCH_serve.json`.
//!
//! Observability rides the existing rails: `serve_*` counters and
//! histograms in [`crate::obs::metrics`], `request`/`batch` spans on
//! per-entry [`crate::obs::trace`] lanes so Chrome traces show request
//! overlap.

pub mod batch;
pub mod bench;
pub mod engine;
pub mod queue;

pub use bench::{run_bench, BenchOptions, BenchReport};
pub use engine::{
    Engine, EngineConfig, EntryId, EntryInfo, EntryKey, EntryStats, Input, Response, ServeError,
    SubmitOptions, Ticket,
};
pub use queue::{SubmitError, SubmitQueue};
