//! The persistent inference engine.
//!
//! ## Why an entry is a thread
//!
//! `exec::Executor<'a>` borrows its `Program` and `Partitions`, so a
//! long-lived engine cannot park warm executors in a struct field
//! without self-referential borrows. Instead, every registered
//! (model, graph) entry gets a dedicated OS thread that owns the whole
//! chain on its stack — built `IrGraph` → compiled `Program` →
//! `Partitions` → one warm `Executor` (persistent worker pool + scratch
//! arenas, reused for every request the entry ever serves) — and drains
//! micro-batches from a bounded submission queue. Safe Rust, no new
//! `unsafe`, and the expensive compile/partition/warm-up happens once
//! per entry while early requests queue behind it.
//!
//! ## Request flow
//!
//! [`Engine::submit_with`] takes an [`Input`] (a caller feature matrix
//! or a deterministic seed) plus [`SubmitOptions`] (deadline),
//! validates the feature shape, then try-sends the job into the
//! entry's [`SubmitQueue`] — a full queue is a typed
//! [`ServeError::Rejected`] (admission control), never unbounded
//! latency. The legacy `submit`/`submit_deadline`/`submit_seeded`/
//! `submit_seeded_deadline` surface survives as thin wrappers. The
//! entry thread lifts whole bursts out with [`next_batch`], expires
//! each member against its *own* deadline, then runs the survivors as
//! **one batched executor run** ([`Executor::try_run_with`] with the
//! live feature matrices stacked) — one partition walk per micro-batch,
//! so the gather/scatter stream is amortized across every request in
//! it — and answers on per-request reply channels held by the callers'
//! [`Ticket`]s. A request whose lane of the batched output is
//! non-finite fails alone ([`ServeError::NonFinite`], counted in
//! `serve_errors`) — its batch-mates still get their (bit-identical to
//! solo) results and the engine keeps serving. Callers that need
//! bounded waits attach a deadline via the options (pair with
//! [`Ticket::wait_timeout`]); a request whose deadline passes while it
//! queues is answered [`ServeError::DeadlineExceeded`] without running,
//! counted in `serve_timeouts` — batch-mates never extend each other's
//! budget.
//!
//! ## Supervised recovery
//!
//! A worker-pool fault (a panicking shard job — see
//! [`exec::PoolError`](crate::exec::PoolError)) used to kill the entry
//! thread forever: every later request got `EngineDown` until process
//! restart. Now the entry loop *supervises* its executor: a fault fails
//! only the in-flight batch's tickets (typed [`ServeError::Faulted`]),
//! then the warm executor is dropped and rebuilt — with capped
//! exponential backoff — and serving resumes bit-identically
//! (`serve_entry_restarts`). Repeated faults walk a degradation ladder
//! whose rungs are all bit-identical by construction: configured modes →
//! pipelining off → naive kernel — and, exhausted, the entry is
//! *quarantined* (`serve_degraded` / `serve_quarantined`): it stays
//! alive and answers every request with a typed
//! [`ServeError::Quarantined`] instead of dying silently.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::compiler::compile;
use crate::coordinator::degree_column;
use crate::exec::{
    weights, Executor, KernelMode, Matrix, PipelineMode, PoolStats, RunRequest, ScratchStats,
};
use crate::graph::Csr;
use crate::ir::spec::{ModelDims, ModelSpec};
use crate::ir::IrGraph;
use crate::obs::{faultinject, metrics, trace};
use crate::partition::Method;
use crate::sim::AcceleratorConfig;

use super::batch::next_batch;
use super::queue::{self, SubmitError, SubmitQueue};

/// Engine-wide configuration, applied to every entry registered after.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Bounded per-entry submission-queue depth; a full queue rejects
    /// ([`ServeError::Rejected`]) instead of queueing unboundedly.
    pub queue_depth: usize,
    /// Micro-batch cap: how many queued requests one entry wakeup may
    /// serve back to back (see [`next_batch`]).
    pub batch_max: usize,
    /// Executor pool width; 0 = the partitioning's sThread count.
    pub workers: usize,
    /// Compute tier of the warm executor.
    pub kernel: KernelMode,
    /// Interval-pipelining mode of the warm executor.
    pub pipeline: PipelineMode,
    /// Accelerator model that shapes the partitioning (shard bytes,
    /// DstBuffer bytes, sThreads).
    pub accel: AcceleratorConfig,
    /// Partitioning method entries are built with.
    pub method: Method,
    /// Consecutive executor faults before an entry descends one rung of
    /// the degradation ladder (configured modes → pipelining off → naive
    /// kernel → quarantined). Clamped to ≥ 1.
    pub fault_threshold: u32,
    /// Cap on the exponential backoff (milliseconds) between an
    /// executor fault and the rebuild.
    pub max_backoff_ms: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            queue_depth: 64,
            batch_max: 8,
            workers: 0,
            kernel: KernelMode::default(),
            pipeline: PipelineMode::default(),
            accel: AcceleratorConfig::switchblade(),
            method: Method::Fggp,
            fault_threshold: 3,
            max_backoff_ms: 100,
        }
    }
}

/// Typed serving failures. None of these takes the engine down: a
/// rejected or poisoned request fails alone and the entry keeps
/// draining its queue.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Admission control: the entry's bounded queue held `depth`
    /// requests already.
    Rejected { entry: String, depth: usize },
    /// The request's feature matrix does not match the entry's
    /// (vertices, input-dim) shape.
    BadRequest { entry: String, reason: String },
    /// The model produced a non-finite output for this request.
    /// Previously an `assert!` here panicked the whole server; now the
    /// one request fails and the error lands in the `serve_errors`
    /// metric.
    NonFinite { entry: String, seq: u64 },
    /// The executor faulted (a worker-pool panic) while this request's
    /// batch was in flight. Only the in-flight batch fails this way; the
    /// entry rebuilds its warm executor and keeps serving
    /// (`serve_entry_restarts`).
    Faulted { entry: String, seq: u64, cause: String },
    /// The request's deadline passed — either while it queued (the entry
    /// skips execution and answers this) or in [`Ticket::wait_timeout`].
    /// Counted in `serve_timeouts`.
    DeadlineExceeded { entry: String, seq: u64 },
    /// The control-plane stats probe could not be admitted because the
    /// entry's queue is saturated — a typed "alive but busy", so health
    /// checks degrade gracefully exactly when traffic peaks.
    StatsUnavailable { entry: String },
    /// The entry exhausted its degradation ladder (persistent faults)
    /// and now rejects all work with this typed answer instead of dying.
    Quarantined { entry: String, seq: u64 },
    /// The entry's thread is gone (engine shutting down).
    EngineDown { entry: String },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Rejected { entry, depth } => {
                write!(f, "{entry}: rejected — submission queue full (depth {depth})")
            }
            ServeError::BadRequest { entry, reason } => {
                write!(f, "{entry}: bad request — {reason}")
            }
            ServeError::NonFinite { entry, seq } => {
                write!(f, "{entry}: request {seq} produced non-finite output")
            }
            ServeError::Faulted { entry, seq, cause } => {
                write!(f, "{entry}: request {seq} lost to an executor fault — {cause}")
            }
            ServeError::DeadlineExceeded { entry, seq } => {
                write!(f, "{entry}: request {seq} exceeded its deadline")
            }
            ServeError::StatsUnavailable { entry } => {
                write!(f, "{entry}: stats probe rejected — queue saturated")
            }
            ServeError::Quarantined { entry, seq } => {
                write!(f, "{entry}: request {seq} rejected — entry quarantined after persistent faults")
            }
            ServeError::EngineDown { entry } => {
                write!(f, "{entry}: engine is shutting down")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Identity of an engine entry: which model (stable spec fingerprint
/// covering name + source), at which build dims, over which graph shape.
/// [`Engine::register`] dedups on this.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct EntryKey {
    pub model: u64,
    pub dims: String,
    pub vertices: usize,
    pub edges: usize,
}

/// Static facts about a registered entry.
#[derive(Debug, Clone)]
pub struct EntryInfo {
    /// Human label: model display name + dims.
    pub label: String,
    pub key: EntryKey,
    /// Expected feature width of a request.
    pub in_dim: usize,
    /// Expected feature rows of a request (graph vertices).
    pub vertices: usize,
}

/// Opaque handle to a registered entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EntryId(pub(crate) usize);

/// One completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    pub out: Matrix,
    /// Per-entry request sequence number.
    pub seq: u64,
    /// Queue wait: submission → picked into a micro-batch.
    pub wait_s: f64,
    /// Execution time inside the warm executor.
    pub exec_s: f64,
    /// Size of the micro-batch this request was served in.
    pub batched: usize,
}

/// Handle to an admitted request; [`Ticket::wait`] blocks for the
/// result. Dropping the ticket abandons the request (it still runs).
pub struct Ticket {
    rx: mpsc::Receiver<Result<Response, ServeError>>,
    entry: String,
    pub seq: u64,
}

impl Ticket {
    pub fn wait(self) -> Result<Response, ServeError> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(ServeError::EngineDown { entry: self.entry }),
        }
    }

    /// Bounded wait: [`ServeError::DeadlineExceeded`] (counted in
    /// `serve_timeouts`) if no reply lands within `timeout`. The request
    /// itself keeps running; its eventual reply is discarded with the
    /// ticket — the entry's `try_send` to a dropped receiver is a no-op.
    pub fn wait_timeout(self, timeout: Duration) -> Result<Response, ServeError> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                metrics::counter("serve_timeouts", 1);
                Err(ServeError::DeadlineExceeded {
                    entry: self.entry,
                    seq: self.seq,
                })
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(ServeError::EngineDown { entry: self.entry })
            }
        }
    }
}

/// Counters snapshotted from a live entry via [`Engine::stats`].
#[derive(Debug, Clone, Default)]
pub struct EntryStats {
    /// Requests served (including ones that failed `NonFinite`).
    pub requests: u64,
    /// Micro-batches drained.
    pub batches: u64,
    /// Largest micro-batch served so far.
    pub max_batch: usize,
    /// Requests that failed with a typed per-request error.
    pub errors: u64,
    /// Submissions rejected by admission control (counted engine-side).
    pub rejected: u64,
    /// Executor faults survived (each fails one in-flight batch).
    pub faults: u64,
    /// Requests answered `DeadlineExceeded` at dequeue (expired while
    /// queued; `Ticket::wait_timeout` timeouts are counted caller-side).
    pub timeouts: u64,
    /// Warm-executor rebuilds after faults (`serve_entry_restarts`).
    pub restarts: u64,
    /// Current degradation rung: 0 = configured modes, 1 = pipelining
    /// off, 2 = naive kernel, 3 = quarantined. Every serving rung is
    /// bit-identical — degradation sheds machinery, not accuracy.
    pub rung: u32,
    /// True once the entry only answers [`ServeError::Quarantined`].
    pub quarantined: bool,
    /// One-time compile + partition + warm-up cost, seconds (summed
    /// across fault-recovery rebuilds).
    pub warm_s: f64,
    /// The warm executor's scratch-pool counters — `misses` staying
    /// flat across requests is the "steady state allocates nothing" pin.
    pub scratch: ScratchStats,
    /// The warm executor's worker-pool counters — `spawned` staying
    /// flat is the "threads spawn once per entry" pin.
    pub pool: PoolStats,
}

/// Request body for [`Engine::submit_with`]: either caller-supplied
/// features or a deterministic seed expanded entry-side (the same
/// construction as `coordinator::reference_run`, so equal seeds pin
/// bit-equal outputs — the load generator and differential tests lean
/// on this).
#[derive(Debug, Clone)]
pub enum Input {
    /// A `[vertices, in_dim]` feature matrix.
    Features(Matrix),
    /// Deterministic features derived from this seed at the entry's
    /// (vertices, in_dim) shape.
    Seeded(u64),
}

/// Per-request options for [`Engine::submit_with`]. `Default` is "no
/// deadline" — add fields here instead of growing new `submit_*`
/// method variants.
#[derive(Debug, Clone, Copy, Default)]
pub struct SubmitOptions {
    /// Queue-wait bound: if the request is still queued when this much
    /// time has elapsed since submission, the entry answers
    /// [`ServeError::DeadlineExceeded`] without running it (counted in
    /// `serve_timeouts`). Pair with [`Ticket::wait_timeout`] for a
    /// fully bounded round trip.
    pub deadline: Option<Duration>,
}

enum Job {
    Infer(InferJob),
    /// Control-plane probe: snapshot the entry's counters + executor
    /// stats. Round-trips through the same queue so it observes every
    /// request admitted before it.
    Stats(mpsc::SyncSender<EntryStats>),
}

struct InferJob {
    seq: u64,
    x: Matrix,
    enq: Instant,
    /// Absolute deadline; a job dequeued past it is answered
    /// `DeadlineExceeded` without running.
    deadline: Option<Instant>,
    reply: mpsc::SyncSender<Result<Response, ServeError>>,
}

struct Entry {
    info: EntryInfo,
    /// `None` once shutdown has begun.
    queue: Option<SubmitQueue<Job>>,
    seq: AtomicU64,
    rejected: AtomicU64,
    handle: Option<JoinHandle<()>>,
}

/// The persistent multi-model serving engine. Entries register once and
/// stay warm until the engine drops; see the module docs for the
/// threading model.
pub struct Engine {
    cfg: EngineConfig,
    entries: Vec<Entry>,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Self {
        Engine {
            cfg,
            entries: Vec::new(),
        }
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    pub fn num_entries(&self) -> usize {
        self.entries.len()
    }

    pub fn ids(&self) -> Vec<EntryId> {
        (0..self.entries.len()).map(EntryId).collect()
    }

    pub fn info(&self, id: EntryId) -> &EntryInfo {
        &self.entries[id.0].info
    }

    /// Register `spec` built at `dims` over graph `g`, spawning the
    /// entry's thread (compile → partition → warm-up run happen there,
    /// off the caller; early submissions queue behind the warm-up).
    /// Re-registering an identical (model, dims, graph-shape) key
    /// returns the existing entry.
    pub fn register(
        &mut self,
        spec: &ModelSpec,
        dims: ModelDims,
        g: Arc<Csr>,
    ) -> Result<EntryId, String> {
        let key = EntryKey {
            model: spec.fingerprint(),
            dims: format!("{dims}"),
            vertices: g.num_vertices(),
            edges: g.num_edges(),
        };
        if let Some(i) = self.entries.iter().position(|e| e.info.key == key) {
            return Ok(EntryId(i));
        }
        let ir = spec.build(dims).map_err(|e| format!("{}: {e}", spec.name()))?;
        let label = format!("{} {dims}", spec.display());
        let info = EntryInfo {
            label: label.clone(),
            key,
            in_dim: ir.input_dim() as usize,
            vertices: g.num_vertices(),
        };
        let (q, rx) = queue::bounded::<Job>(self.cfg.queue_depth);
        let cfg = self.cfg;
        let idx = self.entries.len();
        // Thread-locals don't cross `spawn`: sample the tracing flag
        // here, on the session-owning thread, and ship it in.
        let tracing = trace::active();
        let handle = std::thread::Builder::new()
            .name(format!("sb-serve-{idx}"))
            .spawn(move || entry_loop(ir, g, cfg, rx, idx, label, tracing))
            .map_err(|e| format!("spawning serve entry thread: {e}"))?;
        self.entries.push(Entry {
            info,
            queue: Some(q),
            seq: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            handle: Some(handle),
        });
        Ok(EntryId(idx))
    }

    /// Submit a request for inference — the single submission entry
    /// point. Non-blocking: a full queue returns
    /// [`ServeError::Rejected`] immediately. [`Input::Seeded`] expands
    /// to deterministic features at the entry's shape; a deadline in
    /// `opts` bounds the queue wait (see [`SubmitOptions::deadline`]).
    pub fn submit_with(
        &self,
        id: EntryId,
        input: Input,
        opts: SubmitOptions,
    ) -> Result<Ticket, ServeError> {
        let x = match input {
            Input::Features(x) => x,
            Input::Seeded(seed) => {
                let info = &self.entries[id.0].info;
                weights::init_features(seed, info.vertices, info.in_dim)
            }
        };
        self.submit_inner(id, x, opts.deadline.map(|d| Instant::now() + d))
    }

    /// Deprecated: thin wrapper over [`Engine::submit_with`] with
    /// [`Input::Features`] and default options.
    pub fn submit(&self, id: EntryId, x: Matrix) -> Result<Ticket, ServeError> {
        self.submit_with(id, Input::Features(x), SubmitOptions::default())
    }

    /// Deprecated: thin wrapper over [`Engine::submit_with`] with
    /// [`Input::Features`] and a deadline.
    pub fn submit_deadline(
        &self,
        id: EntryId,
        x: Matrix,
        deadline: Duration,
    ) -> Result<Ticket, ServeError> {
        self.submit_with(
            id,
            Input::Features(x),
            SubmitOptions {
                deadline: Some(deadline),
            },
        )
    }

    fn submit_inner(
        &self,
        id: EntryId,
        x: Matrix,
        deadline: Option<Instant>,
    ) -> Result<Ticket, ServeError> {
        let e = &self.entries[id.0];
        let entry = e.info.label.clone();
        if x.rows != e.info.vertices || x.cols != e.info.in_dim {
            return Err(ServeError::BadRequest {
                entry,
                reason: format!(
                    "features are {}x{}, entry expects {}x{}",
                    x.rows, x.cols, e.info.vertices, e.info.in_dim
                ),
            });
        }
        let q = e
            .queue
            .as_ref()
            .ok_or_else(|| ServeError::EngineDown { entry: entry.clone() })?;
        let seq = e.seq.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::sync_channel(1);
        match q.submit(Job::Infer(InferJob {
            seq,
            x,
            enq: Instant::now(),
            deadline,
            reply,
        })) {
            Ok(()) => Ok(Ticket { rx, entry, seq }),
            Err(SubmitError::Full(_)) => {
                e.rejected.fetch_add(1, Ordering::Relaxed);
                metrics::counter("serve_rejected", 1);
                Err(ServeError::Rejected {
                    entry,
                    depth: q.depth(),
                })
            }
            Err(SubmitError::Closed(_)) => Err(ServeError::EngineDown { entry }),
        }
    }

    /// Deprecated: thin wrapper over [`Engine::submit_with`] with
    /// [`Input::Seeded`] and default options.
    pub fn submit_seeded(&self, id: EntryId, seed: u64) -> Result<Ticket, ServeError> {
        self.submit_with(id, Input::Seeded(seed), SubmitOptions::default())
    }

    /// Deprecated: thin wrapper over [`Engine::submit_with`] with
    /// [`Input::Seeded`] and a deadline.
    pub fn submit_seeded_deadline(
        &self,
        id: EntryId,
        seed: u64,
        deadline: Duration,
    ) -> Result<Ticket, ServeError> {
        self.submit_with(
            id,
            Input::Seeded(seed),
            SubmitOptions {
                deadline: Some(deadline),
            },
        )
    }

    /// Stats probe through the entry's queue (so it observes every
    /// request admitted before it). Non-blocking admission: a saturated
    /// queue answers a typed [`ServeError::StatsUnavailable`] instead of
    /// blocking the health check behind user traffic — "saturated but
    /// alive" is itself the answer.
    pub fn stats(&self, id: EntryId) -> Result<EntryStats, ServeError> {
        let e = &self.entries[id.0];
        let entry = e.info.label.clone();
        let q = e
            .queue
            .as_ref()
            .ok_or_else(|| ServeError::EngineDown { entry: entry.clone() })?;
        let (tx, rx) = mpsc::sync_channel(1);
        match q.submit(Job::Stats(tx)) {
            Ok(()) => {}
            Err(SubmitError::Full(_)) => {
                return Err(ServeError::StatsUnavailable { entry });
            }
            Err(SubmitError::Closed(_)) => return Err(ServeError::EngineDown { entry }),
        }
        let mut st = rx.recv().map_err(|_| ServeError::EngineDown { entry })?;
        st.rejected = e.rejected.load(Ordering::Relaxed);
        Ok(st)
    }

    /// Begin shutdown: close every submission queue, so each entry loop
    /// drains its residue and exits, and every later submit gets a typed
    /// [`ServeError::EngineDown`] instead of racing the teardown.
    /// Idempotent; [`Drop`] calls it and then joins the entry threads.
    pub fn shutdown(&mut self) {
        for e in &mut self.entries {
            e.queue = None;
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // Closing every queue ends each entry loop after its residue
        // drains; join so in-flight batches finish (and their trace
        // spans flush) before the engine is gone. An entry thread that
        // died of a panic is recorded, not swallowed — a corpse found at
        // shutdown still names itself.
        self.shutdown();
        for e in &mut self.entries {
            if let Some(h) = e.handle.take() {
                if h.join().is_err() {
                    metrics::counter("serve_entry_panics", 1);
                    eprintln!(
                        "serve: entry '{}' thread panicked (found at shutdown)",
                        e.info.label
                    );
                }
            }
        }
    }
}

/// The `(kernel, pipeline)` pair for a degradation rung. Every rung is
/// bit-identical to the configured modes by construction (the
/// differential tests pin this), so degradation sheds the machinery a
/// fault might implicate — overlap threads, then the kernel tier —
/// without ever changing answers.
fn degraded_modes(cfg: &EngineConfig, rung: u32) -> (KernelMode, PipelineMode) {
    match rung {
        0 => (cfg.kernel, cfg.pipeline),
        1 => (cfg.kernel, PipelineMode::Off),
        _ => (KernelMode::Naive, PipelineMode::Off),
    }
}

/// The per-entry service loop: owns the compiled program and partitions
/// for the entry's whole lifetime, and *supervises* the warm executor —
/// a fault fails only the in-flight batch, then the executor is rebuilt
/// (capped exponential backoff, degradation ladder) and serving resumes.
fn entry_loop(
    ir: IrGraph,
    g: Arc<Csr>,
    cfg: EngineConfig,
    rx: mpsc::Receiver<Job>,
    idx: usize,
    label: String,
    tracing: bool,
) {
    let track = trace::serve_track(idx);
    // Compile + partition once: they are deterministic over immutable
    // inputs, so a runtime fault cannot have corrupted them — only the
    // executor (pool threads, scratch arenas) is rebuilt on recovery.
    let t_warm = Instant::now();
    let prog = compile(&ir);
    let parts = cfg.method.run(&g, cfg.accel.partition_config(&prog));
    let deg = degree_column(&g);
    let build_s = t_warm.elapsed().as_secs_f64();

    let mut requests = 0u64;
    let mut batches = 0u64;
    let mut errors = 0u64;
    let mut faults = 0u64;
    let mut timeouts = 0u64;
    let mut restarts = 0u64;
    let mut max_batch = 0usize;
    let mut warm_s = build_s;
    // Consecutive faults since the last successful request.
    let mut consecutive = 0u32;
    let mut rung = 0u32;
    let threshold = cfg.fault_threshold.max(1);

    // One fault-and-recovery supervision step per iteration: (re)build
    // the warm executor at the current rung, serve until the queue
    // closes or a fault demands a rebuild.
    let mut shutdown = false;
    'serving: while !shutdown && rung < 3 {
        let _rspan = (restarts > 0).then(|| {
            trace::span_if(
                tracing,
                trace::names::RECOVER,
                trace::cat::SERVE,
                track,
                -1,
                restarts as i32,
                rung as i32,
            )
        });
        if restarts > 0 {
            // Capped exponential backoff keeps a hard-failing entry from
            // burning a core on rebuild churn.
            let ms = (1u64 << consecutive.min(10)).min(cfg.max_backoff_ms.max(1));
            std::thread::sleep(Duration::from_millis(ms));
        }
        let (kmode, pmode) = degraded_modes(&cfg, rung);
        let t0 = Instant::now();
        let mut ex = Executor::new(&prog, &parts)
            .with_kernel_mode(kmode)
            .with_pipeline_mode(pmode);
        if cfg.workers > 0 {
            ex = ex.with_workers(cfg.workers);
        }
        // Warm-up inference: sizes every scratch arena and spawns the
        // worker pool before the first real request, so steady state —
        // no new scratch misses, no new thread spawns — starts at
        // request 1. A warm-up fault (an always-faulting model) walks
        // the same recovery ladder as a serving fault, so it converges
        // on quarantine instead of spinning.
        let x0 = weights::init_features(0, g.num_vertices(), ir.input_dim() as usize);
        if ex.try_run(&x0, &deg).is_err() {
            faults += 1;
            consecutive += 1;
            restarts += 1;
            metrics::counter("serve_entry_restarts", 1);
            if consecutive >= threshold * (rung + 1) {
                rung += 1;
                metrics::counter("serve_degraded", 1);
            }
            continue 'serving;
        }
        warm_s += t0.elapsed().as_secs_f64();
        metrics::observe("serve_warm_s", warm_s);

        let mut faulted = false;
        while let Some(batch) = next_batch(&rx, cfg.batch_max) {
            // Injection site: stall the consumer so admission control
            // (the bounded queue) is testable deterministically.
            faultinject::queue_stall();
            let mut jobs = Vec::with_capacity(batch.len());
            for job in batch {
                match job {
                    Job::Infer(j) => jobs.push(j),
                    Job::Stats(tx) => {
                        let _ = tx.try_send(EntryStats {
                            requests,
                            batches,
                            max_batch,
                            errors,
                            rejected: 0, // merged engine-side
                            faults,
                            timeouts,
                            restarts,
                            rung,
                            quarantined: false,
                            warm_s,
                            scratch: ex.scratch_stats(),
                            pool: ex.pool_stats(),
                        });
                    }
                }
            }
            if jobs.is_empty() {
                continue;
            }
            let size = jobs.len();
            batches += 1;
            max_batch = max_batch.max(size);
            metrics::counter("serve_batches", 1);
            metrics::observe("serve_batch_size", size as f64);
            {
                let _batch_span = trace::span_if(
                    tracing,
                    trace::names::BATCH,
                    trace::cat::SERVE,
                    track,
                    -1,
                    (batches - 1) as i32,
                    size as i32,
                );
                // Expire each member against its *own* deadline at
                // dequeue — batch-mates never extend another request's
                // budget — then run the survivors as ONE batched
                // executor run: a single partition walk serves the
                // whole micro-batch.
                let mut live = Vec::with_capacity(jobs.len());
                for j in jobs {
                    if let Some(dl) = j.deadline {
                        if Instant::now() >= dl {
                            // Expired while queued: answer without
                            // spending executor time on a result the
                            // caller already gave up on.
                            timeouts += 1;
                            metrics::counter("serve_timeouts", 1);
                            let _ = j.reply.try_send(Err(ServeError::DeadlineExceeded {
                                entry: label.clone(),
                                seq: j.seq,
                            }));
                            continue;
                        }
                    }
                    live.push(j);
                }
                if !live.is_empty() {
                    let waits: Vec<f64> =
                        live.iter().map(|j| j.enq.elapsed().as_secs_f64()).collect();
                    let t0 = Instant::now();
                    let res = {
                        let _span = trace::span_if(
                            tracing,
                            trace::names::REQUEST,
                            trace::cat::SERVE,
                            track,
                            -1,
                            live[0].seq as i32,
                            live.len() as i32,
                        );
                        let req =
                            RunRequest::batched(live.iter().map(|j| &j.x).collect(), &deg);
                        ex.try_run_with(&req)
                    };
                    let exec_s = t0.elapsed().as_secs_f64();
                    requests += live.len() as u64;
                    metrics::counter("serve_requests", live.len() as u64);
                    for &w in &waits {
                        metrics::observe("serve_wait_s", w);
                        metrics::observe("serve_latency_s", w + exec_s);
                    }
                    match res {
                        Ok(out) => {
                            consecutive = 0;
                            for ((j, mut m), wait_s) in
                                live.into_iter().zip(out.outputs).zip(waits)
                            {
                                // Injection site: feeds the existing
                                // non-finite guard, proving a poisoned
                                // output fails alone (no restart).
                                faultinject::poison_output(&mut m.data);
                                // Lanes are column-disjoint through the
                                // whole walk, so a non-finite member
                                // fails alone: its batch-mates' lanes
                                // are untouched.
                                let r = if m.data.iter().all(|v| v.is_finite()) {
                                    Ok(Response {
                                        out: m,
                                        seq: j.seq,
                                        wait_s,
                                        exec_s,
                                        batched: size,
                                    })
                                } else {
                                    errors += 1;
                                    metrics::counter("serve_errors", 1);
                                    Err(ServeError::NonFinite {
                                        entry: label.clone(),
                                        seq: j.seq,
                                    })
                                };
                                let _ = j.reply.try_send(r);
                            }
                        }
                        Err(cause) => {
                            // The executor faulted under this batch:
                            // fail every in-flight member with the
                            // typed cause attributed to its own seq,
                            // then leave the batch loop to rebuild.
                            faults += 1;
                            let cause = cause.to_string();
                            for j in live {
                                let _ = j.reply.try_send(Err(ServeError::Faulted {
                                    entry: label.clone(),
                                    seq: j.seq,
                                    cause: cause.clone(),
                                }));
                            }
                            faulted = true;
                        }
                    }
                }
            }
            if tracing {
                trace::flush_thread();
            }
            if faulted {
                break;
            }
        }
        if !faulted {
            // `next_batch` returned `None`: the queue closed — shutdown.
            shutdown = true;
            break 'serving;
        }
        consecutive += 1;
        restarts += 1;
        metrics::counter("serve_entry_restarts", 1);
        if consecutive >= threshold * (rung + 1) {
            rung += 1;
            metrics::counter("serve_degraded", 1);
        }
        // Drop `ex` (joins its pool) and rebuild on the next iteration.
    }

    if !shutdown && rung >= 3 {
        // Degradation ladder exhausted: quarantine. The entry stays
        // alive and answers typed rejections — visibly sick beats
        // silently dead (`EngineDown` on every request forever).
        metrics::counter("serve_quarantined", 1);
        while let Some(batch) = next_batch(&rx, cfg.batch_max) {
            for job in batch {
                match job {
                    Job::Infer(j) => {
                        let _ = j.reply.try_send(Err(ServeError::Quarantined {
                            entry: label.clone(),
                            seq: j.seq,
                        }));
                    }
                    Job::Stats(tx) => {
                        let _ = tx.try_send(EntryStats {
                            requests,
                            batches,
                            max_batch,
                            errors,
                            rejected: 0, // merged engine-side
                            faults,
                            timeouts,
                            restarts,
                            rung,
                            quarantined: true,
                            warm_s,
                            scratch: ScratchStats::default(),
                            pool: PoolStats::default(),
                        });
                    }
                }
            }
        }
    }
    if tracing {
        trace::flush_thread();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::Dataset;
    use crate::ir::zoo::ModelZoo;

    fn tiny() -> (Arc<Csr>, Arc<ModelSpec>) {
        let g = Arc::new(Dataset::Ak.load(7));
        let spec = ModelZoo::builtin().resolve("gcn").unwrap();
        (g, spec)
    }

    #[test]
    fn register_dedups_identical_entries() {
        let (g, spec) = tiny();
        let mut e = Engine::new(EngineConfig::default());
        let a = e
            .register(&spec, ModelDims::uniform(1, 4), g.clone())
            .unwrap();
        let b = e
            .register(&spec, ModelDims::uniform(1, 4), g.clone())
            .unwrap();
        assert_eq!(a, b);
        assert_eq!(e.num_entries(), 1);
        // Different dims is a different entry.
        let c = e.register(&spec, ModelDims::uniform(2, 4), g).unwrap();
        assert_ne!(a, c);
        assert_eq!(e.num_entries(), 2);
    }

    #[test]
    fn serves_and_counts_requests() {
        let (g, spec) = tiny();
        let mut e = Engine::new(EngineConfig::default());
        let id = e.register(&spec, ModelDims::uniform(1, 4), g.clone()).unwrap();
        let r = e.submit_seeded(id, 5).unwrap().wait().unwrap();
        assert_eq!(r.out.rows, g.num_vertices());
        assert!(r.batched >= 1);
        let st = e.stats(id).unwrap();
        assert_eq!(st.requests, 1);
        assert!(st.batches >= 1);
        assert!(st.warm_s > 0.0);
    }

    #[test]
    fn wrong_shape_is_a_bad_request() {
        let (g, spec) = tiny();
        let mut e = Engine::new(EngineConfig::default());
        let id = e.register(&spec, ModelDims::uniform(1, 4), g).unwrap();
        match e.submit(id, Matrix::zeros(3, 3)) {
            Err(ServeError::BadRequest { .. }) => {}
            other => panic!("expected BadRequest, got {:?}", other.map(|_| "ticket")),
        }
    }

    #[test]
    fn same_seed_is_bit_identical_across_requests() {
        let (g, spec) = tiny();
        let mut e = Engine::new(EngineConfig::default());
        let id = e.register(&spec, ModelDims::uniform(1, 4), g).unwrap();
        let a = e.submit_seeded(id, 9).unwrap().wait().unwrap();
        let b = e.submit_seeded(id, 9).unwrap().wait().unwrap();
        assert!(a.out.bits_eq(&b.out));
    }
}
