//! Bounded submission queue — the admission-control primitive of the
//! serving engine.
//!
//! Built on `std::sync::mpsc::sync_channel`: the channel's buffer IS the
//! per-entry request queue, so "queue full" is a channel-level fact, not
//! a counter we maintain on the side. Everything — data plane and the
//! stats probe — submits with [`SubmitQueue::submit`] (non-blocking — a
//! full queue *rejects*, which the engine surfaces as typed `Rejected` /
//! `StatsUnavailable` errors instead of unbounded latency): a health
//! check that blocks behind the saturation it is trying to observe is
//! worse than a typed "saturated but alive". The blocking
//! [`SubmitQueue::push`] remains for callers that genuinely must not be
//! load-shed.

use std::sync::mpsc::{Receiver, SyncSender, TrySendError};

/// Sending half of a bounded queue. Cloneable so several submitters can
/// feed one entry; the engine keeps one per entry.
pub struct SubmitQueue<T> {
    tx: SyncSender<T>,
    depth: usize,
}

/// Why a submission did not enter the queue; returns the item so the
/// caller can retry or drop it deliberately.
#[derive(Debug)]
pub enum SubmitError<T> {
    /// Admission control: the queue held `depth` items already.
    Full(T),
    /// The consuming side is gone (engine shutting down).
    Closed(T),
}

/// A bounded queue of depth `depth` (clamped to >= 1): the sender plus
/// the receiver the owning entry thread drains.
pub fn bounded<T>(depth: usize) -> (SubmitQueue<T>, Receiver<T>) {
    let depth = depth.max(1);
    let (tx, rx) = std::sync::mpsc::sync_channel(depth);
    (SubmitQueue { tx, depth }, rx)
}

impl<T> SubmitQueue<T> {
    /// Configured capacity.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Non-blocking admission: queue the item or reject it immediately.
    pub fn submit(&self, item: T) -> Result<(), SubmitError<T>> {
        match self.tx.try_send(item) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(it)) => Err(SubmitError::Full(it)),
            Err(TrySendError::Disconnected(it)) => Err(SubmitError::Closed(it)),
        }
    }

    /// Blocking push for control-plane messages that must not be
    /// load-shed (waits for a slot instead of rejecting).
    pub fn push(&self, item: T) -> Result<(), SubmitError<T>> {
        self.tx.send(item).map_err(|e| SubmitError::Closed(e.0))
    }
}

// Manual impl: `T` need not be `Clone` for the sender to be.
impl<T> Clone for SubmitQueue<T> {
    fn clone(&self) -> Self {
        SubmitQueue {
            tx: self.tx.clone(),
            depth: self.depth,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_exactly_at_capacity() {
        let (q, rx) = bounded::<u32>(2);
        q.submit(1).unwrap();
        q.submit(2).unwrap();
        match q.submit(3) {
            Err(SubmitError::Full(3)) => {}
            other => panic!("expected Full(3), got {other:?}"),
        }
        // Draining one slot re-admits exactly one submission.
        assert_eq!(rx.recv().unwrap(), 1);
        q.submit(4).unwrap();
        match q.submit(5) {
            Err(SubmitError::Full(5)) => {}
            other => panic!("expected Full(5), got {other:?}"),
        }
        assert_eq!(rx.recv().unwrap(), 2);
        assert_eq!(rx.recv().unwrap(), 4);
    }

    #[test]
    fn closed_when_receiver_dropped() {
        let (q, rx) = bounded::<u32>(1);
        drop(rx);
        match q.submit(7) {
            Err(SubmitError::Closed(7)) => {}
            other => panic!("expected Closed(7), got {other:?}"),
        }
        match q.push(8) {
            Err(SubmitError::Closed(8)) => {}
            other => panic!("expected Closed(8), got {other:?}"),
        }
    }

    #[test]
    fn depth_clamps_to_one() {
        let (q, _rx) = bounded::<u32>(0);
        assert_eq!(q.depth(), 1);
        q.submit(1).unwrap();
        assert!(matches!(q.submit(2), Err(SubmitError::Full(2))));
    }

    #[test]
    fn cloned_senders_share_capacity() {
        let (q, rx) = bounded::<u32>(2);
        let q2 = q.clone();
        q.submit(1).unwrap();
        q2.submit(2).unwrap();
        assert!(matches!(q.submit(3), Err(SubmitError::Full(3))));
        assert!(matches!(q2.submit(3), Err(SubmitError::Full(3))));
        drop(rx);
    }
}
