//! Micro-batch assembly: block for the first job, then greedily drain
//! whatever else is already queued, up to a cap.
//!
//! This is the serving engine's batching policy in one function. It adds
//! no artificial delay (no batching timer): a lone request is served
//! immediately, while a burst that queued up behind a slow request is
//! lifted out in one `recv` wakeup and executed as **one batched run**
//! through `Executor::try_run_with` — one partition walk for the whole
//! micro-batch, the gather/scatter stream amortized across every member.
//! FIFO order is preserved — the channel is the queue.
//!
//! Deadlines stay per-request: the entry loop expires each drained
//! member against its *own* deadline before the batched run, so sharing
//! a walk never extends (or shrinks) a batch-mate's budget.

use std::sync::mpsc::Receiver;

/// Pull the next micro-batch from `rx`: block for the first item, then
/// drain without blocking until the batch holds `max` items (clamped to
/// >= 1) or the queue is momentarily empty. Returns `None` only when
/// every sender is gone *and* the queue is drained — the entry thread's
/// shutdown signal.
pub fn next_batch<T>(rx: &Receiver<T>, max: usize) -> Option<Vec<T>> {
    let max = max.max(1);
    let first = rx.recv().ok()?;
    let mut batch = Vec::with_capacity(max.min(8));
    batch.push(first);
    while batch.len() < max {
        match rx.try_recv() {
            Ok(item) => batch.push(item),
            // Empty or Disconnected: serve what we have; a final
            // Disconnected with residue is caught by the next call.
            Err(_) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    #[test]
    fn drains_queued_burst_in_fifo_order() {
        let (tx, rx) = sync_channel(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let b = next_batch(&rx, 8).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn respects_the_batch_cap() {
        let (tx, rx) = sync_channel(8);
        for i in 0..6 {
            tx.send(i).unwrap();
        }
        assert_eq!(next_batch(&rx, 4).unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(next_batch(&rx, 4).unwrap(), vec![4, 5]);
    }

    #[test]
    fn lone_request_is_served_without_waiting_for_more() {
        let (tx, rx) = sync_channel(8);
        tx.send(42).unwrap();
        assert_eq!(next_batch(&rx, 8).unwrap(), vec![42]);
    }

    #[test]
    fn cap_zero_clamps_to_one() {
        let (tx, rx) = sync_channel(8);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(next_batch(&rx, 0).unwrap(), vec![1]);
        assert_eq!(next_batch(&rx, 0).unwrap(), vec![2]);
    }

    #[test]
    fn residue_after_sender_drop_is_still_served_then_none() {
        let (tx, rx) = sync_channel(8);
        tx.send(9).unwrap();
        tx.send(10).unwrap();
        drop(tx);
        assert_eq!(next_batch(&rx, 8).unwrap(), vec![9, 10]);
        assert!(next_batch(&rx, 8).is_none());
    }
}
