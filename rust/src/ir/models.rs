//! The *legacy* model builders: the four evaluation models of Tbl I,
//! built as unified computational graphs. Each follows the paper's setup:
//! two stacked identical layers, dimension 128 for input / hidden /
//! output (the dims are parameters here so tests and the AOT path can use
//! small shapes).
//!
//! The pipeline's public currency is no longer this closed enum but the
//! open, spec-driven [`zoo`](super::zoo): every builder here has a
//! built-in `.gnn` zoo entry proven node-for-node identical (see
//! `ir::zoo` tests). The enum and builders stay as the differential
//! ground truth and for in-crate tests/benches; new models should be
//! written as specs, not added here.

use super::IrGraph;
use crate::isa::{ElwOp, Reduce};

/// The four evaluation models, paper order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Model {
    Gcn,
    Gat,
    Sage,
    Ggnn,
}

impl Model {
    pub const ALL: [Model; 4] = [Model::Gcn, Model::Gat, Model::Sage, Model::Ggnn];

    pub fn name(&self) -> &'static str {
        match self {
            Model::Gcn => "GCN",
            Model::Gat => "GAT",
            Model::Sage => "SAGE",
            Model::Ggnn => "GGNN",
        }
    }

    pub fn parse(s: &str) -> Option<Model> {
        match s.to_ascii_uppercase().as_str() {
            "GCN" => Some(Model::Gcn),
            "GAT" => Some(Model::Gat),
            "SAGE" | "SAGE-POOL" | "GRAPHSAGE" => Some(Model::Sage),
            "GGNN" | "GG-NN" => Some(Model::Ggnn),
            _ => None,
        }
    }

    /// Build the model IR with `layers` stacked layers.
    pub fn build(&self, layers: u32, in_dim: u32, hid_dim: u32, out_dim: u32) -> IrGraph {
        match self {
            Model::Gcn => gcn(layers, in_dim, hid_dim, out_dim),
            Model::Gat => gat(layers, in_dim, hid_dim, out_dim),
            Model::Sage => sage(layers, in_dim, hid_dim, out_dim),
            Model::Ggnn => ggnn(layers, in_dim),
        }
    }

    /// Paper configuration: 2 layers, 128-dim everywhere (§VI).
    pub fn build_paper(&self) -> IrGraph {
        self.build(2, 128, 128, 128)
    }

    /// The zoo spec equivalent of this legacy builder (proven
    /// node-for-node identical in `ir::zoo` tests).
    pub fn spec(&self) -> std::sync::Arc<super::spec::ModelSpec> {
        super::zoo::ModelZoo::builtin()
            .get(self.name())
            .expect("builtin zoo entry")
    }
}

fn layer_dims(layers: u32, in_dim: u32, hid_dim: u32, out_dim: u32) -> Vec<(u32, u32)> {
    (0..layers)
        .map(|l| {
            let di = if l == 0 { in_dim } else { hid_dim };
            let d_o = if l == layers - 1 { out_dim } else { hid_dim };
            (di, d_o)
        })
        .collect()
}

fn seed(model: &str, layer: u32, which: u32) -> u64 {
    // Stable, collision-free within a model: mirrored in python/compile/model.py.
    let mid = match model {
        "gcn" => 1u64,
        "gat" => 2,
        "sage" => 3,
        "ggnn" => 4,
        _ => 9,
    };
    mid * 1_000_000 + layer as u64 * 1_000 + which as u64
}

/// GCN (Kipf & Welling): `a_i = Σ_{j∈N(i)} h_j d_j^{-1/2}`,
/// `h_i' = ReLU(d_i^{-1/2} · W a_i)` (Tbl I row 1).
pub fn gcn(layers: u32, in_dim: u32, hid_dim: u32, out_dim: u32) -> IrGraph {
    let mut g = IrGraph::new("gcn");
    let deg = g.degree();
    let dn = g.unary(ElwOp::Rsqrt, deg, "deg_rsqrt");
    let mut h = g.input(in_dim);
    for (l, (di, d_o)) in layer_dims(layers, in_dim, hid_dim, out_dim).into_iter().enumerate() {
        let hs = g.row_scale(h, dn, &format!("l{l}.h_norm"));
        let e = g.scatter_src(hs, &format!("l{l}.msg"));
        let a = g.gather(Reduce::Sum, e, &format!("l{l}.agg"));
        let w = g.weight(di, d_o, seed("gcn", l as u32, 0), &format!("l{l}.W"));
        let z = g.dmm(a, w, &format!("l{l}.z"));
        let zn = g.row_scale(z, dn, &format!("l{l}.z_norm"));
        h = g.unary(ElwOp::Relu, zn, &format!("l{l}.relu"));
    }
    g.set_output(h);
    g
}

/// GAT (Veličković et al.), single head, numerically-stable edge softmax:
/// `e_ij = LeakyReLU(a_l·Wh_i + a_r·Wh_j)`,
/// `α_ij = softmax_j(e_ij)`, `a_i = Σ_j α_ij W h_j`, `h' = ReLU(a_i)`.
/// The stable softmax makes this a genuinely multi-round model: the edge
/// scores need a gather(max), a scatter back, then gather(sum) — two PLOF
/// groups per layer.
pub fn gat(layers: u32, in_dim: u32, hid_dim: u32, out_dim: u32) -> IrGraph {
    let mut g = IrGraph::new("gat");
    let mut h = g.input(in_dim);
    for (l, (di, d_o)) in layer_dims(layers, in_dim, hid_dim, out_dim).into_iter().enumerate() {
        let w = g.weight(di, d_o, seed("gat", l as u32, 0), &format!("l{l}.W"));
        let al = g.weight(d_o, 1, seed("gat", l as u32, 1), &format!("l{l}.a_l"));
        let ar = g.weight(d_o, 1, seed("gat", l as u32, 2), &format!("l{l}.a_r"));
        let hw = g.dmm(h, w, &format!("l{l}.hw"));
        let el = g.dmm(hw, al, &format!("l{l}.att_dst"));
        let er = g.dmm(hw, ar, &format!("l{l}.att_src"));
        // Edge score.
        let se = g.scatter_dst(el, &format!("l{l}.s_dst"));
        let ss = g.scatter_src(er, &format!("l{l}.s_src"));
        let sraw = g.binary(ElwOp::Add, se, ss, &format!("l{l}.s_raw"));
        let s = g.unary(ElwOp::LeakyRelu, sraw, &format!("l{l}.s"));
        // Stable softmax over in-edges.
        let m = g.gather(Reduce::Max, s, &format!("l{l}.s_max"));
        let sm = g.scatter_dst(m, &format!("l{l}.s_max_e"));
        let s2 = g.binary(ElwOp::Sub, s, sm, &format!("l{l}.s_cent"));
        let ex = g.unary(ElwOp::Exp, s2, &format!("l{l}.s_exp"));
        let den = g.gather(Reduce::Sum, ex, &format!("l{l}.den"));
        // Weighted message aggregation.
        let msg = g.scatter_src(hw, &format!("l{l}.msg"));
        let wmsg = g.row_scale(msg, ex, &format!("l{l}.wmsg"));
        let num = g.gather(Reduce::Sum, wmsg, &format!("l{l}.num"));
        let rden = g.unary(ElwOp::Recip, den, &format!("l{l}.rden"));
        let a = g.row_scale(num, rden, &format!("l{l}.alpha_agg"));
        h = g.unary(ElwOp::Relu, a, &format!("l{l}.relu"));
    }
    g.set_output(h);
    g
}

/// GraphSAGE with max-pool aggregator (Hamilton et al., Tbl I row 3):
/// `a_i = max_j(W_pool h_j + b)`, `h' = ReLU(W (h_i || a_i))`.
pub fn sage(layers: u32, in_dim: u32, hid_dim: u32, out_dim: u32) -> IrGraph {
    let mut g = IrGraph::new("sage");
    let mut h = g.input(in_dim);
    for (l, (di, d_o)) in layer_dims(layers, in_dim, hid_dim, out_dim).into_iter().enumerate() {
        let wp = g.weight(di, di, seed("sage", l as u32, 0), &format!("l{l}.W_pool"));
        let b = g.bias(di, seed("sage", l as u32, 1), &format!("l{l}.b"));
        let t = g.dmm(h, wp, &format!("l{l}.pool_proj"));
        let tb = g.binary(ElwOp::Add, t, b, &format!("l{l}.pool_biased"));
        let e = g.scatter_src(tb, &format!("l{l}.msg"));
        let a = g.gather(Reduce::Max, e, &format!("l{l}.agg"));
        let cat = g.concat(h, a, &format!("l{l}.cat"));
        let w = g.weight(2 * di, d_o, seed("sage", l as u32, 2), &format!("l{l}.W"));
        let z = g.dmm(cat, w, &format!("l{l}.z"));
        h = g.unary(ElwOp::Relu, z, &format!("l{l}.relu"));
    }
    g.set_output(h);
    g
}

/// GraphSAGE with *mean* aggregator — not in Tbl I but part of the
/// original SAGE family; exercises the `Mean` reduction through the whole
/// stack (compiler GSCTR fusion, executor count-normalisation, oracles).
pub fn sage_mean(layers: u32, in_dim: u32, hid_dim: u32, out_dim: u32) -> IrGraph {
    let mut g = IrGraph::new("sage_mean");
    let mut h = g.input(in_dim);
    for (l, (di, d_o)) in layer_dims(layers, in_dim, hid_dim, out_dim).into_iter().enumerate() {
        let e = g.scatter_src(h, &format!("l{l}.msg"));
        let a = g.gather(Reduce::Mean, e, &format!("l{l}.agg"));
        let cat = g.concat(h, a, &format!("l{l}.cat"));
        let w = g.weight(2 * di, d_o, seed("sage", l as u32, 7), &format!("l{l}.W"));
        let z = g.dmm(cat, w, &format!("l{l}.z"));
        h = g.unary(ElwOp::Relu, z, &format!("l{l}.relu"));
    }
    g.set_output(h);
    g
}

/// GG-NN (Li et al., Tbl I row 4): `a_i = Σ_j (W h_j + b)`,
/// `h' = GRU(h_i, a_i)`. The GRU keeps the hidden size constant, so every
/// layer of GGNN is `dim → dim`.
pub fn ggnn(layers: u32, dim: u32) -> IrGraph {
    let mut g = IrGraph::new("ggnn");
    let mut h = g.input(dim);
    for l in 0..layers {
        let w = g.weight(dim, dim, seed("ggnn", l, 0), &format!("l{l}.W"));
        let b = g.bias(dim, seed("ggnn", l, 1), &format!("l{l}.b"));
        let t = g.dmm(h, w, &format!("l{l}.proj"));
        let tb = g.binary(ElwOp::Add, t, b, &format!("l{l}.proj_b"));
        let e = g.scatter_src(tb, &format!("l{l}.msg"));
        let a = g.gather(Reduce::Sum, e, &format!("l{l}.agg"));
        // GRU cell: z = σ(W_z a + U_z h); r = σ(W_r a + U_r h);
        // h̃ = tanh(W_h a + U_h (r ⊙ h)); h' = (1-z) ⊙ h + z ⊙ h̃.
        let wz = g.weight(dim, dim, seed("ggnn", l, 2), &format!("l{l}.W_z"));
        let uz = g.weight(dim, dim, seed("ggnn", l, 3), &format!("l{l}.U_z"));
        let wr = g.weight(dim, dim, seed("ggnn", l, 4), &format!("l{l}.W_r"));
        let ur = g.weight(dim, dim, seed("ggnn", l, 5), &format!("l{l}.U_r"));
        let wh = g.weight(dim, dim, seed("ggnn", l, 6), &format!("l{l}.W_h"));
        let uh = g.weight(dim, dim, seed("ggnn", l, 7), &format!("l{l}.U_h"));
        let za = g.dmm(a, wz, &format!("l{l}.z_a"));
        let zh = g.dmm(h, uz, &format!("l{l}.z_h"));
        let zsum = g.binary(ElwOp::Add, za, zh, &format!("l{l}.z_sum"));
        let z = g.unary(ElwOp::Sigmoid, zsum, &format!("l{l}.z"));
        let ra = g.dmm(a, wr, &format!("l{l}.r_a"));
        let rh = g.dmm(h, ur, &format!("l{l}.r_h"));
        let rsum = g.binary(ElwOp::Add, ra, rh, &format!("l{l}.r_sum"));
        let r = g.unary(ElwOp::Sigmoid, rsum, &format!("l{l}.r"));
        let rgate = g.binary(ElwOp::Mul, r, h, &format!("l{l}.r_gate"));
        let ha = g.dmm(a, wh, &format!("l{l}.h_a"));
        let hr = g.dmm(rgate, uh, &format!("l{l}.h_r"));
        let hsum = g.binary(ElwOp::Add, ha, hr, &format!("l{l}.h_sum"));
        let hcand = g.unary(ElwOp::Tanh, hsum, &format!("l{l}.h_cand"));
        // (1 - z)
        let negz = g.unary(ElwOp::MulScalar((-1.0f32).to_bits()), z, &format!("l{l}.neg_z"));
        let omz = g.unary(ElwOp::AddScalar(1.0f32.to_bits()), negz, &format!("l{l}.one_m_z"));
        let keep = g.binary(ElwOp::Mul, omz, h, &format!("l{l}.keep"));
        let update = g.binary(ElwOp::Mul, z, hcand, &format!("l{l}.update"));
        h = g.binary(ElwOp::Add, keep, update, &format!("l{l}.h_next"));
    }
    g.set_output(h);
    g
}

/// Helper used throughout benches and examples.
pub fn build_node(model: Model, layers: u32, dim: u32) -> IrGraph {
    model.build(layers, dim, dim, dim)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_validate() {
        for m in Model::ALL {
            let g = m.build_paper();
            g.validate().unwrap_or_else(|e| panic!("{}: {e}", m.name()));
        }
    }

    #[test]
    fn group_counts() {
        // GCN/SAGE/GGNN: one gather round per layer; GAT: two (softmax).
        assert_eq!(Model::Gcn.build_paper().num_groups(), 2);
        assert_eq!(Model::Sage.build_paper().num_groups(), 2);
        assert_eq!(Model::Ggnn.build_paper().num_groups(), 2);
        assert_eq!(Model::Gat.build_paper().num_groups(), 4);
    }

    #[test]
    fn operator_counts_reflect_model_complexity() {
        // The paper attributes higher speedups on GAT/SAGE/GGNN to their
        // larger operator counts (§VII-A). Verify the census ordering.
        let census = |m: Model| {
            let c = m.build_paper().op_census();
            c.get("dmm").copied().unwrap_or(0)
                + c.get("elw").copied().unwrap_or(0)
                + c.get("gtr").copied().unwrap_or(0)
        };
        let gcn = census(Model::Gcn);
        for m in [Model::Gat, Model::Sage, Model::Ggnn] {
            assert!(
                census(m) > gcn,
                "{} should have more ops than GCN",
                m.name()
            );
        }
    }

    #[test]
    fn ggnn_has_many_dmms() {
        let c = Model::Ggnn.build_paper().op_census();
        assert_eq!(c["dmm"], 2 * 7); // 7 matmuls per layer
    }

    #[test]
    fn parse_roundtrip() {
        for m in Model::ALL {
            assert_eq!(Model::parse(m.name()), Some(m));
        }
        assert_eq!(Model::parse("nope"), None);
    }

    #[test]
    fn small_dims_build() {
        for m in Model::ALL {
            let g = m.build(2, 8, 8, 8);
            assert!(g.validate().is_ok());
            assert_eq!(g.nodes[g.output.unwrap()].cols, 8);
        }
    }
}
