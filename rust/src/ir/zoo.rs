//! The open model zoo: a registry of [`ModelSpec`]s that replaces the
//! closed four-variant `Model` enum as the currency of the pipeline.
//!
//! The built-in entries are the paper's Tbl I models (plus `sage_mean`)
//! *expressed as `.gnn` specs* — the legacy Rust builders in
//! [`models`](super::models) stay as ground truth, and the tests below
//! prove each spec builds a node-for-node identical [`IrGraph`]. Anything
//! else enters through [`ModelZoo::resolve`]: a user-supplied `.gnn` file
//! runs the whole compile → partition → simulate → exec stack with no
//! Rust changes.

use std::path::Path;
use std::sync::{Arc, OnceLock};

use super::spec::ModelSpec;

/// GCN (Kipf & Welling, Tbl I row 1) — seeds mirror `models::seed("gcn", ...)`.
const GCN: &str = "\
# GCN: a_i = sum_{j in N(i)} h_j d_j^-1/2 ; h' = ReLU(d_i^-1/2 * W a_i)
model gcn
deg = degree
deg_rsqrt = unary rsqrt deg
h = input IN
layer {
  h_norm = row_scale h deg_rsqrt
  msg = scatter_src h_norm
  agg = gather sum msg
  W = weight DI DO seed 1000000+1000*L
  z = dmm agg W
  z_norm = row_scale z deg_rsqrt
  h = unary relu z_norm as relu
}
output h
";

/// GAT (Veličković et al., Tbl I row 2), single head, stable edge softmax.
const GAT: &str = "\
# GAT: e_ij = LeakyReLU(a_l.Wh_i + a_r.Wh_j); alpha = softmax_j(e_ij);
# a_i = sum_j alpha_ij W h_j ; h' = ReLU(a_i). Two gather rounds per layer.
model gat
h = input IN
layer {
  W = weight DI DO seed 2000000+1000*L
  a_l = weight DO 1 seed 2000001+1000*L
  a_r = weight DO 1 seed 2000002+1000*L
  hw = dmm h W
  att_dst = dmm hw a_l
  att_src = dmm hw a_r
  s_dst = scatter_dst att_dst
  s_src = scatter_src att_src
  s_raw = binary add s_dst s_src
  s = unary leaky_relu s_raw
  s_max = gather max s
  s_max_e = scatter_dst s_max
  s_cent = binary sub s s_max_e
  s_exp = unary exp s_cent
  den = gather sum s_exp
  msg = scatter_src hw
  wmsg = row_scale msg s_exp
  num = gather sum wmsg
  rden = unary recip den
  alpha_agg = row_scale num rden
  h = unary relu alpha_agg as relu
}
output h
";

/// GraphSAGE, max-pool aggregator (Hamilton et al., Tbl I row 3).
const SAGE: &str = "\
# SAGE-pool: a_i = max_j(W_pool h_j + b); h' = ReLU(W (h_i || a_i))
model sage
h = input IN
layer {
  W_pool = weight DI DI seed 3000000+1000*L
  b = bias DI seed 3000001+1000*L
  pool_proj = dmm h W_pool
  pool_biased = binary add pool_proj b
  msg = scatter_src pool_biased
  agg = gather max msg
  cat = concat h agg
  W = weight 2*DI DO seed 3000002+1000*L
  z = dmm cat W
  h = unary relu z as relu
}
output h
";

/// GraphSAGE, *mean* aggregator — exercises `Reduce::Mean` end to end.
const SAGE_MEAN: &str = "\
# SAGE-mean: a_i = mean_j h_j ; h' = ReLU(W (h_i || a_i))
model sage_mean
h = input IN
layer {
  msg = scatter_src h
  agg = gather mean msg
  cat = concat h agg
  W = weight 2*DI DO seed 3000007+1000*L
  z = dmm cat W
  h = unary relu z as relu
}
output h
";

/// GG-NN (Li et al., Tbl I row 4): Σ(Wh+b) aggregation into a GRU cell.
/// The GRU keeps the hidden size constant — instantiate with uniform dims.
const GGNN: &str = "\
# GGNN: a_i = sum_j (W h_j + b); h' = GRU(h_i, a_i)
model ggnn
h = input IN
layer {
  W = weight DI DI seed 4000000+1000*L
  b = bias DI seed 4000001+1000*L
  proj = dmm h W
  proj_b = binary add proj b
  msg = scatter_src proj_b
  agg = gather sum msg
  W_z = weight DI DI seed 4000002+1000*L
  U_z = weight DI DI seed 4000003+1000*L
  W_r = weight DI DI seed 4000004+1000*L
  U_r = weight DI DI seed 4000005+1000*L
  W_h = weight DI DI seed 4000006+1000*L
  U_h = weight DI DI seed 4000007+1000*L
  z_a = dmm agg W_z
  z_h = dmm h U_z
  z_sum = binary add z_a z_h
  z = unary sigmoid z_sum
  r_a = dmm agg W_r
  r_h = dmm h U_r
  r_sum = binary add r_a r_h
  r = unary sigmoid r_sum
  r_gate = binary mul r h
  h_a = dmm agg W_h
  h_r = dmm r_gate U_h
  h_sum = binary add h_a h_r
  h_cand = unary tanh h_sum
  neg_z = unary mul_scalar -1 z
  one_m_z = unary add_scalar 1 neg_z
  keep = binary mul one_m_z h
  update = binary mul z h_cand
  h = binary add keep update as h_next
}
output h
";

const BUILTINS: [(&str, &str); 5] = [
    ("gcn", GCN),
    ("gat", GAT),
    ("sage", SAGE),
    ("sage_mean", SAGE_MEAN),
    ("ggnn", GGNN),
];

/// The four Tbl I models the figure harness sweeps, paper order.
const PAPER_FOUR: [&str; 4] = ["gcn", "gat", "sage", "ggnn"];

/// Historical aliases (kept from the old `Model::parse`).
fn canonical(name: &str) -> String {
    let n = name.to_ascii_lowercase().replace('-', "_");
    match n.as_str() {
        "graphsage" | "sage_pool" => "sage".into(),
        "gg_nn" => "ggnn".into(),
        _ => n,
    }
}

/// An ordered, name-keyed registry of model specs.
pub struct ModelZoo {
    entries: Vec<Arc<ModelSpec>>,
}

impl Default for ModelZoo {
    fn default() -> Self {
        Self::empty()
    }
}

impl ModelZoo {
    pub fn empty() -> ModelZoo {
        ModelZoo {
            entries: Vec::new(),
        }
    }

    /// The built-in zoo (parsed once per process).
    pub fn builtin() -> &'static ModelZoo {
        static ZOO: OnceLock<ModelZoo> = OnceLock::new();
        ZOO.get_or_init(|| {
            let mut z = ModelZoo::empty();
            for (name, text) in BUILTINS {
                z.register(
                    ModelSpec::parse(name, text)
                        .unwrap_or_else(|e| panic!("builtin spec '{name}': {e}")),
                );
            }
            z
        })
    }

    /// Add (or replace) an entry. Replacement matches canonically — the
    /// same rule [`get`](Self::get) uses — so registering `GraphSAGE`
    /// replaces the `sage` slot rather than leaving a shadowed duplicate.
    pub fn register(&mut self, spec: ModelSpec) {
        let canon = canonical(spec.name());
        let spec = Arc::new(spec);
        match self
            .entries
            .iter_mut()
            .find(|s| canonical(s.name()) == canon)
        {
            Some(slot) => *slot = spec,
            None => self.entries.push(spec),
        }
    }

    pub fn entries(&self) -> &[Arc<ModelSpec>] {
        &self.entries
    }

    /// Registered names, registration order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|s| s.name()).collect()
    }

    /// Case-insensitive, alias-aware lookup. Stored names are
    /// canonicalized for comparison too, so a registered `MyGIN` is
    /// reachable as `mygin`/`MyGIN`/`my-gin`.
    pub fn get(&self, name: &str) -> Option<Arc<ModelSpec>> {
        let canon = canonical(name);
        self.entries
            .iter()
            .find(|s| canonical(s.name()) == canon)
            .cloned()
    }

    /// The four Tbl I models (the 4×5 figure sweep), paper order.
    pub fn paper_models(&self) -> Vec<Arc<ModelSpec>> {
        PAPER_FOUR.into_iter().filter_map(|n| self.get(n)).collect()
    }

    /// Resolve a CLI model argument: a zoo name, or a path to a `.gnn`
    /// spec file. The error enumerates the zoo dynamically.
    pub fn resolve(&self, arg: &str) -> Result<Arc<ModelSpec>, String> {
        if let Some(s) = self.get(arg) {
            return Ok(s);
        }
        if arg.ends_with(".gnn") || arg.contains('/') {
            return ModelSpec::from_file(Path::new(arg))
                .map(Arc::new)
                .map_err(|e| e.to_string());
        }
        Err(format!(
            "unknown model '{arg}' (available: {}; or pass a .gnn spec file via --model-file)",
            self.names().join("|").to_uppercase()
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::models::{self, Model};
    use crate::ir::spec::ModelDims;

    /// The tentpole proof: every built-in spec builds the *same graph* —
    /// node for node (op, inputs, location, width, debug name) — as the
    /// legacy Rust builder it replaces, at the paper shape and at an
    /// asymmetric small shape.
    #[test]
    fn builtin_specs_match_legacy_builders() {
        let zoo = ModelZoo::builtin();
        for d in [ModelDims::paper(), ModelDims::new(3, 8, 16, 4)] {
            let build = |n: &str| zoo.get(n).unwrap().build(d).unwrap();
            assert_eq!(build("gcn"), models::gcn(d.layers, d.in_dim, d.hid_dim, d.out_dim));
            assert_eq!(build("gat"), models::gat(d.layers, d.in_dim, d.hid_dim, d.out_dim));
            assert_eq!(build("sage"), models::sage(d.layers, d.in_dim, d.hid_dim, d.out_dim));
            assert_eq!(
                build("sage_mean"),
                models::sage_mean(d.layers, d.in_dim, d.hid_dim, d.out_dim)
            );
        }
        // GGNN holds the hidden size constant: uniform shapes only.
        for dim in [8u32, 128] {
            assert_eq!(
                zoo.get("ggnn").unwrap().build(ModelDims::uniform(2, dim)).unwrap(),
                models::ggnn(2, dim)
            );
        }
    }

    #[test]
    fn default_dims_match_build_paper() {
        let zoo = ModelZoo::builtin();
        for m in Model::ALL {
            let spec = zoo.get(m.name()).expect(m.name());
            assert_eq!(spec.graph(), m.build_paper(), "{}", m.name());
        }
    }

    #[test]
    fn zoo_lists_five_models_in_order() {
        let zoo = ModelZoo::builtin();
        assert_eq!(zoo.names(), ["gcn", "gat", "sage", "sage_mean", "ggnn"]);
        assert_eq!(
            zoo.paper_models()
                .iter()
                .map(|s| s.name().to_string())
                .collect::<Vec<_>>(),
            ["gcn", "gat", "sage", "ggnn"]
        );
    }

    #[test]
    fn lookup_is_case_insensitive_and_alias_aware() {
        let zoo = ModelZoo::builtin();
        for (alias, want) in [
            ("GCN", "gcn"),
            ("GraphSAGE", "sage"),
            ("SAGE-POOL", "sage"),
            ("GG-NN", "ggnn"),
            ("Sage_Mean", "sage_mean"),
        ] {
            assert_eq!(zoo.get(alias).expect(alias).name(), want);
        }
        assert!(zoo.get("nope").is_none());
    }

    #[test]
    fn resolve_error_enumerates_zoo() {
        let e = ModelZoo::builtin().resolve("nope").unwrap_err();
        for n in ["GCN", "GAT", "SAGE", "SAGE_MEAN", "GGNN"] {
            assert!(e.contains(n), "{e}");
        }
        assert!(e.contains(".gnn"), "{e}");
        assert!(ModelZoo::builtin().resolve("/nonexistent/x.gnn").is_err());
    }

    #[test]
    fn register_replaces_by_name() {
        let mut zoo = ModelZoo::empty();
        let a = ModelSpec::parse("m", "h = input IN\noutput h\n").unwrap();
        let b = ModelSpec::parse("m", "h = input IN\ny = unary relu h\noutput y\n").unwrap();
        zoo.register(a);
        zoo.register(b.clone());
        assert_eq!(zoo.entries().len(), 1);
        assert_eq!(zoo.get("m").unwrap().fingerprint(), b.fingerprint());
    }

    #[test]
    fn register_and_get_are_canonical() {
        // A mixed-case registered name is reachable through any casing...
        let mut zoo = ModelZoo::empty();
        let g = ModelSpec::parse("MyGIN", "h = input IN\noutput h\n").unwrap();
        zoo.register(g.clone());
        assert_eq!(zoo.get("mygin").unwrap().fingerprint(), g.fingerprint());
        assert_eq!(zoo.get("MyGIN").unwrap().fingerprint(), g.fingerprint());
        // ...and registering under an alias replaces the aliased slot
        // instead of leaving a shadowed duplicate.
        let mut zoo = ModelZoo::empty();
        for (name, text) in BUILTINS {
            zoo.register(ModelSpec::parse(name, text).unwrap());
        }
        let mine = ModelSpec::parse("GraphSAGE", "h = input IN\noutput h\n").unwrap();
        zoo.register(mine.clone());
        assert_eq!(zoo.entries().len(), BUILTINS.len());
        assert_eq!(zoo.get("sage").unwrap().fingerprint(), mine.fingerprint());
    }
}
