//! `.gnn` model specs — the declarative, *open* front door of the model
//! zoo. A spec is a small text program, one IR operation per line,
//! mirroring the [`IrGraph`](super::IrGraph) builder verbs; parsing it
//! yields a validated [`ModelSpec`] that builds the unified computational
//! graph at any `(layers, in, hid, out)` shape and carries a stable
//! content fingerprint (the `ProgramCache` key). No Rust changes are
//! needed to run a new GNN through compile → partition → simulate → exec.
//!
//! # Grammar
//!
//! Line-oriented; `#` starts a comment; blank lines are ignored.
//!
//! ```text
//! model NAME                      # optional; defaults to the file stem
//! dims LAYERS IN HID OUT          # optional default shape (else 2 128 128 128)
//!
//! h   = input IN                  # per-vertex feature matrix [N, IN]
//! deg = degree                    # in-degree column [N, 1]
//! W   = weight ROWS COLS seed EXPR   # parameter [ROWS, COLS] (seed optional)
//! b   = bias COLS seed EXPR          # bias row [1, COLS]    (seed optional)
//! z   = dmm X W                   # dense matmul with a weight
//! y   = unary OP X                # OP: relu leaky_relu exp sigmoid tanh
//!                                 #     rsqrt recip copy
//! y   = unary add_scalar C X      # x + C (C a float literal); mul_scalar: x * C
//! y   = binary OP A B             # OP: add sub mul div max (B may be a bias)
//! y   = row_scale X S             # X[r, :] * S[r, 0]
//! y   = concat A B                # feature concatenation
//! e   = scatter_src X             # GTR vertex→edge (source endpoint)
//! e   = scatter_dst X             # GTR vertex→edge (destination endpoint)
//! a   = gather REDUCE E           # GTR edge→vertex; REDUCE: sum max mean
//! output X                        # marks the per-vertex model output
//!
//! layer {                         # repeat the body for L in 0..LAYERS
//!   ...
//! }
//! layer A..B {                    # or an explicit half-open range
//!   ...
//! }
//! ```
//!
//! Bindings may be freely re-assigned — a `layer` body that rebinds `h`
//! expresses the usual layer recurrence. Node debug names are the binding
//! identifier, prefixed `l{L}.` inside a layer block; append `as NAME` to
//! a statement to override the debug suffix without renaming the binding
//! (`h = unary relu z as relu` names the node `l0.relu` but keeps `h`
//! referring to it).
//!
//! Dimension, seed and range arguments are single-token integer
//! expressions over `+`, `-` and `*` (no spaces, no parentheses or unary
//! minus — `A-B+C` reads `A + (-B) + C`): literals and the symbols
//! `IN`, `HID`, `OUT`, `LAYERS`, plus — inside a layer block — `L` (the
//! layer index) and `DI`/`DO` (the layer's input/output width, following
//! the stacked-layer convention: `DI = IN if L == 0 else HID`,
//! `DO = OUT if L == LAYERS-1 else HID`). A weight without an explicit
//! `seed` gets a deterministic auto seed, distinct for every weight/bias
//! statement execution across the whole build.
//!
//! Worked examples ship in `examples/models/*.gnn` (a GIN-style sum-MLP
//! and a 3-layer GCN variant); the built-in zoo entries in
//! [`zoo`](super::zoo) are the Tbl I models expressed in this grammar and
//! proven node-for-node identical to the legacy Rust builders.

use std::collections::HashMap;
use std::path::Path;

use super::{IrError, IrGraph, NodeId};
use crate::isa::{ElwOp, Reduce};

/// The shape a spec is instantiated at: layer count plus input / hidden /
/// output feature widths (the paper's models stack `layers` identical
/// layers, §VI).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ModelDims {
    pub layers: u32,
    pub in_dim: u32,
    pub hid_dim: u32,
    pub out_dim: u32,
}

impl ModelDims {
    pub const fn new(layers: u32, in_dim: u32, hid_dim: u32, out_dim: u32) -> Self {
        ModelDims {
            layers,
            in_dim,
            hid_dim,
            out_dim,
        }
    }

    /// Paper configuration: 2 layers, 128-dim everywhere (§VI).
    pub const fn paper() -> Self {
        Self::new(2, 128, 128, 128)
    }

    /// `layers` stacked layers with one width throughout.
    pub const fn uniform(layers: u32, dim: u32) -> Self {
        Self::new(layers, dim, dim, dim)
    }

    /// Per-layer (input, output) widths — `DI`/`DO` in spec expressions,
    /// mirroring `models::layer_dims`.
    pub fn layer_io(&self, l: u32) -> (u32, u32) {
        let di = if l == 0 { self.in_dim } else { self.hid_dim };
        let d_o = if l + 1 == self.layers {
            self.out_dim
        } else {
            self.hid_dim
        };
        (di, d_o)
    }
}

impl std::fmt::Display for ModelDims {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}x[{}->{}->{}]",
            self.layers, self.in_dim, self.hid_dim, self.out_dim
        )
    }
}

// ----- expressions -----------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Var {
    In,
    Hid,
    Out,
    Layers,
    L,
    Di,
    Do,
}

#[derive(Clone, Debug, PartialEq)]
enum Factor {
    Num(i64),
    Var(Var),
}

/// A `+`/`-`/`*` integer expression, stored as a signed sum of products
/// (a subtracted term carries a literal `-1` factor).
#[derive(Clone, Debug, PartialEq)]
struct Expr {
    terms: Vec<Vec<Factor>>,
    src: String,
}

fn parse_expr(tok: &str, line: u32) -> Result<Expr, IrError> {
    let mut terms = Vec::new();
    // Split into sign-carrying `*`-product terms. There is no unary minus
    // or parenthesis: `A-B+C` means `A + (-B) + C`, and a leading /
    // doubled sign falls out as an empty operand below.
    let mut rest = tok;
    let mut negated = false;
    loop {
        let cut = rest.find(|c| c == '+' || c == '-');
        let term = &rest[..cut.unwrap_or(rest.len())];
        let mut factors = Vec::new();
        if negated {
            factors.push(Factor::Num(-1));
        }
        for fct in term.split('*') {
            if fct.is_empty() {
                return Err(
                    IrError::new(format!("malformed expression '{tok}' (empty operand)")).at(line),
                );
            }
            let f = if fct.chars().all(|c| c.is_ascii_digit()) {
                Factor::Num(fct.parse().map_err(|_| {
                    IrError::new(format!("integer '{fct}' out of range in '{tok}'")).at(line)
                })?)
            } else {
                Factor::Var(match fct.to_ascii_uppercase().as_str() {
                    "IN" => Var::In,
                    "HID" => Var::Hid,
                    "OUT" => Var::Out,
                    "LAYERS" => Var::Layers,
                    "L" => Var::L,
                    "DI" => Var::Di,
                    "DO" => Var::Do,
                    _ => {
                        return Err(IrError::new(format!(
                            "unknown symbol '{fct}' in expression '{tok}' \
                             (expected an integer or IN|HID|OUT|LAYERS|L|DI|DO)"
                        ))
                        .at(line))
                    }
                })
            };
            factors.push(f);
        }
        terms.push(factors);
        match cut {
            None => break,
            Some(i) => {
                negated = rest.as_bytes()[i] == b'-';
                rest = &rest[i + 1..];
            }
        }
    }
    Ok(Expr {
        terms,
        src: tok.to_string(),
    })
}

/// Evaluation context: the instantiation dims plus the current layer
/// index (None outside `layer` blocks).
struct EvalCtx {
    dims: ModelDims,
    layer: Option<u32>,
}

impl EvalCtx {
    fn var(&self, v: Var, src: &str, line: u32) -> Result<i64, IrError> {
        Ok(match v {
            Var::In => self.dims.in_dim as i64,
            Var::Hid => self.dims.hid_dim as i64,
            Var::Out => self.dims.out_dim as i64,
            Var::Layers => self.dims.layers as i64,
            Var::L | Var::Di | Var::Do => {
                let Some(l) = self.layer else {
                    return Err(IrError::new(format!(
                        "L/DI/DO in '{src}' are only defined inside a layer block"
                    ))
                    .at(line));
                };
                match v {
                    Var::L => l as i64,
                    Var::Di => self.dims.layer_io(l).0 as i64,
                    _ => self.dims.layer_io(l).1 as i64,
                }
            }
        })
    }

    fn eval(&self, e: &Expr, line: u32) -> Result<i64, IrError> {
        let mut sum = 0i64;
        for term in &e.terms {
            let mut p = 1i64;
            for f in term {
                p = p.saturating_mul(match f {
                    Factor::Num(n) => *n,
                    Factor::Var(v) => self.var(*v, &e.src, line)?,
                });
            }
            sum = sum.saturating_add(p);
        }
        Ok(sum)
    }

    fn eval_dim(&self, e: &Expr, line: u32) -> Result<u32, IrError> {
        let v = self.eval(e, line)?;
        if v < 1 || v > u32::MAX as i64 {
            return Err(
                IrError::new(format!("dimension '{}' evaluates to {v} (need >= 1)", e.src))
                    .at(line),
            );
        }
        Ok(v as u32)
    }
}

// ----- statements ------------------------------------------------------------

#[derive(Clone, Debug)]
enum OpStmt {
    Input { dim: Expr },
    Degree,
    Weight { rows: Expr, cols: Expr, seed: Option<Expr> },
    Bias { cols: Expr, seed: Option<Expr> },
    Dmm { x: String, w: String },
    Unary { op: ElwOp, x: String },
    Binary { op: ElwOp, a: String, b: String },
    RowScale { x: String, s: String },
    Concat { a: String, b: String },
    ScatterSrc { x: String },
    ScatterDst { x: String },
    Gather { reduce: Reduce, e: String },
}

#[derive(Clone, Debug)]
enum Stmt {
    Assign {
        line: u32,
        binding: String,
        alias: Option<String>,
        op: OpStmt,
    },
    Output {
        line: u32,
        arg: String,
    },
    Layer {
        line: u32,
        range: Option<(Expr, Expr)>,
        body: Vec<Stmt>,
    },
}

fn is_ident(s: &str) -> bool {
    let mut chars = s.chars();
    let ok_first = chars
        .next()
        .map(|c| c.is_ascii_alphabetic() || c == '_')
        .unwrap_or(false);
    // `as` is the alias keyword; reserving it keeps operand lists
    // unambiguous.
    ok_first && s != "as" && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn parse_unary_op(s: &str, line: u32) -> Result<ElwOp, IrError> {
    Ok(match s {
        "relu" => ElwOp::Relu,
        "leaky_relu" => ElwOp::LeakyRelu,
        "exp" => ElwOp::Exp,
        "sigmoid" => ElwOp::Sigmoid,
        "tanh" => ElwOp::Tanh,
        "rsqrt" => ElwOp::Rsqrt,
        "recip" => ElwOp::Recip,
        "copy" => ElwOp::Copy,
        _ => {
            return Err(IrError::new(format!(
                "unknown unary op '{s}' (relu|leaky_relu|exp|sigmoid|tanh|rsqrt|\
                 recip|copy|add_scalar C|mul_scalar C)"
            ))
            .at(line))
        }
    })
}

fn parse_binary_op(s: &str, line: u32) -> Result<ElwOp, IrError> {
    Ok(match s {
        "add" => ElwOp::Add,
        "sub" => ElwOp::Sub,
        "mul" => ElwOp::Mul,
        "div" => ElwOp::Div,
        "max" => ElwOp::Max,
        _ => {
            return Err(
                IrError::new(format!("unknown binary op '{s}' (add|sub|mul|div|max)")).at(line),
            )
        }
    })
}

fn parse_reduce(s: &str, line: u32) -> Result<Reduce, IrError> {
    Ok(match s {
        "sum" => Reduce::Sum,
        "max" => Reduce::Max,
        "mean" => Reduce::Mean,
        _ => return Err(IrError::new(format!("unknown reduce '{s}' (sum|max|mean)")).at(line)),
    })
}

fn parse_rhs(tokens: &[&str], line: u32) -> Result<OpStmt, IrError> {
    let verb = tokens[0];
    let args = &tokens[1..];
    let need = |n: usize, sig: &str| -> Result<(), IrError> {
        if args.len() == n {
            Ok(())
        } else {
            Err(IrError::new(format!("'{verb}' expects `{sig}`")).at(line))
        }
    };
    Ok(match verb {
        "input" => {
            need(1, "input DIM")?;
            OpStmt::Input {
                dim: parse_expr(args[0], line)?,
            }
        }
        "degree" => {
            need(0, "degree")?;
            OpStmt::Degree
        }
        "weight" | "bias" => {
            let base = if verb == "weight" { 2 } else { 1 };
            let sig = if verb == "weight" {
                "weight ROWS COLS [seed EXPR]"
            } else {
                "bias COLS [seed EXPR]"
            };
            let with_seed = args.len() == base + 2 && args[base] == "seed";
            if !(args.len() == base || with_seed) {
                return Err(IrError::new(format!("'{verb}' expects `{sig}`")).at(line));
            }
            let seed = if with_seed {
                Some(parse_expr(args[base + 1], line)?)
            } else {
                None
            };
            if verb == "weight" {
                OpStmt::Weight {
                    rows: parse_expr(args[0], line)?,
                    cols: parse_expr(args[1], line)?,
                    seed,
                }
            } else {
                OpStmt::Bias {
                    cols: parse_expr(args[0], line)?,
                    seed,
                }
            }
        }
        "dmm" => {
            need(2, "dmm X W")?;
            OpStmt::Dmm {
                x: args[0].into(),
                w: args[1].into(),
            }
        }
        "unary" => match args.first().copied() {
            Some(s @ ("add_scalar" | "mul_scalar")) => {
                need(3, "unary add_scalar|mul_scalar C X")?;
                let c: f32 = args[1].parse().map_err(|_| {
                    IrError::new(format!("bad scalar '{}' for {s}", args[1])).at(line)
                })?;
                let op = if s == "add_scalar" {
                    ElwOp::AddScalar(c.to_bits())
                } else {
                    ElwOp::MulScalar(c.to_bits())
                };
                OpStmt::Unary {
                    op,
                    x: args[2].into(),
                }
            }
            Some(s) => {
                need(2, "unary OP X")?;
                OpStmt::Unary {
                    op: parse_unary_op(s, line)?,
                    x: args[1].into(),
                }
            }
            None => return Err(IrError::new("'unary' expects `unary OP X`").at(line)),
        },
        "binary" => {
            need(3, "binary OP A B")?;
            OpStmt::Binary {
                op: parse_binary_op(args[0], line)?,
                a: args[1].into(),
                b: args[2].into(),
            }
        }
        "row_scale" => {
            need(2, "row_scale X S")?;
            OpStmt::RowScale {
                x: args[0].into(),
                s: args[1].into(),
            }
        }
        "concat" => {
            need(2, "concat A B")?;
            OpStmt::Concat {
                a: args[0].into(),
                b: args[1].into(),
            }
        }
        "scatter_src" => {
            need(1, "scatter_src X")?;
            OpStmt::ScatterSrc { x: args[0].into() }
        }
        "scatter_dst" => {
            need(1, "scatter_dst X")?;
            OpStmt::ScatterDst { x: args[0].into() }
        }
        "gather" => {
            need(2, "gather sum|max|mean E")?;
            OpStmt::Gather {
                reduce: parse_reduce(args[0], line)?,
                e: args[1].into(),
            }
        }
        _ => {
            return Err(IrError::new(format!(
                "unknown op '{verb}' (input|degree|weight|bias|dmm|unary|binary|\
                 row_scale|concat|scatter_src|scatter_dst|gather)"
            ))
            .at(line))
        }
    })
}

/// Parse the full source into (model name, default dims, statements).
#[allow(clippy::type_complexity)]
fn parse_source(source: &str) -> Result<(Option<String>, Option<ModelDims>, Vec<Stmt>), IrError> {
    let mut name: Option<String> = None;
    let mut dims: Option<ModelDims> = None;
    let mut top: Vec<Stmt> = Vec::new();
    let mut block: Option<(u32, Option<(Expr, Expr)>, Vec<Stmt>)> = None;

    for (i, raw) in source.lines().enumerate() {
        let line = i as u32 + 1;
        let text = raw.split('#').next().unwrap_or("").trim();
        if text.is_empty() {
            continue;
        }
        if text == "}" {
            let (l, range, body) =
                block.take().ok_or_else(|| IrError::new("unmatched '}'").at(line))?;
            top.push(Stmt::Layer {
                line: l,
                range,
                body,
            });
            continue;
        }
        let toks: Vec<&str> = text.split_whitespace().collect();
        match toks[0] {
            "layer" => {
                if block.is_some() {
                    return Err(IrError::new("nested layer blocks are not supported").at(line));
                }
                if toks.last() != Some(&"{") {
                    return Err(
                        IrError::new("layer syntax: `layer [A..B] {` ('{' on the same line)")
                            .at(line),
                    );
                }
                let range = match toks.len() {
                    2 => None,
                    3 => {
                        let (a, b) = toks[1].split_once("..").ok_or_else(|| {
                            IrError::new(format!("bad layer range '{}' (expected A..B)", toks[1]))
                                .at(line)
                        })?;
                        Some((parse_expr(a, line)?, parse_expr(b, line)?))
                    }
                    _ => return Err(IrError::new("layer syntax: `layer [A..B] {`").at(line)),
                };
                block = Some((line, range, Vec::new()));
            }
            "model" => {
                if block.is_some() {
                    return Err(IrError::new("'model' must be at the top level").at(line));
                }
                if toks.len() != 2 || !is_ident(toks[1]) {
                    return Err(IrError::new("model syntax: `model NAME`").at(line));
                }
                if name.is_some() {
                    return Err(IrError::new("duplicate 'model' statement").at(line));
                }
                name = Some(toks[1].to_string());
            }
            "dims" => {
                if block.is_some() {
                    return Err(IrError::new("'dims' must be at the top level").at(line));
                }
                if dims.is_some() {
                    return Err(IrError::new("duplicate 'dims' statement").at(line));
                }
                if toks.len() != 5 {
                    return Err(IrError::new("dims syntax: `dims LAYERS IN HID OUT`").at(line));
                }
                let mut v = [0u32; 4];
                for (slot, tok) in v.iter_mut().zip(&toks[1..]) {
                    *slot = tok.parse().map_err(|_| {
                        IrError::new(format!("bad dims value '{tok}' (positive integer)")).at(line)
                    })?;
                    if *slot == 0 {
                        return Err(IrError::new("dims values must be >= 1").at(line));
                    }
                }
                dims = Some(ModelDims::new(v[0], v[1], v[2], v[3]));
            }
            "output" => {
                if toks.len() != 2 {
                    return Err(IrError::new("output syntax: `output X`").at(line));
                }
                let stmt = Stmt::Output {
                    line,
                    arg: toks[1].to_string(),
                };
                match &mut block {
                    Some((_, _, body)) => body.push(stmt),
                    None => top.push(stmt),
                }
            }
            _ => {
                // Assignment: `binding = verb args... [as NAME]`.
                if toks.len() < 3 || toks[1] != "=" {
                    return Err(IrError::new(format!(
                        "expected `NAME = OP ...`, `output X` or a directive, got '{text}'"
                    ))
                    .at(line));
                }
                let binding = toks[0];
                if !is_ident(binding) {
                    return Err(
                        IrError::new(format!("bad binding name '{binding}'")).at(line)
                    );
                }
                let mut rhs: Vec<&str> = toks[2..].to_vec();
                let alias = if rhs.len() >= 2 && rhs[rhs.len() - 2] == "as" {
                    let a = rhs.pop().unwrap();
                    rhs.pop();
                    if !is_ident(a) {
                        return Err(IrError::new(format!("bad alias name '{a}'")).at(line));
                    }
                    Some(a.to_string())
                } else {
                    None
                };
                if rhs.is_empty() {
                    return Err(IrError::new("assignment needs an op").at(line));
                }
                let op = parse_rhs(&rhs, line)?;
                let stmt = Stmt::Assign {
                    line,
                    binding: binding.to_string(),
                    alias,
                    op,
                };
                match &mut block {
                    Some((_, _, body)) => body.push(stmt),
                    None => top.push(stmt),
                }
            }
        }
    }
    if let Some((line, _, _)) = block {
        return Err(IrError::new("unclosed layer block").at(line));
    }
    Ok((name, dims, top))
}

// ----- interpreter -----------------------------------------------------------

fn lookup(env: &HashMap<String, NodeId>, s: &str, line: u32) -> Result<NodeId, IrError> {
    env.get(s)
        .copied()
        .ok_or_else(|| IrError::new(format!("unknown value '{s}' (not defined above)")).at(line))
}

/// Resolve an optional seed expression; weights/biases without one get a
/// deterministic auto seed from `which`, a build-global counter of
/// weight/bias statement *executions* (never reset, so top-level
/// statements, repeated layer iterations and sibling `layer` blocks can
/// never collide).
fn seed_value(
    seed: &Option<Expr>,
    ctx: &EvalCtx,
    which: &mut u32,
    line: u32,
) -> Result<u64, IrError> {
    let v = match seed {
        Some(e) => {
            let v = ctx.eval(e, line)?;
            if v < 0 {
                return Err(
                    IrError::new(format!("seed '{}' evaluates to {v} (need >= 0)", e.src)).at(line),
                );
            }
            v as u64
        }
        None => 9_000_000 + *which as u64,
    };
    *which += 1;
    Ok(v)
}

fn exec_op(
    op: &OpStmt,
    g: &mut IrGraph,
    env: &HashMap<String, NodeId>,
    ctx: &EvalCtx,
    which: &mut u32,
    name: &str,
    line: u32,
) -> Result<NodeId, IrError> {
    Ok(match op {
        OpStmt::Input { dim } => g.input(ctx.eval_dim(dim, line)?),
        OpStmt::Degree => g.degree(),
        OpStmt::Weight { rows, cols, seed } => {
            let r = ctx.eval_dim(rows, line)?;
            let c = ctx.eval_dim(cols, line)?;
            let s = seed_value(seed, ctx, which, line)?;
            g.weight(r, c, s, name)
        }
        OpStmt::Bias { cols, seed } => {
            let c = ctx.eval_dim(cols, line)?;
            let s = seed_value(seed, ctx, which, line)?;
            g.bias(c, s, name)
        }
        OpStmt::Dmm { x, w } => {
            let (x, w) = (lookup(env, x, line)?, lookup(env, w, line)?);
            g.try_dmm(x, w, name).map_err(|e| e.at(line))?
        }
        OpStmt::Unary { op, x } => {
            let x = lookup(env, x, line)?;
            g.try_unary(*op, x, name).map_err(|e| e.at(line))?
        }
        OpStmt::Binary { op, a, b } => {
            let (a, b) = (lookup(env, a, line)?, lookup(env, b, line)?);
            g.try_binary(*op, a, b, name).map_err(|e| e.at(line))?
        }
        OpStmt::RowScale { x, s } => {
            let (x, s) = (lookup(env, x, line)?, lookup(env, s, line)?);
            g.try_row_scale(x, s, name).map_err(|e| e.at(line))?
        }
        OpStmt::Concat { a, b } => {
            let (a, b) = (lookup(env, a, line)?, lookup(env, b, line)?);
            g.try_concat(a, b, name).map_err(|e| e.at(line))?
        }
        OpStmt::ScatterSrc { x } => {
            let x = lookup(env, x, line)?;
            g.try_scatter_src(x, name).map_err(|e| e.at(line))?
        }
        OpStmt::ScatterDst { x } => {
            let x = lookup(env, x, line)?;
            g.try_scatter_dst(x, name).map_err(|e| e.at(line))?
        }
        OpStmt::Gather { reduce, e } => {
            let e_id = lookup(env, e, line)?;
            g.try_gather(*reduce, e_id, name).map_err(|e| e.at(line))?
        }
    })
}

fn exec_block(
    stmts: &[Stmt],
    g: &mut IrGraph,
    env: &mut HashMap<String, NodeId>,
    ctx: &mut EvalCtx,
    which: &mut u32,
) -> Result<(), IrError> {
    for stmt in stmts {
        match stmt {
            Stmt::Layer { line, range, body } => {
                let (a, b) = match range {
                    None => (0, ctx.dims.layers as i64),
                    Some((ea, eb)) => (ctx.eval(ea, *line)?, ctx.eval(eb, *line)?),
                };
                if a < 0 || b < a {
                    return Err(IrError::new(format!("bad layer range {a}..{b}")).at(*line));
                }
                for l in a..b {
                    ctx.layer = Some(l as u32);
                    exec_block(body, g, env, ctx, which)?;
                }
                ctx.layer = None;
            }
            Stmt::Output { line, arg } => {
                if g.output.is_some() {
                    return Err(IrError::new("duplicate 'output' statement").at(*line));
                }
                let id = lookup(env, arg, *line)?;
                g.try_set_output(id).map_err(|e| e.at(*line))?;
            }
            Stmt::Assign {
                line,
                binding,
                alias,
                op,
            } => {
                let suffix = alias.as_deref().unwrap_or(binding);
                let full = match ctx.layer {
                    Some(l) => format!("l{l}.{suffix}"),
                    None => suffix.to_string(),
                };
                let id = exec_op(op, g, env, ctx, which, &full, *line)?;
                env.insert(binding.clone(), id);
            }
        }
    }
    Ok(())
}

// ----- ModelSpec -------------------------------------------------------------

/// A parsed, validated `.gnn` model definition: the currency of the open
/// model zoo. Carries a name, the canonical source text, default
/// instantiation dims, and a stable content [fingerprint](Self::fingerprint)
/// that the program cache keys on.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    name: String,
    source: String,
    dims: ModelDims,
    stmts: Vec<Stmt>,
}

impl ModelSpec {
    /// Parse `source`, taking the model name from the `model` statement
    /// (falling back to `fallback_name`) and default dims from the `dims`
    /// statement (falling back to the paper shape). Validates by building
    /// once at the default dims.
    pub fn parse(fallback_name: &str, source: &str) -> Result<ModelSpec, IrError> {
        let (name, dims, stmts) = parse_source(source)?;
        let spec = ModelSpec {
            name: name.unwrap_or_else(|| fallback_name.to_string()),
            source: source.to_string(),
            dims: dims.unwrap_or_else(ModelDims::paper),
            stmts,
        };
        if !is_ident(&spec.name) {
            return Err(IrError::new(format!("bad model name '{}'", spec.name)));
        }
        spec.build(spec.dims)?;
        Ok(spec)
    }

    /// Load a spec from a `.gnn` file; the file stem is the fallback name.
    pub fn from_file(path: &Path) -> Result<ModelSpec, IrError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| IrError::new(format!("{}: {e}", path.display())))?;
        let stem = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("model");
        Self::parse(stem, &text).map_err(|e| IrError {
            line: e.line,
            message: format!("{}: {}", path.display(), e.message),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Upper-cased name for tables and CLI reports.
    pub fn display(&self) -> String {
        self.name.to_uppercase()
    }

    pub fn dims(&self) -> ModelDims {
        self.dims
    }

    pub fn source(&self) -> &str {
        &self.source
    }

    /// The same spec with different default dims (re-validated: dims feed
    /// weight shapes, so a shape that breaks the model is rejected here).
    pub fn with_dims(&self, dims: ModelDims) -> Result<ModelSpec, IrError> {
        let mut s = self.clone();
        s.dims = dims;
        s.build(dims)?;
        Ok(s)
    }

    /// Build the IR at an arbitrary shape.
    pub fn build(&self, dims: ModelDims) -> Result<IrGraph, IrError> {
        let mut g = IrGraph::new(&self.name);
        let mut env = HashMap::new();
        let mut ctx = EvalCtx { dims, layer: None };
        let mut which = 0u32;
        exec_block(&self.stmts, &mut g, &mut env, &mut ctx, &mut which)?;
        if g.output.is_none() {
            return Err(IrError::new("spec has no 'output' statement"));
        }
        g.validate().map_err(IrError::new)?;
        Ok(g)
    }

    /// Build at the spec's own default dims. Cannot fail: that exact
    /// build was validated at construction time.
    pub fn graph(&self) -> IrGraph {
        self.build(self.dims)
            .expect("spec validated at construction")
    }

    /// Stable content fingerprint over (name, source, dims) — the program
    /// cache key. Unlike the old enum key, two instantiations that differ
    /// only in layers/dims get distinct fingerprints.
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        eat(self.name.as_bytes());
        eat(&[0]);
        eat(self.source.as_bytes());
        eat(&[0]);
        for v in [
            self.dims.layers,
            self.dims.in_dim,
            self.dims.hid_dim,
            self.dims.out_dim,
        ] {
            eat(&v.to_le_bytes());
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::IrOp;

    const TINY: &str = "\
model tiny
h = input IN
layer {
  e = scatter_src h
  a = gather sum e
  W = weight DI DO seed 100+L
  h = dmm a W as z
}
output h
";

    #[test]
    fn parses_builds_and_repeats_layers() {
        let spec = ModelSpec::parse("fallback", TINY).unwrap();
        assert_eq!(spec.name(), "tiny");
        assert_eq!(spec.dims(), ModelDims::paper());
        let g = spec.build(ModelDims::new(2, 8, 16, 4)).unwrap();
        g.validate().unwrap();
        assert_eq!(g.num_groups(), 2);
        // Per-layer DI/DO: l0 8->16, l1 16->4.
        let weights: Vec<&crate::ir::Node> = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, IrOp::Weight { .. }))
            .collect();
        assert_eq!(weights.len(), 2);
        assert_eq!(weights[0].name, "l0.W");
        assert_eq!(weights[1].name, "l1.W");
        let IrOp::Weight { rows, seed } = weights[0].op else {
            unreachable!()
        };
        assert_eq!((rows, weights[0].cols, seed), (8, 16, 100));
        let IrOp::Weight { rows, seed } = weights[1].op else {
            unreachable!()
        };
        assert_eq!((rows, weights[1].cols, seed), (16, 4, 101));
        // Alias: the dmm node is named l{L}.z but bound to `h`.
        assert!(g.nodes.iter().any(|n| n.name == "l1.z"));
        assert_eq!(g.nodes[g.output.unwrap()].cols, 4);
    }

    #[test]
    fn explicit_layer_ranges() {
        let src = "\
h = input IN
layer 0..LAYERS {
  e = scatter_src h
  h = gather max e as agg
}
output h
";
        let spec = ModelSpec::parse("ranged", src).unwrap();
        let g = spec.build(ModelDims::uniform(3, 8)).unwrap();
        assert_eq!(g.num_groups(), 3);
        assert_eq!(spec.name(), "ranged", "falls back to the given name");
    }

    #[test]
    fn errors_carry_source_lines() {
        // Line 3: dmm against a mis-shaped weight.
        let src = "h = input IN\nW = weight 7 4 seed 1\nz = dmm h W\noutput z\n";
        let e = ModelSpec::parse("bad", src).unwrap_err();
        assert_eq!(e.line, Some(3), "{e}");
        assert!(e.message.contains("shape mismatch"), "{e}");
        assert!(format!("{e}").starts_with("line 3:"));

        let e = ModelSpec::parse("bad", "h = input IN\nz = unary relu nope\noutput z\n")
            .unwrap_err();
        assert_eq!(e.line, Some(2));
        assert!(e.message.contains("unknown value 'nope'"), "{e}");

        let e = ModelSpec::parse("bad", "h = input IN\nW = weight DI 4\noutput h\n").unwrap_err();
        assert_eq!(e.line, Some(2));
        assert!(e.message.contains("layer block"), "{e}");
    }

    #[test]
    fn structural_parse_errors() {
        for (src, what) in [
            ("h = input IN\n}\noutput h\n", "unmatched '}'"),
            ("layer {\nh = input IN\n", "unclosed layer block"),
            ("layer {\nlayer {\n}\n}\n", "nested"),
            ("h = input IN\nh = frobnicate x\noutput h\n", "unknown op"),
            ("h = input IN\noutput h\noutput h\n", "duplicate 'output'"),
            ("model a\nmodel b\nh = input IN\noutput h\n", "duplicate 'model'"),
            ("h = input IN\n", "no 'output'"),
            ("as = input IN\noutput as\n", "bad binding"),
            ("h = input IN\nz = gather sum h\noutput z\n", "must be Edge-located"),
        ] {
            let e = ModelSpec::parse("t", src).unwrap_err();
            assert!(e.message.contains(what), "{src:?}: {e}");
        }
    }

    #[test]
    fn subtraction_in_expressions() {
        // `-` is a negated term in the sum-of-products grammar: dims,
        // seeds and layer ranges all accept it.
        let src = "\
h = input IN
W = weight IN 2*HID-OUT seed 10-3
z = dmm h W
output z
";
        let g = ModelSpec::parse("t", src)
            .unwrap()
            .build(ModelDims::new(1, 8, 6, 4))
            .unwrap();
        let w = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, IrOp::Weight { .. }))
            .expect("weight node");
        assert_eq!(w.cols, 2 * 6 - 4);
        let IrOp::Weight { seed, .. } = w.op else {
            unreachable!()
        };
        assert_eq!(seed, 7);

        // A `LAYERS-1` range leaves the last layer out.
        let ranged = "\
h = input IN
layer 0..LAYERS-1 {
  e = scatter_src h
  h = gather sum e as agg
}
output h
";
        let g = ModelSpec::parse("t", ranged)
            .unwrap()
            .build(ModelDims::uniform(3, 8))
            .unwrap();
        assert_eq!(g.num_groups(), 2);

        // Dims that cancel to zero are rejected with the offending line;
        // dangling / unary minus is malformed.
        let e = ModelSpec::parse("t", "h = input IN-IN\noutput h\n").unwrap_err();
        assert!(e.message.contains("evaluates to 0"), "{e}");
        assert_eq!(e.line, Some(1));
        for bad in ["h = input -8\noutput h\n", "h = input IN-\noutput h\n"] {
            let e = ModelSpec::parse("t", bad).unwrap_err();
            assert!(e.message.contains("empty operand"), "{bad:?}: {e}");
        }
    }

    #[test]
    fn dims_directive_sets_defaults() {
        let src = "dims 3 64 64 32\nh = input IN\noutput h\n";
        let spec = ModelSpec::parse("t", src).unwrap();
        assert_eq!(spec.dims(), ModelDims::new(3, 64, 64, 32));
        assert_eq!(spec.graph().input_dim(), 64);
        assert_eq!(format!("{}", spec.dims()), "3x[64->64->32]");
    }

    #[test]
    fn auto_seeds_are_distinct_per_layer_and_statement() {
        // W0 at top level and W/b inside the layer body: auto seeds must
        // not collide across the top-level/layer boundary nor across
        // layer iterations.
        let src = "\
h = input IN
W0 = weight IN IN
h0 = dmm h W0
layer {
  W = weight DI DO
  b = bias DO
  z = dmm h0 W
  h0 = binary add z b as h2
}
output h0
";
        let g = ModelSpec::parse("t", src)
            .unwrap()
            .build(ModelDims::uniform(2, 8))
            .unwrap();
        let seeds: Vec<u64> = g
            .nodes
            .iter()
            .filter_map(|n| match n.op {
                IrOp::Weight { seed, .. } => Some(seed),
                IrOp::Bias { seed } => Some(seed),
                _ => None,
            })
            .collect();
        let mut dedup = seeds.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), seeds.len(), "auto seeds collide: {seeds:?}");
    }

    #[test]
    fn fingerprint_tracks_name_source_and_dims() {
        let a = ModelSpec::parse("t", TINY).unwrap();
        let b = a.with_dims(ModelDims::uniform(1, 8)).unwrap();
        assert_ne!(a.fingerprint(), b.fingerprint(), "dims must re-key");
        let c = ModelSpec::parse("t", &TINY.replace("gather sum", "gather max")).unwrap();
        assert_ne!(a.fingerprint(), c.fingerprint(), "source must re-key");
        let d = ModelSpec::parse("t", TINY).unwrap();
        assert_eq!(a.fingerprint(), d.fingerprint(), "stable across parses");
        // Spec-level mul_scalar/add_scalar round-trip through f32 bits.
        let e = ModelSpec::parse(
            "t",
            "h = input IN\nq = unary mul_scalar -1 h\noutput q\n",
        )
        .unwrap();
        let n = &e.graph().nodes[1];
        assert_eq!(n.op, IrOp::Unary(ElwOp::MulScalar((-1.0f32).to_bits())));
    }
}
