//! Unified computational graph (paper §V-C1).
//!
//! The compiler front-end of high-level frameworks (DGL `update_all`, PyG
//! `scatter`) is modelled by this IR: framework-specific graph operators are
//! replaced by generic GTR operators (`ScatterSrc`, `ScatterDst`,
//! `Gather(reduce)`), and dense compute by `Dmm` / element-wise nodes.
//!
//! Every node carries a *location*: `Vertex` (one row per graph vertex),
//! `Edge` (one row per edge) or `Param` (model weights). GTR nodes are the
//! only ops that change location.

pub mod models;

use std::collections::HashMap;

use crate::isa::{ElwOp, Reduce};

/// Data location of an IR value.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Loc {
    Vertex,
    Edge,
    Param,
}

/// IR operator kinds.
#[derive(Clone, Debug, PartialEq)]
pub enum IrOp {
    /// Model input: per-vertex feature matrix `[N, dim]`.
    Input,
    /// Per-vertex in-degree as a `[N, 1]` f32 column (GCN normalisation).
    Degree,
    /// Weight parameter `[rows, cols]`, deterministic init from `seed`.
    Weight { rows: u32, seed: u64 },
    /// Bias row `[1, cols]`, broadcast over rows when consumed.
    Bias { seed: u64 },
    /// Dense matmul: `inputs[0] [*, k] × inputs[1] (Weight [k, n])`.
    Dmm,
    /// Unary element-wise op.
    Unary(ElwOp),
    /// Binary element-wise op. `inputs[1]` may be a `Bias` (broadcast row).
    Binary(ElwOp),
    /// Per-row scaling: `inputs[0] [*, d] * inputs[1] [*, 1]`.
    RowScale,
    /// Feature concatenation of two same-location values.
    Concat,
    /// GTR: copy source-vertex rows onto out-edges (vertex → edge).
    ScatterSrc,
    /// GTR: copy destination-vertex rows onto in-edges (vertex → edge).
    ScatterDst,
    /// GTR: segment-reduce edge rows by destination (edge → vertex).
    Gather(Reduce),
    /// Marks the model output (per-vertex).
    Output,
}

pub type NodeId = usize;

/// One node of the unified computational graph.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: NodeId,
    pub op: IrOp,
    pub inputs: Vec<NodeId>,
    pub loc: Loc,
    /// Feature width (columns) of this value.
    pub cols: u32,
    /// Debug name (propagated into the symbol table).
    pub name: String,
}

/// The unified computational graph. Nodes are stored in insertion order,
/// which is a topological order by construction (builders may only
/// reference already-created nodes).
#[derive(Clone, Debug, Default)]
pub struct IrGraph {
    pub nodes: Vec<Node>,
    pub output: Option<NodeId>,
    pub name: String,
}

impl IrGraph {
    pub fn new(name: &str) -> Self {
        IrGraph {
            nodes: Vec::new(),
            output: None,
            name: name.to_string(),
        }
    }

    fn push(&mut self, op: IrOp, inputs: Vec<NodeId>, loc: Loc, cols: u32, name: &str) -> NodeId {
        for &i in &inputs {
            assert!(i < self.nodes.len(), "forward reference in IR builder");
        }
        let id = self.nodes.len();
        self.nodes.push(Node {
            id,
            op,
            inputs,
            loc,
            cols,
            name: name.to_string(),
        });
        id
    }

    // ----- builder API ------------------------------------------------------

    pub fn input(&mut self, dim: u32) -> NodeId {
        self.push(IrOp::Input, vec![], Loc::Vertex, dim, "x")
    }

    pub fn degree(&mut self) -> NodeId {
        self.push(IrOp::Degree, vec![], Loc::Vertex, 1, "deg")
    }

    pub fn weight(&mut self, rows: u32, cols: u32, seed: u64, name: &str) -> NodeId {
        self.push(IrOp::Weight { rows, seed }, vec![], Loc::Param, cols, name)
    }

    pub fn bias(&mut self, cols: u32, seed: u64, name: &str) -> NodeId {
        self.push(IrOp::Bias { seed }, vec![], Loc::Param, cols, name)
    }

    pub fn dmm(&mut self, x: NodeId, w: NodeId, name: &str) -> NodeId {
        let (loc, k) = (self.nodes[x].loc, self.nodes[x].cols);
        let wn = &self.nodes[w];
        let IrOp::Weight { rows, .. } = wn.op else {
            panic!("dmm second input must be a Weight");
        };
        assert_eq!(rows, k, "dmm shape mismatch: [{k}] x [{rows},{}]", wn.cols);
        assert_ne!(loc, Loc::Param);
        let cols = wn.cols;
        self.push(IrOp::Dmm, vec![x, w], loc, cols, name)
    }

    pub fn unary(&mut self, op: ElwOp, x: NodeId, name: &str) -> NodeId {
        assert!(!op.is_binary());
        let (loc, cols) = (self.nodes[x].loc, self.nodes[x].cols);
        self.push(IrOp::Unary(op), vec![x], loc, cols, name)
    }

    pub fn binary(&mut self, op: ElwOp, a: NodeId, b: NodeId, name: &str) -> NodeId {
        assert!(op.is_binary());
        let (loc, cols) = (self.nodes[a].loc, self.nodes[a].cols);
        let bn = &self.nodes[b];
        assert_eq!(bn.cols, cols, "binary width mismatch");
        assert!(
            bn.loc == loc || matches!(bn.op, IrOp::Bias { .. }),
            "binary operands must share location (or b is a Bias)"
        );
        self.push(IrOp::Binary(op), vec![a, b], loc, cols, name)
    }

    pub fn row_scale(&mut self, x: NodeId, s: NodeId, name: &str) -> NodeId {
        let (loc, cols) = (self.nodes[x].loc, self.nodes[x].cols);
        assert_eq!(self.nodes[s].cols, 1, "row_scale scale must be [*,1]");
        assert_eq!(self.nodes[s].loc, loc);
        self.push(IrOp::RowScale, vec![x, s], loc, cols, name)
    }

    pub fn concat(&mut self, a: NodeId, b: NodeId, name: &str) -> NodeId {
        let loc = self.nodes[a].loc;
        assert_eq!(self.nodes[b].loc, loc);
        let cols = self.nodes[a].cols + self.nodes[b].cols;
        self.push(IrOp::Concat, vec![a, b], loc, cols, name)
    }

    pub fn scatter_src(&mut self, x: NodeId, name: &str) -> NodeId {
        assert_eq!(self.nodes[x].loc, Loc::Vertex);
        let cols = self.nodes[x].cols;
        self.push(IrOp::ScatterSrc, vec![x], Loc::Edge, cols, name)
    }

    pub fn scatter_dst(&mut self, x: NodeId, name: &str) -> NodeId {
        assert_eq!(self.nodes[x].loc, Loc::Vertex);
        let cols = self.nodes[x].cols;
        self.push(IrOp::ScatterDst, vec![x], Loc::Edge, cols, name)
    }

    pub fn gather(&mut self, reduce: Reduce, e: NodeId, name: &str) -> NodeId {
        assert_eq!(self.nodes[e].loc, Loc::Edge);
        let cols = self.nodes[e].cols;
        self.push(IrOp::Gather(reduce), vec![e], Loc::Vertex, cols, name)
    }

    pub fn set_output(&mut self, x: NodeId) {
        assert_eq!(self.nodes[x].loc, Loc::Vertex, "output must be per-vertex");
        let id = self.push(IrOp::Output, vec![x], Loc::Vertex, self.nodes[x].cols, "out");
        self.output = Some(id);
    }

    // ----- analysis helpers -------------------------------------------------

    /// Gather depth per node: the maximum number of `Gather` ops on any
    /// path from an input to (and including inputs of) this node. This is
    /// the PLOF *group index* driver (§V-C2).
    pub fn gather_depth(&self) -> Vec<u32> {
        let mut depth = vec![0u32; self.nodes.len()];
        for n in &self.nodes {
            let mut d = 0;
            for &i in &n.inputs {
                let contrib = depth[i] + u32::from(matches!(self.nodes[i].op, IrOp::Gather(_)));
                d = d.max(contrib);
            }
            depth[n.id] = d;
        }
        depth
    }

    /// Number of PLOF groups = max gather depth of any gather node + 1
    /// (0 if the model has no GTR at all).
    pub fn num_groups(&self) -> u32 {
        let depth = self.gather_depth();
        self.nodes
            .iter()
            .filter(|n| matches!(n.op, IrOp::Gather(_)))
            .map(|n| depth[n.id] + 1)
            .max()
            .unwrap_or(0)
    }

    /// Users (consumers) of every node.
    pub fn users(&self) -> Vec<Vec<NodeId>> {
        let mut users = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                users[i].push(n.id);
            }
        }
        users
    }

    /// Count nodes per operator category (used in model-variety reports).
    pub fn op_census(&self) -> HashMap<&'static str, usize> {
        let mut m = HashMap::new();
        for n in &self.nodes {
            let k = match n.op {
                IrOp::Input | IrOp::Degree | IrOp::Weight { .. } | IrOp::Bias { .. } => "data",
                IrOp::Dmm => "dmm",
                IrOp::Unary(_) | IrOp::Binary(_) | IrOp::RowScale | IrOp::Concat => "elw",
                IrOp::ScatterSrc | IrOp::ScatterDst | IrOp::Gather(_) => "gtr",
                IrOp::Output => "data",
            };
            *m.entry(k).or_insert(0) += 1;
        }
        m
    }

    /// Structural validation: topological input order, location typing of
    /// GTR boundaries, a single output.
    pub fn validate(&self) -> Result<(), String> {
        let Some(out) = self.output else {
            return Err("no output set".into());
        };
        if !matches!(self.nodes[out].op, IrOp::Output) {
            return Err("output node is not IrOp::Output".into());
        }
        for n in &self.nodes {
            for &i in &n.inputs {
                if i >= n.id {
                    return Err(format!("node {} references later node {}", n.id, i));
                }
            }
            match n.op {
                IrOp::ScatterSrc | IrOp::ScatterDst => {
                    if self.nodes[n.inputs[0]].loc != Loc::Vertex || n.loc != Loc::Edge {
                        return Err(format!("scatter {} mis-located", n.id));
                    }
                }
                IrOp::Gather(_) => {
                    if self.nodes[n.inputs[0]].loc != Loc::Edge || n.loc != Loc::Vertex {
                        return Err(format!("gather {} mis-located", n.id));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> IrGraph {
        let mut g = IrGraph::new("tiny");
        let x = g.input(8);
        let e = g.scatter_src(x, "e");
        let a = g.gather(Reduce::Sum, e, "a");
        let w = g.weight(8, 4, 1, "w");
        let z = g.dmm(a, w, "z");
        let r = g.unary(ElwOp::Relu, z, "r");
        g.set_output(r);
        g
    }

    #[test]
    fn builds_and_validates() {
        let g = tiny();
        assert!(g.validate().is_ok());
        assert_eq!(g.num_groups(), 1);
    }

    #[test]
    fn gather_depth_counts() {
        let mut g = IrGraph::new("two-round");
        let x = g.input(4);
        let e = g.scatter_src(x, "e1");
        let a = g.gather(Reduce::Sum, e, "a1");
        let e2 = g.scatter_src(a, "e2");
        let a2 = g.gather(Reduce::Max, e2, "a2");
        g.set_output(a2);
        let d = g.gather_depth();
        assert_eq!(d[e], 0);
        assert_eq!(d[a], 0); // gather itself is at the depth of its inputs
        assert_eq!(d[e2], 1);
        assert_eq!(d[a2], 1);
        assert_eq!(g.num_groups(), 2);
    }

    #[test]
    #[should_panic]
    fn dmm_shape_mismatch_panics() {
        let mut g = IrGraph::new("bad");
        let x = g.input(8);
        let w = g.weight(16, 4, 1, "w");
        g.dmm(x, w, "z");
    }

    #[test]
    fn concat_widths_add() {
        let mut g = IrGraph::new("cat");
        let x = g.input(8);
        let y = g.unary(ElwOp::Relu, x, "y");
        let c = g.concat(x, y, "c");
        assert_eq!(g.nodes[c].cols, 16);
    }

    #[test]
    fn census() {
        let g = tiny();
        let c = g.op_census();
        assert_eq!(c["gtr"], 2);
        assert_eq!(c["dmm"], 1);
        assert_eq!(c["elw"], 1);
    }

    #[test]
    fn users_inverse_of_inputs() {
        let g = tiny();
        let users = g.users();
        for n in &g.nodes {
            for &i in &n.inputs {
                assert!(users[i].contains(&n.id));
            }
        }
    }
}
