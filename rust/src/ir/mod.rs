//! Unified computational graph (paper §V-C1).
//!
//! The compiler front-end of high-level frameworks (DGL `update_all`, PyG
//! `scatter`) is modelled by this IR: framework-specific graph operators are
//! replaced by generic GTR operators (`ScatterSrc`, `ScatterDst`,
//! `Gather(reduce)`), and dense compute by `Dmm` / element-wise nodes.
//!
//! Every node carries a *location*: `Vertex` (one row per graph vertex),
//! `Edge` (one row per edge) or `Param` (model weights). GTR nodes are the
//! only ops that change location.
//!
//! Models enter the IR through two doors: the legacy Rust builders in
//! [`models`] (the four Tbl I networks, kept as ground truth) and the
//! open, spec-driven path — [`spec`] parses declarative `.gnn` model
//! definitions into validated graphs, and [`zoo`] registers the built-in
//! entries plus anything user-provided. Because specs arrive from user
//! files, every typing rule the builder enforces is available as a
//! `try_*` method returning a typed [`IrError`] (the panicking builder
//! verbs are thin wrappers over those).

pub mod models;
pub mod spec;
pub mod zoo;

use std::collections::HashMap;
use std::fmt;

use crate::isa::{ElwOp, Reduce};

/// A typed IR construction/validation error. `line` is the 1-based source
/// line of the `.gnn` spec statement that failed, when the error came from
/// the spec front-end ([`spec::ModelSpec`]); builder-level misuse from
/// Rust carries no line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IrError {
    pub line: Option<u32>,
    pub message: String,
}

impl IrError {
    pub fn new(message: impl Into<String>) -> Self {
        IrError {
            line: None,
            message: message.into(),
        }
    }

    /// Attach a source line (keeps the innermost line if one is already
    /// set, so builder errors surface the statement that triggered them).
    pub fn at(mut self, line: u32) -> Self {
        if self.line.is_none() {
            self.line = Some(line);
        }
        self
    }
}

impl fmt::Display for IrError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.line {
            Some(l) => write!(f, "line {l}: {}", self.message),
            None => write!(f, "{}", self.message),
        }
    }
}

impl std::error::Error for IrError {}

/// Data location of an IR value.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Loc {
    Vertex,
    Edge,
    Param,
}

/// IR operator kinds.
#[derive(Clone, Debug, PartialEq)]
pub enum IrOp {
    /// Model input: per-vertex feature matrix `[N, dim]`.
    Input,
    /// Per-vertex in-degree as a `[N, 1]` f32 column (GCN normalisation).
    Degree,
    /// Weight parameter `[rows, cols]`, deterministic init from `seed`.
    Weight { rows: u32, seed: u64 },
    /// Bias row `[1, cols]`, broadcast over rows when consumed.
    Bias { seed: u64 },
    /// Dense matmul: `inputs[0] [*, k] × inputs[1] (Weight [k, n])`.
    Dmm,
    /// Unary element-wise op.
    Unary(ElwOp),
    /// Binary element-wise op. `inputs[1]` may be a `Bias` (broadcast row).
    Binary(ElwOp),
    /// Per-row scaling: `inputs[0] [*, d] * inputs[1] [*, 1]`.
    RowScale,
    /// Feature concatenation of two same-location values.
    Concat,
    /// GTR: copy source-vertex rows onto out-edges (vertex → edge).
    ScatterSrc,
    /// GTR: copy destination-vertex rows onto in-edges (vertex → edge).
    ScatterDst,
    /// GTR: segment-reduce edge rows by destination (edge → vertex).
    Gather(Reduce),
    /// Marks the model output (per-vertex).
    Output,
}

pub type NodeId = usize;

/// One node of the unified computational graph.
#[derive(Clone, Debug, PartialEq)]
pub struct Node {
    pub id: NodeId,
    pub op: IrOp,
    pub inputs: Vec<NodeId>,
    pub loc: Loc,
    /// Feature width (columns) of this value.
    pub cols: u32,
    /// Debug name (propagated into the symbol table).
    pub name: String,
}

/// The unified computational graph. Nodes are stored in insertion order,
/// which is a topological order by construction (builders may only
/// reference already-created nodes). `PartialEq` compares node for node
/// (op, inputs, location, width, debug name) — the equivalence the zoo
/// roundtrip tests assert between spec-built and legacy-built models.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct IrGraph {
    pub nodes: Vec<Node>,
    pub output: Option<NodeId>,
    pub name: String,
}

impl IrGraph {
    pub fn new(name: &str) -> Self {
        IrGraph {
            nodes: Vec::new(),
            output: None,
            name: name.to_string(),
        }
    }

    fn push(&mut self, op: IrOp, inputs: Vec<NodeId>, loc: Loc, cols: u32, name: &str) -> NodeId {
        for &i in &inputs {
            assert!(i < self.nodes.len(), "forward reference in IR builder");
        }
        let id = self.nodes.len();
        self.nodes.push(Node {
            id,
            op,
            inputs,
            loc,
            cols,
            name: name.to_string(),
        });
        id
    }

    // ----- builder API ------------------------------------------------------
    //
    // Every typing rule lives in a `try_*` method returning `IrError` (the
    // spec front-end feeds user files through these); the un-prefixed verbs
    // are panicking wrappers for in-crate builders and tests.

    pub fn input(&mut self, dim: u32) -> NodeId {
        self.push(IrOp::Input, vec![], Loc::Vertex, dim, "x")
    }

    pub fn degree(&mut self) -> NodeId {
        self.push(IrOp::Degree, vec![], Loc::Vertex, 1, "deg")
    }

    pub fn weight(&mut self, rows: u32, cols: u32, seed: u64, name: &str) -> NodeId {
        self.push(IrOp::Weight { rows, seed }, vec![], Loc::Param, cols, name)
    }

    pub fn bias(&mut self, cols: u32, seed: u64, name: &str) -> NodeId {
        self.push(IrOp::Bias { seed }, vec![], Loc::Param, cols, name)
    }

    pub fn try_dmm(&mut self, x: NodeId, w: NodeId, name: &str) -> Result<NodeId, IrError> {
        let (loc, k) = (self.nodes[x].loc, self.nodes[x].cols);
        let wn = &self.nodes[w];
        let IrOp::Weight { rows, .. } = wn.op else {
            return Err(IrError::new(format!(
                "dmm second input '{}' must be a Weight",
                wn.name
            )));
        };
        if rows != k {
            return Err(IrError::new(format!(
                "dmm shape mismatch: [{k}] x [{rows},{}]",
                wn.cols
            )));
        }
        if loc == Loc::Param {
            return Err(IrError::new(format!(
                "dmm first input '{}' must be Vertex- or Edge-located",
                self.nodes[x].name
            )));
        }
        let cols = wn.cols;
        Ok(self.push(IrOp::Dmm, vec![x, w], loc, cols, name))
    }

    pub fn dmm(&mut self, x: NodeId, w: NodeId, name: &str) -> NodeId {
        self.try_dmm(x, w, name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn try_unary(&mut self, op: ElwOp, x: NodeId, name: &str) -> Result<NodeId, IrError> {
        if op.is_binary() {
            return Err(IrError::new(format!("{op:?} is a binary op, not unary")));
        }
        let (loc, cols) = (self.nodes[x].loc, self.nodes[x].cols);
        Ok(self.push(IrOp::Unary(op), vec![x], loc, cols, name))
    }

    pub fn unary(&mut self, op: ElwOp, x: NodeId, name: &str) -> NodeId {
        self.try_unary(op, x, name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn try_binary(
        &mut self,
        op: ElwOp,
        a: NodeId,
        b: NodeId,
        name: &str,
    ) -> Result<NodeId, IrError> {
        if !op.is_binary() {
            return Err(IrError::new(format!("{op:?} is a unary op, not binary")));
        }
        let (loc, cols) = (self.nodes[a].loc, self.nodes[a].cols);
        let bn = &self.nodes[b];
        if bn.cols != cols {
            return Err(IrError::new(format!(
                "binary width mismatch: '{}' is [*,{cols}] but '{}' is [*,{}]",
                self.nodes[a].name, bn.name, bn.cols
            )));
        }
        if bn.loc != loc && !matches!(bn.op, IrOp::Bias { .. }) {
            return Err(IrError::new(format!(
                "binary operands '{}' and '{}' must share a location (or the \
                 second must be a bias row)",
                self.nodes[a].name, bn.name
            )));
        }
        Ok(self.push(IrOp::Binary(op), vec![a, b], loc, cols, name))
    }

    pub fn binary(&mut self, op: ElwOp, a: NodeId, b: NodeId, name: &str) -> NodeId {
        self.try_binary(op, a, b, name)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn try_row_scale(&mut self, x: NodeId, s: NodeId, name: &str) -> Result<NodeId, IrError> {
        let (loc, cols) = (self.nodes[x].loc, self.nodes[x].cols);
        if self.nodes[s].cols != 1 {
            return Err(IrError::new(format!(
                "row_scale scale '{}' must be [*,1], got [*,{}]",
                self.nodes[s].name, self.nodes[s].cols
            )));
        }
        if self.nodes[s].loc != loc {
            return Err(IrError::new(format!(
                "row_scale operands '{}' and '{}' must share a location",
                self.nodes[x].name, self.nodes[s].name
            )));
        }
        Ok(self.push(IrOp::RowScale, vec![x, s], loc, cols, name))
    }

    pub fn row_scale(&mut self, x: NodeId, s: NodeId, name: &str) -> NodeId {
        self.try_row_scale(x, s, name)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn try_concat(&mut self, a: NodeId, b: NodeId, name: &str) -> Result<NodeId, IrError> {
        let loc = self.nodes[a].loc;
        if self.nodes[b].loc != loc {
            return Err(IrError::new(format!(
                "concat operands '{}' and '{}' must share a location",
                self.nodes[a].name, self.nodes[b].name
            )));
        }
        let cols = self.nodes[a].cols + self.nodes[b].cols;
        Ok(self.push(IrOp::Concat, vec![a, b], loc, cols, name))
    }

    pub fn concat(&mut self, a: NodeId, b: NodeId, name: &str) -> NodeId {
        self.try_concat(a, b, name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn try_scatter_src(&mut self, x: NodeId, name: &str) -> Result<NodeId, IrError> {
        if self.nodes[x].loc != Loc::Vertex {
            return Err(IrError::new(format!(
                "scatter_src input '{}' must be Vertex-located",
                self.nodes[x].name
            )));
        }
        let cols = self.nodes[x].cols;
        Ok(self.push(IrOp::ScatterSrc, vec![x], Loc::Edge, cols, name))
    }

    pub fn scatter_src(&mut self, x: NodeId, name: &str) -> NodeId {
        self.try_scatter_src(x, name)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn try_scatter_dst(&mut self, x: NodeId, name: &str) -> Result<NodeId, IrError> {
        if self.nodes[x].loc != Loc::Vertex {
            return Err(IrError::new(format!(
                "scatter_dst input '{}' must be Vertex-located",
                self.nodes[x].name
            )));
        }
        let cols = self.nodes[x].cols;
        Ok(self.push(IrOp::ScatterDst, vec![x], Loc::Edge, cols, name))
    }

    pub fn scatter_dst(&mut self, x: NodeId, name: &str) -> NodeId {
        self.try_scatter_dst(x, name)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn try_gather(&mut self, reduce: Reduce, e: NodeId, name: &str) -> Result<NodeId, IrError> {
        if self.nodes[e].loc != Loc::Edge {
            return Err(IrError::new(format!(
                "gather input '{}' must be Edge-located (scatter first)",
                self.nodes[e].name
            )));
        }
        let cols = self.nodes[e].cols;
        Ok(self.push(IrOp::Gather(reduce), vec![e], Loc::Vertex, cols, name))
    }

    pub fn gather(&mut self, reduce: Reduce, e: NodeId, name: &str) -> NodeId {
        self.try_gather(reduce, e, name)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn try_set_output(&mut self, x: NodeId) -> Result<(), IrError> {
        if self.nodes[x].loc != Loc::Vertex {
            return Err(IrError::new(format!(
                "output '{}' must be per-vertex",
                self.nodes[x].name
            )));
        }
        let id = self.push(IrOp::Output, vec![x], Loc::Vertex, self.nodes[x].cols, "out");
        self.output = Some(id);
        Ok(())
    }

    pub fn set_output(&mut self, x: NodeId) {
        self.try_set_output(x).unwrap_or_else(|e| panic!("{e}"))
    }

    // ----- analysis helpers -------------------------------------------------

    /// Feature width of the model's `Input` node (0 for degenerate graphs
    /// without one). Drivers use this to size the feature matrix instead
    /// of hard-coding the shape.
    pub fn input_dim(&self) -> u32 {
        self.nodes
            .iter()
            .find(|n| matches!(n.op, IrOp::Input))
            .map(|n| n.cols)
            .unwrap_or(0)
    }

    /// Gather depth per node: the maximum number of `Gather` ops on any
    /// path from an input to (and including inputs of) this node. This is
    /// the PLOF *group index* driver (§V-C2).
    pub fn gather_depth(&self) -> Vec<u32> {
        let mut depth = vec![0u32; self.nodes.len()];
        for n in &self.nodes {
            let mut d = 0;
            for &i in &n.inputs {
                let contrib = depth[i] + u32::from(matches!(self.nodes[i].op, IrOp::Gather(_)));
                d = d.max(contrib);
            }
            depth[n.id] = d;
        }
        depth
    }

    /// Number of PLOF groups = max gather depth of any gather node + 1
    /// (0 if the model has no GTR at all).
    pub fn num_groups(&self) -> u32 {
        let depth = self.gather_depth();
        self.nodes
            .iter()
            .filter(|n| matches!(n.op, IrOp::Gather(_)))
            .map(|n| depth[n.id] + 1)
            .max()
            .unwrap_or(0)
    }

    /// Users (consumers) of every node.
    pub fn users(&self) -> Vec<Vec<NodeId>> {
        let mut users = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                users[i].push(n.id);
            }
        }
        users
    }

    /// Count nodes per operator category (used in model-variety reports).
    pub fn op_census(&self) -> HashMap<&'static str, usize> {
        let mut m = HashMap::new();
        for n in &self.nodes {
            let k = match n.op {
                IrOp::Input | IrOp::Degree | IrOp::Weight { .. } | IrOp::Bias { .. } => "data",
                IrOp::Dmm => "dmm",
                IrOp::Unary(_) | IrOp::Binary(_) | IrOp::RowScale | IrOp::Concat => "elw",
                IrOp::ScatterSrc | IrOp::ScatterDst | IrOp::Gather(_) => "gtr",
                IrOp::Output => "data",
            };
            *m.entry(k).or_insert(0) += 1;
        }
        m
    }

    /// Structural validation: topological input order, location typing of
    /// GTR boundaries, a single output.
    pub fn validate(&self) -> Result<(), String> {
        let Some(out) = self.output else {
            return Err("no output set".into());
        };
        if !matches!(self.nodes[out].op, IrOp::Output) {
            return Err("output node is not IrOp::Output".into());
        }
        for n in &self.nodes {
            for &i in &n.inputs {
                if i >= n.id {
                    return Err(format!("node {} references later node {}", n.id, i));
                }
            }
            match n.op {
                IrOp::ScatterSrc | IrOp::ScatterDst => {
                    if self.nodes[n.inputs[0]].loc != Loc::Vertex || n.loc != Loc::Edge {
                        return Err(format!("scatter {} mis-located", n.id));
                    }
                }
                IrOp::Gather(_) => {
                    if self.nodes[n.inputs[0]].loc != Loc::Edge || n.loc != Loc::Vertex {
                        return Err(format!("gather {} mis-located", n.id));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> IrGraph {
        let mut g = IrGraph::new("tiny");
        let x = g.input(8);
        let e = g.scatter_src(x, "e");
        let a = g.gather(Reduce::Sum, e, "a");
        let w = g.weight(8, 4, 1, "w");
        let z = g.dmm(a, w, "z");
        let r = g.unary(ElwOp::Relu, z, "r");
        g.set_output(r);
        g
    }

    #[test]
    fn builds_and_validates() {
        let g = tiny();
        assert!(g.validate().is_ok());
        assert_eq!(g.num_groups(), 1);
    }

    #[test]
    fn gather_depth_counts() {
        let mut g = IrGraph::new("two-round");
        let x = g.input(4);
        let e = g.scatter_src(x, "e1");
        let a = g.gather(Reduce::Sum, e, "a1");
        let e2 = g.scatter_src(a, "e2");
        let a2 = g.gather(Reduce::Max, e2, "a2");
        g.set_output(a2);
        let d = g.gather_depth();
        assert_eq!(d[e], 0);
        assert_eq!(d[a], 0); // gather itself is at the depth of its inputs
        assert_eq!(d[e2], 1);
        assert_eq!(d[a2], 1);
        assert_eq!(g.num_groups(), 2);
    }

    #[test]
    #[should_panic]
    fn dmm_shape_mismatch_panics() {
        let mut g = IrGraph::new("bad");
        let x = g.input(8);
        let w = g.weight(16, 4, 1, "w");
        g.dmm(x, w, "z");
    }

    #[test]
    fn try_builders_report_typed_errors() {
        let mut g = IrGraph::new("bad");
        let x = g.input(8);
        let w = g.weight(16, 4, 1, "w");
        let e = g.try_dmm(x, w, "z").unwrap_err();
        assert!(e.message.contains("shape mismatch"), "{e}");
        assert_eq!(e.line, None);
        assert!(format!("{}", e.at(7)).starts_with("line 7:"));
        let y = g.unary(ElwOp::Relu, x, "y");
        assert!(g.try_gather(Reduce::Sum, y, "a").is_err());
        assert!(g.try_row_scale(x, y, "s").is_err(), "scale must be [*,1]");
        let edge = g.scatter_src(x, "e");
        assert!(g.try_scatter_src(edge, "e2").is_err());
        assert!(g.try_set_output(edge).is_err());
        assert!(g.try_binary(ElwOp::Relu, x, y, "b").is_err());
        assert!(g.try_unary(ElwOp::Add, x, "u").is_err());
    }

    #[test]
    fn input_dim_reads_input_node() {
        let g = tiny();
        assert_eq!(g.input_dim(), 8);
        assert_eq!(IrGraph::new("empty").input_dim(), 0);
    }

    #[test]
    fn concat_widths_add() {
        let mut g = IrGraph::new("cat");
        let x = g.input(8);
        let y = g.unary(ElwOp::Relu, x, "y");
        let c = g.concat(x, y, "c");
        assert_eq!(g.nodes[c].cols, 16);
    }

    #[test]
    fn census() {
        let g = tiny();
        let c = g.op_census();
        assert_eq!(c["gtr"], 2);
        assert_eq!(c["dmm"], 1);
        assert_eq!(c["elw"], 1);
    }

    #[test]
    fn users_inverse_of_inputs() {
        let g = tiny();
        let users = g.users();
        for n in &g.nodes {
            for &i in &n.inputs {
                assert!(users[i].contains(&n.id));
            }
        }
    }
}
