//! Observability: structured tracing, metrics, and deterministic fault
//! injection for the whole pipeline.
//!
//! Three independent pieces, all zero-dependency:
//!
//! * [`trace`] — a lightweight span recorder. Code anywhere in the crate
//!   brackets work in [`trace::span`] guards; when a [`trace::Session`]
//!   is open the guards record `(name, track, start, duration)` spans
//!   into thread-local buffers, and the finished [`trace::Trace`]
//!   exports Chrome trace-event JSON (`chrome://tracing` / Perfetto).
//!   One track per executor worker plus a main/prepare track makes the
//!   interval-pipelining overlap literally visible. When no session is
//!   open the guards are inert: no clock read, no allocation.
//! * [`metrics`] — a process-wide registry of named counters, gauges
//!   and histograms with JSON and Prometheus-text exporters. The
//!   single source for `scripts/bench.sh`'s `BENCH_exec.json` and the
//!   `bench_diff.sh` perf-regression gate.
//! * [`faultinject`] — seeded, site-addressed fault injection
//!   (`worker_panic@shard=k`, `slow_shard`, `nonfinite_output`,
//!   `queue_stall`), armed via `--inject` / [`faultinject::arm`] and a
//!   single relaxed atomic load when disarmed. The deterministic driver
//!   of the reliability layer's chaos tests: the panic-isolated worker
//!   pool and the self-healing serve entries are exercised on a fixed,
//!   reproducible schedule instead of by luck.
//!
//! The CLI wires both: `bench` / `simulate` / `validate` / `serve`
//! accept `--trace out.json` and `--metrics out.json`.
//!
//! `sched::PhaseProfile` is a *consumer* of the span stream
//! ([`crate::sched::PhaseProfile::from_spans`]) rather than a parallel
//! timing mechanism: `exec::Executor::run_profiled` opens a session,
//! drives the walk, and folds the recorded walk spans into the familiar
//! per-(group, phase) table.

pub mod faultinject;
pub mod metrics;
pub mod trace;
