//! Deterministic fault injection — the chaos half of the reliability
//! layer.
//!
//! Production failures (a kernel panic on one worker, a stalled entry
//! thread, a model emitting NaN) are rare and racy; reproducing them in
//! a test requires making them *deterministic*. This module plants
//! named, site-addressed injection points at the seams the recovery
//! machinery defends — the worker pool's shard jobs, the serving
//! entry's batch loop, the output finite-check — and fires them on a
//! schedule fixed entirely by the armed [`Plan`]: which [`Site`], at
//! which shard, after how many eligible passes (`skip`), how many times
//! (`count`). Same plan + same workload → same failure, every run.
//!
//! ## Cost when disarmed
//!
//! The process-wide injector is **disarmed by default** and every hook
//! is a single relaxed atomic load in that state — no lock, no clock,
//! no allocation. `rust/tests/integration_chaos.rs` pins the behavioral
//! half of that claim (bit-identical outputs, zero new scratch misses,
//! zero fires) the same way `untraced_run_records_nothing` pins the
//! disabled tracer.
//!
//! ## Grammar (CLI `--inject`, also [`parse`])
//!
//! Comma-separated points, each `site[@key=value]...`:
//!
//! ```text
//! worker_panic@shard=3            panic the worker running shard 3
//! worker_panic@shard=0@skip=1     ...skipping the first pass (the warm-up)
//! slow_shard@shard=2@delay_ms=30  sleep 30ms inside shard 2's job
//! nonfinite_output@count=2        poison the next two responses with NaN
//! queue_stall@delay_ms=50         stall the entry loop 50ms per batch
//! ```
//!
//! Keys: `shard` (shard-addressed sites only), `skip` (eligible passes
//! to let through first, default 0), `count` (fires before the point
//! exhausts, default 1), `delay_ms` (sleep sites, default 5).
//!
//! The module is zero-dependency and process-global: [`arm`] installs a
//! plan, [`disarm`] removes it, and each site's hook ([`shard_site`],
//! [`poison_output`], [`queue_stall`]) consults the plan only while one
//! is armed. Tests that arm the injector must serialize against each
//! other (the chaos integration suite holds a lock for exactly this).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Where a fault can be injected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Site {
    /// Panic inside a worker-pool shard job (shard-addressed) — the
    /// scenario the panic-isolated pool and self-healing serve entries
    /// exist for.
    WorkerPanic,
    /// Sleep inside a shard job (shard-addressed): a straggler worker.
    SlowShard,
    /// Overwrite the first element of a response with NaN: a
    /// misbehaving model, exercising the typed `NonFinite` path.
    NonFiniteOutput,
    /// Sleep the serving entry loop at the top of a batch: a stalled
    /// consumer, exercising admission control and request deadlines.
    QueueStall,
}

impl Site {
    fn parse(s: &str) -> Result<Site, String> {
        match s {
            "worker_panic" => Ok(Site::WorkerPanic),
            "slow_shard" => Ok(Site::SlowShard),
            "nonfinite_output" => Ok(Site::NonFiniteOutput),
            "queue_stall" => Ok(Site::QueueStall),
            other => Err(format!(
                "inject: unknown site '{other}' \
                 (worker_panic|slow_shard|nonfinite_output|queue_stall)"
            )),
        }
    }

    /// The grammar spelling, for error messages and trailers.
    pub fn label(&self) -> &'static str {
        match self {
            Site::WorkerPanic => "worker_panic",
            Site::SlowShard => "slow_shard",
            Site::NonFiniteOutput => "nonfinite_output",
            Site::QueueStall => "queue_stall",
        }
    }
}

/// One armed injection point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Point {
    pub site: Site,
    /// Shard address for shard-level sites; a `None` matches any shard.
    pub shard: Option<usize>,
    /// Eligible passes to let through unharmed before the first fire.
    pub skip: u64,
    /// Fires before the point exhausts.
    pub count: u64,
    /// Sleep length for `slow_shard` / `queue_stall`.
    pub delay_ms: u64,
}

/// A full injection schedule: every point, evaluated independently.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Plan {
    points: Vec<Point>,
}

impl Plan {
    pub fn points(&self) -> &[Point] {
        &self.points
    }
}

/// Parse the `--inject` grammar (see the module docs).
pub fn parse(s: &str) -> Result<Plan, String> {
    let mut points = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let mut it = part.split('@');
        let site = Site::parse(it.next().unwrap_or(""))?;
        let mut p = Point {
            site,
            shard: None,
            skip: 0,
            count: 1,
            delay_ms: 5,
        };
        for kv in it {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| format!("inject: '{kv}' is not key=value"))?;
            let num = || {
                v.parse::<u64>()
                    .map_err(|_| format!("inject: bad value '{v}' for '{k}'"))
            };
            match k {
                "shard" => p.shard = Some(num()? as usize),
                "skip" => p.skip = num()?,
                "count" => p.count = num()?,
                "delay_ms" => p.delay_ms = num()?,
                other => {
                    return Err(format!(
                        "inject: unknown key '{other}' (shard|skip|count|delay_ms)"
                    ))
                }
            }
        }
        points.push(p);
    }
    if points.is_empty() {
        return Err("inject: empty plan".into());
    }
    Ok(Plan { points })
}

/// A point plus its firing history.
struct PointState {
    point: Point,
    /// Eligible passes observed (matched site + address).
    seen: u64,
    /// Times this point has fired.
    fired: u64,
}

/// The single disarmed-path cost: one relaxed load of this flag.
static ARMED: AtomicBool = AtomicBool::new(false);
/// Total fires across every point since process start (monotone — not
/// reset by [`disarm`], so tests can diff across a window).
static FIRED: AtomicU64 = AtomicU64::new(0);
static PLAN: Mutex<Option<Vec<PointState>>> = Mutex::new(None);

fn plan_lock() -> std::sync::MutexGuard<'static, Option<Vec<PointState>>> {
    // A panic between lock and unlock (worker_panic fires *outside* the
    // lock, but stay defensive) must not poison every later hook.
    PLAN.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Install `plan` process-wide, replacing any previous one. Firing
/// history restarts from zero.
pub fn arm(plan: Plan) {
    let states = plan
        .points
        .into_iter()
        .map(|point| PointState {
            point,
            seen: 0,
            fired: 0,
        })
        .collect();
    *plan_lock() = Some(states);
    ARMED.store(true, Ordering::Release);
}

/// Remove the armed plan; every hook returns to the one-atomic-load
/// fast path.
pub fn disarm() {
    ARMED.store(false, Ordering::Release);
    *plan_lock() = None;
}

/// Whether a plan is armed (one relaxed atomic load).
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Total injected faults since process start.
pub fn fired_total() -> u64 {
    FIRED.load(Ordering::Relaxed)
}

/// Evaluate `site` at `shard` against the armed plan; returns the fired
/// point. Each matching point's `seen` advances whether or not it
/// fires, so `skip`/`count` schedules are exact.
fn check(site: Site, shard: Option<usize>) -> Option<Point> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let mut g = plan_lock();
    let states = g.as_mut()?;
    for ps in states.iter_mut() {
        if ps.point.site != site {
            continue;
        }
        match (ps.point.shard, shard) {
            (Some(want), Some(got)) if want != got => continue,
            (Some(_), None) => continue,
            _ => {}
        }
        ps.seen += 1;
        if ps.seen <= ps.point.skip || ps.fired >= ps.point.count {
            continue;
        }
        ps.fired += 1;
        FIRED.fetch_add(1, Ordering::Relaxed);
        return Some(ps.point.clone());
    }
    None
}

/// Shard-level hook, called by the executor at the top of every shard
/// job (on the owning pool worker, or inline on the driving thread).
/// May sleep (`slow_shard`) and then panic (`worker_panic`) when an
/// armed point fires; both are caught and typed by the pool's panic
/// isolation.
pub fn shard_site(shard: usize) {
    if !ARMED.load(Ordering::Relaxed) {
        return;
    }
    if let Some(p) = check(Site::SlowShard, Some(shard)) {
        std::thread::sleep(Duration::from_millis(p.delay_ms));
    }
    if check(Site::WorkerPanic, Some(shard)).is_some() {
        panic!("fault injected: worker_panic@shard={shard}");
    }
}

/// Response-poisoning hook: overwrite the first element with NaN when a
/// `nonfinite_output` point fires. Returns whether it did.
pub fn poison_output(out: &mut [f32]) -> bool {
    if !ARMED.load(Ordering::Relaxed) {
        return false;
    }
    if check(Site::NonFiniteOutput, None).is_some() {
        if let Some(v) = out.first_mut() {
            *v = f32::NAN;
            return true;
        }
    }
    false
}

/// Entry-loop stall hook, called at the top of every serving batch.
pub fn queue_stall() {
    if !ARMED.load(Ordering::Relaxed) {
        return;
    }
    if let Some(p) = check(Site::QueueStall, None) {
        std::thread::sleep(Duration::from_millis(p.delay_ms));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};

    /// Unit tests share the process with every other `cargo test`
    /// thread, so (a) they serialize among themselves, and (b) they
    /// only arm shard-addressed points at an address no real workload
    /// reaches — arming an unaddressed `worker_panic` here would fault
    /// a concurrently running executor test.
    const FAR: usize = usize::MAX - 1;

    fn serial() -> MutexGuard<'static, ()> {
        static L: OnceLock<Mutex<()>> = OnceLock::new();
        L.get_or_init(|| Mutex::new(()))
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
    }

    struct DisarmOnDrop;
    impl Drop for DisarmOnDrop {
        fn drop(&mut self) {
            disarm();
        }
    }

    #[test]
    fn grammar_round_trips() {
        let plan = parse("worker_panic@shard=3@skip=1,slow_shard@shard=2@delay_ms=30@count=4")
            .unwrap();
        assert_eq!(
            plan.points(),
            &[
                Point {
                    site: Site::WorkerPanic,
                    shard: Some(3),
                    skip: 1,
                    count: 1,
                    delay_ms: 5,
                },
                Point {
                    site: Site::SlowShard,
                    shard: Some(2),
                    skip: 0,
                    count: 4,
                    delay_ms: 30,
                },
            ]
        );
    }

    #[test]
    fn grammar_rejects_unknowns() {
        assert!(parse("").is_err());
        assert!(parse("explode").is_err());
        assert!(parse("worker_panic@shard").is_err());
        assert!(parse("worker_panic@color=red").is_err());
        assert!(parse("worker_panic@shard=x").is_err());
    }

    #[test]
    fn disarmed_hooks_observe_and_record_nothing() {
        let _s = serial();
        assert!(!armed());
        let before = fired_total();
        shard_site(0);
        let mut out = [1.0f32];
        assert!(!poison_output(&mut out));
        assert_eq!(out[0], 1.0);
        queue_stall();
        assert_eq!(fired_total(), before);
    }

    #[test]
    fn skip_and_count_schedule_is_exact() {
        let _s = serial();
        let _d = DisarmOnDrop;
        // delay_ms=0: fires are observable yet harmless even if another
        // test somehow addressed the same shard.
        arm(parse(&format!("slow_shard@shard={FAR}@skip=2@count=2@delay_ms=0")).unwrap());
        let before = fired_total();
        for _ in 0..2 {
            assert!(check(Site::SlowShard, Some(FAR)).is_none(), "skipped pass fired");
        }
        assert!(check(Site::SlowShard, Some(FAR)).is_some());
        assert!(check(Site::SlowShard, Some(FAR)).is_some());
        assert!(check(Site::SlowShard, Some(FAR)).is_none(), "exhausted point fired");
        assert_eq!(fired_total() - before, 2);
    }

    #[test]
    fn shard_addressing_is_respected() {
        let _s = serial();
        let _d = DisarmOnDrop;
        arm(parse(&format!("slow_shard@shard={FAR}@delay_ms=0")).unwrap());
        assert!(check(Site::SlowShard, Some(FAR - 1)).is_none());
        assert!(check(Site::SlowShard, None).is_none());
        // Misses must not consume the schedule.
        assert!(check(Site::SlowShard, Some(FAR)).is_some());
    }

    #[test]
    fn rearm_resets_history() {
        let _s = serial();
        let _d = DisarmOnDrop;
        arm(parse(&format!("slow_shard@shard={FAR}@delay_ms=0")).unwrap());
        assert!(check(Site::SlowShard, Some(FAR)).is_some());
        assert!(check(Site::SlowShard, Some(FAR)).is_none());
        arm(parse(&format!("slow_shard@shard={FAR}@delay_ms=0")).unwrap());
        assert!(check(Site::SlowShard, Some(FAR)).is_some());
    }
}
