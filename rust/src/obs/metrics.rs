//! Process-wide metrics registry: named counters, gauges and
//! histograms, with JSON and Prometheus-text exporters.
//!
//! The registry is a global keyed by plain `[a-z0-9_]` metric names
//! (no labels — names like `sim_traffic_bytes_src` carry the tag in
//! the name so both exporters stay line-oriented and greppable).
//! Recording is lock-per-update on a `BTreeMap`, cheap at this crate's
//! rates (metrics are recorded per run / per phase, not per vertex),
//! and the sorted map makes every export deterministic.
//!
//! [`Snapshot::to_json`] emits one flat JSON object, **one metric per
//! line** — `scripts/bench.sh` and `scripts/bench_diff.sh` extract
//! values with `sed`, which that shape guarantees works. Histograms
//! flatten to `<name>_count` / `_sum` / `_min` / `_max` / `_mean`
//! lines in JSON and expand to real `_bucket{le=...}` series in
//! [`Snapshot::to_prometheus`].
//!
//! Because the registry is process-global and `cargo test` runs many
//! tests in one process, tests must use test-unique metric names and
//! assert only on their own keys; only `main.rs` calls [`reset`] (once,
//! at command start, before any thread is spawned).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io;
use std::path::Path;
use std::sync::{Mutex, OnceLock};

/// Histogram bucket upper bounds: decades from 1 µs-ish to 1000 —
/// wide enough for latencies in seconds and row counts alike.
pub const BOUNDS: [f64; 10] = [1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 1e1, 1e2, 1e3];

/// Streaming histogram: per-decade cumulative counts plus the moments
/// needed for mean / min / max.
#[derive(Clone, Copy, Debug)]
pub struct Hist {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    /// `buckets[i]` counts observations `<= BOUNDS[i]` (cumulative,
    /// Prometheus-style; values above the last bound only land in
    /// `count`).
    pub buckets: [u64; BOUNDS.len()],
}

impl Default for Hist {
    fn default() -> Self {
        Hist {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buckets: [0; BOUNDS.len()],
        }
    }
}

impl Hist {
    pub fn observe(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        for (i, b) in BOUNDS.iter().enumerate() {
            if v <= *b {
                self.buckets[i] += 1;
            }
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

/// One registered metric.
#[derive(Clone, Debug)]
pub enum Metric {
    /// Monotonic count (hits, bytes, rows).
    Counter(u64),
    /// Point-in-time value (latency, utilization, speedup).
    Gauge(f64),
    /// Distribution (per-request latencies).
    Histogram(Hist),
}

fn registry() -> &'static Mutex<BTreeMap<String, Metric>> {
    static REG: OnceLock<Mutex<BTreeMap<String, Metric>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Clear every metric. Command entry points call this once before
/// recording; never call it from library code or tests.
pub fn reset() {
    registry().lock().unwrap().clear();
}

/// Add `delta` to counter `name` (created at 0). A `name` previously
/// registered as another kind is overwritten as a counter.
pub fn counter(name: &str, delta: u64) {
    let mut r = registry().lock().unwrap();
    match r.get_mut(name) {
        Some(Metric::Counter(c)) => *c += delta,
        _ => {
            r.insert(name.to_string(), Metric::Counter(delta));
        }
    }
}

/// Set counter `name` to an absolute value (for counters the source
/// already accumulated, e.g. scratch hits of one run).
pub fn counter_abs(name: &str, v: u64) {
    registry().lock().unwrap().insert(name.to_string(), Metric::Counter(v));
}

/// Set gauge `name`.
pub fn gauge(name: &str, v: f64) {
    registry().lock().unwrap().insert(name.to_string(), Metric::Gauge(v));
}

/// Record one observation into histogram `name` (created empty).
pub fn observe(name: &str, v: f64) {
    let mut r = registry().lock().unwrap();
    match r.get_mut(name) {
        Some(Metric::Histogram(h)) => h.observe(v),
        _ => {
            let mut h = Hist::default();
            h.observe(v);
            r.insert(name.to_string(), Metric::Histogram(h));
        }
    }
}

/// Point-in-time copy of the registry, sorted by name.
pub fn snapshot() -> Snapshot {
    let r = registry().lock().unwrap();
    Snapshot {
        entries: r.iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
    }
}

/// A sorted copy of the registry at one instant.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub entries: Vec<(String, Metric)>,
}

/// JSON number formatting: f64 via `Display` (shortest round-trip, no
/// exponent for the magnitudes we record), non-finite as `null`.
fn fnum(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

impl Snapshot {
    pub fn get(&self, name: &str) -> Option<&Metric> {
        self.entries
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, m)| m)
    }

    /// Scalar view of a metric: counter as f64, gauge value, histogram
    /// mean.
    pub fn value(&self, name: &str) -> Option<f64> {
        self.get(name).map(|m| match m {
            Metric::Counter(c) => *c as f64,
            Metric::Gauge(v) => *v,
            Metric::Histogram(h) => h.mean(),
        })
    }

    /// Flat JSON object, one `"name": value` pair per line (the shape
    /// `bench.sh` / `bench_diff.sh` extract from with `sed`).
    pub fn to_json(&self) -> String {
        let mut lines: Vec<String> = Vec::with_capacity(self.entries.len());
        for (name, m) in &self.entries {
            match m {
                Metric::Counter(c) => lines.push(format!("  \"{name}\": {c}")),
                Metric::Gauge(v) => lines.push(format!("  \"{name}\": {}", fnum(*v))),
                Metric::Histogram(h) => {
                    lines.push(format!("  \"{name}_count\": {}", h.count));
                    lines.push(format!("  \"{name}_sum\": {}", fnum(h.sum)));
                    lines.push(format!("  \"{name}_min\": {}", fnum(h.min)));
                    lines.push(format!("  \"{name}_max\": {}", fnum(h.max)));
                    lines.push(format!("  \"{name}_mean\": {}", fnum(h.mean())));
                }
            }
        }
        format!("{{\n{}\n}}\n", lines.join(",\n"))
    }

    /// Prometheus text exposition format.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, m) in &self.entries {
            match m {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "# TYPE {name} counter");
                    let _ = writeln!(out, "{name} {c}");
                }
                Metric::Gauge(v) => {
                    let _ = writeln!(out, "# TYPE {name} gauge");
                    let _ = writeln!(out, "{name} {v}");
                }
                Metric::Histogram(h) => {
                    let _ = writeln!(out, "# TYPE {name} histogram");
                    for (i, b) in BOUNDS.iter().enumerate() {
                        let _ = writeln!(out, "{name}_bucket{{le=\"{b}\"}} {}", h.buckets[i]);
                    }
                    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count);
                    let _ = writeln!(out, "{name}_sum {}", h.sum);
                    let _ = writeln!(out, "{name}_count {}", h.count);
                }
            }
        }
        out
    }

    /// Write to `path`: Prometheus text when the extension is `.prom`,
    /// flat JSON otherwise.
    pub fn write(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let body = if path.extension().is_some_and(|e| e == "prom") {
            self.to_prometheus()
        } else {
            self.to_json()
        };
        std::fs::write(path, body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is shared by every test in this process: use
    // test-unique names, assert only on our own keys, never reset().

    #[test]
    fn counter_accumulates_and_sets() {
        counter("obs_mtest_c", 2);
        counter("obs_mtest_c", 3);
        counter_abs("obs_mtest_c_abs", 41);
        let s = snapshot();
        assert_eq!(s.value("obs_mtest_c"), Some(5.0));
        assert_eq!(s.value("obs_mtest_c_abs"), Some(41.0));
        assert!(s.value("obs_mtest_c_missing").is_none());
    }

    #[test]
    fn gauge_overwrites() {
        gauge("obs_mtest_g", 1.5);
        gauge("obs_mtest_g", 2.25);
        assert_eq!(snapshot().value("obs_mtest_g"), Some(2.25));
    }

    #[test]
    fn histogram_moments_and_buckets() {
        observe("obs_mtest_h", 0.5e-3);
        observe("obs_mtest_h", 2e-3);
        observe("obs_mtest_h", 2e3); // above the last bound
        let s = snapshot();
        let Some(Metric::Histogram(h)) = s.get("obs_mtest_h") else {
            panic!("histogram registered");
        };
        assert_eq!(h.count, 3);
        assert!((h.min - 0.5e-3).abs() < 1e-12);
        assert!((h.max - 2e3).abs() < 1e-9);
        // 1e-3 bucket holds only the first observation; 1e-2 holds two;
        // the out-of-range value appears in no bucket.
        assert_eq!(h.buckets[3], 1);
        assert_eq!(h.buckets[4], 2);
        assert_eq!(h.buckets[BOUNDS.len() - 1], 2);
        assert!((s.value("obs_mtest_h").unwrap() - h.mean()).abs() < 1e-12);
    }

    #[test]
    fn json_is_flat_one_metric_per_line() {
        counter_abs("obs_mtest_json_hits", 7);
        gauge("obs_mtest_json_ms", 12.5);
        observe("obs_mtest_json_lat", 0.25);
        let j = snapshot().to_json();
        assert!(j.starts_with("{\n"));
        assert!(j.ends_with("}\n"));
        // One key per line, sed-extractable.
        assert!(j.lines().any(|l| l.trim() == "\"obs_mtest_json_hits\": 7"
            || l.trim() == "\"obs_mtest_json_hits\": 7,"));
        assert!(j.contains("\"obs_mtest_json_ms\": 12.5"));
        assert!(j.contains("\"obs_mtest_json_lat_count\": 1"));
        assert!(j.contains("\"obs_mtest_json_lat_mean\": 0.25"));
        // BTreeMap-backed registry ⇒ our keys appear in sorted order
        // (hits < lat < ms) regardless of recording order.
        let pos = |k: &str| j.find(k).unwrap();
        assert!(pos("obs_mtest_json_hits") < pos("obs_mtest_json_lat_count"));
        assert!(pos("obs_mtest_json_lat_count") < pos("obs_mtest_json_ms"));
    }

    #[test]
    fn prometheus_exposition_shape() {
        counter_abs("obs_mtest_prom_total", 3);
        observe("obs_mtest_prom_lat", 0.5);
        let p = snapshot().to_prometheus();
        assert!(p.contains("# TYPE obs_mtest_prom_total counter\nobs_mtest_prom_total 3\n"));
        assert!(p.contains("# TYPE obs_mtest_prom_lat histogram"));
        assert!(p.contains("obs_mtest_prom_lat_bucket{le=\"1\"} 1"));
        assert!(p.contains("obs_mtest_prom_lat_bucket{le=\"+Inf\"} 1"));
        assert!(p.contains("obs_mtest_prom_lat_sum 0.5"));
        assert!(p.contains("obs_mtest_prom_lat_count 1"));
    }

    #[test]
    fn non_finite_gauges_export_as_null() {
        gauge("obs_mtest_nan", f64::NAN);
        let j = snapshot().to_json();
        assert!(j.contains("\"obs_mtest_nan\": null"));
    }
}
