//! Span recorder with Chrome trace-event export.
//!
//! # Model
//!
//! A *span* is one timed region: static name + category, a *track*
//! (Chrome `tid` — lane in the viewer), and optional group / interval /
//! shard indices. Spans are recorded by RAII guards ([`span`],
//! [`span_args`], [`span_if`]) into thread-local buffers and flushed to
//! one process-wide vector, so recording never contends on a lock in
//! the common case.
//!
//! Recording is scoped by an exclusive [`Session`]:
//!
//! * [`begin`] opens the session and enables recording *on the calling
//!   thread* (thread-local flag). Worker threads the instrumented code
//!   spawns are enabled explicitly: the spawning code captures
//!   [`active`] once and passes it to [`span_if`] inside the workers —
//!   spawned threads cannot see the parent's thread-locals.
//! * While a session is open, a second `begin()` from *another* thread
//!   blocks until the session ends (sessions are serialized — this is
//!   what keeps span streams deterministic when `cargo test` runs many
//!   tests in one process). A nested `begin()` from the *owning* thread
//!   returns a borrowed session whose `end()` is a no-op, so
//!   `Executor::run_profiled` composes with a surrounding `--trace`
//!   session instead of stealing its spans.
//! * [`Session::end`] drains everything recorded into a [`Trace`].
//!
//! With no session open, span guards are inert — no clock read, no
//! allocation, one thread-local flag read ([`recorded_total`] lets
//! tests prove it).
//!
//! # Export
//!
//! [`Trace::to_chrome_json`] emits the Chrome trace-event format
//! (`{"traceEvents": [...]}` with `ph:"X"` complete events), loadable
//! in `chrome://tracing` or <https://ui.perfetto.dev>. Each track
//! becomes one named thread lane: track 0 is the main/prepare lane,
//! track `1+w` is executor worker `w` ([`worker_track`]).

use std::cell::{Cell, RefCell};
use std::io;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};
use std::thread::ThreadId;
use std::time::Instant;

/// Track (Chrome `tid`) of the main thread: the walk's phase spans and
/// the pipelined `prepare` spans land here.
pub const TRACK_MAIN: u32 = 0;

/// Track of executor worker `w` (one lane per pool worker).
pub fn worker_track(w: usize) -> u32 {
    1 + w as u32
}

/// First track reserved for serving-engine entry lanes. High enough
/// that no realistic pool width collides with [`worker_track`].
pub const SERVE_TRACK_BASE: u32 = 900;

/// Track of serving-engine entry `i` (one lane per registered model, so
/// Chrome traces show concurrent entries' request overlap side by side).
pub fn serve_track(entry: usize) -> u32 {
    SERVE_TRACK_BASE + entry as u32
}

/// Canonical span names. Walk-level names (category [`cat::WALK`]) are
/// what [`crate::sched::PhaseProfile::from_spans`] folds into the
/// per-(group, phase) profile — keep them in sync with it.
pub mod names {
    /// One phase group (walk scope).
    pub const GROUP: &str = "group";
    /// One destination interval (walk scope).
    pub const INTERVAL: &str = "interval";
    /// ScatterPhase hook (iThread).
    pub const SCATTER: &str = "scatter";
    /// One `gather_shard` hook — a schedule point for pooled backends.
    pub const GATHER_SHARD: &str = "gather_shard";
    /// The `end_gather` barrier: queue drain + deterministic merge.
    pub const GATHER_DRAIN: &str = "gather_drain";
    /// ApplyPhase hook (iThread).
    pub const APPLY: &str = "apply";
    /// Next-interval DstBuffer preparation overlapped under the drain.
    pub const PREPARE: &str = "prepare";
    /// One shard's kernel work on a pool worker (worker lane).
    pub const SHARD: &str = "shard";
    /// IR → ISA compilation.
    pub const COMPILE: &str = "compile";
    /// FGGP partitioning.
    pub const PARTITION_FGGP: &str = "partition_fggp";
    /// DSW partitioning.
    pub const PARTITION_DSW: &str = "partition_dsw";
    /// One end-to-end serving request (engine entry lane; `interval`
    /// carries the request's sequence number).
    pub const REQUEST: &str = "request";
    /// One serving micro-batch (engine entry lane; `shard` carries the
    /// batch size) — request spans nest under it.
    pub const BATCH: &str = "batch";
    /// A serve entry rebuilding its warm executor after an executor
    /// fault (engine entry lane; `interval` carries the restart count,
    /// `shard` the degradation rung).
    pub const RECOVER: &str = "recover";
}

/// Span categories (Chrome `cat`, filterable in the viewer).
pub mod cat {
    /// Spans emitted by `sched::PartitionWalk::drive` — the canonical
    /// walk timeline the phase profile is derived from.
    pub const WALK: &str = "walk";
    /// Executor-internal spans (worker shards, prepare).
    pub const EXEC: &str = "exec";
    /// Frontend spans (compile, partition).
    pub const FRONTEND: &str = "frontend";
    /// Serving-engine spans (request, batch) on per-entry lanes.
    pub const SERVE: &str = "serve";
}

/// One recorded span. `group` / `interval` / `shard` are `-1` when the
/// span carries no such index.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub name: &'static str,
    pub cat: &'static str,
    /// Viewer lane: [`TRACK_MAIN`] or [`worker_track`].
    pub track: u32,
    /// Start, nanoseconds since the process-wide trace epoch.
    pub start_ns: u64,
    pub dur_ns: u64,
    pub group: i32,
    pub interval: i32,
    pub shard: i32,
}

impl Span {
    /// End, nanoseconds since the trace epoch.
    pub fn end_ns(&self) -> u64 {
        self.start_ns + self.dur_ns
    }

    /// Whether `inner` lies entirely within this span's time range —
    /// the overlap predicate the pipelining tests assert (a `prepare`
    /// span contained in a `gather_drain` span).
    pub fn contains(&self, inner: &Span) -> bool {
        inner.start_ns >= self.start_ns && inner.end_ns() <= self.end_ns()
    }
}

// ---- global state ----------------------------------------------------------

/// Spans kept per session before new ones are dropped (a runaway trace
/// must not eat the heap; the export records how many were lost).
const MAX_SPANS: usize = 4 << 20;
/// Thread-local buffer length that triggers a flush to the global vec.
const TLS_FLUSH: usize = 1024;

struct Shared {
    active: bool,
    owner: Option<ThreadId>,
    spans: Vec<Span>,
    dropped: u64,
}

fn shared() -> &'static (Mutex<Shared>, Condvar) {
    static SHARED: OnceLock<(Mutex<Shared>, Condvar)> = OnceLock::new();
    SHARED.get_or_init(|| {
        (
            Mutex::new(Shared {
                active: false,
                owner: None,
                spans: Vec::new(),
                dropped: 0,
            }),
            Condvar::new(),
        )
    })
}

fn now_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Spans recorded process-wide since startup, across all sessions —
/// a test probe: the delta over an untraced region must be zero.
static RECORDED: AtomicU64 = AtomicU64::new(0);

pub fn recorded_total() -> u64 {
    RECORDED.load(Ordering::Relaxed)
}

/// Thread-local span buffer. The `Drop` impl flushes on thread exit, so
/// executor workers (scoped threads that end before `drive` returns)
/// hand their spans to the global vec at scope join.
struct TlsBuf(Vec<Span>);

impl Drop for TlsBuf {
    fn drop(&mut self) {
        flush_vec(&mut self.0);
    }
}

thread_local! {
    static ENABLED: Cell<bool> = const { Cell::new(false) };
    static BUF: RefCell<TlsBuf> = const { RefCell::new(TlsBuf(Vec::new())) };
}

/// Append a local buffer to the global vec (only while a session is
/// open — late flushes after `end()` are discarded, they belong to no
/// one). Always leaves `v` empty.
fn flush_vec(v: &mut Vec<Span>) {
    if v.is_empty() {
        return;
    }
    let (lock, _) = shared();
    let mut sh = lock.lock().unwrap();
    if sh.active {
        let room = MAX_SPANS.saturating_sub(sh.spans.len());
        if v.len() > room {
            sh.dropped += (v.len() - room) as u64;
            v.truncate(room);
        }
        sh.spans.append(v);
    }
    v.clear();
}

fn flush_tls() {
    BUF.with(|b| flush_vec(&mut b.borrow_mut().0));
}

/// Hand this thread's buffered spans to the open session immediately.
/// The `TlsBuf` drop flush covers threads that *exit* while a session
/// is open (PR 5's scoped workers); persistent pool threads never exit
/// mid-session, so they call this at the end of every batch — before
/// the driving thread passes the barrier that lets the session end.
pub fn flush_thread() {
    flush_tls();
}

// ---- sessions --------------------------------------------------------------

/// An open recording session (see module docs). End it on the thread
/// that began it; dropping without [`Session::end`] discards the spans
/// (panic safety) but still releases the session.
pub struct Session {
    owned: bool,
    done: bool,
}

/// Open the exclusive session and enable recording on this thread.
/// Blocks while another thread holds the session; re-entrant from the
/// owning thread (returns a borrowed handle whose `end` is a no-op).
pub fn begin() -> Session {
    let me = std::thread::current().id();
    let (lock, cv) = shared();
    let mut sh = lock.lock().unwrap();
    if sh.active && sh.owner == Some(me) {
        return Session {
            owned: false,
            done: false,
        };
    }
    while sh.active {
        sh = cv.wait(sh).unwrap();
    }
    sh.active = true;
    sh.owner = Some(me);
    sh.spans.clear();
    sh.dropped = 0;
    drop(sh);
    ENABLED.with(|e| e.set(true));
    Session {
        owned: true,
        done: false,
    }
}

impl Session {
    /// Close the session and take everything it recorded. Borrowed
    /// (re-entrant) handles return an empty trace and leave the real
    /// session running.
    pub fn end(mut self) -> Trace {
        self.done = true;
        if !self.owned {
            return Trace {
                spans: Vec::new(),
                dropped: 0,
            };
        }
        ENABLED.with(|e| e.set(false));
        flush_tls();
        let (lock, cv) = shared();
        let mut sh = lock.lock().unwrap();
        let spans = std::mem::take(&mut sh.spans);
        let dropped = sh.dropped;
        sh.dropped = 0;
        sh.active = false;
        sh.owner = None;
        cv.notify_all();
        Trace { spans, dropped }
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        if self.done || !self.owned {
            return;
        }
        ENABLED.with(|e| e.set(false));
        BUF.with(|b| b.borrow_mut().0.clear());
        let (lock, cv) = shared();
        let mut sh = lock.lock().unwrap();
        sh.spans.clear();
        sh.dropped = 0;
        sh.active = false;
        sh.owner = None;
        cv.notify_all();
    }
}

/// Whether recording is enabled on the *calling thread* — capture this
/// before spawning workers and pass it to [`span_if`] inside them.
pub fn active() -> bool {
    ENABLED.with(|e| e.get())
}

/// Current length of the session's span stream (0 when no session is
/// open). Pair with [`since`] to read a tail slice without draining —
/// how `run_profiled` shares a surrounding `--trace` session.
pub fn mark() -> usize {
    flush_tls();
    let (lock, _) = shared();
    let sh = lock.lock().unwrap();
    if sh.active {
        sh.spans.len()
    } else {
        0
    }
}

/// Copy of every span recorded since `mark` (flushes this thread's
/// buffer first; spans stay in the session for its own export).
pub fn since(mark: usize) -> Vec<Span> {
    flush_tls();
    let (lock, _) = shared();
    let sh = lock.lock().unwrap();
    if sh.active && mark <= sh.spans.len() {
        sh.spans[mark..].to_vec()
    } else {
        Vec::new()
    }
}

// ---- span guards -----------------------------------------------------------

struct Pending {
    name: &'static str,
    cat: &'static str,
    track: u32,
    start_ns: u64,
    group: i32,
    interval: i32,
    shard: i32,
}

/// RAII guard: records one span from construction to drop. Inert
/// (`None`) when recording was disabled at construction.
pub struct SpanGuard(Option<Pending>);

/// Index-free span on `track` (see [`span_args`]).
pub fn span(name: &'static str, cat: &'static str, track: u32) -> SpanGuard {
    span_if(active(), name, cat, track, -1, -1, -1)
}

/// Span with group / interval / shard indices (`-1` = absent), gated on
/// this thread's recording flag.
pub fn span_args(
    name: &'static str,
    cat: &'static str,
    track: u32,
    group: i32,
    interval: i32,
    shard: i32,
) -> SpanGuard {
    span_if(active(), name, cat, track, group, interval, shard)
}

/// Span gated on an explicit flag instead of the thread-local one — for
/// spawned worker threads, which inherit nothing: the spawner captures
/// [`active`] once and passes it in.
pub fn span_if(
    enabled: bool,
    name: &'static str,
    cat: &'static str,
    track: u32,
    group: i32,
    interval: i32,
    shard: i32,
) -> SpanGuard {
    if !enabled {
        return SpanGuard(None);
    }
    SpanGuard(Some(Pending {
        name,
        cat,
        track,
        start_ns: now_ns(),
        group,
        interval,
        shard,
    }))
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(p) = self.0.take() else { return };
        let end = now_ns();
        let span = Span {
            name: p.name,
            cat: p.cat,
            track: p.track,
            start_ns: p.start_ns,
            dur_ns: end.saturating_sub(p.start_ns),
            group: p.group,
            interval: p.interval,
            shard: p.shard,
        };
        RECORDED.fetch_add(1, Ordering::Relaxed);
        BUF.with(|b| {
            let mut buf = b.borrow_mut();
            buf.0.push(span);
            if buf.0.len() >= TLS_FLUSH {
                flush_vec(&mut buf.0);
            }
        });
    }
}

// ---- export ----------------------------------------------------------------

/// Everything one session recorded.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub spans: Vec<Span>,
    /// Spans lost to the [`MAX_SPANS`] cap (0 in any sane run).
    pub dropped: u64,
}

impl Trace {
    /// Spans with a given name, in recorded order.
    pub fn named(&self, name: &str) -> Vec<Span> {
        self.spans.iter().filter(|s| s.name == name).copied().collect()
    }

    /// Chrome trace-event JSON: `ph:"X"` complete events (µs), one
    /// named thread lane per track, loadable in `chrome://tracing` or
    /// Perfetto. Span names/cats are crate-internal static identifiers,
    /// so no string escaping is needed.
    pub fn to_chrome_json(&self) -> String {
        let mut sorted = self.spans.clone();
        // Lane-major, then start time; ties broken longest-first so
        // enclosing spans precede their children in the event list.
        sorted.sort_by_key(|s| (s.track, s.start_ns, std::cmp::Reverse(s.dur_ns)));
        let mut tracks: Vec<u32> = sorted.iter().map(|s| s.track).collect();
        tracks.sort_unstable();
        tracks.dedup();
        let mut ev: Vec<String> = Vec::with_capacity(sorted.len() + tracks.len() + 1);
        ev.push(
            "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\
             \"args\":{\"name\":\"switchblade\"}}"
                .into(),
        );
        for t in &tracks {
            let lane = if *t == TRACK_MAIN {
                "main/prepare".to_string()
            } else if *t >= SERVE_TRACK_BASE {
                format!("serve entry {}", t - SERVE_TRACK_BASE)
            } else {
                format!("worker {}", t - 1)
            };
            ev.push(format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{t},\
                 \"args\":{{\"name\":\"{lane}\"}}}}"
            ));
        }
        for s in &sorted {
            let mut args = String::new();
            for (k, v) in [("group", s.group), ("interval", s.interval), ("shard", s.shard)] {
                if v >= 0 {
                    if !args.is_empty() {
                        args.push(',');
                    }
                    args.push_str(&format!("\"{k}\":{v}"));
                }
            }
            ev.push(format!(
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\
                 \"ts\":{:.3},\"dur\":{:.3},\"args\":{{{args}}}}}",
                s.name,
                s.cat,
                s.track,
                s.start_ns as f64 / 1e3,
                s.dur_ns as f64 / 1e3,
            ));
        }
        format!(
            "{{\"traceEvents\":[\n{}\n],\"displayTimeUnit\":\"ms\",\
             \"otherData\":{{\"dropped_spans\":{}}}}}\n",
            ev.join(",\n"),
            self.dropped
        )
    }

    /// Write [`Trace::to_chrome_json`] to `path`.
    pub fn write_chrome(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_chrome_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_guards_record_nothing() {
        // Hold the exclusive session so no concurrent test can record
        // while we sample the global counter; the spawned thread has no
        // TLS flag, so its guards take the disabled (no-allocation)
        // path and must not touch the counter.
        let sess = begin();
        let before = recorded_total();
        std::thread::scope(|s| {
            s.spawn(|| {
                assert!(!active());
                for _ in 0..64 {
                    let _g = span("idle", cat::EXEC, TRACK_MAIN);
                }
                let _h = span_if(false, "idle", cat::EXEC, TRACK_MAIN, 1, 2, 3);
            });
        });
        assert_eq!(recorded_total() - before, 0);
        assert!(sess.end().spans.is_empty());
    }

    #[test]
    fn serve_lanes_export_with_their_own_names() {
        let sess = begin();
        {
            let _m = span(names::COMPILE, cat::FRONTEND, TRACK_MAIN);
            let _b = span_if(true, names::BATCH, cat::SERVE, serve_track(0), -1, 0, 3);
            let _r = span_if(true, names::REQUEST, cat::SERVE, serve_track(1), -1, 5, -1);
        }
        let json = sess.end().to_chrome_json();
        assert!(json.contains("\"serve entry 0\""), "{json}");
        assert!(json.contains("\"serve entry 1\""), "{json}");
        assert!(json.contains("\"main/prepare\""), "{json}");
        assert!(!json.contains("\"worker 899\""), "{json}");
    }

    #[test]
    fn session_records_and_drains() {
        let sess = begin();
        assert!(active());
        {
            let _a = span_args(names::SCATTER, cat::WALK, TRACK_MAIN, 0, 1, -1);
            let _b = span_if(true, names::SHARD, cat::EXEC, worker_track(3), 0, 1, 7);
        }
        let tr = sess.end();
        assert!(!active());
        assert_eq!(tr.spans.len(), 2);
        assert_eq!(tr.dropped, 0);
        let shard = tr.named(names::SHARD)[0];
        assert_eq!(shard.track, worker_track(3));
        assert_eq!((shard.group, shard.interval, shard.shard), (0, 1, 7));
        // Inner span closed first, so both are fully formed.
        let scat = tr.named(names::SCATTER)[0];
        assert!(scat.end_ns() >= scat.start_ns);
    }

    #[test]
    fn reentrant_begin_borrows_not_steals() {
        let outer = begin();
        {
            let _x = span("outer_work", cat::EXEC, TRACK_MAIN);
        }
        let m = mark();
        let inner = begin(); // same thread: borrowed
        {
            let _y = span("inner_work", cat::EXEC, TRACK_MAIN);
        }
        let tail = since(m);
        assert_eq!(tail.len(), 1);
        assert_eq!(tail[0].name, "inner_work");
        let borrowed = inner.end();
        assert!(borrowed.spans.is_empty());
        assert!(active(), "borrowed end must not close the session");
        let tr = outer.end();
        assert_eq!(tr.spans.len(), 2, "outer keeps inner's spans too");
    }

    #[test]
    fn worker_thread_spans_flush_at_join() {
        let sess = begin();
        let on = active();
        std::thread::scope(|s| {
            for w in 0..2 {
                s.spawn(move || {
                    // Workers don't inherit the TLS flag...
                    assert!(!active());
                    // ...so they gate on the captured one.
                    let _g = span_if(on, names::SHARD, cat::EXEC, worker_track(w), 0, 0, w as i32);
                });
            }
        });
        let tr = sess.end();
        assert_eq!(tr.named(names::SHARD).len(), 2);
    }

    #[test]
    fn chrome_export_shape() {
        let sess = begin();
        {
            let _d = span_args(names::GATHER_DRAIN, cat::WALK, TRACK_MAIN, 0, 0, -1);
            let _p = span_args(names::PREPARE, cat::EXEC, TRACK_MAIN, 0, 1, -1);
        }
        {
            let _s = span_if(true, names::SHARD, cat::EXEC, worker_track(0), 0, 0, 2);
        }
        let tr = sess.end();
        let json = tr.to_chrome_json();
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"displayTimeUnit\":\"ms\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"name\":\"thread_name\""));
        assert!(json.contains("\"name\":\"main/prepare\""));
        assert!(json.contains("\"name\":\"worker 0\""));
        assert!(json.contains("\"name\":\"prepare\""));
        assert!(json.contains("\"shard\":2"));
        assert!(json.contains("\"dropped_spans\":0"));
        // Drain encloses prepare (constructed around it) — the overlap
        // predicate the pipelining acceptance test uses.
        let drain = tr.named(names::GATHER_DRAIN)[0];
        let prep = tr.named(names::PREPARE)[0];
        assert!(drain.contains(&prep));
    }

    #[test]
    fn sessions_serialize_across_threads() {
        // A second thread's begin() must block until the first session
        // ends, so concurrent tests cannot interleave their spans.
        let sess = begin();
        {
            let _a = span("first", cat::EXEC, TRACK_MAIN);
        }
        let handle = std::thread::spawn(|| {
            let s2 = begin();
            let _b = span("second", cat::EXEC, TRACK_MAIN);
            drop(_b);
            s2.end().spans.len()
        });
        // Give the spawned thread a chance to hit the condvar, then
        // release the session.
        std::thread::sleep(std::time::Duration::from_millis(10));
        let tr = sess.end();
        assert_eq!(tr.spans.len(), 1);
        assert_eq!(tr.spans[0].name, "first");
        assert_eq!(handle.join().unwrap(), 1);
    }
}
