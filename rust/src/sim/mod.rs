//! Cycle-level model of the SWITCHBLADE GNN accelerator (paper §V-B,
//! Fig 5): an instruction-driven platform with
//!
//! * a **VU** (16×SIMD32 cores) executing ELW + GTR instructions,
//! * an **MU** (32×128 output-stationary systolic array) executing DMM,
//! * an **LSU** + HBM channel model moving shards/intervals,
//! * a **controller** with one iThread and `num_sthreads` sThreads
//!   (shard-level multi-threading, §IV-C) driven by a phase scheduler
//!   implementing Alg 2.
//!
//! Timing style: discrete-event list scheduling at cycle resolution. Every
//! instruction reserves its functional unit for a modelled duration;
//! per-thread issue is in order; operands synchronise through
//! symbol-completion times; sThreads overlap through shared-unit
//! contention exactly as SMT hardware would (greedy arbitration). Shard
//! loads are prefetched (the paper's 1-bit flag): a shard's `LD`s may
//! overlap the previous shard's compute on the same thread.
//!
//! The simulator consumes the *same* compiled programs and partitions as
//! the functional executor — and, since both are visitors over
//! [`sched::PartitionWalk`](crate::sched), the *same* canonical Alg 2
//! traversal — so its timing cannot diverge structurally from the
//! validated semantics.

mod config;
mod cost;
mod dram;
mod engine;
mod stats;

pub use config::{AcceleratorConfig, DramConfig, HBM1, HBM2};
pub use cost::CostModel;
pub use dram::DramModel;
pub use engine::{simulate, simulate_traced};
pub use stats::{SimResult, Traffic, TrafficTag};

/// Test helper: a stable tag for cross-module unit tests.
pub fn stats_tag_for_tests() -> TrafficTag {
    TrafficTag::SrcVertex
}
