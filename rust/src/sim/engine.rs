//! The discrete-event SLMT engine (see module docs in `sim/mod.rs`).
//!
//! The engine is a [`PhaseVisitor`] over [`sched::PartitionWalk`] — the
//! same canonical Alg 2 traversal the functional executor drives through,
//! so the simulated order cannot drift from the executed one (the
//! scheduler tests pin this with walk-trace equivalence). Symbol
//! readiness times live in dense slot vectors (`Program::slot_layout`),
//! not per-instruction hash maps.

use crate::isa::{Dim, Instr, Program, SlotLayout, Space, Sym, Unit};
use crate::partition::{Partitions, Shard};
use crate::sched::{GroupCtx, PartitionWalk, PhaseVisitor, StepCtx, Traced, WalkStep};

use super::config::AcceleratorConfig;
use super::cost::{CostModel, ISSUE_OVERHEAD, PHASE_SWITCH};
use super::dram::DramModel;
use super::stats::{SimResult, TrafficTag};

/// "Not produced yet" marker in the readiness vectors: `max` with any
/// real timestamp erases it, matching the old hash-map-absent semantics.
const ABSENT: f64 = f64::NEG_INFINITY;

/// Simulate one compiled program over one partitioning.
pub fn simulate(program: &Program, parts: &Partitions, cfg: &AcceleratorConfig) -> SimResult {
    let mut e = Engine::new(cfg, program, parts);
    PartitionWalk::new(program, parts).drive(&mut e);
    e.finish(cfg)
}

/// Like [`simulate`], additionally recording the walker's `(group,
/// interval, shard, phase)` trace — compared against the executor's by
/// the scheduler order-equivalence tests.
pub fn simulate_traced(
    program: &Program,
    parts: &Partitions,
    cfg: &AcceleratorConfig,
) -> (SimResult, Vec<WalkStep>) {
    let mut e = Engine::new(cfg, program, parts);
    let mut tr = Traced::new(&mut e);
    PartitionWalk::new(program, parts).drive(&mut tr);
    let steps = tr.into_steps();
    (e.finish(cfg), steps)
}

struct Engine {
    cm: CostModel,
    dram: DramModel,
    vu_free: f64,
    mu_free: f64,
    vu_busy: f64,
    mu_busy: f64,
    now_max: f64,
    instructions: u64,
    shards: u64,
    intervals: u64,
    // ---- walk state (valid while a drive is in flight) ---------------------
    nthreads: usize,
    /// Group start time: the previous group's end (groups are barriers —
    /// apply stores feed the next group's loads through DRAM).
    t: f64,
    group_start: f64,
    /// The iThread is serial: scatter(i+1) waits for apply(i).
    ithread_free: f64,
    /// Per-sThread compute / load pipeline cursors. Intervals *pipeline*
    /// within a group (paper Fig 3): while the iThread applies interval
    /// i, the sThreads already stream interval i+1's shards (the
    /// DstBuffer double-buffers interval state). Since PR 5 this overlap
    /// is no longer simulation-only: the functional executor realises it
    /// as `exec::PipelineMode::Interval` (next-interval DstBuffer state
    /// prepared under the current interval's gather drain), so this
    /// timing model is the oracle for behaviour the executor actually
    /// has — not an aspiration.
    compute_free: Vec<f64>,
    load_free: Vec<f64>,
    group_end: f64,
    /// Earliest time this interval's shards may start computing.
    shard_gate: f64,
    /// Latest shard finish of the current interval.
    gather_done: f64,
    /// Per-interval D-symbol readiness, slot-indexed.
    d_ready: Vec<f64>,
    /// Per-shard S/E-symbol readiness, slot-indexed (reset per shard).
    s_ready: Vec<f64>,
    e_ready: Vec<f64>,
}

impl Engine {
    fn new(cfg: &AcceleratorConfig, program: &Program, parts: &Partitions) -> Self {
        let layout: SlotLayout = program.slot_layout();
        let nthreads = thread_count(parts);
        let mut e = Engine {
            cm: CostModel::new(cfg),
            dram: DramModel::new(cfg),
            vu_free: 0.0,
            mu_free: 0.0,
            vu_busy: 0.0,
            mu_busy: 0.0,
            now_max: 0.0,
            instructions: 0,
            shards: 0,
            intervals: 0,
            nthreads,
            t: 0.0,
            group_start: 0.0,
            ithread_free: 0.0,
            compute_free: vec![0.0; nthreads],
            load_free: vec![0.0; nthreads],
            group_end: 0.0,
            shard_gate: 0.0,
            gather_done: 0.0,
            d_ready: vec![ABSENT; layout.d],
            s_ready: vec![ABSENT; layout.s],
            e_ready: vec![ABSENT; layout.e],
        };
        // Weights load once and stay resident in the weight buffer.
        e.t = e
            .dram
            .transfer(0.0, program.weight_bytes(), TrafficTag::Weights);
        e
    }

    /// Run an interval-side (iThread) phase sequentially; returns finish time.
    fn run_ithread_phase(&mut self, instrs: &[Instr], start: f64, v: u64) -> f64 {
        let mut prev_issue = start;
        let mut finish = start;
        for i in instrs {
            self.instructions += 1;
            match i {
                Instr::Ld { sym, cols, .. } => {
                    let bytes = v * *cols as u64 * 4;
                    let t0 = prev_issue;
                    let done = self.dram.transfer(t0, bytes, TrafficTag::DstLoad);
                    self.d_ready[sym.id as usize] = done;
                    prev_issue = t0 + ISSUE_OVERHEAD;
                    finish = finish.max(done);
                }
                Instr::St { sym, cols, .. } => {
                    let bytes = v * *cols as u64 * 4;
                    // ABSENT folds away under max.
                    let t0 = prev_issue.max(self.d_ready[sym.id as usize]);
                    let done = self.dram.transfer(t0, bytes, TrafficTag::DstStore);
                    prev_issue = t0 + ISSUE_OVERHEAD;
                    finish = finish.max(done);
                }
                _ => {
                    let dur = self.cm.compute_cycles(i, rows_of(i, v, 0, 0));
                    let oper_ready = i
                        .uses()
                        .iter()
                        .fold(0.0f64, |a, s| a.max(self.interval_ready(*s)));
                    let (unit_free, busy) = self.unit_mut(i.unit());
                    let t0 = prev_issue.max(oper_ready).max(*unit_free);
                    *unit_free = t0 + dur;
                    *busy += dur;
                    if let Some(d) = i.def() {
                        self.d_ready[d.id as usize] = t0 + dur;
                    }
                    prev_issue = t0 + ISSUE_OVERHEAD;
                    finish = finish.max(t0 + dur);
                }
            }
        }
        self.now_max = self.now_max.max(finish);
        finish
    }

    /// Run one shard's GatherPhase on sThread `k`; returns finish time.
    fn run_shard(&mut self, instrs: &[Instr], shard: &Shard, v: u64, k: usize) -> f64 {
        let s_loaded = shard.loaded_sources as u64;
        let e_cnt = shard.num_edges() as u64;

        // Shard descriptor + COO metadata into the Graph Buffer. The SEB is
        // divided into `num_sthreads` slots (§V-B3): this thread's slot
        // frees when its *previous* shard's compute finishes, so loads
        // (the prefetch flag, §V-B4) may start then — with one sThread the
        // load→compute pipeline is fully serial (SLMT off), with more
        // threads loads overlap other threads' compute. That is the whole
        // Fig 10/11 mechanism.
        let meta_bytes = 4 * s_loaded + 8 * e_cnt + 16;
        let mut load_cursor = self.load_free[k].max(self.compute_free[k]);
        let meta_done = self.dram.transfer(load_cursor, meta_bytes, TrafficTag::Meta);
        self.s_ready.fill(ABSENT);
        self.e_ready.fill(ABSENT);

        // Compute may not start before the thread's previous shard compute
        // finished (SEB double-buffer swap) nor before the interval's
        // ScatterPhase produced the D data.
        let mut prev_issue = self.compute_free[k].max(self.shard_gate);
        let mut finish = meta_done;

        for i in instrs {
            self.instructions += 1;
            match i {
                Instr::Ld { sym, cols, .. } => {
                    let rows = match sym.space {
                        Space::S => s_loaded,
                        Space::E => e_cnt,
                        _ => unreachable!("gather LD of {sym}"),
                    };
                    let tag = if sym.space == Space::S {
                        TrafficTag::SrcVertex
                    } else {
                        TrafficTag::EdgeData
                    };
                    let bytes = rows * *cols as u64 * 4;
                    let t0 = load_cursor;
                    let done = self.dram.transfer(t0, bytes, tag);
                    self.set_shard_ready(*sym, done);
                    load_cursor = t0 + ISSUE_OVERHEAD;
                    self.load_free[k] = load_cursor;
                    finish = finish.max(done);
                }
                Instr::St { sym, cols, .. } => {
                    let bytes = e_cnt * *cols as u64 * 4;
                    // ABSENT folds away under max.
                    let t0 = prev_issue.max(self.shard_ready(*sym));
                    let done = self.dram.transfer(t0, bytes, TrafficTag::EdgeData);
                    prev_issue = t0 + ISSUE_OVERHEAD;
                    finish = finish.max(done);
                }
                _ => {
                    let rows = rows_of(i, v, s_loaded, e_cnt);
                    let dur = self.cm.compute_cycles(i, rows);
                    let oper_ready = i.uses().iter().fold(0.0f64, |a, s| {
                        a.max(match s.space {
                            Space::D => self.d_ready[s.id as usize],
                            Space::W => ABSENT,
                            _ => self.shard_ready(*s),
                        })
                    });
                    let (unit_free, busy) = self.unit_mut(i.unit());
                    let t0 = prev_issue.max(oper_ready).max(*unit_free);
                    *unit_free = t0 + dur;
                    *busy += dur;
                    let done = t0 + dur;
                    if let Some(d) = i.def() {
                        if d.space == Space::D {
                            // Gather accumulator: cross-shard RMW.
                            let ent = &mut self.d_ready[d.id as usize];
                            *ent = ent.max(done);
                        } else {
                            self.set_shard_ready(d, done);
                        }
                    }
                    prev_issue = t0 + ISSUE_OVERHEAD;
                    finish = finish.max(done);
                }
            }
        }
        self.compute_free[k] = finish + PHASE_SWITCH;
        self.now_max = self.now_max.max(finish);
        finish
    }

    /// Operand readiness in an iThread phase (D data; W is resident,
    /// S/E never appear interval-side — ABSENT folds away under max).
    fn interval_ready(&self, s: Sym) -> f64 {
        match s.space {
            Space::D => self.d_ready[s.id as usize],
            _ => ABSENT,
        }
    }

    fn shard_ready(&self, s: Sym) -> f64 {
        match s.space {
            Space::S => self.s_ready[s.id as usize],
            Space::E => self.e_ready[s.id as usize],
            _ => ABSENT,
        }
    }

    fn set_shard_ready(&mut self, s: Sym, done: f64) {
        match s.space {
            Space::S => self.s_ready[s.id as usize] = done,
            Space::E => self.e_ready[s.id as usize] = done,
            _ => unreachable!("shard-local ready for {s}"),
        }
    }

    fn unit_mut(&mut self, u: Unit) -> (&mut f64, &mut f64) {
        match u {
            Unit::Vu => (&mut self.vu_free, &mut self.vu_busy),
            Unit::Mu => (&mut self.mu_free, &mut self.mu_busy),
            Unit::Lsu => unreachable!("LSU instrs are priced by the DRAM model"),
        }
    }

    fn finish(self, cfg: &AcceleratorConfig) -> SimResult {
        let cycles = self
            .now_max
            .max(self.dram.busy_until())
            .max(self.vu_free)
            .max(self.mu_free);
        SimResult {
            cycles,
            seconds: cycles / cfg.freq_hz,
            vu_busy: self.vu_busy,
            mu_busy: self.mu_busy,
            dram_busy: self.dram.busy_cycles,
            traffic: self.dram.traffic,
            shards_processed: self.shards,
            intervals_processed: self.intervals,
            instructions: self.instructions,
        }
    }
}

impl PhaseVisitor for Engine {
    fn begin_group(&mut self, _cx: &GroupCtx) {
        self.group_start = self.t;
        self.ithread_free = self.group_start;
        self.compute_free.fill(self.group_start);
        self.load_free.fill(self.group_start);
        self.group_end = self.group_start;
    }

    fn begin_interval(&mut self, _cx: &StepCtx) {
        self.intervals += 1;
        self.d_ready.fill(ABSENT);
    }

    fn scatter_phase(&mut self, cx: &StepCtx) {
        let v = cx.interval.len() as u64;
        let scatter_done =
            self.run_ithread_phase(&cx.group.scatter, self.ithread_free + PHASE_SWITCH, v);
        if !cx.group.scatter.is_empty() {
            self.ithread_free = scatter_done;
        }
        // Shards gate on this interval's ScatterPhase only when it
        // produced data they read.
        self.shard_gate = if cx.group.scatter.is_empty() {
            self.group_start
        } else {
            scatter_done
        };
        self.gather_done = self.shard_gate;
    }

    fn gather_shard(&mut self, cx: &StepCtx, _shard_idx: usize, shard: &Shard) {
        self.shards += 1;
        // Dynamic assignment: next shard goes to the thread that frees
        // first (phase scheduler, §V-B2).
        let k = (0..self.nthreads)
            .min_by(|&a, &b| self.compute_free[a].total_cmp(&self.compute_free[b]))
            .unwrap();
        let v = cx.interval.len() as u64;
        let done = self.run_shard(&cx.group.gather, shard, v, k);
        self.gather_done = self.gather_done.max(done);
    }

    fn apply_phase(&mut self, cx: &StepCtx) {
        let v = cx.interval.len() as u64;
        let start = self.gather_done.max(self.ithread_free) + PHASE_SWITCH;
        let apply_done = self.run_ithread_phase(&cx.group.apply, start, v);
        self.ithread_free = apply_done;
        self.group_end = self.group_end.max(apply_done).max(self.gather_done);
        self.now_max = self.now_max.max(self.group_end);
    }

    fn end_group(&mut self, _cx: &GroupCtx) {
        self.t = self.group_end;
    }
}

/// Decode an instruction's row count against the current context.
fn rows_of(i: &Instr, v: u64, s: u64, e: u64) -> u64 {
    let dim = match i {
        Instr::Elw { rows, .. }
        | Instr::RowScale { rows, .. }
        | Instr::Concat { rows, .. }
        | Instr::Dmm { rows, .. } => *rows,
        Instr::Scatter { .. } | Instr::Gather { .. } | Instr::FusedGather { .. } => Dim::E,
        Instr::Ld { rows, .. } | Instr::St { rows, .. } => *rows,
    };
    dim.decode(v as usize, s as usize, e as usize) as u64
}

/// sThread count is a property of the partitioning run (Equ. 1 divides the
/// SEB by it); the engine re-derives it from the configured budget.
fn thread_count(parts: &Partitions) -> usize {
    // The harness partitions with shard_bytes = SEB / num_sthreads, so the
    // count is carried alongside in the config; default to 3 when absent.
    parts.config.num_sthreads.max(1) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::graph::{generators, Csr};
    use crate::ir::models::Model;
    use crate::partition::{partition_dsw, partition_fggp};
    use crate::sim::AcceleratorConfig;

    fn sim_model(
        model: Model,
        cfg: &AcceleratorConfig,
        fggp: bool,
        seed: u64,
    ) -> SimResult {
        let ir = model.build(2, 128, 128, 128);
        let p = compile(&ir);
        let g = Csr::from_edge_list(&generators::rmat(1 << 11, 16_000, 0.57, 0.19, 0.19, seed));
        let mut pc = cfg.partition_config(&p);
        pc.num_sthreads = cfg.num_sthreads;
        let parts = if fggp {
            partition_fggp(&g, pc)
        } else {
            partition_dsw(&g, pc)
        };
        simulate(&p, &parts, cfg)
    }

    #[test]
    fn produces_sane_timing() {
        let cfg = AcceleratorConfig::switchblade();
        let r = sim_model(Model::Gcn, &cfg, true, 1);
        assert!(r.cycles > 0.0);
        assert!(r.vu_busy > 0.0 && r.mu_busy > 0.0 && r.dram_busy > 0.0);
        assert!(r.vu_busy <= r.cycles + 1.0);
        assert!(r.traffic.total() > 0);
        assert!(r.shards_processed > 0);
    }

    #[test]
    fn utilizations_bounded() {
        let cfg = AcceleratorConfig::switchblade();
        for m in Model::ALL {
            let r = sim_model(m, &cfg, true, 2);
            for u in [
                r.vu_utilization(),
                r.mu_utilization(),
                r.bw_utilization(),
                r.overall_utilization(),
            ] {
                assert!((0.0..=1.0).contains(&u), "{}: {u}", m.name());
            }
        }
    }

    #[test]
    fn slmt_improves_latency_and_utilization() {
        // Fig 10/11's first-order claim: 3 sThreads beat 1.
        let base = AcceleratorConfig::switchblade();
        let r1 = sim_model(Model::Gat, &base.with_sthreads(1), true, 3);
        let r3 = sim_model(Model::Gat, &base.with_sthreads(3), true, 3);
        assert!(
            r3.cycles < r1.cycles,
            "3 sThreads {} !< 1 sThread {}",
            r3.cycles,
            r1.cycles
        );
        assert!(r3.overall_utilization() > r1.overall_utilization());
    }

    #[test]
    fn fggp_moves_less_data_than_dsw() {
        let cfg = AcceleratorConfig::switchblade();
        let rf = sim_model(Model::Gcn, &cfg, true, 4);
        let rd = sim_model(Model::Gcn, &cfg, false, 4);
        assert!(rf.traffic.total() < rd.traffic.total());
        assert!(rf.cycles <= rd.cycles * 1.05);
    }

    #[test]
    fn deterministic() {
        let cfg = AcceleratorConfig::switchblade();
        let a = sim_model(Model::Sage, &cfg, true, 5);
        let b = sim_model(Model::Sage, &cfg, true, 5);
        assert_eq!(a.cycles.to_bits(), b.cycles.to_bits());
        assert_eq!(a.traffic.total(), b.traffic.total());
    }

    #[test]
    fn traced_run_matches_untraced() {
        let cfg = AcceleratorConfig::switchblade();
        let ir = Model::Gcn.build(2, 32, 32, 32);
        let p = compile(&ir);
        let g = Csr::from_edge_list(&generators::rmat(1 << 9, 3_000, 0.57, 0.19, 0.19, 6));
        let mut pc = cfg.partition_config(&p);
        pc.num_sthreads = cfg.num_sthreads;
        let parts = partition_fggp(&g, pc);
        let plain = simulate(&p, &parts, &cfg);
        let (traced, steps) = simulate_traced(&p, &parts, &cfg);
        assert_eq!(plain.cycles.to_bits(), traced.cycles.to_bits());
        assert_eq!(steps, crate::sched::canonical_trace(&p, &parts));
    }

    #[test]
    fn empty_program_costs_only_weights() {
        let mut ir = crate::ir::IrGraph::new("empty");
        let x = ir.input(4);
        let w = ir.weight(4, 4, 1, "w");
        let z = ir.dmm(x, w, "z");
        ir.set_output(z);
        let p = compile(&ir);
        let g = Csr::from_edge_list(&generators::mesh2d(4, 4, false));
        let cfg = AcceleratorConfig::switchblade();
        let mut pc = cfg.partition_config(&p);
        pc.num_sthreads = cfg.num_sthreads;
        let parts = partition_fggp(&g, pc);
        let r = simulate(&p, &parts, &cfg);
        assert!(r.cycles > 0.0);
        assert!(r.traffic.get(TrafficTag::Weights) > 0);
    }
}
