//! The discrete-event SLMT engine (see module docs in `sim/mod.rs`).

use std::collections::HashMap;

use crate::isa::{Dim, Instr, Program, Space, Sym, Unit};
use crate::partition::{Partitions, Shard};

use super::config::AcceleratorConfig;
use super::cost::{CostModel, ISSUE_OVERHEAD, PHASE_SWITCH};
use super::dram::DramModel;
use super::stats::{SimResult, TrafficTag};

/// Simulate one compiled program over one partitioning.
pub fn simulate(program: &Program, parts: &Partitions, cfg: &AcceleratorConfig) -> SimResult {
    let mut e = Engine::new(cfg);
    e.run(program, parts);
    e.finish(cfg)
}

struct Engine {
    cm: CostModel,
    dram: DramModel,
    vu_free: f64,
    mu_free: f64,
    vu_busy: f64,
    mu_busy: f64,
    now_max: f64,
    instructions: u64,
    shards: u64,
    intervals: u64,
}

impl Engine {
    fn new(cfg: &AcceleratorConfig) -> Self {
        Engine {
            cm: CostModel::new(cfg),
            dram: DramModel::new(cfg),
            vu_free: 0.0,
            mu_free: 0.0,
            vu_busy: 0.0,
            mu_busy: 0.0,
            now_max: 0.0,
            instructions: 0,
            shards: 0,
            intervals: 0,
        }
    }

    fn run(&mut self, program: &Program, parts: &Partitions) {
        // Weights load once and stay resident in the weight buffer.
        let mut t = self
            .dram
            .transfer(0.0, program.weight_bytes(), TrafficTag::Weights);

        let nthreads = thread_count(parts);
        for group in &program.groups {
            // Intervals *pipeline* within a group (paper Fig 3): while the
            // iThread applies interval i, the sThreads already stream
            // interval i+1's shards (the DstBuffer double-buffers interval
            // state). The iThread itself is serial: scatter(i+1) waits for
            // apply(i). Groups are barriers (apply stores feed the next
            // group's loads through DRAM).
            let group_start = t;
            let mut ithread_free = group_start;
            let mut compute_free = vec![group_start; nthreads];
            let mut load_free = vec![group_start; nthreads];
            let mut group_end = group_start;
            for (ii, iv) in parts.intervals.iter().enumerate() {
                self.intervals += 1;
                let v = iv.len() as u64;

                // ---- ScatterPhase (iThread) --------------------------------
                let mut d_ready: HashMap<Sym, f64> = HashMap::new();
                let scatter_done = self.run_ithread_phase(
                    &group.scatter,
                    ithread_free + PHASE_SWITCH,
                    v,
                    &mut d_ready,
                );
                if !group.scatter.is_empty() {
                    ithread_free = scatter_done;
                }
                // Shards gate on this interval's ScatterPhase only when it
                // produced data they read.
                let shard_gate = if group.scatter.is_empty() {
                    group_start
                } else {
                    scatter_done
                };

                // ---- GatherPhase (sThreads over shards) --------------------
                let mut gather_done = shard_gate;
                for shard in parts.shards_of(ii) {
                    self.shards += 1;
                    // Dynamic assignment: next shard goes to the thread
                    // that frees first (phase scheduler, §V-B2).
                    let k = (0..nthreads)
                        .min_by(|&a, &b| compute_free[a].total_cmp(&compute_free[b]))
                        .unwrap();
                    let done = self.run_shard(
                        &group.gather,
                        shard,
                        v,
                        shard_gate,
                        &mut load_free[k],
                        &mut compute_free[k],
                        &mut d_ready,
                    );
                    gather_done = gather_done.max(done);
                }

                // ---- ApplyPhase (iThread) ----------------------------------
                let apply_done = self.run_ithread_phase(
                    &group.apply,
                    gather_done.max(ithread_free) + PHASE_SWITCH,
                    v,
                    &mut d_ready,
                );
                ithread_free = apply_done;
                group_end = group_end.max(apply_done).max(gather_done);
                self.now_max = self.now_max.max(group_end);
            }
            t = group_end;
        }
    }

    /// Run an interval-side (iThread) phase sequentially; returns finish time.
    fn run_ithread_phase(
        &mut self,
        instrs: &[Instr],
        start: f64,
        v: u64,
        d_ready: &mut HashMap<Sym, f64>,
    ) -> f64 {
        let mut prev_issue = start;
        let mut finish = start;
        for i in instrs {
            self.instructions += 1;
            match i {
                Instr::Ld { sym, cols, .. } => {
                    let bytes = v * *cols as u64 * 4;
                    let t0 = prev_issue;
                    let done = self.dram.transfer(t0, bytes, TrafficTag::DstLoad);
                    d_ready.insert(*sym, done);
                    prev_issue = t0 + ISSUE_OVERHEAD;
                    finish = finish.max(done);
                }
                Instr::St { sym, cols, .. } => {
                    let bytes = v * *cols as u64 * 4;
                    let ready = d_ready.get(sym).copied().unwrap_or(prev_issue);
                    let t0 = prev_issue.max(ready);
                    let done = self.dram.transfer(t0, bytes, TrafficTag::DstStore);
                    prev_issue = t0 + ISSUE_OVERHEAD;
                    finish = finish.max(done);
                }
                _ => {
                    let dur = self.cm.compute_cycles(i, rows_of(i, v, 0, 0));
                    let oper_ready = i
                        .uses()
                        .iter()
                        .filter_map(|s| d_ready.get(s))
                        .fold(0.0f64, |a, &b| a.max(b));
                    let (unit_free, busy) = self.unit_mut(i.unit());
                    let t0 = prev_issue.max(oper_ready).max(*unit_free);
                    *unit_free = t0 + dur;
                    *busy += dur;
                    if let Some(d) = i.def() {
                        d_ready.insert(d, t0 + dur);
                    }
                    prev_issue = t0 + ISSUE_OVERHEAD;
                    finish = finish.max(t0 + dur);
                }
            }
        }
        self.now_max = self.now_max.max(finish);
        finish
    }

    /// Run one shard's GatherPhase on an sThread; returns finish time.
    #[allow(clippy::too_many_arguments)]
    fn run_shard(
        &mut self,
        instrs: &[Instr],
        shard: &Shard,
        v: u64,
        scatter_done: f64,
        load_free: &mut f64,
        compute_free: &mut f64,
        d_ready: &mut HashMap<Sym, f64>,
    ) -> f64 {
        let s_loaded = shard.loaded_sources as u64;
        let s_used = shard.num_src() as u64;
        let e = shard.num_edges() as u64;
        let _ = s_used;

        // Shard descriptor + COO metadata into the Graph Buffer. The SEB is
        // divided into `num_sthreads` slots (§V-B3): this thread's slot
        // frees when its *previous* shard's compute finishes, so loads
        // (the prefetch flag, §V-B4) may start then — with one sThread the
        // load→compute pipeline is fully serial (SLMT off), with more
        // threads loads overlap other threads' compute. That is the whole
        // Fig 10/11 mechanism.
        let meta_bytes = 4 * s_loaded + 8 * e + 16;
        let mut load_cursor = load_free.max(*compute_free);
        let meta_done = self
            .dram
            .transfer(load_cursor, meta_bytes, TrafficTag::Meta);
        let mut local_ready: HashMap<Sym, f64> = HashMap::new();

        // Compute may not start before the thread's previous shard compute
        // finished (SEB double-buffer swap) nor before the interval's
        // ScatterPhase produced the D data.
        let mut prev_issue = compute_free.max(scatter_done);
        let mut finish = meta_done;

        for i in instrs {
            self.instructions += 1;
            match i {
                Instr::Ld { sym, cols, .. } => {
                    let rows = match sym.space {
                        Space::S => s_loaded,
                        Space::E => e,
                        _ => unreachable!("gather LD of {sym}"),
                    };
                    let tag = if sym.space == Space::S {
                        TrafficTag::SrcVertex
                    } else {
                        TrafficTag::EdgeData
                    };
                    let bytes = rows * *cols as u64 * 4;
                    let t0 = load_cursor;
                    let done = self.dram.transfer(t0, bytes, tag);
                    local_ready.insert(*sym, done);
                    load_cursor = t0 + ISSUE_OVERHEAD;
                    *load_free = load_cursor;
                    finish = finish.max(done);
                }
                Instr::St { sym, cols, .. } => {
                    let bytes = e * *cols as u64 * 4;
                    let ready = local_ready.get(sym).copied().unwrap_or(prev_issue);
                    let t0 = prev_issue.max(ready);
                    let done = self.dram.transfer(t0, bytes, TrafficTag::EdgeData);
                    prev_issue = t0 + ISSUE_OVERHEAD;
                    finish = finish.max(done);
                }
                _ => {
                    let rows = rows_of(i, v, s_loaded, e);
                    let dur = self.cm.compute_cycles(i, rows);
                    let oper_ready = i
                        .uses()
                        .iter()
                        .filter_map(|s| match s.space {
                            Space::D => d_ready.get(s),
                            Space::W => None,
                            _ => local_ready.get(s),
                        })
                        .fold(0.0f64, |a, &b| a.max(b));
                    let (unit_free, busy) = self.unit_mut(i.unit());
                    let t0 = prev_issue.max(oper_ready).max(*unit_free);
                    *unit_free = t0 + dur;
                    *busy += dur;
                    let done = t0 + dur;
                    if let Some(d) = i.def() {
                        if d.space == Space::D {
                            // Gather accumulator: cross-shard RMW.
                            let ent = d_ready.entry(d).or_insert(done);
                            *ent = ent.max(done);
                        } else {
                            local_ready.insert(d, done);
                        }
                    }
                    prev_issue = t0 + ISSUE_OVERHEAD;
                    finish = finish.max(done);
                }
            }
        }
        *compute_free = finish + PHASE_SWITCH;
        self.now_max = self.now_max.max(finish);
        finish
    }

    fn unit_mut(&mut self, u: Unit) -> (&mut f64, &mut f64) {
        match u {
            Unit::Vu => (&mut self.vu_free, &mut self.vu_busy),
            Unit::Mu => (&mut self.mu_free, &mut self.mu_busy),
            Unit::Lsu => unreachable!("LSU instrs are priced by the DRAM model"),
        }
    }

    fn finish(self, cfg: &AcceleratorConfig) -> SimResult {
        let cycles = self
            .now_max
            .max(self.dram.busy_until())
            .max(self.vu_free)
            .max(self.mu_free);
        SimResult {
            cycles,
            seconds: cycles / cfg.freq_hz,
            vu_busy: self.vu_busy,
            mu_busy: self.mu_busy,
            dram_busy: self.dram.busy_cycles,
            traffic: self.dram.traffic,
            shards_processed: self.shards,
            intervals_processed: self.intervals,
            instructions: self.instructions,
        }
    }
}

/// Decode an instruction's row count against the current context.
fn rows_of(i: &Instr, v: u64, s: u64, e: u64) -> u64 {
    let dim = match i {
        Instr::Elw { rows, .. }
        | Instr::RowScale { rows, .. }
        | Instr::Concat { rows, .. }
        | Instr::Dmm { rows, .. } => *rows,
        Instr::Scatter { .. } | Instr::Gather { .. } | Instr::FusedGather { .. } => Dim::E,
        Instr::Ld { rows, .. } | Instr::St { rows, .. } => *rows,
    };
    dim.decode(v as usize, s as usize, e as usize) as u64
}

/// sThread count is a property of the partitioning run (Equ. 1 divides the
/// SEB by it); the engine re-derives it from the configured budget.
fn thread_count(parts: &Partitions) -> usize {
    // The harness partitions with shard_bytes = SEB / num_sthreads, so the
    // count is carried alongside in the config; default to 3 when absent.
    parts.config.num_sthreads.max(1) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::graph::{generators, Csr};
    use crate::ir::models::Model;
    use crate::partition::{partition_dsw, partition_fggp};
    use crate::sim::AcceleratorConfig;

    fn sim_model(
        model: Model,
        cfg: &AcceleratorConfig,
        fggp: bool,
        seed: u64,
    ) -> SimResult {
        let ir = model.build(2, 128, 128, 128);
        let p = compile(&ir);
        let g = Csr::from_edge_list(&generators::rmat(1 << 11, 16_000, 0.57, 0.19, 0.19, seed));
        let mut pc = cfg.partition_config(&p);
        pc.num_sthreads = cfg.num_sthreads;
        let parts = if fggp {
            partition_fggp(&g, pc)
        } else {
            partition_dsw(&g, pc)
        };
        simulate(&p, &parts, cfg)
    }

    #[test]
    fn produces_sane_timing() {
        let cfg = AcceleratorConfig::switchblade();
        let r = sim_model(Model::Gcn, &cfg, true, 1);
        assert!(r.cycles > 0.0);
        assert!(r.vu_busy > 0.0 && r.mu_busy > 0.0 && r.dram_busy > 0.0);
        assert!(r.vu_busy <= r.cycles + 1.0);
        assert!(r.traffic.total() > 0);
        assert!(r.shards_processed > 0);
    }

    #[test]
    fn utilizations_bounded() {
        let cfg = AcceleratorConfig::switchblade();
        for m in Model::ALL {
            let r = sim_model(m, &cfg, true, 2);
            for u in [
                r.vu_utilization(),
                r.mu_utilization(),
                r.bw_utilization(),
                r.overall_utilization(),
            ] {
                assert!((0.0..=1.0).contains(&u), "{}: {u}", m.name());
            }
        }
    }

    #[test]
    fn slmt_improves_latency_and_utilization() {
        // Fig 10/11's first-order claim: 3 sThreads beat 1.
        let base = AcceleratorConfig::switchblade();
        let r1 = sim_model(Model::Gat, &base.with_sthreads(1), true, 3);
        let r3 = sim_model(Model::Gat, &base.with_sthreads(3), true, 3);
        assert!(
            r3.cycles < r1.cycles,
            "3 sThreads {} !< 1 sThread {}",
            r3.cycles,
            r1.cycles
        );
        assert!(r3.overall_utilization() > r1.overall_utilization());
    }

    #[test]
    fn fggp_moves_less_data_than_dsw() {
        let cfg = AcceleratorConfig::switchblade();
        let rf = sim_model(Model::Gcn, &cfg, true, 4);
        let rd = sim_model(Model::Gcn, &cfg, false, 4);
        assert!(rf.traffic.total() < rd.traffic.total());
        assert!(rf.cycles <= rd.cycles * 1.05);
    }

    #[test]
    fn deterministic() {
        let cfg = AcceleratorConfig::switchblade();
        let a = sim_model(Model::Sage, &cfg, true, 5);
        let b = sim_model(Model::Sage, &cfg, true, 5);
        assert_eq!(a.cycles.to_bits(), b.cycles.to_bits());
        assert_eq!(a.traffic.total(), b.traffic.total());
    }

    #[test]
    fn empty_program_costs_only_weights() {
        let mut ir = crate::ir::IrGraph::new("empty");
        let x = ir.input(4);
        let w = ir.weight(4, 4, 1, "w");
        let z = ir.dmm(x, w, "z");
        ir.set_output(z);
        let p = compile(&ir);
        let g = Csr::from_edge_list(&generators::mesh2d(4, 4, false));
        let cfg = AcceleratorConfig::switchblade();
        let mut pc = cfg.partition_config(&p);
        pc.num_sthreads = cfg.num_sthreads;
        let parts = partition_fggp(&g, pc);
        let r = simulate(&p, &parts, &cfg);
        assert!(r.cycles > 0.0);
        assert!(r.traffic.get(TrafficTag::Weights) > 0);
    }
}
