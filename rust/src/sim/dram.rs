//! HBM channel model — the Ramulator substitute (DESIGN.md §3).
//!
//! A single aggregate channel with fixed first-word latency and
//! bandwidth-limited serialisation: a request issued at `t` for `b` bytes
//! completes at `max(t, busy) + latency + b / bytes_per_cycle`, and the
//! channel is busy until completion minus the latency overlap (requests
//! pipeline: the next transfer's data phase starts when the previous data
//! phase ends). Row-policy effects are second-order for the streaming
//! access patterns DSW produces and are folded into the latency constant.

use super::config::AcceleratorConfig;
use super::stats::{Traffic, TrafficTag};

/// Stateful DRAM channel.
#[derive(Clone, Debug)]
pub struct DramModel {
    bytes_per_cycle: f64,
    latency: f64,
    /// When the data bus frees.
    busy_until: f64,
    /// Busy-cycle accumulator (bandwidth utilisation numerator).
    pub busy_cycles: f64,
    pub traffic: Traffic,
}

impl DramModel {
    pub fn new(cfg: &AcceleratorConfig) -> Self {
        DramModel {
            bytes_per_cycle: cfg.dram_bytes_per_cycle(),
            latency: cfg.dram_latency_cycles(),
            busy_until: 0.0,
            busy_cycles: 0.0,
            traffic: Traffic::default(),
        }
    }

    /// Issue a transfer of `bytes` at time `t` (cycles); returns the
    /// completion time.
    pub fn transfer(&mut self, t: f64, bytes: u64, tag: TrafficTag) -> f64 {
        self.traffic.add(tag, bytes);
        if bytes == 0 {
            return t;
        }
        let data_cycles = bytes as f64 / self.bytes_per_cycle;
        let data_start = t.max(self.busy_until);
        self.busy_until = data_start + data_cycles;
        self.busy_cycles += data_cycles;
        // First-word latency overlaps the queueing delay only partially:
        // completion = data end + latency for the initial access.
        data_start + data_cycles + self.latency
    }

    /// Earliest time the bus frees (for utilisation snapshots).
    pub fn busy_until(&self) -> f64 {
        self.busy_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::AcceleratorConfig;

    #[test]
    fn serialises_back_to_back() {
        let cfg = AcceleratorConfig::switchblade();
        let mut d = DramModel::new(&cfg);
        // 256 B/cycle: 2560 bytes = 10 cycles of bus time + 100 latency.
        let t1 = d.transfer(0.0, 2560, TrafficTag::SrcVertex);
        assert!((t1 - 110.0).abs() < 1e-9);
        // Second request issued at t=0 queues behind the first data phase.
        let t2 = d.transfer(0.0, 2560, TrafficTag::SrcVertex);
        assert!((t2 - 120.0).abs() < 1e-9);
        assert_eq!(d.traffic.get(TrafficTag::SrcVertex), 5120);
        assert!((d.busy_cycles - 20.0).abs() < 1e-9);
    }

    #[test]
    fn idle_gap_not_counted_busy() {
        let cfg = AcceleratorConfig::switchblade();
        let mut d = DramModel::new(&cfg);
        d.transfer(0.0, 256, TrafficTag::Weights);
        d.transfer(1000.0, 256, TrafficTag::Weights);
        assert!((d.busy_cycles - 2.0).abs() < 1e-9);
    }

    #[test]
    fn zero_bytes_is_free() {
        let cfg = AcceleratorConfig::switchblade();
        let mut d = DramModel::new(&cfg);
        assert_eq!(d.transfer(5.0, 0, TrafficTag::Meta), 5.0);
    }
}
