//! Simulation statistics: traffic breakdown, utilisation, and the final
//! result record every figure harness consumes.

/// Off-chip traffic categories (Fig 9 / Fig 13 accounting).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TrafficTag {
    /// Model weights (loaded once, resident).
    Weights,
    /// Source-vertex rows streamed into SrcEdgeBuffers.
    SrcVertex,
    /// Edge-feature rows (LD.E / ST.E spills).
    EdgeData,
    /// Destination-interval rows loaded (LD.D).
    DstLoad,
    /// Destination-interval rows stored (ST.D).
    DstStore,
    /// Graph-structure metadata (COO lists, shard descriptors).
    Meta,
}

impl TrafficTag {
    pub const ALL: [TrafficTag; 6] = [
        TrafficTag::Weights,
        TrafficTag::SrcVertex,
        TrafficTag::EdgeData,
        TrafficTag::DstLoad,
        TrafficTag::DstStore,
        TrafficTag::Meta,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            TrafficTag::Weights => "weights",
            TrafficTag::SrcVertex => "src",
            TrafficTag::EdgeData => "edge",
            TrafficTag::DstLoad => "dst_ld",
            TrafficTag::DstStore => "dst_st",
            TrafficTag::Meta => "meta",
        }
    }
}

/// Byte counters per category.
#[derive(Clone, Copy, Debug, Default)]
pub struct Traffic {
    counts: [u64; 6],
}

impl Traffic {
    fn idx(tag: TrafficTag) -> usize {
        TrafficTag::ALL.iter().position(|&t| t == tag).unwrap()
    }

    pub fn add(&mut self, tag: TrafficTag, bytes: u64) {
        self.counts[Self::idx(tag)] += bytes;
    }

    pub fn get(&self, tag: TrafficTag) -> u64 {
        self.counts[Self::idx(tag)]
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// `(tag, bytes)` in [`TrafficTag::ALL`] order — the one enumeration
    /// the metrics registry and the per-tag report rows share.
    pub fn iter(&self) -> impl Iterator<Item = (TrafficTag, u64)> + '_ {
        TrafficTag::ALL.iter().map(|&t| (t, self.get(t)))
    }
}

/// One simulation outcome.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Total execution time in cycles (and seconds at the configured clock).
    pub cycles: f64,
    pub seconds: f64,
    /// Busy cycles per functional unit.
    pub vu_busy: f64,
    pub mu_busy: f64,
    pub dram_busy: f64,
    /// Off-chip traffic breakdown.
    pub traffic: Traffic,
    /// Shards processed (across all groups).
    pub shards_processed: u64,
    /// Intervals processed (across all groups).
    pub intervals_processed: u64,
    /// Instructions issued.
    pub instructions: u64,
}

impl SimResult {
    pub fn vu_utilization(&self) -> f64 {
        (self.vu_busy / self.cycles.max(1.0)).min(1.0)
    }

    pub fn mu_utilization(&self) -> f64 {
        (self.mu_busy / self.cycles.max(1.0)).min(1.0)
    }

    pub fn bw_utilization(&self) -> f64 {
        (self.dram_busy / self.cycles.max(1.0)).min(1.0)
    }

    /// Paper Fig 10 metric: mean of DRAM-bandwidth, VU and MU utilisation.
    pub fn overall_utilization(&self) -> f64 {
        (self.vu_utilization() + self.mu_utilization() + self.bw_utilization()) / 3.0
    }

    /// Publish this result into the process metrics registry under
    /// `sim_*` names — the single place the simulator's utilizations and
    /// per-tag traffic become metrics, so `simulate`, `repro` and bench
    /// trailers stop computing them independently.
    pub fn record_metrics(&self) {
        use crate::obs::metrics;
        metrics::gauge("sim_cycles", self.cycles);
        metrics::gauge("sim_latency_s", self.seconds);
        metrics::gauge("sim_vu_utilization", self.vu_utilization());
        metrics::gauge("sim_mu_utilization", self.mu_utilization());
        metrics::gauge("sim_bw_utilization", self.bw_utilization());
        metrics::gauge("sim_overall_utilization", self.overall_utilization());
        metrics::counter_abs("sim_traffic_bytes_total", self.traffic.total());
        for (tag, bytes) in self.traffic.iter() {
            metrics::counter_abs(&format!("sim_traffic_bytes_{}", tag.name()), bytes);
        }
        metrics::counter_abs("sim_shards_processed", self.shards_processed);
        metrics::counter_abs("sim_intervals_processed", self.intervals_processed);
        metrics::counter_abs("sim_instructions", self.instructions);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_accumulates() {
        let mut t = Traffic::default();
        t.add(TrafficTag::SrcVertex, 100);
        t.add(TrafficTag::SrcVertex, 50);
        t.add(TrafficTag::Meta, 8);
        assert_eq!(t.get(TrafficTag::SrcVertex), 150);
        assert_eq!(t.total(), 158);
    }

    #[test]
    fn record_metrics_publishes_sim_names() {
        // The only test in this process recording `sim_*` names (the
        // registry is global; see obs::metrics docs).
        let mut traffic = Traffic::default();
        traffic.add(TrafficTag::SrcVertex, 640);
        traffic.add(TrafficTag::Meta, 64);
        let r = SimResult {
            cycles: 200.0,
            seconds: 2e-7,
            vu_busy: 100.0,
            mu_busy: 50.0,
            dram_busy: 50.0,
            traffic,
            shards_processed: 4,
            intervals_processed: 2,
            instructions: 99,
        };
        r.record_metrics();
        let s = crate::obs::metrics::snapshot();
        assert_eq!(s.value("sim_vu_utilization"), Some(0.5));
        assert_eq!(s.value("sim_overall_utilization"), Some(r.overall_utilization()));
        assert_eq!(s.value("sim_traffic_bytes_src"), Some(640.0));
        assert_eq!(s.value("sim_traffic_bytes_meta"), Some(64.0));
        assert_eq!(s.value("sim_traffic_bytes_total"), Some(704.0));
        assert_eq!(s.value("sim_traffic_bytes_edge"), Some(0.0));
        assert_eq!(s.value("sim_instructions"), Some(99.0));
    }

    #[test]
    fn utilization_bounds() {
        let r = SimResult {
            cycles: 100.0,
            seconds: 1e-7,
            vu_busy: 50.0,
            mu_busy: 100.0,
            dram_busy: 25.0,
            traffic: Traffic::default(),
            shards_processed: 1,
            intervals_processed: 1,
            instructions: 10,
        };
        assert!((r.vu_utilization() - 0.5).abs() < 1e-12);
        assert!((r.overall_utilization() - (0.5 + 1.0 + 0.25) / 3.0).abs() < 1e-12);
    }
}
