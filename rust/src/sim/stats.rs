//! Simulation statistics: traffic breakdown, utilisation, and the final
//! result record every figure harness consumes.

/// Off-chip traffic categories (Fig 9 / Fig 13 accounting).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TrafficTag {
    /// Model weights (loaded once, resident).
    Weights,
    /// Source-vertex rows streamed into SrcEdgeBuffers.
    SrcVertex,
    /// Edge-feature rows (LD.E / ST.E spills).
    EdgeData,
    /// Destination-interval rows loaded (LD.D).
    DstLoad,
    /// Destination-interval rows stored (ST.D).
    DstStore,
    /// Graph-structure metadata (COO lists, shard descriptors).
    Meta,
}

impl TrafficTag {
    pub const ALL: [TrafficTag; 6] = [
        TrafficTag::Weights,
        TrafficTag::SrcVertex,
        TrafficTag::EdgeData,
        TrafficTag::DstLoad,
        TrafficTag::DstStore,
        TrafficTag::Meta,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            TrafficTag::Weights => "weights",
            TrafficTag::SrcVertex => "src",
            TrafficTag::EdgeData => "edge",
            TrafficTag::DstLoad => "dst_ld",
            TrafficTag::DstStore => "dst_st",
            TrafficTag::Meta => "meta",
        }
    }
}

/// Byte counters per category.
#[derive(Clone, Copy, Debug, Default)]
pub struct Traffic {
    counts: [u64; 6],
}

impl Traffic {
    fn idx(tag: TrafficTag) -> usize {
        TrafficTag::ALL.iter().position(|&t| t == tag).unwrap()
    }

    pub fn add(&mut self, tag: TrafficTag, bytes: u64) {
        self.counts[Self::idx(tag)] += bytes;
    }

    pub fn get(&self, tag: TrafficTag) -> u64 {
        self.counts[Self::idx(tag)]
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
}

/// One simulation outcome.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Total execution time in cycles (and seconds at the configured clock).
    pub cycles: f64,
    pub seconds: f64,
    /// Busy cycles per functional unit.
    pub vu_busy: f64,
    pub mu_busy: f64,
    pub dram_busy: f64,
    /// Off-chip traffic breakdown.
    pub traffic: Traffic,
    /// Shards processed (across all groups).
    pub shards_processed: u64,
    /// Intervals processed (across all groups).
    pub intervals_processed: u64,
    /// Instructions issued.
    pub instructions: u64,
}

impl SimResult {
    pub fn vu_utilization(&self) -> f64 {
        (self.vu_busy / self.cycles.max(1.0)).min(1.0)
    }

    pub fn mu_utilization(&self) -> f64 {
        (self.mu_busy / self.cycles.max(1.0)).min(1.0)
    }

    pub fn bw_utilization(&self) -> f64 {
        (self.dram_busy / self.cycles.max(1.0)).min(1.0)
    }

    /// Paper Fig 10 metric: mean of DRAM-bandwidth, VU and MU utilisation.
    pub fn overall_utilization(&self) -> f64 {
        (self.vu_utilization() + self.mu_utilization() + self.bw_utilization()) / 3.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_accumulates() {
        let mut t = Traffic::default();
        t.add(TrafficTag::SrcVertex, 100);
        t.add(TrafficTag::SrcVertex, 50);
        t.add(TrafficTag::Meta, 8);
        assert_eq!(t.get(TrafficTag::SrcVertex), 150);
        assert_eq!(t.total(), 158);
    }

    #[test]
    fn utilization_bounds() {
        let r = SimResult {
            cycles: 100.0,
            seconds: 1e-7,
            vu_busy: 50.0,
            mu_busy: 100.0,
            dram_busy: 25.0,
            traffic: Traffic::default(),
            shards_processed: 1,
            intervals_processed: 1,
            instructions: 10,
        };
        assert!((r.vu_utilization() - 0.5).abs() < 1e-12);
        assert!((r.overall_utilization() - (0.5 + 1.0 + 0.25) / 3.0).abs() < 1e-12);
    }
}
