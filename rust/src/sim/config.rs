//! Accelerator configuration (paper Tbl III).

/// Off-chip memory parameters.
#[derive(Clone, Copy, Debug)]
pub struct DramConfig {
    /// Sustained bandwidth in bytes/second.
    pub bandwidth_bytes_per_s: f64,
    /// Access latency in nanoseconds (first-word; the queue model adds
    /// serialisation on top).
    pub latency_ns: f64,
    /// Access energy in pJ/bit (paper §VI: 7 pJ/bit for HBM).
    pub energy_pj_per_bit: f64,
}

/// 256 GB/s HBM-1 (SWITCHBLADE and HyGCN in Tbl III).
pub const HBM1: DramConfig = DramConfig {
    bandwidth_bytes_per_s: 256.0e9,
    latency_ns: 100.0,
    energy_pj_per_bit: 7.0,
};

/// 900 GB/s HBM-2 (the V100 baseline; a memory-axis option in the DSE
/// search space — see `dse::space::MemoryKind`).
pub const HBM2: DramConfig = DramConfig {
    bandwidth_bytes_per_s: 900.0e9,
    latency_ns: 100.0,
    energy_pj_per_bit: 7.0,
};

/// Full accelerator configuration.
#[derive(Clone, Copy, Debug)]
pub struct AcceleratorConfig {
    /// Clock frequency in Hz (1 GHz in Tbl III).
    pub freq_hz: f64,
    /// VU: number of SIMD cores × lanes per core (16 × 32).
    pub vu_cores: u32,
    pub vu_lanes: u32,
    /// MU: output-stationary systolic array rows × cols (32 × 128).
    pub mu_rows: u32,
    pub mu_cols: u32,
    /// DstBuffer bytes (8 MB "DB" in Tbl III).
    pub dst_buffer: u64,
    /// SrcEdgeBuffer bytes (1 MB "SEB").
    pub src_edge_buffer: u64,
    /// Weight buffer bytes (2 MB).
    pub weight_buffer: u64,
    /// Graph buffer bytes (128 KB "GB": Meta + Data + LSU staging).
    pub graph_buffer: u64,
    /// Number of concurrent sThreads (3 in the paper's default: one per
    /// hardware resource class — VU, MU, bandwidth).
    pub num_sthreads: u32,
    pub dram: DramConfig,
}

impl AcceleratorConfig {
    /// Tbl III SWITCHBLADE row.
    pub fn switchblade() -> Self {
        AcceleratorConfig {
            freq_hz: 1.0e9,
            vu_cores: 16,
            vu_lanes: 32,
            mu_rows: 32,
            mu_cols: 128,
            dst_buffer: 8 * 1024 * 1024,
            src_edge_buffer: 1024 * 1024,
            weight_buffer: 2 * 1024 * 1024,
            graph_buffer: 128 * 1024,
            num_sthreads: 3,
            dram: HBM1,
        }
    }

    /// Variant with a different sThread count (Fig 11 sweep).
    pub fn with_sthreads(mut self, n: u32) -> Self {
        self.num_sthreads = n.max(1);
        self
    }

    /// Variant with a different DstBuffer size (Fig 13: 8 MB → 13 MB).
    pub fn with_dst_buffer(mut self, bytes: u64) -> Self {
        self.dst_buffer = bytes;
        self
    }

    /// Variant with a different SrcEdgeBuffer size (DSE memory axis).
    pub fn with_src_edge_buffer(mut self, bytes: u64) -> Self {
        self.src_edge_buffer = bytes.max(1);
        self
    }

    /// Variant with a different off-chip memory (HBM1 vs HBM2).
    pub fn with_dram(mut self, dram: DramConfig) -> Self {
        self.dram = dram;
        self
    }

    /// Total on-chip SRAM capacity (DstBuffer + SrcEdgeBuffer + weight +
    /// graph buffers). RAM dominates Tbl V area (76%), so this is the
    /// area proxy the DSE Pareto frontier minimises.
    pub fn sram_bytes(&self) -> u64 {
        self.dst_buffer + self.src_edge_buffer + self.weight_buffer + self.graph_buffer
    }

    /// VU element throughput per cycle.
    pub fn vu_throughput(&self) -> u64 {
        self.vu_cores as u64 * self.vu_lanes as u64
    }

    /// Per-sThread SrcEdgeBuffer budget — RHS of Equ. 1.
    pub fn shard_bytes(&self) -> u64 {
        self.src_edge_buffer / self.num_sthreads as u64
    }

    /// Partitioner configuration for a compiled program on this hardware.
    pub fn partition_config(&self, p: &crate::isa::Program) -> crate::partition::PartitionConfig {
        crate::partition::PartitionConfig {
            shard_bytes: self.shard_bytes(),
            dst_bytes: self.dst_buffer,
            dim_src: p.dim_src.max(1),
            dim_edge: p.dim_edge.max(1),
            dim_dst: p.dim_dst.max(1),
            num_sthreads: self.num_sthreads,
        }
    }

    /// DRAM bytes transferable per cycle.
    pub fn dram_bytes_per_cycle(&self) -> f64 {
        self.dram.bandwidth_bytes_per_s / self.freq_hz
    }

    /// DRAM latency in cycles.
    pub fn dram_latency_cycles(&self) -> f64 {
        self.dram.latency_ns * 1e-9 * self.freq_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tbl3_defaults() {
        let c = AcceleratorConfig::switchblade();
        assert_eq!(c.vu_throughput(), 512);
        assert_eq!(c.shard_bytes(), 1024 * 1024 / 3);
        assert!((c.dram_bytes_per_cycle() - 256.0).abs() < 1e-9);
        assert!((c.dram_latency_cycles() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn variants() {
        let c = AcceleratorConfig::switchblade().with_sthreads(5);
        assert_eq!(c.num_sthreads, 5);
        let c = c.with_dst_buffer(13 * 1024 * 1024);
        assert_eq!(c.dst_buffer, 13 * 1024 * 1024);
        let c = c.with_src_edge_buffer(2 * 1024 * 1024);
        assert_eq!(c.src_edge_buffer, 2 * 1024 * 1024);
        let c = c.with_dram(HBM2);
        assert!((c.dram.bandwidth_bytes_per_s - 900.0e9).abs() < 1e-3);
    }

    #[test]
    fn sram_proxy_sums_all_buffers() {
        let c = AcceleratorConfig::switchblade();
        assert_eq!(
            c.sram_bytes(),
            (8 + 2) * 1024 * 1024 + 1024 * 1024 + 128 * 1024
        );
        assert!(c.with_dst_buffer(13 * 1024 * 1024).sram_bytes() > c.sram_bytes());
    }
}
