//! Per-instruction timing costs, derived from the synthesis-calibrated
//! unit parameters of Tbl III (the paper's Verilog/DC step is replaced by
//! these closed forms — DESIGN.md §3).

use crate::isa::{ElwOp, Instr};

use super::config::AcceleratorConfig;

/// Fixed decode/issue overhead per instruction (controller pipeline).
pub const ISSUE_OVERHEAD: f64 = 4.0;

/// Phase-scheduler switch cost (PC swap + metadata probe), per phase/shard
/// transition (§V-B2).
pub const PHASE_SWITCH: f64 = 12.0;

/// Cost model over one accelerator configuration.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    vu_rate_simple: f64,
    vu_rate_special: f64,
    vu_rate_gtr: f64,
    mu_rows: f64,
    mu_cols: f64,
}

impl CostModel {
    pub fn new(cfg: &AcceleratorConfig) -> Self {
        let t = cfg.vu_throughput() as f64;
        CostModel {
            // Full-rate: one elem per lane per cycle.
            vu_rate_simple: t,
            // Transcendentals microcode to ~4 cycles per elem.
            vu_rate_special: t / 4.0,
            // GTR: indirection halves sustained throughput (bank
            // conflicts in the crossbar between buffer and cores).
            vu_rate_gtr: t / 2.0,
            mu_rows: cfg.mu_rows as f64,
            mu_cols: cfg.mu_cols as f64,
        }
    }

    /// VU cycles for an element-wise op over `work` elements.
    fn vu(&self, op_rate: f64, work: u64) -> f64 {
        ISSUE_OVERHEAD + (work as f64 / op_rate).ceil()
    }

    /// MU cycles for `rows×k×n`: output-stationary tiling — each
    /// `mu_rows × mu_cols` output tile streams `k` partial sums, plus the
    /// array fill/drain once per instruction.
    pub fn mu(&self, rows: u64, k: u64, n: u64) -> f64 {
        let tiles = (rows as f64 / self.mu_rows).ceil() * (n as f64 / self.mu_cols).ceil();
        ISSUE_OVERHEAD + tiles * k as f64 + (self.mu_rows + self.mu_cols)
    }

    /// Compute-instruction duration (LD/ST are priced by the DRAM model).
    /// `rows` is the decoded row count for the current interval/shard.
    pub fn compute_cycles(&self, i: &Instr, rows: u64) -> f64 {
        match i {
            Instr::Elw { op, cols, .. } => {
                let work = rows * *cols as u64;
                let rate = match op {
                    ElwOp::Exp
                    | ElwOp::Sigmoid
                    | ElwOp::Tanh
                    | ElwOp::Rsqrt
                    | ElwOp::Recip
                    | ElwOp::Div => self.vu_rate_special,
                    _ => self.vu_rate_simple,
                };
                self.vu(rate, work)
            }
            Instr::RowScale { cols, .. } => self.vu(self.vu_rate_simple, rows * *cols as u64),
            Instr::Concat { cols_a, cols_b, .. } => {
                self.vu(self.vu_rate_simple, rows * (*cols_a + *cols_b) as u64)
            }
            Instr::Dmm { k, n, .. } if *n <= 4 => {
                // Matrix-vector on the VU: one fused multiply-add per
                // element of the input matrix.
                self.vu(self.vu_rate_simple, rows * *k as u64 * *n as u64)
            }
            Instr::Dmm { k, n, .. } => self.mu(rows, *k as u64, *n as u64),
            Instr::Scatter { cols, .. } | Instr::Gather { cols, .. } => {
                self.vu(self.vu_rate_gtr, rows * *cols as u64)
            }
            Instr::FusedGather { cols, .. } => {
                // One read + one RMW per edge element, same crossbar rate.
                self.vu(self.vu_rate_gtr, rows * *cols as u64)
            }
            Instr::Ld { .. } | Instr::St { .. } => {
                unreachable!("memory instructions are priced by the DRAM model")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Dim, Space, Sym};

    fn cm() -> CostModel {
        CostModel::new(&AcceleratorConfig::switchblade())
    }

    #[test]
    fn elw_throughput() {
        let i = Instr::Elw {
            op: ElwOp::Add,
            dst: Sym::new(Space::D, 0),
            a: Sym::new(Space::D, 0),
            b: None,
            broadcast_b: false,
            rows: Dim::V,
            cols: 128,
        };
        // 512 rows × 128 cols = 65536 elems at 512/cycle = 128 cycles.
        assert!((cm().compute_cycles(&i, 512) - (ISSUE_OVERHEAD + 128.0)).abs() < 1e-9);
    }

    #[test]
    fn transcendental_slower() {
        let mk = |op| Instr::Elw {
            op,
            dst: Sym::new(Space::D, 0),
            a: Sym::new(Space::D, 0),
            b: None,
            broadcast_b: false,
            rows: Dim::V,
            cols: 128,
        };
        let fast = cm().compute_cycles(&mk(ElwOp::Add), 128);
        let slow = cm().compute_cycles(&mk(ElwOp::Exp), 128);
        assert!(slow > 3.0 * fast);
    }

    #[test]
    fn mu_scales_with_tiles() {
        let c = cm();
        let one_tile = c.mu(32, 128, 128);
        let four_tiles = c.mu(64, 128, 256);
        assert!((one_tile - (ISSUE_OVERHEAD + 128.0 + 160.0)).abs() < 1e-9);
        // Fill/drain amortises across tiles: 4 tiles cost < 4x one tile
        // but still scale super-linearly past 2x.
        assert!(four_tiles > 2.0 * one_tile && four_tiles < 4.0 * one_tile);
    }

    #[test]
    fn gather_half_rate() {
        let g = Instr::Gather {
            reduce: crate::isa::Reduce::Sum,
            dst: Sym::new(Space::D, 0),
            src: Sym::new(Space::E, 0),
            cols: 128,
        };
        // 256 edges × 128 cols at 256/cycle = 128 cycles.
        assert!((cm().compute_cycles(&g, 256) - (ISSUE_OVERHEAD + 128.0)).abs() < 1e-9);
    }
}
