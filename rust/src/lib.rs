//! SWITCHBLADE — reproduction of "Accelerating Generic Graph Neural Networks
//! via Architecture, Compiler, Partition Method Co-Design" (CS.AR 2023).
//!
//! The crate implements the paper's full stack:
//!
//! * [`ir`] — the unified computational graph, the declarative `.gnn`
//!   model-spec format (`ir::spec`) and the *open* model zoo (`ir::zoo`):
//!   the Tbl I models ship as built-in specs, and user spec files run the
//!   whole pipeline with no Rust changes,
//! * [`compiler`] — PLOF phase construction and ISA code generation (§V-C),
//! * [`partition`] — DSW-GP (Alg 1) and FGGP (Alg 3) graph partitioners,
//! * [`isa`] — the accelerator instruction set (§V-A),
//! * [`sched`] — the shared partition-walk scheduler: the single
//!   definition of the Alg 2 group→interval→shard order, driven through
//!   phase-hook visitors by both `sim` and `exec`,
//! * [`sim`] — the cycle-level accelerator model with SLMT (§V-B),
//! * [`exec`] — a functional executor for compiled programs (numerics;
//!   shard-parallel across a worker pool, bit-identical at any width),
//! * [`baseline`] — V100 GPU cost model and the HyGCN reproduction,
//! * [`energy`] — area/power/energy models (Tbl V),
//! * [`runtime`] — PJRT loader for the AOT-compiled JAX reference models,
//! * [`coordinator`] — multi-threaded experiment fan-out + reporting,
//! * [`graph`] — CSR/COO substrate and Tbl IV dataset stand-ins,
//! * [`dse`] — parallel design-space exploration & auto-tuning: budgeted
//!   sweeps over (architecture × partition method) through a generalized
//!   program/graph/partition cache layer, with Pareto reporting over
//!   (latency, energy, SRAM area) — the `switchblade tune` subcommand,
//! * [`obs`] — observability: the span recorder behind `--trace`
//!   (Chrome trace-event export, per-worker lanes) and the metrics
//!   registry behind `--metrics` (JSON / Prometheus exporters, the
//!   source of `BENCH_exec.json` and the CI perf-regression gate),
//! * [`serve`] — the persistent inference service: per-(model, graph)
//!   engine entries owning warm executors, bounded submission queues
//!   with micro-batching + admission control, and the `serve --bench`
//!   load generator behind `BENCH_serve.json`.

pub mod coordinator;
pub mod dse;
pub mod energy;
pub mod exec;
pub mod graph;
pub mod ir;
pub mod isa;
pub mod obs;
pub mod baseline;
pub mod compiler;
pub mod partition;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod util;
