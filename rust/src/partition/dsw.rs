//! Baseline dual-sliding-window partitioning (paper Alg 1) with the
//! HyGCN-style *sparsity elimination* of Fig 4-a: shards cover contiguous
//! source windows; fully-empty shards are skipped and each window is
//! trimmed to its first/last connected source, but every source inside the
//! trimmed window is loaded whether used or not. That is the redundancy
//! FGGP removes.

use super::{Interval, Method, PartitionConfig, Partitions, Shard, ShardEdge};
use crate::graph::{Csr, VertexId};

/// `calShardHeight` (Alg 1 line 1): choose the source-window height so that
/// an *average-density* shard obeys Equ. 1. Dense shards that still
/// overflow are split at materialisation time, preserving the "each shard
/// fits the memory space" guarantee (§II-B).
fn cal_shard_height(g: &Csr, cfg: &PartitionConfig, interval_height: usize) -> usize {
    // Expected edges landing in one (window × interval) shard for a window
    // of height h: h * avg_out_degree * (interval_height / |V|).
    let avg_deg = g.avg_degree();
    let iv_frac = (interval_height as f64 / g.num_vertices().max(1) as f64).min(1.0);
    let per_src_bytes =
        (cfg.dim_src as f64 + avg_deg * iv_frac * cfg.dim_edge as f64) * super::F32_BYTES as f64;
    ((cfg.shard_bytes as f64 / per_src_bytes) as usize).max(1)
}

/// Partition `g` with plain DSW-GP + sparsity elimination.
pub fn partition_dsw(g: &Csr, cfg: PartitionConfig) -> Partitions {
    let _span = crate::obs::trace::span(
        crate::obs::trace::names::PARTITION_DSW,
        crate::obs::trace::cat::FRONTEND,
        crate::obs::trace::TRACK_MAIN,
    );
    let n = g.num_vertices();
    let interval_height = cfg.interval_height();
    let shard_height = cal_shard_height(g, &cfg, interval_height);

    let mut intervals = Vec::new();
    let mut shards: Vec<Shard> = Vec::new();

    let mut iv_begin = 0usize;
    while iv_begin < n {
        let iv_end = (iv_begin + interval_height).min(n);
        let shard_begin = shards.len();

        // Collect this interval's in-edges grouped by source window.
        // (src, dst, edge_id), sorted by src — `Csr::in_edges` lists each
        // destination's sources in ascending order, and we merge them into
        // window buckets directly.
        let mut by_window: Vec<Vec<(VertexId, VertexId, u64)>> =
            vec![Vec::new(); (n + shard_height - 1) / shard_height];
        for dst in iv_begin as VertexId..iv_end as VertexId {
            for (src, eid) in g.in_edges(dst) {
                by_window[src as usize / shard_height].push((src, dst, eid));
            }
        }

        for (w, mut bucket) in by_window.into_iter().enumerate() {
            if bucket.is_empty() {
                continue; // sparsity elimination: skip empty shards
            }
            bucket.sort_unstable();
            // Window trimming: load from the first to the last used source.
            let win_lo = bucket.first().unwrap().0;
            let win_hi = bucket.last().unwrap().0 + 1;
            debug_assert!(win_lo as usize >= w * shard_height);
            debug_assert!(win_hi as usize <= (w + 1) * shard_height);
            emit_windows(
                &cfg,
                &mut shards,
                intervals.len() as u32,
                &bucket,
                win_lo,
                win_hi,
            );
        }

        intervals.push(Interval {
            begin: iv_begin as VertexId,
            end: iv_end as VertexId,
            shard_begin,
            shard_end: shards.len(),
        });
        iv_begin = iv_end;
    }

    Partitions {
        method: Method::Dsw,
        config: cfg,
        num_vertices: n,
        num_edges: g.num_edges(),
        intervals,
        shards,
    }
}

/// Materialise one trimmed window as one shard, splitting it in half
/// (recursively) if it violates Equ. 1 — mirrors real DSW systems that
/// guarantee residency by construction.
fn emit_windows(
    cfg: &PartitionConfig,
    shards: &mut Vec<Shard>,
    interval: u32,
    bucket: &[(VertexId, VertexId, u64)], // sorted by src
    win_lo: VertexId,
    win_hi: VertexId,
) {
    let loaded = win_hi - win_lo; // every source in the window is loaded
    if !cfg.fits(loaded as u64, bucket.len() as u64) && win_hi - win_lo == 1 {
        // A single hub source whose edges alone bust the budget: split the
        // edge list into budget-sized chunks (each chunk re-loads the
        // source row, as the hardware would).
        let max_edges = ((cfg.shard_bytes / super::F32_BYTES)
            .saturating_sub(cfg.dim_src as u64)
            / cfg.dim_edge.max(1) as u64)
            .max(1) as usize;
        for chunk in bucket.chunks(max_edges) {
            emit_one(shards, interval, chunk, win_lo, win_hi);
        }
        return;
    }
    if !cfg.fits(loaded as u64, bucket.len() as u64) && win_hi - win_lo > 1 {
        let mid = win_lo + (win_hi - win_lo) / 2;
        let split = bucket.partition_point(|&(s, _, _)| s < mid);
        let (left, right) = bucket.split_at(split);
        // Re-trim both halves.
        if !left.is_empty() {
            let (lo, hi) = (left.first().unwrap().0, left.last().unwrap().0 + 1);
            emit_windows(cfg, shards, interval, left, lo, hi);
        }
        if !right.is_empty() {
            let (lo, hi) = (right.first().unwrap().0, right.last().unwrap().0 + 1);
            emit_windows(cfg, shards, interval, right, lo, hi);
        }
        return;
    }

    emit_one(shards, interval, bucket, win_lo, win_hi);
}

/// Materialise one shard from a sorted edge bucket.
fn emit_one(
    shards: &mut Vec<Shard>,
    interval: u32,
    bucket: &[(VertexId, VertexId, u64)],
    win_lo: VertexId,
    win_hi: VertexId,
) {
    // Build shard-local source list: the *used* sources (ascending,
    // deduplicated) — but the load window covers [win_lo, win_hi).
    let mut sources: Vec<VertexId> = Vec::new();
    let mut edges: Vec<ShardEdge> = Vec::with_capacity(bucket.len());
    for &(src, dst, eid) in bucket {
        if sources.last() != Some(&src) {
            sources.push(src);
        }
        edges.push(ShardEdge {
            src_slot: (sources.len() - 1) as u32,
            dst,
            edge_id: eid,
        });
    }
    shards.push(Shard {
        interval,
        sources,
        edges,
        win_begin: win_lo,
        win_end: win_hi,
        loaded_sources: win_hi - win_lo,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn cfg(shard_kb: u64, dst_kb: u64) -> PartitionConfig {
        PartitionConfig {
            shard_bytes: shard_kb * 1024,
            dst_bytes: dst_kb * 1024,
            dim_src: 128,
            dim_edge: 0,
            dim_dst: 128,
            num_sthreads: 1,
        }
    }

    #[test]
    fn covers_all_edges_and_validates() {
        let g = Csr::from_edge_list(&generators::rmat(1 << 10, 8_000, 0.57, 0.19, 0.19, 1));
        let p = partition_dsw(&g, cfg(64, 64));
        p.validate().expect("valid partitioning");
        let total: usize = p.shards.iter().map(|s| s.num_edges()).sum();
        assert_eq!(total, g.num_edges());
    }

    #[test]
    fn windows_are_contiguous_and_loaded_ge_used(){
        let g = Csr::from_edge_list(&generators::rmat(1 << 10, 8_000, 0.57, 0.19, 0.19, 2));
        let p = partition_dsw(&g, cfg(64, 64));
        for s in &p.shards {
            assert!(s.loaded_sources as usize >= s.num_src());
            assert_eq!(s.loaded_sources, s.win_end - s.win_begin);
            for &src in &s.sources {
                assert!(src >= s.win_begin && src < s.win_end);
            }
        }
        // On a skewed graph, the baseline loads redundant sources overall.
        let loaded: u64 = p.shards.iter().map(|s| s.loaded_sources as u64).sum();
        let used: u64 = p.shards.iter().map(|s| s.num_src() as u64).sum();
        assert!(loaded > used, "loaded {loaded} should exceed used {used}");
    }

    #[test]
    fn respects_budget() {
        let g = Csr::from_edge_list(&generators::rmat(1 << 9, 6_000, 0.57, 0.19, 0.19, 3));
        let c = cfg(16, 32);
        let p = partition_dsw(&g, c);
        for s in &p.shards {
            assert!(
                c.fits(s.num_src() as u64, s.num_edges() as u64),
                "shard overflows Equ.1"
            );
        }
    }

    #[test]
    fn empty_graph() {
        let g = Csr::from_edge_list(&crate::graph::EdgeList::new(100));
        let p = partition_dsw(&g, cfg(64, 64));
        p.validate().unwrap();
        assert!(p.shards.is_empty());
        assert!(!p.intervals.is_empty());
    }

    #[test]
    fn single_interval_when_buffer_large() {
        let g = Csr::from_edge_list(&generators::mesh2d(16, 16, false));
        let mut c = cfg(1024, 1024 * 1024);
        c.dim_dst = 1;
        let p = partition_dsw(&g, c);
        assert_eq!(p.intervals.len(), 1);
        p.validate().unwrap();
    }
}
