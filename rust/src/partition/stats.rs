//! Partition-quality statistics: the measurements behind Fig 12 (buffer
//! occupancy) and Fig 13 (data transfer / reuse).

use super::{PartitionConfig, Partitions};

/// Aggregate statistics for one partitioning.
#[derive(Clone, Copy, Debug)]
pub struct PartitionStats {
    pub num_intervals: usize,
    pub num_shards: usize,
    /// Paper Fig 12 metric: mean over shard loads of
    /// `useful bytes / per-thread buffer budget`.
    pub occupancy_rate: f64,
    /// Total bytes streamed from DRAM for source rows + edges over one
    /// full sweep (the Fig 13 "total data transfer" numerator for shard
    /// traffic).
    pub loaded_bytes: u64,
    /// Bytes of those that are actually used by computation.
    pub useful_bytes: u64,
    /// Mean times each vertex is loaded as a source per sweep
    /// (redundancy factor; 1.0 = perfect reuse).
    pub src_load_redundancy: f64,
    /// Mean shard edge count (density proxy).
    pub avg_edges_per_shard: f64,
}

/// Compute statistics for a partitioning.
pub fn analyze(p: &Partitions) -> PartitionStats {
    let cfg: &PartitionConfig = &p.config;
    let mut occ_sum = 0.0;
    let mut loaded = 0u64;
    let mut useful = 0u64;
    let mut src_loads = 0u64;
    let mut edges = 0u64;
    for s in &p.shards {
        let u = s.useful_bytes(cfg);
        let l = s.loaded_bytes(cfg);
        occ_sum += u as f64 / cfg.shard_bytes as f64;
        loaded += l;
        useful += u;
        src_loads += s.loaded_sources as u64;
        edges += s.num_edges() as u64;
    }
    let n_sh = p.shards.len().max(1);
    PartitionStats {
        num_intervals: p.intervals.len(),
        num_shards: p.shards.len(),
        occupancy_rate: occ_sum / n_sh as f64,
        loaded_bytes: loaded,
        useful_bytes: useful,
        src_load_redundancy: src_loads as f64 / p.num_vertices.max(1) as f64,
        avg_edges_per_shard: edges as f64 / n_sh as f64,
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::{generators, Csr};
    use crate::partition::{partition_dsw, partition_fggp, PartitionConfig};

    fn cfg() -> PartitionConfig {
        PartitionConfig {
            shard_bytes: 32 * 1024,
            dst_bytes: 128 * 1024,
            dim_src: 128,
            dim_edge: 1,
            dim_dst: 128,
            num_sthreads: 1,
        }
    }

    #[test]
    fn fggp_occupancy_beats_dsw() {
        // Fig 12's qualitative claim: FGGP ≈99% vs baseline ≈44%.
        let g = Csr::from_edge_list(&generators::rmat(1 << 12, 32_000, 0.57, 0.19, 0.19, 5));
        let fg = super::analyze(&partition_fggp(&g, cfg()));
        let ds = super::analyze(&partition_dsw(&g, cfg()));
        assert!(
            fg.occupancy_rate > 0.85,
            "FGGP occupancy {:.2} should be near 1",
            fg.occupancy_rate
        );
        assert!(
            fg.occupancy_rate > ds.occupancy_rate + 0.15,
            "FGGP {:.2} vs DSW {:.2}",
            fg.occupancy_rate,
            ds.occupancy_rate
        );
    }

    #[test]
    fn redundancy_at_least_one_when_all_vertices_used() {
        let g = Csr::from_edge_list(&generators::mesh2d(32, 32, true));
        let st = super::analyze(&partition_fggp(&g, cfg()));
        assert!(st.src_load_redundancy >= 1.0);
    }

    #[test]
    fn useful_le_loaded() {
        let g = Csr::from_edge_list(&generators::rmat(1 << 10, 10_000, 0.57, 0.19, 0.19, 6));
        for p in [partition_fggp(&g, cfg()), partition_dsw(&g, cfg())] {
            let st = super::analyze(&p);
            assert!(st.useful_bytes <= st.loaded_bytes);
        }
    }
}
