//! Fine-grained graph partitioning (paper §IV-D / §V-D, Alg 3).
//!
//! Shards are packed at the granularity of individual `(source, edges)`
//! bundles: for each destination interval we sweep sources in ascending
//! order (`srcPtr`), fetch the source's neighbour list restricted to the
//! interval (`acquireNeiList`), skip unconnected sources, and append the
//! bundle to the open shard while Equ. 1 holds (`probeShardSize`). Source
//! lists therefore become *discontinuous* (Fig 4-b), no unused source is
//! ever loaded, and shards are packed to ~the memory budget — the ~99%
//! occupancy of Fig 12.

use super::{Interval, Method, PartitionConfig, Partitions, Shard, ShardEdge};
use crate::graph::{Csr, VertexId};

/// Partition `g` with FGGP (Alg 3).
pub fn partition_fggp(g: &Csr, cfg: PartitionConfig) -> Partitions {
    let _span = crate::obs::trace::span(
        crate::obs::trace::names::PARTITION_FGGP,
        crate::obs::trace::cat::FRONTEND,
        crate::obs::trace::TRACK_MAIN,
    );
    let n = g.num_vertices();
    let interval_height = cfg.interval_height();

    let mut intervals = Vec::new();
    let mut shards: Vec<Shard> = Vec::new();

    let mut iv_begin = 0usize;
    while iv_begin < n {
        let iv_end = (iv_begin + interval_height).min(n);
        let shard_begin = shards.len();
        let iv_idx = intervals.len() as u32;

        // acquireNeiList for the whole interval at once: gather (src, dst,
        // edge_id) for every in-edge of the interval, sorted by src. The
        // per-src slices of this vector are exactly Alg 3's `dstList`s, and
        // building it once is O(E_interval log) instead of O(V) probes.
        let mut edges_by_src: Vec<(VertexId, VertexId, u64)> = Vec::new();
        for dst in iv_begin as VertexId..iv_end as VertexId {
            for (src, eid) in g.in_edges(dst) {
                edges_by_src.push((src, dst, eid));
            }
        }
        edges_by_src.sort_unstable();

        // Alg 3 inner loop: sweep sources, pack bundles.
        let mut cur = Shard {
            interval: iv_idx,
            ..Shard::default()
        };
        let mut i = 0usize;
        while i < edges_by_src.len() {
            let src = edges_by_src[i].0;
            let mut j = i;
            while j < edges_by_src.len() && edges_by_src[j].0 == src {
                j += 1;
            }
            let bundle = &edges_by_src[i..j];

            // probeShardSize: would adding (1 src, bundle.len() edges)
            // overflow Equ. 1?
            let would_src = cur.sources.len() as u64 + 1;
            let would_edges = cur.edges.len() as u64 + bundle.len() as u64;
            if !cfg.fits(would_src, would_edges) && !cur.sources.is_empty() {
                finalize(&mut shards, std::mem::take(&mut cur), iv_idx);
            }

            // A single source whose bundle alone overflows the budget must
            // be split across shards (hub vertices on power-law graphs).
            let mut k = 0usize;
            while k < bundle.len() {
                if cur.sources.last() != Some(&src) {
                    // Adding the source row itself must fit.
                    if !cfg.fits(cur.sources.len() as u64 + 1, cur.edges.len() as u64 + 1) {
                        finalize(&mut shards, std::mem::take(&mut cur), iv_idx);
                    }
                    cur.sources.push(src);
                }
                let slot = (cur.sources.len() - 1) as u32;
                // How many of this bundle's edges still fit?
                let room = edge_room(&cfg, cur.sources.len() as u64, cur.edges.len() as u64);
                let take = room.min(bundle.len() - k);
                if take == 0 {
                    // No room for even one more edge: close the shard and
                    // retry (a fresh shard always has room). Only drop the
                    // source row if no edge references it yet (it may carry
                    // edges from an earlier slice of this same bundle).
                    let last_slot_used = cur
                        .edges
                        .last()
                        .is_some_and(|e| e.src_slot as usize == cur.sources.len() - 1);
                    if !last_slot_used {
                        cur.sources.pop();
                    }
                    finalize(&mut shards, std::mem::take(&mut cur), iv_idx);
                    continue;
                }
                for &(_, dst, eid) in &bundle[k..k + take] {
                    cur.edges.push(ShardEdge {
                        src_slot: slot,
                        dst,
                        edge_id: eid,
                    });
                }
                k += take;
            }
            i = j;
        }
        if !cur.sources.is_empty() {
            finalize(&mut shards, cur, iv_idx);
        }

        intervals.push(Interval {
            begin: iv_begin as VertexId,
            end: iv_end as VertexId,
            shard_begin,
            shard_end: shards.len(),
        });
        iv_begin = iv_end;
    }

    Partitions {
        method: Method::Fggp,
        config: cfg,
        num_vertices: n,
        num_edges: g.num_edges(),
        intervals,
        shards,
    }
}

/// How many more edges fit alongside `num_src` sources (Equ. 1 solved for
/// `num_edge`). With `dim_edge == 0` edges are metadata-only (held in the
/// DataBuffer, not the SrcEdgeBuffer) and the answer is unbounded.
fn edge_room(cfg: &PartitionConfig, num_src: u64, num_edge: u64) -> usize {
    if cfg.dim_edge == 0 {
        return usize::MAX;
    }
    let used = cfg.shard_footprint(num_src, num_edge);
    if used >= cfg.shard_bytes {
        return 0;
    }
    ((cfg.shard_bytes - used) / (cfg.dim_edge as u64 * super::F32_BYTES)) as usize
}

fn finalize(shards: &mut Vec<Shard>, mut s: Shard, interval: u32) {
    s.interval = interval;
    s.loaded_sources = s.sources.len() as u32; // FGGP loads only used sources
    s.win_begin = s.sources.first().copied().unwrap_or(0);
    s.win_end = s.sources.last().map(|v| v + 1).unwrap_or(0);
    shards.push(s);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::partition::partition_dsw;

    fn cfg(shard_kb: u64, dst_kb: u64, dim_edge: u32) -> PartitionConfig {
        PartitionConfig {
            shard_bytes: shard_kb * 1024,
            dst_bytes: dst_kb * 1024,
            dim_src: 128,
            dim_edge,
            dim_dst: 128,
            num_sthreads: 1,
        }
    }

    #[test]
    fn covers_all_edges_and_validates() {
        let g = Csr::from_edge_list(&generators::rmat(1 << 10, 8_000, 0.57, 0.19, 0.19, 1));
        let p = partition_fggp(&g, cfg(64, 64, 1));
        p.validate().expect("valid partitioning");
    }

    #[test]
    fn loads_only_used_sources() {
        let g = Csr::from_edge_list(&generators::rmat(1 << 10, 8_000, 0.57, 0.19, 0.19, 2));
        let p = partition_fggp(&g, cfg(64, 64, 1));
        for s in &p.shards {
            assert_eq!(s.loaded_sources as usize, s.num_src());
        }
    }

    #[test]
    fn denser_than_dsw() {
        // The headline FGGP property (Fig 12): same budget, fewer shards,
        // less loaded data.
        let g = Csr::from_edge_list(&generators::rmat(1 << 11, 16_000, 0.57, 0.19, 0.19, 3));
        let c = cfg(32, 64, 1);
        let fg = partition_fggp(&g, c);
        let ds = partition_dsw(&g, c);
        let loaded = |p: &Partitions| -> u64 {
            p.shards.iter().map(|s| s.loaded_bytes(&p.config)).sum()
        };
        assert!(fg.shards.len() <= ds.shards.len());
        assert!(
            loaded(&fg) < loaded(&ds),
            "FGGP loaded {} !< DSW loaded {}",
            loaded(&fg),
            loaded(&ds)
        );
    }

    #[test]
    fn hub_vertex_splits_across_shards() {
        // A star graph: vertex 0 points at everyone; every other vertex
        // points at vertex 1. In-degree of 1 is huge => bundles overflow.
        let mut el = crate::graph::EdgeList::new(4_000);
        for v in 2..4_000u32 {
            el.push(v, 1);
            el.push(0, v);
        }
        let g = Csr::from_edge_list(&el);
        // Tiny budget: force splitting.
        let c = PartitionConfig {
            shard_bytes: 4 * 1024,
            dst_bytes: 1024 * 1024,
            dim_src: 16,
            dim_edge: 16,
            dim_dst: 16,
            num_sthreads: 1,
        };
        let p = partition_fggp(&g, c);
        p.validate().unwrap();
    }

    #[test]
    fn discontinuous_sources_exist_on_sparse_graphs() {
        let g = Csr::from_edge_list(&generators::rmat(1 << 12, 8_000, 0.57, 0.19, 0.19, 4));
        let p = partition_fggp(&g, cfg(64, 256, 1));
        let any_gap = p.shards.iter().any(|s| {
            s.sources
                .windows(2)
                .any(|w| w[1] - w[0] > 1)
        });
        assert!(any_gap, "expected discontinuous source lists (Fig 4-b)");
    }

    #[test]
    fn empty_graph() {
        let g = Csr::from_edge_list(&crate::graph::EdgeList::new(64));
        let p = partition_fggp(&g, cfg(64, 64, 1));
        p.validate().unwrap();
        assert!(p.shards.is_empty());
    }
}
