//! Graph partitioning: dual-sliding-window (DSW-GP, paper Alg 1, with the
//! HyGCN-style sparsity elimination of Fig 4-a) and the paper's fine-grained
//! graph partitioning (FGGP, Alg 3 / Fig 4-b).
//!
//! Both partitioners produce the same [`Partitions`] structure consumed by
//! the simulator and the functional executor, so every downstream component
//! can run with either method — that is exactly the ablation axis of
//! Fig 12 / Fig 13.

mod dsw;
mod fggp;
pub mod stats;

pub use dsw::partition_dsw;
pub use fggp::partition_fggp;

use crate::graph::{Csr, VertexId};

/// Partitioning method selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Baseline: contiguous source windows with sparsity elimination
    /// (empty-shard skipping + window trimming), as in HyGCN.
    Dsw,
    /// Fine-grained graph partitioning (the paper's contribution).
    Fggp,
}

impl Method {
    /// Paper order: the contribution first, the baseline second.
    pub const ALL: [Method; 2] = [Method::Fggp, Method::Dsw];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Dsw => "DSW",
            Method::Fggp => "FGGP",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "fggp" => Some(Method::Fggp),
            "dsw" | "dsw-gp" | "hygcn" => Some(Method::Dsw),
            _ => None,
        }
    }

    /// Run the selected partitioner — the single dispatch point shared by
    /// the CLI, the experiment harness and the DSE sweep.
    pub fn run(&self, g: &Csr, pc: PartitionConfig) -> Partitions {
        match self {
            Method::Fggp => partition_fggp(g, pc),
            Method::Dsw => partition_dsw(g, pc),
        }
    }
}

/// Partitioning parameters. Data dimensions come from the compiler
/// (`Program::dim_src` / `dim_edge` / `dim_dst`, §V-C3); memory budgets
/// from the accelerator config (Tbl III). All-integer and hashable, so it
/// doubles as the `dse::cache::PartitionCache` key component.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PartitionConfig {
    /// Per-sThread SrcEdgeBuffer budget in bytes — the RHS of Equ. 1
    /// (`mem_capacity / num_sThread`).
    pub shard_bytes: u64,
    /// DstBuffer budget in bytes; bounds the destination interval size.
    pub dst_bytes: u64,
    /// Σ feature elements per source vertex resident in a shard.
    pub dim_src: u32,
    /// Σ feature elements per edge resident in a shard.
    pub dim_edge: u32,
    /// Σ feature elements per destination vertex resident in an interval.
    pub dim_dst: u32,
    /// Number of sThreads the shard budget was divided by (Equ. 1 RHS is
    /// `mem_capacity / num_sThread`); carried for the simulator.
    pub num_sthreads: u32,
}

pub const F32_BYTES: u64 = 4;

impl PartitionConfig {
    /// Destination-interval height: how many dst vertices fit in DstBuffer.
    pub fn interval_height(&self) -> usize {
        let per_vertex = self.dim_dst.max(1) as u64 * F32_BYTES;
        (self.dst_bytes / per_vertex).max(1) as usize
    }

    /// Shard footprint in bytes for `num_src` sources and `num_edge` edges
    /// (LHS of Equ. 1, in bytes).
    pub fn shard_footprint(&self, num_src: u64, num_edge: u64) -> u64 {
        (num_src * self.dim_src as u64 + num_edge * self.dim_edge as u64) * F32_BYTES
    }

    /// Equ. 1: does a shard of this size fit the per-thread budget?
    pub fn fits(&self, num_src: u64, num_edge: u64) -> bool {
        self.shard_footprint(num_src, num_edge) <= self.shard_bytes
    }
}

/// One edge inside a shard, in shard-local COO form (this is what the
/// accelerator's DataBuffer holds, §V-B4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardEdge {
    /// Index into the shard's `sources` list.
    pub src_slot: u32,
    /// Destination vertex (global id; dst-interval-relative slot is
    /// `dst - interval.begin`).
    pub dst: VertexId,
    /// Canonical edge id (indexes edge-feature storage in DRAM).
    pub edge_id: u64,
}

/// A shard: the unit of sThread work.
#[derive(Clone, Debug, Default)]
pub struct Shard {
    /// Interval this shard belongs to.
    pub interval: u32,
    /// Source vertices resident in the SrcEdgeBuffer for this shard
    /// (ascending; contiguous for DSW, possibly discontinuous for FGGP).
    pub sources: Vec<VertexId>,
    /// Shard-local COO edges, ordered by (src_slot, dst).
    pub edges: Vec<ShardEdge>,
    /// For DSW: the contiguous source window `[win_begin, win_end)` that is
    /// *loaded* (may include unused sources). For FGGP this equals the used
    /// source set, so `loaded_sources == sources.len()`.
    pub win_begin: VertexId,
    pub win_end: VertexId,
    /// Number of source rows actually transferred from DRAM for this shard.
    pub loaded_sources: u32,
}

impl Shard {
    pub fn num_src(&self) -> usize {
        self.sources.len()
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Bytes of *useful* data (used sources + edges) at the given dims.
    pub fn useful_bytes(&self, cfg: &PartitionConfig) -> u64 {
        cfg.shard_footprint(self.sources.len() as u64, self.edges.len() as u64)
    }

    /// Bytes actually loaded from DRAM (window sources + edges).
    pub fn loaded_bytes(&self, cfg: &PartitionConfig) -> u64 {
        cfg.shard_footprint(self.loaded_sources as u64, self.edges.len() as u64)
    }

    /// Inclusive `(min, max)` destination-vertex range this shard's edges
    /// touch; `None` for an edgeless shard. The executor sizes per-shard
    /// partial gather accumulators to this window instead of the whole
    /// interval.
    pub fn dst_span(&self) -> Option<(VertexId, VertexId)> {
        let mut it = self.edges.iter();
        let first = it.next()?;
        let (mut lo, mut hi) = (first.dst, first.dst);
        for e in it {
            lo = lo.min(e.dst);
            hi = hi.max(e.dst);
        }
        Some((lo, hi))
    }
}

/// A destination interval and the index range of its shards.
#[derive(Clone, Debug)]
pub struct Interval {
    pub begin: VertexId,
    pub end: VertexId,
    /// Indices into `Partitions::shards`.
    pub shard_begin: usize,
    pub shard_end: usize,
}

impl Interval {
    pub fn len(&self) -> usize {
        (self.end - self.begin) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.begin == self.end
    }

    pub fn num_shards(&self) -> usize {
        self.shard_end - self.shard_begin
    }
}

/// The full partitioning of a graph for one compiled model.
#[derive(Clone, Debug)]
pub struct Partitions {
    pub method: Method,
    pub config: PartitionConfig,
    pub num_vertices: usize,
    pub num_edges: usize,
    pub intervals: Vec<Interval>,
    pub shards: Vec<Shard>,
}

impl Partitions {
    pub fn shards_of(&self, interval: usize) -> &[Shard] {
        let iv = &self.intervals[interval];
        &self.shards[iv.shard_begin..iv.shard_end]
    }

    /// Global shard-index range of one interval — the unit the walk
    /// scheduler iterates (`sched::PartitionWalk`).
    pub fn shard_range(&self, interval: usize) -> std::ops::Range<usize> {
        let iv = &self.intervals[interval];
        iv.shard_begin..iv.shard_end
    }

    /// `(global shard index, shard)` pairs of one interval, in canonical
    /// (ascending) order. The global index is what walk traces and the
    /// executor's deterministic gather-merge key on.
    pub fn shards_of_indexed(
        &self,
        interval: usize,
    ) -> impl Iterator<Item = (usize, &Shard)> + '_ {
        self.shard_range(interval).zip(self.shards_of(interval))
    }

    /// Structural invariants shared by both methods; used by integration
    /// and property tests.
    pub fn validate(&self) -> Result<(), String> {
        let mut edge_seen = vec![false; self.num_edges];
        let mut covered_edges = 0usize;
        for (ii, iv) in self.intervals.iter().enumerate() {
            if iv.shard_begin > iv.shard_end || iv.shard_end > self.shards.len() {
                return Err(format!("interval {ii} bad shard range"));
            }
            for s in &self.shards[iv.shard_begin..iv.shard_end] {
                if s.interval as usize != ii {
                    return Err(format!("shard belongs to {} not {}", s.interval, ii));
                }
                if !self
                    .config
                    .fits(s.num_src() as u64, s.num_edges() as u64)
                {
                    return Err(format!(
                        "shard exceeds Equ.1 budget: {} > {}",
                        s.useful_bytes(&self.config),
                        self.config.shard_bytes
                    ));
                }
                if s.sources.windows(2).any(|w| w[0] >= w[1]) {
                    return Err("shard sources not strictly ascending".into());
                }
                for e in &s.edges {
                    if e.src_slot as usize >= s.sources.len() {
                        return Err("edge src_slot out of range".into());
                    }
                    if e.dst < iv.begin || e.dst >= iv.end {
                        return Err(format!(
                            "edge dst {} outside interval [{}, {})",
                            e.dst, iv.begin, iv.end
                        ));
                    }
                    let eid = e.edge_id as usize;
                    if eid >= self.num_edges || edge_seen[eid] {
                        return Err(format!("edge id {eid} duplicated or out of range"));
                    }
                    edge_seen[eid] = true;
                    covered_edges += 1;
                }
            }
        }
        if covered_edges != self.num_edges {
            return Err(format!(
                "edge coverage {covered_edges} != {}",
                self.num_edges
            ));
        }
        // Intervals must tile [0, num_vertices).
        let mut expect = 0 as VertexId;
        for iv in &self.intervals {
            if iv.begin != expect {
                return Err("interval gap".into());
            }
            expect = iv.end;
        }
        if expect as usize != self.num_vertices {
            return Err("intervals do not cover all vertices".into());
        }
        Ok(())
    }
}
