//! Experiment coordinator: fans the (model × dataset × config) sweeps out
//! over OS threads and renders every table and figure of the paper's
//! evaluation (§VII). This is the L3 driver the `switchblade repro`
//! subcommand and all bench targets call into. Graphs, compiled programs
//! and partitionings are memoised through the generalized
//! [`dse::cache`](crate::dse::cache) layer ([`Caches`]), shared with the
//! `switchblade tune` design-space explorer.

use std::sync::{Arc, Mutex};

use crate::baseline::{gpu_run, hygcn_run, GpuConfig, GpuResult, HygcnConfig, HygcnResult};
use crate::compiler::compile;
use crate::energy::{switchblade_energy, tbl5_rows, EnergyResult, TBL5};
use crate::exec::{KernelMode, Matrix, PipelineMode, PoolStats, RunRequest, ScratchStats};
use crate::graph::datasets::Dataset;
use crate::graph::Csr;
use crate::ir::spec::ModelSpec;
use crate::ir::zoo::ModelZoo;
use crate::ir::IrGraph;
use crate::isa::Program;
use crate::partition::{partition_fggp, stats as pstats, Method, Partitions};
use crate::sched::PhaseProfile;
use crate::sim::{simulate, AcceleratorConfig, SimResult};
use crate::util::report::{f, speedup, Table};
use crate::util::{geomean, mean};

pub use crate::dse::cache::{Caches, GraphCache};

/// Harness parameters.
#[derive(Clone, Copy, Debug)]
pub struct Harness {
    /// Dataset scale: graphs are generated at `1/2^scale` of paper size
    /// (see `graph::datasets`).
    pub scale: u32,
    pub accel: AcceleratorConfig,
    pub gpu: GpuConfig,
    pub hygcn: HygcnConfig,
}

impl Default for Harness {
    fn default() -> Self {
        Harness {
            scale: crate::graph::datasets::DEFAULT_SCALE,
            accel: AcceleratorConfig::switchblade(),
            gpu: GpuConfig::default(),
            hygcn: HygcnConfig::default(),
        }
    }
}

/// One (model, dataset) evaluation under a given accelerator config.
#[derive(Clone, Debug)]
pub struct EvalRow {
    pub model: Arc<ModelSpec>,
    pub dataset: Dataset,
    pub sim: SimResult,
    pub energy: EnergyResult,
    pub gpu: GpuResult,
    pub hygcn: Option<HygcnResult>,
}

impl EvalRow {
    pub fn speedup_vs_gpu(&self) -> f64 {
        self.gpu.seconds / self.sim.seconds
    }

    pub fn energy_saving_vs_gpu(&self) -> f64 {
        self.gpu.energy_j / self.energy.total_j()
    }
}

impl Harness {
    /// Compile + partition + simulate one combination (uncached; the
    /// cached path is [`Harness::eval_point`]). The spec builds at its
    /// own default dims (paper shape for the built-in zoo).
    pub fn eval_one(
        &self,
        spec: &ModelSpec,
        g: &Csr,
        accel: &AcceleratorConfig,
    ) -> (Program, Partitions, SimResult) {
        let prog = compile(&spec.graph());
        let pc = accel.partition_config(&prog);
        let parts = partition_fggp(g, pc);
        let sim = simulate(&prog, &parts, accel);
        (prog, parts, sim)
    }

    /// Simulate one (model spec, dataset, method, accel) point with
    /// program / graph / partition reuse through the cache bundle.
    pub fn eval_point(
        &self,
        spec: &ModelSpec,
        dataset: Dataset,
        method: Method,
        accel: &AcceleratorConfig,
        caches: &Caches,
    ) -> SimResult {
        let prog = caches.program(spec);
        let pc = accel.partition_config(&prog);
        let parts = caches.partitions(dataset, method, pc);
        simulate(&prog, &parts, accel)
    }

    /// Full 4×5 sweep (Fig 7/8/9/10 input), fanned out over OS threads.
    pub fn eval_all(&self, caches: &Caches) -> Vec<EvalRow> {
        let models = ModelZoo::builtin().paper_models();
        let combos: Vec<(Arc<ModelSpec>, Dataset)> = models
            .iter()
            .flat_map(|m| Dataset::ALL.iter().map(move |&d| (m.clone(), d)))
            .collect();
        let results: Mutex<Vec<EvalRow>> = Mutex::new(Vec::new());
        let results_ref = &results;
        std::thread::scope(|s| {
            for chunk in combos.chunks(combos.len().div_ceil(num_workers())) {
                s.spawn(move || {
                    for (m, d) in chunk {
                        let g = caches.graph(*d);
                        let sim = self.eval_point(m, *d, Method::Fggp, &self.accel, caches);
                        let energy = switchblade_energy(&sim, self.accel.freq_hz, true);
                        let gpu = gpu_run(&m.graph(), &g, &self.gpu);
                        let hygcn = (m.name() == "gcn")
                            .then(|| hygcn_run(&g, 2, 128, &self.hygcn));
                        results_ref.lock().unwrap().push(EvalRow {
                            model: m.clone(),
                            dataset: *d,
                            sim,
                            energy,
                            gpu,
                            hygcn,
                        });
                    }
                });
            }
        });
        let mut rows = results.into_inner().unwrap();
        rows.sort_by_key(|r| {
            (
                models.iter().position(|m| m.name() == r.model.name()),
                Dataset::ALL.iter().position(|&d| d == r.dataset),
            )
        });
        rows
    }

    // ---- Figure renderers ----------------------------------------------------

    /// Fig 7: speedup over the V100 (plus HyGCN on GCN workloads).
    pub fn fig07(&self, rows: &[EvalRow]) -> Table {
        let mut t = Table::new(
            "Fig 7 — speedup over V100 GPU (higher is better)",
            &["model", "AK", "AD", "HW", "CP", "SL", "geomean", "vs HyGCN (GCN)"],
        );
        let mut all = Vec::new();
        for m in ModelZoo::builtin().paper_models() {
            let mut cells = vec![m.display()];
            let mut sp = Vec::new();
            let mut hyg = Vec::new();
            for d in Dataset::ALL {
                let r = rows
                    .iter()
                    .find(|r| r.model.name() == m.name() && r.dataset == d)
                    .expect("row");
                sp.push(r.speedup_vs_gpu());
                cells.push(speedup(r.speedup_vs_gpu()));
                if let Some(h) = &r.hygcn {
                    hyg.push(h.seconds / r.sim.seconds);
                }
            }
            all.extend(sp.clone());
            cells.push(speedup(geomean(&sp)));
            cells.push(if hyg.is_empty() {
                "-".into()
            } else {
                speedup(geomean(&hyg))
            });
            t.row(cells);
        }
        t.row(vec![
            "ALL".into(),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
            speedup(geomean(&all)),
            "".into(),
        ]);
        t
    }

    /// Fig 8: energy saving over the V100.
    pub fn fig08(&self, rows: &[EvalRow]) -> Table {
        let mut t = Table::new(
            "Fig 8 — energy saving over V100 GPU (higher is better)",
            &["model", "AK", "AD", "HW", "CP", "SL", "geomean"],
        );
        let mut all = Vec::new();
        for m in ModelZoo::builtin().paper_models() {
            let mut cells = vec![m.display()];
            let mut sv = Vec::new();
            for d in Dataset::ALL {
                let r = rows
                    .iter()
                    .find(|r| r.model.name() == m.name() && r.dataset == d)
                    .expect("row");
                sv.push(r.energy_saving_vs_gpu());
                cells.push(speedup(r.energy_saving_vs_gpu()));
            }
            all.extend(sv.clone());
            cells.push(speedup(geomean(&sv)));
            t.row(cells);
        }
        t.row(vec![
            "ALL".into(),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
            speedup(geomean(&all)),
        ]);
        t
    }

    /// Fig 9: off-chip traffic with PLOF, normalised to the GPU
    /// operator-by-operator paradigm (lower is better).
    pub fn fig09(&self, rows: &[EvalRow]) -> Table {
        let mut t = Table::new(
            "Fig 9 — off-chip data transfer normalised to GPU op-by-op (lower is better)",
            &["model", "AK", "AD", "HW", "CP", "SL", "mean"],
        );
        for m in ModelZoo::builtin().paper_models() {
            let mut cells = vec![m.display()];
            let mut vals = Vec::new();
            for d in Dataset::ALL {
                let r = rows
                    .iter()
                    .find(|r| r.model.name() == m.name() && r.dataset == d)
                    .expect("row");
                let ratio = r.sim.traffic.total() as f64 / r.gpu.dram_bytes as f64;
                vals.push(ratio);
                cells.push(f(ratio, 3));
            }
            cells.push(f(mean(&vals), 3));
            t.row(cells);
        }
        t
    }

    /// Fig 10: overall HW utilisation, SLMT (3 sThreads) vs off (1).
    pub fn fig10(&self, caches: &Caches) -> Table {
        let mut t = Table::new(
            "Fig 10 — overall utilisation (mean of BW/VU/MU), 1 vs 3 sThreads",
            &["model", "dataset", "util@1", "util@3", "gain"],
        );
        for m in ModelZoo::builtin().paper_models() {
            for d in Dataset::ALL {
                let u1 = self
                    .eval_point(&m, d, Method::Fggp, &self.accel.with_sthreads(1), caches)
                    .overall_utilization();
                let u3 = self
                    .eval_point(&m, d, Method::Fggp, &self.accel.with_sthreads(3), caches)
                    .overall_utilization();
                t.row(vec![
                    m.display(),
                    d.code().into(),
                    f(u1, 3),
                    f(u3, 3),
                    format!("{:+.1}%", (u3 - u1) * 100.0),
                ]);
            }
        }
        t
    }

    /// Fig 11: latency vs sThread count, normalised to 1 sThread.
    pub fn fig11(&self, caches: &Caches, counts: &[u32]) -> Table {
        let mut headers: Vec<String> = vec!["model".into(), "dataset".into()];
        headers.extend(counts.iter().map(|c| format!("T={c}")));
        let mut t = Table::new(
            "Fig 11 — latency vs sThread count (normalised to T=1, lower is better)",
            &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        for m in ModelZoo::builtin().paper_models() {
            for d in Dataset::ALL {
                let base = self
                    .eval_point(&m, d, Method::Fggp, &self.accel.with_sthreads(1), caches)
                    .cycles;
                let mut cells = vec![m.display(), d.code().to_string()];
                for &c in counts {
                    let r =
                        self.eval_point(&m, d, Method::Fggp, &self.accel.with_sthreads(c), caches);
                    cells.push(f(r.cycles / base, 3));
                }
                t.row(cells);
            }
        }
        t
    }

    /// Fig 12: SEB occupancy, FGGP vs the HyGCN-style baseline.
    pub fn fig12(&self, caches: &Caches) -> Table {
        let mut t = Table::new(
            "Fig 12 — buffer occupancy rate (higher is better)",
            &["dataset", "FGGP", "DSW (HyGCN-style)"],
        );
        let gcn = ModelZoo::builtin().get("gcn").expect("builtin gcn");
        let prog = caches.program(&gcn);
        for d in Dataset::ALL {
            let pc = self.accel.partition_config(&prog);
            let occ_f = pstats::analyze(&caches.partitions(d, Method::Fggp, pc)).occupancy_rate;
            let occ_d = pstats::analyze(&caches.partitions(d, Method::Dsw, pc)).occupancy_rate;
            t.row(vec![d.code().into(), f(occ_f, 3), f(occ_d, 3)]);
        }
        t
    }

    /// Fig 13: traffic reduction and speedup from enlarging the DstBuffer
    /// (8 MB → 13 MB) under FGGP.
    pub fn fig13(&self, caches: &Caches) -> Table {
        let mut t = Table::new(
            "Fig 13 — FGGP with DB 8 MB → 13 MB: traffic ratio and speedup",
            &["dataset", "traffic 13/8", "speedup"],
        );
        let gcn = ModelZoo::builtin().get("gcn").expect("builtin gcn");
        for d in Dataset::ALL {
            let base = self.eval_point(&gcn, d, Method::Fggp, &self.accel, caches);
            let big = self.eval_point(
                &gcn,
                d,
                Method::Fggp,
                &self.accel.with_dst_buffer(13 * 1024 * 1024),
                caches,
            );
            t.row(vec![
                d.code().into(),
                f(big.traffic.total() as f64 / base.traffic.total() as f64, 3),
                speedup(base.cycles / big.cycles),
            ]);
        }
        t
    }

    /// Tbl V: area/power breakdown.
    pub fn tbl05(&self) -> Table {
        let mut t = Table::new(
            "Tbl V — area & power breakdown (TSMC 28 nm @ 1 GHz)",
            &["component", "area %", "power %"],
        );
        for (name, a, p) in tbl5_rows() {
            t.row(vec![name.into(), f(a, 2), f(p, 2)]);
        }
        t.row(vec![
            "TOTAL".into(),
            format!("{} mm2", TBL5.total_area_mm2),
            format!("{} W", TBL5.total_power_w),
        ]);
        t
    }

    /// Tbl IV: dataset summary (paper vs generated).
    pub fn tbl04(&self, caches: &Caches) -> Table {
        let mut t = Table::new(
            "Tbl IV — datasets (synthetic stand-ins at harness scale)",
            &["dataset", "paper |V|", "paper |E|", "gen |V|", "gen |E|", "deg cv"],
        );
        for d in Dataset::ALL {
            let g = caches.graph(d);
            let (pv, pe) = d.paper_size();
            t.row(vec![
                d.full_name().into(),
                pv.to_string(),
                pe.to_string(),
                g.num_vertices().to_string(),
                g.num_edges().to_string(),
                f(g.in_degree_cv(), 2),
            ]);
        }
        t
    }
}

/// One functional-executor timing probe: the `switchblade bench`
/// subcommand (and `scripts/bench.sh`, which seeds `BENCH_exec.json`)
/// reports these numbers.
#[derive(Clone, Debug)]
pub struct ExecBench {
    /// Worker-pool width of the parallel run.
    pub workers: usize,
    /// Interval-pipelining mode of the measured runs.
    pub pipeline: PipelineMode,
    /// Kernel layer of the single/parallel/sweep timings.
    pub kernel: KernelMode,
    /// Mean seconds per run, forced single worker (kernel layer).
    pub secs_single: f64,
    /// Mean seconds per run at `workers` (kernel layer).
    pub secs_parallel: f64,
    /// Mean seconds per run at `workers` through the explicit-width SIMD
    /// kernels ([`KernelMode::Simd`]) — always measured (reused from the
    /// parallel run when the probe itself runs the Simd layer), folded
    /// into the bit-identity verdict.
    pub secs_simd: f64,
    /// Mean seconds per run at `workers` with interval pipelining forced
    /// off — the sequential baseline of [`ExecBench::pipeline_speedup`].
    /// `None` when the probe itself ran with pipelining off.
    pub secs_pipeline_off: Option<f64>,
    /// Mean seconds per single-worker run through the preserved naive
    /// compute path ([`KernelMode::Naive`]) — only measured under
    /// `--profile`, so bench.sh can record kernel vs. legacy.
    pub secs_legacy: Option<f64>,
    pub vertices: usize,
    pub iters: usize,
    /// Whether every measured run agreed bit-for-bit (they must):
    /// single vs. parallel vs. pipeline-off, and — when measured — the
    /// legacy path too.
    pub bit_identical: bool,
    /// Per-(group, phase) wall-time breakdown of one profiled parallel
    /// run (`--profile` only; includes the per-group `prepare` row).
    pub profile: Option<PhaseProfile>,
    /// Scratch-arena hit/miss counters of the parallel run.
    pub scratch: ScratchStats,
    /// Intervals whose DstBuffer state was prepared under the previous
    /// interval's gather drain in one parallel run (0 with pipelining
    /// off or single-interval partitionings).
    pub prepared_intervals: u64,
    /// Persistent worker-pool counters of the parallel run: thread spawns
    /// (once per executor, never per interval), batches drained, shard
    /// throughput and lane occupancy.
    pub pool: PoolStats,
    /// `(width, mean seconds)` per worker-sweep point (`--sweep` only;
    /// widths 1/2/4/8 at the probe's kernel + pipeline mode), each folded
    /// into the bit-identity verdict.
    pub sweep: Vec<(usize, f64)>,
    /// Cross-request batch width of the amortization probe (1 = not
    /// probed).
    pub batch: usize,
    /// Cross-request amortization factor: B back-to-back solo runs over
    /// one batched run of the same B inputs (higher is better; > 1
    /// means sharing the partition walk paid off). `None` unless
    /// `batch > 1`; per-request bit-identity vs the solo runs is folded
    /// into the verdict.
    pub batch_amortization: Option<f64>,
}

impl ExecBench {
    pub fn speedup(&self) -> f64 {
        self.secs_single / self.secs_parallel
    }

    /// Kernel-layer speedup over the preserved naive path (single worker
    /// both sides); `None` unless the legacy run was measured.
    pub fn kernel_speedup(&self) -> Option<f64> {
        self.secs_legacy.map(|l| l / self.secs_single)
    }

    /// Interval-pipelining speedup at the parallel width (sequential
    /// intervals / pipelined intervals); `None` when the probe ran with
    /// pipelining off.
    pub fn pipeline_speedup(&self) -> Option<f64> {
        self.secs_pipeline_off.map(|s| s / self.secs_parallel)
    }

    /// Executor throughput at the parallel width.
    pub fn vertices_per_sec(&self) -> f64 {
        self.vertices as f64 / self.secs_parallel
    }

    /// SIMD-layer speedup over the probe's kernel layer at the parallel
    /// width (1.0 by construction when the probe itself ran Simd).
    pub fn simd_speedup(&self) -> f64 {
        self.secs_parallel / self.secs_simd
    }

    /// Publish the probe into the process metrics registry under the
    /// `exec_*` names `scripts/bench.sh` embeds into `BENCH_exec.json`
    /// (and `scripts/bench_diff.sh` gates on). One source of truth: the
    /// bench table, the stdout trailer, and the `--metrics` artifact all
    /// read the same struct this publishes.
    pub fn record_metrics(&self) {
        use crate::obs::metrics;
        metrics::gauge("exec_ms_single", self.secs_single * 1e3);
        metrics::gauge("exec_ms_parallel", self.secs_parallel * 1e3);
        metrics::counter_abs("exec_workers", self.workers as u64);
        metrics::gauge("exec_speedup", self.speedup());
        metrics::gauge("exec_vertices_per_sec", self.vertices_per_sec());
        metrics::counter_abs("exec_bitmatch", self.bit_identical as u64);
        metrics::counter_abs(
            "exec_pipeline_on",
            !matches!(self.pipeline, PipelineMode::Off) as u64,
        );
        metrics::counter_abs("exec_prepared", self.prepared_intervals);
        metrics::counter_abs("exec_scratch_hits", self.scratch.hits);
        metrics::counter_abs("exec_scratch_misses", self.scratch.misses);
        metrics::gauge("exec_scratch_hit_rate", self.scratch.hit_rate());
        metrics::gauge("exec_ms_simd", self.secs_simd * 1e3);
        metrics::gauge("exec_simd_speedup", self.simd_speedup());
        metrics::counter_abs("exec_pool_spawned", self.pool.spawned);
        metrics::counter_abs("exec_pool_batches", self.pool.batches);
        metrics::counter_abs("exec_pool_shards", self.pool.shards);
        metrics::gauge("exec_pool_utilization", self.pool.utilization());
        metrics::gauge("exec_pool_queue_depth", self.pool.queue_depth());
        for &(w, s) in &self.sweep {
            metrics::gauge(&format!("exec_ms_w{w}"), s * 1e3);
        }
        if let Some(off) = self.secs_pipeline_off {
            metrics::gauge("exec_ms_pipeline_off", off * 1e3);
        }
        if let Some(sp) = self.pipeline_speedup() {
            metrics::gauge("exec_pipeline_speedup", sp);
        }
        if let Some(legacy) = self.secs_legacy {
            metrics::gauge("exec_ms_legacy", legacy * 1e3);
        }
        if let Some(sp) = self.kernel_speedup() {
            metrics::gauge("exec_kernel_speedup", sp);
        }
        if let Some(p) = &self.profile {
            metrics::gauge("exec_profile_total_s", p.total_s());
            metrics::counter_abs(
                "exec_profile_shards",
                p.groups.iter().map(|g| g.shards).sum::<u64>(),
            );
        }
        if let Some(a) = self.batch_amortization {
            metrics::counter_abs("exec_batch", self.batch as u64);
            metrics::gauge("exec_batch_amortization", a);
        }
    }
}

/// Everything [`bench_executor`] needs, named. The probe's positional
/// argument list grew past readability (and the cross-request batch
/// axis would have doubled it again) — construct with
/// [`BenchRequest::new`] and set the knobs that differ from the
/// defaults.
#[derive(Clone, Copy, Debug)]
pub struct BenchRequest<'a> {
    pub ir: &'a IrGraph,
    pub g: &'a Csr,
    pub accel: &'a AcceleratorConfig,
    /// Worker-pool width; 0 = the partitioning's sThread count.
    pub workers: usize,
    /// Timed iterations per probe (clamped to >= 1).
    pub iters: usize,
    /// Also time the preserved naive path and record a phase profile.
    pub profile: bool,
    /// Kernel layer of the main timings (a Simd probe always rides
    /// alongside).
    pub kernel: KernelMode,
    pub pipeline: PipelineMode,
    /// Add the 1/2/4/8-worker scaling ladder.
    pub sweep: bool,
    /// Cross-request batch width for the amortization probe (B solo
    /// runs vs one batched run over the same B inputs); <= 1 skips it.
    pub batch: usize,
}

impl<'a> BenchRequest<'a> {
    pub fn new(ir: &'a IrGraph, g: &'a Csr, accel: &'a AcceleratorConfig) -> Self {
        BenchRequest {
            ir,
            g,
            accel,
            workers: 0,
            iters: 1,
            profile: false,
            kernel: KernelMode::default(),
            pipeline: PipelineMode::default(),
            sweep: false,
            batch: 1,
        }
    }
}

/// Time the shard-parallel executor against a forced single-worker run on
/// one (model IR, graph) workload. Works for any validated `IrGraph` —
/// zoo entry or user `.gnn` spec — sized from the IR's own input width.
/// `workers == 0` means "the partitioning's simulated sThread count".
/// With any pipelined mode (`bench` defaults to Interval), the probe
/// also times `PipelineMode::Off` at the parallel width — the per-mode
/// numbers `scripts/bench.sh` embeds into `BENCH_exec.json`.
/// With `profile` set, additionally times the preserved naive kernel path
/// and records a per-(group, phase) [`PhaseProfile`] of one parallel run.
/// `kernel` picks the layer of the main timings (`bench` defaults to
/// Blocked; a Simd probe is always timed alongside either way), `sweep`
/// adds a 1/2/4/8-worker scaling ladder at that layer, and `batch > 1`
/// adds the cross-request amortization probe (B solo runs vs one
/// batched run, bit-identity enforced per request).
pub fn bench_executor(req: &BenchRequest) -> ExecBench {
    fn timed(
        prog: &Program,
        parts: &Partitions,
        x: &Matrix,
        deg: &Matrix,
        workers: usize,
        iters: usize,
        mode: KernelMode,
        pipeline: PipelineMode,
    ) -> (f64, Matrix, ScratchStats, u64, PoolStats) {
        let mut ex = crate::exec::Executor::new(prog, parts)
            .with_workers(workers)
            .with_kernel_mode(mode)
            .with_pipeline_mode(pipeline);
        let run = RunRequest::new(x, deg);
        let t0 = std::time::Instant::now();
        let mut out = ex.try_run_with(&run).expect("bench run faulted").into_output();
        for _ in 1..iters {
            out = ex.try_run_with(&run).expect("bench run faulted").into_output();
        }
        (
            t0.elapsed().as_secs_f64() / iters as f64,
            out,
            ex.scratch_stats(),
            ex.prepared_intervals(),
            ex.pool_stats(),
        )
    }

    let (ir, g, accel) = (req.ir, req.g, req.accel);
    let (profile, kernel, pipeline, sweep) = (req.profile, req.kernel, req.pipeline, req.sweep);
    let iters = req.iters.max(1);
    let workers = req.workers;
    let prog = compile(ir);
    let pc = accel.partition_config(&prog);
    let parts = partition_fggp(g, pc);
    let workers = if workers == 0 {
        parts.config.num_sthreads.max(1) as usize
    } else {
        workers
    };
    let x = crate::exec::weights::init_features(11, g.num_vertices(), ir.input_dim() as usize);
    let deg = degree_column(g);
    let (secs_single, out_single, _, _, _) =
        timed(&prog, &parts, &x, &deg, 1, iters, kernel, pipeline);
    let (secs_parallel, out_parallel, scratch, prepared_intervals, pool) =
        timed(&prog, &parts, &x, &deg, workers, iters, kernel, pipeline);
    let mut bit_identical = out_single.bits_eq(&out_parallel);
    // The SIMD layer is always probed at the parallel width (reusing the
    // parallel run when it already ran Simd) so `exec_ms_simd` lands in
    // every bench artifact — and its output joins the bit verdict.
    let secs_simd = if kernel == KernelMode::Simd {
        secs_parallel
    } else {
        let (simd_s, out_simd, _, _, _) = timed(
            &prog,
            &parts,
            &x,
            &deg,
            workers,
            iters,
            KernelMode::Simd,
            pipeline,
        );
        bit_identical = bit_identical && out_single.bits_eq(&out_simd);
        simd_s
    };
    // Pipelined probes also time the sequential interval order at the
    // same width — the per-mode comparison the pipeline speedup is made
    // of — and fold its output into the bit-identity verdict.
    let secs_pipeline_off = if pipeline != PipelineMode::Off {
        let (off_s, out_off, _, _, _) = timed(
            &prog,
            &parts,
            &x,
            &deg,
            workers,
            iters,
            kernel,
            PipelineMode::Off,
        );
        bit_identical = bit_identical && out_single.bits_eq(&out_off);
        Some(off_s)
    } else {
        None
    };
    // Optional worker-scaling ladder: every width reuses the same inputs
    // and must reproduce the same bits (the canonical-order merge claim,
    // measured rather than just asserted).
    let sweep_points = if sweep {
        let mut pts = Vec::new();
        for w in [1usize, 2, 4, 8] {
            let (s, out_w, _, _, _) = timed(&prog, &parts, &x, &deg, w, iters, kernel, pipeline);
            bit_identical = bit_identical && out_single.bits_eq(&out_w);
            pts.push((w, s));
        }
        pts
    } else {
        Vec::new()
    };
    let (secs_legacy, profile_data) = if profile {
        // The legacy reference is doubly golden: naive kernels AND
        // strictly sequential intervals.
        let (legacy_s, out_legacy, _, _, _) = timed(
            &prog,
            &parts,
            &x,
            &deg,
            1,
            iters,
            KernelMode::Naive,
            PipelineMode::Off,
        );
        bit_identical = bit_identical && out_single.bits_eq(&out_legacy);
        // Warm the scratch pools with one discarded run first, so the
        // profile reflects steady-state phase costs (what the timed
        // iterations measure), not first-interval pool allocation.
        let mut ex = crate::exec::Executor::new(&prog, &parts)
            .with_workers(workers)
            .with_pipeline_mode(pipeline);
        let _ = ex
            .try_run_with(&RunRequest::new(&x, &deg))
            .expect("profile warm-up faulted");
        let mut out = ex
            .try_run_with(&RunRequest::new(&x, &deg).with_profile(true))
            .expect("profiled run faulted");
        let p = out.profile.take().expect("profile requested");
        (Some(legacy_s), Some(p))
    } else {
        (None, None)
    };
    // Cross-request amortization probe: B solo runs vs one batched run
    // over the same B seed-distinct inputs, on one warm executor.
    let batch = req.batch.max(1);
    let batch_amortization = if batch > 1 {
        let inputs: Vec<Matrix> = (0..batch)
            .map(|i| {
                crate::exec::weights::init_features(
                    11 + i as u64,
                    g.num_vertices(),
                    ir.input_dim() as usize,
                )
            })
            .collect();
        let refs: Vec<&Matrix> = inputs.iter().collect();
        let mut ex = crate::exec::Executor::new(&prog, &parts)
            .with_workers(workers)
            .with_kernel_mode(kernel)
            .with_pipeline_mode(pipeline);
        // Untimed pass on both shapes: sizes the scratch pools and
        // collects the outputs for the per-request bit verdict.
        let solo_outs: Vec<Matrix> = inputs
            .iter()
            .map(|xi| {
                let r = ex
                    .try_run_with(&RunRequest::new(xi, &deg))
                    .expect("bench solo run faulted");
                r.into_output()
            })
            .collect();
        let batched = ex
            .try_run_with(&RunRequest::batched(refs.clone(), &deg))
            .expect("bench batched run faulted");
        bit_identical = bit_identical
            && batched.outputs.len() == solo_outs.len()
            && solo_outs.iter().zip(&batched.outputs).all(|(a, b)| a.bits_eq(b));
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            for xi in &inputs {
                let _ = ex
                    .try_run_with(&RunRequest::new(xi, &deg))
                    .expect("bench solo run faulted");
            }
        }
        let solo_s = t0.elapsed().as_secs_f64() / iters as f64;
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            let _ = ex
                .try_run_with(&RunRequest::batched(refs.clone(), &deg))
                .expect("bench batched run faulted");
        }
        let batched_s = t0.elapsed().as_secs_f64() / iters as f64;
        Some(solo_s / batched_s.max(f64::MIN_POSITIVE))
    } else {
        None
    };
    ExecBench {
        workers,
        pipeline,
        kernel,
        secs_single,
        secs_parallel,
        secs_simd,
        secs_pipeline_off,
        secs_legacy,
        vertices: g.num_vertices(),
        iters,
        bit_identical,
        profile: profile_data,
        scratch,
        prepared_intervals,
        pool,
        sweep: sweep_points,
        batch,
        batch_amortization,
    }
}

/// The in-degree column every executor run needs alongside the feature
/// matrix (normalization input of the compiled programs) — one shared
/// definition for the bench/validate harnesses and the serving engine.
pub fn degree_column(g: &Csr) -> Matrix {
    let mut deg = Matrix::zeros(g.num_vertices(), 1);
    for v in 0..g.num_vertices() {
        deg.set(v, 0, g.in_degree(v as u32) as f32);
    }
    deg
}

/// One direct, cold executor run of `ir` on `g` with `seed`-derived
/// features: compile → partition (`method`) → execute, nothing cached,
/// nothing reused. This is the golden reference the serving engine is
/// differential-tested against — `serve --verify` and
/// `tests/integration_serve.rs` pin engine outputs bit-identical to it
/// (the engine's `submit_seeded` builds the same features from the same
/// seed). `workers == 0` means the partitioning's sThread count, the
/// same convention as [`crate::exec::Executor`] and the engine config.
#[allow(clippy::too_many_arguments)]
pub fn reference_run(
    ir: &IrGraph,
    g: &Csr,
    accel: &AcceleratorConfig,
    method: Method,
    workers: usize,
    kernel: KernelMode,
    pipeline: PipelineMode,
    seed: u64,
) -> Matrix {
    let prog = compile(ir);
    let parts = method.run(g, accel.partition_config(&prog));
    let x = crate::exec::weights::init_features(seed, g.num_vertices(), ir.input_dim() as usize);
    let deg = degree_column(g);
    let mut ex = crate::exec::Executor::new(&prog, &parts)
        .with_kernel_mode(kernel)
        .with_pipeline_mode(pipeline);
    if workers > 0 {
        ex = ex.with_workers(workers);
    }
    ex.try_run_with(&RunRequest::new(&x, &deg))
        .expect("reference run faulted")
        .into_output()
}

/// Validation harness used by the CLI/examples/tests: compare the
/// compiled executor against the IR reference on a sampled graph. Works
/// for any validated `IrGraph`, sized from the IR's own input width —
/// this is the differential check a user-supplied `.gnn` spec runs
/// through `switchblade validate --model-file`. Runs the executor at its
/// default (pipelined) mode; see [`validate_numerics_pipelined`].
pub fn validate_numerics(ir: &IrGraph, g: &Csr, accel: &AcceleratorConfig) -> f32 {
    validate_numerics_pipelined(ir, g, accel, PipelineMode::default())
}

/// [`validate_numerics`] with an explicit executor pipeline mode —
/// `switchblade validate --pipeline off` routes here, the CLI escape
/// hatch for diffing a suspected pipelining issue against the strictly
/// sequential reference order.
pub fn validate_numerics_pipelined(
    ir: &IrGraph,
    g: &Csr,
    accel: &AcceleratorConfig,
    pipeline: PipelineMode,
) -> f32 {
    let prog = compile(ir);
    let pc = accel.partition_config(&prog);
    let parts = partition_fggp(g, pc);
    let x = crate::exec::weights::init_features(7, g.num_vertices(), ir.input_dim() as usize);
    let deg = degree_column(g);
    let got = crate::exec::Executor::new(&prog, &parts)
        .with_pipeline_mode(pipeline)
        .try_run_with(&RunRequest::new(&x, &deg))
        .expect("validation run faulted")
        .into_output();
    let want = crate::exec::reference::evaluate(ir, g, &x);
    got.max_abs_diff(&want)
}

pub(crate) fn num_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::spec::ModelDims;

    #[test]
    fn eval_one_runs_at_tiny_scale() {
        let h = Harness {
            scale: 10,
            ..Default::default()
        };
        let cache = GraphCache::new(h.scale);
        let g = cache.get(Dataset::Ak);
        let gcn = ModelZoo::builtin().get("gcn").unwrap();
        let (prog, parts, sim) = h.eval_one(&gcn, &g, &h.accel);
        assert!(prog.num_instrs() > 0);
        parts.validate().unwrap();
        assert!(sim.cycles > 0.0);
    }

    #[test]
    fn validate_numerics_tight() {
        let cache = GraphCache::new(10);
        let g = cache.get(Dataset::Ak);
        // All five zoo entries — including sage_mean, whose Reduce::Mean
        // exercises the executor's count-normalisation path.
        for m in ModelZoo::builtin().entries() {
            let ir = m.build(ModelDims::uniform(2, 16)).unwrap();
            let diff = validate_numerics(&ir, &g, &AcceleratorConfig::switchblade());
            assert!(diff < 1e-4, "{}: {diff}", m.name());
        }
    }

    #[test]
    fn bench_executor_reports_bit_identity() {
        let cache = GraphCache::new(10);
        let g = cache.get(Dataset::Ak);
        let ir = ModelZoo::builtin()
            .get("gcn")
            .unwrap()
            .build(ModelDims::uniform(2, 32))
            .unwrap();
        let accel = AcceleratorConfig::switchblade();
        let b = bench_executor(&BenchRequest {
            workers: 2,
            kernel: KernelMode::Blocked,
            pipeline: PipelineMode::Interval,
            ..BenchRequest::new(&ir, &g, &accel)
        });
        assert!(b.bit_identical, "parallel executor diverged bitwise");
        assert!(b.secs_single > 0.0 && b.secs_parallel > 0.0);
        assert_eq!(b.workers, 2);
        assert!(b.vertices_per_sec() > 0.0);
        assert!(b.speedup() > 0.0);
        // Pipelined probes time the sequential interval order too.
        assert_eq!(b.pipeline, PipelineMode::Interval);
        let off = b.secs_pipeline_off.expect("pipeline-off baseline measured");
        assert!(off > 0.0 && b.pipeline_speedup().unwrap() > 0.0);
        // The SIMD layer is probed alongside even on a Blocked bench.
        assert!(b.secs_simd > 0.0 && b.simd_speedup() > 0.0);
        // The parallel run went through the persistent pool: threads
        // spawned once, every drained batch accounted.
        assert_eq!(b.pool.spawned, 2, "pool must spawn exactly `workers` threads");
        assert!(b.pool.batches > 0 && b.pool.shards > 0);
        // Non-profiled, non-sweep probes skip legacy/profile/sweep.
        assert!(b.secs_legacy.is_none() && b.profile.is_none());
        assert!(b.sweep.is_empty());
        assert!(b.scratch.hits + b.scratch.misses > 0);
    }

    #[test]
    fn bench_executor_sweeps_workers_on_the_simd_layer() {
        let cache = GraphCache::new(11);
        let g = cache.get(Dataset::Ak);
        let ir = ModelZoo::builtin()
            .get("gcn")
            .unwrap()
            .build(ModelDims::uniform(2, 16))
            .unwrap();
        let accel = AcceleratorConfig::switchblade();
        let b = bench_executor(&BenchRequest {
            workers: 2,
            kernel: KernelMode::Simd,
            pipeline: PipelineMode::Interval,
            sweep: true,
            ..BenchRequest::new(&ir, &g, &accel)
        });
        assert!(b.bit_identical, "simd sweep diverged bitwise");
        assert_eq!(b.kernel, KernelMode::Simd);
        // A Simd probe reuses its own parallel run as the simd number.
        assert_eq!(b.secs_simd, b.secs_parallel);
        let widths: Vec<usize> = b.sweep.iter().map(|&(w, _)| w).collect();
        assert_eq!(widths, vec![1, 2, 4, 8]);
        assert!(b.sweep.iter().all(|&(_, s)| s > 0.0));
    }

    #[test]
    fn bench_executor_profile_covers_legacy_and_phases() {
        let cache = GraphCache::new(11);
        let g = cache.get(Dataset::Ak);
        let ir = ModelZoo::builtin()
            .get("gcn")
            .unwrap()
            .build(ModelDims::uniform(2, 16))
            .unwrap();
        let accel = AcceleratorConfig::switchblade();
        let b = bench_executor(&BenchRequest {
            workers: 2,
            profile: true,
            kernel: KernelMode::Blocked,
            pipeline: PipelineMode::Interval,
            ..BenchRequest::new(&ir, &g, &accel)
        });
        assert!(b.bit_identical, "kernel/legacy/pipeline/parallel runs diverged");
        let legacy = b.secs_legacy.expect("legacy timing measured");
        assert!(legacy > 0.0 && b.kernel_speedup().unwrap() > 0.0);
        let p = b.profile.as_ref().expect("phase profile recorded");
        assert!(!p.groups.is_empty());
        assert!(p.groups.iter().map(|g| g.shards).sum::<u64>() > 0);
        assert!(p.to_json().contains("\"groups\""));
    }

    #[test]
    fn bench_executor_pipeline_off_is_sequential() {
        let cache = GraphCache::new(11);
        let g = cache.get(Dataset::Ak);
        let ir = ModelZoo::builtin()
            .get("gcn")
            .unwrap()
            .build(ModelDims::uniform(2, 16))
            .unwrap();
        let accel = AcceleratorConfig::switchblade();
        let b = bench_executor(&BenchRequest {
            workers: 1,
            kernel: KernelMode::Blocked,
            pipeline: PipelineMode::Off,
            ..BenchRequest::new(&ir, &g, &accel)
        });
        assert!(b.bit_identical);
        assert_eq!(b.pipeline, PipelineMode::Off);
        // No pipelined run, no baseline to compare against, no prefetch.
        assert!(b.secs_pipeline_off.is_none() && b.pipeline_speedup().is_none());
        assert_eq!(b.prepared_intervals, 0, "off mode must not prefetch");
        // Un-probed batch axis reports its absence.
        assert_eq!(b.batch, 1);
        assert!(b.batch_amortization.is_none());
    }

    #[test]
    fn bench_executor_batch_probe_amortizes_and_matches_bits() {
        let cache = GraphCache::new(11);
        let g = cache.get(Dataset::Ak);
        let ir = ModelZoo::builtin()
            .get("gcn")
            .unwrap()
            .build(ModelDims::uniform(2, 16))
            .unwrap();
        let accel = AcceleratorConfig::switchblade();
        let b = bench_executor(&BenchRequest {
            workers: 2,
            batch: 3,
            ..BenchRequest::new(&ir, &g, &accel)
        });
        // The probe folds per-request batched-vs-solo bit equality into
        // the overall verdict.
        assert!(b.bit_identical, "batched outputs diverged from solo runs");
        assert_eq!(b.batch, 3);
        let a = b.batch_amortization.expect("batch probe measured");
        assert!(a > 0.0, "amortization factor must be positive, got {a}");
    }

    #[test]
    fn tbl05_renders() {
        let t = Harness::default().tbl05();
        let s = t.render();
        assert!(s.contains("RAM"));
        assert!(s.contains("28.25"));
    }
}
