//! The PLOF compiler (paper §IV-B, §V-C): maps a unified computational
//! graph onto PLOF phase groups, generates ISA code, performs
//! memory-symbol liveness merging, and exports the partitioning
//! parameters (`dim_src`, `dim_edge`).
//!
//! ## Phase construction (§V-C2)
//!
//! 1. **Gather depth** — for every node, the number of `Gather` ops on the
//!    longest input path (`IrGraph::gather_depth`). Gather nodes of depth
//!    `g` terminate PLOF group `g`; the model needs `G = max depth + 1`
//!    groups, each a full dual-sliding-window sweep (Alg 2).
//! 2. **Edge-node groups** — an edge-located op is scheduled in the group
//!    of the *earliest* gather that (transitively) consumes it, but never
//!    before its inputs exist. Edge values crossing a group boundary are
//!    spilled (`ST.E`) and reloaded (`LD.E`) — this is where PLOF still
//!    pays DRAM traffic, and exactly at phase boundaries as §IV-B states.
//! 3. **Vertex-node placement** —
//!    * depth ≥ 1 ⇒ ApplyPhase of group `depth − 1` (computed once per
//!      destination interval, `Dim::V` rows);
//!    * depth 0 vertex values have no "home": they are *rematerialised*
//!      per role — on shard source rows (`Dim::S`) inside the GatherPhase
//!      that needs them for `ScatterSrc`, or on interval rows (`Dim::V`)
//!      inside the ScatterPhase for `ScatterDst`. Recomputing a depth-0
//!      chain per shard trades FLOPs for DRAM traffic, which is the
//!      paper's central bandwidth-over-compute trade (§III-A).
//!
//! ## Code generation (§V-C3)
//!
//! Every IR value gets per-role memory symbols (`D`/`S`/`E` spaces);
//! memory instructions are inserted whenever a symbol is not produced in
//! the phase that consumes it. A final linear-scan pass merges dead
//! symbols of identical shape (`liveness`), then `dim_src`/`dim_edge` are
//! the per-group maxima of resident S/E widths.

mod codegen;
mod liveness;

pub use codegen::{compile, compile_with, CompilerOptions};

#[cfg(test)]
mod tests;
