//! Memory-symbol liveness analysis and merging (paper §V-C3): "GC first
//! calculates the size of each symbol and then merges two symbols of the
//! same size if the former is no longer in use", improving on-chip buffer
//! utilisation and shrinking `dim_src` / `dim_edge` / `dim_dst`.
//!
//! Implementation: a linear scan over the static instruction order (groups
//! in sequence; scatter → gather → apply inside a group). Two symbols may
//! share a slot iff they live in the same space, have the same column
//! width and the same row-dimension class — the address arithmetic the
//! hardware controller performs (§V-A) depends on all three.

use std::collections::HashMap;

use crate::isa::{Instr, PhaseGroup, Space, Sym, SymInfo, SymbolTable};

/// Merge dead symbols; returns rewritten groups and the new symbol table.
pub fn merge_symbols(
    groups: Vec<PhaseGroup>,
    symbols: &SymbolTable,
) -> (Vec<PhaseGroup>, SymbolTable) {
    // 1. Linearise and compute live ranges [first_def, last_touch].
    let mut order: Vec<&Instr> = Vec::new();
    // Gather phases are *loops* at runtime (re-executed per shard), so
    // record their static extents: D-space symbols touched inside one are
    // loop-carried (accumulators, DstToEdge sources) and must stay live —
    // and slot-exclusive — for the whole phase.
    let mut gather_extents: Vec<(usize, usize)> = Vec::new();
    for g in &groups {
        order.extend(g.scatter.iter());
        let gstart = order.len();
        order.extend(g.gather.iter());
        gather_extents.push((gstart, order.len()));
        order.extend(g.apply.iter());
    }
    let mut first: HashMap<Sym, usize> = HashMap::new();
    let mut last: HashMap<Sym, usize> = HashMap::new();
    for (idx, i) in order.iter().enumerate() {
        for s in i.def().into_iter().chain(i.uses()) {
            if s.space == Space::W {
                continue; // weights are resident, never merged
            }
            let (mut f, mut l) = (idx, idx);
            if s.space == Space::D {
                // Extend across any gather loop containing this touch.
                for &(gs, ge) in &gather_extents {
                    if idx >= gs && idx < ge {
                        f = gs;
                        l = ge.saturating_sub(1);
                    }
                }
            }
            let e = first.entry(s).or_insert(f);
            *e = (*e).min(f);
            let e = last.entry(s).or_insert(l);
            *e = (*e).max(l);
        }
    }

    // 2. Greedy linear scan per (space, cols, rows) class.
    #[derive(PartialEq, Eq, Hash)]
    struct Class {
        space: Space,
        cols: u32,
        rows: crate::isa::Dim,
    }
    let mut ranges: Vec<(Sym, usize, usize)> = first
        .iter()
        .map(|(&s, &f)| (s, f, last[&s]))
        .collect();
    ranges.sort_by_key(|&(_, f, _)| f);

    let mut free: HashMap<Class, Vec<(Sym, usize)>> = HashMap::new(); // (slot, free_from)
    let mut next_slot: HashMap<Space, u32> = HashMap::new();
    let mut remap: HashMap<Sym, Sym> = HashMap::new();
    let mut new_table = SymbolTable::default();
    // Keep W symbols as-is.
    for info in symbols.iter() {
        if info.sym.space == Space::W {
            new_table.insert(info.clone());
        }
    }

    for (sym, f, l) in ranges {
        let info = symbols.get(sym).expect("symbol in table").clone();
        let class = Class {
            space: sym.space,
            cols: info.cols,
            rows: info.rows,
        };
        let slots = free.entry(class).or_default();
        // Reuse the slot that freed earliest, if it freed before this def.
        let slot = if let Some(pos) = slots.iter().position(|&(_, when)| when <= f) {
            slots.remove(pos).0
        } else {
            let id = next_slot.entry(sym.space).or_insert(0);
            let s = Sym::new(sym.space, *id);
            *id += 1;
            new_table.insert(SymInfo {
                sym: s,
                cols: info.cols,
                rows: info.rows,
                origin: info.origin.clone(),
            });
            s
        };
        remap.insert(sym, slot);
        let class = Class {
            space: sym.space,
            cols: info.cols,
            rows: info.rows,
        };
        free.entry(class).or_default().push((slot, l + 1));
    }

    // 3. Rewrite instructions.
    let rw = |s: Sym| -> Sym {
        if s.space == Space::W {
            s
        } else {
            remap[&s]
        }
    };
    let groups = groups
        .into_iter()
        .map(|g| PhaseGroup {
            scatter: g.scatter.into_iter().map(|i| rewrite(i, &rw)).collect(),
            gather: g.gather.into_iter().map(|i| rewrite(i, &rw)).collect(),
            apply: g.apply.into_iter().map(|i| rewrite(i, &rw)).collect(),
        })
        .collect();

    (groups, new_table)
}

fn rewrite(i: Instr, rw: &impl Fn(Sym) -> Sym) -> Instr {
    match i {
        Instr::Elw {
            op,
            dst,
            a,
            b,
            broadcast_b,
            rows,
            cols,
        } => Instr::Elw {
            op,
            dst: rw(dst),
            a: rw(a),
            b: b.map(rw),
            broadcast_b,
            rows,
            cols,
        },
        Instr::RowScale {
            dst,
            a,
            scale,
            rows,
            cols,
        } => Instr::RowScale {
            dst: rw(dst),
            a: rw(a),
            scale: rw(scale),
            rows,
            cols,
        },
        Instr::Concat {
            dst,
            a,
            b,
            rows,
            cols_a,
            cols_b,
        } => Instr::Concat {
            dst: rw(dst),
            a: rw(a),
            b: rw(b),
            rows,
            cols_a,
            cols_b,
        },
        Instr::Dmm { dst, a, w, rows, k, n } => Instr::Dmm {
            dst: rw(dst),
            a: rw(a),
            w: rw(w),
            rows,
            k,
            n,
        },
        Instr::Scatter { dir, dst, src, cols } => Instr::Scatter {
            dir,
            dst: rw(dst),
            src: rw(src),
            cols,
        },
        Instr::Gather {
            reduce,
            dst,
            src,
            cols,
        } => Instr::Gather {
            reduce,
            dst: rw(dst),
            src: rw(src),
            cols,
        },
        Instr::FusedGather {
            reduce,
            dst,
            src,
            scale,
            cols,
        } => Instr::FusedGather {
            reduce,
            dst: rw(dst),
            src: rw(src),
            scale: scale.map(rw),
            cols,
        },
        Instr::Ld { sym, data, rows, cols } => Instr::Ld {
            sym: rw(sym),
            data,
            rows,
            cols,
        },
        Instr::St { sym, data, rows, cols } => Instr::St {
            sym: rw(sym),
            data,
            rows,
            cols,
        },
    }
}
