//! ISA code generation from the unified computational graph.

use std::collections::{HashMap, HashSet};

use crate::ir::{IrGraph, IrOp, Loc, NodeId};
use crate::isa::{
    DataRef, Dim, Instr, PhaseGroup, Program, ScatterDir, Space, Sym, SymInfo, SymbolTable,
    WeightInfo,
};

/// Compiler feature toggles — the ablation axes of the instruction-level
/// design choices (DESIGN.md §5; `examples/ablation.rs` sweeps them).
#[derive(Clone, Copy, Debug)]
pub struct CompilerOptions {
    /// PLOF peephole: fuse Scatter(+RowScale)+Gather into `GSCTR`,
    /// removing the `num_edge × dim_edge` Equ. 1 term.
    pub fuse_gathers: bool,
    /// Precompute depth-0 vertex projections once per vertex (prologue
    /// sweep) instead of re-running the MU per shard occurrence.
    pub prologue: bool,
}

impl Default for CompilerOptions {
    fn default() -> Self {
        CompilerOptions {
            fuse_gathers: true,
            prologue: true,
        }
    }
}

/// Compile a validated IR graph into a PLOF program (default options).
pub fn compile(ir: &IrGraph) -> Program {
    compile_with(ir, CompilerOptions::default())
}

/// Compile with explicit feature toggles.
pub fn compile_with(ir: &IrGraph, opts: CompilerOptions) -> Program {
    let _span = crate::obs::trace::span(
        crate::obs::trace::names::COMPILE,
        crate::obs::trace::cat::FRONTEND,
        crate::obs::trace::TRACK_MAIN,
    );
    ir.validate().expect("IR must validate before compilation");
    let mut cg = Codegen::new(ir);
    cg.opts = opts;
    cg.assign_groups();
    if opts.prologue {
        cg.assign_prologue();
    }
    cg.analyze_stores();
    cg.emit_all();
    cg.finish()
}


struct Codegen<'a> {
    opts: CompilerOptions,
    ir: &'a IrGraph,
    depth: Vec<u32>,
    users: Vec<Vec<NodeId>>,
    num_groups: u32,
    /// Group assignment for edge-located nodes (incl. gathers).
    egroup: Vec<u32>,
    /// Depth-0 vertex DMM nodes precomputed once per vertex in a prologue
    /// sweep (computing them per shard would replicate MU work by the
    /// source-redundancy factor — see module docs).
    prologue: Vec<NodeId>,
    /// Vertex nodes that must be spilled to DRAM (`ST.D`) by their
    /// producing group.
    store_d: HashSet<NodeId>,
    /// Edge nodes that must be spilled (`ST.E`) by their producing group.
    store_e: HashSet<NodeId>,
    // Symbol allocation.
    next_id: HashMap<Space, u32>,
    symbols: SymbolTable,
    d_sym: HashMap<NodeId, Sym>,
    s_sym: HashMap<(u32, NodeId), Sym>,
    e_sym: HashMap<NodeId, Sym>,
    w_sym: HashMap<NodeId, Sym>,
    weights: Vec<WeightInfo>,
    // Per-group emission state.
    groups: Vec<PhaseGroup>,
    d_resident: HashSet<NodeId>,
    e_loaded: HashSet<NodeId>,
}

impl<'a> Codegen<'a> {
    fn new(ir: &'a IrGraph) -> Self {
        let depth = ir.gather_depth();
        let users = ir.users();
        // Models without any GTR still get one group (pure ApplyPhase).
        let num_groups = ir.num_groups().max(1);
        Codegen {
            opts: CompilerOptions::default(),
            ir,
            depth,
            users,
            num_groups,
            egroup: vec![u32::MAX; ir.nodes.len()],
            prologue: Vec::new(),
            store_d: HashSet::new(),
            store_e: HashSet::new(),
            next_id: HashMap::new(),
            symbols: SymbolTable::default(),
            d_sym: HashMap::new(),
            s_sym: HashMap::new(),
            e_sym: HashMap::new(),
            w_sym: HashMap::new(),
            weights: Vec::new(),
            groups: Vec::new(),
            d_resident: HashSet::new(),
            e_loaded: HashSet::new(),
        }
    }

    fn node(&self, n: NodeId) -> &crate::ir::Node {
        &self.ir.nodes[n]
    }

    fn is_gather(&self, n: NodeId) -> bool {
        matches!(self.node(n).op, IrOp::Gather(_))
    }

    fn is_edge(&self, n: NodeId) -> bool {
        self.node(n).loc == Loc::Edge
    }

    /// The sweep that *produces* this vertex value in D space: `-1` for
    /// the prologue, `g` for group g's gather/apply, None for inputs and
    /// rematerialised depth-0 computes.
    fn produced_group(&self, n: NodeId) -> Option<i64> {
        if self.prologue.contains(&n) {
            return Some(-1);
        }
        match self.node(n).op {
            IrOp::Input | IrOp::Degree | IrOp::Weight { .. } | IrOp::Bias { .. } => None,
            IrOp::Gather(_) => Some(self.depth[n] as i64),
            _ if self.node(n).loc == Loc::Vertex => {
                if self.depth[n] >= 1 {
                    Some(self.depth[n] as i64 - 1)
                } else {
                    None
                }
            }
            _ => None,
        }
    }

    /// Pick the prologue set: depth-0 vertex `Dmm` nodes. Their
    /// (cheap, ELW-only) consumers still rematerialise per role, but read
    /// the stored projection instead of re-running the MU per shard.
    fn assign_prologue(&mut self) {
        for n in 0..self.ir.nodes.len() {
            if self.node(n).loc == Loc::Vertex
                && self.depth[n] == 0
                && matches!(self.node(n).op, IrOp::Dmm)
            {
                self.prologue.push(n);
            }
        }
    }

    /// Step 2 of phase construction: edge-node groups (see module docs).
    fn assign_groups(&mut self) {
        // Reverse topological order = reverse insertion order.
        for id in (0..self.ir.nodes.len()).rev() {
            let node = self.node(id);
            if !self.is_edge(id) {
                continue;
            }
            if self.is_gather(id) {
                unreachable!("gathers are vertex-located");
            }
            let mut g = u32::MAX;
            for &u in &self.users[id] {
                let ug = if self.is_gather(u) {
                    self.depth[u]
                } else if self.is_edge(u) {
                    self.egroup[u]
                } else {
                    continue;
                };
                g = g.min(ug);
            }
            // An edge value consumed by no gather (dead end) stays at its
            // own depth; model outputs are vertex-located so this only
            // happens in synthetic tests.
            if g == u32::MAX {
                g = self.depth[id];
            }
            assert!(
                g >= self.depth[id],
                "edge node {} ({}) scheduled before its inputs exist",
                id,
                node.name
            );
            self.egroup[id] = g;
        }
    }

    /// Decide which values must round-trip through DRAM.
    fn analyze_stores(&mut self) {
        for u in 0..self.ir.nodes.len() {
            match self.node(u).op {
                IrOp::ScatterSrc => {
                    // Source rows always stream from DRAM; the scattered
                    // vertex value must be stored unless it is an input or
                    // a rematerialised depth-0 chain.
                    let i = self.node(u).inputs[0];
                    if self.produced_group(i).is_some() {
                        self.store_d.insert(i);
                    }
                }
                IrOp::ScatterDst => {
                    let i = self.node(u).inputs[0];
                    if let Some(pg) = self.produced_group(i) {
                        let gu = self.egroup[u] as i64;
                        assert!(
                            pg < gu,
                            "ScatterDst consumes a value produced in the same sweep"
                        );
                        self.store_d.insert(i);
                    }
                }
                IrOp::Output => {
                    let i = self.node(u).inputs[0];
                    if self.produced_group(i).is_some() {
                        self.store_d.insert(i);
                    }
                }
                _ if self.is_edge(u) => {
                    let inputs = self.node(u).inputs.clone();
                    for i in inputs {
                        if self.is_edge(i) && self.egroup[i] < self.egroup[u] {
                            self.store_e.insert(i);
                        }
                    }
                }
                _ if self.node(u).loc == Loc::Vertex => {
                    // Vertex compute consuming vertex values from earlier
                    // sweeps loads them via LD.D/LD.S — they must be
                    // stored. Homeless (depth-0) chains rematerialise in
                    // whatever sweep consumes them, so *any* produced
                    // input of theirs needs a store.
                    let hu = self.home(u);
                    let inputs = self.node(u).inputs.clone();
                    for i in inputs {
                        if self.node(i).loc != Loc::Vertex {
                            continue;
                        }
                        match (self.produced_group(i), hu) {
                            (Some(pg), Some(hu)) if pg < hu as i64 => {
                                self.store_d.insert(i);
                            }
                            (Some(_), None) if self.home(u).is_none()
                                && self.produced_group(u).is_none() =>
                            {
                                self.store_d.insert(i);
                            }
                            _ => {}
                        }
                    }
                }
                _ => {}
            }
        }
    }

    /// ApplyPhase group hosting this vertex compute node, if any.
    fn home(&self, n: NodeId) -> Option<u32> {
        match self.node(n).op {
            IrOp::Input | IrOp::Degree | IrOp::Weight { .. } | IrOp::Bias { .. } => None,
            IrOp::Gather(_) => None, // produced by the gather phase itself
            IrOp::Output => Some(self.num_groups - 1),
            _ if self.node(n).loc == Loc::Vertex => {
                if self.depth[n] >= 1 {
                    Some(self.depth[n] - 1)
                } else {
                    None
                }
            }
            _ => None,
        }
    }

    // ---- symbol helpers -----------------------------------------------------

    fn alloc(&mut self, space: Space, cols: u32, rows: Dim, origin: &str) -> Sym {
        let id = self.next_id.entry(space).or_insert(0);
        let sym = Sym::new(space, *id);
        *id += 1;
        self.symbols.insert(SymInfo {
            sym,
            cols,
            rows,
            origin: origin.to_string(),
        });
        sym
    }

    fn weight_sym(&mut self, n: NodeId) -> Sym {
        if let Some(&s) = self.w_sym.get(&n) {
            return s;
        }
        let node = self.node(n).clone();
        let (rows, seed) = match node.op {
            IrOp::Weight { rows, seed } => (rows, seed),
            IrOp::Bias { seed } => (1, seed),
            _ => panic!("not a weight node"),
        };
        let sym = self.alloc(Space::W, node.cols, Dim::Lit(rows), &node.name);
        self.weights.push(WeightInfo {
            sym,
            rows,
            cols: node.cols,
            seed,
        });
        self.w_sym.insert(n, sym);
        sym
    }

    fn data_ref(&self, n: NodeId) -> DataRef {
        match self.node(n).op {
            IrOp::Input => DataRef::Input,
            IrOp::Degree => DataRef::Degree,
            _ => DataRef::Node(n),
        }
    }

    // ---- materialisation ----------------------------------------------------

    /// Materialise vertex value `n` on the current shard's source rows
    /// (S space) inside group `g`'s GatherPhase.
    fn mat_s(&mut self, n: NodeId, g: u32, out: &mut Vec<Instr>) -> Sym {
        if let Some(&s) = self.s_sym.get(&(g, n)) {
            return s;
        }
        let node = self.node(n).clone();
        assert_eq!(node.loc, Loc::Vertex, "mat_s on non-vertex {}", node.name);
        let sym = match node.op {
            IrOp::Input | IrOp::Degree => {
                let sym = self.alloc(Space::S, node.cols, Dim::S, &node.name);
                out.push(Instr::Ld {
                    sym,
                    data: self.data_ref(n),
                    rows: Dim::S,
                    cols: node.cols,
                });
                sym
            }
            _ if self.produced_group(n).is_some() => {
                // Stored by an earlier sweep: stream source rows.
                debug_assert!(self.produced_group(n).unwrap() < g as i64);
                debug_assert!(self.store_d.contains(&n));
                let sym = self.alloc(Space::S, node.cols, Dim::S, &node.name);
                out.push(Instr::Ld {
                    sym,
                    data: DataRef::Node(n),
                    rows: Dim::S,
                    cols: node.cols,
                });
                sym
            }
            _ => {
                // Depth-0 compute chain: rematerialise on shard rows.
                let sym = self.alloc(Space::S, node.cols, Dim::S, &node.name);
                self.emit_compute(n, sym, Dim::S, g, RoleCtx::SrcRows, out);
                sym
            }
        };
        self.s_sym.insert((g, n), sym);
        sym
    }

    /// Materialise vertex value `n` on the current destination interval
    /// (D space), emitting into `out` (a ScatterPhase or ApplyPhase list).
    fn mat_d(&mut self, n: NodeId, g: u32, out: &mut Vec<Instr>) -> Sym {
        if self.d_resident.contains(&n) {
            return self.d_sym[&n];
        }
        let node = self.node(n).clone();
        assert_eq!(node.loc, Loc::Vertex, "mat_d on non-vertex {}", node.name);
        let sym = self.d_sym_for(n);
        match node.op {
            IrOp::Input | IrOp::Degree => {
                out.push(Instr::Ld {
                    sym,
                    data: self.data_ref(n),
                    rows: Dim::V,
                    cols: node.cols,
                });
            }
            _ if self.produced_group(n).is_some_and(|pg| pg < g as i64) => {
                debug_assert!(self.store_d.contains(&n));
                out.push(Instr::Ld {
                    sym,
                    data: DataRef::Node(n),
                    rows: Dim::V,
                    cols: node.cols,
                });
            }
            _ if self.produced_group(n) == Some(g as i64) => {
                panic!(
                    "mat_d of {} before its producer ran in group {g}",
                    node.name
                );
            }
            _ => {
                // Depth-0 chain rematerialised on interval rows.
                self.emit_compute(n, sym, Dim::V, g, RoleCtx::DstRows, out);
            }
        }
        self.d_resident.insert(n);
        sym
    }

    /// Materialise a vertex value inside the prologue sweep (inputs and
    /// cheap depth-0 chains only — prologue nodes are emitted in topo
    /// order so their prologue deps are already resident).
    fn mat_d_pro(&mut self, n: NodeId, out: &mut Vec<Instr>) -> Sym {
        if self.d_resident.contains(&n) {
            return self.d_sym[&n];
        }
        let node = self.node(n).clone();
        let sym = self.d_sym_for(n);
        match node.op {
            IrOp::Input | IrOp::Degree => {
                out.push(Instr::Ld {
                    sym,
                    data: self.data_ref(n),
                    rows: Dim::V,
                    cols: node.cols,
                });
            }
            _ => {
                // depth-0 ELW chain.
                let inputs = node.inputs.clone();
                for i in inputs {
                    if self.node(i).loc == Loc::Vertex {
                        self.mat_d_pro(i, out);
                    }
                }
                self.emit_compute(n, sym, Dim::V, 0, RoleCtx::DstRows, out);
            }
        }
        self.d_resident.insert(n);
        sym
    }

    fn d_sym_for(&mut self, n: NodeId) -> Sym {
        if let Some(&s) = self.d_sym.get(&n) {
            return s;
        }
        let (cols, name) = (self.node(n).cols, self.node(n).name.clone());
        let sym = self.alloc(Space::D, cols, Dim::V, &name);
        self.d_sym.insert(n, sym);
        sym
    }

    fn e_sym_for(&mut self, n: NodeId) -> Sym {
        if let Some(&s) = self.e_sym.get(&n) {
            return s;
        }
        let (cols, name) = (self.node(n).cols, self.node(n).name.clone());
        let sym = self.alloc(Space::E, cols, Dim::E, &name);
        self.e_sym.insert(n, sym);
        sym
    }

    /// Emit the compute instruction for node `n` writing `dst` with row
    /// dimension `rows`. Operands are materialised in the same role.
    fn emit_compute(
        &mut self,
        n: NodeId,
        dst: Sym,
        rows: Dim,
        g: u32,
        role: RoleCtx,
        out: &mut Vec<Instr>,
    ) {
        let node = self.node(n).clone();
        let operand = |cg: &mut Self, i: NodeId, out: &mut Vec<Instr>| -> Sym {
            let inode = cg.node(i).clone();
            match inode.op {
                IrOp::Weight { .. } | IrOp::Bias { .. } => cg.weight_sym(i),
                _ => match role {
                    RoleCtx::SrcRows => cg.mat_s(i, g, out),
                    RoleCtx::DstRows => cg.mat_d(i, g, out),
                    RoleCtx::EdgeRows => {
                        if inode.loc == Loc::Edge {
                            cg.edge_operand(i, g, out)
                        } else {
                            panic!("vertex operand {} in edge compute", inode.name)
                        }
                    }
                },
            }
        };
        match node.op {
            IrOp::Dmm => {
                let a = operand(self, node.inputs[0], out);
                let w = self.weight_sym(node.inputs[1]);
                let k = self.node(node.inputs[0]).cols;
                out.push(Instr::Dmm {
                    dst,
                    a,
                    w,
                    rows,
                    k,
                    n: node.cols,
                });
            }
            IrOp::Unary(op) => {
                let a = operand(self, node.inputs[0], out);
                out.push(Instr::Elw {
                    op,
                    dst,
                    a,
                    b: None,
                    broadcast_b: false,
                    rows,
                    cols: node.cols,
                });
            }
            IrOp::Binary(op) => {
                let a = operand(self, node.inputs[0], out);
                let bnode = node.inputs[1];
                let is_bias = matches!(self.node(bnode).op, IrOp::Bias { .. });
                let b = operand(self, bnode, out);
                out.push(Instr::Elw {
                    op,
                    dst,
                    a,
                    b: Some(b),
                    broadcast_b: is_bias,
                    rows,
                    cols: node.cols,
                });
            }
            IrOp::RowScale => {
                let a = operand(self, node.inputs[0], out);
                let scale = operand(self, node.inputs[1], out);
                out.push(Instr::RowScale {
                    dst,
                    a,
                    scale,
                    rows,
                    cols: node.cols,
                });
            }
            IrOp::Concat => {
                let a = operand(self, node.inputs[0], out);
                let b = operand(self, node.inputs[1], out);
                out.push(Instr::Concat {
                    dst,
                    a,
                    b,
                    rows,
                    cols_a: self.node(node.inputs[0]).cols,
                    cols_b: self.node(node.inputs[1]).cols,
                });
            }
            ref op => panic!("emit_compute on {op:?} ({})", node.name),
        }
    }

    /// Resolve an edge operand inside group `g`'s GatherPhase: either it
    /// was computed earlier in this phase (topo order), or it was spilled
    /// by an earlier group and needs an `LD.E`.
    fn edge_operand(&mut self, i: NodeId, g: u32, out: &mut Vec<Instr>) -> Sym {
        let sym = self.e_sym_for(i);
        if self.egroup[i] < g && !self.e_loaded.contains(&i) {
            debug_assert!(self.store_e.contains(&i));
            out.push(Instr::Ld {
                sym,
                data: DataRef::Node(i),
                rows: Dim::E,
                cols: self.node(i).cols,
            });
            self.e_loaded.insert(i);
        }
        sym
    }

    // ---- per-group emission -------------------------------------------------

    fn emit_all(&mut self) {
        // Prologue sweep: per-vertex projections computed once and stored
        // (a PhaseGroup with only a ScatterPhase — the iThread pre-compute
        // role of §V-B2).
        if !self.prologue.is_empty() {
            self.d_resident.clear();
            let mut instrs = Vec::new();
            let order = self.prologue.clone();
            for n in order {
                let node = self.node(n).clone();
                for &i in &node.inputs {
                    if self.node(i).loc == Loc::Vertex {
                        self.mat_d_pro(i, &mut instrs);
                    }
                }
                let dst = self.d_sym_for(n);
                self.emit_compute(n, dst, Dim::V, 0, RoleCtx::DstRows, &mut instrs);
                self.d_resident.insert(n);
                instrs.push(Instr::St {
                    sym: dst,
                    data: DataRef::Node(n),
                    rows: Dim::V,
                    cols: node.cols,
                });
            }
            self.groups.push(PhaseGroup {
                scatter: instrs,
                gather: Vec::new(),
                apply: Vec::new(),
            });
        }
        for g in 0..self.num_groups {
            self.d_resident.clear();
            self.e_loaded.clear();
            let mut group = PhaseGroup::default();

            // ScatterPhase: interval-side values feeding ScatterDst ops of
            // this group.
            for n in 0..self.ir.nodes.len() {
                if matches!(self.node(n).op, IrOp::ScatterDst) && self.egroup[n] == g {
                    let input = self.node(n).inputs[0];
                    let mut instrs = std::mem::take(&mut group.scatter);
                    self.mat_d(input, g, &mut instrs);
                    group.scatter = instrs;
                }
            }

            // GatherPhase: all edge nodes assigned to this group plus the
            // gathers terminating it, in topological order.
            for n in 0..self.ir.nodes.len() {
                let node = self.node(n).clone();
                if self.is_gather(n) && self.depth[n] == g {
                    let mut instrs = std::mem::take(&mut group.gather);
                    let src = self.edge_operand(node.inputs[0], g, &mut instrs);
                    let dst = self.d_sym_for(n);
                    let IrOp::Gather(reduce) = node.op else { unreachable!() };
                    instrs.push(Instr::Gather {
                        reduce,
                        dst,
                        src,
                        cols: node.cols,
                    });
                    group.gather = instrs;
                    self.d_resident.insert(n);
                    continue;
                }
                if !self.is_edge(n) || self.egroup[n] != g {
                    continue;
                }
                let mut instrs = std::mem::take(&mut group.gather);
                match node.op {
                    IrOp::ScatterSrc => {
                        let s = self.mat_s(node.inputs[0], g, &mut instrs);
                        let dst = self.e_sym_for(n);
                        instrs.push(Instr::Scatter {
                            dir: ScatterDir::SrcToEdge,
                            dst,
                            src: s,
                            cols: node.cols,
                        });
                    }
                    IrOp::ScatterDst => {
                        // Interval data was prepared by this group's
                        // ScatterPhase (or an earlier group + LD.D there).
                        let input = node.inputs[0];
                        assert!(
                            self.d_resident.contains(&input),
                            "ScatterDst input {} not resident",
                            self.node(input).name
                        );
                        let src = self.d_sym[&input];
                        let dst = self.e_sym_for(n);
                        instrs.push(Instr::Scatter {
                            dir: ScatterDir::DstToEdge,
                            dst,
                            src,
                            cols: node.cols,
                        });
                    }
                    _ => {
                        let dst = self.e_sym_for(n);
                        self.emit_compute(n, dst, Dim::E, g, RoleCtx::EdgeRows, &mut instrs);
                    }
                }
                // Spill edge values needed by later groups.
                if self.store_e.contains(&n) {
                    instrs.push(Instr::St {
                        sym: self.e_sym[&n],
                        data: DataRef::Node(n),
                        rows: Dim::E,
                        cols: node.cols,
                    });
                }
                group.gather = instrs;
            }

            // ApplyPhase: vertex computes homed here, then stores.
            for n in 0..self.ir.nodes.len() {
                if self.home(n) != Some(g) || matches!(self.node(n).op, IrOp::Output) {
                    continue;
                }
                let node = self.node(n).clone();
                let mut instrs = std::mem::take(&mut group.apply);
                // Materialise vertex operands not yet resident.
                for &i in &node.inputs {
                    if self.node(i).loc == Loc::Vertex {
                        self.mat_d(i, g, &mut instrs);
                    }
                }
                let dst = self.d_sym_for(n);
                self.emit_compute(n, dst, Dim::V, g, RoleCtx::DstRows, &mut instrs);
                self.d_resident.insert(n);
                group.apply = instrs;
            }
            // The final result may be a depth-0 chain (GTR-free models):
            // materialise it on interval rows in the last group so the
            // store below has something to write.
            if g + 1 == self.num_groups {
                let result = self.node(self.ir.output.unwrap()).inputs[0];
                if self.produced_group(result).is_none() {
                    let mut instrs = std::mem::take(&mut group.apply);
                    self.mat_d(result, g, &mut instrs);
                    instrs.push(Instr::St {
                        sym: self.d_sym[&result],
                        data: DataRef::Node(result),
                        rows: Dim::V,
                        cols: self.node(result).cols,
                    });
                    group.apply = instrs;
                }
            }
            // Stores: every value produced in this group that later groups
            // (or the host) read back.
            for n in 0..self.ir.nodes.len() {
                if self.produced_group(n) == Some(g as i64) && self.store_d.contains(&n) {
                    let sym = self.d_sym[&n];
                    group.apply.push(Instr::St {
                        sym,
                        data: DataRef::Node(n),
                        rows: Dim::V,
                        cols: self.node(n).cols,
                    });
                }
            }
            self.groups.push(group);
        }
    }

    fn finish(mut self) -> Program {
        let groups = std::mem::take(&mut self.groups);
        let groups = if self.opts.fuse_gathers {
            fuse_gathers(groups)
        } else {
            groups
        };
        let (groups, symbols) = super::liveness::merge_symbols(groups, &self.symbols);
        let out_node = self.ir.output.expect("validated IR has output");
        let result_node = self.node(out_node).inputs[0];

        // Partitioning parameters (§V-C3): per-group resident widths.
        let mut dim_src = 0u32;
        let mut dim_edge = 0u32;
        let mut dim_dst = 0u32;
        for g in &groups {
            let mut s_syms: HashMap<Sym, u32> = HashMap::new();
            let mut e_syms: HashMap<Sym, u32> = HashMap::new();
            let mut d_syms: HashMap<Sym, u32> = HashMap::new();
            for i in g.all_instrs() {
                for sym in i.def().into_iter().chain(i.uses()) {
                    let cols = symbols.cols(sym);
                    match sym.space {
                        Space::S => {
                            s_syms.insert(sym, cols);
                        }
                        Space::E => {
                            e_syms.insert(sym, cols);
                        }
                        Space::D => {
                            d_syms.insert(sym, cols);
                        }
                        Space::W => {}
                    }
                }
            }
            dim_src = dim_src.max(s_syms.values().sum());
            dim_edge = dim_edge.max(e_syms.values().sum());
            dim_dst = dim_dst.max(d_syms.values().sum());
        }

        let in_dim = self
            .ir
            .nodes
            .iter()
            .find(|n| matches!(n.op, IrOp::Input))
            .map(|n| n.cols)
            .unwrap_or(0);

        Program {
            model_name: self.ir.name.clone(),
            has_prologue: !self.prologue.is_empty(),
            groups,
            symbols,
            weights: std::mem::take(&mut self.weights),
            dim_src,
            dim_edge,
            dim_dst,
            in_dim,
            out_dim: self.node(result_node).cols,
        }
    }
}

/// Row-role under which a compute chain is being rematerialised.
#[derive(Clone, Copy, PartialEq, Eq)]
enum RoleCtx {
    SrcRows,
    DstRows,
    EdgeRows,
}

/// The PLOF peephole (§IV-B at instruction granularity): fuse
///
/// * `SCTR.F  %E0, %Sx` + `GTHR %D, %E0`                    → `GSCTR %D, %Sx`
/// * `SCTR.F  %E1, %Sx` + `RSCALE %E0, %E1, %Es` + `GTHR %D, %E0`
///                                                          → `GSCTR %D, %Sx, %Es`
///
/// when the intermediate edge symbols have no other readers and are never
/// spilled. This removes the `num_edge × dim_edge` term of Equ. 1 for the
/// dominant aggregation pattern: the hardware's VU cores stream source
/// rows through the crossbar straight into the destination accumulator
/// instead of materialising `[E, cols]` messages in the SrcEdgeBuffer.
fn fuse_gathers(groups: Vec<PhaseGroup>) -> Vec<PhaseGroup> {
    use std::collections::HashMap as Map;
    // Count uses of every E symbol across the whole program (spills and
    // cross-group loads keep symbols alive).
    let mut e_reads: Map<Sym, usize> = Map::new();
    let mut e_spilled: std::collections::HashSet<Sym> = Default::default();
    for g in &groups {
        for i in g.all_instrs() {
            for u in i.uses() {
                if u.space == Space::E {
                    *e_reads.entry(u).or_insert(0) += 1;
                }
            }
            if let Instr::St { sym, .. } = i {
                if sym.space == Space::E {
                    e_spilled.insert(*sym);
                }
            }
            if let Instr::Ld { sym, .. } = i {
                if sym.space == Space::E {
                    // Reloaded symbols alias DRAM state; don't fuse through.
                    e_spilled.insert(*sym);
                }
            }
        }
    }

    groups
        .into_iter()
        .map(|mut g| {
            let instrs = std::mem::take(&mut g.gather);
            let mut out: Vec<Instr> = Vec::with_capacity(instrs.len());
            for i in instrs {
                if let Instr::Gather {
                    reduce,
                    dst,
                    src,
                    cols,
                } = i
                {
                    // Pattern 2: ... SCTR.F e1,sx ; RSCALE src,e1,es ; GTHR dst,src
                    if out.len() >= 2 && e_reads.get(&src) == Some(&1) && !e_spilled.contains(&src)
                    {
                        let n = out.len();
                        if let (
                            Instr::Scatter {
                                dir: ScatterDir::SrcToEdge,
                                dst: e1,
                                src: sx,
                                ..
                            },
                            Instr::RowScale {
                                dst: rs_dst,
                                a: rs_a,
                                scale,
                                ..
                            },
                        ) = (out[n - 2].clone(), out[n - 1].clone())
                        {
                            if rs_dst == src
                                && rs_a == e1
                                && e_reads.get(&e1) == Some(&1)
                                && !e_spilled.contains(&e1)
                                && sx.space == Space::S
                                && scale.space == Space::E
                            {
                                out.truncate(n - 2);
                                out.push(Instr::FusedGather {
                                    reduce,
                                    dst,
                                    src: sx,
                                    scale: Some(scale),
                                    cols,
                                });
                                continue;
                            }
                        }
                    }
                    // Pattern 1: ... SCTR.F src,sx ; GTHR dst,src
                    if let Some(Instr::Scatter {
                        dir: ScatterDir::SrcToEdge,
                        dst: e0,
                        src: sx,
                        ..
                    }) = out.last().cloned()
                    {
                        if e0 == src
                            && e_reads.get(&e0) == Some(&1)
                            && !e_spilled.contains(&e0)
                            && sx.space == Space::S
                        {
                            out.pop();
                            out.push(Instr::FusedGather {
                                reduce,
                                dst,
                                src: sx,
                                scale: None,
                                cols,
                            });
                            continue;
                        }
                    }
                    out.push(Instr::Gather {
                        reduce,
                        dst,
                        src,
                        cols,
                    });
                } else {
                    out.push(i);
                }
            }
            g.gather = out;
            g
        })
        .collect()
}
