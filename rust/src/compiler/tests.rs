//! Compiler unit tests: structural properties of generated programs.

use crate::compiler::compile;
use crate::ir::models::Model;
use crate::ir::IrGraph;
use crate::isa::{Dim, Instr, Reduce, Space};

fn all_programs() -> Vec<crate::isa::Program> {
    Model::ALL.iter().map(|m| compile(&m.build(2, 16, 16, 16))).collect()
}

#[test]
fn group_counts_match_ir() {
    for m in Model::ALL {
        let ir = m.build(2, 16, 16, 16);
        let p = compile(&ir);
        let expect = ir.num_groups() + u32::from(p.has_prologue);
        assert_eq!(
            p.groups.len() as u32,
            expect,
            "{}: group count",
            m.name()
        );
    }
}

#[test]
fn gathers_only_in_gather_phase() {
    let is_gather =
        |i: &Instr| matches!(i, Instr::Gather { .. } | Instr::FusedGather { .. });
    for p in all_programs() {
        for (gi, g) in p.groups.iter().enumerate() {
            assert!(!g.scatter.iter().any(is_gather));
            assert!(!g.apply.iter().any(is_gather));
            if gi == 0 && p.has_prologue {
                assert!(g.gather.is_empty(), "{}: prologue has no gather", p.model_name);
                continue;
            }
            assert!(
                g.gather.iter().any(is_gather),
                "{}: every group ends in a gather",
                p.model_name
            );
        }
    }
}

#[test]
fn phase_space_discipline() {
    // ScatterPhase and ApplyPhase are iThread interval work: they may only
    // touch D and W spaces. GatherPhase may touch everything but performs
    // no V-row compute.
    for p in all_programs() {
        for g in &p.groups {
            for i in g.scatter.iter().chain(g.apply.iter()) {
                for s in i.def().into_iter().chain(i.uses()) {
                    assert!(
                        matches!(s.space, Space::D | Space::W),
                        "{}: iThread instr touches {}: {}",
                        p.model_name,
                        s,
                        i.render()
                    );
                }
            }
            for i in &g.gather {
                if let Instr::Ld { sym, .. } = i {
                    assert_ne!(sym.space, Space::D, "GatherPhase must not LD.D");
                }
            }
        }
    }
}

#[test]
fn defs_precede_uses_statically() {
    for p in all_programs() {
        let mut defined: std::collections::HashSet<_> = p
            .weights
            .iter()
            .map(|w| w.sym)
            .collect();
        for g in &p.groups {
            for i in g
                .scatter
                .iter()
                .chain(g.gather.iter())
                .chain(g.apply.iter())
            {
                for u in i.uses() {
                    // Gathers read their own accumulator (init by
                    // hardware); skip the self-use.
                    if let Instr::Gather { dst, .. } | Instr::FusedGather { dst, .. } = i {
                        if u == *dst {
                            continue;
                        }
                    }
                    assert!(
                        defined.contains(&u),
                        "{}: use of undefined {} in {}",
                        p.model_name,
                        u,
                        i.render()
                    );
                }
                if let Some(d) = i.def() {
                    defined.insert(d);
                }
            }
        }
    }
}

#[test]
fn loads_and_stores_pair_up() {
    // Every LD of a Node(i) DataRef must be preceded (in group order) by a
    // ST of the same DataRef.
    use crate::isa::DataRef;
    for p in all_programs() {
        let mut stored: std::collections::HashSet<DataRef> = Default::default();
        for g in &p.groups {
            // Loads of this group may rely on stores from *earlier* groups
            // only (gather/scatter phases) — except ApplyPhase LD.D of a
            // value stored in this same group is impossible by
            // construction (it would still be resident).
            for i in g
                .scatter
                .iter()
                .chain(g.gather.iter())
                .chain(g.apply.iter())
            {
                if let Instr::Ld { data, .. } = i {
                    if let DataRef::Node(_) = data {
                        assert!(
                            stored.contains(data),
                            "{}: LD of never-stored {data}",
                            p.model_name
                        );
                    }
                }
            }
            for i in g
                .scatter
                .iter()
                .chain(g.gather.iter())
                .chain(g.apply.iter())
            {
                if let Instr::St { data, .. } = i {
                    stored.insert(*data);
                }
            }
        }
    }
}

#[test]
fn dims_exported_for_partitioner() {
    for p in all_programs() {
        assert!(p.dim_src > 0, "{}: dim_src", p.model_name);
        assert!(p.dim_dst > 0, "{}: dim_dst", p.model_name);
        // Every model scatters ≥16-wide messages plus degree-or-score data.
        assert!(p.dim_src >= 16);
    }
}

#[test]
fn gcn_structure() {
    let p = compile(&Model::Gcn.build(2, 16, 16, 16));
    assert_eq!(p.groups.len(), 2);
    // GCN GatherPhase: LD.S input, LD.S degree, rsqrt, rowscale, scatter,
    // gather(sum).
    let g0 = &p.groups[0];
    assert!(g0.scatter.is_empty(), "GCN has no ScatterDst");
    let has = |k: fn(&Instr) -> bool| g0.gather.iter().any(k);
    assert!(has(|i| matches!(i, Instr::Ld { .. })));
    assert!(has(|i| matches!(i, Instr::RowScale { .. })));
    // The scatter+gather pair fuses into GSCTR (PLOF peephole): no edge
    // data is materialised for GCN at all.
    assert!(has(|i| matches!(
        i,
        Instr::FusedGather {
            reduce: Reduce::Sum,
            ..
        }
    )));
    assert_eq!(p.dim_edge, 0, "GCN needs no SEB edge storage");
    // ApplyPhase: DMM on V rows + final store.
    assert!(g0
        .apply
        .iter()
        .any(|i| matches!(i, Instr::Dmm { rows: Dim::V, .. })));
    assert!(p.groups[1]
        .apply
        .iter()
        .any(|i| matches!(i, Instr::St { .. })));
}

#[test]
fn gat_spills_edge_scores_across_groups() {
    let p = compile(&Model::Gat.build(1, 8, 8, 8));
    assert!(p.has_prologue, "GAT precomputes hw/el/er");
    assert_eq!(p.groups.len(), 3);
    let g0 = 1; // prologue shifts group indices
    let st_e = p.groups[g0]
        .gather
        .iter()
        .any(|i| matches!(i, Instr::St { sym, .. } if sym.space == Space::E));
    let ld_e = p.groups[g0 + 1]
        .gather
        .iter()
        .any(|i| matches!(i, Instr::Ld { sym, .. } if sym.space == Space::E));
    assert!(st_e, "group 0 must ST.E the edge scores");
    assert!(ld_e, "group 1 must LD.E the edge scores");
    // Group 1 has the DstToEdge scatter of the max (softmax centring).
    assert!(p.groups[g0 + 1].gather.iter().any(|i| matches!(
        i,
        Instr::Scatter {
            dir: crate::isa::ScatterDir::DstToEdge,
            ..
        }
    )));
    // And its ScatterPhase loads the stored max back.
    assert!(!p.groups[g0 + 1].scatter.is_empty());
}

#[test]
fn sage_prologue_and_fused_max() {
    let p = compile(&Model::Sage.build(1, 8, 8, 8));
    // The pool projection is precomputed once per vertex in the prologue
    // (MU-efficient V-row GEMM), not per shard.
    assert!(p.has_prologue);
    assert!(p.groups[0]
        .scatter
        .iter()
        .any(|i| matches!(i, Instr::Dmm { rows: Dim::V, .. })));
    assert!(p.groups[1]
        .apply
        .iter()
        .any(|i| matches!(i, Instr::Concat { .. })));
    // Max-reduce gather (fused with its scatter).
    assert!(p.groups[1].gather.iter().any(|i| matches!(
        i,
        Instr::FusedGather {
            reduce: Reduce::Max,
            ..
        }
    )));
}

#[test]
fn ggnn_apply_has_gru() {
    let p = compile(&Model::Ggnn.build(1, 8, 8, 8));
    // The GRU's h-side projections (U_z h, U_r h) are depth-0 and move to
    // the prologue; the a-side ones stay in the ApplyPhase. Together the
    // layer still runs 7 matmuls.
    let apply_dmms = p
        .groups
        .last()
        .unwrap()
        .apply
        .iter()
        .filter(|i| matches!(i, Instr::Dmm { .. }))
        .count();
    let pro_dmms = p.groups[0]
        .scatter
        .iter()
        .filter(|i| matches!(i, Instr::Dmm { .. }))
        .count();
    assert!(apply_dmms >= 4, "a-side matmuls in apply, got {apply_dmms}");
    assert_eq!(apply_dmms + pro_dmms, 7, "GRU + projection = 7 matmuls");
}

#[test]
fn liveness_merging_reduces_symbols() {
    // A 2-layer model reuses layer-1 symbols for layer-2 if merging works:
    // total distinct S symbols should be well under the naive count.
    let ir = Model::Gat.build(2, 16, 16, 16);
    let p = compile(&ir);
    // Naive: each (group, node) S materialisation is distinct; merged
    // programs reuse slots across groups.
    let s_count = p.symbols.count(Space::S);
    assert!(
        s_count <= 4,
        "expected few merged S symbols, got {s_count}"
    );
}

#[test]
fn weight_seeds_unique() {
    for p in all_programs() {
        let mut seen = std::collections::HashSet::new();
        for w in &p.weights {
            assert!(seen.insert(w.seed), "duplicate weight seed {}", w.seed);
        }
    }
}

#[test]
fn disassembly_roundtrips_phases() {
    let p = compile(&Model::Gcn.build_paper());
    let d = p.disassemble();
    assert!(d.contains("GSCTR.SUM"));
    assert!(d.contains("LD.S"));
    assert!(d.contains("ST.D"));
}

#[test]
fn ablation_options_preserve_numerics() {
    use crate::compiler::{compile_with, CompilerOptions};
    use crate::exec::{reference, weights, Executor, Matrix};
    use crate::graph::generators;
    use crate::partition::{partition_fggp, PartitionConfig};

    let g = crate::graph::Csr::from_edge_list(&generators::rmat(
        1 << 7,
        700,
        0.57,
        0.19,
        0.19,
        21,
    ));
    let x = weights::init_features(5, g.num_vertices(), 8);
    let mut deg = Matrix::zeros(g.num_vertices(), 1);
    for v in 0..g.num_vertices() {
        deg.set(v, 0, g.in_degree(v as u32) as f32);
    }
    for m in Model::ALL {
        let ir = m.build(2, 8, 8, 8);
        let want = reference::evaluate(&ir, &g, &x);
        for fuse in [true, false] {
            for pro in [true, false] {
                let prog = compile_with(
                    &ir,
                    CompilerOptions {
                        fuse_gathers: fuse,
                        prologue: pro,
                    },
                );
                let cfg = PartitionConfig {
                    shard_bytes: 8 * 1024,
                    dst_bytes: 16 * 1024,
                    dim_src: prog.dim_src.max(1),
                    dim_edge: prog.dim_edge.max(1),
                    dim_dst: prog.dim_dst.max(1),
                    num_sthreads: 2,
                };
                let parts = partition_fggp(&g, cfg);
                let got = Executor::new(&prog, &parts).run(&x, &deg);
                assert!(
                    got.allclose(&want, 1e-4, 1e-5),
                    "{} fuse={fuse} prologue={pro}: {}",
                    m.name(),
                    got.max_abs_diff(&want)
                );
            }
        }
    }
}

#[test]
fn fusion_off_restores_edge_materialisation() {
    use crate::compiler::{compile_with, CompilerOptions};
    let ir = Model::Gcn.build(2, 16, 16, 16);
    let fused = compile_with(&ir, CompilerOptions::default());
    let unfused = compile_with(
        &ir,
        CompilerOptions {
            fuse_gathers: false,
            prologue: true,
        },
    );
    assert_eq!(fused.dim_edge, 0);
    assert!(unfused.dim_edge >= 16, "unfused GCN materialises messages");
}

#[test]
fn no_gtr_model_compiles_to_pure_apply() {
    // An MLP (no graph ops) must compile to a single group with empty
    // scatter/gather phases.
    let mut ir = IrGraph::new("mlp");
    let x = ir.input(8);
    let w = ir.weight(8, 8, 1, "w");
    let z = ir.dmm(x, w, "z");
    let r = ir.unary(crate::isa::ElwOp::Relu, z, "r");
    ir.set_output(r);
    let p = compile(&ir);
    // dmm(x, w) is a depth-0 projection → prologue + one (empty-gather)
    // group that loads and finishes the result.
    assert!(p.has_prologue);
    assert_eq!(p.groups.len(), 2);
    assert!(p.groups.iter().all(|g| g.gather.is_empty()));
    assert_eq!(p.dim_src, 0);
    assert_eq!(p.dim_edge, 0);
}
