//! PJRT runtime: load AOT-compiled HLO text produced by
//! `python/compile/aot.py` and execute it on the CPU PJRT client — the
//! only place Rust touches XLA; Python never runs on the request path.
//!
//! The real client lives in [`pjrt`] behind the off-by-default `pjrt`
//! cargo feature, because its `xla` and `anyhow` dependencies are not
//! resolvable in the offline image. Without the feature, [`stub`]
//! provides the same API surface with constructors that fail fast and a
//! clear remediation message, so every binary/example still builds.
//! Artifact naming and discovery are feature-independent and live here.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{GnnExecutable, Runtime, Trainer};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{GnnExecutable, PjrtUnavailable, Runtime, Trainer};

use std::path::PathBuf;

/// Shape signature of the model artifacts (mirrors aot.py defaults).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArtifactShape {
    pub n: usize,
    pub e: usize,
    pub d: usize,
}

impl Default for ArtifactShape {
    fn default() -> Self {
        ArtifactShape { n: 64, e: 256, d: 16 }
    }
}

impl ArtifactShape {
    /// Artifact file name for a model, e.g. `gcn_n64_e256_d16.hlo.txt`.
    pub fn file_name(&self, model: &str) -> String {
        format!("{}_n{}_e{}_d{}.hlo.txt", model, self.n, self.e, self.d)
    }
}

/// Locate the artifacts directory: `$SWITCHBLADE_ARTIFACTS`, else
/// `./artifacts` under the workspace root.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("SWITCHBLADE_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut d = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    d.push("artifacts");
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_file_names() {
        let s = ArtifactShape::default();
        assert_eq!(s.file_name("gcn"), "gcn_n64_e256_d16.hlo.txt");
    }

    // PJRT-dependent tests live in rust/tests/integration_runtime.rs
    // (feature-gated) so the unit suite stays hermetic.
}
