//! The real PJRT runtime (compiled only with `--features pjrt`): load AOT
//! HLO text produced by `python/compile/aot.py` and execute it on the CPU
//! PJRT client via the `xla` crate. This is the only place Rust touches
//! XLA; Python never runs on the request path.
//!
//! Interchange is HLO **text** (see aot.py): jax ≥ 0.5 emits protos with
//! 64-bit ids that xla_extension 0.5.1 rejects; the text parser reassigns
//! ids.
//!
//! Enabling the `pjrt` feature requires the `xla` and `anyhow` crates to
//! be resolvable (they are not vendored in the offline image) — add them
//! to `[dependencies]` in `rust/Cargo.toml` when building online.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::ArtifactShape;
use crate::exec::Matrix;

/// A compiled GNN executable on the PJRT CPU client.
pub struct GnnExecutable {
    exe: xla::PjRtLoadedExecutable,
    pub shape: ArtifactShape,
    pub model: String,
    pub path: PathBuf,
}

/// The PJRT runtime: one client, many loaded executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    /// Load a GNN model artifact produced by `make artifacts`.
    pub fn load_model(
        &self,
        artifacts_dir: &Path,
        model: &str,
        shape: ArtifactShape,
    ) -> Result<GnnExecutable> {
        let path = artifacts_dir.join(shape.file_name(model));
        let exe = self.load_hlo(&path)?;
        Ok(GnnExecutable {
            exe,
            shape,
            model: model.to_string(),
            path,
        })
    }
}

impl GnnExecutable {
    /// Execute on `(x [N,D], src [E], dst [E], deg [N,1])`; returns the
    /// `[N, D]` output embeddings.
    ///
    /// Weights are runtime inputs (not HLO constants — the HLO text writer
    /// elides large literals, see aot.py); they are regenerated from the
    /// shared deterministic init in the same order as the compiler's
    /// `Program::weights` / Python's `build_params`.
    pub fn run(&self, x: &Matrix, src: &[i32], dst: &[i32], deg: &[f32]) -> Result<Matrix> {
        let s = &self.shape;
        anyhow::ensure!(x.rows == s.n && x.cols == s.d, "x shape {}x{}", x.rows, x.cols);
        anyhow::ensure!(src.len() == s.e && dst.len() == s.e, "edge count");
        anyhow::ensure!(deg.len() == s.n, "degree length");

        let xl = xla::Literal::vec1(&x.data).reshape(&[s.n as i64, s.d as i64])?;
        let sl = xla::Literal::vec1(src).reshape(&[s.e as i64])?;
        let dl = xla::Literal::vec1(dst).reshape(&[s.e as i64])?;
        let gl = xla::Literal::vec1(deg).reshape(&[s.n as i64, 1])?;

        let mut args = vec![xl, sl, dl, gl];
        for w in self.model_weights()? {
            let lit =
                xla::Literal::vec1(&w.data).reshape(&[w.rows as i64, w.cols as i64])?;
            args.push(lit);
        }

        let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        Ok(Matrix::from_vec(s.n, s.d, values))
    }

    /// The model's weight matrices, regenerated deterministically in the
    /// same order the compiler allocates them (IR builder order).
    fn model_weights(&self) -> Result<Vec<Matrix>> {
        init_model_weights(&self.model, self.shape)
    }
}

/// Regenerate a model's weight/bias matrices from the shared deterministic
/// init, in IR builder order — the single source of the ordering contract
/// between inference (`GnnExecutable`), training (`Trainer`) and the
/// compiler's `Program::weights` / Python's `build_params`.
fn init_model_weights(model: &str, shape: ArtifactShape) -> Result<Vec<Matrix>> {
    let m = crate::ir::models::Model::parse(model)
        .with_context(|| format!("unknown model {model}"))?;
    let d = shape.d as u32;
    let ir = m.build(2, d, d, d);
    let mut out = Vec::new();
    for node in &ir.nodes {
        match node.op {
            crate::ir::IrOp::Weight { rows, seed } => {
                out.push(crate::exec::weights::init_weight(seed, rows, node.cols));
            }
            crate::ir::IrOp::Bias { seed } => {
                out.push(crate::exec::weights::init_weight(seed, 1, node.cols));
            }
            _ => {}
        }
    }
    Ok(out)
}

/// A training-step executable: one PJRT call returns `[loss, ∂W...]`,
/// and the Rust side owns the SGD loop — full training with Python only
/// at compile time.
pub struct Trainer {
    exe: xla::PjRtLoadedExecutable,
    pub shape: ArtifactShape,
    /// Current weights, in `build_params` order.
    pub weights: Vec<Matrix>,
    pub lr: f32,
}

impl Runtime {
    /// Load the `<model>_train_*` artifact and initialise weights from
    /// the shared deterministic scheme.
    pub fn load_trainer(
        &self,
        artifacts_dir: &Path,
        model: &str,
        shape: ArtifactShape,
        lr: f32,
    ) -> Result<Trainer> {
        let path = artifacts_dir.join(format!(
            "{}_train_n{}_e{}_d{}.hlo.txt",
            model, shape.n, shape.e, shape.d
        ));
        let exe = self.load_hlo(&path)?;
        let weights = init_model_weights(model, shape)?;
        Ok(Trainer {
            exe,
            shape,
            weights,
            lr,
        })
    }
}

impl Trainer {
    /// One SGD step on `(x, src, dst, deg, target)`; returns the loss.
    pub fn step(
        &mut self,
        x: &Matrix,
        src: &[i32],
        dst: &[i32],
        deg: &[f32],
        target: &Matrix,
    ) -> Result<f32> {
        let s = &self.shape;
        let xl = xla::Literal::vec1(&x.data).reshape(&[s.n as i64, s.d as i64])?;
        let sl = xla::Literal::vec1(src).reshape(&[s.e as i64])?;
        let dl = xla::Literal::vec1(dst).reshape(&[s.e as i64])?;
        let gl = xla::Literal::vec1(deg).reshape(&[s.n as i64, 1])?;
        let tl = xla::Literal::vec1(&target.data).reshape(&[s.n as i64, s.d as i64])?;
        let mut args = vec![xl, sl, dl, gl, tl];
        for w in &self.weights {
            args.push(
                xla::Literal::vec1(&w.data).reshape(&[w.rows as i64, w.cols as i64])?,
            );
        }
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let packed = result.to_tuple1()?.to_vec::<f32>()?;
        let loss = packed[0];
        // Unpack gradients in weight order and apply SGD.
        let mut off = 1usize;
        for w in &mut self.weights {
            let len = w.rows * w.cols;
            for (wi, gi) in w.data.iter_mut().zip(&packed[off..off + len]) {
                *wi -= self.lr * gi;
            }
            off += len;
        }
        anyhow::ensure!(off == packed.len(), "gradient size mismatch");
        Ok(loss)
    }
}
