//! API-compatible stand-in for the PJRT runtime, compiled when the `pjrt`
//! feature is off (the default — the offline image has neither the `xla`
//! nor the `anyhow` crate). Every entry point fails fast with
//! [`PjrtUnavailable`] so binaries, examples and the serving demo still
//! build and degrade with a clear message instead of a link error.

use std::fmt;
use std::path::{Path, PathBuf};

use super::ArtifactShape;
use crate::exec::Matrix;

/// Error returned by every stub entry point.
#[derive(Clone, Copy, Debug)]
pub struct PjrtUnavailable;

impl fmt::Display for PjrtUnavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "PJRT runtime not compiled in (rebuild with `--features pjrt` \
             and the `xla`/`anyhow` crates available)"
        )
    }
}

impl std::error::Error for PjrtUnavailable {}

pub type Result<T> = std::result::Result<T, PjrtUnavailable>;

/// Stub PJRT runtime — construction always fails.
pub struct Runtime {}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Err(PjrtUnavailable)
    }

    pub fn platform(&self) -> String {
        "pjrt-unavailable".into()
    }

    pub fn load_model(
        &self,
        _artifacts_dir: &Path,
        _model: &str,
        _shape: ArtifactShape,
    ) -> Result<GnnExecutable> {
        Err(PjrtUnavailable)
    }

    pub fn load_trainer(
        &self,
        _artifacts_dir: &Path,
        _model: &str,
        _shape: ArtifactShape,
        _lr: f32,
    ) -> Result<Trainer> {
        Err(PjrtUnavailable)
    }
}

/// Stub executable — never constructed (loading always fails).
pub struct GnnExecutable {
    pub shape: ArtifactShape,
    pub model: String,
    pub path: PathBuf,
}

impl GnnExecutable {
    pub fn run(&self, _x: &Matrix, _src: &[i32], _dst: &[i32], _deg: &[f32]) -> Result<Matrix> {
        Err(PjrtUnavailable)
    }
}

/// Stub trainer — never constructed (loading always fails).
pub struct Trainer {
    pub shape: ArtifactShape,
    pub weights: Vec<Matrix>,
    pub lr: f32,
}

impl Trainer {
    pub fn step(
        &mut self,
        _x: &Matrix,
        _src: &[i32],
        _dst: &[i32],
        _deg: &[f32],
        _target: &Matrix,
    ) -> Result<f32> {
        Err(PjrtUnavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_clear_message() {
        let e = Runtime::cpu().err().expect("stub must not construct");
        assert!(e.to_string().contains("--features pjrt"));
    }
}
