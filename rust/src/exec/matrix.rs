//! Minimal row-major f32 matrix used by the functional paths.

/// Row-major `rows × cols` f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn filled(rows: usize, cols: usize, v: f32) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![v; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// `self × other` via the cache-blocked kernel layer
    /// ([`kernels::matmul_blocked`](crate::exec::kernels::matmul_blocked));
    /// the pre-kernel loop survives as
    /// [`kernels::matmul_naive`](crate::exec::kernels::matmul_naive), the
    /// bit-identity reference of the differential tests.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, other.cols);
        crate::exec::kernels::matmul_blocked(self, other, &mut out);
        out
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Bitwise equality: same shape and every element's f32 bit pattern
    /// identical — the differential tests' notion of "identical output".
    pub fn bits_eq(&self, other: &Matrix) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }

    /// Relative-tolerance comparison mirroring `np.allclose`.
    pub fn allclose(&self, other: &Matrix, rtol: f32, atol: f32) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let i = Matrix::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Matrix::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn allclose_tolerances() {
        let a = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Matrix::from_vec(1, 2, vec![1.0 + 1e-6, 2.0 - 1e-6]);
        assert!(a.allclose(&b, 1e-4, 1e-5));
        let c = Matrix::from_vec(1, 2, vec![1.1, 2.0]);
        assert!(!a.allclose(&c, 1e-4, 1e-5));
    }
}
