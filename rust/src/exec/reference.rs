//! Direct IR interpreter over the whole (unpartitioned) graph — the
//! in-Rust numerics oracle. Shares only the weight initialiser with the
//! compiled path.

use crate::exec::{weights, Matrix};
use crate::graph::Csr;
use crate::ir::{IrGraph, IrOp, Loc};
use crate::isa::{ElwOp, Reduce};

/// Evaluate `ir` over `g` with input features `x` (`[N, in_dim]`).
/// Returns the per-vertex output matrix.
pub fn evaluate(ir: &IrGraph, g: &Csr, x: &Matrix) -> Matrix {
    assert_eq!(x.rows, g.num_vertices());
    let n = g.num_vertices();
    let m = g.num_edges();
    let mut values: Vec<Option<Matrix>> = vec![None; ir.nodes.len()];

    // Canonical edge endpoints, indexed by edge id.
    let mut esrc = vec![0u32; m];
    let mut edst = vec![0u32; m];
    for (s, d, id) in g.edges_canonical() {
        esrc[id as usize] = s;
        edst[id as usize] = d;
    }

    for node in &ir.nodes {
        let rows = match node.loc {
            Loc::Vertex => n,
            Loc::Edge => m,
            Loc::Param => 0,
        };
        let val = match &node.op {
            IrOp::Input => x.clone(),
            IrOp::Degree => {
                let mut d = Matrix::zeros(n, 1);
                for v in 0..n as u32 {
                    d.set(v as usize, 0, g.in_degree(v) as f32);
                }
                d
            }
            IrOp::Weight { rows, seed } => weights::init_weight(*seed, *rows, node.cols),
            IrOp::Bias { seed } => weights::init_weight(*seed, 1, node.cols),
            IrOp::Dmm => {
                let a = values[node.inputs[0]].as_ref().unwrap();
                let w = values[node.inputs[1]].as_ref().unwrap();
                a.matmul(w)
            }
            IrOp::Unary(op) => {
                let a = values[node.inputs[0]].as_ref().unwrap();
                let mut out = a.clone();
                for v in &mut out.data {
                    *v = apply_unary(*op, *v);
                }
                out
            }
            IrOp::Binary(op) => {
                let a = values[node.inputs[0]].as_ref().unwrap();
                let b = values[node.inputs[1]].as_ref().unwrap();
                let mut out = a.clone();
                if b.rows == 1 && a.rows != 1 {
                    // Bias broadcast.
                    for r in 0..out.rows {
                        for c in 0..out.cols {
                            let v = apply_binary(*op, a.get(r, c), b.get(0, c));
                            out.set(r, c, v);
                        }
                    }
                } else {
                    for i in 0..out.data.len() {
                        out.data[i] = apply_binary(*op, a.data[i], b.data[i]);
                    }
                }
                out
            }
            IrOp::RowScale => {
                let a = values[node.inputs[0]].as_ref().unwrap();
                let s = values[node.inputs[1]].as_ref().unwrap();
                let mut out = a.clone();
                for r in 0..out.rows {
                    let f = s.get(r, 0);
                    for v in out.row_mut(r) {
                        *v *= f;
                    }
                }
                out
            }
            IrOp::Concat => {
                let a = values[node.inputs[0]].as_ref().unwrap();
                let b = values[node.inputs[1]].as_ref().unwrap();
                let mut out = Matrix::zeros(rows, node.cols as usize);
                for r in 0..rows {
                    out.row_mut(r)[..a.cols].copy_from_slice(a.row(r));
                    out.row_mut(r)[a.cols..].copy_from_slice(b.row(r));
                }
                out
            }
            IrOp::ScatterSrc => {
                let v = values[node.inputs[0]].as_ref().unwrap();
                let mut out = Matrix::zeros(m, node.cols as usize);
                for e in 0..m {
                    out.row_mut(e).copy_from_slice(v.row(esrc[e] as usize));
                }
                out
            }
            IrOp::ScatterDst => {
                let v = values[node.inputs[0]].as_ref().unwrap();
                let mut out = Matrix::zeros(m, node.cols as usize);
                for e in 0..m {
                    out.row_mut(e).copy_from_slice(v.row(edst[e] as usize));
                }
                out
            }
            IrOp::Gather(reduce) => {
                let ev = values[node.inputs[0]].as_ref().unwrap();
                gather(*reduce, ev, &edst, n)
            }
            IrOp::Output => values[node.inputs[0]].as_ref().unwrap().clone(),
        };
        values[node.id] = Some(val);
    }

    values[ir.output.expect("output set")].take().unwrap()
}

/// Segment-reduce edge rows by destination. Vertices with no in-edges get
/// zero rows (the convention shared with the compiled path and the JAX
/// oracle).
pub fn gather(reduce: Reduce, edge_vals: &Matrix, edst: &[u32], n: usize) -> Matrix {
    let cols = edge_vals.cols;
    let mut out = match reduce {
        Reduce::Sum | Reduce::Mean => Matrix::zeros(n, cols),
        Reduce::Max => Matrix::filled(n, cols, f32::NEG_INFINITY),
    };
    let mut count = vec![0u32; n];
    for e in 0..edge_vals.rows {
        let d = edst[e] as usize;
        count[d] += 1;
        let row = edge_vals.row(e);
        let orow = out.row_mut(d);
        match reduce {
            Reduce::Sum | Reduce::Mean => {
                for (o, &v) in orow.iter_mut().zip(row) {
                    *o += v;
                }
            }
            Reduce::Max => {
                for (o, &v) in orow.iter_mut().zip(row) {
                    *o = o.max(v);
                }
            }
        }
    }
    for v in 0..n {
        if count[v] == 0 {
            out.row_mut(v).fill(0.0);
        } else if reduce == Reduce::Mean {
            let inv = 1.0 / count[v] as f32;
            for o in out.row_mut(v) {
                *o *= inv;
            }
        }
    }
    out
}

/// Unary op semantics — single source of truth shared with the executor.
pub fn apply_unary(op: ElwOp, v: f32) -> f32 {
    match op {
        ElwOp::Relu => v.max(0.0),
        ElwOp::LeakyRelu => {
            if v >= 0.0 {
                v
            } else {
                0.01 * v
            }
        }
        ElwOp::Exp => v.exp(),
        ElwOp::Sigmoid => 1.0 / (1.0 + (-v).exp()),
        ElwOp::Tanh => v.tanh(),
        ElwOp::Rsqrt => {
            // Degree-normalisation convention: rsqrt(0) := 1 so isolated
            // vertices pass features through unscaled (DGL's GCN adds
            // self-loops; we clamp instead and mirror it in the oracle).
            if v <= 0.0 {
                1.0
            } else {
                1.0 / v.sqrt()
            }
        }
        ElwOp::Recip => {
            if v == 0.0 {
                0.0
            } else {
                1.0 / v
            }
        }
        ElwOp::Copy => v,
        ElwOp::AddScalar(bits) => v + f32::from_bits(bits),
        ElwOp::MulScalar(bits) => v * f32::from_bits(bits),
        _ => panic!("binary op {op:?} used as unary"),
    }
}

/// Binary op semantics.
pub fn apply_binary(op: ElwOp, a: f32, b: f32) -> f32 {
    match op {
        ElwOp::Add => a + b,
        ElwOp::Sub => a - b,
        ElwOp::Mul => a * b,
        ElwOp::Div => a / b,
        ElwOp::Max => a.max(b),
        _ => panic!("unary op {op:?} used as binary"),
    }
}
