//! Deterministic weight initialisation shared by every numerics path.
//!
//! The formula is pure 64-bit integer mixing (splitmix64 finalizer), so
//! the Rust executor, the Rust IR reference, and the JAX oracle
//! (`python/compile/model.py::init_weight`) produce bit-identical f32
//! values with no dependence on libm.

use crate::exec::Matrix;

/// splitmix64 finalizer.
#[inline]
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Element `(i, j)` of the weight with the given seed: uniform in
/// `[-0.1, 0.1)`, computed as exact integer ops then one f64→f32 cast.
#[inline]
pub fn weight_elem(seed: u64, i: u64, j: u64, cols: u64) -> f32 {
    let h = mix(seed ^ mix(i * cols + j + 1));
    let unit = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64); // [0, 1)
    ((unit * 2.0 - 1.0) * 0.1) as f32
}

/// Materialise a `[rows, cols]` weight matrix.
pub fn init_weight(seed: u64, rows: u32, cols: u32) -> Matrix {
    let (r, c) = (rows as usize, cols as usize);
    let mut m = Matrix::zeros(r, c);
    for i in 0..r {
        for j in 0..c {
            m.set(i, j, weight_elem(seed, i as u64, j as u64, c as u64));
        }
    }
    m
}

/// Deterministic input features `[n, dim]` in `[-1, 1)` — shared with the
/// JAX oracle (`model.py::init_features`).
pub fn init_features(seed: u64, n: usize, dim: usize) -> Matrix {
    let mut m = Matrix::zeros(n, dim);
    for i in 0..n {
        for j in 0..dim {
            let h = mix(seed ^ mix((i * dim + j) as u64 ^ 0xFEED));
            let unit = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            m.set(i, j, (unit * 2.0 - 1.0) as f32);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = init_weight(1, 8, 8);
        let b = init_weight(1, 8, 8);
        let c = init_weight(2, 8, 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn range_bounded() {
        let w = init_weight(7, 32, 32);
        for &v in &w.data {
            assert!((-0.1..0.1).contains(&v));
        }
        let x = init_features(3, 16, 16);
        for &v in &x.data {
            assert!((-1.0..1.0).contains(&v));
        }
    }

    #[test]
    fn known_values_pinned() {
        // Pin a few elements so the Python mirror can assert the same
        // numbers (see python/tests/test_weights.py).
        let w = weight_elem(42, 0, 0, 16);
        let x = weight_elem(42, 3, 5, 16);
        // Values recorded from this implementation; they must never drift.
        assert!((w - (-0.0010140946)).abs() < 1e-7, "w00 = {w}");
        assert!((x - (0.04941747)).abs() < 1e-7, "w35 = {x}");
    }

    #[test]
    fn mean_near_zero() {
        let w = init_weight(9, 64, 64);
        let mean: f32 = w.data.iter().sum::<f32>() / w.data.len() as f32;
        assert!(mean.abs() < 0.01);
    }
}
