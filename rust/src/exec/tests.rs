//! The stack-level correctness property:
//! `Executor(compile(ir), partition(g)) == reference(ir, g)` for every
//! model, graph shape and partitioning method.

use crate::compiler::compile;
use crate::exec::{reference, weights, Executor, Matrix};
use crate::graph::{generators, Csr, EdgeList};
use crate::ir::models::Model;
use crate::partition::{partition_dsw, partition_fggp, PartitionConfig};

fn degree_col(g: &Csr) -> Matrix {
    let mut d = Matrix::zeros(g.num_vertices(), 1);
    for v in 0..g.num_vertices() {
        d.set(v, 0, g.in_degree(v as u32) as f32);
    }
    d
}

fn cfg_for(p: &crate::isa::Program, shard_bytes: u64, dst_bytes: u64) -> PartitionConfig {
    PartitionConfig {
        shard_bytes,
        dst_bytes,
        dim_src: p.dim_src.max(1),
        dim_edge: p.dim_edge.max(1),
        dim_dst: p.dim_dst.max(1),
        num_sthreads: 1,
    }
}

/// Run the full pipeline and compare against the IR oracle.
fn check(model: Model, g: &Csr, shard_bytes: u64, dst_bytes: u64, fggp: bool) {
    let ir = model.build(2, 8, 8, 8);
    let prog = compile(&ir);
    let cfg = cfg_for(&prog, shard_bytes, dst_bytes);
    let parts = if fggp {
        partition_fggp(g, cfg)
    } else {
        partition_dsw(g, cfg)
    };
    parts.validate().expect("partitions valid");

    let x = weights::init_features(7, g.num_vertices(), 8);
    let deg = degree_col(g);
    let got = Executor::new(&prog, &parts).run(&x, &deg);
    let want = reference::evaluate(&ir, g, &x);
    assert_eq!(got.rows, want.rows);
    assert_eq!(got.cols, want.cols);
    assert!(
        got.allclose(&want, 1e-4, 1e-5),
        "{} ({}) mismatch: max|Δ| = {}",
        model.name(),
        if fggp { "FGGP" } else { "DSW" },
        got.max_abs_diff(&want)
    );
}

fn small_graphs() -> Vec<Csr> {
    vec![
        Csr::from_edge_list(&generators::rmat(1 << 7, 600, 0.57, 0.19, 0.19, 11)),
        Csr::from_edge_list(&generators::mesh2d(8, 8, true)),
        Csr::from_edge_list(&generators::erdos_renyi(100, 400, 12)),
    ]
}

#[test]
fn gcn_matches_reference() {
    for g in small_graphs() {
        check(Model::Gcn, &g, 4 * 1024, 8 * 1024, true);
        check(Model::Gcn, &g, 4 * 1024, 8 * 1024, false);
    }
}

#[test]
fn gat_matches_reference() {
    for g in small_graphs() {
        check(Model::Gat, &g, 4 * 1024, 8 * 1024, true);
        check(Model::Gat, &g, 4 * 1024, 8 * 1024, false);
    }
}

#[test]
fn sage_matches_reference() {
    for g in small_graphs() {
        check(Model::Sage, &g, 4 * 1024, 8 * 1024, true);
        check(Model::Sage, &g, 4 * 1024, 8 * 1024, false);
    }
}

#[test]
fn ggnn_matches_reference() {
    for g in small_graphs() {
        check(Model::Ggnn, &g, 4 * 1024, 8 * 1024, true);
        check(Model::Ggnn, &g, 4 * 1024, 8 * 1024, false);
    }
}

#[test]
fn tiny_buffers_force_many_shards_and_still_match() {
    // Stress the shard/interval streaming with pathologically small
    // budgets (many intervals, hub splitting).
    let g = Csr::from_edge_list(&generators::rmat(1 << 7, 800, 0.57, 0.19, 0.19, 13));
    for model in Model::ALL {
        check(model, &g, 1024, 1024, true);
        check(model, &g, 1024, 1024, false);
    }
}

#[test]
fn isolated_vertices_get_zero_aggregates() {
    // A graph where some vertices have no in-edges at all.
    let mut el = EdgeList::new(32);
    for i in 0..16u32 {
        el.push(i, (i + 1) % 16); // ring over first half; second half isolated
    }
    let g = Csr::from_edge_list(&el);
    for model in Model::ALL {
        check(model, &g, 2 * 1024, 4 * 1024, true);
    }
}

#[test]
fn mean_aggregation_matches_reference() {
    // SAGE-mean exercises Reduce::Mean through the fused GSCTR path,
    // including the count-normalisation at interval boundaries.
    use crate::ir::models::sage_mean;
    for g in small_graphs() {
        let ir = sage_mean(2, 8, 8, 8);
        let prog = compile(&ir);
        let cfg = cfg_for(&prog, 4 * 1024, 8 * 1024);
        for parts in [partition_fggp(&g, cfg), partition_dsw(&g, cfg)] {
            let x = weights::init_features(7, g.num_vertices(), 8);
            let got = Executor::new(&prog, &parts).run(&x, &degree_col(&g));
            let want = reference::evaluate(&ir, &g, &x);
            assert!(
                got.allclose(&want, 1e-4, 1e-5),
                "sage_mean ({:?}): {}",
                parts.method,
                got.max_abs_diff(&want)
            );
        }
    }
}

#[test]
fn single_vertex_graph() {
    let mut el = EdgeList::new(1);
    el.push(0, 0); // self loop
    let g = Csr::from_edge_list(&el);
    check(Model::Gcn, &g, 1024, 1024, true);
}

#[test]
fn shard_parallel_bit_identical_to_single_worker() {
    // The differential property of the worker pool: for every model, a
    // forced single-worker run and a multi-worker run produce the same
    // bits, because partial gather accumulators merge in canonical shard
    // order regardless of how the workers raced.
    let g = Csr::from_edge_list(&generators::rmat(1 << 8, 3_000, 0.57, 0.19, 0.19, 17));
    for model in Model::ALL {
        let ir = model.build(2, 8, 8, 8);
        let prog = compile(&ir);
        // Small budgets force many shards per interval; 4 sThreads make
        // the pool genuinely concurrent.
        let mut cfg = cfg_for(&prog, 2 * 1024, 4 * 1024);
        cfg.num_sthreads = 4;
        let parts = partition_fggp(&g, cfg);
        let x = weights::init_features(7, g.num_vertices(), 8);
        let deg = degree_col(&g);
        let serial = Executor::new(&prog, &parts).with_workers(1).run(&x, &deg);
        let parallel = Executor::new(&prog, &parts).with_workers(4).run(&x, &deg);
        assert!(
            serial.bits_eq(&parallel),
            "{}: parallel run diverged bitwise",
            model.name()
        );
    }
}

#[test]
fn kernel_executor_bit_identical_to_naive_reference() {
    // The kernel layer (blocked branch-free DMM, slice-based ELW/RSCALE/
    // CAT, fused gather row kernels, scratch-arena buffers) must be
    // bit-identical to the preserved naive `compute_instr` reference —
    // on every zoo model, both partition methods, and both worker counts.
    use crate::exec::KernelMode;
    use crate::ir::spec::ModelDims;
    use crate::ir::zoo::ModelZoo;
    let g = Csr::from_edge_list(&generators::rmat(1 << 8, 3_000, 0.57, 0.19, 0.19, 23));
    let deg = degree_col(&g);
    for m in ModelZoo::builtin().entries() {
        let ir = m.build(ModelDims::uniform(2, 8)).unwrap();
        let prog = compile(&ir);
        // Small budgets force many shards per interval; 4 sThreads make
        // the pool genuinely concurrent.
        let mut cfg = cfg_for(&prog, 2 * 1024, 4 * 1024);
        cfg.num_sthreads = 4;
        let x = weights::init_features(7, g.num_vertices(), ir.input_dim() as usize);
        for parts in [partition_fggp(&g, cfg), partition_dsw(&g, cfg)] {
            let golden = Executor::new(&prog, &parts)
                .with_kernel_mode(KernelMode::Naive)
                .with_workers(1)
                .run(&x, &deg);
            for workers in [1usize, 4, 8] {
                let got = Executor::new(&prog, &parts)
                    .with_workers(workers)
                    .run(&x, &deg);
                assert!(
                    got.bits_eq(&golden),
                    "{} ({:?}, {workers} workers): kernel path diverged bitwise \
                     from the naive reference",
                    m.name(),
                    parts.method,
                );
            }
        }
    }
}

#[test]
fn simd_executor_bit_identical_to_naive_reference() {
    // The explicit-width differential property: KernelMode::Simd (chunks
    // of 8 with array-of-8 accumulators, in the DMM *and* the gather/
    // merge row kernels) must be bit-identical to the naive reference on
    // every zoo model, both partition methods, every pool width and every
    // pipeline mode — tails included, since dims of 8 across graph-sized
    // rows still leave non-multiple-of-8 shard windows everywhere.
    use crate::exec::{KernelMode, PipelineMode};
    use crate::ir::spec::ModelDims;
    use crate::ir::zoo::ModelZoo;
    let g = Csr::from_edge_list(&generators::rmat(1 << 8, 3_000, 0.57, 0.19, 0.19, 41));
    let deg = degree_col(&g);
    for m in ModelZoo::builtin().entries() {
        let ir = m.build(ModelDims::uniform(2, 8)).unwrap();
        let prog = compile(&ir);
        let mut cfg = cfg_for(&prog, 2 * 1024, 4 * 1024);
        cfg.num_sthreads = 4;
        let x = weights::init_features(7, g.num_vertices(), ir.input_dim() as usize);
        for parts in [partition_fggp(&g, cfg), partition_dsw(&g, cfg)] {
            let golden = Executor::new(&prog, &parts)
                .with_kernel_mode(KernelMode::Naive)
                .with_pipeline_mode(PipelineMode::Off)
                .with_workers(1)
                .run(&x, &deg);
            for workers in [1usize, 4, 8] {
                for pipeline in [
                    PipelineMode::Off,
                    PipelineMode::Interval,
                    PipelineMode::Group,
                ] {
                    let got = Executor::new(&prog, &parts)
                        .with_kernel_mode(KernelMode::Simd)
                        .with_pipeline_mode(pipeline)
                        .with_workers(workers)
                        .run(&x, &deg);
                    assert!(
                        got.bits_eq(&golden),
                        "{} ({:?}, {workers} workers, pipeline {}): SIMD path \
                         diverged bitwise from the naive reference",
                        m.name(),
                        parts.method,
                        pipeline.label(),
                    );
                }
            }
        }
    }
}

#[test]
fn pipelined_executor_bit_identical_to_sequential() {
    // The interval-pipelining differential property: with
    // PipelineMode::Interval the next interval's DstBuffer state is
    // prepared under the previous interval's gather drain, and the output
    // must still be bit-identical to the strictly sequential
    // PipelineMode::Off reference — on every zoo model, both partition
    // methods, and both worker counts (serial prepare and overlapped
    // prepare exercise different code paths).
    use crate::exec::PipelineMode;
    use crate::ir::spec::ModelDims;
    use crate::ir::zoo::ModelZoo;
    let g = Csr::from_edge_list(&generators::rmat(1 << 8, 3_000, 0.57, 0.19, 0.19, 31));
    let deg = degree_col(&g);
    for m in ModelZoo::builtin().entries() {
        let ir = m.build(ModelDims::uniform(2, 8)).unwrap();
        let prog = compile(&ir);
        // Small budgets force several intervals per group (no intervals,
        // no pipeline) and several shards per interval.
        let mut cfg = cfg_for(&prog, 2 * 1024, 4 * 1024);
        cfg.num_sthreads = 4;
        let x = weights::init_features(7, g.num_vertices(), ir.input_dim() as usize);
        for parts in [partition_fggp(&g, cfg), partition_dsw(&g, cfg)] {
            assert!(parts.intervals.len() > 1, "need intervals to pipeline");
            let golden = Executor::new(&prog, &parts)
                .with_pipeline_mode(PipelineMode::Off)
                .with_workers(1)
                .run(&x, &deg);
            for workers in [1usize, 4] {
                let mut ex = Executor::new(&prog, &parts)
                    .with_pipeline_mode(PipelineMode::Interval)
                    .with_workers(workers);
                let got = ex.run(&x, &deg);
                assert!(
                    ex.prepared_intervals() > 0,
                    "{} ({:?}, {workers} workers): pipelining never engaged",
                    m.name(),
                    parts.method,
                );
                assert!(
                    got.bits_eq(&golden),
                    "{} ({:?}, {workers} workers): pipelined run diverged bitwise \
                     from the sequential reference",
                    m.name(),
                    parts.method,
                );
            }
        }
    }
}

#[test]
fn group_pipelined_executor_bit_identical_and_engages() {
    // PipelineMode::Group hands the prologue computes to the persistent
    // prepare lane (overlapping the ApplyPhase, and group boundaries
    // where the dependence gate allows) — outputs must stay bit-identical
    // to the strictly sequential reference, and the pipeline must
    // actually engage.
    use crate::exec::PipelineMode;
    use crate::ir::spec::ModelDims;
    use crate::ir::zoo::ModelZoo;
    let g = Csr::from_edge_list(&generators::rmat(1 << 8, 3_000, 0.57, 0.19, 0.19, 43));
    let deg = degree_col(&g);
    for m in ModelZoo::builtin().entries() {
        let ir = m.build(ModelDims::uniform(2, 8)).unwrap();
        let prog = compile(&ir);
        let mut cfg = cfg_for(&prog, 2 * 1024, 4 * 1024);
        cfg.num_sthreads = 4;
        let x = weights::init_features(7, g.num_vertices(), ir.input_dim() as usize);
        for parts in [partition_fggp(&g, cfg), partition_dsw(&g, cfg)] {
            assert!(parts.intervals.len() > 1, "need intervals to pipeline");
            let golden = Executor::new(&prog, &parts)
                .with_pipeline_mode(PipelineMode::Off)
                .with_workers(1)
                .run(&x, &deg);
            for workers in [1usize, 4] {
                let mut ex = Executor::new(&prog, &parts)
                    .with_pipeline_mode(PipelineMode::Group)
                    .with_workers(workers);
                let got = ex.run(&x, &deg);
                assert!(
                    ex.prepared_intervals() > 0,
                    "{} ({:?}, {workers} workers): group pipelining never engaged",
                    m.name(),
                    parts.method,
                );
                assert!(
                    got.bits_eq(&golden),
                    "{} ({:?}, {workers} workers): group-pipelined run diverged \
                     bitwise from the sequential reference",
                    m.name(),
                    parts.method,
                );
                // Reruns on a live pool + prepare lane stay bit-identical.
                let again = ex.run(&x, &deg);
                assert!(again.bits_eq(&golden), "rerun diverged");
            }
        }
    }
}

#[test]
fn pool_lifecycle_reuses_threads_and_scratch() {
    // The persistent-pool lifecycle pins the "zero thread spawns per
    // interval in steady state" acceptance criterion: threads are spawned
    // once at the first drain (none at all for a single worker), reruns
    // reuse them (`spawned` frozen) and their warm scratch (no new pool
    // misses), and dropping the executor joins everything (the liveness
    // probe dies — no leaked threads).
    let g = Csr::from_edge_list(&generators::rmat(1 << 8, 3_000, 0.57, 0.19, 0.19, 47));
    let ir = Model::Gcn.build(2, 8, 8, 8);
    let prog = compile(&ir);
    let cfg = cfg_for(&prog, 2 * 1024, 4 * 1024);
    let parts = partition_fggp(&g, cfg);
    let x = weights::init_features(7, g.num_vertices(), 8);
    let deg = degree_col(&g);
    for workers in [1usize, 2, 8] {
        let mut ex = Executor::new(&prog, &parts).with_workers(workers);
        assert!(ex.pool_probe().is_none(), "pool must not exist before a run");
        let out1 = ex.run(&x, &deg);
        let after_warmup = ex.pool_stats();
        assert_eq!(
            after_warmup.spawned,
            if workers > 1 { workers as u64 } else { 0 },
            "{workers} workers: pool spawned the wrong number of threads"
        );
        assert!(after_warmup.batches > 0, "no batches recorded");
        let warm = ex.scratch_stats();
        assert!(warm.misses > 0, "first run must populate the pools");
        let probe = ex.pool_probe().expect("pool exists after a run");
        assert!(probe.upgrade().is_some(), "pool probe dead while pool lives");
        // Idle gap, then rerun: same threads (spawn counter frozen — zero
        // spawns per interval in steady state), warm scratch (miss counter
        // frozen — exact at any width, thanks to the static shard→worker
        // affinity), identical bits.
        let out2 = ex.run(&x, &deg);
        let steady = ex.pool_stats();
        assert_eq!(
            steady.spawned, after_warmup.spawned,
            "{workers} workers: rerun spawned threads"
        );
        assert_eq!(steady.workers, workers.max(1));
        assert!(steady.batches > after_warmup.batches, "rerun ran no batches");
        let steady_scratch = ex.scratch_stats();
        assert_eq!(
            steady_scratch.misses, warm.misses,
            "{workers} workers: steady-state rerun allocated fresh buffers"
        );
        assert!(out1.bits_eq(&out2), "{workers} workers: rerun diverged bitwise");
        drop(ex);
        assert!(
            probe.upgrade().is_none(),
            "{workers} workers: worker threads leaked past executor drop"
        );
    }
}

#[test]
fn pipelined_scratch_arena_steady_state_no_new_misses() {
    // Interval pipelining holds two interval states live at once (the
    // active one plus the standby being prepared), so the interval pools
    // run two deep per slot. The allocation-freedom property must hold at
    // that depth: once the first run has sized the pools, a repeat run
    // (single worker, deterministic prepare order) allocates nothing.
    use crate::exec::PipelineMode;
    let g = Csr::from_edge_list(&generators::rmat(1 << 8, 3_000, 0.57, 0.19, 0.19, 37));
    let ir = Model::Gcn.build(2, 8, 8, 8);
    let prog = compile(&ir);
    let cfg = cfg_for(&prog, 2 * 1024, 4 * 1024);
    let parts = partition_fggp(&g, cfg);
    assert!(
        parts.intervals.len() > 1,
        "need multiple intervals to exercise depth-2 buffer reuse"
    );
    let x = weights::init_features(7, g.num_vertices(), 8);
    let deg = degree_col(&g);
    let mut ex = Executor::new(&prog, &parts)
        .with_pipeline_mode(PipelineMode::Interval)
        .with_workers(1);
    let out1 = ex.run(&x, &deg);
    assert!(ex.prepared_intervals() > 0, "pipelining never engaged");
    let warm = ex.scratch_stats();
    assert!(warm.misses > 0, "first run must populate the pools");
    let out2 = ex.run(&x, &deg);
    let steady = ex.scratch_stats();
    assert_eq!(
        steady.misses, warm.misses,
        "steady-state pipelined run allocated fresh buffers (pool misses grew)"
    );
    assert!(steady.hits > warm.hits, "steady-state run bypassed the pools");
    assert!(out1.bits_eq(&out2), "repeat pipelined run diverged bitwise");
}

#[test]
fn scratch_arena_steady_state_no_new_misses() {
    // The allocation-freedom property: once the first run has sized every
    // pool, a repeat run (identical shard/interval demands, single worker
    // so the assignment is deterministic) must serve every buffer request
    // from the arenas — the miss counter may not move.
    let g = Csr::from_edge_list(&generators::rmat(1 << 8, 3_000, 0.57, 0.19, 0.19, 29));
    let ir = Model::Gcn.build(2, 8, 8, 8);
    let prog = compile(&ir);
    let cfg = cfg_for(&prog, 2 * 1024, 4 * 1024);
    let parts = partition_fggp(&g, cfg);
    assert!(
        parts.intervals.len() > 1,
        "need multiple intervals to exercise buffer reuse"
    );
    let x = weights::init_features(7, g.num_vertices(), 8);
    let deg = degree_col(&g);
    let mut ex = Executor::new(&prog, &parts).with_workers(1);
    let out1 = ex.run(&x, &deg);
    let warm = ex.scratch_stats();
    // Reuse already kicks in within the first run: intervals after the
    // first of each group recycle the previous interval's buffers.
    assert!(warm.hits > 0, "no pool reuse within the first run");
    assert!(warm.misses > 0, "first run must populate the pools");
    let out2 = ex.run(&x, &deg);
    let steady = ex.scratch_stats();
    assert_eq!(
        steady.misses, warm.misses,
        "steady-state run allocated fresh buffers (pool misses grew)"
    );
    assert!(steady.hits > warm.hits, "steady-state run bypassed the pools");
    assert!(out1.bits_eq(&out2), "repeat run diverged bitwise");
}

#[test]
fn default_worker_count_follows_partition_sthreads() {
    let ir = Model::Gcn.build(2, 8, 8, 8);
    let prog = compile(&ir);
    let g = Csr::from_edge_list(&generators::mesh2d(4, 4, false));
    let mut cfg = cfg_for(&prog, 4 * 1024, 4 * 1024);
    cfg.num_sthreads = 3;
    let parts = partition_fggp(&g, cfg);
    assert_eq!(Executor::new(&prog, &parts).workers(), 3);
    assert_eq!(Executor::new(&prog, &parts).with_workers(8).workers(), 8);
}

#[test]
fn executor_output_ref_points_at_result() {
    let ir = Model::Gcn.build(2, 8, 8, 8);
    let prog = compile(&ir);
    let g = Csr::from_edge_list(&generators::mesh2d(4, 4, false));
    let cfg = cfg_for(&prog, 4 * 1024, 4 * 1024);
    let parts = partition_fggp(&g, cfg);
    let ex = Executor::new(&prog, &parts);
    // The output ref must be a Node (not Input/Degree).
    assert!(matches!(ex.output_ref(), crate::isa::DataRef::Node(_)));
}

#[test]
fn batched_executor_bit_identical_to_sequential() {
    // The cross-request batching property: one batched run over B
    // column-stacked feature matrices must reproduce, per request, the
    // exact bits of B solo runs — on every zoo model, both partition
    // methods, batch sizes 1/3/8 and both worker counts. Stacking never
    // reorders any per-request FP reduction, so `bits_eq` (not allclose)
    // is the bar.
    use crate::exec::RunRequest;
    use crate::ir::spec::ModelDims;
    use crate::ir::zoo::ModelZoo;
    let g = Csr::from_edge_list(&generators::rmat(1 << 8, 3_000, 0.57, 0.19, 0.19, 53));
    let deg = degree_col(&g);
    for m in ModelZoo::builtin().entries() {
        let ir = m.build(ModelDims::uniform(2, 8)).unwrap();
        let prog = compile(&ir);
        let mut cfg = cfg_for(&prog, 2 * 1024, 4 * 1024);
        cfg.num_sthreads = 4;
        for parts in [partition_fggp(&g, cfg), partition_dsw(&g, cfg)] {
            for batch in [1usize, 3, 8] {
                let inputs: Vec<Matrix> = (0..batch)
                    .map(|b| {
                        weights::init_features(
                            7 + b as u64,
                            g.num_vertices(),
                            ir.input_dim() as usize,
                        )
                    })
                    .collect();
                // Solo goldens go through the legacy wrapper, which also
                // pins `run` as a faithful front for `try_run_with`.
                let goldens: Vec<Matrix> = inputs
                    .iter()
                    .map(|x| Executor::new(&prog, &parts).with_workers(1).run(x, &deg))
                    .collect();
                for workers in [1usize, 4] {
                    let mut ex = Executor::new(&prog, &parts).with_workers(workers);
                    let out = ex
                        .try_run_with(&RunRequest::batched(inputs.iter().collect(), &deg))
                        .expect("batched run faulted");
                    assert_eq!(out.batch, batch);
                    assert_eq!(out.outputs.len(), batch);
                    for (i, (got, want)) in out.outputs.iter().zip(&goldens).enumerate() {
                        assert!(
                            got.bits_eq(want),
                            "{} ({:?}, {workers} workers, batch {batch}): request {i} \
                             diverged bitwise from its solo run",
                            m.name(),
                            parts.method,
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn batched_run_performs_one_partition_walk() {
    // The amortization pin at the walk level: a traced batched run emits
    // exactly the solo run's step stream — the executor walks the
    // partitions once per micro-batch, not once per request.
    use crate::exec::RunRequest;
    let ir = Model::Gcn.build(2, 8, 8, 8);
    let prog = compile(&ir);
    let g = Csr::from_edge_list(&generators::rmat(1 << 7, 800, 0.57, 0.19, 0.19, 59));
    let cfg = cfg_for(&prog, 2 * 1024, 4 * 1024);
    let parts = partition_fggp(&g, cfg);
    let deg = degree_col(&g);
    let x0 = weights::init_features(7, g.num_vertices(), 8);
    let (_, solo_steps) = Executor::new(&prog, &parts).run_traced(&x0, &deg);
    let inputs: Vec<Matrix> = (0..8)
        .map(|b| weights::init_features(7 + b as u64, g.num_vertices(), 8))
        .collect();
    let mut ex = Executor::new(&prog, &parts);
    let out = ex
        .try_run_with(&RunRequest::batched(inputs.iter().collect(), &deg).with_trace(true))
        .expect("batched traced run faulted");
    assert_eq!(
        out.steps.expect("trace was requested"),
        solo_steps,
        "a batched run must drive exactly the solo partition walk"
    );
}
