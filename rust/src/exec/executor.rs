//! Functional executor: interprets a compiled PLOF program over a
//! partitioned graph, following the Alg 2 execution order the hardware
//! uses (per group: per interval — ScatterPhase, shards' GatherPhases,
//! ApplyPhase). Produces real numbers; the cycle-level simulator mirrors
//! the same order for time.

use std::collections::HashMap;

use crate::exec::reference::{apply_binary, apply_unary};
use crate::exec::{weights, Matrix};
use crate::isa::{DataRef, Dim, Instr, Program, Reduce, ScatterDir, Space, Sym};
use crate::partition::{Interval, Partitions, Shard};

/// Functional executor over one (program, partitions) pair.
pub struct Executor<'a> {
    program: &'a Program,
    parts: &'a Partitions,
    /// Off-chip storage, keyed by DataRef: vertex arrays are `[N, cols]`,
    /// edge arrays `[M, cols]`.
    dram: HashMap<DataRef, Matrix>,
    weights: HashMap<Sym, Matrix>,
}

impl<'a> Executor<'a> {
    pub fn new(program: &'a Program, parts: &'a Partitions) -> Self {
        let mut w = HashMap::new();
        for wi in &program.weights {
            w.insert(wi.sym, weights::init_weight(wi.seed, wi.rows, wi.cols));
        }
        Executor {
            program,
            parts,
            dram: HashMap::new(),
            weights: w,
        }
    }

    /// Run the whole program. `x` is `[N, in_dim]`; `degree` the in-degree
    /// column used by `DataRef::Degree`.
    pub fn run(&mut self, x: &Matrix, degree: &Matrix) -> Matrix {
        assert_eq!(x.rows, self.parts.num_vertices);
        assert_eq!(x.cols as u32, self.program.in_dim);
        self.dram.insert(DataRef::Input, x.clone());
        self.dram.insert(DataRef::Degree, degree.clone());

        for group in &self.program.groups {
            for (ii, iv) in self.parts.intervals.iter().enumerate() {
                let mut ictx = IntervalCtx::new(iv);
                // ScatterPhase (iThread).
                for i in &group.scatter {
                    self.exec_interval_instr(i, &mut ictx);
                }
                // Gather accumulators exist per interval even when the
                // interval has no shards (isolated destination ranges).
                for i in &group.gather {
                    match i {
                        Instr::Gather { reduce, dst, cols, .. }
                        | Instr::FusedGather { reduce, dst, cols, .. } => {
                            let _ = ictx.accumulator(*dst, *reduce, *cols as usize);
                        }
                        _ => {}
                    }
                }
                // GatherPhase per shard (sThreads).
                for shard in self.parts.shards_of(ii) {
                    let mut sctx = ShardCtx::new(shard);
                    for i in &group.gather {
                        self.exec_shard_instr(i, &mut ictx, &mut sctx);
                    }
                }
                // Mean finalisation + empty-row convention.
                ictx.finalize_gathers();
                // ApplyPhase (iThread).
                for i in &group.apply {
                    self.exec_interval_instr(i, &mut ictx);
                }
            }
        }

        // Assemble the output from DRAM.
        let out_ref = self.output_ref();
        self.dram
            .get(&out_ref)
            .unwrap_or_else(|| panic!("program never stored its output"))
            .clone()
    }

    /// The DataRef holding the final result: the last `ST.D` of the last
    /// group's ApplyPhase.
    pub fn output_ref(&self) -> DataRef {
        self.program
            .groups
            .last()
            .and_then(|g| {
                g.apply.iter().rev().find_map(|i| match i {
                    Instr::St { data, .. } => Some(*data),
                    _ => None,
                })
            })
            .expect("last group must store the result")
    }

    // ---- interval-phase execution (Scatter / Apply) --------------------------

    fn exec_interval_instr(&mut self, i: &Instr, ictx: &mut IntervalCtx) {
        let v = ictx.len();
        match i {
            Instr::Ld { sym, data, cols, .. } => {
                let src = &self.dram[data];
                let mut m = Matrix::zeros(v, *cols as usize);
                for (r, gv) in (ictx.begin..ictx.end).enumerate() {
                    m.row_mut(r).copy_from_slice(src.row(gv));
                }
                ictx.d.insert(*sym, m);
            }
            Instr::St { sym, data, cols, .. } => {
                let m = &ictx.d[sym];
                let dst = self
                    .dram
                    .entry(*data)
                    .or_insert_with(|| Matrix::zeros(self.parts.num_vertices, *cols as usize));
                for (r, gv) in (ictx.begin..ictx.end).enumerate() {
                    dst.row_mut(gv).copy_from_slice(m.row(r));
                }
            }
            _ => {
                let out = self.compute(i, Dim::V, v, &ictx.d, None, &ictx.d);
                ictx.d.insert(i.def().expect("compute defines"), out);
            }
        }
    }

    // ---- shard-phase execution (Gather) ---------------------------------------

    fn exec_shard_instr(&mut self, i: &Instr, ictx: &mut IntervalCtx, sctx: &mut ShardCtx) {
        let shard = sctx.shard;
        match i {
            Instr::Ld { sym, data, cols, .. } => {
                let src = &self.dram[data];
                match sym.space {
                    Space::S => {
                        let mut m = Matrix::zeros(shard.num_src(), *cols as usize);
                        for (r, &gv) in shard.sources.iter().enumerate() {
                            m.row_mut(r).copy_from_slice(src.row(gv as usize));
                        }
                        sctx.s.insert(*sym, m);
                    }
                    Space::E => {
                        let mut m = Matrix::zeros(shard.num_edges(), *cols as usize);
                        for (r, e) in shard.edges.iter().enumerate() {
                            m.row_mut(r).copy_from_slice(src.row(e.edge_id as usize));
                        }
                        sctx.e.insert(*sym, m);
                    }
                    _ => panic!("GatherPhase LD of {sym}"),
                }
            }
            Instr::St { sym, data, cols, .. } => {
                // ST.E — spill edge rows at canonical ids.
                let m = &sctx.e[sym];
                let dst = self
                    .dram
                    .entry(*data)
                    .or_insert_with(|| Matrix::zeros(self.parts.num_edges, *cols as usize));
                for (r, e) in shard.edges.iter().enumerate() {
                    dst.row_mut(e.edge_id as usize).copy_from_slice(m.row(r));
                }
            }
            Instr::Scatter { dir, dst, src, cols } => {
                let mut out = Matrix::zeros(shard.num_edges(), *cols as usize);
                match dir {
                    ScatterDir::SrcToEdge => {
                        let sm = &sctx.s[src];
                        for (r, e) in shard.edges.iter().enumerate() {
                            out.row_mut(r).copy_from_slice(sm.row(e.src_slot as usize));
                        }
                    }
                    ScatterDir::DstToEdge => {
                        let dm = &ictx.d[src];
                        for (r, e) in shard.edges.iter().enumerate() {
                            let local = (e.dst - ictx.begin as u32) as usize;
                            out.row_mut(r).copy_from_slice(dm.row(local));
                        }
                    }
                }
                sctx.e.insert(*dst, out);
            }
            Instr::FusedGather {
                reduce,
                dst,
                src,
                scale,
                cols,
            } => {
                let iv_begin = ictx.begin as u32;
                let scale_col: Option<Vec<f32>> = scale.map(|sc| {
                    let m = &sctx.e[&sc];
                    (0..shard.num_edges()).map(|r| m.get(r, 0)).collect()
                });
                let acc = ictx.accumulator(*dst, *reduce, *cols as usize);
                let sm = &sctx.s[src];
                for (r, e) in shard.edges.iter().enumerate() {
                    let local = (e.dst - iv_begin) as usize;
                    acc.counts[local] += 1;
                    let row = sm.row(e.src_slot as usize);
                    let f = scale_col.as_ref().map_or(1.0, |c| c[r]);
                    let orow = acc.m.row_mut(local);
                    match reduce {
                        Reduce::Sum | Reduce::Mean => {
                            for (o, &x) in orow.iter_mut().zip(row) {
                                *o += x * f;
                            }
                        }
                        Reduce::Max => {
                            for (o, &x) in orow.iter_mut().zip(row) {
                                *o = o.max(x * f);
                            }
                        }
                    }
                }
            }
            Instr::Gather {
                reduce,
                dst,
                src,
                cols,
            } => {
                let iv_begin = ictx.begin as u32;
                let acc = ictx.accumulator(*dst, *reduce, *cols as usize);
                let ev = &sctx.e[src];
                for (r, e) in shard.edges.iter().enumerate() {
                    let local = (e.dst - iv_begin) as usize;
                    acc.counts[local] += 1;
                    let row = ev.row(r);
                    let orow = acc.m.row_mut(local);
                    match reduce {
                        Reduce::Sum | Reduce::Mean => {
                            for (o, &x) in orow.iter_mut().zip(row) {
                                *o += x;
                            }
                        }
                        Reduce::Max => {
                            for (o, &x) in orow.iter_mut().zip(row) {
                                *o = o.max(x);
                            }
                        }
                    }
                }
            }
            _ => {
                // Shard-side compute: rows decode against the shard.
                let rows_dim = instr_rows(i);
                let rows = rows_dim.decode(ictx.len(), shard.num_src(), shard.num_edges());
                let out = self.compute(i, rows_dim, rows, &sctx.s, Some(&sctx.e), &ictx.d);
                match i.def().expect("compute defines").space {
                    Space::S => sctx.s.insert(i.def().unwrap(), out),
                    Space::E => sctx.e.insert(i.def().unwrap(), out),
                    _ => panic!("GatherPhase compute must write S/E"),
                };
            }
        }
    }

    /// Evaluate a compute instruction. Operand lookup: W from weights, S
    /// from `s`, E from `e` (if present), D from `d`.
    fn compute(
        &self,
        i: &Instr,
        _rows_dim: Dim,
        rows: usize,
        s: &HashMap<Sym, Matrix>,
        e: Option<&HashMap<Sym, Matrix>>,
        d: &HashMap<Sym, Matrix>,
    ) -> Matrix {
        let look = |sym: &Sym| -> &Matrix {
            match sym.space {
                Space::W => &self.weights[sym],
                Space::S => s.get(sym).unwrap_or_else(|| panic!("S operand {sym} missing")),
                Space::E => e
                    .and_then(|m| m.get(sym))
                    .unwrap_or_else(|| panic!("E operand {sym} missing")),
                Space::D => d.get(sym).unwrap_or_else(|| panic!("D operand {sym} missing")),
            }
        };
        match i {
            Instr::Elw {
                op,
                a,
                b,
                broadcast_b,
                cols,
                ..
            } => {
                let am = look(a);
                let mut out = Matrix::zeros(rows, *cols as usize);
                match b {
                    None => {
                        for r in 0..rows {
                            for c in 0..*cols as usize {
                                out.set(r, c, apply_unary(*op, am.get(r, c)));
                            }
                        }
                    }
                    Some(bs) => {
                        let bm = look(bs);
                        for r in 0..rows {
                            let br = if *broadcast_b { 0 } else { r };
                            for c in 0..*cols as usize {
                                out.set(r, c, apply_binary(*op, am.get(r, c), bm.get(br, c)));
                            }
                        }
                    }
                }
                out
            }
            Instr::RowScale { a, scale, cols, .. } => {
                let am = look(a);
                let sm = look(scale);
                let mut out = Matrix::zeros(rows, *cols as usize);
                for r in 0..rows {
                    let f = sm.get(r, 0);
                    for c in 0..*cols as usize {
                        out.set(r, c, am.get(r, c) * f);
                    }
                }
                out
            }
            Instr::Concat {
                a, b, cols_a, cols_b, ..
            } => {
                let am = look(a);
                let bm = look(b);
                let mut out = Matrix::zeros(rows, (*cols_a + *cols_b) as usize);
                for r in 0..rows {
                    out.row_mut(r)[..*cols_a as usize].copy_from_slice(am.row(r));
                    out.row_mut(r)[*cols_a as usize..].copy_from_slice(bm.row(r));
                }
                out
            }
            Instr::Dmm { a, w, .. } => {
                let am = look(a);
                let wm = look(w);
                am.matmul(wm)
            }
            _ => panic!("not a compute instruction: {}", i.render()),
        }
    }
}

fn instr_rows(i: &Instr) -> Dim {
    match i {
        Instr::Elw { rows, .. }
        | Instr::RowScale { rows, .. }
        | Instr::Concat { rows, .. }
        | Instr::Dmm { rows, .. } => *rows,
        Instr::Scatter { .. } | Instr::Gather { .. } | Instr::FusedGather { .. } => Dim::E,
        Instr::Ld { rows, .. } | Instr::St { rows, .. } => *rows,
    }
}

/// Per-interval state: resident D buffers + gather accumulators.
struct IntervalCtx<'a> {
    begin: usize,
    end: usize,
    d: HashMap<Sym, Matrix>,
    gathers: Vec<(Sym, Reduce)>,
    counts: HashMap<Sym, Vec<u32>>,
    _iv: &'a Interval,
}

/// A gather accumulator view.
struct AccView<'m> {
    m: &'m mut Matrix,
    counts: &'m mut Vec<u32>,
}

impl<'a> IntervalCtx<'a> {
    fn new(iv: &'a Interval) -> Self {
        IntervalCtx {
            begin: iv.begin as usize,
            end: iv.end as usize,
            d: HashMap::new(),
            gathers: Vec::new(),
            counts: HashMap::new(),
            _iv: iv,
        }
    }

    fn len(&self) -> usize {
        self.end - self.begin
    }

    /// Lazily-initialised gather accumulator (first touch in this
    /// interval zeroes it — mirrors the hardware's phase-scheduler reset).
    fn accumulator(&mut self, sym: Sym, reduce: Reduce, cols: usize) -> AccView<'_> {
        if !self.d.contains_key(&sym) || !self.counts.contains_key(&sym) {
            let init = match reduce {
                Reduce::Sum | Reduce::Mean => Matrix::zeros(self.len(), cols),
                Reduce::Max => Matrix::filled(self.len(), cols, f32::NEG_INFINITY),
            };
            self.d.insert(sym, init);
            self.counts.insert(sym, vec![0; self.len()]);
            self.gathers.push((sym, reduce));
        }
        AccView {
            m: self.d.get_mut(&sym).unwrap(),
            counts: self.counts.get_mut(&sym).unwrap(),
        }
    }

    /// Post-shard fixups: Mean division and the zero-for-empty convention.
    fn finalize_gathers(&mut self) {
        for (sym, reduce) in std::mem::take(&mut self.gathers) {
            let counts = self.counts.remove(&sym).unwrap();
            let m = self.d.get_mut(&sym).unwrap();
            for (r, &cnt) in counts.iter().enumerate() {
                if cnt == 0 {
                    m.row_mut(r).fill(0.0);
                } else if reduce == Reduce::Mean {
                    let inv = 1.0 / cnt as f32;
                    for v in m.row_mut(r) {
                        *v *= inv;
                    }
                }
            }
        }
    }
}

/// Per-shard state: S and E buffers.
struct ShardCtx<'a> {
    shard: &'a Shard,
    s: HashMap<Sym, Matrix>,
    e: HashMap<Sym, Matrix>,
}

impl<'a> ShardCtx<'a> {
    fn new(shard: &'a Shard) -> Self {
        ShardCtx {
            shard,
            s: HashMap::new(),
            e: HashMap::new(),
        }
    }
}
