//! Functional executor: interprets a compiled PLOF program over a
//! partitioned graph. The execution order is not defined here — the
//! executor is a [`PhaseVisitor`] over [`sched::PartitionWalk`], the
//! same canonical Alg 2 traversal the cycle simulator drives through.
//!
//! Performance properties mirroring the hardware:
//!
//! * **Partition-level multi-threading in software**: shards within an
//!   interval are independent (paper §IV-C), so their GatherPhases run
//!   across a scoped-thread worker pool (default width = the
//!   partitioning's simulated sThread count). Each shard produces
//!   *partial* gather accumulators that are merged in canonical shard
//!   order after the pool drains, so the output is bit-identical for
//!   every worker count — including the forced single-worker mode the
//!   differential tests pin.
//! * **Dense slot arenas**: symbols and DRAM arrays are addressed by
//!   `Vec` index (`Program::slot_layout`), not by hashing `Sym`/`DataRef`
//!   per instruction.
//! * **Kernel-layer inner loops** ([`exec::kernels`](crate::exec::kernels)):
//!   cache-blocked branch-free DMM and fused slice-based row kernels
//!   drive every compute instruction, the gather inner loops, and the
//!   shard merge. The pre-kernel per-element loops are preserved as
//!   [`KernelMode::Naive`] purely as the bit-identity reference the
//!   differential tests diff against.
//! * **Scratch arenas** ([`exec::scratch`](crate::exec::scratch)):
//!   interval matrices, gather accumulators, and per-worker shard
//!   matrices are recycled through slot-keyed buffer pools, so the walk
//!   performs no per-shard / per-interval `Matrix` allocation once the
//!   first interval of a group has sized the pools (steady state; exact
//!   under deterministic single-worker assignment, asymptotic under the
//!   racy multi-worker pool whose per-worker arenas warm independently).
//! * **Interval pipelining** ([`PipelineMode::Interval`], the default):
//!   the phases of consecutive intervals overlap on different resources,
//!   exactly as the paper's partition-level multi-threading (§IV-C) and
//!   the cycle simulator's SLMT timing model describe. While interval
//!   *i*'s shards drain through the worker pool, the main (iThread)
//!   thread prepares interval *i+1*'s DstBuffer state — ScatterPhase LDs
//!   and computes plus the pre-created gather accumulators — into a
//!   second `IntervalState` ping-ponged through the scratch pools
//!   (pipeline depth 2). The walk order, merge order, and output bits
//!   are untouched: only *when* next-interval state is materialised
//!   changes, and only for groups where that is provably safe (no
//!   ScatterPhase STs, no ScatterPhase LD of a DataRef the same group
//!   stores — the prologue group stays strictly sequential).
//!   [`PipelineMode::Off`] preserves the sequential order as the golden
//!   reference of the pipelining differential tests.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::exec::kernels;
use crate::exec::reference::{apply_binary, apply_unary};
use crate::exec::scratch::{IntervalScratch, Pool, ScratchStats, WorkerScratch};
use crate::exec::{weights, Matrix};
use crate::isa::{
    DataRef, Dim, Instr, PhaseGroup, Program, Reduce, ScatterDir, SlotLayout, Space, Sym,
};
use crate::obs::trace;
use crate::partition::{Interval, Partitions, Shard};
use crate::sched::{PartitionWalk, PhaseProfile, PhaseVisitor, StepCtx, Traced, WalkStep};

/// Which compute-instruction implementation the executor runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelMode {
    /// The kernel layer: blocked branch-free DMM + slice-based ELW /
    /// RSCALE / CAT writing into scratch-arena buffers. The default.
    #[default]
    Blocked,
    /// The preserved pre-kernel reference: naive zero-skipping matmul and
    /// per-element `get`/`set` loops, allocating fresh matrices. Kept
    /// only so tests can prove the kernel path bit-identical.
    Naive,
}

/// Whether the executor overlaps consecutive destination intervals.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PipelineMode {
    /// Double-buffered interval pipelining: while one interval's shards
    /// drain through the worker pool, the next interval's DstBuffer state
    /// is prepared from a second buffer set (walk lookahead, see the
    /// module docs). Bit-identical to [`PipelineMode::Off`]. The default.
    #[default]
    Interval,
    /// Strictly sequential intervals — the golden reference the
    /// pipelining differential tests diff against.
    Off,
}

impl PipelineMode {
    /// CLI rendering (`bench --pipeline on|off`, trailer lines).
    pub fn label(&self) -> &'static str {
        match self {
            PipelineMode::Interval => "on",
            PipelineMode::Off => "off",
        }
    }
}

/// A next-interval state built under the previous interval's gather drain,
/// waiting for its `begin_interval` to swap it in.
struct Prepared {
    group: usize,
    interval: usize,
    state: IntervalState,
}

/// Functional executor over one (program, partitions) pair.
pub struct Executor<'a> {
    program: &'a Program,
    parts: &'a Partitions,
    layout: SlotLayout,
    /// Off-chip storage arena indexed by [`DataRef::slot`]: vertex arrays
    /// are `[N, cols]`, edge arrays `[M, cols]`.
    dram: Vec<Option<Matrix>>,
    /// Weight arena indexed by W-symbol id.
    weights: Vec<Option<Matrix>>,
    /// GatherPhase worker-pool width (the software sThread count).
    workers: usize,
    mode: KernelMode,
    /// Live state of the interval currently being walked. Never dropped:
    /// `begin_interval` drains its matrices back into `iv_scratch` and
    /// re-arms it (or swaps in a prepared standby and keeps this one as
    /// the spare), so at most two interval states — pipeline depth 2 —
    /// are ever allocated per executor.
    iv: Option<IntervalState>,
    /// Shard indices queued by `gather_shard`, drained at `end_gather`.
    pending: Vec<usize>,
    /// iThread-side buffer pools (D matrices + gather accumulators).
    iv_scratch: IntervalScratch,
    /// One scratch arena per GatherPhase worker, grown lazily to the pool
    /// width. Merged buffers return to the worker they came from, so each
    /// arena's contents stay effectively thread-private.
    shard_scratch: Vec<Mutex<WorkerScratch>>,
    /// Per `(group, gather-instr)` flag: true when an `ST.E` is the last
    /// use of its symbol in the phase, so the spill can move the matrix
    /// out of the arena instead of cloning it.
    movable_spills: Vec<Vec<bool>>,
    /// Interval-pipelining mode (see [`PipelineMode`]).
    pipeline: PipelineMode,
    /// Per-group prefetch safety, computed once at construction: a group
    /// may pipeline only when its ScatterPhase contains no `ST` and no
    /// `LD` of a DataRef the same group stores — otherwise preparing the
    /// next interval early would write DRAM ahead of order, or read rows
    /// the in-flight interval's merge/apply is still producing. (In
    /// practice this keeps the prologue sweep sequential; groups are DRAM
    /// barriers for everything else.)
    prefetchable: Vec<bool>,
    /// The walker's `lookahead_interval` notice: `(group, next interval)`
    /// to prepare during the coming `end_gather` drain.
    lookahead: Option<(usize, usize)>,
    /// A prepared next-interval state (pipeline depth 2: this plus `iv`).
    standby: Option<Prepared>,
    /// Empty `IntervalState` container recycled between preparations, so
    /// depth-2 pipelining allocates its second state exactly once.
    spare: Option<IntervalState>,
    /// True when the current interval's ScatterPhase already ran at
    /// prepare time — `scatter_phase` then skips, verbatim.
    scatter_prepared: bool,
    /// Per-group `(prepared intervals, seconds)` pipelining telemetry for
    /// the last run; backfilled into `PhaseProfile` by `run_profiled`.
    prep_stats: Vec<(u64, f64)>,
}

impl<'a> Executor<'a> {
    pub fn new(program: &'a Program, parts: &'a Partitions) -> Self {
        let layout = program.slot_layout();
        let mut w = vec![None; layout.w];
        for wi in &program.weights {
            w[wi.sym.id as usize] = Some(weights::init_weight(wi.seed, wi.rows, wi.cols));
        }
        let movable_spills = program
            .groups
            .iter()
            .map(|g| {
                g.gather
                    .iter()
                    .enumerate()
                    .map(|(idx, i)| match i {
                        Instr::St { sym, .. } if sym.space == Space::E => {
                            !g.gather[idx + 1..].iter().any(|later| later.uses().contains(sym))
                        }
                        _ => false,
                    })
                    .collect()
            })
            .collect();
        let prefetchable = program
            .groups
            .iter()
            .map(|g| {
                let stores: Vec<usize> = g
                    .all_instrs()
                    .filter_map(|i| match i {
                        Instr::St { data, .. } => Some(data.slot()),
                        _ => None,
                    })
                    .collect();
                g.scatter.iter().all(|i| match i {
                    Instr::St { .. } => false,
                    Instr::Ld { data, .. } => !stores.contains(&data.slot()),
                    _ => true,
                })
            })
            .collect();
        Executor {
            program,
            parts,
            iv_scratch: IntervalScratch::new(&layout),
            layout,
            dram: Vec::new(),
            weights: w,
            workers: parts.config.num_sthreads.max(1) as usize,
            mode: KernelMode::default(),
            iv: None,
            pending: Vec::new(),
            shard_scratch: Vec::new(),
            movable_spills,
            pipeline: PipelineMode::default(),
            prefetchable,
            lookahead: None,
            standby: None,
            spare: None,
            scatter_prepared: false,
            prep_stats: Vec::new(),
        }
    }

    /// Override the GatherPhase worker-pool width. Defaults to the
    /// partitioning's simulated sThread count; `1` forces the serial
    /// path. Outputs are bit-identical across widths.
    pub fn with_workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Select the compute-kernel implementation (differential tests run
    /// [`KernelMode::Naive`] as the golden reference).
    pub fn with_kernel_mode(mut self, mode: KernelMode) -> Self {
        self.mode = mode;
        self
    }

    /// Select the interval-pipelining mode (differential tests run
    /// [`PipelineMode::Off`] as the golden reference).
    pub fn with_pipeline_mode(mut self, mode: PipelineMode) -> Self {
        self.pipeline = mode;
        self
    }

    /// The effective worker-pool width.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The active compute-kernel implementation.
    pub fn kernel_mode(&self) -> KernelMode {
        self.mode
    }

    /// The active interval-pipelining mode.
    pub fn pipeline_mode(&self) -> PipelineMode {
        self.pipeline
    }

    /// Intervals whose DstBuffer state was prepared ahead of order during
    /// the last run — 0 when pipelining is off, every group is
    /// single-interval, or no group is prefetch-safe.
    pub fn prepared_intervals(&self) -> u64 {
        self.prep_stats.iter().map(|&(n, _)| n).sum()
    }

    /// Aggregate scratch-arena hit/miss counters (interval pools + every
    /// worker arena). In steady state — after the first interval of each
    /// group has sized the pools — `misses` stops growing. That guarantee
    /// is exact for deterministic shard assignment (a single worker, as
    /// `scratch_arena_steady_state_no_new_misses` pins); with a racy
    /// multi-worker pool a worker can still meet a shard size its private
    /// arena has never seen, so misses taper rather than stop.
    pub fn scratch_stats(&self) -> ScratchStats {
        let mut st = self.iv_scratch.stats();
        for ws in &self.shard_scratch {
            st.merge(ws.lock().unwrap().stats());
        }
        st
    }

    /// Run the whole program. `x` is `[N, in_dim]`; `degree` the in-degree
    /// column used by `DataRef::Degree`.
    pub fn run(&mut self, x: &Matrix, degree: &Matrix) -> Matrix {
        self.seed_inputs(x, degree);
        PartitionWalk::new(self.program, self.parts).drive(&mut *self);
        self.take_output()
    }

    /// Like [`Executor::run`], additionally recording the walker's
    /// `(group, interval, shard, phase)` trace — the order-equivalence
    /// witness the scheduler tests compare against the simulator's.
    pub fn run_traced(&mut self, x: &Matrix, degree: &Matrix) -> (Matrix, Vec<WalkStep>) {
        self.seed_inputs(x, degree);
        let walk = PartitionWalk::new(self.program, self.parts);
        let mut traced = Traced::new(&mut *self);
        walk.drive(&mut traced);
        let steps = traced.into_steps();
        (self.take_output(), steps)
    }

    /// Like [`Executor::run`], additionally timing every walk phase —
    /// the `switchblade bench --profile` path.
    ///
    /// Implemented on the span stream: an [`obs::trace`](crate::obs::trace)
    /// session is opened around the walk (re-entrant — inside a
    /// surrounding `--trace` session this borrows it and reads only the
    /// tail recorded here, leaving the spans for the outer export) and
    /// [`PhaseProfile::from_spans`] folds the recorded walk + `prepare`
    /// spans into the per-(group, phase) profile. The pipelining columns
    /// need no backfill: the executor's `prepare` spans carry them.
    pub fn run_profiled(&mut self, x: &Matrix, degree: &Matrix) -> (Matrix, PhaseProfile) {
        self.seed_inputs(x, degree);
        let sess = trace::begin();
        let mark = trace::mark();
        PartitionWalk::new(self.program, self.parts).drive(&mut *self);
        let spans = trace::since(mark);
        drop(sess.end());
        let mut profile = PhaseProfile::from_spans(&spans);
        profile.pad_groups(self.program.groups.len());
        (self.take_output(), profile)
    }

    fn seed_inputs(&mut self, x: &Matrix, degree: &Matrix) {
        assert_eq!(x.rows, self.parts.num_vertices);
        assert_eq!(x.cols as u32, self.program.in_dim);
        self.dram = vec![None; self.layout.dram];
        self.dram[DataRef::Input.slot()] = Some(x.clone());
        self.dram[DataRef::Degree.slot()] = Some(degree.clone());
        // Re-arm the pipeline for a fresh walk. A completed walk leaves no
        // standby (the last interval has no lookahead), but recycle one
        // defensively so its buffers flow back into the pools.
        self.lookahead = None;
        self.scatter_prepared = false;
        self.prep_stats.clear();
        if let Some(p) = self.standby.take() {
            let mut st = p.state;
            st.recycle(&mut self.iv_scratch);
            self.spare = Some(st);
        }
    }

    /// Move the output matrix out of its DRAM slot (no copy — the run is
    /// over and `seed_inputs` re-arms the arena for the next one).
    fn take_output(&mut self) -> Matrix {
        let slot = self.output_ref().slot();
        self.dram[slot]
            .take()
            .unwrap_or_else(|| panic!("program never stored its output"))
    }

    /// The DataRef holding the final result: the last `ST.D` of the last
    /// group's ApplyPhase.
    pub fn output_ref(&self) -> DataRef {
        self.program
            .groups
            .last()
            .and_then(|g| {
                g.apply.iter().rev().find_map(|i| match i {
                    Instr::St { data, .. } => Some(*data),
                    _ => None,
                })
            })
            .expect("last group must store the result")
    }

    // ---- interval-phase execution (Scatter / Apply) --------------------------

    fn exec_interval_instr(&mut self, i: &Instr, iv: &mut IntervalState) {
        if let Instr::St { sym, data, cols, .. } = i {
            // ST — the one interval instruction that writes DRAM, so it
            // stays on the sequential path (prefetch-unsafe groups never
            // reach the prepare-ahead code).
            let slot = data.slot();
            if self.dram[slot].is_none() {
                self.dram[slot] = Some(Matrix::zeros(self.parts.num_vertices, *cols as usize));
            }
            let m = iv.d[sym.id as usize]
                .as_ref()
                .unwrap_or_else(|| panic!("ST of undefined {sym}"));
            let dst = self.dram[slot].as_mut().unwrap();
            for (r, gv) in (iv.begin..iv.end).enumerate() {
                dst.row_mut(gv).copy_from_slice(m.row(r));
            }
            return;
        }
        exec_interval_read_instr(
            i,
            iv,
            &self.dram,
            &self.weights,
            &mut self.iv_scratch,
            self.mode,
        );
    }

    // ---- shard-phase execution (Gather) ---------------------------------------

    /// Drain the interval's queued shards through the worker pool, then
    /// merge their partial results in canonical shard order. However the
    /// workers raced, the merge sees the same partials in the same order,
    /// so any pool width is bit-identical to a single worker.
    ///
    /// When the walker announced a lookahead (pipelining on, group
    /// prefetch-safe), the next interval's DstBuffer state is prepared on
    /// this thread *while the workers drain* — the software realisation
    /// of the paper's interval overlap. The standby state is swapped in
    /// by the next `begin_interval`; the serial (≤1 worker) path prepares
    /// after the drain so buffer-pool traffic stays deterministic at any
    /// width.
    fn run_pending_shards(&mut self, cx: &StepCtx) {
        let mut pending = std::mem::take(&mut self.pending);
        let prefetch = self
            .lookahead
            .take()
            .and_then(|(g, i)| (g == cx.group_idx).then_some(i));
        if pending.is_empty() && prefetch.is_none() {
            self.pending = pending; // keep the capacity for the next interval
            return;
        }
        // Rebind the standby container up front (recycling whatever the
        // spare held) so pool take order is independent of the drain.
        let mut standby = prefetch.map(|ni| {
            let mut st = self
                .spare
                .take()
                .unwrap_or_else(|| IntervalState::empty(&self.layout));
            st.reset(&self.parts.intervals[ni], &mut self.iv_scratch);
            (ni, st)
        });
        let mut prep_s = 0.0f64;
        if pending.is_empty() {
            // An interval with no shards still pipelines the next one.
            prep_s = timed_prepare(
                cx.group_idx,
                cx.group,
                &mut standby,
                &self.dram,
                &self.weights,
                &mut self.iv_scratch,
                self.mode,
            );
        } else {
            let workers = self.workers.min(pending.len()).max(1);
            while self.shard_scratch.len() < workers {
                self.shard_scratch
                    .push(Mutex::new(WorkerScratch::new(&self.layout)));
            }
            let mut iv = self.iv.take().expect("interval state");
            let outs: Vec<ShardOut> = {
                // `scratch` (the main thread's prepare arena) and the
                // worker-facing borrows inside `env` are disjoint fields,
                // so the prepare can run under the pool without touching
                // anything a worker reads.
                let scratch = &mut self.iv_scratch;
                let worker_arenas = &self.shard_scratch;
                let env = ShardEnv {
                    layout: &self.layout,
                    weights: &self.weights,
                    dram: &self.dram,
                    iv: &iv,
                    parts: self.parts,
                    gather: &cx.group.gather[..],
                    movable: &self.movable_spills[cx.group_idx][..],
                    mode: self.mode,
                };
                // Worker spans gate on an explicit flag captured here:
                // spawned pool threads cannot see this thread's
                // trace-session flag.
                let tracing = trace::active();
                let (g_arg, i_arg) = (cx.group_idx as i32, cx.interval_idx as i32);
                if workers <= 1 {
                    let outs: Vec<ShardOut> = {
                        let mut ws = worker_arenas[0].lock().unwrap();
                        pending
                            .iter()
                            .map(|&si| {
                                let _span = trace::span_if(
                                    tracing,
                                    trace::names::SHARD,
                                    trace::cat::EXEC,
                                    trace::worker_track(0),
                                    g_arg,
                                    i_arg,
                                    si as i32,
                                );
                                env.run_shard(si, &mut ws, 0)
                            })
                            .collect()
                    };
                    prep_s = timed_prepare(
                        cx.group_idx,
                        cx.group,
                        &mut standby,
                        env.dram,
                        env.weights,
                        scratch,
                        env.mode,
                    );
                    outs
                } else {
                    let cells: Vec<Mutex<Option<ShardOut>>> =
                        pending.iter().map(|_| Mutex::new(None)).collect();
                    let next = AtomicUsize::new(0);
                    let (env_ref, cells_ref, next_ref, pending_ref) =
                        (&env, &cells, &next, &pending);
                    std::thread::scope(|scope| {
                        for (w, ws_cell) in worker_arenas[..workers].iter().enumerate() {
                            scope.spawn(move || {
                                let mut ws = ws_cell.lock().unwrap();
                                loop {
                                    // Dynamic assignment: the next shard goes to
                                    // whichever worker frees first (the software
                                    // analogue of the phase scheduler, §V-B2).
                                    let k = next_ref.fetch_add(1, Ordering::Relaxed);
                                    if k >= pending_ref.len() {
                                        break;
                                    }
                                    let _span = trace::span_if(
                                        tracing,
                                        trace::names::SHARD,
                                        trace::cat::EXEC,
                                        trace::worker_track(w),
                                        g_arg,
                                        i_arg,
                                        pending_ref[k] as i32,
                                    );
                                    let out = env_ref.run_shard(pending_ref[k], &mut ws, w);
                                    *cells_ref[k].lock().unwrap() = Some(out);
                                }
                            });
                        }
                        // The overlap: interval i+1's iThread preparation
                        // runs here, concurrent with interval i's sThread
                        // drain above.
                        prep_s = timed_prepare(
                            cx.group_idx,
                            cx.group,
                            &mut standby,
                            env.dram,
                            env.weights,
                            scratch,
                            env.mode,
                        );
                    });
                    cells
                        .into_iter()
                        .map(|c| c.into_inner().unwrap().expect("worker filled its slot"))
                        .collect()
                }
            };
            for (&si, out) in pending.iter().zip(outs) {
                self.merge_shard(&mut iv, si, out);
            }
            pending.clear();
            self.iv = Some(iv);
        }
        self.pending = pending; // keep the capacity for the next interval
        if let Some((ni, st)) = standby {
            self.note_prepared(cx.group_idx, prep_s);
            self.standby = Some(Prepared {
                group: cx.group_idx,
                interval: ni,
                state: st,
            });
        }
    }

    /// Record one prepared interval in the per-group pipeline telemetry.
    fn note_prepared(&mut self, group: usize, secs: f64) {
        if self.prep_stats.len() <= group {
            self.prep_stats.resize(group + 1, (0, 0.0));
        }
        let (n, s) = &mut self.prep_stats[group];
        *n += 1;
        *s += secs;
    }

    /// Fold one shard's partial accumulators and spills into the interval
    /// state, then recycle the shard's buffers into the arena of the
    /// worker that produced them. Called in canonical shard order only.
    fn merge_shard(&mut self, iv: &mut IntervalState, shard_idx: usize, mut out: ShardOut) {
        let shard = &self.parts.shards[shard_idx];
        let mut ws = self.shard_scratch[out.worker].lock().unwrap();
        for &slot in &out.touched {
            let slot = slot as usize;
            let p = out.partials[slot]
                .take()
                .expect("touched slot carries a partial");
            let acc = iv.accs[slot]
                .as_mut()
                .expect("gather accumulator pre-created by scatter_phase");
            // The partial covers only the shard's dst window, and rows it
            // never touched (count 0) merge as identity — so the merge is
            // O(touched rows), not O(interval height).
            for (r, &cnt) in p.acc.counts.iter().enumerate() {
                if cnt == 0 {
                    continue;
                }
                let ar = p.base + r;
                match acc.reduce {
                    Reduce::Sum | Reduce::Mean => {
                        kernels::axpy(acc.m.row_mut(ar), p.acc.m.row(r))
                    }
                    Reduce::Max => kernels::max_assign(acc.m.row_mut(ar), p.acc.m.row(r)),
                }
                acc.counts[ar] += cnt;
            }
            ws.pm.give(slot, p.acc.m.data);
            ws.pc.give(slot, p.acc.counts);
        }
        for (dram_slot, e_slot, m) in out.spills.drain(..) {
            // ST.E rows land at canonical edge ids; shards own disjoint
            // edge sets, so the order is immaterial for the values.
            if self.dram[dram_slot].is_none() {
                self.dram[dram_slot] = Some(Matrix::zeros(self.parts.num_edges, m.cols));
            }
            let dst = self.dram[dram_slot].as_mut().unwrap();
            for (r, e) in shard.edges.iter().enumerate() {
                dst.row_mut(e.edge_id as usize).copy_from_slice(m.row(r));
            }
            ws.e.give(e_slot as usize, m.data);
        }
    }
}

impl PhaseVisitor for Executor<'_> {
    fn begin_interval(&mut self, cx: &StepCtx) {
        self.scatter_prepared = false;
        if let Some(p) = self.standby.take() {
            if p.group == cx.group_idx && p.interval == cx.interval_idx {
                // The pipeline ping-pong: the prepared state becomes the
                // live one; the outgoing interval's buffers flow back
                // into the pools and its container becomes the spare for
                // the next preparation.
                if let Some(mut old) = self.iv.take() {
                    old.recycle(&mut self.iv_scratch);
                    self.spare = Some(old);
                }
                self.iv = Some(p.state);
                self.scatter_prepared = true;
                self.pending.clear();
                return;
            }
            // Stale standby (unreachable under the walk contract —
            // defensive): recycle its buffers and container.
            let mut st = p.state;
            st.recycle(&mut self.iv_scratch);
            self.spare = Some(st);
        }
        let mut st = self
            .iv
            .take()
            .unwrap_or_else(|| IntervalState::empty(&self.layout));
        st.reset(cx.interval, &mut self.iv_scratch);
        self.iv = Some(st);
        self.pending.clear();
    }

    fn scatter_phase(&mut self, cx: &StepCtx) {
        if std::mem::take(&mut self.scatter_prepared) {
            // Already ran at prepare time, under the previous interval's
            // gather drain — LDs, computes and the pre-created gather
            // accumulators are in place, verbatim.
            return;
        }
        let mut iv = self.iv.take().expect("interval state");
        for i in &cx.group.scatter {
            self.exec_interval_instr(i, &mut iv);
        }
        // Gather accumulators exist per interval even when the interval
        // has no shards (isolated destination ranges).
        ensure_accs(cx.group, &mut iv, &mut self.iv_scratch);
        self.iv = Some(iv);
    }

    fn gather_shard(&mut self, _cx: &StepCtx, shard_idx: usize, _shard: &Shard) {
        // Schedule point only — the pool drains at `end_gather` so shards
        // overlap while the merge order stays canonical.
        self.pending.push(shard_idx);
    }

    fn lookahead_interval(&mut self, cx: &StepCtx, next: &StepCtx) {
        // Record the walker's lookahead; the coming `end_gather` drain
        // consumes it and prepares that interval's DstBuffer state under
        // the worker pool. Gated on the group's prefetch safety so the
        // ST-bearing prologue (and any intra-group DRAM dependence) keeps
        // the strictly sequential order.
        if self.pipeline == PipelineMode::Interval && self.prefetchable[cx.group_idx] {
            self.lookahead = Some((next.group_idx, next.interval_idx));
        }
    }

    fn end_gather(&mut self, cx: &StepCtx) {
        self.run_pending_shards(cx);
    }

    fn apply_phase(&mut self, cx: &StepCtx) {
        let mut iv = self.iv.take().expect("interval state");
        // Mean finalisation + empty-row convention.
        iv.finalize_gathers(&mut self.iv_scratch);
        for i in &cx.group.apply {
            self.exec_interval_instr(i, &mut iv);
        }
        self.iv = Some(iv);
    }

    // `end_interval` intentionally stays a no-op: the interval state is
    // retained and recycled by the next `begin_interval`'s reset, so the
    // matrices it holds flow back into the scratch pools instead of the
    // allocator.
}

/// Per-interval state: resident D slots + gather accumulators. One
/// instance lives for the whole executor; `reset` re-arms it per interval
/// and drains retired buffers into the scratch pools.
struct IntervalState {
    begin: usize,
    end: usize,
    /// DstBuffer arena, indexed by D-symbol id.
    d: Vec<Option<Matrix>>,
    /// Gather accumulators, indexed by D-symbol id; moved into `d` by
    /// `finalize_gathers` once every shard's partials merged.
    accs: Vec<Option<Acc>>,
}

impl IntervalState {
    fn empty(layout: &SlotLayout) -> Self {
        IntervalState {
            begin: 0,
            end: 0,
            d: (0..layout.d).map(|_| None).collect(),
            accs: (0..layout.d).map(|_| None).collect(),
        }
    }

    /// Drain every buffer this state holds back into the scratch pools
    /// (the state stays usable as an empty container).
    fn recycle(&mut self, scratch: &mut IntervalScratch) {
        for (slot, m) in self.d.iter_mut().enumerate() {
            if let Some(m) = m.take() {
                scratch.m.give(slot, m.data);
            }
        }
        for (slot, a) in self.accs.iter_mut().enumerate() {
            if let Some(a) = a.take() {
                scratch.m.give(slot, a.m.data);
                scratch.counts.give(slot, a.counts);
            }
        }
    }

    /// Point the state at a new interval, recycling every buffer the
    /// previous interval left behind.
    fn reset(&mut self, iv: &Interval, scratch: &mut IntervalScratch) {
        self.recycle(scratch);
        self.begin = iv.begin as usize;
        self.end = iv.end as usize;
    }

    fn len(&self) -> usize {
        self.end - self.begin
    }

    /// Pre-create a gather accumulator (first touch in this interval
    /// zeroes it — mirrors the hardware's phase-scheduler reset).
    fn ensure_acc(&mut self, dst: Sym, reduce: Reduce, cols: usize, scratch: &mut IntervalScratch) {
        let slot = dst.id as usize;
        if self.accs[slot].is_none() {
            let rows = self.len();
            self.accs[slot] = Some(Acc {
                reduce,
                m: scratch.m.take_matrix_filled(slot, rows, cols, reduce_identity(reduce)),
                counts: scratch.counts.take_filled(slot, rows, 0),
            });
        }
    }

    /// Post-merge fixups: Mean division and the zero-for-empty convention.
    fn finalize_gathers(&mut self, scratch: &mut IntervalScratch) {
        for (slot, (acc_slot, d_slot)) in
            self.accs.iter_mut().zip(self.d.iter_mut()).enumerate()
        {
            if let Some(mut acc) = acc_slot.take() {
                for (r, &cnt) in acc.counts.iter().enumerate() {
                    if cnt == 0 {
                        acc.m.row_mut(r).fill(0.0);
                    } else if acc.reduce == Reduce::Mean {
                        let inv = 1.0 / cnt as f32;
                        for v in acc.m.row_mut(r) {
                            *v *= inv;
                        }
                    }
                }
                scratch.counts.give(slot, acc.counts);
                if let Some(old) = d_slot.replace(acc.m) {
                    scratch.m.give(slot, old.data);
                }
            }
        }
    }
}

/// The reduce-specific accumulator identity element.
fn reduce_identity(reduce: Reduce) -> f32 {
    match reduce {
        Reduce::Sum | Reduce::Mean => 0.0,
        Reduce::Max => f32::NEG_INFINITY,
    }
}

/// A gather accumulator (interval-level or per-shard partial).
struct Acc {
    reduce: Reduce,
    m: Matrix,
    counts: Vec<u32>,
}

/// A shard's partial gather accumulator: an [`Acc`] covering only the
/// shard's destination window, placed at interval-local row `base`.
struct Partial {
    base: usize,
    acc: Acc,
}

/// What one shard's GatherPhase produced: partial gather accumulators
/// (merged in shard order) and queued ST.E spills. Matrix buffers inside
/// come from — and return to — the producing worker's scratch arena; the
/// three container `Vec`s are the only per-shard heap traffic left.
struct ShardOut {
    /// Worker index that ran the shard (owner of the buffers inside).
    worker: usize,
    /// Partials indexed by D slot (`SlotLayout::d` wide) — no linear
    /// `position()` scan per gather instruction.
    partials: Vec<Option<Partial>>,
    /// D slots present in `partials`, in first-touch order (the
    /// deterministic merge order).
    touched: Vec<u32>,
    /// `(DRAM slot, E slot, [shard_edges, cols] rows)` to write at
    /// canonical edge ids; the E slot routes the buffer back to the
    /// worker's pool after the merge.
    spills: Vec<(usize, u32, Matrix)>,
}

impl ShardOut {
    fn new(worker: usize, d_slots: usize) -> Self {
        ShardOut {
            worker,
            partials: (0..d_slots).map(|_| None).collect(),
            touched: Vec::new(),
            spills: Vec::new(),
        }
    }

    /// Get-or-create the shard's partial accumulator for `slot`.
    #[allow(clippy::too_many_arguments)]
    fn partial(
        &mut self,
        slot: usize,
        reduce: Reduce,
        base: usize,
        rows: usize,
        cols: usize,
        pm: &mut Pool<f32>,
        pc: &mut Pool<u32>,
    ) -> &mut Acc {
        if self.partials[slot].is_none() {
            self.touched.push(slot as u32);
            self.partials[slot] = Some(Partial {
                base,
                acc: Acc {
                    reduce,
                    m: pm.take_matrix_filled(slot, rows, cols, reduce_identity(reduce)),
                    counts: pc.take_filled(slot, rows, 0),
                },
            });
        }
        &mut self.partials[slot].as_mut().unwrap().acc
    }
}

/// Read-only view shared by the GatherPhase workers.
struct ShardEnv<'x> {
    layout: &'x SlotLayout,
    weights: &'x [Option<Matrix>],
    dram: &'x [Option<Matrix>],
    iv: &'x IntervalState,
    parts: &'x Partitions,
    gather: &'x [Instr],
    /// Per gather-instruction last-use flags for ST.E spills.
    movable: &'x [bool],
    mode: KernelMode,
}

impl ShardEnv<'_> {
    fn run_shard(&self, shard_idx: usize, ws: &mut WorkerScratch, worker: usize) -> ShardOut {
        let shard = &self.parts.shards[shard_idx];
        let span = shard.dst_span();
        let mut out = ShardOut::new(worker, self.layout.d);
        for (idx, i) in self.gather.iter().enumerate() {
            self.exec_shard_instr(i, self.movable[idx], shard, span, ws, &mut out);
        }
        // Retire the shard's S/E matrices into the worker's pools.
        for (slot, m) in ws.s_arena.iter_mut().enumerate() {
            if let Some(m) = m.take() {
                ws.s.give(slot, m.data);
            }
        }
        for (slot, m) in ws.e_arena.iter_mut().enumerate() {
            if let Some(m) = m.take() {
                ws.e.give(slot, m.data);
            }
        }
        out
    }

    /// Get-or-create the shard's partial accumulator for `dst`, sized to
    /// the shard's destination window within the interval.
    #[allow(clippy::too_many_arguments)]
    fn windowed_partial<'o>(
        &self,
        out: &'o mut ShardOut,
        dst: Sym,
        reduce: Reduce,
        span: Option<(u32, u32)>,
        cols: usize,
        pm: &mut Pool<f32>,
        pc: &mut Pool<u32>,
    ) -> &'o mut Acc {
        let (lo, hi) = span.expect("edgeless shards return before accumulating");
        let base = lo as usize - self.iv.begin;
        let rows = (hi - lo + 1) as usize;
        out.partial(dst.id as usize, reduce, base, rows, cols, pm, pc)
    }

    fn exec_shard_instr(
        &self,
        i: &Instr,
        movable: bool,
        shard: &Shard,
        span: Option<(u32, u32)>,
        ws: &mut WorkerScratch,
        out: &mut ShardOut,
    ) {
        let iv = self.iv;
        match i {
            Instr::Ld { sym, data, cols, .. } => {
                let src = self.dram[data.slot()]
                    .as_ref()
                    .unwrap_or_else(|| panic!("LD of unwritten {data}"));
                let slot = sym.id as usize;
                match sym.space {
                    Space::S => {
                        let mut m =
                            ws.s.take_matrix_any(slot, shard.num_src(), *cols as usize);
                        for (r, &gv) in shard.sources.iter().enumerate() {
                            m.row_mut(r).copy_from_slice(src.row(gv as usize));
                        }
                        if let Some(old) = ws.s_arena[slot].replace(m) {
                            ws.s.give(slot, old.data);
                        }
                    }
                    Space::E => {
                        let mut m =
                            ws.e.take_matrix_any(slot, shard.num_edges(), *cols as usize);
                        for (r, ed) in shard.edges.iter().enumerate() {
                            m.row_mut(r).copy_from_slice(src.row(ed.edge_id as usize));
                        }
                        if let Some(old) = ws.e_arena[slot].replace(m) {
                            ws.e.give(slot, old.data);
                        }
                    }
                    _ => panic!("GatherPhase LD of {sym}"),
                }
            }
            Instr::St { sym, data, .. } => {
                // ST.E — spill edge rows; the writes are queued and land
                // at canonical edge ids during the deterministic merge.
                // When this is the symbol's last use in the phase the
                // matrix moves out of the arena (no copy); otherwise it is
                // duplicated into a pool buffer.
                let slot = sym.id as usize;
                let m = if movable {
                    ws.e_arena[slot]
                        .take()
                        .unwrap_or_else(|| panic!("ST of undefined {sym}"))
                } else {
                    let src = ws.e_arena[slot]
                        .as_ref()
                        .unwrap_or_else(|| panic!("ST of undefined {sym}"));
                    let mut c = ws.e.take_matrix_any(slot, src.rows, src.cols);
                    c.data.copy_from_slice(&src.data);
                    c
                };
                out.spills.push((data.slot(), slot as u32, m));
            }
            Instr::Scatter { dir, dst, src, cols } => {
                let slot = dst.id as usize;
                let mut m = ws.e.take_matrix_any(slot, shard.num_edges(), *cols as usize);
                match dir {
                    ScatterDir::SrcToEdge => {
                        let sm = ws.s_arena[src.id as usize]
                            .as_ref()
                            .unwrap_or_else(|| panic!("S operand {src} missing"));
                        for (r, ed) in shard.edges.iter().enumerate() {
                            m.row_mut(r).copy_from_slice(sm.row(ed.src_slot as usize));
                        }
                    }
                    ScatterDir::DstToEdge => {
                        let dm = iv.d[src.id as usize]
                            .as_ref()
                            .unwrap_or_else(|| panic!("D operand {src} missing"));
                        for (r, ed) in shard.edges.iter().enumerate() {
                            let local = (ed.dst - iv.begin as u32) as usize;
                            m.row_mut(r).copy_from_slice(dm.row(local));
                        }
                    }
                }
                if let Some(old) = ws.e_arena[slot].replace(m) {
                    ws.e.give(slot, old.data);
                }
            }
            Instr::FusedGather {
                reduce,
                dst,
                src,
                scale,
                cols,
            } => {
                // An edgeless shard contributes nothing (the interval-level
                // accumulator was pre-created by `scatter_phase`).
                let Some((lo, _)) = span else { return };
                let sm = ws.s_arena[src.id as usize]
                    .as_ref()
                    .unwrap_or_else(|| panic!("S operand {src} missing"));
                let scale_m = scale.map(|sc| {
                    ws.e_arena[sc.id as usize]
                        .as_ref()
                        .unwrap_or_else(|| panic!("E operand {sc} missing"))
                });
                let acc = self.windowed_partial(
                    out,
                    *dst,
                    *reduce,
                    span,
                    *cols as usize,
                    &mut ws.pm,
                    &mut ws.pc,
                );
                for (r, ed) in shard.edges.iter().enumerate() {
                    let local = (ed.dst - lo) as usize;
                    acc.counts[local] += 1;
                    let row = sm.row(ed.src_slot as usize);
                    let f = scale_m.map_or(1.0, |m| m.get(r, 0));
                    match reduce {
                        Reduce::Sum | Reduce::Mean => {
                            kernels::scale_axpy(acc.m.row_mut(local), row, f)
                        }
                        Reduce::Max => {
                            kernels::scale_max_assign(acc.m.row_mut(local), row, f)
                        }
                    }
                }
            }
            Instr::Gather {
                reduce,
                dst,
                src,
                cols,
            } => {
                let Some((lo, _)) = span else { return };
                let ev = ws.e_arena[src.id as usize]
                    .as_ref()
                    .unwrap_or_else(|| panic!("E operand {src} missing"));
                let acc = self.windowed_partial(
                    out,
                    *dst,
                    *reduce,
                    span,
                    *cols as usize,
                    &mut ws.pm,
                    &mut ws.pc,
                );
                for (r, ed) in shard.edges.iter().enumerate() {
                    let local = (ed.dst - lo) as usize;
                    acc.counts[local] += 1;
                    let row = ev.row(r);
                    match reduce {
                        Reduce::Sum | Reduce::Mean => {
                            kernels::axpy(acc.m.row_mut(local), row)
                        }
                        Reduce::Max => kernels::max_assign(acc.m.row_mut(local), row),
                    }
                }
            }
            _ => {
                // Shard-side compute: rows decode against the shard.
                let rows_dim = instr_rows(i);
                let rows = rows_dim.decode(iv.len(), shard.num_src(), shard.num_edges());
                let def = i.def().expect("compute defines");
                let slot = def.id as usize;
                let m = match self.mode {
                    KernelMode::Blocked => {
                        // The def's pool is a field disjoint from the
                        // operand arenas, so this borrow-splits cleanly.
                        let pool = match def.space {
                            Space::S => &mut ws.s,
                            Space::E => &mut ws.e,
                            _ => panic!("GatherPhase compute must write S/E"),
                        };
                        compute_instr_kernel(
                            i,
                            rows,
                            self.weights,
                            Some(&ws.s_arena[..]),
                            Some(&ws.e_arena[..]),
                            &iv.d,
                            pool,
                            slot,
                        )
                    }
                    KernelMode::Naive => compute_instr_naive(
                        i,
                        rows,
                        self.weights,
                        Some(&ws.s_arena[..]),
                        Some(&ws.e_arena[..]),
                        &iv.d,
                    ),
                };
                let (arena, pool) = match def.space {
                    Space::S => (&mut ws.s_arena, &mut ws.s),
                    Space::E => (&mut ws.e_arena, &mut ws.e),
                    _ => panic!("GatherPhase compute must write S/E"),
                };
                if let Some(old) = arena[slot].replace(m) {
                    pool.give(slot, old.data);
                }
            }
        }
    }
}

/// Execute one ScatterPhase/ApplyPhase instruction that only *reads*
/// DRAM — `LD` or compute. `ST`, the one DRAM-writing interval
/// instruction, is handled by the sequential caller
/// (`Executor::exec_interval_instr`); the pipelined prepare path never
/// sees one because ST-bearing ScatterPhases are not prefetch-safe.
fn exec_interval_read_instr(
    i: &Instr,
    iv: &mut IntervalState,
    dram: &[Option<Matrix>],
    weights: &[Option<Matrix>],
    scratch: &mut IntervalScratch,
    mode: KernelMode,
) {
    let v = iv.len();
    match i {
        Instr::Ld { sym, data, cols, .. } => {
            let src = dram[data.slot()]
                .as_ref()
                .unwrap_or_else(|| panic!("LD of unwritten {data}"));
            let slot = sym.id as usize;
            let mut m = scratch.m.take_matrix_any(slot, v, *cols as usize);
            for (r, gv) in (iv.begin..iv.end).enumerate() {
                m.row_mut(r).copy_from_slice(src.row(gv));
            }
            if let Some(old) = iv.d[slot].replace(m) {
                scratch.m.give(slot, old.data);
            }
        }
        Instr::St { .. } => unreachable!("ST is the sequential caller's case"),
        _ => {
            let def = i.def().expect("compute defines");
            let slot = def.id as usize;
            let out = match mode {
                KernelMode::Blocked => {
                    compute_instr_kernel(i, v, weights, None, None, &iv.d, &mut scratch.m, slot)
                }
                KernelMode::Naive => compute_instr_naive(i, v, weights, None, None, &iv.d),
            };
            if let Some(old) = iv.d[slot].replace(out) {
                scratch.m.give(slot, old.data);
            }
        }
    }
}

/// Pre-create the interval's gather accumulators (first touch zeroes them
/// — mirrors the hardware's phase-scheduler reset). Shared by the
/// sequential `scatter_phase` and the pipelined prepare.
fn ensure_accs(group: &PhaseGroup, iv: &mut IntervalState, scratch: &mut IntervalScratch) {
    for i in &group.gather {
        match i {
            Instr::Gather { reduce, dst, cols, .. }
            | Instr::FusedGather { reduce, dst, cols, .. } => {
                iv.ensure_acc(*dst, *reduce, *cols as usize, scratch);
            }
            _ => {}
        }
    }
}

/// The single timed entry point all three `run_pending_shards` arms
/// (empty-pending, serial, threaded) share: run [`prepare_interval`] for
/// the standby, if one is planned, and return the seconds spent.
///
/// Always called on the walk's driving thread (the threaded arm calls it
/// from inside the scope, not from a spawned worker), so the `prepare`
/// trace span gates on this thread's session flag and lands on the main
/// track — in a trace it shows up *under* the enclosing `gather_drain`
/// span, which is exactly the pipelining overlap being claimed.
fn timed_prepare(
    group_idx: usize,
    group: &PhaseGroup,
    standby: &mut Option<(usize, IntervalState)>,
    dram: &[Option<Matrix>],
    weights: &[Option<Matrix>],
    scratch: &mut IntervalScratch,
    mode: KernelMode,
) -> f64 {
    let Some((ni, st)) = standby.as_mut() else {
        return 0.0;
    };
    let _span = trace::span_args(
        trace::names::PREPARE,
        trace::cat::EXEC,
        trace::TRACK_MAIN,
        group_idx as i32,
        *ni as i32,
        -1,
    );
    let t0 = Instant::now();
    prepare_interval(group, st, dram, weights, scratch, mode);
    t0.elapsed().as_secs_f64()
}

/// Build a (rebound) standby `IntervalState` for the *next* interval of a
/// prefetch-safe group: run its ScatterPhase LDs/computes and pre-create
/// its gather accumulators. Runs on the main thread, overlapped with the
/// current interval's worker-pool drain — every input it reads (DRAM
/// arrays, weights) is provably unchanged until the interval's own
/// `scatter_phase` slot in the sequential order, so the prepared state is
/// bit-identical to what `PipelineMode::Off` would build there.
fn prepare_interval(
    group: &PhaseGroup,
    st: &mut IntervalState,
    dram: &[Option<Matrix>],
    weights: &[Option<Matrix>],
    scratch: &mut IntervalScratch,
    mode: KernelMode,
) {
    for i in &group.scatter {
        exec_interval_read_instr(i, st, dram, weights, scratch, mode);
    }
    ensure_accs(group, st, scratch);
}

/// Resolve a compute operand against the slot arenas: W from `weights`,
/// S/E from the shard arenas (GatherPhase only), D from the interval
/// arena.
fn look_operand<'m>(
    sym: &Sym,
    weights: &'m [Option<Matrix>],
    s: Option<&'m [Option<Matrix>]>,
    e: Option<&'m [Option<Matrix>]>,
    d: &'m [Option<Matrix>],
) -> &'m Matrix {
    let arena: &[Option<Matrix>] = match sym.space {
        Space::W => weights,
        Space::S => s.unwrap_or_else(|| panic!("S operand {sym} outside GatherPhase")),
        Space::E => e.unwrap_or_else(|| panic!("E operand {sym} outside GatherPhase")),
        Space::D => d,
    };
    arena[sym.id as usize]
        .as_ref()
        .unwrap_or_else(|| panic!("operand {sym} missing"))
}

/// Evaluate a compute instruction through the kernel layer, writing into
/// a scratch buffer taken from `pool` at `slot` (blocked branch-free DMM,
/// flat-slice ELW/RSCALE/CAT — no per-element `get`/`set`). Results are
/// bit-identical to [`compute_instr_naive`] for finite inputs.
#[allow(clippy::too_many_arguments)]
fn compute_instr_kernel(
    i: &Instr,
    rows: usize,
    weights: &[Option<Matrix>],
    s: Option<&[Option<Matrix>]>,
    e: Option<&[Option<Matrix>]>,
    d: &[Option<Matrix>],
    pool: &mut Pool<f32>,
    slot: usize,
) -> Matrix {
    match i {
        Instr::Elw {
            op,
            a,
            b,
            broadcast_b,
            cols,
            ..
        } => {
            let cols = *cols as usize;
            let am = look_operand(a, weights, s, e, d);
            let mut out = pool.take_matrix_any(slot, rows, cols);
            match b {
                None => kernels::elw_unary(*op, &am.data[..rows * cols], &mut out.data),
                Some(bs) => {
                    let bm = look_operand(bs, weights, s, e, d);
                    if *broadcast_b {
                        for r in 0..rows {
                            kernels::elw_binary(*op, am.row(r), bm.row(0), out.row_mut(r));
                        }
                    } else {
                        kernels::elw_binary(
                            *op,
                            &am.data[..rows * cols],
                            &bm.data[..rows * cols],
                            &mut out.data,
                        );
                    }
                }
            }
            out
        }
        Instr::RowScale { a, scale, cols, .. } => {
            let cols = *cols as usize;
            let am = look_operand(a, weights, s, e, d);
            let sm = look_operand(scale, weights, s, e, d);
            let mut out = pool.take_matrix_any(slot, rows, cols);
            for r in 0..rows {
                kernels::row_scale(&am.row(r)[..cols], sm.get(r, 0), out.row_mut(r));
            }
            out
        }
        Instr::Concat {
            a, b, cols_a, cols_b, ..
        } => {
            let (ca, cb) = (*cols_a as usize, *cols_b as usize);
            let am = look_operand(a, weights, s, e, d);
            let bm = look_operand(b, weights, s, e, d);
            let mut out = pool.take_matrix_any(slot, rows, ca + cb);
            for r in 0..rows {
                out.row_mut(r)[..ca].copy_from_slice(am.row(r));
                out.row_mut(r)[ca..].copy_from_slice(bm.row(r));
            }
            out
        }
        Instr::Dmm { a, w, .. } => {
            let am = look_operand(a, weights, s, e, d);
            let wm = look_operand(w, weights, s, e, d);
            let mut out = pool.take_matrix_any(slot, am.rows, wm.cols);
            kernels::matmul_blocked(am, wm, &mut out);
            out
        }
        _ => panic!("not a compute instruction: {}", i.render()),
    }
}

/// The pre-kernel-layer compute path, preserved verbatim: naive
/// zero-skipping matmul, per-element `get`/`set` loops, and a fresh
/// allocation per result. This is the golden reference the differential
/// tests diff [`KernelMode::Blocked`] against — do not "optimise" it.
fn compute_instr_naive(
    i: &Instr,
    rows: usize,
    weights: &[Option<Matrix>],
    s: Option<&[Option<Matrix>]>,
    e: Option<&[Option<Matrix>]>,
    d: &[Option<Matrix>],
) -> Matrix {
    match i {
        Instr::Elw {
            op,
            a,
            b,
            broadcast_b,
            cols,
            ..
        } => {
            let am = look_operand(a, weights, s, e, d);
            let mut out = Matrix::zeros(rows, *cols as usize);
            match b {
                None => {
                    for r in 0..rows {
                        for c in 0..*cols as usize {
                            out.set(r, c, apply_unary(*op, am.get(r, c)));
                        }
                    }
                }
                Some(bs) => {
                    let bm = look_operand(bs, weights, s, e, d);
                    for r in 0..rows {
                        let br = if *broadcast_b { 0 } else { r };
                        for c in 0..*cols as usize {
                            out.set(r, c, apply_binary(*op, am.get(r, c), bm.get(br, c)));
                        }
                    }
                }
            }
            out
        }
        Instr::RowScale { a, scale, cols, .. } => {
            let am = look_operand(a, weights, s, e, d);
            let sm = look_operand(scale, weights, s, e, d);
            let mut out = Matrix::zeros(rows, *cols as usize);
            for r in 0..rows {
                let f = sm.get(r, 0);
                for c in 0..*cols as usize {
                    out.set(r, c, am.get(r, c) * f);
                }
            }
            out
        }
        Instr::Concat {
            a, b, cols_a, cols_b, ..
        } => {
            let am = look_operand(a, weights, s, e, d);
            let bm = look_operand(b, weights, s, e, d);
            let mut out = Matrix::zeros(rows, (*cols_a + *cols_b) as usize);
            for r in 0..rows {
                out.row_mut(r)[..*cols_a as usize].copy_from_slice(am.row(r));
                out.row_mut(r)[*cols_a as usize..].copy_from_slice(bm.row(r));
            }
            out
        }
        Instr::Dmm { a, w, .. } => {
            let am = look_operand(a, weights, s, e, d);
            let wm = look_operand(w, weights, s, e, d);
            kernels::matmul_naive(am, wm)
        }
        _ => panic!("not a compute instruction: {}", i.render()),
    }
}

fn instr_rows(i: &Instr) -> Dim {
    match i {
        Instr::Elw { rows, .. }
        | Instr::RowScale { rows, .. }
        | Instr::Concat { rows, .. }
        | Instr::Dmm { rows, .. } => *rows,
        Instr::Scatter { .. } | Instr::Gather { .. } | Instr::FusedGather { .. } => Dim::E,
        Instr::Ld { rows, .. } | Instr::St { rows, .. } => *rows,
    }
}
