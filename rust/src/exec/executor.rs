//! Functional executor: interprets a compiled PLOF program over a
//! partitioned graph. The execution order is not defined here — the
//! executor is a [`PhaseVisitor`] over [`sched::PartitionWalk`], the
//! same canonical Alg 2 traversal the cycle simulator drives through.
//!
//! Performance properties mirroring the hardware:
//!
//! * **Partition-level multi-threading in software**: shards within an
//!   interval are independent (paper §IV-C), so their GatherPhases run
//!   across a *persistent* worker pool ([`exec::pool`](super::pool);
//!   default width = the partitioning's simulated sThread count).
//!   Workers are spawned once per executor and own their scratch
//!   outright; interval batches reach them over an epoch protocol with
//!   a static strided shard→worker affinity, so a shard position
//!   revisits the same worker's warm pools on every interval and every
//!   rerun. Each shard produces *partial* gather accumulators that are
//!   merged in canonical shard order after the batch drains, so the
//!   output is bit-identical for every worker count — including the
//!   threadless single-worker mode the differential tests pin.
//! * **Dense slot arenas**: symbols and DRAM arrays are addressed by
//!   `Vec` index (`Program::slot_layout`), not by hashing `Sym`/`DataRef`
//!   per instruction.
//! * **Kernel-layer inner loops** ([`exec::kernels`](crate::exec::kernels)):
//!   cache-blocked branch-free DMM and fused slice-based row kernels
//!   drive every compute instruction, the gather inner loops, and the
//!   shard merge. [`KernelMode::Simd`] swaps in the explicit
//!   chunks-of-8 variants (bit-identical by construction); the
//!   pre-kernel per-element loops are preserved as [`KernelMode::Naive`]
//!   purely as the bit-identity reference the differential tests diff
//!   against.
//! * **Scratch arenas** ([`exec::scratch`](crate::exec::scratch)):
//!   interval matrices, gather accumulators, and per-worker shard
//!   matrices are recycled through slot-keyed buffer pools, so the walk
//!   performs no per-shard / per-interval `Matrix` allocation once the
//!   first interval of a group has sized the pools. The guarantee is
//!   exact at *any* worker count: assignment is deterministic, and
//!   buffers the canonical-order merge finishes with travel back to the
//!   worker that lent them through per-worker mailboxes.
//! * **Interval pipelining** ([`PipelineMode::Interval`], the default):
//!   while interval *i*'s shards drain through the pool, the driving
//!   (iThread) thread prepares interval *i+1*'s DstBuffer state —
//!   ScatterPhase LDs and computes plus the pre-created gather
//!   accumulators — into a second `IntervalState` ping-ponged through
//!   the scratch pools (pipeline depth 2). The walk order, merge order,
//!   and output bits are untouched: only *when* next-interval state is
//!   materialised changes, and only for groups where that is provably
//!   safe (no ScatterPhase STs, no ScatterPhase LD of a DataRef the
//!   same group stores — the prologue group stays strictly sequential).
//! * **Cross-request batching** ([`Executor::try_run_with`] with 2+
//!   inputs): B feature matrices are column-stacked into one `[N, B·F]`
//!   DRAM image, so a micro-batch shares *one* partition walk — the
//!   per-interval scatter LDs, gather accumulator setup, and shard
//!   traversal (the paper's bandwidth-dominant gather/scatter stream)
//!   run once across the batch instead of once per request. Weights are
//!   never stacked; the few instructions that mix a stacked operand with
//!   an unstacked one (DMM against a weight, ELW/CAT/RSCALE with a W
//!   operand, FusedGather with a per-edge scale) compute each request's
//!   column lane separately in the exact iteration order of a sequential
//!   run, so every batched output is bit-identical to running its
//!   request alone.
//! * **Group pipelining** ([`PipelineMode::Group`]): because the pool
//!   outlives intervals, the prepare no longer has to finish inside the
//!   gather drain — a persistent *prepare lane* thread carries the
//!   prologue computes and accumulator pre-creation across the current
//!   interval's ApplyPhase and, when the cross-group dependence gate
//!   allows, into the next group's prologue window. The DRAM-reading LD
//!   prefix still runs on the driving thread at the dispatch point
//!   (inside the safety window the prefetch gates establish), so the
//!   lane touches only its own state + the immutable weights. The
//!   rendezvous is the target's `begin_interval`. Bit-identical to
//!   [`PipelineMode::Off`], which preserves the strictly sequential
//!   order as the golden reference of the pipelining differential
//!   tests.

use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::exec::kernels;
use crate::exec::pool::{panic_message, PoolError, PoolStats, RetBuf, WorkerPool};
use crate::exec::reference::{apply_binary, apply_unary};
use crate::exec::scratch::{IntervalScratch, Pool, ScratchStats, WorkerScratch};
use crate::exec::{weights, Matrix};
use crate::isa::{
    DataRef, Dim, Instr, PhaseGroup, Program, Reduce, ScatterDir, SlotLayout, Space, Sym,
};
use crate::obs::{faultinject, metrics, trace};
use crate::partition::{Interval, Partitions, Shard};
use crate::sched::{PartitionWalk, PhaseProfile, PhaseVisitor, StepCtx, Traced, WalkStep};

/// Which compute-instruction implementation the executor runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelMode {
    /// The kernel layer: blocked branch-free DMM + slice-based ELW /
    /// RSCALE / CAT writing into scratch-arena buffers. The default.
    #[default]
    Blocked,
    /// The explicit-width tier: chunks-of-8 `[f32; 8]`-accumulator
    /// kernels for DMM and the gather/merge row ops (safe portable
    /// code, no intrinsics). Bit-identical to [`KernelMode::Blocked`]
    /// — same per-element FP order — so it shares the same golden
    /// reference.
    Simd,
    /// The preserved pre-kernel reference: naive zero-skipping matmul and
    /// per-element `get`/`set` loops, allocating fresh matrices. Kept
    /// only so tests can prove the kernel paths bit-identical.
    Naive,
}

impl KernelMode {
    /// CLI rendering (`bench --kernel naive|blocked|simd`).
    pub fn label(&self) -> &'static str {
        match self {
            KernelMode::Blocked => "blocked",
            KernelMode::Simd => "simd",
            KernelMode::Naive => "naive",
        }
    }
}

/// Whether (and how far) the executor overlaps consecutive intervals.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PipelineMode {
    /// Double-buffered interval pipelining: while one interval's shards
    /// drain through the worker pool, the next interval's DstBuffer state
    /// is prepared from a second buffer set (walk lookahead, see the
    /// module docs). Bit-identical to [`PipelineMode::Off`]. The default.
    #[default]
    Interval,
    /// Interval pipelining plus the persistent prepare lane: the next
    /// interval's prologue computes overlap the current ApplyPhase, and
    /// dependence-free group boundaries prefetch the next group's first
    /// interval (see the module docs). Bit-identical to
    /// [`PipelineMode::Off`].
    Group,
    /// Strictly sequential intervals — the golden reference the
    /// pipelining differential tests diff against.
    Off,
}

impl PipelineMode {
    /// CLI rendering (`bench --pipeline on|group|off`, trailer lines).
    pub fn label(&self) -> &'static str {
        match self {
            PipelineMode::Interval => "on",
            PipelineMode::Group => "group",
            PipelineMode::Off => "off",
        }
    }
}

/// One executor run, described declaratively: 1..=B feature matrices
/// (2+ inputs make the run *batched* — one partition walk serves every
/// request, see the module docs) plus the trace/profile toggles that
/// used to be separate `run_*` entry points.
///
/// This is the canonical run surface; `Executor::{run, try_run,
/// run_traced, run_profiled}` are thin wrappers over it.
pub struct RunRequest<'r> {
    /// The per-request `[N, in_dim]` feature matrices, one per batch
    /// member. Order is preserved into [`RunOutput::outputs`].
    pub inputs: Vec<&'r Matrix>,
    /// The `[N, 1]` in-degree column (`DataRef::Degree`), shared by
    /// every batch member.
    pub degree: &'r Matrix,
    /// Record the walker's `(group, interval, shard, phase)` steps into
    /// [`RunOutput::steps`]. The step count is independent of the batch
    /// size — the witness that a batch performs exactly one walk.
    pub trace: bool,
    /// Time every walk phase into [`RunOutput::profile`].
    pub profile: bool,
}

impl<'r> RunRequest<'r> {
    /// A single-request run — what the legacy `run`/`try_run` wrappers
    /// build.
    pub fn new(x: &'r Matrix, degree: &'r Matrix) -> Self {
        RunRequest {
            inputs: vec![x],
            degree,
            trace: false,
            profile: false,
        }
    }

    /// A batched run over `inputs` (must be non-empty; every matrix
    /// `[N, in_dim]`).
    pub fn batched(inputs: Vec<&'r Matrix>, degree: &'r Matrix) -> Self {
        RunRequest {
            inputs,
            degree,
            trace: false,
            profile: false,
        }
    }

    /// Toggle walk-step tracing (see [`RunRequest::trace`]).
    pub fn with_trace(mut self, on: bool) -> Self {
        self.trace = on;
        self
    }

    /// Toggle phase profiling (see [`RunRequest::profile`]).
    pub fn with_profile(mut self, on: bool) -> Self {
        self.profile = on;
        self
    }

    /// The batch size of this request.
    pub fn batch(&self) -> usize {
        self.inputs.len()
    }
}

/// What [`Executor::try_run_with`] produced: one output matrix per
/// request (same order as [`RunRequest::inputs`]) plus whatever optional
/// instrumentation the request toggled on.
pub struct RunOutput {
    /// Per-request `[N, out_dim]` results, bit-identical to running each
    /// request alone.
    pub outputs: Vec<Matrix>,
    /// The walk-step trace, when [`RunRequest::trace`] was set. Its
    /// length equals the canonical walk's — independent of batch size.
    pub steps: Option<Vec<WalkStep>>,
    /// The per-(group, phase) wall-time profile, when
    /// [`RunRequest::profile`] was set.
    pub profile: Option<PhaseProfile>,
    /// How many requests shared this run's single partition walk — the
    /// amortization factor of the gather/scatter stream.
    pub batch: usize,
    /// Intervals whose DstBuffer state was prepared ahead of order
    /// during this run (pipelining telemetry).
    pub prepared_intervals: u64,
}

impl RunOutput {
    /// Unwrap the single output of an unbatched run (what the legacy
    /// wrappers return). Panics when the run was batched.
    pub fn into_output(mut self) -> Matrix {
        assert_eq!(
            self.outputs.len(),
            1,
            "into_output on a batched run — use .outputs"
        );
        self.outputs.pop().expect("one output")
    }
}

/// A next-interval state built under the previous interval's gather drain,
/// waiting for its `begin_interval` to swap it in.
struct Prepared {
    group: usize,
    interval: usize,
    state: IntervalState,
}

/// The ScatterPhase instruction suffix the prepare lane runs (everything
/// after the LD prefix) plus the gather list it pre-creates accumulators
/// from — cloned out of the program once per group so the lane borrows
/// nothing from the executor.
struct PrepInstrs {
    computes: Vec<Instr>,
    gathers: Vec<Instr>,
}

/// Functional executor over one (program, partitions) pair.
pub struct Executor<'a> {
    program: &'a Program,
    parts: &'a Partitions,
    layout: SlotLayout,
    /// Off-chip storage arena indexed by [`DataRef::slot`]: vertex arrays
    /// are `[N, cols]`, edge arrays `[M, cols]`.
    dram: Vec<Option<Matrix>>,
    /// Weight arena indexed by W-symbol id. Shared with the prepare lane
    /// (weights are immutable after construction).
    weights: Arc<Vec<Option<Matrix>>>,
    /// GatherPhase worker-pool width (the software sThread count).
    workers: usize,
    mode: KernelMode,
    /// Batch size of the current run: how many requests are column-
    /// stacked into each D/S/E buffer (`cols · batch` wide). Set by
    /// `seed_inputs` from the run request; 1 outside batched runs.
    batch: usize,
    /// Live state of the interval currently being walked. Never dropped:
    /// `begin_interval` drains its matrices back into its scratch bank
    /// and re-arms it (or swaps in a prepared standby and keeps this one
    /// as the spare), so at most two interval states — pipeline depth 2 —
    /// are ever allocated per executor.
    iv: Option<IntervalState>,
    /// Shard indices queued by `gather_shard`, drained at `end_gather`.
    pending: Vec<usize>,
    /// iThread-side buffer-pool banks (D matrices + gather accumulators).
    /// Bank 0 always exists; bank 1 is created on the first Group-mode
    /// dispatch. An `IntervalState` records which bank its buffers came
    /// from, and a bank is `None` exactly while it is checked out to the
    /// prepare lane — the pairing is what keeps loan accounting exact
    /// when a prepared state and the live state coexist.
    banks: [Option<IntervalScratch>; 2],
    /// The persistent worker pool, created at the first drain and
    /// dropped (threads joined) with the executor. `None` until then —
    /// zero thread spawns per interval in steady state.
    pool: Option<WorkerPool>,
    /// Reusable batch-output buffer (canonical order).
    outs: Vec<ShardOut>,
    /// Per-worker return mailbox staging: the canonical-order merge
    /// pushes finished buffers here, one `deposit_returns` per drain
    /// hands them back to the owning workers.
    ret_bufs: Vec<Vec<RetBuf>>,
    /// Per `(group, gather-instr)` flag: true when an `ST.E` is the last
    /// use of its symbol in the phase, so the spill can move the matrix
    /// out of the arena instead of cloning it.
    movable_spills: Vec<Vec<bool>>,
    /// Interval-pipelining mode (see [`PipelineMode`]).
    pipeline: PipelineMode,
    /// Per-group prefetch safety, computed once at construction: a group
    /// may pipeline only when its ScatterPhase contains no `ST` and no
    /// `LD` of a DataRef the same group stores — otherwise preparing the
    /// next interval early would write DRAM ahead of order, or read rows
    /// the in-flight interval's merge/apply is still producing. (In
    /// practice this keeps the prologue sweep sequential; groups are DRAM
    /// barriers for everything else.)
    prefetchable: Vec<bool>,
    /// Per-group cross-boundary safety: group g's last interval may
    /// prefetch group g+1's first interval only when g+1 is itself
    /// prefetch-safe, its ScatterPhase stores nothing, and none of its
    /// ScatterPhase LDs read a DataRef group g stores — g's remaining
    /// ApplyPhase STs are the only writes between the dispatch point and
    /// g+1's own ScatterPhase slot.
    cross_prefetchable: Vec<bool>,
    /// Per-group async-prepare shape: `Some(k)` when the ScatterPhase is
    /// an LD prefix `scatter[..k]` followed by pure computes (no further
    /// LD/ST) — the split the prepare lane requires, since it runs the
    /// computes away from DRAM.
    scatter_split: Vec<Option<usize>>,
    /// Lazily built per-group instruction clones for the prepare lane.
    prep_cache: Vec<Option<Arc<PrepInstrs>>>,
    /// The persistent prepare lane (Group mode only), spawned on first
    /// dispatch and joined on drop.
    prep_lane: Option<PrepareLane>,
    /// Target `(group, interval)` of an in-flight lane job; its
    /// `begin_interval` is the rendezvous.
    pending_prepare: Option<(usize, usize)>,
    /// The walker's `lookahead_interval` notice: `(group, interval)` to
    /// prepare during the coming `end_gather` drain.
    lookahead: Option<(usize, usize)>,
    /// A prepared next-interval state (pipeline depth 2: this plus `iv`).
    standby: Option<Prepared>,
    /// Empty `IntervalState` container recycled between preparations, so
    /// depth-2 pipelining allocates its second state exactly once.
    spare: Option<IntervalState>,
    /// True when the current interval's ScatterPhase already ran at
    /// prepare time — `scatter_phase` then skips, verbatim.
    scatter_prepared: bool,
    /// Per-group `(prepared intervals, seconds)` pipelining telemetry for
    /// the last run; backfilled into `PhaseProfile` by `run_profiled`.
    prep_stats: Vec<(u64, f64)>,
    /// First batch failure of the current walk. The walk continues
    /// structurally after a failed batch (accumulators exist, their
    /// values are garbage) so later phases stay well-formed; the fault
    /// is surfaced — and the run's output discarded — by
    /// [`Executor::try_run`].
    fault: Option<PoolError>,
}

impl<'a> Executor<'a> {
    pub fn new(program: &'a Program, parts: &'a Partitions) -> Self {
        let layout = program.slot_layout();
        let mut w = vec![None; layout.w];
        for wi in &program.weights {
            w[wi.sym.id as usize] = Some(weights::init_weight(wi.seed, wi.rows, wi.cols));
        }
        let movable_spills = program
            .groups
            .iter()
            .map(|g| {
                g.gather
                    .iter()
                    .enumerate()
                    .map(|(idx, i)| match i {
                        Instr::St { sym, .. } if sym.space == Space::E => {
                            !g.gather[idx + 1..].iter().any(|later| later.uses().contains(sym))
                        }
                        _ => false,
                    })
                    .collect()
            })
            .collect();
        let group_stores: Vec<Vec<usize>> = program
            .groups
            .iter()
            .map(|g| {
                g.all_instrs()
                    .filter_map(|i| match i {
                        Instr::St { data, .. } => Some(data.slot()),
                        _ => None,
                    })
                    .collect()
            })
            .collect();
        let prefetchable: Vec<bool> = program
            .groups
            .iter()
            .enumerate()
            .map(|(gi, g)| {
                g.scatter.iter().all(|i| match i {
                    Instr::St { .. } => false,
                    Instr::Ld { data, .. } => !group_stores[gi].contains(&data.slot()),
                    _ => true,
                })
            })
            .collect();
        let cross_prefetchable = program
            .groups
            .iter()
            .enumerate()
            .map(|(gi, _)| {
                let Some(next) = program.groups.get(gi + 1) else {
                    return false;
                };
                if !prefetchable[gi + 1] {
                    return false;
                }
                next.scatter.iter().all(|i| match i {
                    Instr::St { .. } => false,
                    Instr::Ld { data, .. } => !group_stores[gi].contains(&data.slot()),
                    _ => true,
                })
            })
            .collect();
        let scatter_split = program
            .groups
            .iter()
            .map(|g| {
                let k = g
                    .scatter
                    .iter()
                    .position(|i| !matches!(i, Instr::Ld { .. }))
                    .unwrap_or(g.scatter.len());
                g.scatter[k..]
                    .iter()
                    .all(|i| !matches!(i, Instr::Ld { .. } | Instr::St { .. }))
                    .then_some(k)
            })
            .collect();
        let groups = program.groups.len();
        Executor {
            program,
            parts,
            banks: [Some(IntervalScratch::new(&layout)), None],
            layout,
            dram: Vec::new(),
            weights: Arc::new(w),
            workers: parts.config.num_sthreads.max(1) as usize,
            mode: KernelMode::default(),
            batch: 1,
            iv: None,
            pending: Vec::new(),
            pool: None,
            outs: Vec::new(),
            ret_bufs: Vec::new(),
            movable_spills,
            pipeline: PipelineMode::default(),
            prefetchable,
            cross_prefetchable,
            scatter_split,
            prep_cache: vec![None; groups],
            prep_lane: None,
            pending_prepare: None,
            lookahead: None,
            standby: None,
            spare: None,
            scatter_prepared: false,
            prep_stats: Vec::new(),
            fault: None,
        }
    }

    /// Override the GatherPhase worker-pool width. Defaults to the
    /// partitioning's simulated sThread count; `1` forces the threadless
    /// inline path. Outputs are bit-identical across widths. Resizing
    /// drops an already-spawned pool (threads join) so the next run
    /// spawns at the new width.
    pub fn with_workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self.pool = None;
        self.ret_bufs.clear();
        self
    }

    /// Select the compute-kernel implementation (differential tests run
    /// [`KernelMode::Naive`] as the golden reference).
    pub fn with_kernel_mode(mut self, mode: KernelMode) -> Self {
        self.mode = mode;
        self
    }

    /// Select the interval-pipelining mode (differential tests run
    /// [`PipelineMode::Off`] as the golden reference).
    pub fn with_pipeline_mode(mut self, mode: PipelineMode) -> Self {
        self.pipeline = mode;
        self
    }

    /// The effective worker-pool width.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The active compute-kernel implementation.
    pub fn kernel_mode(&self) -> KernelMode {
        self.mode
    }

    /// The active interval-pipelining mode.
    pub fn pipeline_mode(&self) -> PipelineMode {
        self.pipeline
    }

    /// Intervals whose DstBuffer state was prepared ahead of order during
    /// the last run — 0 when pipelining is off, every group is
    /// single-interval, or no group is prefetch-safe.
    pub fn prepared_intervals(&self) -> u64 {
        self.prep_stats.iter().map(|&(n, _)| n).sum()
    }

    /// Worker-pool counters for the last runs (all zeros before the
    /// first drain creates the pool).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.as_ref().map(WorkerPool::stats).unwrap_or_default()
    }

    /// Aggregate scratch-arena hit/miss counters (interval banks + the
    /// pool's inline and per-worker arenas). In steady state — after the
    /// first interval of each group has sized the pools — `misses` stops
    /// growing, and the guarantee is exact at any worker count: shard
    /// assignment is static/strided, and merged buffers return to the
    /// worker that lent them.
    pub fn scratch_stats(&self) -> ScratchStats {
        let mut st = ScratchStats::default();
        for b in self.banks.iter().flatten() {
            st.merge(b.stats());
        }
        if let Some(p) = &self.pool {
            st.merge(p.scratch_stats());
        }
        st
    }

    /// Liveness witness over the pool's worker threads (test probe: dead
    /// once the executor drops and every worker joined).
    #[cfg(test)]
    pub(crate) fn pool_probe(&self) -> Option<std::sync::Weak<()>> {
        self.pool.as_ref().map(WorkerPool::probe)
    }

    /// The canonical run entry point: execute the whole program for the
    /// request's 1..=B feature matrices in **one partition walk**,
    /// surfacing worker-pool faults (a panicking shard job) as a typed
    /// error. The executor stays fully usable after an `Err`: the pool
    /// has healed (fresh scratch, respawned threads), the next run
    /// reseeds DRAM, and its output is bit-identical to a never-faulted
    /// run.
    ///
    /// Batched runs column-stack the inputs into one `[N, B·F]` DRAM
    /// image (see the module docs); each request's output is
    /// bit-identical to running it alone, because every kernel either
    /// operates column-independently on full stacked rows or computes
    /// the request's lane in the exact sequential iteration order.
    ///
    /// With [`RunRequest::profile`] set, an
    /// [`obs::trace`](crate::obs::trace) session is opened around the
    /// walk (re-entrant — inside a surrounding `--trace` session this
    /// borrows it and reads only the tail recorded here, leaving the
    /// spans for the outer export) and [`PhaseProfile::from_spans`]
    /// folds the recorded walk + `prepare` spans into the per-(group,
    /// phase) profile.
    pub fn try_run_with(&mut self, req: &RunRequest) -> Result<RunOutput, PoolError> {
        self.seed_inputs(&req.inputs, req.degree);
        let walk = PartitionWalk::new(self.program, self.parts);
        let sess_mark = req.profile.then(|| {
            let sess = trace::begin();
            let mark = trace::mark();
            (sess, mark)
        });
        let steps = if req.trace {
            let mut traced = Traced::new(&mut *self);
            walk.drive(&mut traced);
            Some(traced.into_steps())
        } else {
            walk.drive(&mut *self);
            None
        };
        let profile = sess_mark.map(|(sess, mark)| {
            let spans = trace::since(mark);
            drop(sess.end());
            let mut profile = PhaseProfile::from_spans(&spans);
            profile.pad_groups(self.program.groups.len());
            profile
        });
        match self.fault.take() {
            // The walk ran to completion structurally, but every value
            // downstream of the failed batch is garbage — discard.
            Some(e) => Err(e),
            None => Ok(RunOutput {
                outputs: self.take_outputs(),
                steps,
                profile,
                batch: req.inputs.len(),
                prepared_intervals: self.prepared_intervals(),
            }),
        }
    }

    /// Run the whole program for one request. `x` is `[N, in_dim]`;
    /// `degree` the in-degree column used by `DataRef::Degree`. Panics
    /// on a worker-pool fault — recoverable callers (the serve entry
    /// loop) use [`Executor::try_run`].
    ///
    /// Deprecated: thin wrapper over [`Executor::try_run_with`], the
    /// canonical (and batch-capable) run surface.
    pub fn run(&mut self, x: &Matrix, degree: &Matrix) -> Matrix {
        self.try_run(x, degree)
            .unwrap_or_else(|e| panic!("executor fault: {e}"))
    }

    /// Run one request, surfacing worker-pool faults as a typed error.
    ///
    /// Deprecated: thin wrapper over [`Executor::try_run_with`], the
    /// canonical (and batch-capable) run surface.
    pub fn try_run(&mut self, x: &Matrix, degree: &Matrix) -> Result<Matrix, PoolError> {
        self.try_run_with(&RunRequest::new(x, degree))
            .map(RunOutput::into_output)
    }

    /// Like [`Executor::run`], additionally recording the walker's
    /// `(group, interval, shard, phase)` trace — the order-equivalence
    /// witness the scheduler tests compare against the simulator's.
    ///
    /// Deprecated: thin wrapper over [`Executor::try_run_with`] with
    /// [`RunRequest::trace`] set.
    pub fn run_traced(&mut self, x: &Matrix, degree: &Matrix) -> (Matrix, Vec<WalkStep>) {
        let mut out = self
            .try_run_with(&RunRequest::new(x, degree).with_trace(true))
            .unwrap_or_else(|e| panic!("executor fault: {e}"));
        let steps = out.steps.take().expect("trace was requested");
        (out.into_output(), steps)
    }

    /// Like [`Executor::run`], additionally timing every walk phase —
    /// the `switchblade bench --profile` path.
    ///
    /// Deprecated: thin wrapper over [`Executor::try_run_with`] with
    /// [`RunRequest::profile`] set.
    pub fn run_profiled(&mut self, x: &Matrix, degree: &Matrix) -> (Matrix, PhaseProfile) {
        let mut out = self
            .try_run_with(&RunRequest::new(x, degree).with_profile(true))
            .unwrap_or_else(|e| panic!("executor fault: {e}"));
        let profile = out.profile.take().expect("profile was requested");
        (out.into_output(), profile)
    }

    /// Seed the DRAM arena for a (possibly batched) run: requests are
    /// column-stacked into one `[N, B·F]` Input image and the degree
    /// column is tiled to `[N, B]`, so every downstream LD/ST row copy
    /// serves the whole batch at once. Batch size 1 clones the input
    /// verbatim — the exact pre-batching path.
    fn seed_inputs(&mut self, inputs: &[&Matrix], degree: &Matrix) {
        assert!(!inputs.is_empty(), "a run needs at least one input");
        for x in inputs {
            assert_eq!(x.rows, self.parts.num_vertices);
            assert_eq!(x.cols as u32, self.program.in_dim);
        }
        self.batch = inputs.len();
        self.fault = None;
        self.dram = vec![None; self.layout.dram];
        if self.batch == 1 {
            self.dram[DataRef::Input.slot()] = Some(inputs[0].clone());
            self.dram[DataRef::Degree.slot()] = Some(degree.clone());
        } else {
            let n = self.parts.num_vertices;
            let f = self.program.in_dim as usize;
            let mut x = Matrix::zeros(n, f * self.batch);
            for r in 0..n {
                let row = x.row_mut(r);
                for (l, m) in inputs.iter().enumerate() {
                    row[l * f..(l + 1) * f].copy_from_slice(m.row(r));
                }
            }
            let mut deg = Matrix::zeros(n, self.batch);
            for r in 0..n {
                deg.row_mut(r).fill(degree.get(r, 0));
            }
            self.dram[DataRef::Input.slot()] = Some(x);
            self.dram[DataRef::Degree.slot()] = Some(deg);
        }
        // Re-arm the pipeline for a fresh walk. A completed walk leaves no
        // standby or in-flight lane job (the last interval has no
        // lookahead), but drain both defensively so buffers flow back.
        self.lookahead = None;
        self.scatter_prepared = false;
        self.prep_stats.clear();
        if self.pending_prepare.take().is_some() {
            let done = self
                .prep_lane
                .as_ref()
                .expect("pending prepare has a lane")
                .recv();
            let b = done.state.bank;
            self.banks[b] = Some(done.scratch);
            let mut st = done.state;
            st.recycle(bank_mut(&mut self.banks, b));
            if self.spare.is_none() {
                self.spare = Some(st);
            }
        }
        if let Some(p) = self.standby.take() {
            let mut st = p.state;
            st.recycle(bank_mut(&mut self.banks, st.bank));
            self.spare = Some(st);
        }
        // Normalise container↔bank pairing so every run starts from the
        // same pool state (Group-mode runs may end on either bank).
        if let Some(mut st) = self.iv.take() {
            st.recycle(bank_mut(&mut self.banks, st.bank));
            st.bank = 0;
            self.iv = Some(st);
        }
        if let Some(st) = self.spare.as_mut() {
            st.bank = 0; // always recycled before becoming the spare
        }
    }

    /// Move the output out of its DRAM slot and split the stacked
    /// `[N, B·out]` image back into per-request `[N, out]` matrices
    /// (batch 1 moves the matrix with no copy — the run is over and
    /// `seed_inputs` re-arms the arena for the next one).
    fn take_outputs(&mut self) -> Vec<Matrix> {
        let slot = self.output_ref().slot();
        let m = self.dram[slot]
            .take()
            .unwrap_or_else(|| panic!("program never stored its output"));
        if self.batch == 1 {
            return vec![m];
        }
        let per = m.cols / self.batch;
        debug_assert_eq!(per * self.batch, m.cols, "stacked output width");
        (0..self.batch)
            .map(|l| {
                let mut out = Matrix::zeros(m.rows, per);
                for r in 0..m.rows {
                    out.row_mut(r).copy_from_slice(&m.row(r)[l * per..(l + 1) * per]);
                }
                out
            })
            .collect()
    }

    /// The DataRef holding the final result: the last `ST.D` of the last
    /// group's ApplyPhase.
    pub fn output_ref(&self) -> DataRef {
        self.program
            .groups
            .last()
            .and_then(|g| {
                g.apply.iter().rev().find_map(|i| match i {
                    Instr::St { data, .. } => Some(*data),
                    _ => None,
                })
            })
            .expect("last group must store the result")
    }

    // ---- interval-phase execution (Scatter / Apply) --------------------------

    fn exec_interval_instr(&mut self, i: &Instr, iv: &mut IntervalState) {
        if let Instr::St { sym, data, cols, .. } = i {
            // ST — the one interval instruction that writes DRAM, so it
            // stays on the sequential path (prefetch-unsafe groups never
            // reach the prepare-ahead code).
            let slot = data.slot();
            if self.dram[slot].is_none() {
                self.dram[slot] = Some(Matrix::zeros(
                    self.parts.num_vertices,
                    *cols as usize * self.batch,
                ));
            }
            let m = iv.d[sym.id as usize]
                .as_ref()
                .unwrap_or_else(|| panic!("ST of undefined {sym}"));
            let dst = self.dram[slot].as_mut().unwrap();
            for (r, gv) in (iv.begin..iv.end).enumerate() {
                dst.row_mut(gv).copy_from_slice(m.row(r));
            }
            return;
        }
        let scratch = bank_mut(&mut self.banks, iv.bank);
        exec_interval_read_instr(i, iv, &self.dram, &self.weights, scratch, self.mode, self.batch);
    }

    // ---- shard-phase execution (Gather) ---------------------------------------

    /// Drain the interval's queued shards through the worker pool, then
    /// merge their partial results in canonical shard order. However the
    /// workers raced, the merge sees the same partials in the same order,
    /// so any pool width is bit-identical to a single worker.
    ///
    /// When the walker announced a lookahead (pipelining on, group
    /// prefetch-safe), the next interval's DstBuffer state is prepared on
    /// this thread *while the workers drain* — the software realisation
    /// of the paper's interval overlap — or, in [`PipelineMode::Group`]
    /// with a splittable prologue, handed to the persistent prepare lane
    /// so the overlap extends across the ApplyPhase. The standby state is
    /// swapped in by the target's `begin_interval`; the inline (≤1
    /// worker) path prepares after the drain so buffer-pool traffic stays
    /// deterministic at any width.
    fn run_pending_shards(&mut self, cx: &StepCtx) {
        let mut pending = std::mem::take(&mut self.pending);
        let prefetch = self.lookahead.take();
        if pending.is_empty() && prefetch.is_none() {
            self.pending = pending; // keep the capacity for the next interval
            return;
        }
        if self.pool.is_none() {
            // The one spawn point: workers outlive every interval and
            // every run of this executor.
            self.pool = Some(WorkerPool::new(&self.layout, self.workers));
            self.ret_bufs.resize_with(self.workers, Vec::new);
        }
        // Worker/lane spans gate on a flag sampled here, per drain, on
        // the driving thread — persistent threads cannot see this
        // thread's TLS session flag, and sampling per batch means a
        // session opened *after* the pool spawned is observed on the
        // very next drain.
        let tracing = trace::active();
        // Plan the lookahead: offload to the prepare lane (Group mode,
        // splittable prologue) or rebind a standby container for the
        // under-drain prepare on this thread.
        let mut standby: Option<(usize, usize, IntervalState)> = None;
        if let Some((tg, ni)) = prefetch {
            if self.pipeline == PipelineMode::Group && self.scatter_split[tg].is_some() {
                self.dispatch_prepare(tg, ni, tracing);
            } else {
                let mut st = self
                    .spare
                    .take()
                    .unwrap_or_else(|| IntervalState::empty(&self.layout));
                reset_state(&mut self.banks, &mut st, &self.parts.intervals[ni], 0);
                standby = Some((tg, ni, st));
            }
        }
        let mut prep_s = 0.0f64;
        if pending.is_empty() {
            // An interval with no shards still pipelines the next one.
            prep_s = timed_prepare(
                self.program,
                &mut standby,
                &self.dram,
                &self.weights,
                bank_mut(&mut self.banks, 0),
                self.mode,
                self.batch,
            );
        } else {
            let mut iv = self.iv.take().expect("interval state");
            let mut outs = std::mem::take(&mut self.outs);
            {
                let pool = self.pool.as_mut().expect("pool created above");
                let env = ShardEnv {
                    layout: &self.layout,
                    weights: &self.weights,
                    dram: &self.dram,
                    iv: &iv,
                    parts: self.parts,
                    gather: &cx.group.gather[..],
                    movable: &self.movable_spills[cx.group_idx][..],
                    mode: self.mode,
                    batch: self.batch,
                };
                let (g_arg, i_arg) = (cx.group_idx as i32, cx.interval_idx as i32);
                let (env_ref, pending_ref) = (&env, &pending);
                let run = move |k: usize, w: usize, ws: &mut WorkerScratch| {
                    let si = pending_ref[k];
                    let _span = trace::span_if(
                        tracing,
                        trace::names::SHARD,
                        trace::cat::EXEC,
                        trace::worker_track(w),
                        g_arg,
                        i_arg,
                        si as i32,
                    );
                    // A single relaxed atomic load when disarmed; armed,
                    // may sleep (`slow_shard`) or panic (`worker_panic`)
                    // — the chaos tests' deterministic trigger.
                    faultinject::shard_site(si);
                    env_ref.run_shard(si, ws, w)
                };
                let mut fault: Option<PoolError> = None;
                if pool.is_inline() {
                    // Single-worker mode: the driving thread owns the
                    // scratch outright — no Mutex, no threads — and the
                    // prepare runs after the drain so pool traffic stays
                    // deterministic. The per-shard catch mirrors the
                    // threaded workers': a panicking shard fails the
                    // batch, not the caller.
                    let t0 = Instant::now();
                    {
                        let ws = pool.inline_scratch();
                        for k in 0..pending.len() {
                            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                                || run(k, 0, &mut *ws),
                            ));
                            match r {
                                Ok(out) => outs.push(out),
                                Err(payload) => {
                                    fault = Some(PoolError::WorkerPanicked {
                                        worker: 0,
                                        shard: k,
                                        msg: panic_message(&*payload),
                                    });
                                    break;
                                }
                            }
                        }
                    }
                    pool.note_inline_batch(pending.len(), t0.elapsed().as_nanos() as u64);
                    if fault.is_some() {
                        // The panicking shard may have stranded loaned
                        // buffers — restart the inline scratch clean.
                        pool.note_inline_panic();
                        outs.clear();
                    }
                    prep_s = timed_prepare(
                        self.program,
                        &mut standby,
                        &self.dram,
                        &self.weights,
                        bank_mut(&mut self.banks, 0),
                        self.mode,
                        self.batch,
                    );
                } else {
                    let ticket = pool.begin_batch(pending.len(), &run);
                    // The overlap: the next interval's iThread
                    // preparation runs here, concurrent with the
                    // workers' drain.
                    prep_s = timed_prepare(
                        self.program,
                        &mut standby,
                        &self.dram,
                        &self.weights,
                        bank_mut(&mut self.banks, 0),
                        self.mode,
                        self.batch,
                    );
                    if let Err(e) = ticket.finish(&mut outs) {
                        fault = Some(e);
                    }
                }
                if let Some(e) = fault {
                    // Rewrite the pool's batch position to the canonical
                    // shard id before surfacing.
                    let e = match e {
                        PoolError::WorkerPanicked { worker, shard, msg } => {
                            PoolError::WorkerPanicked {
                                worker,
                                shard: pending[shard],
                                msg,
                            }
                        }
                        other => other,
                    };
                    metrics::counter("exec_worker_panics", 1);
                    if self.fault.is_none() {
                        self.fault = Some(e);
                    }
                }
            }
            for (&si, out) in pending.iter().zip(outs.drain(..)) {
                self.merge_shard(&mut iv, si, out);
            }
            self.outs = outs; // keep the capacity
            self.pool
                .as_mut()
                .expect("pool exists")
                .deposit_returns(&mut self.ret_bufs);
            pending.clear();
            self.iv = Some(iv);
        }
        self.pending = pending; // keep the capacity for the next interval
        if let Some((tg, ni, st)) = standby {
            self.note_prepared(tg, prep_s);
            self.standby = Some(Prepared {
                group: tg,
                interval: ni,
                state: st,
            });
        }
    }

    /// Hand a `(group, interval)` preparation to the persistent lane:
    /// run the DRAM-reading LD prefix here (inside the safety window the
    /// prefetch gates establish), then ship the state, its scratch bank,
    /// and the compute suffix to the lane thread. The rendezvous is the
    /// target's `begin_interval`.
    fn dispatch_prepare(&mut self, tg: usize, ni: usize, tracing: bool) {
        let live_bank = self.iv.as_ref().map_or(0, |s| s.bank);
        let b = 1 - live_bank;
        if self.banks[b].is_none() {
            self.banks[b] = Some(IntervalScratch::new(&self.layout));
        }
        let mut st = self
            .spare
            .take()
            .unwrap_or_else(|| IntervalState::empty(&self.layout));
        reset_state(&mut self.banks, &mut st, &self.parts.intervals[ni], b);
        let mut scratch = self.banks[b].take().expect("bank present");
        let split = self.scatter_split[tg].expect("dispatch requires a split prologue");
        let group = &self.program.groups[tg];
        for i in &group.scatter[..split] {
            exec_interval_read_instr(
                i,
                &mut st,
                &self.dram,
                &self.weights,
                &mut scratch,
                self.mode,
                self.batch,
            );
        }
        let instrs = self.prep_instrs(tg);
        let job = PrepJob {
            state: st,
            scratch,
            instrs,
            weights: Arc::clone(&self.weights),
            mode: self.mode,
            batch: self.batch,
            tracing,
            // One lane past the pool's worker tracks.
            track: trace::worker_track(self.workers),
            group: tg as i32,
            interval: ni as i32,
        };
        self.prep_lane.get_or_insert_with(PrepareLane::new).send(job);
        self.pending_prepare = Some((tg, ni));
    }

    fn prep_instrs(&mut self, g: usize) -> Arc<PrepInstrs> {
        if self.prep_cache[g].is_none() {
            let split = self.scatter_split[g].expect("splittable group");
            let group = &self.program.groups[g];
            self.prep_cache[g] = Some(Arc::new(PrepInstrs {
                computes: group.scatter[split..].to_vec(),
                gathers: group.gather.clone(),
            }));
        }
        Arc::clone(self.prep_cache[g].as_ref().expect("just filled"))
    }

    /// Record one prepared interval in the per-group pipeline telemetry.
    fn note_prepared(&mut self, group: usize, secs: f64) {
        if self.prep_stats.len() <= group {
            self.prep_stats.resize(group + 1, (0, 0.0));
        }
        let (n, s) = &mut self.prep_stats[group];
        *n += 1;
        *s += secs;
    }

    /// Fold one shard's partial accumulators and spills into the interval
    /// state, staging the shard's buffers for return to the worker that
    /// produced them. Called in canonical shard order only.
    fn merge_shard(&mut self, iv: &mut IntervalState, shard_idx: usize, mut out: ShardOut) {
        let shard = &self.parts.shards[shard_idx];
        let mode = self.mode;
        let rets = &mut self.ret_bufs[out.worker];
        for &slot in &out.touched {
            let slot = slot as usize;
            let p = out.partials[slot]
                .take()
                .expect("touched slot carries a partial");
            let acc = iv.accs[slot]
                .as_mut()
                .expect("gather accumulator pre-created by scatter_phase");
            // The partial covers only the shard's dst window, and rows it
            // never touched (count 0) merge as identity — so the merge is
            // O(touched rows), not O(interval height).
            for (r, &cnt) in p.acc.counts.iter().enumerate() {
                if cnt == 0 {
                    continue;
                }
                let ar = p.base + r;
                match acc.reduce {
                    Reduce::Sum | Reduce::Mean => {
                        k_axpy(mode, acc.m.row_mut(ar), p.acc.m.row(r))
                    }
                    Reduce::Max => k_max_assign(mode, acc.m.row_mut(ar), p.acc.m.row(r)),
                }
                acc.counts[ar] += cnt;
            }
            rets.push(RetBuf::Pm(slot, p.acc.m.data));
            rets.push(RetBuf::Pc(slot, p.acc.counts));
        }
        for (dram_slot, e_slot, m) in out.spills.drain(..) {
            // ST.E rows land at canonical edge ids; shards own disjoint
            // edge sets, so the order is immaterial for the values.
            if self.dram[dram_slot].is_none() {
                self.dram[dram_slot] = Some(Matrix::zeros(self.parts.num_edges, m.cols));
            }
            let dst = self.dram[dram_slot].as_mut().unwrap();
            for (r, e) in shard.edges.iter().enumerate() {
                dst.row_mut(e.edge_id as usize).copy_from_slice(m.row(r));
            }
            self.ret_bufs[out.worker].push(RetBuf::E(e_slot as usize, m.data));
        }
    }
}

impl PhaseVisitor for Executor<'_> {
    fn begin_interval(&mut self, cx: &StepCtx) {
        self.scatter_prepared = false;
        // Join an in-flight lane preparation (Group mode): the lane
        // worked through the previous ApplyPhase (and, cross-group, the
        // group boundary); this is the rendezvous.
        if let Some(target) = self.pending_prepare.take() {
            let done = self
                .prep_lane
                .as_ref()
                .expect("pending prepare has a lane")
                .recv();
            let b = done.state.bank;
            debug_assert!(self.banks[b].is_none(), "bank returned twice");
            self.banks[b] = Some(done.scratch);
            if target == (cx.group_idx, cx.interval_idx) {
                self.note_prepared(target.0, done.secs);
                if let Some(mut old) = self.iv.take() {
                    old.recycle(bank_mut(&mut self.banks, old.bank));
                    self.spare = Some(old);
                }
                self.iv = Some(done.state);
                self.scatter_prepared = true;
                self.pending.clear();
                return;
            }
            // Stale lane result (unreachable under the walk contract —
            // defensive): recycle its buffers into its bank.
            let mut st = done.state;
            st.recycle(bank_mut(&mut self.banks, b));
            if self.spare.is_none() {
                self.spare = Some(st);
            }
        }
        if let Some(p) = self.standby.take() {
            if p.group == cx.group_idx && p.interval == cx.interval_idx {
                // The pipeline ping-pong: the prepared state becomes the
                // live one; the outgoing interval's buffers flow back
                // into the pools and its container becomes the spare for
                // the next preparation.
                if let Some(mut old) = self.iv.take() {
                    old.recycle(bank_mut(&mut self.banks, old.bank));
                    self.spare = Some(old);
                }
                self.iv = Some(p.state);
                self.scatter_prepared = true;
                self.pending.clear();
                return;
            }
            // Stale standby (unreachable under the walk contract —
            // defensive): recycle its buffers and container.
            let mut st = p.state;
            st.recycle(bank_mut(&mut self.banks, st.bank));
            self.spare = Some(st);
        }
        let mut st = self
            .iv
            .take()
            .unwrap_or_else(|| IntervalState::empty(&self.layout));
        reset_state(&mut self.banks, &mut st, cx.interval, 0);
        self.iv = Some(st);
        self.pending.clear();
    }

    fn scatter_phase(&mut self, cx: &StepCtx) {
        if std::mem::take(&mut self.scatter_prepared) {
            // Already ran at prepare time, under the previous interval's
            // gather drain (or on the prepare lane) — LDs, computes and
            // the pre-created gather accumulators are in place, verbatim.
            return;
        }
        let mut iv = self.iv.take().expect("interval state");
        for i in &cx.group.scatter {
            self.exec_interval_instr(i, &mut iv);
        }
        // Gather accumulators exist per interval even when the interval
        // has no shards (isolated destination ranges).
        let b = iv.bank;
        ensure_accs(&cx.group.gather, &mut iv, bank_mut(&mut self.banks, b), self.batch);
        self.iv = Some(iv);
    }

    fn gather_shard(&mut self, _cx: &StepCtx, shard_idx: usize, _shard: &Shard) {
        // Schedule point only — the pool drains at `end_gather` so shards
        // overlap while the merge order stays canonical.
        self.pending.push(shard_idx);
    }

    fn lookahead_interval(&mut self, cx: &StepCtx, next: &StepCtx) {
        // Record the walker's lookahead; the coming `end_gather` drain
        // consumes it and prepares that interval's DstBuffer state under
        // the worker pool (or on the prepare lane). Gated on the group's
        // prefetch safety so the ST-bearing prologue (and any DRAM
        // dependence) keeps the strictly sequential order; crossing a
        // group boundary additionally needs Group mode and the
        // cross-group dependence gate.
        if self.pipeline == PipelineMode::Off {
            return;
        }
        let safe = if next.group_idx == cx.group_idx {
            self.prefetchable[cx.group_idx]
        } else {
            self.pipeline == PipelineMode::Group && self.cross_prefetchable[cx.group_idx]
        };
        if safe {
            self.lookahead = Some((next.group_idx, next.interval_idx));
        }
    }

    fn end_gather(&mut self, cx: &StepCtx) {
        self.run_pending_shards(cx);
    }

    fn apply_phase(&mut self, cx: &StepCtx) {
        let mut iv = self.iv.take().expect("interval state");
        // Mean finalisation + empty-row convention.
        iv.finalize_gathers(bank_mut(&mut self.banks, iv.bank));
        for i in &cx.group.apply {
            self.exec_interval_instr(i, &mut iv);
        }
        self.iv = Some(iv);
    }

    // `end_interval` intentionally stays a no-op: the interval state is
    // retained and recycled by the next `begin_interval`'s reset, so the
    // matrices it holds flow back into the scratch pools instead of the
    // allocator.
}

// ---- the prepare lane (PipelineMode::Group) ---------------------------------

/// One job for the lane: a state whose LD prefix already ran, the
/// scratch bank its buffers are paired with, and the instruction suffix
/// to execute. Everything is owned or `Arc`-shared — the lane borrows
/// nothing from the executor.
struct PrepJob {
    state: IntervalState,
    scratch: IntervalScratch,
    instrs: Arc<PrepInstrs>,
    weights: Arc<Vec<Option<Matrix>>>,
    mode: KernelMode,
    batch: usize,
    tracing: bool,
    track: u32,
    group: i32,
    interval: i32,
}

struct PrepDone {
    state: IntervalState,
    scratch: IntervalScratch,
    secs: f64,
}

/// The persistent prepare thread: one job in flight at a time, fed and
/// joined by the driving thread (`dispatch_prepare` / `begin_interval`).
/// Plain `mpsc` — the executor never blocks on `send` (channel is
/// unbounded, at most one job queued) and blocks on `recv` only at the
/// rendezvous.
struct PrepareLane {
    tx: Option<mpsc::Sender<PrepJob>>,
    rx: mpsc::Receiver<PrepDone>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl PrepareLane {
    fn new() -> Self {
        let (tx, jrx) = mpsc::channel::<PrepJob>();
        let (dtx, rx) = mpsc::channel::<PrepDone>();
        let handle = std::thread::Builder::new()
            .name("sb-prepare".into())
            .spawn(move || {
                while let Ok(job) = jrx.recv() {
                    let t0 = Instant::now();
                    let mut st = job.state;
                    let mut scratch = job.scratch;
                    {
                        let _span = trace::span_if(
                            job.tracing,
                            trace::names::PREPARE,
                            trace::cat::EXEC,
                            job.track,
                            job.group,
                            job.interval,
                            -1,
                        );
                        for i in &job.instrs.computes {
                            // The compute suffix never touches DRAM (the
                            // split guarantees no LD/ST), hence the empty
                            // arena.
                            exec_interval_read_instr(
                                i,
                                &mut st,
                                &[],
                                &job.weights,
                                &mut scratch,
                                job.mode,
                                job.batch,
                            );
                        }
                        ensure_accs(&job.instrs.gathers, &mut st, &mut scratch, job.batch);
                    }
                    // Persistent thread: hand spans to the session now —
                    // the thread-exit flush would come far too late.
                    trace::flush_thread();
                    let secs = t0.elapsed().as_secs_f64();
                    if dtx
                        .send(PrepDone {
                            state: st,
                            scratch,
                            secs,
                        })
                        .is_err()
                    {
                        break;
                    }
                }
            })
            .expect("spawn prepare lane");
        PrepareLane {
            tx: Some(tx),
            rx,
            handle: Some(handle),
        }
    }

    fn send(&self, job: PrepJob) {
        self.tx
            .as_ref()
            .expect("lane channel open")
            .send(job)
            .expect("prepare lane alive");
    }

    fn recv(&self) -> PrepDone {
        self.rx.recv().expect("prepare lane alive")
    }
}

impl Drop for PrepareLane {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; the lane loop exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

// ---- interval state ---------------------------------------------------------

/// Per-interval state: resident D slots + gather accumulators. One
/// instance lives for the whole executor; `reset_state` re-arms it per
/// interval and drains retired buffers into the scratch bank it is
/// paired with.
struct IntervalState {
    begin: usize,
    end: usize,
    /// Which scratch bank this state's buffers came from (and return
    /// to). Interval/Off pipelining keeps everything on bank 0; the
    /// Group-mode lane alternates so a prepared state and the live state
    /// never share a bank.
    bank: usize,
    /// DstBuffer arena, indexed by D-symbol id.
    d: Vec<Option<Matrix>>,
    /// Gather accumulators, indexed by D-symbol id; moved into `d` by
    /// `finalize_gathers` once every shard's partials merged.
    accs: Vec<Option<Acc>>,
}

impl IntervalState {
    fn empty(layout: &SlotLayout) -> Self {
        IntervalState {
            begin: 0,
            end: 0,
            bank: 0,
            d: (0..layout.d).map(|_| None).collect(),
            accs: (0..layout.d).map(|_| None).collect(),
        }
    }

    /// Drain every buffer this state holds back into the scratch pools
    /// (the state stays usable as an empty container). `scratch` must be
    /// the bank recorded in `self.bank`.
    fn recycle(&mut self, scratch: &mut IntervalScratch) {
        for (slot, m) in self.d.iter_mut().enumerate() {
            if let Some(m) = m.take() {
                scratch.m.give(slot, m.data);
            }
        }
        for (slot, a) in self.accs.iter_mut().enumerate() {
            if let Some(a) = a.take() {
                scratch.m.give(slot, a.m.data);
                scratch.counts.give(slot, a.counts);
            }
        }
    }

    /// Point the (already recycled) state at a new interval.
    fn rearm(&mut self, iv: &Interval) {
        self.begin = iv.begin as usize;
        self.end = iv.end as usize;
    }

    fn len(&self) -> usize {
        self.end - self.begin
    }

    fn holds_buffers(&self) -> bool {
        self.d.iter().any(Option::is_some) || self.accs.iter().any(Option::is_some)
    }

    /// Pre-create a gather accumulator (first touch in this interval
    /// zeroes it — mirrors the hardware's phase-scheduler reset).
    fn ensure_acc(&mut self, dst: Sym, reduce: Reduce, cols: usize, scratch: &mut IntervalScratch) {
        let slot = dst.id as usize;
        if self.accs[slot].is_none() {
            let rows = self.len();
            self.accs[slot] = Some(Acc {
                reduce,
                m: scratch.m.take_matrix_filled(slot, rows, cols, reduce_identity(reduce)),
                counts: scratch.counts.take_filled(slot, rows, 0),
            });
        }
    }

    /// Post-merge fixups: Mean division and the zero-for-empty convention.
    fn finalize_gathers(&mut self, scratch: &mut IntervalScratch) {
        for (slot, (acc_slot, d_slot)) in
            self.accs.iter_mut().zip(self.d.iter_mut()).enumerate()
        {
            if let Some(mut acc) = acc_slot.take() {
                for (r, &cnt) in acc.counts.iter().enumerate() {
                    if cnt == 0 {
                        acc.m.row_mut(r).fill(0.0);
                    } else if acc.reduce == Reduce::Mean {
                        let inv = 1.0 / cnt as f32;
                        for v in acc.m.row_mut(r) {
                            *v *= inv;
                        }
                    }
                }
                scratch.counts.give(slot, acc.counts);
                if let Some(old) = d_slot.replace(acc.m) {
                    scratch.m.give(slot, old.data);
                }
            }
        }
    }
}

/// The live bank accessor — panics if the bank is checked out to the
/// prepare lane, which the dispatch/join protocol makes impossible at
/// any point this is called.
fn bank_mut(banks: &mut [Option<IntervalScratch>; 2], b: usize) -> &mut IntervalScratch {
    banks[b].as_mut().expect("scratch bank checked out")
}

/// Recycle `st` into its own bank, repoint it at `bank`, and re-arm it
/// for `iv`. The one place a state changes banks.
fn reset_state(
    banks: &mut [Option<IntervalScratch>; 2],
    st: &mut IntervalState,
    iv: &Interval,
    bank: usize,
) {
    match banks[st.bank].as_mut() {
        Some(sc) => st.recycle(sc),
        // The state's bank is checked out — only reachable for an empty
        // container (spare states are always recycled first).
        None => debug_assert!(!st.holds_buffers(), "recycle with bank checked out"),
    }
    st.bank = bank;
    st.rearm(iv);
}

/// The reduce-specific accumulator identity element.
fn reduce_identity(reduce: Reduce) -> f32 {
    match reduce {
        Reduce::Sum | Reduce::Mean => 0.0,
        Reduce::Max => f32::NEG_INFINITY,
    }
}

// ---- kernel-mode dispatch ---------------------------------------------------
//
// The row kernels are per-element independent, so the explicit-width
// variants are bit-identical to the scalar ones — the dispatch exists to
// keep the whole hot path (gather inner loops AND the merge) on the
// selected tier. `Naive` mode intentionally takes the scalar kernel arm:
// these row ops were never part of the naive compute reference.

#[inline]
fn k_axpy(mode: KernelMode, o: &mut [f32], x: &[f32]) {
    match mode {
        KernelMode::Simd => kernels::axpy_simd(o, x),
        _ => kernels::axpy(o, x),
    }
}

#[inline]
fn k_scale_axpy(mode: KernelMode, o: &mut [f32], x: &[f32], f: f32) {
    match mode {
        KernelMode::Simd => kernels::scale_axpy_simd(o, x, f),
        _ => kernels::scale_axpy(o, x, f),
    }
}

#[inline]
fn k_max_assign(mode: KernelMode, o: &mut [f32], x: &[f32]) {
    match mode {
        KernelMode::Simd => kernels::max_assign_simd(o, x),
        _ => kernels::max_assign(o, x),
    }
}

#[inline]
fn k_scale_max_assign(mode: KernelMode, o: &mut [f32], x: &[f32], f: f32) {
    match mode {
        KernelMode::Simd => kernels::scale_max_assign_simd(o, x, f),
        _ => kernels::scale_max_assign(o, x, f),
    }
}

// ---- shard execution --------------------------------------------------------

/// A gather accumulator (interval-level or per-shard partial).
struct Acc {
    reduce: Reduce,
    m: Matrix,
    counts: Vec<u32>,
}

/// A shard's partial gather accumulator: an [`Acc`] covering only the
/// shard's destination window, placed at interval-local row `base`.
struct Partial {
    base: usize,
    acc: Acc,
}

/// What one shard's GatherPhase produced: partial gather accumulators
/// (merged in shard order) and queued ST.E spills. Matrix buffers inside
/// come from — and return to — the producing worker's scratch arena; the
/// three container `Vec`s are the only per-shard heap traffic left.
pub(super) struct ShardOut {
    /// Worker index that ran the shard (owner of the buffers inside).
    worker: usize,
    /// Partials indexed by D slot (`SlotLayout::d` wide) — no linear
    /// `position()` scan per gather instruction.
    partials: Vec<Option<Partial>>,
    /// D slots present in `partials`, in first-touch order (the
    /// deterministic merge order).
    touched: Vec<u32>,
    /// `(DRAM slot, E slot, [shard_edges, cols] rows)` to write at
    /// canonical edge ids; the E slot routes the buffer back to the
    /// worker's pool after the merge.
    spills: Vec<(usize, u32, Matrix)>,
}

impl ShardOut {
    fn new(worker: usize, d_slots: usize) -> Self {
        ShardOut {
            worker,
            partials: (0..d_slots).map(|_| None).collect(),
            touched: Vec::new(),
            spills: Vec::new(),
        }
    }

    /// Get-or-create the shard's partial accumulator for `slot`.
    #[allow(clippy::too_many_arguments)]
    fn partial(
        &mut self,
        slot: usize,
        reduce: Reduce,
        base: usize,
        rows: usize,
        cols: usize,
        pm: &mut Pool<f32>,
        pc: &mut Pool<u32>,
    ) -> &mut Acc {
        if self.partials[slot].is_none() {
            self.touched.push(slot as u32);
            self.partials[slot] = Some(Partial {
                base,
                acc: Acc {
                    reduce,
                    m: pm.take_matrix_filled(slot, rows, cols, reduce_identity(reduce)),
                    counts: pc.take_filled(slot, rows, 0),
                },
            });
        }
        &mut self.partials[slot].as_mut().unwrap().acc
    }
}

/// Read-only view shared by the GatherPhase workers.
struct ShardEnv<'x> {
    layout: &'x SlotLayout,
    weights: &'x [Option<Matrix>],
    dram: &'x [Option<Matrix>],
    iv: &'x IntervalState,
    parts: &'x Partitions,
    gather: &'x [Instr],
    /// Per gather-instruction last-use flags for ST.E spills.
    movable: &'x [bool],
    mode: KernelMode,
    /// Batch size of the run: every S/E/D buffer is `cols · batch` wide
    /// (see the module docs on cross-request batching).
    batch: usize,
}

impl ShardEnv<'_> {
    fn run_shard(&self, shard_idx: usize, ws: &mut WorkerScratch, worker: usize) -> ShardOut {
        let shard = &self.parts.shards[shard_idx];
        let span = shard.dst_span();
        let mut out = ShardOut::new(worker, self.layout.d);
        for (idx, i) in self.gather.iter().enumerate() {
            self.exec_shard_instr(i, self.movable[idx], shard, span, ws, &mut out);
        }
        // Retire the shard's S/E matrices into the worker's pools.
        for (slot, m) in ws.s_arena.iter_mut().enumerate() {
            if let Some(m) = m.take() {
                ws.s.give(slot, m.data);
            }
        }
        for (slot, m) in ws.e_arena.iter_mut().enumerate() {
            if let Some(m) = m.take() {
                ws.e.give(slot, m.data);
            }
        }
        out
    }

    /// Get-or-create the shard's partial accumulator for `dst`, sized to
    /// the shard's destination window within the interval.
    #[allow(clippy::too_many_arguments)]
    fn windowed_partial<'o>(
        &self,
        out: &'o mut ShardOut,
        dst: Sym,
        reduce: Reduce,
        span: Option<(u32, u32)>,
        cols: usize,
        pm: &mut Pool<f32>,
        pc: &mut Pool<u32>,
    ) -> &'o mut Acc {
        let (lo, hi) = span.expect("edgeless shards return before accumulating");
        let base = lo as usize - self.iv.begin;
        let rows = (hi - lo + 1) as usize;
        out.partial(dst.id as usize, reduce, base, rows, cols, pm, pc)
    }

    fn exec_shard_instr(
        &self,
        i: &Instr,
        movable: bool,
        shard: &Shard,
        span: Option<(u32, u32)>,
        ws: &mut WorkerScratch,
        out: &mut ShardOut,
    ) {
        let iv = self.iv;
        match i {
            Instr::Ld { sym, data, cols, .. } => {
                let src = self.dram[data.slot()]
                    .as_ref()
                    .unwrap_or_else(|| panic!("LD of unwritten {data}"));
                let slot = sym.id as usize;
                // One row copy of `cols · batch` floats serves every
                // batch member — the amortized gather/scatter stream.
                match sym.space {
                    Space::S => {
                        let mut m = ws.s.take_matrix_any(
                            slot,
                            shard.num_src(),
                            *cols as usize * self.batch,
                        );
                        for (r, &gv) in shard.sources.iter().enumerate() {
                            m.row_mut(r).copy_from_slice(src.row(gv as usize));
                        }
                        if let Some(old) = ws.s_arena[slot].replace(m) {
                            ws.s.give(slot, old.data);
                        }
                    }
                    Space::E => {
                        let mut m = ws.e.take_matrix_any(
                            slot,
                            shard.num_edges(),
                            *cols as usize * self.batch,
                        );
                        for (r, ed) in shard.edges.iter().enumerate() {
                            m.row_mut(r).copy_from_slice(src.row(ed.edge_id as usize));
                        }
                        if let Some(old) = ws.e_arena[slot].replace(m) {
                            ws.e.give(slot, old.data);
                        }
                    }
                    _ => panic!("GatherPhase LD of {sym}"),
                }
            }
            Instr::St { sym, data, .. } => {
                // ST.E — spill edge rows; the writes are queued and land
                // at canonical edge ids during the deterministic merge.
                // When this is the symbol's last use in the phase the
                // matrix moves out of the arena (no copy); otherwise it is
                // duplicated into a pool buffer.
                let slot = sym.id as usize;
                let m = if movable {
                    ws.e_arena[slot]
                        .take()
                        .unwrap_or_else(|| panic!("ST of undefined {sym}"))
                } else {
                    let src = ws.e_arena[slot]
                        .as_ref()
                        .unwrap_or_else(|| panic!("ST of undefined {sym}"));
                    let mut c = ws.e.take_matrix_any(slot, src.rows, src.cols);
                    c.data.copy_from_slice(&src.data);
                    c
                };
                out.spills.push((data.slot(), slot as u32, m));
            }
            Instr::Scatter { dir, dst, src, cols } => {
                let slot = dst.id as usize;
                let mut m = ws.e.take_matrix_any(
                    slot,
                    shard.num_edges(),
                    *cols as usize * self.batch,
                );
                match dir {
                    ScatterDir::SrcToEdge => {
                        let sm = ws.s_arena[src.id as usize]
                            .as_ref()
                            .unwrap_or_else(|| panic!("S operand {src} missing"));
                        for (r, ed) in shard.edges.iter().enumerate() {
                            m.row_mut(r).copy_from_slice(sm.row(ed.src_slot as usize));
                        }
                    }
                    ScatterDir::DstToEdge => {
                        let dm = iv.d[src.id as usize]
                            .as_ref()
                            .unwrap_or_else(|| panic!("D operand {src} missing"));
                        for (r, ed) in shard.edges.iter().enumerate() {
                            let local = (ed.dst - iv.begin as u32) as usize;
                            m.row_mut(r).copy_from_slice(dm.row(local));
                        }
                    }
                }
                if let Some(old) = ws.e_arena[slot].replace(m) {
                    ws.e.give(slot, old.data);
                }
            }
            Instr::FusedGather {
                reduce,
                dst,
                src,
                scale,
                cols,
            } => {
                // An edgeless shard contributes nothing (the interval-level
                // accumulator was pre-created by `scatter_phase`).
                let Some((lo, _)) = span else { return };
                let sm = ws.s_arena[src.id as usize]
                    .as_ref()
                    .unwrap_or_else(|| panic!("S operand {src} missing"));
                let scale_m = scale.map(|sc| {
                    ws.e_arena[sc.id as usize]
                        .as_ref()
                        .unwrap_or_else(|| panic!("E operand {sc} missing"))
                });
                let cw = *cols as usize;
                let acc = self.windowed_partial(
                    out,
                    *dst,
                    *reduce,
                    span,
                    cw * self.batch,
                    &mut ws.pm,
                    &mut ws.pc,
                );
                for (r, ed) in shard.edges.iter().enumerate() {
                    let local = (ed.dst - lo) as usize;
                    acc.counts[local] += 1;
                    let row = sm.row(ed.src_slot as usize);
                    match scale_m {
                        // Unscaled (f = 1.0 for every lane): one fused
                        // row op covers the whole stacked row —
                        // element-wise, so bit-identical per lane.
                        None => match reduce {
                            Reduce::Sum | Reduce::Mean => {
                                k_scale_axpy(self.mode, acc.m.row_mut(local), row, 1.0)
                            }
                            Reduce::Max => {
                                k_scale_max_assign(self.mode, acc.m.row_mut(local), row, 1.0)
                            }
                        },
                        // Scaled: the stacked scale is `[edges, batch]`
                        // — each lane applies its own request's factor,
                        // in the sequential kernel's iteration order.
                        Some(m) => {
                            let arow = acc.m.row_mut(local);
                            for l in 0..self.batch {
                                let f = m.get(r, l);
                                let o = &mut arow[l * cw..(l + 1) * cw];
                                let x = &row[l * cw..(l + 1) * cw];
                                match reduce {
                                    Reduce::Sum | Reduce::Mean => {
                                        k_scale_axpy(self.mode, o, x, f)
                                    }
                                    Reduce::Max => k_scale_max_assign(self.mode, o, x, f),
                                }
                            }
                        }
                    }
                }
            }
            Instr::Gather {
                reduce,
                dst,
                src,
                cols,
            } => {
                let Some((lo, _)) = span else { return };
                let ev = ws.e_arena[src.id as usize]
                    .as_ref()
                    .unwrap_or_else(|| panic!("E operand {src} missing"));
                let acc = self.windowed_partial(
                    out,
                    *dst,
                    *reduce,
                    span,
                    *cols as usize * self.batch,
                    &mut ws.pm,
                    &mut ws.pc,
                );
                for (r, ed) in shard.edges.iter().enumerate() {
                    let local = (ed.dst - lo) as usize;
                    acc.counts[local] += 1;
                    let row = ev.row(r);
                    match reduce {
                        Reduce::Sum | Reduce::Mean => {
                            k_axpy(self.mode, acc.m.row_mut(local), row)
                        }
                        Reduce::Max => k_max_assign(self.mode, acc.m.row_mut(local), row),
                    }
                }
            }
            _ => {
                // Shard-side compute: rows decode against the shard.
                let rows_dim = instr_rows(i);
                let rows = rows_dim.decode(iv.len(), shard.num_src(), shard.num_edges());
                let def = i.def().expect("compute defines");
                let slot = def.id as usize;
                let m = match self.mode {
                    KernelMode::Blocked | KernelMode::Simd => {
                        // The def's pool is a field disjoint from the
                        // operand arenas, so this borrow-splits cleanly.
                        let pool = match def.space {
                            Space::S => &mut ws.s,
                            Space::E => &mut ws.e,
                            _ => panic!("GatherPhase compute must write S/E"),
                        };
                        compute_instr_kernel(
                            i,
                            rows,
                            self.weights,
                            Some(&ws.s_arena[..]),
                            Some(&ws.e_arena[..]),
                            &iv.d,
                            pool,
                            slot,
                            self.mode,
                            self.batch,
                        )
                    }
                    KernelMode::Naive => compute_instr_naive(
                        i,
                        rows,
                        self.weights,
                        Some(&ws.s_arena[..]),
                        Some(&ws.e_arena[..]),
                        &iv.d,
                        self.batch,
                    ),
                };
                let (arena, pool) = match def.space {
                    Space::S => (&mut ws.s_arena, &mut ws.s),
                    Space::E => (&mut ws.e_arena, &mut ws.e),
                    _ => panic!("GatherPhase compute must write S/E"),
                };
                if let Some(old) = arena[slot].replace(m) {
                    pool.give(slot, old.data);
                }
            }
        }
    }
}

/// Execute one ScatterPhase/ApplyPhase instruction that only *reads*
/// DRAM — `LD` or compute. `ST`, the one DRAM-writing interval
/// instruction, is handled by the sequential caller
/// (`Executor::exec_interval_instr`); the pipelined prepare paths never
/// see one because ST-bearing ScatterPhases are not prefetch-safe.
#[allow(clippy::too_many_arguments)]
fn exec_interval_read_instr(
    i: &Instr,
    iv: &mut IntervalState,
    dram: &[Option<Matrix>],
    weights: &[Option<Matrix>],
    scratch: &mut IntervalScratch,
    mode: KernelMode,
    batch: usize,
) {
    let v = iv.len();
    match i {
        Instr::Ld { sym, data, cols, .. } => {
            let src = dram[data.slot()]
                .as_ref()
                .unwrap_or_else(|| panic!("LD of unwritten {data}"));
            let slot = sym.id as usize;
            // DRAM arrays are batch-stacked, so one row copy of
            // `cols · batch` floats serves every batch member.
            let mut m = scratch.m.take_matrix_any(slot, v, *cols as usize * batch);
            for (r, gv) in (iv.begin..iv.end).enumerate() {
                m.row_mut(r).copy_from_slice(src.row(gv));
            }
            if let Some(old) = iv.d[slot].replace(m) {
                scratch.m.give(slot, old.data);
            }
        }
        Instr::St { .. } => unreachable!("ST is the sequential caller's case"),
        _ => {
            let def = i.def().expect("compute defines");
            let slot = def.id as usize;
            let out = match mode {
                KernelMode::Blocked | KernelMode::Simd => compute_instr_kernel(
                    i,
                    v,
                    weights,
                    None,
                    None,
                    &iv.d,
                    &mut scratch.m,
                    slot,
                    mode,
                    batch,
                ),
                KernelMode::Naive => {
                    compute_instr_naive(i, v, weights, None, None, &iv.d, batch)
                }
            };
            if let Some(old) = iv.d[slot].replace(out) {
                scratch.m.give(slot, old.data);
            }
        }
    }
}

/// Pre-create the interval's gather accumulators (first touch zeroes them
/// — mirrors the hardware's phase-scheduler reset). Shared by the
/// sequential `scatter_phase`, the pipelined prepare, and the prepare
/// lane (hence the instruction-slice parameter).
fn ensure_accs(gather: &[Instr], iv: &mut IntervalState, scratch: &mut IntervalScratch, batch: usize) {
    for i in gather {
        match i {
            Instr::Gather { reduce, dst, cols, .. }
            | Instr::FusedGather { reduce, dst, cols, .. } => {
                iv.ensure_acc(*dst, *reduce, *cols as usize * batch, scratch);
            }
            _ => {}
        }
    }
}

/// The single timed entry point the `run_pending_shards` arms
/// (empty-pending, inline, threaded) share: run [`prepare_interval`] for
/// the standby, if one is planned, and return the seconds spent.
///
/// Always called on the walk's driving thread (the threaded arm calls it
/// between `begin_batch` and the ticket's `finish`), so the `prepare`
/// trace span gates on this thread's session flag and lands on the main
/// track — in a trace it shows up *under* the enclosing `gather_drain`
/// span, which is exactly the pipelining overlap being claimed.
#[allow(clippy::too_many_arguments)]
fn timed_prepare(
    program: &Program,
    standby: &mut Option<(usize, usize, IntervalState)>,
    dram: &[Option<Matrix>],
    weights: &[Option<Matrix>],
    scratch: &mut IntervalScratch,
    mode: KernelMode,
    batch: usize,
) -> f64 {
    let Some((tg, ni, st)) = standby.as_mut() else {
        return 0.0;
    };
    let group = &program.groups[*tg];
    let _span = trace::span_args(
        trace::names::PREPARE,
        trace::cat::EXEC,
        trace::TRACK_MAIN,
        *tg as i32,
        *ni as i32,
        -1,
    );
    let t0 = Instant::now();
    prepare_interval(group, st, dram, weights, scratch, mode, batch);
    t0.elapsed().as_secs_f64()
}

/// Build a (rebound) standby `IntervalState` for the *next* interval of a
/// prefetch-safe group: run its ScatterPhase LDs/computes and pre-create
/// its gather accumulators. Runs on the main thread, overlapped with the
/// current interval's worker-pool drain — every input it reads (DRAM
/// arrays, weights) is provably unchanged until the interval's own
/// `scatter_phase` slot in the sequential order, so the prepared state is
/// bit-identical to what `PipelineMode::Off` would build there.
#[allow(clippy::too_many_arguments)]
fn prepare_interval(
    group: &PhaseGroup,
    st: &mut IntervalState,
    dram: &[Option<Matrix>],
    weights: &[Option<Matrix>],
    scratch: &mut IntervalScratch,
    mode: KernelMode,
    batch: usize,
) {
    for i in &group.scatter {
        exec_interval_read_instr(i, st, dram, weights, scratch, mode, batch);
    }
    ensure_accs(&group.gather, st, scratch, batch);
}

/// Resolve a compute operand against the slot arenas: W from `weights`,
/// S/E from the shard arenas (GatherPhase only), D from the interval
/// arena.
fn look_operand<'m>(
    sym: &Sym,
    weights: &'m [Option<Matrix>],
    s: Option<&'m [Option<Matrix>]>,
    e: Option<&'m [Option<Matrix>]>,
    d: &'m [Option<Matrix>],
) -> &'m Matrix {
    let arena: &[Option<Matrix>] = match sym.space {
        Space::W => weights,
        Space::S => s.unwrap_or_else(|| panic!("S operand {sym} outside GatherPhase")),
        Space::E => e.unwrap_or_else(|| panic!("E operand {sym} outside GatherPhase")),
        Space::D => d,
    };
    arena[sym.id as usize]
        .as_ref()
        .unwrap_or_else(|| panic!("operand {sym} missing"))
}

/// Evaluate a compute instruction through the kernel layer, writing into
/// a scratch buffer taken from `pool` at `slot` (blocked branch-free DMM,
/// flat-slice ELW/RSCALE/CAT — no per-element `get`/`set`).
/// [`KernelMode::Simd`] swaps the DMM for its explicit-width twin.
/// Results are bit-identical to [`compute_instr_naive`] for finite
/// inputs.
///
/// `batch > 1` evaluates the column-stacked layout: every non-weight
/// operand (and the output) is `cols · batch` wide. Purely element-wise
/// work runs on the full stacked rows (bit-identical per lane by
/// column independence); anywhere an *unstacked* W operand or a
/// per-lane scalar enters — DMM, ELW/CAT with a W operand, RSCALE —
/// each lane is computed separately in the sequential kernel's exact
/// iteration order, so the result stays bit-identical to running every
/// request alone. `batch == 1` takes the original code paths verbatim.
#[allow(clippy::too_many_arguments)]
fn compute_instr_kernel(
    i: &Instr,
    rows: usize,
    weights: &[Option<Matrix>],
    s: Option<&[Option<Matrix>]>,
    e: Option<&[Option<Matrix>]>,
    d: &[Option<Matrix>],
    pool: &mut Pool<f32>,
    slot: usize,
    mode: KernelMode,
    batch: usize,
) -> Matrix {
    // Stacked operand window: W-space operands are never stacked, so a
    // lane reads them at offset 0 with their real width.
    let lane_off = |sym: &Sym, l: usize, w: usize| if sym.space == Space::W { 0 } else { l * w };
    match i {
        Instr::Elw {
            op,
            a,
            b,
            broadcast_b,
            cols,
            ..
        } => {
            let cols = *cols as usize;
            let am = look_operand(a, weights, s, e, d);
            let mut out = pool.take_matrix_any(slot, rows, cols * batch);
            let stacked = |sym: &Sym| sym.space != Space::W;
            match b {
                None if batch == 1 || stacked(a) => {
                    kernels::elw_unary(*op, &am.data[..rows * cols * batch], &mut out.data)
                }
                None => {
                    // Unstacked (W) source broadcast into every lane.
                    for r in 0..rows {
                        let orow = out.row_mut(r);
                        for l in 0..batch {
                            kernels::elw_unary(
                                *op,
                                &am.row(r)[..cols],
                                &mut orow[l * cols..(l + 1) * cols],
                            );
                        }
                    }
                }
                Some(bs) => {
                    let bm = look_operand(bs, weights, s, e, d);
                    if batch == 1 || (stacked(a) && stacked(bs)) {
                        // Both operands stacked: the broadcast row and
                        // the flat slices are themselves stacked, so the
                        // unbatched code runs on the wider rows.
                        if *broadcast_b {
                            for r in 0..rows {
                                kernels::elw_binary(*op, am.row(r), bm.row(0), out.row_mut(r));
                            }
                        } else {
                            kernels::elw_binary(
                                *op,
                                &am.data[..rows * cols * batch],
                                &bm.data[..rows * cols * batch],
                                &mut out.data,
                            );
                        }
                    } else {
                        // A W operand is shared by every lane.
                        for r in 0..rows {
                            let orow = out.row_mut(r);
                            for l in 0..batch {
                                let ao = lane_off(a, l, cols);
                                let bo = lane_off(bs, l, cols);
                                let br = if *broadcast_b { 0 } else { r };
                                kernels::elw_binary(
                                    *op,
                                    &am.row(r)[ao..ao + cols],
                                    &bm.row(br)[bo..bo + cols],
                                    &mut orow[l * cols..(l + 1) * cols],
                                );
                            }
                        }
                    }
                }
            }
            out
        }
        Instr::RowScale { a, scale, cols, .. } => {
            let cols = *cols as usize;
            let am = look_operand(a, weights, s, e, d);
            let sm = look_operand(scale, weights, s, e, d);
            let mut out = pool.take_matrix_any(slot, rows, cols * batch);
            if batch == 1 {
                for r in 0..rows {
                    kernels::row_scale(&am.row(r)[..cols], sm.get(r, 0), out.row_mut(r));
                }
            } else {
                // The stacked scale column is `[rows, batch]`; each lane
                // scales by its own request's factor.
                for r in 0..rows {
                    let orow = out.row_mut(r);
                    for l in 0..batch {
                        let f = if scale.space == Space::W {
                            sm.get(r, 0)
                        } else {
                            sm.get(r, l)
                        };
                        let ao = lane_off(a, l, cols);
                        kernels::row_scale(
                            &am.row(r)[ao..ao + cols],
                            f,
                            &mut orow[l * cols..(l + 1) * cols],
                        );
                    }
                }
            }
            out
        }
        Instr::Concat {
            a, b, cols_a, cols_b, ..
        } => {
            let (ca, cb) = (*cols_a as usize, *cols_b as usize);
            let am = look_operand(a, weights, s, e, d);
            let bm = look_operand(b, weights, s, e, d);
            let mut out = pool.take_matrix_any(slot, rows, (ca + cb) * batch);
            if batch == 1 {
                for r in 0..rows {
                    out.row_mut(r)[..ca].copy_from_slice(am.row(r));
                    out.row_mut(r)[ca..].copy_from_slice(bm.row(r));
                }
            } else {
                // Interleave per lane: `[a_0 | b_0 | a_1 | b_1 | ...]`.
                for r in 0..rows {
                    let orow = out.row_mut(r);
                    for l in 0..batch {
                        let ao = lane_off(a, l, ca);
                        let bo = lane_off(b, l, cb);
                        let base = l * (ca + cb);
                        orow[base..base + ca].copy_from_slice(&am.row(r)[ao..ao + ca]);
                        orow[base + ca..base + ca + cb]
                            .copy_from_slice(&bm.row(r)[bo..bo + cb]);
                    }
                }
            }
            out
        }
        Instr::Dmm { a, w, .. } => {
            let am = look_operand(a, weights, s, e, d);
            let wm = look_operand(w, weights, s, e, d);
            let mut out = pool.take_matrix_any(slot, am.rows, wm.cols * batch);
            if batch == 1 {
                match mode {
                    KernelMode::Simd => kernels::matmul_simd(am, wm, &mut out),
                    _ => kernels::matmul_blocked(am, wm, &mut out),
                }
            } else {
                // Stacked activation × shared weight: one lane-windowed
                // matmul per request, each in the sequential kernel's
                // exact tile/summation order.
                assert_eq!(w.space, Space::W, "batched DMM needs an unstacked weight");
                let k = wm.rows;
                for l in 0..batch {
                    match mode {
                        KernelMode::Simd => {
                            kernels::matmul_simd_lane(am, l * k, k, wm, &mut out, l * wm.cols)
                        }
                        _ => kernels::matmul_blocked_lane(
                            am,
                            l * k,
                            k,
                            wm,
                            &mut out,
                            l * wm.cols,
                        ),
                    }
                }
            }
            out
        }
        _ => panic!("not a compute instruction: {}", i.render()),
    }
}

/// The pre-kernel-layer compute path, preserved verbatim: naive
/// zero-skipping matmul, per-element `get`/`set` loops, and a fresh
/// allocation per result. This is the golden reference the differential
/// tests diff [`KernelMode::Blocked`] and [`KernelMode::Simd`] against —
/// do not "optimise" it.
fn compute_instr_naive(
    i: &Instr,
    rows: usize,
    weights: &[Option<Matrix>],
    s: Option<&[Option<Matrix>]>,
    e: Option<&[Option<Matrix>]>,
    d: &[Option<Matrix>],
    batch: usize,
) -> Matrix {
    // Batched lane windows mirror `compute_instr_kernel`'s: W operands
    // are unstacked (offset 0), everything else offsets by lane. Each
    // lane's element order matches the unbatched loops exactly.
    let lane_off = |sym: &Sym, l: usize, w: usize| if sym.space == Space::W { 0 } else { l * w };
    match i {
        Instr::Elw {
            op,
            a,
            b,
            broadcast_b,
            cols,
            ..
        } => {
            let cols = *cols as usize;
            let am = look_operand(a, weights, s, e, d);
            let mut out = Matrix::zeros(rows, cols * batch);
            match b {
                None => {
                    for r in 0..rows {
                        for l in 0..batch {
                            let ao = lane_off(a, l, cols);
                            for c in 0..cols {
                                out.set(r, l * cols + c, apply_unary(*op, am.get(r, ao + c)));
                            }
                        }
                    }
                }
                Some(bs) => {
                    let bm = look_operand(bs, weights, s, e, d);
                    for r in 0..rows {
                        let br = if *broadcast_b { 0 } else { r };
                        for l in 0..batch {
                            let ao = lane_off(a, l, cols);
                            let bo = lane_off(bs, l, cols);
                            for c in 0..cols {
                                out.set(
                                    r,
                                    l * cols + c,
                                    apply_binary(*op, am.get(r, ao + c), bm.get(br, bo + c)),
                                );
                            }
                        }
                    }
                }
            }
            out
        }
        Instr::RowScale { a, scale, cols, .. } => {
            let cols = *cols as usize;
            let am = look_operand(a, weights, s, e, d);
            let sm = look_operand(scale, weights, s, e, d);
            let mut out = Matrix::zeros(rows, cols * batch);
            for r in 0..rows {
                for l in 0..batch {
                    let f = if scale.space == Space::W {
                        sm.get(r, 0)
                    } else {
                        sm.get(r, l)
                    };
                    let ao = lane_off(a, l, cols);
                    for c in 0..cols {
                        out.set(r, l * cols + c, am.get(r, ao + c) * f);
                    }
                }
            }
            out
        }
        Instr::Concat {
            a, b, cols_a, cols_b, ..
        } => {
            let (ca, cb) = (*cols_a as usize, *cols_b as usize);
            let am = look_operand(a, weights, s, e, d);
            let bm = look_operand(b, weights, s, e, d);
            let mut out = Matrix::zeros(rows, (ca + cb) * batch);
            for r in 0..rows {
                let orow = out.row_mut(r);
                for l in 0..batch {
                    let ao = lane_off(a, l, ca);
                    let bo = lane_off(b, l, cb);
                    let base = l * (ca + cb);
                    orow[base..base + ca].copy_from_slice(&am.row(r)[ao..ao + ca]);
                    orow[base + ca..base + ca + cb].copy_from_slice(&bm.row(r)[bo..bo + cb]);
                }
            }
            out
        }
        Instr::Dmm { a, w, .. } => {
            let am = look_operand(a, weights, s, e, d);
            let wm = look_operand(w, weights, s, e, d);
            if batch == 1 {
                kernels::matmul_naive(am, wm)
            } else {
                assert_eq!(w.space, Space::W, "batched DMM needs an unstacked weight");
                let k = wm.rows;
                let mut out = Matrix::zeros(am.rows, wm.cols * batch);
                for l in 0..batch {
                    kernels::matmul_naive_lane(am, l * k, k, wm, &mut out, l * wm.cols);
                }
                out
            }
        }
        _ => panic!("not a compute instruction: {}", i.render()),
    }
}

fn instr_rows(i: &Instr) -> Dim {
    match i {
        Instr::Elw { rows, .. }
        | Instr::RowScale { rows, .. }
        | Instr::Concat { rows, .. }
        | Instr::Dmm { rows, .. } => *rows,
        Instr::Scatter { .. } | Instr::Gather { .. } | Instr::FusedGather { .. } => Dim::E,
        Instr::Ld { rows, .. } | Instr::St { rows, .. } => *rows,
    }
}
