//! Functional executor: interprets a compiled PLOF program over a
//! partitioned graph. The execution order is not defined here — the
//! executor is a [`PhaseVisitor`] over [`sched::PartitionWalk`], the
//! same canonical Alg 2 traversal the cycle simulator drives through.
//!
//! Two performance properties mirror the hardware:
//!
//! * **Partition-level multi-threading in software**: shards within an
//!   interval are independent (paper §IV-C), so their GatherPhases run
//!   across a scoped-thread worker pool (default width = the
//!   partitioning's simulated sThread count). Each shard produces
//!   *partial* gather accumulators that are merged in canonical shard
//!   order after the pool drains, so the output is bit-identical for
//!   every worker count — including the forced single-worker mode the
//!   differential tests pin.
//! * **Dense slot arenas**: symbols and DRAM arrays are addressed by
//!   `Vec` index (`Program::slot_layout`), not by hashing `Sym`/`DataRef`
//!   per instruction.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::exec::reference::{apply_binary, apply_unary};
use crate::exec::{weights, Matrix};
use crate::isa::{
    DataRef, Dim, Instr, PhaseGroup, Program, Reduce, ScatterDir, SlotLayout, Space, Sym,
};
use crate::partition::{Interval, Partitions, Shard};
use crate::sched::{PartitionWalk, PhaseVisitor, StepCtx, Traced, WalkStep};

/// Functional executor over one (program, partitions) pair.
pub struct Executor<'a> {
    program: &'a Program,
    parts: &'a Partitions,
    layout: SlotLayout,
    /// Off-chip storage arena indexed by [`DataRef::slot`]: vertex arrays
    /// are `[N, cols]`, edge arrays `[M, cols]`.
    dram: Vec<Option<Matrix>>,
    /// Weight arena indexed by W-symbol id.
    weights: Vec<Option<Matrix>>,
    /// GatherPhase worker-pool width (the software sThread count).
    workers: usize,
    /// Live state of the interval currently being walked.
    iv: Option<IntervalState>,
    /// Shard indices queued by `gather_shard`, drained at `end_gather`.
    pending: Vec<usize>,
}

impl<'a> Executor<'a> {
    pub fn new(program: &'a Program, parts: &'a Partitions) -> Self {
        let layout = program.slot_layout();
        let mut w = vec![None; layout.w];
        for wi in &program.weights {
            w[wi.sym.id as usize] = Some(weights::init_weight(wi.seed, wi.rows, wi.cols));
        }
        Executor {
            program,
            parts,
            layout,
            dram: vec![None; layout.dram],
            weights: w,
            workers: parts.config.num_sthreads.max(1) as usize,
            iv: None,
            pending: Vec::new(),
        }
    }

    /// Override the GatherPhase worker-pool width. Defaults to the
    /// partitioning's simulated sThread count; `1` forces the serial
    /// path. Outputs are bit-identical across widths.
    pub fn with_workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// The effective worker-pool width.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run the whole program. `x` is `[N, in_dim]`; `degree` the in-degree
    /// column used by `DataRef::Degree`.
    pub fn run(&mut self, x: &Matrix, degree: &Matrix) -> Matrix {
        self.seed_inputs(x, degree);
        PartitionWalk::new(self.program, self.parts).drive(&mut *self);
        self.take_output()
    }

    /// Like [`Executor::run`], additionally recording the walker's
    /// `(group, interval, shard, phase)` trace — the order-equivalence
    /// witness the scheduler tests compare against the simulator's.
    pub fn run_traced(&mut self, x: &Matrix, degree: &Matrix) -> (Matrix, Vec<WalkStep>) {
        self.seed_inputs(x, degree);
        let walk = PartitionWalk::new(self.program, self.parts);
        let mut traced = Traced::new(&mut *self);
        walk.drive(&mut traced);
        let steps = traced.into_steps();
        (self.take_output(), steps)
    }

    fn seed_inputs(&mut self, x: &Matrix, degree: &Matrix) {
        assert_eq!(x.rows, self.parts.num_vertices);
        assert_eq!(x.cols as u32, self.program.in_dim);
        self.dram = vec![None; self.layout.dram];
        self.dram[DataRef::Input.slot()] = Some(x.clone());
        self.dram[DataRef::Degree.slot()] = Some(degree.clone());
    }

    fn take_output(&mut self) -> Matrix {
        self.dram[self.output_ref().slot()]
            .clone()
            .unwrap_or_else(|| panic!("program never stored its output"))
    }

    /// The DataRef holding the final result: the last `ST.D` of the last
    /// group's ApplyPhase.
    pub fn output_ref(&self) -> DataRef {
        self.program
            .groups
            .last()
            .and_then(|g| {
                g.apply.iter().rev().find_map(|i| match i {
                    Instr::St { data, .. } => Some(*data),
                    _ => None,
                })
            })
            .expect("last group must store the result")
    }

    // ---- interval-phase execution (Scatter / Apply) --------------------------

    fn exec_interval_instr(&mut self, i: &Instr, iv: &mut IntervalState) {
        let v = iv.len();
        match i {
            Instr::Ld { sym, data, cols, .. } => {
                let src = self.dram[data.slot()]
                    .as_ref()
                    .unwrap_or_else(|| panic!("LD of unwritten {data}"));
                let mut m = Matrix::zeros(v, *cols as usize);
                for (r, gv) in (iv.begin..iv.end).enumerate() {
                    m.row_mut(r).copy_from_slice(src.row(gv));
                }
                iv.d[sym.id as usize] = Some(m);
            }
            Instr::St { sym, data, cols, .. } => {
                let slot = data.slot();
                if self.dram[slot].is_none() {
                    self.dram[slot] =
                        Some(Matrix::zeros(self.parts.num_vertices, *cols as usize));
                }
                let m = iv.d[sym.id as usize]
                    .as_ref()
                    .unwrap_or_else(|| panic!("ST of undefined {sym}"));
                let dst = self.dram[slot].as_mut().unwrap();
                for (r, gv) in (iv.begin..iv.end).enumerate() {
                    dst.row_mut(gv).copy_from_slice(m.row(r));
                }
            }
            _ => {
                let out = compute_instr(i, v, &self.weights, None, None, &iv.d);
                iv.d[i.def().expect("compute defines").id as usize] = Some(out);
            }
        }
    }

    // ---- shard-phase execution (Gather) ---------------------------------------

    /// Drain the interval's queued shards through the worker pool, then
    /// merge their partial results in canonical shard order. However the
    /// workers raced, the merge sees the same partials in the same order,
    /// so any pool width is bit-identical to a single worker.
    fn run_pending_shards(&mut self, group: &PhaseGroup) {
        let pending = std::mem::take(&mut self.pending);
        if pending.is_empty() {
            return;
        }
        let mut iv = self.iv.take().expect("interval state");
        let outs: Vec<ShardOut> = {
            let env = ShardEnv {
                layout: &self.layout,
                weights: &self.weights,
                dram: &self.dram,
                iv: &iv,
                parts: self.parts,
                gather: &group.gather[..],
            };
            let workers = self.workers.min(pending.len());
            if workers <= 1 {
                pending.iter().map(|&si| env.run_shard(si)).collect()
            } else {
                let cells: Vec<Mutex<Option<ShardOut>>> =
                    pending.iter().map(|_| Mutex::new(None)).collect();
                let next = AtomicUsize::new(0);
                std::thread::scope(|scope| {
                    for _ in 0..workers {
                        scope.spawn(|| loop {
                            // Dynamic assignment: the next shard goes to
                            // whichever worker frees first (the software
                            // analogue of the phase scheduler, §V-B2).
                            let k = next.fetch_add(1, Ordering::Relaxed);
                            if k >= pending.len() {
                                break;
                            }
                            let out = env.run_shard(pending[k]);
                            *cells[k].lock().unwrap() = Some(out);
                        });
                    }
                });
                cells
                    .into_iter()
                    .map(|c| c.into_inner().unwrap().expect("worker filled its slot"))
                    .collect()
            }
        };
        for (&si, out) in pending.iter().zip(outs) {
            self.merge_shard(&mut iv, si, out);
        }
        self.iv = Some(iv);
    }

    /// Fold one shard's partial accumulators and spills into the interval
    /// state. Called in canonical shard order only.
    fn merge_shard(&mut self, iv: &mut IntervalState, shard_idx: usize, out: ShardOut) {
        let shard = &self.parts.shards[shard_idx];
        for (slot, p) in out.partials {
            let acc = iv.accs[slot]
                .as_mut()
                .expect("gather accumulator pre-created by scatter_phase");
            // The partial covers only the shard's dst window, and rows it
            // never touched (count 0) merge as identity — so the merge is
            // O(touched rows), not O(interval height).
            for (r, &cnt) in p.acc.counts.iter().enumerate() {
                if cnt == 0 {
                    continue;
                }
                let ar = p.base + r;
                let orow = acc.m.row_mut(ar);
                let prow = p.acc.m.row(r);
                match acc.reduce {
                    Reduce::Sum | Reduce::Mean => {
                        for (o, &x) in orow.iter_mut().zip(prow) {
                            *o += x;
                        }
                    }
                    Reduce::Max => {
                        for (o, &x) in orow.iter_mut().zip(prow) {
                            *o = o.max(x);
                        }
                    }
                }
                acc.counts[ar] += cnt;
            }
        }
        for (slot, m) in out.spills {
            // ST.E rows land at canonical edge ids; shards own disjoint
            // edge sets, so the order is immaterial for the values.
            if self.dram[slot].is_none() {
                self.dram[slot] = Some(Matrix::zeros(self.parts.num_edges, m.cols));
            }
            let dst = self.dram[slot].as_mut().unwrap();
            for (r, e) in shard.edges.iter().enumerate() {
                dst.row_mut(e.edge_id as usize).copy_from_slice(m.row(r));
            }
        }
    }
}

impl PhaseVisitor for Executor<'_> {
    fn begin_interval(&mut self, cx: &StepCtx) {
        self.iv = Some(IntervalState::new(cx.interval, &self.layout));
        self.pending.clear();
    }

    fn scatter_phase(&mut self, cx: &StepCtx) {
        let mut iv = self.iv.take().expect("interval state");
        for i in &cx.group.scatter {
            self.exec_interval_instr(i, &mut iv);
        }
        // Gather accumulators exist per interval even when the interval
        // has no shards (isolated destination ranges).
        for i in &cx.group.gather {
            match i {
                Instr::Gather { reduce, dst, cols, .. }
                | Instr::FusedGather { reduce, dst, cols, .. } => {
                    iv.ensure_acc(*dst, *reduce, *cols as usize);
                }
                _ => {}
            }
        }
        self.iv = Some(iv);
    }

    fn gather_shard(&mut self, _cx: &StepCtx, shard_idx: usize, _shard: &Shard) {
        // Schedule point only — the pool drains at `end_gather` so shards
        // overlap while the merge order stays canonical.
        self.pending.push(shard_idx);
    }

    fn end_gather(&mut self, cx: &StepCtx) {
        self.run_pending_shards(cx.group);
    }

    fn apply_phase(&mut self, cx: &StepCtx) {
        let mut iv = self.iv.take().expect("interval state");
        // Mean finalisation + empty-row convention.
        iv.finalize_gathers();
        for i in &cx.group.apply {
            self.exec_interval_instr(i, &mut iv);
        }
        self.iv = Some(iv);
    }

    fn end_interval(&mut self, _cx: &StepCtx) {
        self.iv = None;
    }
}

/// Per-interval state: resident D slots + gather accumulators.
struct IntervalState {
    begin: usize,
    end: usize,
    /// DstBuffer arena, indexed by D-symbol id.
    d: Vec<Option<Matrix>>,
    /// Gather accumulators, indexed by D-symbol id; moved into `d` by
    /// `finalize_gathers` once every shard's partials merged.
    accs: Vec<Option<Acc>>,
}

impl IntervalState {
    fn new(iv: &Interval, layout: &SlotLayout) -> Self {
        IntervalState {
            begin: iv.begin as usize,
            end: iv.end as usize,
            d: vec![None; layout.d],
            accs: vec![None; layout.d],
        }
    }

    fn len(&self) -> usize {
        self.end - self.begin
    }

    /// Pre-create a gather accumulator (first touch in this interval
    /// zeroes it — mirrors the hardware's phase-scheduler reset).
    fn ensure_acc(&mut self, dst: Sym, reduce: Reduce, cols: usize) {
        let slot = dst.id as usize;
        if self.accs[slot].is_none() {
            self.accs[slot] = Some(Acc::new(reduce, self.len(), cols));
        }
    }

    /// Post-merge fixups: Mean division and the zero-for-empty convention.
    fn finalize_gathers(&mut self) {
        for (acc_slot, d_slot) in self.accs.iter_mut().zip(self.d.iter_mut()) {
            if let Some(mut acc) = acc_slot.take() {
                for (r, &cnt) in acc.counts.iter().enumerate() {
                    if cnt == 0 {
                        acc.m.row_mut(r).fill(0.0);
                    } else if acc.reduce == Reduce::Mean {
                        let inv = 1.0 / cnt as f32;
                        for v in acc.m.row_mut(r) {
                            *v *= inv;
                        }
                    }
                }
                *d_slot = Some(acc.m);
            }
        }
    }
}

/// A gather accumulator (interval-level or per-shard partial).
struct Acc {
    reduce: Reduce,
    m: Matrix,
    counts: Vec<u32>,
}

impl Acc {
    fn new(reduce: Reduce, rows: usize, cols: usize) -> Self {
        let m = match reduce {
            Reduce::Sum | Reduce::Mean => Matrix::zeros(rows, cols),
            Reduce::Max => Matrix::filled(rows, cols, f32::NEG_INFINITY),
        };
        Acc {
            reduce,
            m,
            counts: vec![0; rows],
        }
    }
}

/// A shard's partial gather accumulator: an [`Acc`] covering only the
/// shard's destination window, placed at interval-local row `base`.
struct Partial {
    base: usize,
    acc: Acc,
}

/// What one shard's GatherPhase produced: partial gather accumulators
/// (merged in shard order) and queued ST.E spills.
struct ShardOut {
    /// `(D slot, windowed partial)` in first-touch order.
    partials: Vec<(usize, Partial)>,
    /// `(DRAM slot, [shard_edges, cols] rows)` to write at canonical ids.
    spills: Vec<(usize, Matrix)>,
}

impl ShardOut {
    fn partial(
        &mut self,
        slot: usize,
        reduce: Reduce,
        base: usize,
        rows: usize,
        cols: usize,
    ) -> &mut Acc {
        if let Some(pos) = self.partials.iter().position(|(s, _)| *s == slot) {
            &mut self.partials[pos].1.acc
        } else {
            self.partials.push((
                slot,
                Partial {
                    base,
                    acc: Acc::new(reduce, rows, cols),
                },
            ));
            &mut self.partials.last_mut().unwrap().1.acc
        }
    }
}

/// Read-only view shared by the GatherPhase workers.
struct ShardEnv<'x> {
    layout: &'x SlotLayout,
    weights: &'x [Option<Matrix>],
    dram: &'x [Option<Matrix>],
    iv: &'x IntervalState,
    parts: &'x Partitions,
    gather: &'x [Instr],
}

impl ShardEnv<'_> {
    fn run_shard(&self, shard_idx: usize) -> ShardOut {
        let shard = &self.parts.shards[shard_idx];
        let span = shard.dst_span();
        let mut s: Vec<Option<Matrix>> = vec![None; self.layout.s];
        let mut e: Vec<Option<Matrix>> = vec![None; self.layout.e];
        let mut out = ShardOut {
            partials: Vec::new(),
            spills: Vec::new(),
        };
        for i in self.gather {
            self.exec_shard_instr(i, shard, span, &mut s, &mut e, &mut out);
        }
        out
    }

    /// Get-or-create the shard's partial accumulator for `dst`, sized to
    /// the shard's destination window within the interval.
    fn windowed_partial<'o>(
        &self,
        out: &'o mut ShardOut,
        dst: Sym,
        reduce: Reduce,
        span: Option<(u32, u32)>,
        cols: usize,
    ) -> &'o mut Acc {
        let (lo, hi) = span.expect("edgeless shards return before accumulating");
        let base = lo as usize - self.iv.begin;
        let rows = (hi - lo + 1) as usize;
        out.partial(dst.id as usize, reduce, base, rows, cols)
    }

    fn exec_shard_instr(
        &self,
        i: &Instr,
        shard: &Shard,
        span: Option<(u32, u32)>,
        s: &mut [Option<Matrix>],
        e: &mut [Option<Matrix>],
        out: &mut ShardOut,
    ) {
        let iv = self.iv;
        match i {
            Instr::Ld { sym, data, cols, .. } => {
                let src = self.dram[data.slot()]
                    .as_ref()
                    .unwrap_or_else(|| panic!("LD of unwritten {data}"));
                match sym.space {
                    Space::S => {
                        let mut m = Matrix::zeros(shard.num_src(), *cols as usize);
                        for (r, &gv) in shard.sources.iter().enumerate() {
                            m.row_mut(r).copy_from_slice(src.row(gv as usize));
                        }
                        s[sym.id as usize] = Some(m);
                    }
                    Space::E => {
                        let mut m = Matrix::zeros(shard.num_edges(), *cols as usize);
                        for (r, ed) in shard.edges.iter().enumerate() {
                            m.row_mut(r).copy_from_slice(src.row(ed.edge_id as usize));
                        }
                        e[sym.id as usize] = Some(m);
                    }
                    _ => panic!("GatherPhase LD of {sym}"),
                }
            }
            Instr::St { sym, data, .. } => {
                // ST.E — spill edge rows; the writes are queued and land
                // at canonical edge ids during the deterministic merge.
                let m = e[sym.id as usize]
                    .as_ref()
                    .unwrap_or_else(|| panic!("ST of undefined {sym}"))
                    .clone();
                out.spills.push((data.slot(), m));
            }
            Instr::Scatter { dir, dst, src, cols } => {
                let mut m = Matrix::zeros(shard.num_edges(), *cols as usize);
                match dir {
                    ScatterDir::SrcToEdge => {
                        let sm = s[src.id as usize]
                            .as_ref()
                            .unwrap_or_else(|| panic!("S operand {src} missing"));
                        for (r, ed) in shard.edges.iter().enumerate() {
                            m.row_mut(r).copy_from_slice(sm.row(ed.src_slot as usize));
                        }
                    }
                    ScatterDir::DstToEdge => {
                        let dm = iv.d[src.id as usize]
                            .as_ref()
                            .unwrap_or_else(|| panic!("D operand {src} missing"));
                        for (r, ed) in shard.edges.iter().enumerate() {
                            let local = (ed.dst - iv.begin as u32) as usize;
                            m.row_mut(r).copy_from_slice(dm.row(local));
                        }
                    }
                }
                e[dst.id as usize] = Some(m);
            }
            Instr::FusedGather {
                reduce,
                dst,
                src,
                scale,
                cols,
            } => {
                // An edgeless shard contributes nothing (the interval-level
                // accumulator was pre-created by `scatter_phase`).
                let Some((lo, _)) = span else { return };
                let scale_col: Option<Vec<f32>> = scale.map(|sc| {
                    let m = e[sc.id as usize]
                        .as_ref()
                        .unwrap_or_else(|| panic!("E operand {sc} missing"));
                    (0..shard.num_edges()).map(|r| m.get(r, 0)).collect()
                });
                let sm = s[src.id as usize]
                    .as_ref()
                    .unwrap_or_else(|| panic!("S operand {src} missing"));
                let acc = self.windowed_partial(out, *dst, *reduce, span, *cols as usize);
                for (r, ed) in shard.edges.iter().enumerate() {
                    let local = (ed.dst - lo) as usize;
                    acc.counts[local] += 1;
                    let row = sm.row(ed.src_slot as usize);
                    let f = scale_col.as_ref().map_or(1.0, |c| c[r]);
                    let orow = acc.m.row_mut(local);
                    match reduce {
                        Reduce::Sum | Reduce::Mean => {
                            for (o, &x) in orow.iter_mut().zip(row) {
                                *o += x * f;
                            }
                        }
                        Reduce::Max => {
                            for (o, &x) in orow.iter_mut().zip(row) {
                                *o = o.max(x * f);
                            }
                        }
                    }
                }
            }
            Instr::Gather {
                reduce,
                dst,
                src,
                cols,
            } => {
                let Some((lo, _)) = span else { return };
                let ev = e[src.id as usize]
                    .as_ref()
                    .unwrap_or_else(|| panic!("E operand {src} missing"));
                let acc = self.windowed_partial(out, *dst, *reduce, span, *cols as usize);
                for (r, ed) in shard.edges.iter().enumerate() {
                    let local = (ed.dst - lo) as usize;
                    acc.counts[local] += 1;
                    let row = ev.row(r);
                    let orow = acc.m.row_mut(local);
                    match reduce {
                        Reduce::Sum | Reduce::Mean => {
                            for (o, &x) in orow.iter_mut().zip(row) {
                                *o += x;
                            }
                        }
                        Reduce::Max => {
                            for (o, &x) in orow.iter_mut().zip(row) {
                                *o = o.max(x);
                            }
                        }
                    }
                }
            }
            _ => {
                // Shard-side compute: rows decode against the shard.
                let rows_dim = instr_rows(i);
                let rows = rows_dim.decode(iv.len(), shard.num_src(), shard.num_edges());
                let m = compute_instr(i, rows, self.weights, Some(&*s), Some(&*e), &iv.d);
                let def = i.def().expect("compute defines");
                match def.space {
                    Space::S => s[def.id as usize] = Some(m),
                    Space::E => e[def.id as usize] = Some(m),
                    _ => panic!("GatherPhase compute must write S/E"),
                }
            }
        }
    }
}

/// Evaluate a compute instruction against slot-arena operand sources:
/// W from `weights`, S/E from the shard arenas (GatherPhase only), D
/// from the interval arena.
fn compute_instr(
    i: &Instr,
    rows: usize,
    weights: &[Option<Matrix>],
    s: Option<&[Option<Matrix>]>,
    e: Option<&[Option<Matrix>]>,
    d: &[Option<Matrix>],
) -> Matrix {
    let look = |sym: &Sym| -> &Matrix {
        let arena: &[Option<Matrix>] = match sym.space {
            Space::W => weights,
            Space::S => s.unwrap_or_else(|| panic!("S operand {sym} outside GatherPhase")),
            Space::E => e.unwrap_or_else(|| panic!("E operand {sym} outside GatherPhase")),
            Space::D => d,
        };
        arena[sym.id as usize]
            .as_ref()
            .unwrap_or_else(|| panic!("operand {sym} missing"))
    };
    match i {
        Instr::Elw {
            op,
            a,
            b,
            broadcast_b,
            cols,
            ..
        } => {
            let am = look(a);
            let mut out = Matrix::zeros(rows, *cols as usize);
            match b {
                None => {
                    for r in 0..rows {
                        for c in 0..*cols as usize {
                            out.set(r, c, apply_unary(*op, am.get(r, c)));
                        }
                    }
                }
                Some(bs) => {
                    let bm = look(bs);
                    for r in 0..rows {
                        let br = if *broadcast_b { 0 } else { r };
                        for c in 0..*cols as usize {
                            out.set(r, c, apply_binary(*op, am.get(r, c), bm.get(br, c)));
                        }
                    }
                }
            }
            out
        }
        Instr::RowScale { a, scale, cols, .. } => {
            let am = look(a);
            let sm = look(scale);
            let mut out = Matrix::zeros(rows, *cols as usize);
            for r in 0..rows {
                let f = sm.get(r, 0);
                for c in 0..*cols as usize {
                    out.set(r, c, am.get(r, c) * f);
                }
            }
            out
        }
        Instr::Concat {
            a, b, cols_a, cols_b, ..
        } => {
            let am = look(a);
            let bm = look(b);
            let mut out = Matrix::zeros(rows, (*cols_a + *cols_b) as usize);
            for r in 0..rows {
                out.row_mut(r)[..*cols_a as usize].copy_from_slice(am.row(r));
                out.row_mut(r)[*cols_a as usize..].copy_from_slice(bm.row(r));
            }
            out
        }
        Instr::Dmm { a, w, .. } => {
            let am = look(a);
            let wm = look(w);
            am.matmul(wm)
        }
        _ => panic!("not a compute instruction: {}", i.render()),
    }
}

fn instr_rows(i: &Instr) -> Dim {
    match i {
        Instr::Elw { rows, .. }
        | Instr::RowScale { rows, .. }
        | Instr::Concat { rows, .. }
        | Instr::Dmm { rows, .. } => *rows,
        Instr::Scatter { .. } | Instr::Gather { .. } | Instr::FusedGather { .. } => Dim::E,
        Instr::Ld { rows, .. } | Instr::St { rows, .. } => *rows,
    }
}
