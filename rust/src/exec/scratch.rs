//! Scratch arenas: slot-keyed buffer pools that make the executor
//! allocation-free in steady state.
//!
//! Every `Ld`, `Scatter`, compute instruction and gather accumulator used
//! to allocate a fresh [`Matrix`] per shard / per interval. The pools here
//! recycle those buffers: a matrix retired at the end of an interval (or a
//! shard) goes back into the pool slot of the symbol that owned it, and
//! the next interval's instruction for the same symbol takes it out again.
//! After the first interval of each group the demanded sizes repeat (or
//! shrink, for the ragged last interval), so every `take` is a capacity
//! hit and the walk performs no further heap allocation — exact under
//! deterministic (single-worker) shard assignment, where
//! `exec::tests::scratch_arena_steady_state_no_new_misses` pins it via
//! the hit/miss counters; under the racy multi-worker pool each worker's
//! private arenas warm independently, so misses taper instead of
//! stopping at a hard boundary.
//!
//! Layout: one [`Pool`] slot per symbol id (sized from
//! [`SlotLayout`](crate::isa::SlotLayout)), each slot a small stack of
//! buffers — a stack because one slot can transiently own two buffers
//! (e.g. a D symbol that is overwritten within an interval). Interval
//! pipelining (`PipelineMode::Interval`) leans on the same property: two
//! `IntervalState`s are live at once — the active interval and the
//! standby being prepared under its gather drain — so the interval pools
//! run two deep per slot in steady state, and the no-new-misses
//! invariant holds unchanged once the first *two* intervals of a group
//! have sized them (pinned by
//! `exec::tests::pipelined_scratch_arena_steady_state_no_new_misses`).
//! [`WorkerScratch`] is private to one GatherPhase worker thread, so the
//! pools need no synchronisation beyond the per-worker `Mutex` the
//! executor holds them in.
//!
//! The pools are *size-agnostic*: batched runs (`Executor::try_run_with`
//! with B > 1 inputs) simply demand `B·cols`-wide buffers through the
//! same slots, and best-fit selection plus capacity-based regrowth make
//! the transition between batch sizes just another warm-up — no
//! batch-keyed arenas needed.

use crate::exec::matrix::Matrix;
use crate::isa::SlotLayout;

/// Aggregate hit/miss counters across one or more pools. A *miss* is a
/// `take` that had to allocate (empty slot) or regrow (buffer capacity
/// smaller than the request); in steady state misses stop growing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScratchStats {
    pub hits: u64,
    pub misses: u64,
}

impl ScratchStats {
    pub fn merge(&mut self, other: ScratchStats) {
        self.hits += other.hits;
        self.misses += other.misses;
    }

    /// Fraction of takes served without allocating, in `[0, 1]`.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }
}

/// A slot-keyed pool of `Vec<T>` buffers.
///
/// Each slot tracks how many of its buffers are currently *loaned out*
/// (taken, not yet given back). A `give` when nothing is on loan means
/// the buffer did not originate here — e.g. `KernelMode::Naive` compute
/// results, which allocate outside the pools by design — and is dropped
/// instead of stored, so foreign buffers cannot grow the pool without
/// bound (one fresh matrix per compute slot per interval/shard, forever).
#[derive(Clone, Debug, Default)]
pub struct Pool<T> {
    slots: Vec<Vec<Vec<T>>>,
    /// Buffers taken and not yet returned, per slot.
    loaned: Vec<usize>,
    hits: u64,
    misses: u64,
}

impl<T: Copy + Default> Pool<T> {
    pub fn new(slots: usize) -> Self {
        Pool {
            slots: (0..slots).map(|_| Vec::new()).collect(),
            loaned: vec![0; slots],
            hits: 0,
            misses: 0,
        }
    }

    /// Take a buffer of exactly `len` elements whose contents are
    /// *unspecified* (stale data or `T::default()` tail) — for writers
    /// that overwrite every element (LD row copies, ELW, DMM, ...).
    ///
    /// Selection is *best-fit* (smallest pooled buffer whose capacity
    /// covers `len`), not LIFO: shard windows and interval heights vary,
    /// and a repeat run pairs its demands with pooled buffers in a
    /// different order than the run that grew them — best-fit guarantees
    /// that once one pass has sized the pool, every later identical
    /// demand sequence is served without regrowing (the steady-state
    /// property the executor test pins). Slots hold a handful of buffers,
    /// so the scan is trivial.
    pub fn take_any(&mut self, slot: usize, len: usize) -> Vec<T> {
        self.loaned[slot] += 1;
        let stack = &mut self.slots[slot];
        if stack.is_empty() {
            self.misses += 1;
            return vec![T::default(); len];
        }
        let mut pick = 0;
        for (i, v) in stack.iter().enumerate() {
            let better_fit = v.capacity() >= len
                && (stack[pick].capacity() < len || v.capacity() < stack[pick].capacity());
            // While nothing fits, track the largest buffer — regrowing it
            // wastes the least.
            let larger_fallback =
                stack[pick].capacity() < len && v.capacity() > stack[pick].capacity();
            if better_fit || larger_fallback {
                pick = i;
            }
        }
        let mut v = stack.swap_remove(pick);
        if v.capacity() >= len {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        if v.len() > len {
            v.truncate(len);
        } else {
            v.resize(len, T::default());
        }
        v
    }

    /// Take a buffer of `len` elements, every element set to `fill` — for
    /// accumulators (gather partials, counts).
    pub fn take_filled(&mut self, slot: usize, len: usize, fill: T) -> Vec<T> {
        let mut v = self.take_any(slot, len);
        v.fill(fill);
        v
    }

    /// Return a buffer to its slot for reuse. A buffer handed in while
    /// nothing is on loan did not come from this pool (naive-mode compute
    /// results retire through the same code paths as pooled matrices) and
    /// is dropped, keeping the pool bounded by its own loan count.
    pub fn give(&mut self, slot: usize, v: Vec<T>) {
        if self.loaned[slot] == 0 {
            return;
        }
        self.loaned[slot] -= 1;
        self.slots[slot].push(v);
    }

    pub fn stats(&self) -> ScratchStats {
        ScratchStats {
            hits: self.hits,
            misses: self.misses,
        }
    }
}

impl Pool<f32> {
    /// [`Pool::take_any`] wrapped as a `rows × cols` matrix.
    pub fn take_matrix_any(&mut self, slot: usize, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take_any(slot, rows * cols))
    }

    /// [`Pool::take_filled`] wrapped as a `rows × cols` matrix.
    pub fn take_matrix_filled(&mut self, slot: usize, rows: usize, cols: usize, fill: f32) -> Matrix {
        Matrix::from_vec(rows, cols, self.take_filled(slot, rows * cols, fill))
    }
}

/// Interval-side scratch (iThread): D-symbol matrices and gather
/// accumulators, keyed by D slot. Accumulator matrices and plain D
/// matrices share `m` — `finalize_gathers` moves an accumulator's matrix
/// into the D arena, and the buffer must flow back into the same pool at
/// the next interval reset regardless of which role it last played.
#[derive(Debug)]
pub struct IntervalScratch {
    /// `[interval height, cols]` f32 buffers, keyed by D-symbol id.
    pub m: Pool<f32>,
    /// Gather-count columns, keyed by D-symbol id.
    pub counts: Pool<u32>,
}

impl IntervalScratch {
    pub fn new(layout: &SlotLayout) -> Self {
        IntervalScratch {
            m: Pool::new(layout.d),
            counts: Pool::new(layout.d),
        }
    }

    pub fn stats(&self) -> ScratchStats {
        let mut s = self.m.stats();
        s.merge(self.counts.stats());
        s
    }
}

/// Per-worker shard-side scratch (one sThread): S/E matrix pools,
/// partial gather-accumulator pools, and the reusable live slot arenas
/// of `run_shard`. (`ShardOut` itself is *not* pooled — its three small
/// container `Vec`s are the one remaining per-shard heap touch.) Owned
/// by exactly one worker while the pool is running; the executor returns
/// merged buffers to the worker they came from, so pool contents stay
/// thread-private.
#[derive(Debug)]
pub struct WorkerScratch {
    /// `[shard sources, cols]` buffers keyed by S-symbol id.
    pub s: Pool<f32>,
    /// `[shard edges, cols]` buffers keyed by E-symbol id (also receives
    /// ST.E spill buffers back after the merge writes them to DRAM).
    pub e: Pool<f32>,
    /// Partial gather-accumulator matrices keyed by D-symbol id.
    pub pm: Pool<f32>,
    /// Partial gather-count columns keyed by D-symbol id.
    pub pc: Pool<u32>,
    /// Live S-slot arena reused across shards (cleared each shard).
    pub s_arena: Vec<Option<Matrix>>,
    /// Live E-slot arena reused across shards (cleared each shard).
    pub e_arena: Vec<Option<Matrix>>,
}

impl WorkerScratch {
    pub fn new(layout: &SlotLayout) -> Self {
        WorkerScratch {
            s: Pool::new(layout.s),
            e: Pool::new(layout.e),
            pm: Pool::new(layout.d),
            pc: Pool::new(layout.d),
            s_arena: (0..layout.s).map(|_| None).collect(),
            e_arena: (0..layout.e).map(|_| None).collect(),
        }
    }

    pub fn stats(&self) -> ScratchStats {
        let mut st = self.s.stats();
        st.merge(self.e.stats());
        st.merge(self.pm.stats());
        st.merge(self.pc.stats());
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_give_cycle_hits_after_first_miss() {
        let mut p: Pool<f32> = Pool::new(2);
        let v = p.take_any(0, 16);
        assert_eq!(v.len(), 16);
        assert_eq!(p.stats(), ScratchStats { hits: 0, misses: 1 });
        p.give(0, v);
        let v2 = p.take_any(0, 12); // smaller fits: hit
        assert_eq!(v2.len(), 12);
        assert_eq!(p.stats(), ScratchStats { hits: 1, misses: 1 });
        p.give(0, v2);
        let v3 = p.take_any(0, 64); // larger: capacity miss, buffer regrown
        assert_eq!(v3.len(), 64);
        assert_eq!(p.stats().misses, 2);
        // Slots are independent.
        let _ = p.take_any(1, 4);
        assert_eq!(p.stats().misses, 3);
    }

    #[test]
    fn take_filled_resets_contents() {
        let mut p: Pool<u32> = Pool::new(1);
        let mut v = p.take_filled(0, 4, 7);
        assert_eq!(v, vec![7; 4]);
        v[2] = 99;
        p.give(0, v);
        assert_eq!(p.take_filled(0, 4, 0), vec![0; 4]);
    }

    #[test]
    fn slots_hold_multiple_buffers() {
        let mut p: Pool<f32> = Pool::new(1);
        let a = p.take_any(0, 8);
        let b = p.take_any(0, 8); // second live buffer on the same slot
        p.give(0, a);
        p.give(0, b);
        let _ = p.take_any(0, 8);
        let _ = p.take_any(0, 8);
        assert_eq!(p.stats(), ScratchStats { hits: 2, misses: 2 });
    }

    #[test]
    fn take_is_best_fit_not_lifo() {
        // A repeat run pairs demands with pooled buffers in a different
        // order than the run that grew them; best-fit must still serve
        // (100, 90) from a pool holding capacities {100, 90} regardless
        // of give order.
        let mut p: Pool<f32> = Pool::new(1);
        let big = p.take_any(0, 100);
        let small = p.take_any(0, 90);
        p.give(0, small);
        p.give(0, big); // LIFO would hand `big` to the 90-demand below
        let first = p.take_any(0, 90);
        assert!(
            first.capacity() < 100,
            "best-fit must pick the smaller buffer, got capacity {}",
            first.capacity()
        );
        let second = p.take_any(0, 100);
        assert!(second.capacity() >= 100);
        assert_eq!(p.stats(), ScratchStats { hits: 2, misses: 2 });
    }

    #[test]
    fn matrix_take_shapes() {
        let mut p: Pool<f32> = Pool::new(1);
        let m = p.take_matrix_filled(0, 3, 4, -1.0);
        assert_eq!((m.rows, m.cols, m.data.len()), (3, 4, 12));
        assert!(m.data.iter().all(|&v| v == -1.0));
        p.give(0, m.data);
        let m2 = p.take_matrix_any(0, 2, 6);
        assert_eq!((m2.rows, m2.cols), (2, 6));
    }

    #[test]
    fn foreign_gives_are_dropped() {
        // Buffers that never came from the pool (KernelMode::Naive
        // compute results) retire through the same give() calls; the
        // pool must drop them rather than grow without bound.
        let mut p: Pool<f32> = Pool::new(1);
        p.give(0, vec![0.0; 8]);
        p.give(0, vec![0.0; 8]);
        let first = p.take_any(0, 8);
        assert_eq!(
            p.stats(),
            ScratchStats { hits: 0, misses: 1 },
            "foreign buffers must not be stored"
        );
        // With one buffer on loan, a same-sized foreign buffer may be
        // accepted in its stead (replace-then-retire interleavings swap
        // which Vec carries the slot) — but the extra give is dropped, so
        // depth stays bounded by the loan count.
        p.give(0, vec![1.0; 8]); // accepted: stands in for `first`
        p.give(0, first); // nothing on loan any more: dropped
        let again = p.take_any(0, 8);
        assert_eq!(again.len(), 8);
        assert_eq!(p.stats(), ScratchStats { hits: 1, misses: 1 });
        assert!(p.slots[0].is_empty(), "pool depth exceeded its loan count");
    }

    #[test]
    fn hit_rate_aggregates() {
        let mut s = ScratchStats { hits: 3, misses: 1 };
        s.merge(ScratchStats { hits: 1, misses: 3 });
        assert_eq!(s, ScratchStats { hits: 4, misses: 4 });
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(ScratchStats::default().hit_rate(), 0.0);
    }
}
