//! Functional execution of compiled programs.
//!
//! Two independent evaluation paths:
//!
//! * [`Executor`] interprets the *compiled ISA program* over the
//!   *partitioned* graph with real `f32` data — exercising the compiler,
//!   the partitioner and the PLOF/DSW execution semantics end to end. It
//!   drives the canonical [`sched::PartitionWalk`](crate::sched) order
//!   and runs each interval's shards across a worker pool (software
//!   partition-level multi-threading) with a deterministic merge, so the
//!   output is bit-identical at any worker count.
//! * [`reference`] interprets the *IR directly* over the whole graph with
//!   dense per-node matrices — a simple oracle that shares no code with
//!   the compiled path.
//!
//! `compile(ir) ∘ partition(g) ∘ Executor == reference(ir, g)` is the
//! core correctness property of the whole stack (tested here and, against
//! the JAX/PJRT oracle, in `rust/tests/integration_runtime.rs`).

mod executor;
mod matrix;
pub mod reference;
pub mod weights;

pub use executor::Executor;
pub use matrix::Matrix;

#[cfg(test)]
mod tests;
