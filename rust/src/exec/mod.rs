//! Functional execution of compiled programs.
//!
//! Two independent evaluation paths:
//!
//! * [`Executor`] interprets the *compiled ISA program* over the
//!   *partitioned* graph with real `f32` data — exercising the compiler,
//!   the partitioner and the PLOF/DSW execution semantics end to end. It
//!   drives the canonical [`sched::PartitionWalk`](crate::sched) order
//!   and runs each interval's shards across a worker pool (software
//!   partition-level multi-threading) with a deterministic merge, so the
//!   output is bit-identical at any worker count.
//! * [`reference`] interprets the *IR directly* over the whole graph with
//!   dense per-node matrices — a simple oracle that shares no code with
//!   the compiled path.
//!
//! `compile(ir) ∘ partition(g) ∘ Executor == reference(ir, g)` is the
//! core correctness property of the whole stack (tested here and, against
//! the JAX/PJRT oracle, in `rust/tests/integration_runtime.rs`).
//!
//! The executor's hot path is built from three support layers: [`kernels`]
//! (cache-blocked branch-free matmul + fused slice-based row kernels,
//! with explicit chunks-of-8 variants behind [`KernelMode::Simd`], all
//! bit-identical to the preserved naive loops), [`scratch`] (slot-keyed
//! buffer pools making the walk allocation-free in steady state), and
//! [`pool`] (the persistent worker pool: sThreads spawned once per
//! executor, each owning its scratch — no per-interval spawn/join and no
//! `Mutex` on the hot path). [`KernelMode::Naive`] keeps the pre-kernel
//! compute path alive purely as the differential-test reference.
//!
//! Consecutive destination intervals are pipelined by default
//! ([`PipelineMode::Interval`]): while one interval's shards drain
//! through the worker pool, the next interval's DstBuffer state is
//! prepared from a second buffer set ping-ponged through the scratch
//! pools — the functional realisation of the simulator's interval-overlap
//! timing. [`PipelineMode::Group`] extends the overlap past the gather
//! drain: a persistent prepare lane carries the prologue computes across
//! the ApplyPhase and, where the cross-group dependence gate allows,
//! across the group boundary. [`PipelineMode::Off`] preserves the
//! strictly sequential order as the golden reference of the pipelining
//! differential tests.
//!
//! Faults are isolated, not fatal: a shard job that panics fails only
//! its batch — surfaced as a typed [`PoolError`] through
//! [`Executor::try_run`] — while the pool heals itself (fresh scratch,
//! respawned worker threads at the same affinity slot) and the executor
//! stays usable for the next run, bit-identically.
//!
//! Cross-request batching rides the same walk: [`Executor::try_run_with`]
//! takes a [`RunRequest`] carrying 1..=B feature matrices and performs
//! *one* partition walk for all of them — every non-weight buffer is
//! column-stacked to `[rows, B·cols]`, so the per-interval scatter LDs,
//! gather accumulator setup and shard traversal are paid once per batch
//! instead of once per request, while per-lane windows over weight
//! operands keep each request's FP reduction order — and therefore its
//! bits — identical to a solo run. The legacy `run`/`try_run`/
//! `run_traced`/`run_profiled` surface survives as thin wrappers.

mod executor;
pub mod kernels;
mod matrix;
mod pool;
pub mod reference;
pub mod scratch;
pub mod weights;

pub use executor::{Executor, KernelMode, PipelineMode, RunOutput, RunRequest};
pub use matrix::Matrix;
pub use pool::{PoolError, PoolStats};
pub use scratch::ScratchStats;

#[cfg(test)]
mod tests;
