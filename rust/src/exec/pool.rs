//! Persistent GatherPhase worker pool.
//!
//! PR 5's executor spawned a fresh `std::thread::scope` per interval —
//! thousands of spawn/join barriers per run, and every worker's scratch
//! lived behind a `Mutex<WorkerScratch>` so the scoped closures could
//! reach it. This module replaces both with a pool that matches the
//! paper's sThread model (§V-B): workers are spawned **once per
//! `Executor`**, each *owns* its [`WorkerScratch`] outright (no lock on
//! the hot path), and interval shard batches are published to them over
//! an epoch/condvar protocol:
//!
//! * The driving thread publishes a batch (an erased `run(k, w, scratch)`
//!   closure plus its length) under the pool mutex, bumps the epoch and
//!   wakes the workers. It is then free to do *other* work — the
//!   executor runs the next interval's prepare there — before calling
//!   [`BatchTicket::finish`], which parks on the done condvar until every
//!   participating worker has signalled.
//! * Each worker processes the strided slice `k = w, w+width, …` —
//!   a static shard→worker affinity, so across intervals (and across
//!   whole reruns) the same shard positions revisit the same worker's
//!   warm scratch pools. Static assignment is also what makes the
//!   per-worker scratch hit/miss sequence deterministic, which the
//!   steady-state tests pin.
//! * Buffers the *main* thread ends up holding after the canonical-order
//!   merge (partial accumulators, ST.E spill matrices) are routed back to
//!   the worker that took them from its pool via per-worker mailboxes
//!   ([`RetBuf`]), drained by that worker at the top of its next batch —
//!   loan accounting stays exact and no buffer migrates between pools.
//!
//! With `workers <= 1` the pool spawns **no threads at all**: it owns a
//! single inline [`WorkerScratch`] that the driving thread borrows
//! directly — no `Mutex`, no channel, nothing on the hot path.
//!
//! The one `unsafe` impl in the executor stack lives here: the batch
//! closure borrows interval-lived state, so its reference is
//! lifetime-erased to cross the thread boundary. Soundness is the
//! epoch protocol itself — [`BatchTicket`] will not let the borrow end
//! (its `finish`/`Drop` block) until `remaining == 0`, i.e. until no
//! worker can still dereference the pointer.

use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::isa::SlotLayout;
use crate::obs::trace;

use super::executor::ShardOut;
use super::scratch::{ScratchStats, WorkerScratch};

/// What a batch runs per shard: `(batch position, worker id, scratch)`.
pub(super) type RunFn<'e> = &'e DynRun<'e>;

type DynRun<'e> = dyn Fn(usize, usize, &mut WorkerScratch) -> ShardOut + Sync + 'e;

/// Lifetime-erased batch closure pointer. `Send` so it can sit in the
/// shared [`State`]; workers only dereference it between observing an
/// epoch and decrementing `remaining`, and the publisher keeps the
/// pointee alive past that point (see module docs).
#[derive(Clone, Copy)]
struct ErasedRun(*const DynRun<'static>);

unsafe impl Send for ErasedRun {}
unsafe impl Sync for ErasedRun {}

/// A buffer the main thread took out of a worker's scratch pool (inside
/// a [`ShardOut`]) and finished with during the merge, travelling home.
pub(super) enum RetBuf {
    /// Partial gather-accumulator data, keyed by D slot (`pm` pool).
    Pm(usize, Vec<f32>),
    /// Partial gather-count column, keyed by D slot (`pc` pool).
    Pc(usize, Vec<u32>),
    /// ST.E spill matrix data, keyed by E slot (`e` pool).
    E(usize, Vec<f32>),
}

fn give_back(ws: &mut WorkerScratch, buf: RetBuf) {
    match buf {
        RetBuf::Pm(slot, v) => ws.pm.give(slot, v),
        RetBuf::Pc(slot, v) => ws.pc.give(slot, v),
        RetBuf::E(slot, v) => ws.e.give(slot, v),
    }
}

#[derive(Clone, Copy)]
struct Job {
    run: ErasedRun,
    len: usize,
    /// Workers `w < width` participate; the rest skip the epoch.
    width: usize,
}

struct State {
    /// Monotone batch counter; a change is the wake signal.
    epoch: u64,
    job: Option<Job>,
    /// Participating workers that have not yet signalled completion.
    remaining: usize,
    /// A worker panicked mid-batch; surfaced by [`BatchTicket`].
    poisoned: bool,
    shutdown: bool,
    /// One slot per batch position, filled by the owning worker.
    results: Vec<Option<ShardOut>>,
    /// Per-worker return mailboxes (see [`RetBuf`]).
    returns: Vec<Vec<RetBuf>>,
    /// Per-worker scratch-pool counters, refreshed at each batch end.
    stats: Vec<ScratchStats>,
    /// Summed worker wall time inside batches.
    busy_ns: u64,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between batches (idle parking).
    work: Condvar,
    /// The publisher parks here until `remaining == 0`.
    done: Condvar,
}

/// Decrements `remaining` exactly once per worker per epoch — also on
/// the panic path, so the publisher unblocks (and sees `poisoned`)
/// instead of deadlocking.
struct DoneGuard<'a> {
    shared: &'a Shared,
}

impl Drop for DoneGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        if std::thread::panicking() {
            st.poisoned = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            self.shared.done.notify_all();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, w: usize, layout: SlotLayout, probe: Arc<()>) {
    let _probe = probe; // dropped when the thread exits — the leak test's witness
    let mut ws = WorkerScratch::new(&layout);
    let mut ret: Vec<RetBuf> = Vec::new();
    let mut outs: Vec<(usize, ShardOut)> = Vec::new();
    let mut seen = 0u64;
    'epochs: loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    st.stats[w] = ws.stats();
                    return;
                }
                if st.epoch != seen {
                    break;
                }
                st = shared.work.wait(st).unwrap();
            }
            seen = st.epoch;
            let job = st.job.expect("epoch published without a job");
            if w >= job.width {
                continue 'epochs;
            }
            std::mem::swap(&mut st.returns[w], &mut ret);
            job
        };
        // Feed the buffers the merge returned after our previous batch
        // back into our pools before this batch takes from them.
        for buf in ret.drain(..) {
            give_back(&mut ws, buf);
        }
        let done = DoneGuard { shared: &shared };
        let t0 = Instant::now();
        // SAFETY: see module docs — the pointee outlives this epoch
        // because the publisher blocks until `remaining == 0`, and
        // `done`'s decrement runs strictly after this use.
        let run = unsafe { &*job.run.0 };
        let mut k = w;
        while k < job.len {
            outs.push((k, run(k, w, &mut ws)));
            k += job.width;
        }
        let busy = t0.elapsed().as_nanos() as u64;
        // Persistent threads never exit mid-session, so the thread-exit
        // flush that covered scoped workers never fires here — hand the
        // span buffer to the session before the batch completes.
        trace::flush_thread();
        {
            let mut st = shared.state.lock().unwrap();
            for (k, out) in outs.drain(..) {
                st.results[k] = Some(out);
            }
            st.stats[w] = ws.stats();
            st.busy_ns += busy;
        }
        drop(done);
    }
}

/// Aggregate pool counters, surfaced via `Executor::pool_stats()` and
/// published as `exec_pool_*` metrics by the bench path.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Configured pool width.
    pub workers: usize,
    /// Threads spawned over the pool's lifetime. Spawning happens once,
    /// in `WorkerPool::new` — this staying constant across runs is the
    /// "zero thread spawns per interval in steady state" pin.
    pub spawned: u64,
    /// Batches published (incl. inline single-worker drains).
    pub batches: u64,
    /// Shards run across all batches.
    pub shards: u64,
    /// Largest single batch (peak queue depth handed to the pool).
    pub max_batch: usize,
    /// Summed worker wall seconds inside batches.
    pub busy_s: f64,
    /// Summed publisher wall seconds from publish to last completion.
    pub drain_s: f64,
}

impl PoolStats {
    /// Mean busy fraction of the pool while batches drained, in `[0, 1]`
    /// (1.0 = every worker busy for the whole drain window).
    pub fn utilization(&self) -> f64 {
        let denom = self.drain_s * self.workers.max(1) as f64;
        if denom > 0.0 {
            (self.busy_s / denom).min(1.0)
        } else {
            0.0
        }
    }

    /// Mean shards per batch — the queue depth each publish hands over.
    pub fn queue_depth(&self) -> f64 {
        if self.batches > 0 {
            self.shards as f64 / self.batches as f64
        } else {
            0.0
        }
    }
}

/// The persistent pool. One per `Executor`, created at the first drain
/// and dropped (workers joined) with it.
pub(super) struct WorkerPool {
    /// `None` in inline (`workers <= 1`) mode.
    shared: Option<Arc<Shared>>,
    handles: Vec<JoinHandle<()>>,
    /// Inline-mode scratch, owned directly — the single-worker hot path
    /// takes no lock and touches no thread machinery.
    inline: WorkerScratch,
    max_workers: usize,
    /// Witness for thread liveness: one clone per worker thread, so a
    /// `Weak` on it observes the joins (the lifecycle test's "no leaked
    /// threads" probe, race-free under parallel test execution).
    probe: Arc<()>,
    spawned: u64,
    batches: u64,
    shards: u64,
    max_batch: usize,
    inline_busy_ns: u64,
    drain_ns: u64,
}

impl WorkerPool {
    pub(super) fn new(layout: &SlotLayout, workers: usize) -> Self {
        let max_workers = workers.max(1);
        let mut pool = WorkerPool {
            shared: None,
            handles: Vec::new(),
            inline: WorkerScratch::new(layout),
            max_workers,
            probe: Arc::new(()),
            spawned: 0,
            batches: 0,
            shards: 0,
            max_batch: 0,
            inline_busy_ns: 0,
            drain_ns: 0,
        };
        if max_workers > 1 {
            let shared = Arc::new(Shared {
                state: Mutex::new(State {
                    epoch: 0,
                    job: None,
                    remaining: 0,
                    poisoned: false,
                    shutdown: false,
                    results: Vec::new(),
                    returns: (0..max_workers).map(|_| Vec::new()).collect(),
                    stats: vec![ScratchStats::default(); max_workers],
                    busy_ns: 0,
                }),
                work: Condvar::new(),
                done: Condvar::new(),
            });
            for w in 0..max_workers {
                let sh = Arc::clone(&shared);
                let lay = *layout;
                let probe = Arc::clone(&pool.probe);
                let handle = std::thread::Builder::new()
                    .name(format!("sb-worker-{w}"))
                    .spawn(move || worker_loop(sh, w, lay, probe))
                    .expect("spawn pool worker");
                pool.handles.push(handle);
                pool.spawned += 1;
            }
            pool.shared = Some(shared);
        }
        pool
    }

    /// True when the pool runs batches on the driving thread itself.
    pub(super) fn is_inline(&self) -> bool {
        self.shared.is_none()
    }

    /// The inline-mode scratch (panics if threads exist — the threaded
    /// pool's scratches are owned by the workers).
    pub(super) fn inline_scratch(&mut self) -> &mut WorkerScratch {
        debug_assert!(self.shared.is_none(), "inline scratch on a threaded pool");
        &mut self.inline
    }

    /// Record an inline drain so inline and threaded runs report through
    /// the same counters.
    pub(super) fn note_inline_batch(&mut self, len: usize, wall_ns: u64) {
        self.batches += 1;
        self.shards += len as u64;
        self.max_batch = self.max_batch.max(len);
        self.inline_busy_ns += wall_ns;
        self.drain_ns += wall_ns;
    }

    /// Publish a batch of `len` shards to the worker threads and return
    /// immediately — the caller overlaps its own work (the executor runs
    /// the next interval's prepare) before [`BatchTicket::finish`].
    pub(super) fn begin_batch<'p, 'e>(&'p mut self, len: usize, run: RunFn<'e>) -> BatchTicket<'p, 'e> {
        let width = self.max_workers.min(len).max(1);
        self.batches += 1;
        self.shards += len as u64;
        self.max_batch = self.max_batch.max(len);
        let shared = self
            .shared
            .as_ref()
            .expect("begin_batch on an inline pool");
        // SAFETY: only erases the lifetime; BatchTicket's finish/Drop
        // keep `run` borrowed until every worker is done with it.
        let ptr: *const DynRun<'e> = run;
        let erased = ErasedRun(unsafe {
            std::mem::transmute::<*const DynRun<'e>, *const DynRun<'static>>(ptr)
        });
        {
            let mut st = shared.state.lock().unwrap();
            debug_assert_eq!(st.remaining, 0, "overlapping batches");
            st.results.clear();
            st.results.resize_with(len, || None);
            st.job = Some(Job {
                run: erased,
                len,
                width,
            });
            st.remaining = width;
            st.epoch += 1;
        }
        shared.work.notify_all();
        BatchTicket {
            pool: self,
            t0: Instant::now(),
            waited: false,
            _run: std::marker::PhantomData,
        }
    }

    /// Append merged-buffer returns into the per-worker mailboxes (one
    /// lock), or straight back into the inline scratch.
    pub(super) fn deposit_returns(&mut self, rets: &mut [Vec<RetBuf>]) {
        match &self.shared {
            None => {
                for per in rets.iter_mut() {
                    for buf in per.drain(..) {
                        give_back(&mut self.inline, buf);
                    }
                }
            }
            Some(sh) => {
                let mut st = sh.state.lock().unwrap();
                for (w, per) in rets.iter_mut().enumerate() {
                    debug_assert!(per.is_empty() || w < st.returns.len());
                    if w < st.returns.len() {
                        st.returns[w].append(per);
                    }
                }
            }
        }
    }

    /// Merged scratch counters across the inline scratch and every
    /// worker's (as of each worker's last completed batch).
    pub(super) fn scratch_stats(&self) -> ScratchStats {
        let mut s = self.inline.stats();
        if let Some(sh) = &self.shared {
            let st = sh.state.lock().unwrap();
            for ws in &st.stats {
                s.merge(*ws);
            }
        }
        s
    }

    pub(super) fn stats(&self) -> PoolStats {
        let busy_ns = self.inline_busy_ns
            + self
                .shared
                .as_ref()
                .map_or(0, |sh| sh.state.lock().unwrap().busy_ns);
        PoolStats {
            workers: self.max_workers,
            spawned: self.spawned,
            batches: self.batches,
            shards: self.shards,
            max_batch: self.max_batch,
            busy_s: busy_ns as f64 * 1e-9,
            drain_s: self.drain_ns as f64 * 1e-9,
        }
    }

    /// Downgraded liveness witness: upgradeable while any worker thread
    /// (or the pool itself) is alive; dead once the pool dropped and all
    /// workers joined.
    #[cfg(test)]
    pub(super) fn probe(&self) -> std::sync::Weak<()> {
        Arc::downgrade(&self.probe)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if let Some(sh) = &self.shared {
            sh.state.lock().unwrap().shutdown = true;
            sh.work.notify_all();
        }
        for h in self.handles.drain(..) {
            // A worker that panicked already poisoned the batch that was
            // running; nothing more to surface at teardown.
            let _ = h.join();
        }
    }
}

/// Handle for one in-flight batch. `finish` (or `Drop`, as the
/// soundness backstop) blocks until the batch fully drains, and the
/// `'e` parameter keeps the batch closure's borrows alive for the
/// ticket's whole lifetime — the borrow checker itself enforces the
/// erased pointer's validity window.
pub(super) struct BatchTicket<'p, 'e> {
    pool: &'p mut WorkerPool,
    t0: Instant,
    waited: bool,
    _run: std::marker::PhantomData<RunFn<'e>>,
}

impl BatchTicket<'_, '_> {
    fn wait(&mut self) {
        if self.waited {
            return;
        }
        self.waited = true;
        let shared = self.pool.shared.as_ref().expect("ticket without threads");
        let poisoned = {
            let mut st = shared.state.lock().unwrap();
            while st.remaining > 0 {
                st = shared.done.wait(st).unwrap();
            }
            st.job = None;
            std::mem::take(&mut st.poisoned)
        };
        self.pool.drain_ns += self.t0.elapsed().as_nanos() as u64;
        if poisoned && !std::thread::panicking() {
            panic!("worker pool thread panicked during a batch");
        }
    }

    /// Block until every worker signalled, then move the batch's outputs
    /// into `out` in canonical batch order.
    pub(super) fn finish(mut self, out: &mut Vec<ShardOut>) {
        self.wait();
        let shared = self.pool.shared.as_ref().expect("ticket without threads");
        let mut st = shared.state.lock().unwrap();
        for r in st.results.drain(..) {
            out.push(r.expect("a worker left its batch slot empty"));
        }
    }
}

impl Drop for BatchTicket<'_, '_> {
    fn drop(&mut self) {
        self.wait();
    }
}
