//! Persistent GatherPhase worker pool.
//!
//! PR 5's executor spawned a fresh `std::thread::scope` per interval —
//! thousands of spawn/join barriers per run, and every worker's scratch
//! lived behind a `Mutex<WorkerScratch>` so the scoped closures could
//! reach it. This module replaces both with a pool that matches the
//! paper's sThread model (§V-B): workers are spawned **once per
//! `Executor`**, each *owns* its [`WorkerScratch`] outright (no lock on
//! the hot path), and interval shard batches are published to them over
//! an epoch/condvar protocol:
//!
//! * The driving thread publishes a batch (an erased `run(k, w, scratch)`
//!   closure plus its length) under the pool mutex, bumps the epoch and
//!   wakes the workers. It is then free to do *other* work — the
//!   executor runs the next interval's prepare there — before calling
//!   [`BatchTicket::finish`], which parks on the done condvar until every
//!   participating worker has signalled.
//! * Each worker processes the strided slice `k = w, w+width, …` —
//!   a static shard→worker affinity, so across intervals (and across
//!   whole reruns) the same shard positions revisit the same worker's
//!   warm scratch pools. Static assignment is also what makes the
//!   per-worker scratch hit/miss sequence deterministic, which the
//!   steady-state tests pin.
//! * Buffers the *main* thread ends up holding after the canonical-order
//!   merge (partial accumulators, ST.E spill matrices) are routed back to
//!   the worker that took them from its pool via per-worker mailboxes
//!   ([`RetBuf`]), drained by that worker at the top of its next batch —
//!   loan accounting stays exact and no buffer migrates between pools.
//!
//! With `workers <= 1` the pool spawns **no threads at all**: it owns a
//! single inline [`WorkerScratch`] that the driving thread borrows
//! directly — no `Mutex`, no channel, nothing on the hot path.
//!
//! The pool is batch-agnostic: a cross-request batched run
//! (`Executor::try_run_with` with B inputs) widens the matrices flowing
//! through each shard job to `B·cols`, but the `run` closure captures
//! that via its `ShardEnv` — the epoch protocol, affinity, and buffer
//! mailboxes are untouched, so one walk serves the whole micro-batch.
//!
//! ## Panic isolation
//!
//! A shard job that panics (a kernel bug, a pathological spec, an
//! injected `worker_panic`) used to poison the batch and **re-panic the
//! driving thread**, killing whatever owned the executor — for a
//! serving entry, permanently. Now each shard job runs under
//! `catch_unwind`: the worker records the failure, replaces its scratch
//! with a fresh [`WorkerScratch`] (a panic mid-shard can strand loaned
//! buffers, so the arena restarts clean), and keeps serving later
//! epochs — counted in [`PoolStats::respawned`]. Only the affected
//! batch fails, as a typed [`PoolError`] returned by
//! [`BatchTicket::finish`]. Should a worker thread die anyway (a panic
//! escaping the per-shard catch), the ticket's wait detects it and the
//! pool respawns the thread at the same index before returning — the
//! static shard→worker affinity survives the supervision.
//!
//! The one `unsafe` impl in the executor stack lives here: the batch
//! closure borrows interval-lived state, so its reference is
//! lifetime-erased to cross the thread boundary. Soundness is the
//! epoch protocol itself — [`BatchTicket`] will not let the borrow end
//! (its `finish`/`Drop` block) until `remaining == 0`, i.e. until no
//! worker can still dereference the pointer.

use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::isa::SlotLayout;
use crate::obs::trace;

use super::executor::ShardOut;
use super::scratch::{ScratchStats, WorkerScratch};

/// Typed batch failure, surfaced by [`BatchTicket::finish`] instead of
/// the old pool-wide re-panic. The pool itself has already healed
/// (fresh scratch, respawned thread if needed) by the time the caller
/// sees this — only the one batch's results are lost.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PoolError {
    /// A shard job panicked; the owning worker caught it, rebuilt its
    /// scratch, and kept running. `shard` is the batch position as the
    /// pool saw it — the executor rewrites it to the canonical shard id.
    WorkerPanicked {
        worker: usize,
        shard: usize,
        msg: String,
    },
    /// A worker thread died outside the per-shard catch; it was joined
    /// and respawned with fresh scratch at the same index.
    WorkerDied { worker: usize },
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolError::WorkerPanicked { worker, shard, msg } => {
                write!(f, "worker {worker} panicked on shard {shard}: {msg}")
            }
            PoolError::WorkerDied { worker } => {
                write!(f, "worker {worker} died mid-batch (respawned)")
            }
        }
    }
}

impl std::error::Error for PoolError {}

/// Render a `catch_unwind` payload — almost always the `&str`/`String`
/// a `panic!` carries.
pub(super) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// What a batch runs per shard: `(batch position, worker id, scratch)`.
pub(super) type RunFn<'e> = &'e DynRun<'e>;

type DynRun<'e> = dyn Fn(usize, usize, &mut WorkerScratch) -> ShardOut + Sync + 'e;

/// Lifetime-erased batch closure pointer. `Send` so it can sit in the
/// shared [`State`]; workers only dereference it between observing an
/// epoch and decrementing `remaining`, and the publisher keeps the
/// pointee alive past that point (see module docs).
#[derive(Clone, Copy)]
struct ErasedRun(*const DynRun<'static>);

unsafe impl Send for ErasedRun {}
unsafe impl Sync for ErasedRun {}

/// A buffer the main thread took out of a worker's scratch pool (inside
/// a [`ShardOut`]) and finished with during the merge, travelling home.
pub(super) enum RetBuf {
    /// Partial gather-accumulator data, keyed by D slot (`pm` pool).
    Pm(usize, Vec<f32>),
    /// Partial gather-count column, keyed by D slot (`pc` pool).
    Pc(usize, Vec<u32>),
    /// ST.E spill matrix data, keyed by E slot (`e` pool).
    E(usize, Vec<f32>),
}

fn give_back(ws: &mut WorkerScratch, buf: RetBuf) {
    match buf {
        RetBuf::Pm(slot, v) => ws.pm.give(slot, v),
        RetBuf::Pc(slot, v) => ws.pc.give(slot, v),
        RetBuf::E(slot, v) => ws.e.give(slot, v),
    }
}

#[derive(Clone, Copy)]
struct Job {
    run: ErasedRun,
    len: usize,
    /// Workers `w < width` participate; the rest skip the epoch.
    width: usize,
}

struct State {
    /// Monotone batch counter; a change is the wake signal.
    epoch: u64,
    job: Option<Job>,
    /// Participating workers that have not yet signalled completion.
    remaining: usize,
    /// A worker *thread* died mid-batch (panic escaping the per-shard
    /// catch); surfaced by [`BatchTicket`] and healed by a respawn.
    poisoned: bool,
    shutdown: bool,
    /// One slot per batch position, filled by the owning worker.
    results: Vec<Option<ShardOut>>,
    /// Caught shard-job panics this batch: `(worker, batch position,
    /// panic message)`. Non-empty fails the batch with a typed error.
    failures: Vec<(usize, usize, String)>,
    /// Per-worker "thread died" flags set by [`DoneGuard`] on the
    /// unwind path; consumed by the pool's respawn pass.
    dead: Vec<bool>,
    /// In-place worker recoveries: caught panics that rebuilt a
    /// worker's scratch without losing the thread.
    respawned: u64,
    /// Per-worker return mailboxes (see [`RetBuf`]).
    returns: Vec<Vec<RetBuf>>,
    /// Per-worker scratch-pool counters, refreshed at each batch end.
    stats: Vec<ScratchStats>,
    /// Summed worker wall time inside batches.
    busy_ns: u64,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between batches (idle parking).
    work: Condvar,
    /// The publisher parks here until `remaining == 0`.
    done: Condvar,
}

impl Shared {
    /// The state lock, tolerant of mutex poisoning: a worker that dies
    /// while holding the lock must not turn every later drain into a
    /// `PoisonError` panic — the whole point of this module's fault
    /// story is that one casualty stays one casualty.
    fn lock(&self) -> std::sync::MutexGuard<'_, State> {
        self.state
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }
}

/// Decrements `remaining` exactly once per worker per epoch — also on
/// the panic path, so the publisher unblocks (and sees `poisoned` plus
/// the worker's `dead` flag) instead of deadlocking.
struct DoneGuard<'a> {
    shared: &'a Shared,
    w: usize,
}

impl Drop for DoneGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.shared.lock();
        if std::thread::panicking() {
            // Only reachable when a panic escapes the per-shard catch —
            // this thread is about to die; mark it for respawn.
            st.poisoned = true;
            st.dead[self.w] = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            self.shared.done.notify_all();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, w: usize, layout: SlotLayout, probe: Arc<()>) {
    let _probe = probe; // dropped when the thread exits — the leak test's witness
    let mut ws = WorkerScratch::new(&layout);
    let mut ret: Vec<RetBuf> = Vec::new();
    let mut outs: Vec<(usize, ShardOut)> = Vec::new();
    let mut failed: Vec<(usize, String)> = Vec::new();
    let mut seen = 0u64;
    'epochs: loop {
        let job = {
            let mut st = shared.lock();
            loop {
                if st.shutdown {
                    st.stats[w] = ws.stats();
                    return;
                }
                if st.epoch != seen {
                    break;
                }
                st = shared.work.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
            }
            seen = st.epoch;
            // A respawned worker joins at whatever epoch the pool is on;
            // the wait() that healed it has already cleared `job`, so a
            // stale wake with no job just re-parks.
            let Some(job) = st.job else {
                continue 'epochs;
            };
            if w >= job.width {
                continue 'epochs;
            }
            std::mem::swap(&mut st.returns[w], &mut ret);
            job
        };
        // Feed the buffers the merge returned after our previous batch
        // back into our pools before this batch takes from them.
        for buf in ret.drain(..) {
            give_back(&mut ws, buf);
        }
        let done = DoneGuard { shared: &shared, w };
        let t0 = Instant::now();
        // SAFETY: see module docs — the pointee outlives this epoch
        // because the publisher blocks until `remaining == 0`, and
        // `done`'s decrement runs strictly after this use.
        let run = unsafe { &*job.run.0 };
        let mut k = w;
        while k < job.len {
            // A panicking shard job may have taken buffers from the
            // scratch pools without returning them, and may have left
            // pool internals mid-update — rebuild the scratch from the
            // layout rather than reason about its state.
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run(k, w, &mut ws))) {
                Ok(out) => outs.push((k, out)),
                Err(payload) => {
                    failed.push((k, panic_message(&*payload)));
                    ws = WorkerScratch::new(&layout);
                }
            }
            k += job.width;
        }
        let busy = t0.elapsed().as_nanos() as u64;
        // Persistent threads never exit mid-session, so the thread-exit
        // flush that covered scoped workers never fires here — hand the
        // span buffer to the session before the batch completes.
        trace::flush_thread();
        {
            let mut st = shared.lock();
            for (k, out) in outs.drain(..) {
                st.results[k] = Some(out);
            }
            st.respawned += failed.len() as u64;
            for (k, msg) in failed.drain(..) {
                st.failures.push((w, k, msg));
            }
            st.stats[w] = ws.stats();
            st.busy_ns += busy;
        }
        drop(done);
    }
}

/// Aggregate pool counters, surfaced via `Executor::pool_stats()` and
/// published as `exec_pool_*` metrics by the bench path.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Configured pool width.
    pub workers: usize,
    /// Threads spawned over the pool's lifetime. Spawning happens once,
    /// in `WorkerPool::new` — this staying constant across runs is the
    /// "zero thread spawns per interval in steady state" pin (fault
    /// recovery is the one sanctioned exception, counted in
    /// `respawned`).
    pub spawned: u64,
    /// Worker recoveries: caught shard-job panics that rebuilt a
    /// worker's scratch in place, plus worker threads respawned after a
    /// panic escaped the per-shard catch. Zero in healthy runs — the
    /// disarmed-differential chaos test pins that.
    pub respawned: u64,
    /// Batches published (incl. inline single-worker drains).
    pub batches: u64,
    /// Shards run across all batches.
    pub shards: u64,
    /// Largest single batch (peak queue depth handed to the pool).
    pub max_batch: usize,
    /// Summed worker wall seconds inside batches.
    pub busy_s: f64,
    /// Summed publisher wall seconds from publish to last completion.
    pub drain_s: f64,
}

impl PoolStats {
    /// Mean busy fraction of the pool while batches drained, in `[0, 1]`
    /// (1.0 = every worker busy for the whole drain window).
    pub fn utilization(&self) -> f64 {
        let denom = self.drain_s * self.workers.max(1) as f64;
        if denom > 0.0 {
            (self.busy_s / denom).min(1.0)
        } else {
            0.0
        }
    }

    /// Mean shards per batch — the queue depth each publish hands over.
    pub fn queue_depth(&self) -> f64 {
        if self.batches > 0 {
            self.shards as f64 / self.batches as f64
        } else {
            0.0
        }
    }
}

/// The persistent pool. One per `Executor`, created at the first drain
/// and dropped (workers joined) with it.
pub(super) struct WorkerPool {
    /// `None` in inline (`workers <= 1`) mode.
    shared: Option<Arc<Shared>>,
    handles: Vec<JoinHandle<()>>,
    /// Inline-mode scratch, owned directly — the single-worker hot path
    /// takes no lock and touches no thread machinery.
    inline: WorkerScratch,
    max_workers: usize,
    /// Witness for thread liveness: one clone per worker thread, so a
    /// `Weak` on it observes the joins (the lifecycle test's "no leaked
    /// threads" probe, race-free under parallel test execution).
    probe: Arc<()>,
    /// Kept for respawns: a healed worker thread starts from a fresh
    /// `WorkerScratch` over the same layout.
    layout: SlotLayout,
    spawned: u64,
    /// Thread-level respawns performed by [`WorkerPool::heal`] plus
    /// inline-mode panic recoveries (the in-place scratch rebuilds are
    /// counted inside `State::respawned`).
    respawned: u64,
    batches: u64,
    shards: u64,
    max_batch: usize,
    inline_busy_ns: u64,
    drain_ns: u64,
}

impl WorkerPool {
    pub(super) fn new(layout: &SlotLayout, workers: usize) -> Self {
        let max_workers = workers.max(1);
        let mut pool = WorkerPool {
            shared: None,
            handles: Vec::new(),
            inline: WorkerScratch::new(layout),
            max_workers,
            probe: Arc::new(()),
            layout: *layout,
            spawned: 0,
            respawned: 0,
            batches: 0,
            shards: 0,
            max_batch: 0,
            inline_busy_ns: 0,
            drain_ns: 0,
        };
        if max_workers > 1 {
            let shared = Arc::new(Shared {
                state: Mutex::new(State {
                    epoch: 0,
                    job: None,
                    remaining: 0,
                    poisoned: false,
                    shutdown: false,
                    results: Vec::new(),
                    failures: Vec::new(),
                    dead: vec![false; max_workers],
                    respawned: 0,
                    returns: (0..max_workers).map(|_| Vec::new()).collect(),
                    stats: vec![ScratchStats::default(); max_workers],
                    busy_ns: 0,
                }),
                work: Condvar::new(),
                done: Condvar::new(),
            });
            for w in 0..max_workers {
                let sh = Arc::clone(&shared);
                let lay = *layout;
                let probe = Arc::clone(&pool.probe);
                let handle = std::thread::Builder::new()
                    .name(format!("sb-worker-{w}"))
                    .spawn(move || worker_loop(sh, w, lay, probe))
                    .expect("spawn pool worker");
                pool.handles.push(handle);
                pool.spawned += 1;
            }
            pool.shared = Some(shared);
        }
        pool
    }

    /// True when the pool runs batches on the driving thread itself.
    pub(super) fn is_inline(&self) -> bool {
        self.shared.is_none()
    }

    /// The inline-mode scratch (panics if threads exist — the threaded
    /// pool's scratches are owned by the workers).
    pub(super) fn inline_scratch(&mut self) -> &mut WorkerScratch {
        debug_assert!(self.shared.is_none(), "inline scratch on a threaded pool");
        &mut self.inline
    }

    /// Record an inline drain so inline and threaded runs report through
    /// the same counters.
    pub(super) fn note_inline_batch(&mut self, len: usize, wall_ns: u64) {
        self.batches += 1;
        self.shards += len as u64;
        self.max_batch = self.max_batch.max(len);
        self.inline_busy_ns += wall_ns;
        self.drain_ns += wall_ns;
    }

    /// Inline-mode recovery: a caught shard panic may have stranded
    /// loaned buffers, so the inline scratch restarts clean — the same
    /// treatment a threaded worker gives itself.
    pub(super) fn note_inline_panic(&mut self) {
        self.inline = WorkerScratch::new(&self.layout);
        self.respawned += 1;
    }

    /// Join and respawn every worker thread whose `dead` flag is set,
    /// preserving the static shard→worker affinity by reusing the slot
    /// index. Returns the indices of the workers that died. Called by
    /// [`BatchTicket::wait`] once the batch has fully drained, so no
    /// epoch is in flight while threads are replaced.
    fn heal(&mut self) -> Vec<usize> {
        let Some(shared) = self.shared.as_ref().map(Arc::clone) else {
            return Vec::new();
        };
        let died: Vec<usize> = {
            let mut st = shared.lock();
            let died = (0..st.dead.len()).filter(|&w| st.dead[w]).collect();
            for d in st.dead.iter_mut() {
                *d = false;
            }
            died
        };
        for &w in &died {
            let old = std::mem::replace(
                &mut self.handles[w],
                std::thread::Builder::new()
                    .name(format!("sb-worker-{w}"))
                    .spawn({
                        let sh = Arc::clone(&shared);
                        let lay = self.layout;
                        let probe = Arc::clone(&self.probe);
                        move || worker_loop(sh, w, lay, probe)
                    })
                    .expect("respawn pool worker"),
            );
            let _ = old.join(); // already dead; reap the panic payload
            self.spawned += 1;
            self.respawned += 1;
        }
        died
    }

    /// Publish a batch of `len` shards to the worker threads and return
    /// immediately — the caller overlaps its own work (the executor runs
    /// the next interval's prepare) before [`BatchTicket::finish`].
    pub(super) fn begin_batch<'p, 'e>(&'p mut self, len: usize, run: RunFn<'e>) -> BatchTicket<'p, 'e> {
        let width = self.max_workers.min(len).max(1);
        self.batches += 1;
        self.shards += len as u64;
        self.max_batch = self.max_batch.max(len);
        let shared = self
            .shared
            .as_ref()
            .expect("begin_batch on an inline pool");
        // SAFETY: only erases the lifetime; BatchTicket's finish/Drop
        // keep `run` borrowed until every worker is done with it.
        let ptr: *const DynRun<'e> = run;
        let erased = ErasedRun(unsafe {
            std::mem::transmute::<*const DynRun<'e>, *const DynRun<'static>>(ptr)
        });
        {
            let mut st = shared.lock();
            debug_assert_eq!(st.remaining, 0, "overlapping batches");
            st.results.clear();
            st.results.resize_with(len, || None);
            st.failures.clear();
            st.job = Some(Job {
                run: erased,
                len,
                width,
            });
            st.remaining = width;
            st.epoch += 1;
        }
        shared.work.notify_all();
        BatchTicket {
            pool: self,
            t0: Instant::now(),
            waited: false,
            err: None,
            _run: std::marker::PhantomData,
        }
    }

    /// Append merged-buffer returns into the per-worker mailboxes (one
    /// lock), or straight back into the inline scratch.
    pub(super) fn deposit_returns(&mut self, rets: &mut [Vec<RetBuf>]) {
        match &self.shared {
            None => {
                for per in rets.iter_mut() {
                    for buf in per.drain(..) {
                        give_back(&mut self.inline, buf);
                    }
                }
            }
            Some(sh) => {
                let mut st = sh.lock();
                for (w, per) in rets.iter_mut().enumerate() {
                    debug_assert!(per.is_empty() || w < st.returns.len());
                    if w < st.returns.len() {
                        st.returns[w].append(per);
                    }
                }
            }
        }
    }

    /// Merged scratch counters across the inline scratch and every
    /// worker's (as of each worker's last completed batch).
    pub(super) fn scratch_stats(&self) -> ScratchStats {
        let mut s = self.inline.stats();
        if let Some(sh) = &self.shared {
            let st = sh.lock();
            for ws in &st.stats {
                s.merge(*ws);
            }
        }
        s
    }

    pub(super) fn stats(&self) -> PoolStats {
        let (busy, in_place) = self
            .shared
            .as_ref()
            .map_or((0, 0), |sh| {
                let st = sh.lock();
                (st.busy_ns, st.respawned)
            });
        let busy_ns = self.inline_busy_ns + busy;
        PoolStats {
            workers: self.max_workers,
            spawned: self.spawned,
            respawned: self.respawned + in_place,
            batches: self.batches,
            shards: self.shards,
            max_batch: self.max_batch,
            busy_s: busy_ns as f64 * 1e-9,
            drain_s: self.drain_ns as f64 * 1e-9,
        }
    }

    /// Downgraded liveness witness: upgradeable while any worker thread
    /// (or the pool itself) is alive; dead once the pool dropped and all
    /// workers joined.
    #[cfg(test)]
    pub(super) fn probe(&self) -> std::sync::Weak<()> {
        Arc::downgrade(&self.probe)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if let Some(sh) = &self.shared {
            sh.lock().shutdown = true;
            sh.work.notify_all();
        }
        for h in self.handles.drain(..) {
            // A worker that panicked already poisoned the batch that was
            // running; nothing more to surface at teardown.
            let _ = h.join();
        }
    }
}

/// Handle for one in-flight batch. `finish` (or `Drop`, as the
/// soundness backstop) blocks until the batch fully drains, and the
/// `'e` parameter keeps the batch closure's borrows alive for the
/// ticket's whole lifetime — the borrow checker itself enforces the
/// erased pointer's validity window.
pub(super) struct BatchTicket<'p, 'e> {
    pool: &'p mut WorkerPool,
    t0: Instant,
    waited: bool,
    err: Option<PoolError>,
    _run: std::marker::PhantomData<RunFn<'e>>,
}

impl BatchTicket<'_, '_> {
    /// Block until every participating worker signalled, heal any
    /// casualties (respawn dead threads at their slot index), and
    /// return the batch's failure, if any. Idempotent; also runs from
    /// `Drop` as the soundness backstop.
    fn wait(&mut self) -> Option<PoolError> {
        if self.waited {
            return self.err.clone();
        }
        self.waited = true;
        let shared = Arc::clone(self.pool.shared.as_ref().expect("ticket without threads"));
        let (mut failures, poisoned) = {
            let mut st = shared.lock();
            while st.remaining > 0 {
                st = shared
                    .done
                    .wait(st)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
            st.job = None;
            (
                std::mem::take(&mut st.failures),
                std::mem::take(&mut st.poisoned),
            )
        };
        self.pool.drain_ns += self.t0.elapsed().as_nanos() as u64;
        let died = if poisoned { self.pool.heal() } else { Vec::new() };
        failures.sort_by_key(|f| f.1);
        self.err = if let Some((worker, shard, msg)) = failures.into_iter().next() {
            Some(PoolError::WorkerPanicked { worker, shard, msg })
        } else if poisoned {
            Some(PoolError::WorkerDied {
                worker: died.first().copied().unwrap_or(0),
            })
        } else {
            None
        };
        self.err.clone()
    }

    /// Block until every worker signalled, then move the batch's outputs
    /// into `out` in canonical batch order — or surface the batch's
    /// failure, discarding its partial results (the pool has already
    /// healed; the executor owns the retry/report policy).
    pub(super) fn finish(mut self, out: &mut Vec<ShardOut>) -> Result<(), PoolError> {
        if let Some(err) = self.wait() {
            let shared = self.pool.shared.as_ref().expect("ticket without threads");
            shared.lock().results.clear();
            return Err(err);
        }
        let shared = self.pool.shared.as_ref().expect("ticket without threads");
        let mut st = shared.lock();
        for r in st.results.drain(..) {
            out.push(r.expect("a worker left its batch slot empty"));
        }
        Ok(())
    }
}

impl Drop for BatchTicket<'_, '_> {
    fn drop(&mut self) {
        let _ = self.wait();
    }
}
