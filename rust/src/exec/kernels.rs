//! The executor's inner-loop kernel layer: cache-blocked, branch-free
//! matmul plus fused row kernels, all written as straight-line slice
//! iteration so the compiler's autovectorizer can keep the SIMD lanes
//! full (the software analogue of keeping the VU/MU saturated, §IV).
//!
//! Two tiers above the preserved naive loops:
//!
//! * the `*_blocked` / plain row kernels rely on autovectorization over
//!   variable-length slices;
//! * the `*_simd` kernels (`KernelMode::Simd`) commit to an explicit
//!   width — [`SIMD_LANES`]-element `[f32; 8]` chunks via
//!   `chunks_exact`, so the compiler sees fixed-trip-count inner loops
//!   it can lower to full vector registers without a length check —
//!   with a scalar tail for the remainder. Portable safe Rust: no
//!   `unsafe`, no feature flags, no intrinsics.
//!
//! Every kernel preserves the *exact* floating-point operation order of
//! the naive loops it replaced, so the executor's output stays
//! bit-identical — the differential tests in `exec::tests` pin both
//! kernel paths against the preserved naive reference
//! ([`matmul_naive`] / `compute_instr_naive`) on every zoo model.

use crate::exec::matrix::Matrix;
use crate::exec::reference::{apply_binary, apply_unary};
use crate::isa::ElwOp;

/// Column-tile width of the blocked matmul: 8 f32 lanes (one AVX2
/// register / two NEON registers) of output accumulated in registers.
pub const MM_TILE: usize = 8;

/// Cache-blocked, branch-free matmul: `out[i, j] = Σ_k a[i, k] · b[k, j]`,
/// written into the pre-sized `out` (`[out.rows, b.cols]`; contents are
/// fully overwritten, so scratch-arena buffers need no zeroing).
///
/// Three properties vs. the naive triple loop:
/// * no `a == 0.0` skip branch — the data-dependent branch defeated
///   autovectorization and bought nothing on dense activations;
/// * 8-wide column tiles with a fixed-size register accumulator, so the
///   inner loop is a pure `acc[j] += a·b[j]` FMA chain over a slice;
/// * for each output element the k-summation order is unchanged
///   (ascending), so results are bit-identical to [`matmul_naive`] for
///   finite inputs.
pub fn matmul_blocked(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "matmul shape");
    assert_eq!(out.cols, b.cols, "matmul out cols");
    assert!(a.rows >= out.rows, "matmul out rows");
    let n = b.cols;
    let mut j = 0;
    while j < n {
        let jw = MM_TILE.min(n - j);
        for i in 0..out.rows {
            let arow = a.row(i);
            let mut acc = [0.0f32; MM_TILE];
            for (k, &av) in arow.iter().enumerate() {
                let brow = &b.row(k)[j..j + jw];
                for (x, &bv) in acc[..jw].iter_mut().zip(brow) {
                    *x += av * bv;
                }
            }
            out.row_mut(i)[j..j + jw].copy_from_slice(&acc[..jw]);
        }
        j += MM_TILE;
    }
}

/// The pre-kernel-layer matmul, preserved verbatim as the differential
/// reference (and to document what the blocked kernel replaced): row-major
/// triple loop with an `a == 0.0` skip branch.
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul shape");
    let mut out = Matrix::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        let arow = a.row(i);
        let orow = out.row_mut(i);
        for (k, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = b.row(k);
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

// ---- lane-windowed matmul variants (batched execution) ----------------------
//
// Batched runs column-stack B feature matrices into one `[rows, B·F]`
// buffer (see `exec::RunRequest`). Element-wise kernels are column-
// independent, so they run on full stacked rows unchanged — but a Dmm
// multiplies a *stacked* activation against an *unstacked* weight, so
// each request's lane must be computed separately: lane `l` reads
// `a[i, a_off .. a_off + k]` and writes `out[i, out_off .. out_off + n]`.
// Every lane variant walks its window in the exact iteration order of
// its sequential twin, so a batched lane is bit-identical to the same
// request run alone.

/// [`matmul_blocked`] over one lane window: `out[i, out_off + j] =
/// Σ_k a[i, a_off + k] · b[k, j]`. Same 8-wide column tiles, same
/// ascending-k register accumulation — bit-identical to running
/// [`matmul_blocked`] on the lane's sub-matrices.
pub fn matmul_blocked_lane(
    a: &Matrix,
    a_off: usize,
    k: usize,
    b: &Matrix,
    out: &mut Matrix,
    out_off: usize,
) {
    assert_eq!(k, b.rows, "matmul lane shape");
    assert!(a.cols >= a_off + k, "matmul lane a window");
    assert!(out.cols >= out_off + b.cols, "matmul lane out window");
    assert!(a.rows >= out.rows, "matmul out rows");
    let n = b.cols;
    let mut j = 0;
    while j < n {
        let jw = MM_TILE.min(n - j);
        for i in 0..out.rows {
            let arow = &a.row(i)[a_off..a_off + k];
            let mut acc = [0.0f32; MM_TILE];
            for (k, &av) in arow.iter().enumerate() {
                let brow = &b.row(k)[j..j + jw];
                for (x, &bv) in acc[..jw].iter_mut().zip(brow) {
                    *x += av * bv;
                }
            }
            out.row_mut(i)[out_off + j..out_off + j + jw].copy_from_slice(&acc[..jw]);
        }
        j += MM_TILE;
    }
}

/// [`matmul_simd`] over one lane window; same exact-8-chunk walk and
/// ascending-k accumulation as the unwindowed kernel, so a batched lane
/// is bit-identical to the same request run alone.
pub fn matmul_simd_lane(
    a: &Matrix,
    a_off: usize,
    k: usize,
    b: &Matrix,
    out: &mut Matrix,
    out_off: usize,
) {
    assert_eq!(k, b.rows, "matmul lane shape");
    assert!(a.cols >= a_off + k, "matmul lane a window");
    assert!(out.cols >= out_off + b.cols, "matmul lane out window");
    assert!(a.rows >= out.rows, "matmul out rows");
    let n = b.cols;
    let whole = n - n % SIMD_LANES;
    for i in 0..out.rows {
        let arow = &a.row(i)[a_off..a_off + k];
        let mut j = 0;
        while j < whole {
            let mut acc = [0.0f32; SIMD_LANES];
            for (k, &av) in arow.iter().enumerate() {
                let brow: &[f32; SIMD_LANES] =
                    b.row(k)[j..j + SIMD_LANES].try_into().unwrap();
                for (x, &bv) in acc.iter_mut().zip(brow) {
                    *x += av * bv;
                }
            }
            out.row_mut(i)[out_off + j..out_off + j + SIMD_LANES].copy_from_slice(&acc);
            j += SIMD_LANES;
        }
        if j < n {
            let jw = n - j;
            let mut acc = [0.0f32; SIMD_LANES];
            for (k, &av) in arow.iter().enumerate() {
                let brow = &b.row(k)[j..];
                for (x, &bv) in acc[..jw].iter_mut().zip(brow) {
                    *x += av * bv;
                }
            }
            out.row_mut(i)[out_off + j..out_off + n].copy_from_slice(&acc[..jw]);
        }
    }
}

/// [`matmul_naive`] over one lane window, writing into `out` instead of
/// allocating: the window is zeroed, then accumulated with the same
/// `a == 0.0` skip and the same loop order as the preserved reference,
/// so a batched lane is bit-identical to the same request run alone.
pub fn matmul_naive_lane(
    a: &Matrix,
    a_off: usize,
    k: usize,
    b: &Matrix,
    out: &mut Matrix,
    out_off: usize,
) {
    assert_eq!(k, b.rows, "matmul lane shape");
    assert!(a.cols >= a_off + k, "matmul lane a window");
    assert!(out.cols >= out_off + b.cols, "matmul lane out window");
    assert!(a.rows >= out.rows, "matmul out rows");
    let n = b.cols;
    for i in 0..out.rows {
        let arow = &a.row(i)[a_off..a_off + k];
        let orow = &mut out.row_mut(i)[out_off..out_off + n];
        orow.fill(0.0);
        for (k, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = b.row(k);
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

// ---- explicit-width SIMD kernels (KernelMode::Simd) -------------------------

/// Lane count of the explicit-width kernels: 8 f32 elements, matching
/// [`MM_TILE`] (one AVX2 register / two NEON registers).
pub const SIMD_LANES: usize = 8;

/// Explicit-width matmul: per output row, the column range is walked in
/// exact [`SIMD_LANES`]-wide chunks with a `[f32; 8]` register
/// accumulator (fixed-trip-count inner loop), then a scalar tail. Per
/// output element the k-summation is the same ascending `acc += a·b`
/// chain as [`matmul_blocked`], so results are bit-identical to it and
/// to [`matmul_naive`] for finite inputs.
pub fn matmul_simd(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "matmul shape");
    assert_eq!(out.cols, b.cols, "matmul out cols");
    assert!(a.rows >= out.rows, "matmul out rows");
    let n = b.cols;
    let whole = n - n % SIMD_LANES;
    for i in 0..out.rows {
        let arow = a.row(i);
        let mut j = 0;
        while j < whole {
            let mut acc = [0.0f32; SIMD_LANES];
            for (k, &av) in arow.iter().enumerate() {
                let brow: &[f32; SIMD_LANES] =
                    b.row(k)[j..j + SIMD_LANES].try_into().unwrap();
                for (x, &bv) in acc.iter_mut().zip(brow) {
                    *x += av * bv;
                }
            }
            out.row_mut(i)[j..j + SIMD_LANES].copy_from_slice(&acc);
            j += SIMD_LANES;
        }
        if j < n {
            let jw = n - j;
            let mut acc = [0.0f32; SIMD_LANES];
            for (k, &av) in arow.iter().enumerate() {
                let brow = &b.row(k)[j..];
                for (x, &bv) in acc[..jw].iter_mut().zip(brow) {
                    *x += av * bv;
                }
            }
            out.row_mut(i)[j..].copy_from_slice(&acc[..jw]);
        }
    }
}

/// `o += x` in exact 8-lane chunks plus a scalar tail. Element-wise ops
/// are independent, so any chunking is bit-identical to [`axpy`].
#[inline]
pub fn axpy_simd(o: &mut [f32], x: &[f32]) {
    let n = o.len().min(x.len());
    let (o, x) = (&mut o[..n], &x[..n]);
    let mut oc = o.chunks_exact_mut(SIMD_LANES);
    let mut xc = x.chunks_exact(SIMD_LANES);
    for (ob, xb) in (&mut oc).zip(&mut xc) {
        let ob: &mut [f32; SIMD_LANES] = ob.try_into().unwrap();
        let xb: &[f32; SIMD_LANES] = xb.try_into().unwrap();
        for (o, &v) in ob.iter_mut().zip(xb) {
            *o += v;
        }
    }
    for (o, &v) in oc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o += v;
    }
}

/// `o += f · x` in exact 8-lane chunks plus a scalar tail; bit-identical
/// to [`scale_axpy`].
#[inline]
pub fn scale_axpy_simd(o: &mut [f32], x: &[f32], f: f32) {
    let n = o.len().min(x.len());
    let (o, x) = (&mut o[..n], &x[..n]);
    let mut oc = o.chunks_exact_mut(SIMD_LANES);
    let mut xc = x.chunks_exact(SIMD_LANES);
    for (ob, xb) in (&mut oc).zip(&mut xc) {
        let ob: &mut [f32; SIMD_LANES] = ob.try_into().unwrap();
        let xb: &[f32; SIMD_LANES] = xb.try_into().unwrap();
        for (o, &v) in ob.iter_mut().zip(xb) {
            *o += v * f;
        }
    }
    for (o, &v) in oc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o += v * f;
    }
}

/// `o = max(o, x)` in exact 8-lane chunks plus a scalar tail;
/// bit-identical to [`max_assign`].
#[inline]
pub fn max_assign_simd(o: &mut [f32], x: &[f32]) {
    let n = o.len().min(x.len());
    let (o, x) = (&mut o[..n], &x[..n]);
    let mut oc = o.chunks_exact_mut(SIMD_LANES);
    let mut xc = x.chunks_exact(SIMD_LANES);
    for (ob, xb) in (&mut oc).zip(&mut xc) {
        let ob: &mut [f32; SIMD_LANES] = ob.try_into().unwrap();
        let xb: &[f32; SIMD_LANES] = xb.try_into().unwrap();
        for (o, &v) in ob.iter_mut().zip(xb) {
            *o = o.max(v);
        }
    }
    for (o, &v) in oc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o = o.max(v);
    }
}

/// `o = max(o, f · x)` in exact 8-lane chunks plus a scalar tail;
/// bit-identical to [`scale_max_assign`].
#[inline]
pub fn scale_max_assign_simd(o: &mut [f32], x: &[f32], f: f32) {
    let n = o.len().min(x.len());
    let (o, x) = (&mut o[..n], &x[..n]);
    let mut oc = o.chunks_exact_mut(SIMD_LANES);
    let mut xc = x.chunks_exact(SIMD_LANES);
    for (ob, xb) in (&mut oc).zip(&mut xc) {
        let ob: &mut [f32; SIMD_LANES] = ob.try_into().unwrap();
        let xb: &[f32; SIMD_LANES] = xb.try_into().unwrap();
        for (o, &v) in ob.iter_mut().zip(xb) {
            *o = o.max(v * f);
        }
    }
    for (o, &v) in oc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o = o.max(v * f);
    }
}

// ---- fused row kernels (gather inner loops + shard merge) -------------------

/// `o += x`, element-wise over a row.
#[inline]
pub fn axpy(o: &mut [f32], x: &[f32]) {
    for (o, &v) in o.iter_mut().zip(x) {
        *o += v;
    }
}

/// `o += f · x`, element-wise over a row (the FusedGather inner loop).
#[inline]
pub fn scale_axpy(o: &mut [f32], x: &[f32], f: f32) {
    for (o, &v) in o.iter_mut().zip(x) {
        *o += v * f;
    }
}

/// `o = max(o, x)`, element-wise over a row.
#[inline]
pub fn max_assign(o: &mut [f32], x: &[f32]) {
    for (o, &v) in o.iter_mut().zip(x) {
        *o = o.max(v);
    }
}

/// `o = max(o, f · x)`, element-wise over a row.
#[inline]
pub fn scale_max_assign(o: &mut [f32], x: &[f32], f: f32) {
    for (o, &v) in o.iter_mut().zip(x) {
        *o = o.max(v * f);
    }
}

// ---- slice-based element-wise kernels (ELW / RSCALE) ------------------------

/// Unary ELW over a flat slice: `out[i] = op(a[i])`.
#[inline]
pub fn elw_unary(op: ElwOp, a: &[f32], out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(a) {
        *o = apply_unary(op, v);
    }
}

/// Binary ELW over flat slices: `out[i] = op(a[i], b[i])`.
#[inline]
pub fn elw_binary(op: ElwOp, a: &[f32], b: &[f32], out: &mut [f32]) {
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = apply_binary(op, x, y);
    }
}

/// Row-scale: `out[i] = f · a[i]` over one row.
#[inline]
pub fn row_scale(a: &[f32], f: f32, out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(a) {
        *o = v * f;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::weights;

    fn check_shape(m: usize, k: usize, n: usize, seed: u64) {
        let a = weights::init_weight(seed, m as u32, k as u32);
        let b = weights::init_weight(seed + 1, k as u32, n as u32);
        let want = matmul_naive(&a, &b);
        let mut got = Matrix::zeros(m, n);
        matmul_blocked(&a, &b, &mut got);
        assert!(got.bits_eq(&want), "blocked != naive at {m}x{k}x{n}");
    }

    #[test]
    fn blocked_matches_naive_on_odd_shapes() {
        // 1×k, k×1, exact tile multiples, and every misalignment of the
        // 8-wide column tile.
        check_shape(1, 7, 5, 3);
        check_shape(5, 7, 1, 4);
        check_shape(1, 1, 1, 5);
        check_shape(8, 8, 8, 6);
        check_shape(16, 32, 24, 7);
        for n in 1..=17 {
            check_shape(3, 5, n, 100 + n as u64);
        }
    }

    #[test]
    fn blocked_matches_naive_with_zero_rows() {
        // The naive kernel's `a == 0.0` skip must be value-equivalent to
        // the branch-free accumulation (isolated-vertex zero rows).
        let mut a = weights::init_weight(9, 4, 6);
        a.row_mut(1).fill(0.0);
        a.set(3, 0, 0.0);
        a.set(3, 5, 0.0);
        let b = weights::init_weight(10, 6, 9);
        let want = matmul_naive(&a, &b);
        let mut got = Matrix::zeros(4, 9);
        matmul_blocked(&a, &b, &mut got);
        assert!(got.bits_eq(&want));
        assert!(got.row(1).iter().all(|v| v.to_bits() == 0.0f32.to_bits()));
    }

    #[test]
    fn blocked_overwrites_stale_out() {
        // Scratch-arena buffers arrive with stale contents; the kernel
        // must not read-modify-write them.
        let a = weights::init_weight(11, 3, 4);
        let b = weights::init_weight(12, 4, 10);
        let want = matmul_naive(&a, &b);
        let mut got = Matrix::filled(3, 10, f32::NAN);
        matmul_blocked(&a, &b, &mut got);
        assert!(got.bits_eq(&want));
    }

    #[test]
    fn row_kernels_match_scalar_loops() {
        let x = [1.5f32, -2.0, 0.25, 3.0];
        let mut o = [0.5f32, 1.0, -1.0, 2.0];
        let mut o2 = o;
        axpy(&mut o, &x);
        for (o, &v) in o2.iter_mut().zip(&x) {
            *o += v;
        }
        assert_eq!(o, o2);

        let mut m = [0.5f32, 1.0, -1.0, 2.0];
        max_assign(&mut m, &x);
        assert_eq!(m, [1.5, 1.0, 0.25, 3.0]);

        let mut s = [0.0f32; 4];
        scale_axpy(&mut s, &x, 2.0);
        assert_eq!(s, [3.0, -4.0, 0.5, 6.0]);

        let mut sm = [2.9f32, 0.0, 0.0, 0.0];
        scale_max_assign(&mut sm, &x, 2.0);
        assert_eq!(sm, [3.0, 0.0, 0.5, 6.0]);
    }

    #[test]
    fn simd_matmul_matches_naive_on_tail_shapes() {
        // Deliberately non-multiple-of-8 column counts: every tail width
        // 1..=7, plus exact-lane and just-over-lane widths, must be
        // bit-identical to the naive reference.
        for n in 1..=17 {
            let a = weights::init_weight(200 + n as u64, 3, 5);
            let b = weights::init_weight(300 + n as u64, 5, n as u32);
            let want = matmul_naive(&a, &b);
            let mut got = Matrix::filled(3, n, f32::NAN);
            matmul_simd(&a, &b, &mut got);
            assert!(got.bits_eq(&want), "simd != naive at 3x5x{n}");
        }
        // And a lane-aligned big-ish shape.
        let a = weights::init_weight(42, 16, 32);
        let b = weights::init_weight(43, 32, 24);
        let want = matmul_naive(&a, &b);
        let mut got = Matrix::zeros(16, 24);
        matmul_simd(&a, &b, &mut got);
        assert!(got.bits_eq(&want));
    }

    #[test]
    fn simd_row_kernels_handle_non_multiple_of_8_tails() {
        // Row widths 1..=19 cover empty-chunk, one-chunk and chunk+tail
        // layouts; each SIMD kernel must be bit-identical to its scalar
        // twin on the same data.
        for len in 1..=19usize {
            let x: Vec<f32> = (0..len).map(|i| (i as f32 - 7.5) * 0.37).collect();
            let base: Vec<f32> = (0..len).map(|i| (i as f32) * -0.21 + 1.0).collect();

            let (mut a, mut b) = (base.clone(), base.clone());
            axpy(&mut a, &x);
            axpy_simd(&mut b, &x);
            assert_eq!(a, b, "axpy tail at len {len}");

            let (mut a, mut b) = (base.clone(), base.clone());
            scale_axpy(&mut a, &x, 1.7);
            scale_axpy_simd(&mut b, &x, 1.7);
            assert_eq!(a, b, "scale_axpy tail at len {len}");

            let (mut a, mut b) = (base.clone(), base.clone());
            max_assign(&mut a, &x);
            max_assign_simd(&mut b, &x);
            assert_eq!(a, b, "max_assign tail at len {len}");

            let (mut a, mut b) = (base.clone(), base);
            scale_max_assign(&mut a, &x, -0.9);
            scale_max_assign_simd(&mut b, &x, -0.9);
            assert_eq!(a, b, "scale_max_assign tail at len {len}");
        }
    }

    /// Column-stack `parts` into one `[rows, Σ cols]` matrix, the
    /// layout batched runs use for activations.
    fn stack(parts: &[&Matrix]) -> Matrix {
        let rows = parts[0].rows;
        let total: usize = parts.iter().map(|m| m.cols).sum();
        let mut s = Matrix::filled(rows, total, f32::NAN);
        for i in 0..rows {
            let mut off = 0;
            for m in parts {
                s.row_mut(i)[off..off + m.cols].copy_from_slice(m.row(i));
                off += m.cols;
            }
        }
        s
    }

    #[test]
    fn lane_matmuls_match_their_sequential_twins() {
        // Three requests of width k stacked into [rows, 3k]; each lane
        // of every variant must be bit-identical to the unwindowed
        // kernel run on that request alone — including tail widths.
        for n in [1usize, 5, 8, 11] {
            let k = 6;
            let rows = 7;
            let reqs: Vec<Matrix> = (0..3)
                .map(|b| weights::init_weight(400 + n as u64 * 10 + b, rows as u32, k as u32))
                .collect();
            let a = stack(&reqs.iter().collect::<Vec<_>>());
            let w = weights::init_weight(500 + n as u64, k as u32, n as u32);

            let mut blocked = Matrix::filled(rows, 3 * n, f32::NAN);
            let mut simd = Matrix::filled(rows, 3 * n, f32::NAN);
            let mut naive = Matrix::filled(rows, 3 * n, f32::NAN);
            for lane in 0..3 {
                matmul_blocked_lane(&a, lane * k, k, &w, &mut blocked, lane * n);
                matmul_simd_lane(&a, lane * k, k, &w, &mut simd, lane * n);
                matmul_naive_lane(&a, lane * k, k, &w, &mut naive, lane * n);
            }
            for (lane, req) in reqs.iter().enumerate() {
                let want = matmul_naive(req, &w);
                let mut want_b = Matrix::zeros(rows, n);
                matmul_blocked(req, &w, &mut want_b);
                for i in 0..rows {
                    let wb: Vec<u32> = want_b.row(i).iter().map(|v| v.to_bits()).collect();
                    let wn: Vec<u32> = want.row(i).iter().map(|v| v.to_bits()).collect();
                    let gb: Vec<u32> = blocked.row(i)[lane * n..(lane + 1) * n]
                        .iter()
                        .map(|v| v.to_bits())
                        .collect();
                    let gs: Vec<u32> = simd.row(i)[lane * n..(lane + 1) * n]
                        .iter()
                        .map(|v| v.to_bits())
                        .collect();
                    let gn: Vec<u32> = naive.row(i)[lane * n..(lane + 1) * n]
                        .iter()
                        .map(|v| v.to_bits())
                        .collect();
                    assert_eq!(gb, wb, "blocked lane {lane} row {i} at n={n}");
                    assert_eq!(gs, wb, "simd lane {lane} row {i} at n={n}");
                    assert_eq!(gn, wn, "naive lane {lane} row {i} at n={n}");
                }
            }
        }
    }

    #[test]
    fn lane_matmul_with_one_lane_matches_unwindowed() {
        // Batch size 1 goes through the same lane code with offset 0;
        // pin that it is literally the unwindowed result.
        let a = weights::init_weight(600, 5, 7);
        let w = weights::init_weight(601, 7, 9);
        let mut want = Matrix::zeros(5, 9);
        matmul_blocked(&a, &w, &mut want);
        let mut got = Matrix::filled(5, 9, f32::NAN);
        matmul_blocked_lane(&a, 0, 7, &w, &mut got, 0);
        assert!(got.bits_eq(&want));
        let mut got = Matrix::filled(5, 9, f32::NAN);
        matmul_simd_lane(&a, 0, 7, &w, &mut got, 0);
        let mut want_s = Matrix::zeros(5, 9);
        matmul_simd(&a, &w, &mut want_s);
        assert!(got.bits_eq(&want_s));
        let mut got = Matrix::filled(5, 9, f32::NAN);
        matmul_naive_lane(&a, 0, 7, &w, &mut got, 0);
        assert!(got.bits_eq(&matmul_naive(&a, &w)));
    }

    #[test]
    fn elw_kernels_apply_op_semantics() {
        let a = [-1.0f32, 0.0, 2.0];
        let mut out = [0.0f32; 3];
        elw_unary(ElwOp::Relu, &a, &mut out);
        assert_eq!(out, [0.0, 0.0, 2.0]);
        let b = [3.0f32, 4.0, 5.0];
        elw_binary(ElwOp::Add, &a, &b, &mut out);
        assert_eq!(out, [2.0, 4.0, 7.0]);
        row_scale(&a, -2.0, &mut out);
        assert_eq!(out, [2.0, -0.0, -4.0]);
    }
}
