//! Instruction definitions.

use std::fmt;

/// Memory-symbol *space* (paper §V-A): where the operand lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Space {
    /// Destination-interval vertex data — lives in the DstBuffer.
    D,
    /// Source vertex data of the current shard — SrcEdgeBuffer.
    S,
    /// Edge data of the current shard — SrcEdgeBuffer.
    E,
    /// Model weights — weight buffer, resident for the whole run.
    W,
}

impl fmt::Display for Space {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let c = match self {
            Space::D => 'D',
            Space::S => 'S',
            Space::E => 'E',
            Space::W => 'W',
        };
        write!(f, "{c}")
    }
}

/// A memory symbol: `%D3`, `%E0`, ... Resolved to buffer addresses by the
/// hardware controller at runtime (the compiler performs liveness merging
/// on these, §V-C3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Sym {
    pub space: Space,
    pub id: u32,
}

impl Sym {
    pub fn new(space: Space, id: u32) -> Self {
        Sym { space, id }
    }
}

impl fmt::Display for Sym {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "%{}{}", self.space, self.id)
    }
}

/// Row-count dimension. Interval/shard-dependent sizes are macros decoded
/// at runtime by the controller (paper §V-A: "a set of macros representing
/// the parameters of intervals and shards").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dim {
    /// Number of destination vertices in the current interval.
    V,
    /// Number of source vertices in the current shard.
    S,
    /// Number of edges in the current shard.
    E,
    /// Compile-time literal (weight matrices, broadcast rows).
    Lit(u32),
}

impl Dim {
    /// Decode against concrete interval/shard sizes.
    #[inline]
    pub fn decode(&self, v: usize, s: usize, e: usize) -> usize {
        match self {
            Dim::V => v,
            Dim::S => s,
            Dim::E => e,
            Dim::Lit(n) => *n as usize,
        }
    }
}

impl fmt::Display for Dim {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Dim::V => write!(f, "V"),
            Dim::S => write!(f, "S"),
            Dim::E => write!(f, "E"),
            Dim::Lit(n) => write!(f, "{n}"),
        }
    }
}

/// Element-wise compute ops (VU).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ElwOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Relu,
    LeakyRelu,
    Exp,
    Sigmoid,
    Tanh,
    Rsqrt,
    Recip,
    Copy,
    /// Add a compile-time scalar (degree-norm epsilons etc.).
    AddScalar(u32), // f32 bits, kept hashable
    /// Multiply by a compile-time scalar.
    MulScalar(u32),
}

impl ElwOp {
    pub fn is_binary(&self) -> bool {
        matches!(
            self,
            ElwOp::Add | ElwOp::Sub | ElwOp::Mul | ElwOp::Div | ElwOp::Max
        )
    }

    pub fn mnemonic(&self) -> String {
        match self {
            ElwOp::Add => "ADD".into(),
            ElwOp::Sub => "SUB".into(),
            ElwOp::Mul => "MUL".into(),
            ElwOp::Div => "DIV".into(),
            ElwOp::Max => "MAXE".into(),
            ElwOp::Relu => "RELU".into(),
            ElwOp::LeakyRelu => "LRELU".into(),
            ElwOp::Exp => "EXP".into(),
            ElwOp::Sigmoid => "SIGM".into(),
            ElwOp::Tanh => "TANH".into(),
            ElwOp::Rsqrt => "RSQRT".into(),
            ElwOp::Recip => "RECIP".into(),
            ElwOp::Copy => "CPY".into(),
            ElwOp::AddScalar(b) => format!("ADDI[{}]", f32::from_bits(*b)),
            ElwOp::MulScalar(b) => format!("MULI[{}]", f32::from_bits(*b)),
        }
    }
}

/// Gather reduction functions (paper §II-A).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Reduce {
    Sum,
    Max,
    Mean,
}

impl Reduce {
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Reduce::Sum => "SUM",
            Reduce::Max => "MAX",
            Reduce::Mean => "MEAN",
        }
    }
}

/// Scatter direction: which endpoint's embedding is copied onto each edge.
/// `SCTR.F` (forward: src→edge) / `SCTR.B` (backward: dst→edge) in Tbl II.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ScatterDir {
    SrcToEdge,
    DstToEdge,
}

/// What DRAM-backed array a memory instruction refers to. The symbol names
/// the on-chip buffer slot; `DataRef` names the off-chip storage. (The
/// hardware controller derives concrete addresses from this at runtime,
/// §V-A; the functional executor and the simulator's traffic accounting
/// both key on it.)
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DataRef {
    /// The model's input feature matrix `[N, in_dim]`.
    Input,
    /// Per-vertex in-degree column `[N, 1]`.
    Degree,
    /// The DRAM spill/result array of IR node `id` (vertex-located:
    /// `[N, cols]`, rows indexed by global vertex id; edge-located:
    /// `[M, cols]`, rows indexed by canonical edge id).
    Node(usize),
}

impl DataRef {
    /// Dense arena index for DRAM-backed arrays: `Input` and `Degree`
    /// first, then one slot per IR node id. Both functional backends
    /// address off-chip storage through this instead of hashing the enum
    /// (see `Program::slot_layout`).
    pub fn slot(&self) -> usize {
        match self {
            DataRef::Input => 0,
            DataRef::Degree => 1,
            DataRef::Node(n) => 2 + n,
        }
    }
}

impl fmt::Display for DataRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DataRef::Input => write!(f, "@input"),
            DataRef::Degree => write!(f, "@degree"),
            DataRef::Node(n) => write!(f, "@n{n}"),
        }
    }
}

/// A single SWITCHBLADE instruction.
#[derive(Clone, Debug, PartialEq)]
pub enum Instr {
    /// ELW — element-wise op on the VU. `b` is the second operand for
    /// binary ops; if `broadcast_b`, `b` is a single row `[1, cols]`
    /// broadcast across `rows` (bias adds, per-head scalars).
    Elw {
        op: ElwOp,
        dst: Sym,
        a: Sym,
        b: Option<Sym>,
        broadcast_b: bool,
        rows: Dim,
        cols: u32,
    },
    /// Row-broadcast multiply by a per-row scalar column: `dst[r, c] =
    /// a[r, c] * s[r, 0]`. Used for degree normalisation and attention
    /// weighting (kept distinct from `Elw` because the VU reads the scalar
    /// operand once per row — different energy/bandwidth profile).
    RowScale {
        dst: Sym,
        a: Sym,
        scale: Sym,
        rows: Dim,
        cols: u32,
    },
    /// Feature concatenation on the VU: `dst = [a || b]` column-wise.
    Concat {
        dst: Sym,
        a: Sym,
        b: Sym,
        rows: Dim,
        cols_a: u32,
        cols_b: u32,
    },
    /// DMM — dense matmul on the MU: `dst[rows, n] = a[rows, k] × w[k, n]`.
    Dmm {
        dst: Sym,
        a: Sym,
        w: Sym,
        rows: Dim,
        k: u32,
        n: u32,
    },
    /// GTR scatter — copy an endpoint embedding onto each edge of the shard.
    Scatter {
        dir: ScatterDir,
        dst: Sym, // E-space
        src: Sym, // S-space (SrcToEdge) or D-space (DstToEdge)
        cols: u32,
    },
    /// GTR gather — segment-reduce shard edges into destination rows:
    /// `dst[d, :] ⊕= src[e, :]` for every edge `e` with destination `d`.
    /// This is the only GatherPhase op with cross-shard dependencies
    /// (paper §IV-C), handled by the accumulating semantics.
    Gather {
        reduce: Reduce,
        dst: Sym, // D-space accumulator
        src: Sym, // E-space
        cols: u32,
    },
    /// PLOF-fused GTR (compiler peephole): scatter source rows onto
    /// in-edges, optionally scale each edge row by a resident `[E,1]`
    /// column, and segment-reduce into the destination accumulator — all
    /// without materialising `[E, cols]` edge data in the SrcEdgeBuffer.
    /// This is the instruction-level heart of partition-level operator
    /// fusion: it removes the dominant `num_edge × dim_edge` term from
    /// Equ. 1 for GCN/SAGE/GGNN-style aggregation.
    FusedGather {
        reduce: Reduce,
        dst: Sym, // D-space accumulator
        src: Sym, // S-space source rows
        scale: Option<Sym>, // E-space [E,1] per-edge coefficient
        cols: u32,
    },
    /// Memory — load a symbol's backing data from DRAM into its buffer.
    /// The rows transferred depend on the symbol space: `S` loads the
    /// current shard's source-vertex rows, `E` the shard's edge rows, `D`
    /// the current destination interval's rows.
    Ld {
        sym: Sym,
        data: DataRef,
        rows: Dim,
        cols: u32,
    },
    /// Memory — store a symbol from its buffer to DRAM.
    St {
        sym: Sym,
        data: DataRef,
        rows: Dim,
        cols: u32,
    },
}

impl Instr {
    /// Destination symbol written by this instruction (None for St).
    pub fn def(&self) -> Option<Sym> {
        match self {
            Instr::Elw { dst, .. }
            | Instr::RowScale { dst, .. }
            | Instr::Concat { dst, .. }
            | Instr::Dmm { dst, .. }
            | Instr::Scatter { dst, .. }
            | Instr::Gather { dst, .. }
            | Instr::FusedGather { dst, .. } => Some(*dst),
            Instr::Ld { sym, .. } => Some(*sym),
            Instr::St { .. } => None,
        }
    }

    /// Symbols read by this instruction.
    pub fn uses(&self) -> Vec<Sym> {
        match self {
            Instr::Elw { a, b, .. } => {
                let mut v = vec![*a];
                if let Some(b) = b {
                    v.push(*b);
                }
                v
            }
            Instr::RowScale { a, scale, .. } => vec![*a, *scale],
            Instr::Concat { a, b, .. } => vec![*a, *b],
            Instr::Dmm { a, w, .. } => vec![*a, *w],
            Instr::Scatter { src, .. } => vec![*src],
            Instr::Gather { src, dst, .. } => vec![*src, *dst], // accumulates
            Instr::FusedGather { src, dst, scale, .. } => {
                let mut v = vec![*src, *dst];
                if let Some(s) = scale {
                    v.push(*s);
                }
                v
            }
            Instr::Ld { .. } => vec![],
            Instr::St { sym, .. } => vec![*sym],
        }
    }

    /// Which functional unit executes this instruction. Matrix-*vector*
    /// products (attention projections, `n ≤ 4`) run on the VU's
    /// dot-product datapath — mapping them onto the 32×128 systolic array
    /// would light up a single output column.
    pub fn unit(&self) -> Unit {
        match self {
            Instr::Dmm { n, .. } if *n <= 4 => Unit::Vu,
            Instr::Dmm { .. } => Unit::Mu,
            Instr::Ld { .. } | Instr::St { .. } => Unit::Lsu,
            _ => Unit::Vu,
        }
    }

    /// Assembly-ish rendering for dumps and tests.
    pub fn render(&self) -> String {
        match self {
            Instr::Elw {
                op,
                dst,
                a,
                b,
                broadcast_b,
                rows,
                cols,
            } => {
                let b_s = b
                    .map(|b| {
                        format!(", {}{}", b, if *broadcast_b { "(bc)" } else { "" })
                    })
                    .unwrap_or_default();
                format!("{:9} {dst}, {a}{b_s} [{rows}x{cols}]", op.mnemonic())
            }
            Instr::RowScale {
                dst,
                a,
                scale,
                rows,
                cols,
            } => format!("RSCALE    {dst}, {a}, {scale} [{rows}x{cols}]"),
            Instr::Concat {
                dst,
                a,
                b,
                rows,
                cols_a,
                cols_b,
            } => format!("CAT       {dst}, {a}, {b} [{rows}x({cols_a}+{cols_b})]"),
            Instr::Dmm { dst, a, w, rows, k, n } => {
                format!("GEMM      {dst}, {a}, {w} [{rows}x{k}x{n}]")
            }
            Instr::Scatter { dir, dst, src, cols } => {
                let m = match dir {
                    ScatterDir::SrcToEdge => "SCTR.F",
                    ScatterDir::DstToEdge => "SCTR.B",
                };
                format!("{m:9} {dst}, {src} [Ex{cols}]")
            }
            Instr::Gather {
                reduce,
                dst,
                src,
                cols,
            } => format!("GTHR.{:4} {dst}, {src} [Ex{cols}]", reduce.mnemonic()),
            Instr::FusedGather {
                reduce,
                dst,
                src,
                scale,
                cols,
            } => {
                let sc = scale.map(|s| format!(", {s}")).unwrap_or_default();
                format!("GSCTR.{:4} {dst}, {src}{sc} [Ex{cols}]", reduce.mnemonic())
            }
            Instr::Ld { sym, data, rows, cols } => {
                format!("LD.{:6} {sym}, {data} [{rows}x{cols}]", sym.space.to_string())
            }
            Instr::St { sym, data, rows, cols } => {
                format!("ST.{:6} {sym}, {data} [{rows}x{cols}]", sym.space.to_string())
            }
        }
    }
}

/// Functional units of the accelerator (paper Fig 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Unit {
    /// Vector unit — 16×SIMD32 cores (ELW + GTR).
    Vu,
    /// Matrix unit — 32×128 output-stationary systolic array (DMM).
    Mu,
    /// Load-store unit — DRAM transfers.
    Lsu,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sym_display() {
        assert_eq!(Sym::new(Space::D, 3).to_string(), "%D3");
        assert_eq!(Sym::new(Space::E, 0).to_string(), "%E0");
    }

    #[test]
    fn dim_decode() {
        assert_eq!(Dim::V.decode(10, 20, 30), 10);
        assert_eq!(Dim::S.decode(10, 20, 30), 20);
        assert_eq!(Dim::E.decode(10, 20, 30), 30);
        assert_eq!(Dim::Lit(7).decode(10, 20, 30), 7);
    }

    #[test]
    fn def_use_chains() {
        let i = Instr::Dmm {
            dst: Sym::new(Space::D, 1),
            a: Sym::new(Space::D, 0),
            w: Sym::new(Space::W, 0),
            rows: Dim::V,
            k: 128,
            n: 128,
        };
        assert_eq!(i.def(), Some(Sym::new(Space::D, 1)));
        assert_eq!(i.uses(), vec![Sym::new(Space::D, 0), Sym::new(Space::W, 0)]);
        assert_eq!(i.unit(), Unit::Mu);
    }

    #[test]
    fn gather_reads_its_accumulator() {
        let g = Instr::Gather {
            reduce: Reduce::Sum,
            dst: Sym::new(Space::D, 2),
            src: Sym::new(Space::E, 1),
            cols: 128,
        };
        assert!(g.uses().contains(&Sym::new(Space::D, 2)));
        assert_eq!(g.unit(), Unit::Vu);
    }

    #[test]
    fn units() {
        let ld = Instr::Ld {
            sym: Sym::new(Space::S, 0),
            data: DataRef::Input,
            rows: Dim::S,
            cols: 128,
        };
        assert_eq!(ld.unit(), Unit::Lsu);
        assert_eq!(ld.def(), Some(Sym::new(Space::S, 0)));
        let relu = Instr::Elw {
            op: ElwOp::Relu,
            dst: Sym::new(Space::D, 0),
            a: Sym::new(Space::D, 0),
            b: None,
            broadcast_b: false,
            rows: Dim::V,
            cols: 64,
        };
        assert_eq!(relu.unit(), Unit::Vu);
    }

    #[test]
    fn render_smoke() {
        let i = Instr::Scatter {
            dir: ScatterDir::SrcToEdge,
            dst: Sym::new(Space::E, 0),
            src: Sym::new(Space::S, 0),
            cols: 128,
        };
        assert!(i.render().contains("SCTR.F"));
        assert!(i.render().contains("%E0"));
    }
}
