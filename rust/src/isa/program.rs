//! Compiled program representation: PLOF phase groups + symbol table +
//! partitioning parameters.

use std::collections::HashMap;

use super::{Dim, Instr, Space, Sym};

/// Metadata for one memory symbol.
#[derive(Clone, Debug, PartialEq)]
pub struct SymInfo {
    pub sym: Sym,
    /// Feature width (columns) of the symbol's rows.
    pub cols: u32,
    /// Row dimension (macro) the symbol is sized by.
    pub rows: Dim,
    /// Human-readable origin (op name in the IR), for dumps/debugging.
    pub origin: String,
}

/// Symbol table: per-space symbol metadata, after liveness merging.
#[derive(Clone, Debug, Default)]
pub struct SymbolTable {
    entries: HashMap<Sym, SymInfo>,
}

impl SymbolTable {
    pub fn insert(&mut self, info: SymInfo) {
        self.entries.insert(info.sym, info);
    }

    pub fn get(&self, sym: Sym) -> Option<&SymInfo> {
        self.entries.get(&sym)
    }

    pub fn cols(&self, sym: Sym) -> u32 {
        self.entries
            .get(&sym)
            .unwrap_or_else(|| panic!("unknown symbol {sym}"))
            .cols
    }

    pub fn iter(&self) -> impl Iterator<Item = &SymInfo> {
        self.entries.values()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total feature width of all symbols in a space (Σ cols). This is how
    /// the compiler derives `dim_src` (space S) and `dim_edge` (space E)
    /// for the graph partitioner (paper §V-C3).
    pub fn total_cols(&self, space: Space) -> u32 {
        self.entries
            .values()
            .filter(|s| s.sym.space == space)
            .map(|s| s.cols)
            .sum()
    }

    /// Number of distinct symbols in a space.
    pub fn count(&self, space: Space) -> usize {
        self.entries.values().filter(|s| s.sym.space == space).count()
    }
}

/// Dense slot counts per symbol space plus the width of the DRAM arena,
/// computed once per program from the symbol table and the instruction
/// stream. The functional executor and the cycle simulator allocate flat
/// `Vec`-indexed arenas of these sizes instead of hashing `Sym`s on every
/// instruction — symbol ids are small and dense after liveness merging,
/// so a slot lookup is one bounds-checked index.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SlotLayout {
    /// Slots for D-space symbols (destination-interval data).
    pub d: usize,
    /// Slots for S-space symbols (shard source-vertex data).
    pub s: usize,
    /// Slots for E-space symbols (shard edge data).
    pub e: usize,
    /// Slots for W-space symbols (resident weights).
    pub w: usize,
    /// Slots for `DataRef` arrays (`DataRef::slot()` indexes).
    pub dram: usize,
}

impl SlotLayout {
    fn grow_sym(&mut self, sym: Sym) {
        let c = match sym.space {
            Space::D => &mut self.d,
            Space::S => &mut self.s,
            Space::E => &mut self.e,
            Space::W => &mut self.w,
        };
        *c = (*c).max(sym.id as usize + 1);
    }
}

/// One PLOF phase group: the unit of a full dual-sliding-window sweep
/// (paper Alg 2). A model compiles to one or more groups executed in
/// sequence; each group's GatherPhase iterates shards, Scatter/ApplyPhase
/// iterate intervals.
#[derive(Clone, Debug, Default)]
pub struct PhaseGroup {
    /// Executed by the iThread per *source-side* interval before shards
    /// stream (per-vertex pre-processing feeding Scatter data).
    pub scatter: Vec<Instr>,
    /// Executed by sThreads per shard.
    pub gather: Vec<Instr>,
    /// Executed by the iThread per destination interval after all its
    /// shards completed.
    pub apply: Vec<Instr>,
}

impl PhaseGroup {
    pub fn all_instrs(&self) -> impl Iterator<Item = &Instr> {
        self.scatter
            .iter()
            .chain(self.gather.iter())
            .chain(self.apply.iter())
    }

    pub fn len(&self) -> usize {
        self.scatter.len() + self.gather.len() + self.apply.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Weight tensor carried with the program (resident in the weight buffer).
#[derive(Clone, Debug)]
pub struct WeightInfo {
    pub sym: Sym,
    pub rows: u32,
    pub cols: u32,
    /// Deterministic init seed — the functional executor and the JAX oracle
    /// must generate identical weights.
    pub seed: u64,
}

/// A fully compiled GNN model.
#[derive(Clone, Debug, Default)]
pub struct Program {
    pub model_name: String,
    /// True when `groups[0]` is the prologue sweep (per-vertex projection
    /// precompute; empty GatherPhase).
    pub has_prologue: bool,
    pub groups: Vec<PhaseGroup>,
    pub symbols: SymbolTable,
    pub weights: Vec<WeightInfo>,
    /// Σ cols of S-space symbols per GatherPhase — partitioner input.
    pub dim_src: u32,
    /// Σ cols of E-space symbols per GatherPhase — partitioner input.
    pub dim_edge: u32,
    /// Σ cols of D-space symbols — sizes the destination interval.
    pub dim_dst: u32,
    /// Input feature width (per vertex).
    pub in_dim: u32,
    /// Output feature width (per vertex).
    pub out_dim: u32,
}

impl Program {
    /// Total instruction count across groups.
    pub fn num_instrs(&self) -> usize {
        self.groups.iter().map(|g| g.len()).sum()
    }

    /// Weight bytes (f32) — resident footprint in the weight buffer.
    pub fn weight_bytes(&self) -> u64 {
        self.weights
            .iter()
            .map(|w| w.rows as u64 * w.cols as u64 * 4)
            .sum()
    }

    /// Compute the dense arena sizes for this program: the union of the
    /// symbol table, the weight list, and every symbol / `DataRef`
    /// mentioned by an instruction (defensive — liveness merging keeps
    /// the table authoritative, but a hand-built test program may skip it).
    pub fn slot_layout(&self) -> SlotLayout {
        let mut l = SlotLayout::default();
        for info in self.symbols.iter() {
            l.grow_sym(info.sym);
        }
        for w in &self.weights {
            l.grow_sym(w.sym);
        }
        for g in &self.groups {
            for i in g.all_instrs() {
                if let Some(d) = i.def() {
                    l.grow_sym(d);
                }
                for u in i.uses() {
                    l.grow_sym(u);
                }
                if let Instr::Ld { data, .. } | Instr::St { data, .. } = i {
                    l.dram = l.dram.max(data.slot() + 1);
                }
            }
        }
        // Input and Degree are always addressable (the host seeds them).
        l.dram = l.dram.max(2);
        l
    }

    /// Assembly dump of the whole program (used by `switchblade compile`).
    pub fn disassemble(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "; model={} groups={} dim_src={} dim_edge={} dim_dst={}\n",
            self.model_name,
            self.groups.len(),
            self.dim_src,
            self.dim_edge,
            self.dim_dst
        ));
        for (gi, g) in self.groups.iter().enumerate() {
            out.push_str(&format!("group {gi}:\n"));
            for (name, phase) in [
                ("ScatterPhase", &g.scatter),
                ("GatherPhase", &g.gather),
                ("ApplyPhase", &g.apply),
            ] {
                out.push_str(&format!("  .{name}:\n"));
                for i in phase {
                    out.push_str(&format!("    {}\n", i.render()));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{DataRef, ElwOp, Reduce, ScatterDir};

    fn sample_program() -> Program {
        let s0 = Sym::new(Space::S, 0);
        let e0 = Sym::new(Space::E, 0);
        let d0 = Sym::new(Space::D, 0);
        let w0 = Sym::new(Space::W, 0);
        let mut symbols = SymbolTable::default();
        symbols.insert(SymInfo {
            sym: s0,
            cols: 16,
            rows: Dim::S,
            origin: "input".into(),
        });
        symbols.insert(SymInfo {
            sym: e0,
            cols: 16,
            rows: Dim::E,
            origin: "scatter".into(),
        });
        symbols.insert(SymInfo {
            sym: d0,
            cols: 16,
            rows: Dim::V,
            origin: "gather".into(),
        });
        let group = PhaseGroup {
            scatter: vec![],
            gather: vec![
                Instr::Ld {
                    sym: s0,
                    data: DataRef::Input,
                    rows: Dim::S,
                    cols: 16,
                },
                Instr::Scatter {
                    dir: ScatterDir::SrcToEdge,
                    dst: e0,
                    src: s0,
                    cols: 16,
                },
                Instr::Gather {
                    reduce: Reduce::Sum,
                    dst: d0,
                    src: e0,
                    cols: 16,
                },
            ],
            apply: vec![
                Instr::Dmm {
                    dst: d0,
                    a: d0,
                    w: w0,
                    rows: Dim::V,
                    k: 16,
                    n: 16,
                },
                Instr::Elw {
                    op: ElwOp::Relu,
                    dst: d0,
                    a: d0,
                    b: None,
                    broadcast_b: false,
                    rows: Dim::V,
                    cols: 16,
                },
                Instr::St {
                    sym: d0,
                    data: DataRef::Node(5),
                    rows: Dim::V,
                    cols: 16,
                },
            ],
        };
        Program {
            model_name: "toy".into(),
            has_prologue: false,
            groups: vec![group],
            symbols,
            weights: vec![WeightInfo {
                sym: w0,
                rows: 16,
                cols: 16,
                seed: 1,
            }],
            dim_src: 16,
            dim_edge: 16,
            dim_dst: 16,
            in_dim: 16,
            out_dim: 16,
        }
    }

    #[test]
    fn totals() {
        let p = sample_program();
        assert_eq!(p.num_instrs(), 6);
        assert_eq!(p.weight_bytes(), 16 * 16 * 4);
        assert_eq!(p.symbols.total_cols(Space::S), 16);
        assert_eq!(p.symbols.count(Space::D), 1);
    }

    #[test]
    fn slot_layout_covers_symbols_weights_and_dram() {
        let l = sample_program().slot_layout();
        assert_eq!((l.d, l.s, l.e, l.w), (1, 1, 1, 1));
        // DataRef::Node(5) → slot 7, so the arena must hold 8 slots.
        assert_eq!(l.dram, 8);
        // An empty program still addresses Input and Degree.
        assert_eq!(Program::default().slot_layout().dram, 2);
    }

    #[test]
    fn disassemble_contains_phases() {
        let d = sample_program().disassemble();
        assert!(d.contains(".ScatterPhase"));
        assert!(d.contains(".GatherPhase"));
        assert!(d.contains(".ApplyPhase"));
        assert!(d.contains("GTHR.SUM"));
        assert!(d.contains("GEMM"));
    }
}
