//! SWITCHBLADE instruction set architecture (paper §V-A, Tbl II).
//!
//! Instructions have three fields:
//!  * `opname` — the operation (Compute: ELW / DMM / GTR; Memory: LD / ST),
//!  * `data-dimension` — shape parameters; sizes that depend on the current
//!    interval/shard are *macros* (`Dim::V`, `Dim::E`, `Dim::S`) decoded by
//!    the hardware controller at runtime,
//!  * `memory-symbol` — symbolic operands naming on-chip buffer locations,
//!    typed `D` (destination interval data), `S` (source vertex data in a
//!    shard) or `E` (edge data in a shard), plus `W` for resident weights.
//!
//! A compiled model is a [`Program`]: three phase instruction sequences
//! (ScatterPhase / GatherPhase / ApplyPhase) plus the symbol table and the
//! partitioning parameters (`dim_src`, `dim_edge`) exported to the graph
//! partitioner.

mod instr;
mod program;

pub use instr::*;
pub use program::*;
