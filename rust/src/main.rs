//! `switchblade` — the leader binary: compile models, partition graphs,
//! simulate the accelerator, regenerate the paper's figures, and serve
//! AOT-compiled GNN inference over PJRT.
//!
//! (clap is not available in the offline build image; the argument parser
//! is hand-rolled but follows the same subcommand conventions.)

use std::path::PathBuf;
use std::process::ExitCode;
use std::sync::Arc;

use switchblade::compiler::compile;
use switchblade::coordinator::{bench_executor, BenchRequest, Caches, Harness};
use switchblade::dse::{self, Objective, TuneOptions};
use switchblade::exec::{weights, KernelMode, PipelineMode};
use switchblade::graph::datasets::{Dataset, DEFAULT_SCALE};
use switchblade::ir::spec::{ModelDims, ModelSpec};
use switchblade::ir::zoo::ModelZoo;
use switchblade::obs::{metrics, trace};
use switchblade::partition::{stats as pstats, Method};
use switchblade::runtime::{artifacts_dir, ArtifactShape, Runtime};
use switchblade::sim::{simulate, AcceleratorConfig};
use switchblade::util::report::{bytes, f as ff, Table};

/// Usage text; the MODELS line is generated from the zoo so a registered
/// model is never missing from the help (and a removed one never lingers).
fn usage() -> String {
    let models = ModelZoo::builtin().names().join(" ").to_uppercase();
    format!(
        "\
switchblade — generic GNN acceleration via architecture/compiler/partition co-design

USAGE:
    switchblade <COMMAND> [OPTIONS]

COMMANDS:
    compile   <model>                      dump the compiled PLOF/ISA program
    partition <dataset> [--scale N] [--method fggp|dsw] [--model M]
                                           partition a graph and print stats
    simulate  <model> <dataset> [--scale N] [--sthreads T] [--method fggp|dsw]
              [--trace F] [--metrics F]    cycle-level simulation of one workload
    tune      <model> <dataset> [--scale N] [--budget N] [--objective latency|energy|edp]
              [--out DIR] [--trace F] [--metrics F]
                                           design-space exploration: sweep accelerator
                                           + partition configs, report Pareto frontier
                                           (budget 0 = exhaustive; default 64)
    repro     [--fig 7|8|9|10|11|12|13] [--tbl 4|5] [--all] [--scale N] [--out DIR]
              [--config FILE]              regenerate the paper's figures/tables
    serve     [MODELS...] [--model M[,M,...]] [--model-file PATH] [--dataset D]
              [--scale N] [--requests R] [--verify] [--queue-depth N] [--batch N]
              [--pool-workers W] [--kernel K] [--pipeline P] [--layers N] [--dim D]
              [--config FILE] [--backend native|pjrt]
              [--inject SPEC] [--deadline-ms N]
              [--bench [--qps N] [--duration S] [--out F]]
              [--trace F] [--metrics F]    persistent inference engine over the
                                           native executor; any zoo/spec model is
                                           servable (see SERVING, RELIABILITY)
    validate  [--scale N] [--layers N] [--dim D] [--model M] [--pipeline on|group|off]
              [--trace F] [--metrics F]    executor-vs-oracle numerics check over the
                                           zoo (or one model / spec file)
    bench     [--model M] [--dataset D] [--scale N] [--iters N] [--workers W]
              [--pool-workers W] [--layers N] [--dim D] [--kernel naive|blocked|simd]
              [--pipeline on|group|off] [--sweep] [--profile] [--batch-size B]
              [--trace F] [--metrics F]    functional-executor throughput probe
                                           (single vs shard-parallel; bench.sh
                                           folds this into BENCH_exec.json)
    help                                   this text

MODELS:   {models}
          or any .gnn spec file via --model-file PATH (accepted wherever a
          model is; grammar documented in rust/src/ir/spec.rs)
DATASETS: AK AD HW CP SL

TUNED CONFIGS (--config):
    `repro` and `serve` accept a `dse_*_frontier.json|csv` (or sweep)
    artifact written by `switchblade tune`; its latency-champion row
    replaces the hard-coded Tbl III accelerator. `repro --config`
    re-renders every figure on the tuned hardware; `serve --config`
    builds every engine entry's partitioning on the tuned
    (accelerator, method) point (and, under `--backend pjrt`, prints
    the predicted accelerator latency for the serving shape).

SERVING (serve):
    `serve` runs a persistent inference engine over the native
    executor. Each registered (model, graph) entry owns its compiled
    Program, partitions, and one warm executor — persistent worker
    pool + scratch arenas reused across requests — on a dedicated
    thread, so compile/partition/warm-up are paid once per entry, not
    per request. Register several models at once (positionals, a
    comma-separated `--model` list, and/or `--model-file`); entries
    micro-batch independently and drain concurrently. Requests flow
    through a bounded submission queue (`--queue-depth`, default 64)
    with micro-batching (`--batch`, default 8: one wakeup serves the
    whole queued burst up to the cap, no batching timer). A full queue
    rejects new work with a typed error — admission control, never
    unbounded latency — and a request producing non-finite output
    fails alone (counted in `serve_errors`); the engine keeps serving.
    `--verify` first pins every entry bit-identical to a direct
    (cold) executor run of the same seed, then prints
    `serve_verified=ok`. `--backend pjrt` instead serves the four
    paper models' AOT artifacts through the PJRT runtime (requires
    the `pjrt` feature + `make artifacts`); spec-defined models have
    no artifacts and are exactly what the native engine is for.

    `serve --bench` runs the load generator and writes BENCH_serve.json
    (`--out`, default BENCH_serve.json): flat JSON with serve_qps,
    serve_p50_ms / serve_p95_ms / serve_p99_ms / serve_mean_ms,
    serve_requests / serve_rejected / serve_errors, serve_wall_s and
    serve_mode. Closed loop by default (`--requests N` back to back
    over a small in-flight window); `--qps N --duration S` switches to
    open loop: fixed-rate arrivals, sojourn-time percentiles, and
    rejections counted when the engine can't keep up.
    scripts/bench.sh folds the artifact beside BENCH_exec.json and
    scripts/bench_diff.sh gates its p50/p99 keys in CI.

RELIABILITY (serve --inject / --deadline-ms):
    The serving stack survives a misbehaving model without taking the
    process — or its neighbor entries — down. A worker-pool panic fails
    only the in-flight batch (typed `Faulted` errors on its tickets);
    the pool catches the panic, rebuilds the worker's scratch (thread
    respawn if needed — PoolStats `respawned`), and the entry rebuilds
    its warm executor with capped exponential backoff and resumes
    bit-identically (`serve_entry_restarts`). Persistent faults walk a
    degradation ladder of bit-identical rungs — configured modes →
    pipelining off → naive kernel — and finally quarantine the entry:
    alive, answering typed `Quarantined` rejections (`serve_degraded`,
    `serve_quarantined`). Stats probes never block behind saturation:
    a full queue answers a typed `StatsUnavailable`.

    --deadline-ms N  bound every bench request: expired-in-queue
                 requests are answered `DeadlineExceeded` without
                 running, and result waits use the same bound; both
                 count into `serve_timeouts`.
    --inject SPEC    deterministic fault injection (obs::faultinject),
                 the chaos tests' driver. SPEC is comma-separated
                 points `site[@key=val]...` with sites worker_panic |
                 slow_shard | nonfinite_output | queue_stall and keys
                 shard=K (worker_panic/slow_shard: fire only on shard
                 K), skip=N (let N triggers pass first), count=N (fire
                 at most N times, default 1), delay_ms=N (sleep length
                 for slow_shard/queue_stall, default 5). Example:
                 --inject 'worker_panic@shard=0@skip=1' panics the
                 second visit to shard 0, exercising the whole
                 recovery path in one bench run; disarmed (no flag),
                 every injection site is a single relaxed atomic load.
                 Armed runs print a `serve_faults_injected=` trailer.
                 Fault/recovery counters (serve_errors, serve_timeouts,
                 exec_worker_panics, serve_entry_restarts, ...) are
                 deliberately NOT gated by bench_diff.sh.

BATCHING (bench --batch-size / serve --batch):
    Requests that share a (model, graph) entry also share its Program,
    partitions, and degree column — so a micro-batch executes as ONE
    batched run: the executor column-stacks the B feature matrices and
    performs a single partition walk, applying each interval's scatter
    LDs, gather accumulator setup, and shard traversal once across the
    whole batch instead of once per request. Per-request FP reduction
    order is preserved (weight operands get per-lane windows), so every
    member's output is bit-identical to a solo run — differential- and
    integration-tested. `serve --batch N` caps the micro-batch (the
    serving engine drains up to N queued requests into one batched
    run; deadlines stay per-request). `bench --batch-size B` adds the
    executor-level amortization probe: B back-to-back solo runs timed
    against one batched run of the same B inputs on a warm executor,
    reported as the `exec_batch=` and `exec_batch_amortization=`
    trailers (solo/batched, higher is better, > 1 means sharing the
    walk paid off) and the matching metrics-registry gauges.
    scripts/bench.sh records serve p50 at batch caps 1 and 8
    (`serve_batch1_p50_ms` / `serve_batch8_p50_ms`, gated by
    scripts/bench_diff.sh) plus the amortization factor in
    BENCH_serve.json.

PIPELINE (bench/validate --pipeline on|group|off, default on):
    The functional executor overlaps consecutive destination intervals
    (PipelineMode::Interval): while interval i's shards drain through the
    worker pool, interval i+1's DstBuffer state is prepared from a second
    buffer set — the software analogue of the paper's partition-level
    multi-threading (§IV-C), bit-identical to the sequential order.
    `--pipeline group` stretches the overlap further: a dedicated
    prepare lane carries the next interval's prologue past the gather
    drain, across the ApplyPhase and — where the conservative slot-
    disjointness gate allows — across the group boundary into the next
    group's prologue. `--pipeline off` forces the strictly sequential
    reference — the escape hatch for diffing a suspected pipelining
    issue (`validate --pipeline off` re-runs the oracle check that way).
    When pipelined, bench also times the off mode at the same worker
    count; all per-mode numbers land in the `--metrics` registry and the
    OBSERVABILITY trailers. `repro` figures come from the cycle
    simulator, whose SLMT timing always models this overlap — there is
    no executor mode to toggle there. `bench --trace` makes the overlap
    visible: `prepare` spans sit under `gather_drain` on the main lane
    (or on their own lane in group mode) while `shard` spans fill the
    worker lanes.

WORKER POOL + KERNELS (bench --pool-workers / --kernel / --sweep):
    Shards run on a persistent worker pool: sThreads are spawned once
    per executor (never per interval), each owning its scratch arenas,
    with static strided shard→worker affinity (shard k goes to worker
    k mod W — deterministic placement, so per-worker scratch stays warm
    across intervals and runs). `--pool-workers W` (alias: `--workers`)
    sets the pool width; W=1 runs shards inline on the driving thread
    with no pool at all. `--kernel naive|blocked|simd` picks the compute
    layer of the timed runs: `blocked` (default) is the cache-blocked
    kernel tier, `simd` the explicit chunks-of-8 accumulator tier
    (portable safe code, bit-identical to blocked), `naive` the
    preserved pre-kernel reference. A simd probe is timed alongside
    either way (`exec_ms_simd=`). `--sweep` adds a 1/2/4/8-worker
    scaling ladder at the chosen kernel (`exec_ms_w1..w8=`); every
    width must reproduce the same bits.

PROFILER (bench --profile):
    Adds a walk-level profile of one shard-parallel run: a table with one
    row per (group, phase) — scatter / gather / apply plus a `prepare`
    row counting next-interval preparations overlapped under the gather
    drain — columns time ms / calls / mean us / share — plus a TOTAL row,
    and also times the preserved naive (pre-kernel) executor for a
    kernel-vs-legacy comparison. The profile is folded from the same
    span stream `--trace` exports (sched::PhaseProfile::from_spans), so
    profile and trace always agree. Adds the `exec_ms_legacy=` and
    `exec_profile_json=` trailers (see OBSERVABILITY).

OBSERVABILITY (--trace F / --metrics F on bench, simulate, validate, serve, tune):
    --trace F    record a span timeline of the whole run — compile,
                 partition, every walk phase (scatter / gather_shard /
                 gather_drain / apply), pipelined `prepare` steps, and
                 per-worker `shard` spans — and write Chrome trace-event
                 JSON to F. Load it in chrome://tracing or
                 https://ui.perfetto.dev: one lane per executor worker
                 plus a main/prepare lane; interval-pipelining overlap
                 appears as `prepare` spans nested under `gather_drain`.
    --metrics F  write the process metrics registry to F after the run:
                 flat JSON (one \"name\": value per line), or Prometheus
                 text when F ends in `.prom`. Series include the
                 executor probe (exec_ms_single / exec_ms_parallel /
                 exec_ms_simd / exec_ms_pipeline_off / exec_ms_legacy /
                 exec_ms_w1..w8 under --sweep / exec_workers /
                 exec_speedup / exec_simd_speedup /
                 exec_pipeline_speedup / exec_prepared / exec_bitmatch /
                 exec_scratch_hits / exec_scratch_misses /
                 exec_scratch_hit_rate / exec_pool_spawned /
                 exec_pool_batches / exec_pool_shards /
                 exec_pool_utilization / exec_pool_queue_depth),
                 the simulator (sim_cycles /
                 sim_latency_s / sim_vu|mu|bw|overall_utilization /
                 sim_traffic_bytes_* per tag), the serving engine
                 (serve_requests / serve_batches / serve_rejected /
                 serve_errors counters, serve_latency_s / serve_wait_s /
                 serve_batch_size / serve_warm_s histograms, serve_qps +
                 serve_p50_ms/p95/p99 gauges; `serve --trace` adds
                 request/batch spans on per-entry lanes), validation deltas
                 (validate_max_abs_diff_*), and DSE cache accounting
                 (dse_cache_{graphs,programs,partitions}_*).
    The same `exec_*` names are also printed as `key=value` stdout
    trailers by bench (kept for greppability); scripts/bench.sh builds
    BENCH_exec.json from the `--metrics` artifact, and
    scripts/bench_diff.sh gates CI on it against main's baseline.
"
    )
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { &args[..] } else { &args[1..] };
    let r = match cmd {
        "compile" => cmd_compile(rest),
        "partition" => cmd_partition(rest),
        "simulate" => cmd_simulate(rest),
        "tune" => cmd_tune(rest),
        "repro" => cmd_repro(rest),
        "serve" => cmd_serve(rest),
        "validate" => cmd_validate(rest),
        "bench" => cmd_bench(rest),
        "help" | "--help" | "-h" => {
            print!("{}", usage());
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n\n{}", usage())),
    };
    match r {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

// ---- option helpers ----------------------------------------------------------

/// Options that consume the following token as their value; everything
/// else starting with `--` is a bare flag.
const VALUE_OPTS: &[&str] = &[
    "--scale", "--method", "--model", "--model-file", "--sthreads", "--budget", "--objective",
    "--out", "--fig", "--tbl", "--config", "--requests", "--dataset", "--iters", "--workers",
    "--pool-workers", "--layers", "--dim", "--kernel", "--pipeline", "--trace", "--metrics",
    "--backend", "--queue-depth", "--batch", "--qps", "--duration", "--inject", "--deadline-ms",
    "--batch-size",
];

/// Positional arguments: whatever is not an option or an option's value.
fn positionals(rest: &[String]) -> Vec<&str> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < rest.len() {
        let a = rest[i].as_str();
        if VALUE_OPTS.contains(&a) {
            i += 2;
        } else if a.starts_with("--") {
            i += 1;
        } else {
            out.push(a);
            i += 1;
        }
    }
    out
}

fn opt_val<'a>(rest: &'a [String], name: &str) -> Option<&'a str> {
    rest.iter()
        .position(|a| a == name)
        .and_then(|i| rest.get(i + 1))
        .map(String::as_str)
}

fn opt_u32(rest: &[String], name: &str, default: u32) -> Result<u32, String> {
    match opt_val(rest, name) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| format!("bad {name} value '{v}'")),
    }
}

fn opt_f64(rest: &[String], name: &str, default: f64) -> Result<f64, String> {
    match opt_val(rest, name) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| format!("bad {name} value '{v}'")),
    }
}

fn has_flag(rest: &[String], name: &str) -> bool {
    rest.iter().any(|a| a == name)
}

/// Resolve the model for a subcommand: `--model-file PATH` wins (and
/// replaces the model positional), else `name` is looked up in the zoo or
/// treated as a spec path. The zoo's error enumerates the available names.
fn resolve_model(rest: &[String], name: Option<&str>, cmd: &str) -> Result<Arc<ModelSpec>, String> {
    if let Some(p) = opt_val(rest, "--model-file") {
        return ModelSpec::from_file(std::path::Path::new(p))
            .map(Arc::new)
            .map_err(|e| e.to_string());
    }
    let name = name.ok_or_else(|| format!("{cmd} needs a model (or --model-file path.gnn)"))?;
    ModelZoo::builtin().resolve(name)
}

fn parse_dataset(s: &str) -> Result<Dataset, String> {
    Dataset::parse(s).ok_or_else(|| format!("unknown dataset '{s}' (AK|AD|HW|CP|SL)"))
}

fn parse_method(s: &str) -> Result<Method, String> {
    Method::parse(s).ok_or_else(|| format!("unknown method '{s}' (fggp|dsw)"))
}

/// Shared `<model> <dataset> [--scale N]` parsing for the workload-taking
/// subcommands (simulate / tune). With `--model FILE-or-NAME` or
/// `--model-file PATH` the model positional is omitted and the dataset
/// moves up front.
fn parse_workload(rest: &[String], cmd: &str) -> Result<(Arc<ModelSpec>, Dataset, u32), String> {
    let pos = positionals(rest);
    let by_opt = opt_val(rest, "--model-file").is_some() || opt_val(rest, "--model").is_some();
    let (model_name, dataset_pos) = if by_opt {
        (opt_val(rest, "--model"), pos.first().copied())
    } else {
        (pos.first().copied(), pos.get(1).copied())
    };
    let spec = resolve_model(rest, model_name, cmd)?;
    let d = parse_dataset(dataset_pos.ok_or_else(|| format!("{cmd} needs a dataset"))?)?;
    let scale = opt_u32(rest, "--scale", DEFAULT_SCALE)?;
    Ok((spec, d, scale))
}

/// Model shape for `validate`/`bench`: explicit `--layers`/`--dim` force
/// a uniform shape; a spec-file model (however it was passed — it is one
/// exactly when it isn't a builtin zoo entry) otherwise runs at its own
/// declared `dims`; zoo entries keep the fast historical defaults (their
/// declared shape is the 128-dim paper config — too slow for a smoke
/// check against the dense oracle).
fn opt_dims(
    rest: &[String],
    spec: &ModelSpec,
    def_layers: u32,
    def_dim: u32,
) -> Result<ModelDims, String> {
    if opt_val(rest, "--layers").is_some() || opt_val(rest, "--dim").is_some() {
        return Ok(ModelDims::uniform(
            opt_u32(rest, "--layers", def_layers)?,
            opt_u32(rest, "--dim", def_dim)?,
        ));
    }
    let is_builtin = ModelZoo::builtin()
        .get(spec.name())
        .map(|z| z.fingerprint() == spec.fingerprint())
        .unwrap_or(false);
    if is_builtin {
        Ok(ModelDims::uniform(def_layers, def_dim))
    } else {
        Ok(spec.dims())
    }
}

/// `--pipeline on|group|off` for the executor-running subcommands
/// (bench / validate); defaults to the pipelined executor.
fn opt_pipeline(rest: &[String]) -> Result<PipelineMode, String> {
    match opt_val(rest, "--pipeline").unwrap_or("on") {
        "on" | "interval" => Ok(PipelineMode::Interval),
        "group" => Ok(PipelineMode::Group),
        "off" => Ok(PipelineMode::Off),
        other => Err(format!("bad --pipeline value '{other}' (on|group|off)")),
    }
}

/// `bench --kernel naive|blocked|simd`: the compute layer of the timed
/// runs; defaults to the blocked kernel tier.
fn opt_kernel(rest: &[String]) -> Result<KernelMode, String> {
    match opt_val(rest, "--kernel").unwrap_or("blocked") {
        "blocked" => Ok(KernelMode::Blocked),
        "simd" => Ok(KernelMode::Simd),
        "naive" => Ok(KernelMode::Naive),
        other => Err(format!("bad --kernel value '{other}' (naive|blocked|simd)")),
    }
}

/// `--config FILE`: load a tuned design point from a `switchblade tune`
/// artifact (see USAGE); `None` means the Tbl III default.
fn opt_design(rest: &[String]) -> Result<Option<dse::DesignPoint>, String> {
    match opt_val(rest, "--config") {
        None => Ok(None),
        Some(p) => dse::load_design(std::path::Path::new(p)).map(Some),
    }
}

/// `--trace F` / `--metrics F` wiring shared by the observability-aware
/// subcommands (bench / simulate / validate / serve / tune): open a trace
/// session and reset the metrics registry up front, export both files
/// at [`Obs::finish`]. See OBSERVABILITY in the usage text.
struct Obs {
    trace_path: Option<PathBuf>,
    metrics_path: Option<PathBuf>,
    session: Option<trace::Session>,
}

fn obs_begin(rest: &[String]) -> Obs {
    let trace_path = opt_val(rest, "--trace").map(PathBuf::from);
    let metrics_path = opt_val(rest, "--metrics").map(PathBuf::from);
    if metrics_path.is_some() {
        // One command = one metrics run; recording happens regardless
        // (it is cheap), the flag only controls reset + export.
        metrics::reset();
    }
    let session = trace_path.is_some().then(trace::begin);
    Obs {
        trace_path,
        metrics_path,
        session,
    }
}

impl Obs {
    fn finish(self) -> Result<(), String> {
        if let Some(sess) = self.session {
            let tr = sess.end();
            let path = self.trace_path.expect("session implies a path");
            tr.write_chrome(&path)
                .map_err(|e| format!("writing trace {}: {e}", path.display()))?;
            eprintln!(
                "wrote trace {} ({} spans{}) — load in chrome://tracing or ui.perfetto.dev",
                path.display(),
                tr.spans.len(),
                if tr.dropped > 0 {
                    format!(", {} dropped", tr.dropped)
                } else {
                    String::new()
                }
            );
        }
        if let Some(path) = self.metrics_path {
            let snap = metrics::snapshot();
            snap.write(&path)
                .map_err(|e| format!("writing metrics {}: {e}", path.display()))?;
            eprintln!("wrote metrics {} ({} series)", path.display(), snap.entries.len());
        }
        Ok(())
    }
}

// ---- subcommands ---------------------------------------------------------------

fn cmd_compile(rest: &[String]) -> Result<(), String> {
    let pos = positionals(rest);
    let spec = resolve_model(rest, pos.first().copied(), "compile")?;
    let prog = compile(&spec.graph());
    print!("{}", prog.disassemble());
    println!(
        "; weights: {} tensors, {}",
        prog.weights.len(),
        bytes(prog.weight_bytes())
    );
    Ok(())
}

fn cmd_partition(rest: &[String]) -> Result<(), String> {
    let pos = positionals(rest);
    let d = parse_dataset(pos.first().ok_or("partition needs a dataset")?)?;
    let scale = opt_u32(rest, "--scale", DEFAULT_SCALE)?;
    let spec = resolve_model(rest, Some(opt_val(rest, "--model").unwrap_or("GCN")), "partition")?;
    let method = parse_method(opt_val(rest, "--method").unwrap_or("fggp"))?;
    let accel = AcceleratorConfig::switchblade();
    let prog = compile(&spec.graph());
    let pc = accel.partition_config(&prog);
    eprintln!("generating {} at scale {scale}...", d.full_name());
    let g = d.load(scale);
    let parts = method.run(&g, pc);
    parts
        .validate()
        .map_err(|e| format!("invalid partitioning: {e}"))?;
    let st = pstats::analyze(&parts);
    let mut t = Table::new(
        &format!("{} / {} / {}", d.full_name(), spec.display(), method.name()),
        &["metric", "value"],
    );
    t.row(vec!["vertices".into(), g.num_vertices().to_string()]);
    t.row(vec!["edges".into(), g.num_edges().to_string()]);
    t.row(vec!["intervals".into(), st.num_intervals.to_string()]);
    t.row(vec!["shards".into(), st.num_shards.to_string()]);
    t.row(vec!["occupancy".into(), ff(st.occupancy_rate, 3)]);
    t.row(vec!["loaded".into(), bytes(st.loaded_bytes)]);
    t.row(vec!["useful".into(), bytes(st.useful_bytes)]);
    t.row(vec!["src redundancy".into(), ff(st.src_load_redundancy, 2)]);
    t.print();
    Ok(())
}

fn cmd_simulate(rest: &[String]) -> Result<(), String> {
    let (spec, d, scale) = parse_workload(rest, "simulate")?;
    let sthreads = opt_u32(rest, "--sthreads", 3)?;
    let method = parse_method(opt_val(rest, "--method").unwrap_or("fggp"))?;
    let obs = obs_begin(rest);
    let accel = AcceleratorConfig::switchblade().with_sthreads(sthreads);
    let prog = compile(&spec.graph());
    let pc = accel.partition_config(&prog);
    eprintln!("generating {} at scale {scale}...", d.full_name());
    let g = d.load(scale);
    let parts = method.run(&g, pc);
    let r = simulate(&prog, &parts, &accel);
    let e = switchblade::energy::switchblade_energy(&r, accel.freq_hz, true);
    let mut t = Table::new(
        &format!(
            "{} on {} (scale {scale}, {sthreads} sThreads, {})",
            spec.display(),
            d.full_name(),
            method.name()
        ),
        &["metric", "value"],
    );
    t.row(vec!["cycles".into(), format!("{:.0}", r.cycles)]);
    t.row(vec!["latency".into(), format!("{:.3} ms", r.seconds * 1e3)]);
    t.row(vec!["VU util".into(), ff(r.vu_utilization(), 3)]);
    t.row(vec!["MU util".into(), ff(r.mu_utilization(), 3)]);
    t.row(vec!["BW util".into(), ff(r.bw_utilization(), 3)]);
    t.row(vec!["overall util".into(), ff(r.overall_utilization(), 3)]);
    t.row(vec!["DRAM traffic".into(), bytes(r.traffic.total())]);
    for (tag, b) in r.traffic.iter() {
        t.row(vec![format!("  traffic {}", tag.name()), bytes(b)]);
    }
    t.row(vec!["shards".into(), r.shards_processed.to_string()]);
    t.row(vec!["instructions".into(), r.instructions.to_string()]);
    t.row(vec!["energy".into(), format!("{:.3} mJ", e.total_j() * 1e3)]);
    t.print();
    // One recorder for utilizations + per-tag traffic — the table above,
    // repro, and the metrics artifact all read the same SimResult.
    r.record_metrics();
    metrics::gauge("sim_energy_j", e.total_j());
    obs.finish()
}

/// `tune`: budgeted design-space exploration for one workload.
fn cmd_tune(rest: &[String]) -> Result<(), String> {
    let (spec, d, scale) = parse_workload(rest, "tune")?;
    let budget = opt_u32(rest, "--budget", 64)? as usize;
    let obj_s = opt_val(rest, "--objective").unwrap_or("latency");
    let objective = Objective::parse(obj_s)
        .ok_or_else(|| format!("unknown objective '{obj_s}' (latency|energy|edp)"))?;
    let out_dir = PathBuf::from(opt_val(rest, "--out").unwrap_or("results"));

    let opts = TuneOptions {
        budget,
        objective,
        ..Default::default()
    };
    let obs = obs_begin(rest);
    let caches = Caches::new(scale);
    eprintln!(
        "tuning {} on {} (scale 1/2^{scale}): evaluating {} of {} grid points...",
        spec.display(),
        d.full_name(),
        if budget == 0 {
            opts.space.len()
        } else {
            budget.min(opts.space.len())
        },
        opts.space.len()
    );
    let t0 = std::time::Instant::now();
    let r = dse::tune(&spec, d, &caches, &opts);
    eprintln!("swept {} points in {:?}", r.evaluated.len(), t0.elapsed());

    println!();
    r.frontier_table().print();
    println!();
    print!("{}", r.summary());
    println!();

    let slug = format!("{}_{}", spec.name().to_lowercase(), d.code().to_lowercase());
    let sweep = r.sweep_table();
    let csv = out_dir.join(format!("dse_{slug}_sweep.csv"));
    sweep.write_csv(&csv).map_err(|e| e.to_string())?;
    let json = out_dir.join(format!("dse_{slug}_sweep.json"));
    sweep.write_json(&json).map_err(|e| e.to_string())?;
    let fcsv = out_dir.join(format!("dse_{slug}_frontier.csv"));
    r.frontier_table().write_csv(&fcsv).map_err(|e| e.to_string())?;
    eprintln!("wrote {}, {}, {}", csv.display(), json.display(), fcsv.display());
    r.caches.record_metrics();
    metrics::counter_abs("dse_points_evaluated", r.evaluated.len() as u64);
    obs.finish()
}

fn cmd_repro(rest: &[String]) -> Result<(), String> {
    let scale = opt_u32(rest, "--scale", DEFAULT_SCALE)?;
    let out_dir = PathBuf::from(opt_val(rest, "--out").unwrap_or("results"));
    let all = has_flag(rest, "--all")
        || (opt_val(rest, "--fig").is_none() && opt_val(rest, "--tbl").is_none());
    let fig = opt_val(rest, "--fig");
    let tbl = opt_val(rest, "--tbl");
    let mut h = Harness {
        scale,
        ..Default::default()
    };
    if let Some(dp) = opt_design(rest)? {
        eprintln!("tuned accelerator config: {}", dp.label());
        h.accel = dp.accel();
    }
    let cache = Caches::new(scale);
    eprintln!("harness scale: 1/2^{scale} of paper dataset sizes");

    let want = |x: &str| all || fig == Some(x);
    let want_t = |x: &str| all || tbl == Some(x);

    let mut tables: Vec<Table> = Vec::new();
    if want_t("4") {
        tables.push(h.tbl04(&cache));
    }
    if want("7") || want("8") || want("9") {
        eprintln!("running 4 models x 5 datasets sweep...");
        let rows = h.eval_all(&cache);
        if want("7") {
            tables.push(h.fig07(&rows));
        }
        if want("8") {
            tables.push(h.fig08(&rows));
        }
        if want("9") {
            tables.push(h.fig09(&rows));
        }
    }
    if want("10") {
        eprintln!("running Fig 10 (utilisation, 1 vs 3 sThreads)...");
        tables.push(h.fig10(&cache));
    }
    if want("11") {
        eprintln!("running Fig 11 (sThread sweep)...");
        tables.push(h.fig11(&cache, &[1, 2, 3, 4, 6]));
    }
    if want("12") {
        eprintln!("running Fig 12 (occupancy)...");
        tables.push(h.fig12(&cache));
    }
    if want("13") {
        eprintln!("running Fig 13 (DB 8->13 MB)...");
        tables.push(h.fig13(&cache));
    }
    if want_t("5") {
        tables.push(h.tbl05());
    }
    for t in &tables {
        println!();
        t.print();
        let slug: String = t
            .title
            .chars()
            .take_while(|c| *c != '—')
            .collect::<String>()
            .trim()
            .to_lowercase()
            .replace(' ', "_");
        let file = out_dir.join(format!("{slug}.csv"));
        t.write_csv(&file).map_err(|e| e.to_string())?;
    }
    eprintln!("\nCSV written to {}/", out_dir.display());
    Ok(())
}

/// `bench`: functional-executor throughput, single vs shard-parallel,
/// interval pipeline on vs off (see PIPELINE in help). Prints a table
/// plus stable `key=value` lines `scripts/bench.sh` greps into
/// `BENCH_exec.json`. With `--profile`, adds the walk-level per-(group,
/// phase) timing table (including the pipelining `prepare` row), the
/// preserved naive-kernel (legacy) timing, and the `exec_profile_json=`
/// trailer (see PROFILER in help).
fn cmd_bench(rest: &[String]) -> Result<(), String> {
    let spec = resolve_model(rest, Some(opt_val(rest, "--model").unwrap_or("GCN")), "bench")?;
    let d = parse_dataset(opt_val(rest, "--dataset").unwrap_or("AK"))?;
    let scale = opt_u32(rest, "--scale", DEFAULT_SCALE)?;
    let iters = opt_u32(rest, "--iters", 3)?.max(1) as usize;
    // `--pool-workers` is the pool-centric spelling of `--workers`
    // (either sets the persistent pool's width; 0 = sThread count).
    let workers = match opt_val(rest, "--pool-workers") {
        Some(_) => opt_u32(rest, "--pool-workers", 0)? as usize,
        None => opt_u32(rest, "--workers", 0)? as usize,
    };
    let profile = has_flag(rest, "--profile");
    let sweep = has_flag(rest, "--sweep");
    let kernel = opt_kernel(rest)?;
    let pipeline = opt_pipeline(rest)?;
    let batch = opt_u32(rest, "--batch-size", 1)?.max(1) as usize;
    let dims = opt_dims(rest, &spec, 2, 32)?;
    let ir = spec
        .build(dims)
        .map_err(|e| format!("{}: {e}", spec.name()))?;
    let accel = AcceleratorConfig::switchblade();
    eprintln!("generating {} at scale {scale}...", d.full_name());
    let g = d.load(scale);
    let obs = obs_begin(rest);
    let b = bench_executor(&BenchRequest {
        workers,
        iters,
        profile,
        kernel,
        pipeline,
        sweep,
        batch,
        ..BenchRequest::new(&ir, &g, &accel)
    });
    if !b.bit_identical {
        return Err(
            "executor runs diverged bitwise (single vs parallel vs simd vs pipeline-off \
             vs legacy vs sweep vs batched)"
                .into(),
        );
    }
    let mut t = Table::new(
        &format!(
            "executor throughput — {} on {} (scale {scale}, dims {dims}, {} iters)",
            spec.display(),
            d.full_name(),
            b.iters
        ),
        &["metric", "value"],
    );
    t.row(vec!["vertices".into(), b.vertices.to_string()]);
    t.row(vec!["workers".into(), b.workers.to_string()]);
    t.row(vec!["kernel".into(), b.kernel.label().into()]);
    t.row(vec![
        "single-worker".into(),
        format!("{:.3} ms/run", b.secs_single * 1e3),
    ]);
    t.row(vec![
        "shard-parallel".into(),
        format!("{:.3} ms/run", b.secs_parallel * 1e3),
    ]);
    t.row(vec![
        "simd kernels".into(),
        format!("{:.3} ms/run", b.secs_simd * 1e3),
    ]);
    t.row(vec!["pipeline".into(), b.pipeline.label().into()]);
    if let Some(off) = b.secs_pipeline_off {
        t.row(vec![
            "pipeline off".into(),
            format!("{:.3} ms/run", off * 1e3),
        ]);
        t.row(vec![
            "pipeline speedup".into(),
            format!("{:.2}x", b.pipeline_speedup().unwrap_or(0.0)),
        ]);
    }
    t.row(vec![
        "prefetched intervals".into(),
        b.prepared_intervals.to_string(),
    ]);
    if let Some(legacy) = b.secs_legacy {
        t.row(vec![
            "legacy kernels".into(),
            format!("{:.3} ms/run", legacy * 1e3),
        ]);
        t.row(vec![
            "kernel speedup".into(),
            format!("{:.2}x", b.kernel_speedup().unwrap_or(0.0)),
        ]);
    }
    t.row(vec![
        "throughput".into(),
        format!("{:.0} vertices/s", b.vertices_per_sec()),
    ]);
    t.row(vec!["speedup".into(), format!("{:.2}x", b.speedup())]);
    t.row(vec![
        "scratch hit rate".into(),
        format!(
            "{:.1}% ({} hits / {} misses)",
            b.scratch.hit_rate() * 100.0,
            b.scratch.hits,
            b.scratch.misses
        ),
    ]);
    t.row(vec![
        "pool".into(),
        format!(
            "{} threads spawned, {} batches / {} shards, {:.0}% busy",
            b.pool.spawned,
            b.pool.batches,
            b.pool.shards,
            b.pool.utilization() * 100.0
        ),
    ]);
    for &(w, s) in &b.sweep {
        t.row(vec![
            format!("sweep w={w}"),
            format!("{:.3} ms/run", s * 1e3),
        ]);
    }
    if let Some(a) = b.batch_amortization {
        t.row(vec![
            format!("batch B={}", b.batch),
            format!("{a:.2}x amortization"),
        ]);
    }
    t.print();
    if let Some(p) = &b.profile {
        println!();
        p.table().print();
    }
    // Publish the probe into the metrics registry (the single source
    // `--metrics` exports and scripts/bench.sh reads), then echo the
    // historical stdout trailers from the same struct — table, trailer
    // and artifact can no longer disagree.
    b.record_metrics();
    println!("exec_ms_single={:.3}", b.secs_single * 1e3);
    println!("exec_ms_parallel={:.3}", b.secs_parallel * 1e3);
    println!("exec_ms_simd={:.3}", b.secs_simd * 1e3);
    println!("exec_simd_speedup={:.3}", b.simd_speedup());
    println!("exec_kernel={}", b.kernel.label());
    println!("exec_workers={}", b.workers);
    println!("exec_speedup={:.3}", b.speedup());
    println!("exec_bitmatch={}", b.bit_identical);
    println!("exec_pool_spawned={}", b.pool.spawned);
    println!("exec_pool_batches={}", b.pool.batches);
    println!("exec_pool_shards={}", b.pool.shards);
    println!("exec_pool_utilization={:.4}", b.pool.utilization());
    println!("exec_pool_queue_depth={:.3}", b.pool.queue_depth());
    for &(w, s) in &b.sweep {
        println!("exec_ms_w{w}={:.3}", s * 1e3);
    }
    println!("exec_scratch_hits={}", b.scratch.hits);
    println!("exec_scratch_misses={}", b.scratch.misses);
    println!("exec_scratch_hit_rate={:.4}", b.scratch.hit_rate());
    println!("exec_pipeline={}", b.pipeline.label());
    println!("exec_prepared={}", b.prepared_intervals);
    if let Some(off) = b.secs_pipeline_off {
        println!("exec_ms_pipeline_off={:.3}", off * 1e3);
        println!(
            "exec_pipeline_speedup={:.3}",
            b.pipeline_speedup().unwrap_or(0.0)
        );
    }
    if let Some(legacy) = b.secs_legacy {
        println!("exec_ms_legacy={:.3}", legacy * 1e3);
    }
    if let Some(a) = b.batch_amortization {
        println!("exec_batch={}", b.batch);
        println!("exec_batch_amortization={a:.3}");
    }
    if let Some(p) = &b.profile {
        println!("exec_profile_json={}", p.to_json());
    }
    obs.finish()
}

fn cmd_serve(rest: &[String]) -> Result<(), String> {
    match opt_val(rest, "--backend").unwrap_or("native") {
        "native" => cmd_serve_native(rest),
        "pjrt" => cmd_serve_pjrt(rest),
        other => Err(format!("bad --backend value '{other}' (native|pjrt)")),
    }
}

/// The default serving path: the persistent native engine
/// (`switchblade::serve`). Any zoo or `--model-file` spec is servable
/// — the old hard requirement for AOT artifacts now applies only to
/// `--backend pjrt`.
fn cmd_serve_native(rest: &[String]) -> Result<(), String> {
    use switchblade::serve::{run_bench, BenchOptions, Engine, EngineConfig, EntryId};

    // Models: positionals + a comma-separated `--model` list +
    // `--model-file`; default GCN. Duplicate entries collapse in the
    // engine (same model, dims, graph → same entry).
    let mut specs: Vec<Arc<ModelSpec>> = Vec::new();
    for name in positionals(rest) {
        specs.push(ModelZoo::builtin().resolve(name)?);
    }
    if let Some(names) = opt_val(rest, "--model") {
        for name in names.split(',').filter(|s| !s.is_empty()) {
            specs.push(ModelZoo::builtin().resolve(name)?);
        }
    }
    if let Some(p) = opt_val(rest, "--model-file") {
        specs.push(
            ModelSpec::from_file(std::path::Path::new(p))
                .map(Arc::new)
                .map_err(|e| e.to_string())?,
        );
    }
    if specs.is_empty() {
        specs.push(ModelZoo::builtin().resolve("gcn")?);
    }

    let d = parse_dataset(opt_val(rest, "--dataset").unwrap_or("AK"))?;
    let scale = opt_u32(rest, "--scale", DEFAULT_SCALE)?;
    let requests = opt_u32(rest, "--requests", 32)? as usize;
    let qps = opt_f64(rest, "--qps", 0.0)?;
    let duration = opt_f64(rest, "--duration", 2.0)?;
    if requests == 0 && qps <= 0.0 {
        return Err("serve needs --requests >= 1 (latency percentiles are undefined \
                    over an empty run)"
            .into());
    }
    let deadline_ms = match opt_val(rest, "--deadline-ms") {
        Some(v) => Some(
            v.parse::<u64>()
                .map_err(|_| format!("bad --deadline-ms value '{v}'"))?,
        ),
        None => None,
    };
    // Deterministic fault injection (see RELIABILITY in `--help`): armed
    // for the whole run; every site is a single atomic load when absent.
    let injected = match opt_val(rest, "--inject") {
        Some(spec) => {
            let plan = switchblade::obs::faultinject::parse(spec)
                .map_err(|e| format!("bad --inject spec: {e}"))?;
            eprintln!("fault injection armed: {spec}");
            switchblade::obs::faultinject::arm(plan);
            true
        }
        None => false,
    };
    let cfg = EngineConfig {
        queue_depth: opt_u32(rest, "--queue-depth", 64)? as usize,
        batch_max: opt_u32(rest, "--batch", 8)? as usize,
        workers: opt_u32(rest, "--pool-workers", opt_u32(rest, "--workers", 0)?)? as usize,
        kernel: opt_kernel(rest)?,
        pipeline: opt_pipeline(rest)?,
        ..EngineConfig::default()
    };
    let cfg = if let Some(dp) = opt_design(rest)? {
        eprintln!("tuned engine config: {}", dp.label());
        EngineConfig {
            accel: dp.accel(),
            method: dp.method,
            ..cfg
        }
    } else {
        cfg
    };

    let obs = obs_begin(rest);
    eprintln!("generating {} at scale {scale}...", d.full_name());
    let g = switchblade::coordinator::GraphCache::new(scale).get(d);
    let mut engine = Engine::new(cfg);
    let mut ids = Vec::new();
    for spec in &specs {
        let dims = opt_dims(rest, spec, 2, 32)?;
        let id = engine.register(spec, dims, g.clone())?;
        eprintln!("registered {}", engine.info(id).label);
        ids.push(id);
    }

    // Differential pin: every entry must reproduce a direct (cold)
    // executor run of the same seed bit for bit before anything is
    // timed — the engine's warm reuse must not change a single bit.
    let mut verified = false;
    if has_flag(rest, "--verify") {
        const VERIFY_SEED: u64 = 1;
        for (spec, id) in specs.iter().zip(&ids) {
            let dims = opt_dims(rest, spec, 2, 32)?;
            let ir = spec.build(dims).map_err(|e| format!("{}: {e}", spec.name()))?;
            let want = switchblade::coordinator::reference_run(
                &ir,
                &g,
                &cfg.accel,
                cfg.method,
                cfg.workers,
                cfg.kernel,
                cfg.pipeline,
                VERIFY_SEED,
            );
            let got = engine
                .submit_seeded(*id, VERIFY_SEED)
                .map_err(|e| e.to_string())?
                .wait()
                .map_err(|e| e.to_string())?;
            if !got.out.bits_eq(&want) {
                return Err(format!(
                    "{}: engine output diverged from the direct executor run \
                     (max |delta| {:.2e})",
                    engine.info(*id).label,
                    got.out.max_abs_diff(&want)
                ));
            }
            eprintln!(
                "verified {}: bit-identical to a direct executor run",
                engine.info(*id).label
            );
        }
        verified = true;
    }

    let report = run_bench(
        &engine,
        &ids,
        &BenchOptions {
            qps,
            duration_s: duration,
            requests,
            deadline_ms,
            ..BenchOptions::default()
        },
    );

    // Per-entry engine health: each stats probe round-trips through its
    // entry's queue, so it reflects everything the run admitted.
    let mut t = Table::new(
        &format!("serve [native] {} scale {scale}", d.full_name()),
        &["entry", "requests", "batches", "max", "warm ms", "scratch hit%", "pool", "health"],
    );
    let mut seen: Vec<EntryId> = Vec::new();
    for id in &ids {
        if seen.contains(id) {
            continue;
        }
        seen.push(*id);
        let st = engine.stats(*id).map_err(|e| e.to_string())?;
        let health = if st.quarantined {
            "quarantined".to_string()
        } else if st.restarts > 0 {
            format!("{} restarts (rung {})", st.restarts, st.rung)
        } else {
            "ok".to_string()
        };
        t.row(vec![
            engine.info(*id).label.clone(),
            st.requests.to_string(),
            st.batches.to_string(),
            st.max_batch.to_string(),
            ff(st.warm_s * 1e3, 1),
            ff(st.scratch.hit_rate() * 100.0, 1),
            format!("{}w/{}sp", st.pool.workers, st.pool.spawned),
            health,
        ]);
    }
    t.print();
    report.table("latency / throughput").print();
    report.record_metrics();

    // Greppable trailers (check.sh's serve smoke stage pins these).
    println!("serve_backend=native");
    println!("serve_entries={}", engine.num_entries());
    println!("serve_requests={}", report.completed);
    println!("serve_rejected={}", report.rejected);
    println!("serve_errors={}", report.errors);
    println!("serve_timeouts={}", report.timeouts);
    println!("serve_qps={:.1}", report.qps());
    println!("serve_p50_ms={:.3}", report.p50() * 1e3);
    println!("serve_p95_ms={:.3}", report.p95() * 1e3);
    println!("serve_p99_ms={:.3}", report.p99() * 1e3);
    if verified {
        println!("serve_verified=ok");
    }
    if injected {
        println!(
            "serve_faults_injected={}",
            switchblade::obs::faultinject::fired_total()
        );
        switchblade::obs::faultinject::disarm();
    }

    if has_flag(rest, "--bench") {
        let out = PathBuf::from(opt_val(rest, "--out").unwrap_or("BENCH_serve.json"));
        if let Some(dir) = out.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .map_err(|e| format!("creating {}: {e}", dir.display()))?;
            }
        }
        std::fs::write(&out, report.to_json())
            .map_err(|e| format!("writing {}: {e}", out.display()))?;
        eprintln!("wrote {}", out.display());
    }

    // Join the entry threads before the trace session ends, so their
    // final spans are flushed into the export.
    drop(engine);
    obs.finish()
}

/// `--backend pjrt`: the original AOT-artifact serving demo over the
/// PJRT runtime, kept for the four paper models that have baked
/// artifacts.
fn cmd_serve_pjrt(rest: &[String]) -> Result<(), String> {
    let spec = resolve_model(rest, Some(opt_val(rest, "--model").unwrap_or("gcn")), "serve")?;
    // The Python side bakes artifacts for the paper four only — anything
    // else is exactly what the native engine (the default backend)
    // serves, so point there instead of failing on a downstream load.
    if switchblade::ir::models::Model::parse(spec.name()).is_none() {
        return Err(format!(
            "--backend pjrt requires an AOT-compiled artifact model (GCN|GAT|SAGE|GGNN); \
             '{}' has no artifacts — drop `--backend pjrt` to serve it through the \
             persistent native engine",
            spec.display()
        ));
    }
    // AOT artifacts are keyed by the canonical (lowercase) model name.
    let model = spec.name().to_lowercase();
    let requests = opt_u32(rest, "--requests", 32)? as usize;
    if requests == 0 {
        return Err("serve needs --requests >= 1 (latency percentiles are undefined \
                    over an empty run)"
            .into());
    }
    let shape = ArtifactShape::default();
    let obs = obs_begin(rest);
    if let Some(dp) = opt_design(rest)? {
        // Predicted accelerator latency for the serving shape under the
        // tuned (config, partition method) point.
        let prog = compile(&spec.graph());
        let accel = dp.accel();
        let el = switchblade::graph::generators::rmat(shape.n, shape.e, 0.57, 0.19, 0.19, 1000);
        let g = switchblade::graph::Csr::from_edge_list(&el);
        let parts = dp.method.run(&g, accel.partition_config(&prog));
        let r = simulate(&prog, &parts, &accel);
        eprintln!(
            "tuned accelerator config: {} — predicted {:.3} ms/request at the \
             serving shape (n={}, e={})",
            dp.label(),
            r.seconds * 1e3,
            shape.n,
            shape.e
        );
    }
    let dir = artifacts_dir();
    let rt = Runtime::cpu().map_err(|e| format!("{e:#}"))?;
    eprintln!("PJRT platform: {}", rt.platform());
    let exe = rt
        .load_model(&dir, &model, shape)
        .map_err(|e| format!("{e:#} — run `make artifacts` first"))?;

    // Serve `requests` random graphs at the artifact shape, executing the
    // AOT-compiled model on the PJRT CPU client. Python is NOT involved.
    let mut lat = Vec::with_capacity(requests);
    let mut errors = 0u64;
    let t_all = std::time::Instant::now();
    for r in 0..requests {
        let el = switchblade::graph::generators::rmat(
            shape.n,
            shape.e,
            0.57,
            0.19,
            0.19,
            1000 + r as u64,
        );
        let g = switchblade::graph::Csr::from_edge_list(&el);
        let mut src = vec![0i32; shape.e];
        let mut dst = vec![0i32; shape.e];
        for (s, d, id) in g.edges_canonical() {
            src[id as usize] = s as i32;
            dst[id as usize] = d as i32;
        }
        let deg: Vec<f32> = (0..shape.n)
            .map(|v| g.in_degree(v as u32) as f32)
            .collect();
        let x = weights::init_features(r as u64, shape.n, shape.d);
        let t0 = std::time::Instant::now();
        let out = {
            let _span = trace::span_args(
                trace::names::REQUEST,
                trace::cat::EXEC,
                trace::TRACK_MAIN,
                -1,
                r as i32,
                -1,
            );
            exe.run(&x, &src, &dst, &deg).map_err(|e| format!("{e:#}"))?
        };
        let dt = t0.elapsed();
        metrics::observe("serve_latency_s", dt.as_secs_f64());
        // Per-request typed failure instead of the old server-wide
        // assert: one poisoned request is counted, not fatal.
        if out.data.iter().all(|v| v.is_finite()) {
            lat.push(dt);
        } else {
            errors += 1;
            metrics::counter("serve_errors", 1);
            eprintln!("request {r}: non-finite output — dropped from the latency tally");
        }
    }
    let total = t_all.elapsed();
    if lat.is_empty() {
        return Err(format!("all {requests} requests produced non-finite outputs"));
    }
    let n = lat.len();
    lat.sort();
    let mut t = Table::new(
        &format!(
            "serve {model} x{requests} (n={}, e={}, d={})",
            shape.n, shape.e, shape.d
        ),
        &["metric", "value"],
    );
    t.row(vec!["completed".into(), n.to_string()]);
    t.row(vec!["errors".into(), errors.to_string()]);
    t.row(vec!["p50 latency".into(), format!("{:?}", lat[n / 2])]);
    t.row(vec![
        "p99 latency".into(),
        format!("{:?}", lat[(n * 99 / 100).min(n - 1)]),
    ]);
    t.row(vec![
        "throughput".into(),
        format!("{:.1} req/s", n as f64 / total.as_secs_f64()),
    ]);
    t.print();
    metrics::gauge("serve_p50_s", lat[n / 2].as_secs_f64());
    metrics::gauge(
        "serve_p99_s",
        lat[(n * 99 / 100).min(n - 1)].as_secs_f64(),
    );
    metrics::gauge("serve_requests_per_sec", n as f64 / total.as_secs_f64());
    metrics::counter_abs("serve_requests", n as u64);
    println!("serve_backend=pjrt");
    println!("serve_requests={n}");
    println!("serve_errors={errors}");
    obs.finish()
}

fn cmd_validate(rest: &[String]) -> Result<(), String> {
    // Historical default: validation runs at a smaller scale (1/2^9) than
    // repro, and zoo models at a small shape (2 layers, 16-dim), so the
    // dense IR reference stays fast. A `--model-file` spec validates at
    // its own declared dims; `--layers`/`--dim` override either.
    let scale = opt_u32(rest, "--scale", 9)?;
    let pos = positionals(rest);
    // Default: sweep the whole zoo (including sage_mean's Mean reduce);
    // `--model`/`--model-file`/a positional narrows it to one model.
    let one = opt_val(rest, "--model").or_else(|| pos.first().copied());
    let specs: Vec<Arc<ModelSpec>> =
        if one.is_some() || opt_val(rest, "--model-file").is_some() {
            vec![resolve_model(rest, one, "validate")?]
        } else {
            ModelZoo::builtin().entries().to_vec()
        };
    let pipeline = opt_pipeline(rest)?;
    let obs = obs_begin(rest);
    let cache = Caches::new(scale);
    let g = cache.graph(Dataset::Ak);
    let accel = AcceleratorConfig::switchblade();
    let mut t = Table::new(
        "numerics: compiled-ISA executor vs IR reference",
        &["model", "dims", "max |delta|", "status"],
    );
    for m in &specs {
        let dims = opt_dims(rest, m, 2, 16)?;
        let ir = m.build(dims).map_err(|e| format!("{}: {e}", m.name()))?;
        let diff =
            switchblade::coordinator::validate_numerics_pipelined(&ir, &g, &accel, pipeline);
        metrics::gauge(
            &format!("validate_max_abs_diff_{}", m.name().to_lowercase()),
            diff as f64,
        );
        let ok = diff < 1e-4;
        t.row(vec![
            m.display(),
            format!("{dims}"),
            format!("{diff:.2e}"),
            if ok { "OK".into() } else { "FAIL".into() },
        ]);
        if !ok {
            return Err(format!("{} numerics diverged: {diff}", m.display()));
        }
    }
    t.print();
    println!(
        "(for the PJRT three-way check, add the `anyhow`/`xla` deps per rust/Cargo.toml's \
         note, then run `cargo test --features pjrt --test integration_runtime`)"
    );
    obs.finish()
}
