//! Fig 12 — SEB occupancy: FGGP vs HyGCN-style window sliding.

use switchblade::coordinator::{Caches, Harness};
use switchblade::util::bench;

fn main() {
    let scale = 8;
    let h = Harness { scale, ..Default::default() };
    let cache = Caches::new(scale);
    let stats = bench::bench(1, 3, || h.fig12(&cache));
    bench::report("fig12/partition(FGGP+DSW x5)", &stats);
    h.fig12(&cache).print();
}
