//! Fig 11 — execution latency vs sThread count (the U-curve).

use switchblade::coordinator::{Caches, Harness};
use switchblade::util::bench;

fn main() {
    let scale = 8;
    let h = Harness { scale, ..Default::default() };
    let cache = Caches::new(scale);
    let counts = [1u32, 2, 3, 4, 6];
    let stats = bench::bench(0, 1, || h.fig11(&cache, &counts));
    bench::report("fig11/sweep(T=1..6)", &stats);
    h.fig11(&cache, &counts).print();
}
